# Convenience targets; everything also works via plain cargo / python.

.PHONY: build test test-faults bench bench-launches bench-serving bench-fusion bench-vm bench-global bench-profile bench-autotune bench-buckets bench-slo artifacts doc

build:
	cargo build --release

test:
	cargo test -q

# Same suite plus the deterministic fault-injection tests (seeded
# compile failures, slow kernels, worker panics) that only compile with
# the non-default `faults` feature.
test-faults:
	cargo test -q --features faults

bench:
	cargo bench

# Executed launch-reduction bench (smoke mode): runs every plan on the
# stitched VM and writes BENCH_launch_reduction.json at the repo root.
bench-launches:
	BENCH_SMOKE=1 cargo bench --bench launch_reduction

# Multi-worker serving throughput bench (smoke mode): sharded pool at
# 1/2/4 workers, writes BENCH_serving_throughput.json at the repo root.
bench-serving:
	BENCH_SMOKE=1 cargo bench --bench serving_throughput

# Fusion-profit bench (smoke mode): greedy vs cost-guided fusion on the
# six Table 2 models, executed on the stitched VM; writes
# BENCH_fusion_profit.json at the repo root.
bench-fusion:
	BENCH_SMOKE=1 cargo bench --bench fusion_profit

# VM wall-clock bench (smoke mode): boxed PR-2 VM vs the memory-planned
# block-parallel VM on all six models, bit-identity checked; writes
# BENCH_vm_wallclock.json at the repo root. FUSION_VM_THREADS is pinned
# so the speedup gate is reproducible across machines.
bench-vm:
	BENCH_SMOKE=1 FUSION_VM_THREADS=2 cargo bench --bench vm_wallclock

# Global-memory stitching bench: overflow corpus executed with the
# third tier on vs off, bit-identity and strict launch reduction gated;
# writes BENCH_global_stitch.json at the repo root.
bench-global:
	BENCH_SMOKE=1 cargo bench --bench global_stitch

# Flight-recorder overhead bench (smoke mode): tracing-on vs -off vs
# baseline on all six models, plus the per-group modeled-vs-measured
# divergence report; writes BENCH_profile_overhead.json at the repo
# root. Full runs gate enabled overhead at <= 5% and disabled at ~0%.
bench-profile:
	BENCH_SMOKE=1 cargo bench --bench profile_overhead

# Feedback-directed autotuning bench (smoke mode): per-epoch oracle
# divergence on all six models (must shrink as measured write-backs
# land) plus a live-pool hot-swap leg (zero request errors across the
# swap); writes BENCH_autotune_convergence.json at the repo root.
bench-autotune:
	BENCH_SMOKE=1 cargo bench --bench autotune_convergence

# Shape-class bucketing bench (smoke mode): one heterogeneous trace
# (24 distinct row lengths) served exact-shape vs bucketed; gates >= 4x
# fewer cold compiles, strictly higher cache hit rate, bounded padding
# waste and bitwise value identity; writes BENCH_shape_buckets.json at
# the repo root.
bench-buckets:
	BENCH_SMOKE=1 cargo bench --bench shape_buckets

# Deadline-SLO bench (smoke mode): slack admission vs a no-deadline
# baseline under a heavy-tailed bursty arrival trace; full runs gate
# admitted-p99-within-deadline at saturation, the baseline miss, and a
# bounded moderate-load shed rate; writes BENCH_deadline_slo.json at
# the repo root.
bench-slo:
	BENCH_SMOKE=1 cargo bench --bench deadline_slo

doc:
	cargo doc --no-deps

# AOT-lower the JAX/Pallas layers to HLO-text artifacts (needs jax).
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts
