# Convenience targets; everything also works via plain cargo / python.

.PHONY: build test bench bench-launches artifacts doc

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

# Executed launch-reduction bench (smoke mode): runs every plan on the
# stitched VM and writes BENCH_launch_reduction.json at the repo root.
bench-launches:
	BENCH_SMOKE=1 cargo bench --bench launch_reduction

doc:
	cargo doc --no-deps

# AOT-lower the JAX/Pallas layers to HLO-text artifacts (needs jax).
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts
