# Convenience targets; everything also works via plain cargo / python.

.PHONY: build test bench artifacts doc

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

doc:
	cargo doc --no-deps

# AOT-lower the JAX/Pallas layers to HLO-text artifacts (needs jax).
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts
