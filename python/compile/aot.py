"""AOT lowering: JAX/Pallas (L2/L1) → HLO text artifacts for the Rust
runtime.

HLO **text**, not ``.serialize()``: jax ≥ 0.5 emits `HloModuleProto`s
with 64-bit instruction ids that the runtime's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Run once at build time (`make artifacts`); never on the request path.

Usage: python -m compile.aot [--out-dir ../artifacts] [--only STEM]
"""

import argparse
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ARTIFACTS


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (`return_tuple=True` so the
    Rust side unwraps a tuple literal)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(fn, shapes) -> str:
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single artifact stem")
    # Back-compat with the original Makefile target.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out).parent if args.out else pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    for stem, (fn, shapes) in ARTIFACTS.items():
        if args.only and stem != args.only:
            continue
        text = lower_artifact(fn, shapes)
        path = out_dir / f"{stem}.hlo.txt"
        path.write_text(text)
        n_kernels = text.count("fusion(") + text.count("fusion.")
        print(f"wrote {path} ({len(text)} chars)")
        del n_kernels

    # Stamp file so `make artifacts` can be a cheap no-op when inputs are
    # unchanged.
    (out_dir / ".stamp").write_text("ok\n")


if __name__ == "__main__":
    main()
