"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every Pallas kernel in this package has a reference implementation here,
written with ordinary jax.numpy ops only. pytest (python/tests) asserts
allclose between kernel and oracle across a hypothesis-driven sweep of
shapes and dtypes.
"""

import jax.numpy as jnp


def softmax_bmm_ref(scores, v):
    """Figure 3's pattern: softmax over the last dim of ``scores``,
    then a batched matmul with ``v``.

    scores: [B, S, S], v: [B, S, D] -> [B, S, D]
    """
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    p = e / s
    return jnp.einsum("bij,bjd->bid", p, v)


def softmax_ref(scores):
    """Numerically-stable softmax over the last dim."""
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def layernorm_ref(x, gamma, beta, eps=1e-6):
    """Layer normalization over the last dim.

    x: [N, D], gamma/beta: [D] -> [N, D]
    """
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta
