"""L1 Pallas kernel: block-composed softmax → BatchDot (Figure 3).

The paper composes `reduce(max) → sub → exp → reduce(sum) → div → dot`
into ONE GPU kernel by giving each op its own parallel loop and stitching
them through on-chip shared memory (`IrEmitterStitched`, §5). The TPU
adaptation (DESIGN.md §Hardware-Adaptation):

- one Pallas *grid cell* plays the thread block (CTA): ``grid=(B,)`` is
  the paper's `Row` schedule with ``split_dim=0, sword=B`` — one block
  per batch element;
- VMEM scratch plays shared memory: the ``exp`` intermediate lives in a
  VMEM scratch buffer between the reduce/divide stages;
- *space sharing* (§5.1.3): ``div`` overwrites the ``exp`` buffer in
  place — exactly the paper's `Divide.1 SHAREs Exponential.1`;
- the MXU plays cuBLAS for the stitched contraction: the final dot
  inside the kernel hits the systolic array per block.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact
runs under the Rust runtime. Real-TPU perf is estimated from the VMEM
footprint in DESIGN.md/EXPERIMENTS.md §Perf.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(scores_ref, v_ref, o_ref, exp_ref):
    """One grid cell = one batch element (one 'thread block').

    scores_ref: [1, S, S] VMEM block of the scores
    v_ref:      [1, S, D] VMEM block of the values
    o_ref:      [1, S, D] output block
    exp_ref:    [S, S]    VMEM scratch — the 'shared memory' intermediary
    """
    scores = scores_ref[0]
    # Stage 1 — Reduce.1 (max), its own loop over rows.
    m = jnp.max(scores, axis=-1, keepdims=True)
    # Stage 2 — subtract + Exponential.1, written to scratch (ALLOC).
    exp_ref[...] = jnp.exp(scores - m)
    # Stage 3 — Reduce.2 (sum) reads the scratch buffer.
    s = jnp.sum(exp_ref[...], axis=-1, keepdims=True)
    # Stage 4 — Divide.1 SHAREs Exponential.1's buffer (in-place reuse,
    # §5.1.3 space sharing).
    exp_ref[...] = exp_ref[...] / s
    # Stage 5 — Dot.1 on the MXU, fed straight from scratch.
    o_ref[0] = jnp.dot(exp_ref[...], v_ref[0], preferred_element_type=o_ref.dtype)


def stitched_softmax_bmm(scores, v):
    """``softmax(scores) @ v`` in a single stitched kernel.

    scores: [B, S, S], v: [B, S, D] -> [B, S, D]
    """
    b, s, s2 = scores.shape
    assert s == s2, f"scores must be square per batch, got {scores.shape}"
    bv, sv, d = v.shape
    assert (bv, sv) == (b, s), f"v shape {v.shape} mismatches scores {scores.shape}"
    return pl.pallas_call(
        _kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, s, s), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), scores.dtype),
        scratch_shapes=[pltpu.VMEM((s, s), scores.dtype)],
        interpret=True,
    )(scores, v)


def vmem_bytes(b, s, d, itemsize=4):
    """Per-block VMEM footprint of the stitched kernel: input block +
    value block + output block + the shared scratch. Used by the §Perf
    roofline estimate (DESIGN.md)."""
    del b  # per-block footprint is batch-independent
    return itemsize * (s * s + s * d + s * d + s * s)
