"""L1 Pallas kernel: block-composed layer normalization.

The second stitched pattern from the benchmarks (the W2V/LR/Speech-style
`reduce → elementwise tail` interaction): `mean-reduce → sub → square →
mean-reduce → rsqrt → scale/shift` in one kernel. Under XLA's baseline
this is ≥2 kernels (each reduce is a fusion root, §3.2); block
composition stitches both reduces and the elementwise tail through
on-chip memory.

Schedule (paper terms): ``Row`` with ``split_dim=0, sword=N/rows_per_block``
— each grid cell normalizes a contiguous strip of rows; all reduction
work for a row stays inside one block (the Table 1 reduce constraint).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, gamma_ref, beta_ref, o_ref, cent_ref, *, eps):
    """cent_ref: [R, D] VMEM scratch holding the centered values between
    the two reduce stages (the 'shared memory' buffer)."""
    x = x_ref[...]
    # Stage 1 — Reduce.1 (mean over the minor dim).
    mu = jnp.mean(x, axis=-1, keepdims=True)
    # Stage 2 — centering, stored to scratch (ALLOC).
    cent_ref[...] = x - mu
    # Stage 3 — Reduce.2 (variance) reads the scratch.
    var = jnp.mean(cent_ref[...] * cent_ref[...], axis=-1, keepdims=True)
    # Stage 4 — normalize in place (space sharing: the centered buffer is
    # overwritten by the normalized values).
    cent_ref[...] = cent_ref[...] * jax.lax.rsqrt(var + eps)
    # Stage 5 — scale/shift elementwise tail.
    o_ref[...] = cent_ref[...] * gamma_ref[...] + beta_ref[...]


def stitched_layernorm(x, gamma, beta, eps=1e-6, rows_per_block=None):
    """Layer norm over the last dim in a single stitched kernel.

    x: [N, D], gamma/beta: [D] -> [N, D]
    """
    n, d = x.shape
    assert gamma.shape == (d,) and beta.shape == (d,)
    if rows_per_block is None:
        # Target a ~128-row strip but never exceed N; N is required to be
        # divisible (the paper's `sword must divide K` legality rule).
        rows_per_block = min(n, 128)
        while n % rows_per_block != 0:
            rows_per_block //= 2
    assert n % rows_per_block == 0, f"{rows_per_block} must divide {n}"
    grid = n // rows_per_block

    def kernel(x_ref, g_ref, b_ref, o_ref, cent_ref):
        _kernel(x_ref, g_ref, b_ref, o_ref, cent_ref, eps=eps)

    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((rows_per_block, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows_per_block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((rows_per_block, d), x.dtype)],
        interpret=True,
    )(x, gamma, beta)


def vmem_bytes(rows_per_block, d, itemsize=4):
    """Per-block VMEM footprint: x strip + gamma + beta + out strip +
    centered scratch (§Perf roofline input)."""
    return itemsize * (rows_per_block * d * 3 + 2 * d)
