"""Pallas kernels (L1) + pure-jnp oracles.

Authored and verified at build time only; lowered into the L2 model's
HLO by `compile.aot` and executed by the Rust runtime.
"""

from .ref import layernorm_ref, softmax_bmm_ref, softmax_ref  # noqa: F401
from .stitched_layernorm import stitched_layernorm  # noqa: F401
from .stitched_softmax_bmm import stitched_softmax_bmm  # noqa: F401
