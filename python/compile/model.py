"""L2: the JAX model — the paper's NMT attention block (the Figure 3
subgraph embedded in a decoder layer), in two variants:

- ``attention_fused``  — the softmax→BatchDot core runs as the L1
  stitched Pallas kernel (FusionStitching's output);
- ``attention_unfused`` — identical math, op-by-op jnp (what the XLA
  baseline executes: each reduce its own fusion root).

Both lower to HLO text via `compile.aot` and are served by the Rust
coordinator; pytest asserts they agree to float tolerance. Weights are
baked in as constants from a fixed seed so the serving artifact takes
only the hidden states.
"""

import jax
import jax.numpy as jnp

from . import kernels

# Shapes baked into the artifacts — keep in sync with the Rust server
# config (rust/src/main.rs `cmd_serve`) and examples/nmt_serving.rs.
BATCH = 8
SEQ = 64
MODEL = 512
DIM = 64
SCALE = 1.0 / (DIM**0.5)

# LayerNorm demo shapes (the W2V/Speech-style pattern).
LN_ROWS = 256
LN_DIM = 512


def _weights(seed: int = 0):
    """Deterministic projection weights, shared by both variants."""
    k = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(k, 3)
    scale = 1.0 / (MODEL**0.5)
    wq = jax.random.normal(kq, (MODEL, DIM), jnp.float32) * scale
    wk = jax.random.normal(kk, (MODEL, DIM), jnp.float32) * scale
    wv = jax.random.normal(kv, (MODEL, DIM), jnp.float32) * scale
    return wq, wk, wv


def _qkv(hidden):
    """Projections + reshape to per-batch tensors. `hidden`: [B*S, MODEL]."""
    wq, wk, wv = _weights()
    q = (hidden @ wq).reshape(BATCH, SEQ, DIM)
    k = (hidden @ wk).reshape(BATCH, SEQ, DIM)
    v = (hidden @ wv).reshape(BATCH, SEQ, DIM)
    scores = jnp.einsum("bid,bjd->bij", q, k) * SCALE
    return scores, v


def attention_fused(hidden):
    """Attention context with the stitched softmax→BMM kernel (L1)."""
    scores, v = _qkv(hidden)
    ctx = kernels.stitched_softmax_bmm(scores, v)
    return (ctx,)


def attention_unfused(hidden):
    """Same math, op-by-op (the XLA-baseline artifact)."""
    scores, v = _qkv(hidden)
    ctx = kernels.softmax_bmm_ref(scores, v)
    return (ctx,)


def _ln_params(seed: int = 1):
    k = jax.random.PRNGKey(seed)
    kg, kb = jax.random.split(k)
    gamma = 1.0 + 0.1 * jax.random.normal(kg, (LN_DIM,), jnp.float32)
    beta = 0.1 * jax.random.normal(kb, (LN_DIM,), jnp.float32)
    return gamma, beta


def layernorm_fused(x):
    """Stitched layer norm over [LN_ROWS, LN_DIM]."""
    gamma, beta = _ln_params()
    return (kernels.stitched_layernorm(x, gamma, beta),)


def layernorm_unfused(x):
    gamma, beta = _ln_params()
    return (kernels.layernorm_ref(x, gamma, beta),)


#: artifact stem -> (function, example input shapes)
ARTIFACTS = {
    "attention_fused": (attention_fused, [(BATCH * SEQ, MODEL)]),
    "attention_unfused": (attention_unfused, [(BATCH * SEQ, MODEL)]),
    "layernorm_fused": (layernorm_fused, [(LN_ROWS, LN_DIM)]),
    "layernorm_unfused": (layernorm_unfused, [(LN_ROWS, LN_DIM)]),
}
