"""AOT lowering tests: every artifact lowers to parseable HLO text with
the entry signature the Rust runtime expects."""

import re

from compile import aot, model


def test_all_artifacts_lower():
    for stem, (fn, shapes) in model.ARTIFACTS.items():
        text = aot.lower_artifact(fn, shapes)
        assert "ENTRY" in text, f"{stem}: no entry computation"
        assert "HloModule" in text
        # return_tuple=True → the root is a tuple
        assert re.search(r"ROOT .*tuple", text) or "(f32[" in text


def test_attention_artifact_signature():
    fn, shapes = model.ARTIFACTS["attention_fused"]
    text = aot.lower_artifact(fn, shapes)
    flat = model.BATCH * model.SEQ
    assert f"f32[{flat},{model.MODEL}]" in text, "input shape must be baked"
    assert f"f32[{model.BATCH},{model.SEQ},{model.DIM}]" in text, "output shape baked"


def test_fused_artifact_contains_stitched_body():
    # interpret-mode pallas lowers to plain HLO: the stitched kernel body
    # (exp/div/dot chain) must appear in the fused artifact.
    fn, shapes = model.ARTIFACTS["attention_fused"]
    text = aot.lower_artifact(fn, shapes)
    for op in ["exponential", "divide", "dot"]:
        assert op in text, f"missing {op} in fused artifact"


def test_unfused_artifact_differs():
    f, sf = model.ARTIFACTS["attention_fused"]
    u, su = model.ARTIFACTS["attention_unfused"]
    assert aot.lower_artifact(f, sf) != aot.lower_artifact(u, su)
