"""Kernel vs oracle — the core correctness signal (L1).

Hypothesis sweeps shapes and dtypes for every Pallas kernel and asserts
allclose against the pure-jnp references in `compile.kernels.ref`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels

jax.config.update("jax_enable_x64", False)


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: 1e-5, jnp.bfloat16: 5e-2}


# ---------------------------------------------------------------------
# stitched_softmax_bmm
# ---------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=6),
    s=st.sampled_from([4, 8, 16, 33, 64]),
    d=st.sampled_from([1, 4, 8, 32]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_softmax_bmm_matches_ref_f32(b, s, d, seed):
    rng = np.random.default_rng(seed)
    scores = _rand(rng, (b, s, s), jnp.float32)
    v = _rand(rng, (b, s, d), jnp.float32)
    got = kernels.stitched_softmax_bmm(scores, v)
    want = kernels.softmax_bmm_ref(scores, v)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=3),
    s=st.sampled_from([8, 16]),
    d=st.sampled_from([8, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_softmax_bmm_matches_ref_bf16(b, s, d, seed):
    rng = np.random.default_rng(seed)
    scores = _rand(rng, (b, s, s), jnp.bfloat16)
    v = _rand(rng, (b, s, d), jnp.bfloat16)
    got = np.asarray(kernels.stitched_softmax_bmm(scores, v), np.float32)
    want = np.asarray(
        kernels.softmax_bmm_ref(
            jnp.asarray(scores, jnp.float32), jnp.asarray(v, jnp.float32)
        )
    )
    np.testing.assert_allclose(got, want, atol=6e-2, rtol=6e-2)


def test_softmax_bmm_rows_sum_to_one_property():
    # softmax(scores) @ ones == ones: probabilities sum to 1 per row.
    rng = np.random.default_rng(7)
    scores = _rand(rng, (4, 32, 32), jnp.float32)
    ones = jnp.ones((4, 32, 1), jnp.float32)
    out = kernels.stitched_softmax_bmm(scores, ones)
    np.testing.assert_allclose(out, np.ones_like(out), atol=1e-5)


def test_softmax_bmm_shift_invariance_property():
    # softmax is invariant to a per-row constant shift.
    rng = np.random.default_rng(8)
    scores = _rand(rng, (2, 16, 16), jnp.float32)
    v = _rand(rng, (2, 16, 8), jnp.float32)
    a = kernels.stitched_softmax_bmm(scores, v)
    b = kernels.stitched_softmax_bmm(scores + 100.0, v)
    np.testing.assert_allclose(a, b, atol=1e-4)


def test_softmax_bmm_extreme_values_stable():
    # the max-subtraction must keep exp from overflowing.
    scores = jnp.full((1, 8, 8), 1e4, jnp.float32)
    v = jnp.ones((1, 8, 4), jnp.float32)
    out = kernels.stitched_softmax_bmm(scores, v)
    assert np.isfinite(np.asarray(out)).all()


def test_softmax_bmm_shape_mismatch_raises():
    scores = jnp.zeros((2, 8, 8), jnp.float32)
    v = jnp.zeros((3, 8, 4), jnp.float32)
    with pytest.raises(AssertionError):
        kernels.stitched_softmax_bmm(scores, v)


# ---------------------------------------------------------------------
# stitched_layernorm
# ---------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([1, 8, 64, 96, 256]),
    d=st.sampled_from([4, 16, 48, 512]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_layernorm_matches_ref_f32(n, d, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (n, d), jnp.float32)
    gamma = _rand(rng, (d,), jnp.float32)
    beta = _rand(rng, (d,), jnp.float32)
    got = kernels.stitched_layernorm(x, gamma, beta)
    want = kernels.layernorm_ref(x, gamma, beta)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    rows=st.sampled_from([1, 2, 8, 32]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_layernorm_rows_per_block_invariant(rows, seed):
    # The schedule (sword) must not change the numbers — the paper's
    # whole premise: schedules tune performance, not semantics.
    rng = np.random.default_rng(seed)
    x = _rand(rng, (64, 32), jnp.float32)
    gamma = jnp.ones((32,), jnp.float32)
    beta = jnp.zeros((32,), jnp.float32)
    a = kernels.stitched_layernorm(x, gamma, beta, rows_per_block=rows)
    b = kernels.layernorm_ref(x, gamma, beta)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


def test_layernorm_output_standardized_property():
    # gamma=1, beta=0: rows have ~zero mean, ~unit variance.
    rng = np.random.default_rng(9)
    x = _rand(rng, (32, 128), jnp.float32)
    out = kernels.stitched_layernorm(
        x, jnp.ones((128,), jnp.float32), jnp.zeros((128,), jnp.float32)
    )
    out = np.asarray(out)
    np.testing.assert_allclose(out.mean(axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(out.std(axis=-1), 1.0, atol=1e-2)
