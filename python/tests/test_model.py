"""L2 model tests: fused vs unfused variants agree; shapes match what the
Rust server bakes in."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def _hidden(seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.standard_normal((model.BATCH * model.SEQ, model.MODEL)), jnp.float32
    )


def test_fused_matches_unfused():
    h = _hidden()
    (fused,) = model.attention_fused(h)
    (unfused,) = model.attention_unfused(h)
    np.testing.assert_allclose(fused, unfused, atol=1e-4, rtol=1e-4)


def test_attention_output_shape():
    h = _hidden()
    (ctx,) = model.attention_fused(h)
    assert ctx.shape == (model.BATCH, model.SEQ, model.DIM)


def test_attention_deterministic_weights():
    # Same input twice → identical output (weights are baked constants).
    a = model.attention_fused(_hidden(1))[0]
    b = model.attention_fused(_hidden(1))[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_layernorm_variants_agree():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((model.LN_ROWS, model.LN_DIM)), jnp.float32)
    (fused,) = model.layernorm_fused(x)
    (unfused,) = model.layernorm_unfused(x)
    np.testing.assert_allclose(fused, unfused, atol=1e-4, rtol=1e-4)


def test_artifact_registry_complete():
    assert set(model.ARTIFACTS) == {
        "attention_fused",
        "attention_unfused",
        "layernorm_fused",
        "layernorm_unfused",
    }
    for _, (fn, shapes) in model.ARTIFACTS.items():
        specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
        out = jax.eval_shape(fn, *specs)
        assert isinstance(out, tuple) and len(out) == 1
