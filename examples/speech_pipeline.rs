//! Speech pipeline walkthrough — the workload where FusionStitching
//! shines in the paper (fusion ratio 0.25, §6.3: "complex interaction
//! patterns among reduce, transpose, concat, and elementwise ops.
//! FusionStitching handles them gracefully").
//!
//! Compiles the Speech training graph under both fusion modes and walks
//! through what deep fusion did: the Work/Span layering, the kernel
//! partition, which groups are block-composed (stitched), and their
//! shared-memory plans.
//!
//! ```bash
//! cargo run --release --example speech_pipeline
//! ```

use fusion_stitching::analysis::SpanAnalysis;
use fusion_stitching::coordinator::pipeline::{compile_module, FusionMode, PipelineConfig};
use fusion_stitching::fusion::GroupKind;
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::models;
use fusion_stitching::schedule::PerfLibrary;

fn main() -> anyhow::Result<()> {
    let (meta, module) = models::by_name("Speech").expect("Speech benchmark");
    let comp = &module.entry;

    // Work/Span analysis — the layering that drives Algorithm 1.
    let spans = SpanAnalysis::run(comp);
    println!(
        "Speech graph: {} instructions, critical path {} layers, {} LC-layers",
        comp.len(),
        spans.critical_path(0),
        spans.lc_layers(comp, 0).len()
    );

    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    let mut cfg = PipelineConfig::default();
    cfg.deep.fuse_batch_dot = meta.fuse_batch_dot;

    let base = compile_module(&module, FusionMode::XlaBaseline, &mut lib, &cfg)?;
    let fs = compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg)?;

    println!(
        "\nXLA baseline : {} kernels ({:.1} us simulated)",
        base.plan.generated_kernel_count(comp),
        base.timing.total_us()
    );
    println!(
        "FusionStitching: {} kernels ({:.1} us simulated) — ratio {:.2}",
        fs.plan.generated_kernel_count(comp),
        fs.timing.total_us(),
        fs.plan.generated_kernel_count(comp) as f64
            / base.plan.generated_kernel_count(comp) as f64
    );

    println!("\nper-kernel view (FusionStitching):");
    for (gid, kernel) in fs.generated_group_ids.iter().zip(&fs.kernels) {
        let group = &fs.plan.groups[*gid];
        let ops: Vec<String> = {
            let mut m: Vec<_> = group.members.iter().copied().collect();
            m.sort();
            m.iter().map(|&i| comp.get(i).opcode.to_string()).collect()
        };
        println!(
            "  {} [{:?}] <<<{}, {}>>> smem {} B{} — {} ops: {}",
            kernel.name,
            group.kind,
            kernel.blocks,
            kernel.threads,
            kernel.shm.total_bytes,
            if kernel.shm.shrink_triggered() { " (shrunk)" } else { "" },
            group.members.len(),
            ops.join(", ")
        );
    }

    let stitched = fs.plan.groups.iter().filter(|g| g.kind == GroupKind::Stitched).count();
    println!("\n{stitched} block-composed (stitched) kernels — the paper's §5 contribution");
    Ok(())
}
