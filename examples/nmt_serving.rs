//! End-to-end driver — the deliverable that proves all three layers
//! compose on a real workload:
//!
//!   L1 (Pallas stitched softmax→BMM kernel) → L2 (JAX attention block)
//!   → `make artifacts` (AOT HLO text) → Rust runtime (the HLO-text
//!   interpreter behind the PJRT-shaped client) → L3 serving
//!   coordinator (dynamic batching), fused vs unfused.
//!
//! It serves batched translation-style requests against both artifact
//! variants, checks the numerics agree between them (the stitched kernel
//! is semantically identical to the op-by-op graph), and reports
//! latency/throughput. Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example nmt_serving
//! ```

use fusion_stitching::coordinator::batcher::BatchPolicy;
use fusion_stitching::coordinator::metrics::throughput_rps;
use fusion_stitching::coordinator::{
    PoolConfig, ServerConfig, ServingCoordinator, ServingPool, StreamingSummary,
};
use std::path::Path;
use std::time::{Duration, Instant};

// Shapes baked by python/compile/aot.py (see python/compile/model.py).
const BATCH: usize = 8;
const SEQ: usize = 64;
const MODEL: usize = 512;
const DIM: usize = 64;
const REQUESTS: usize = 64;

fn request(i: usize) -> Vec<f32> {
    // Deterministic pseudo-embedding for request i.
    (0..SEQ * MODEL)
        .map(|j| (((i * 131 + j * 31) % 977) as f32 / 977.0) - 0.5)
        .collect()
}

fn serve(artifact: &str) -> anyhow::Result<(Vec<Vec<f32>>, StreamingSummary, f64)> {
    let srv = ServingCoordinator::start(Path::new("artifacts"), config(artifact))?;
    let _ = srv.infer(request(0))?; // warmup: first execute touches cold buffers

    let mut lat = StreamingSummary::default();
    let mut outputs = Vec::new();
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for i in 0..REQUESTS {
        pending.push((Instant::now(), srv.infer_async(request(i))?));
        if pending.len() == BATCH {
            for (t, rx) in pending.drain(..) {
                outputs.push(rx.recv()??);
                lat.record(t.elapsed());
            }
        }
    }
    for (t, rx) in pending.drain(..) {
        outputs.push(rx.recv()??);
        lat.record(t.elapsed());
    }
    let rps = throughput_rps(lat.count() as usize, t0.elapsed());
    srv.shutdown().ok();
    Ok((outputs, lat, rps))
}

fn config(artifact: &str) -> ServerConfig {
    ServerConfig {
        artifact: artifact.to_string(),
        batch: BATCH,
        in_elems_per_request: SEQ * MODEL,
        out_elems_per_request: SEQ * DIM,
        input_dims: vec![(BATCH * SEQ) as i64, MODEL as i64],
        policy: BatchPolicy { max_batch: BATCH, max_wait: Duration::from_millis(2) },
        compile: None,
        trace: None,
        buckets: None,
        deadline: None,
        faults: None,
    }
}

/// Serve the same request stream through the sharded multi-worker pool:
/// four client-side shape keys spread the traffic over the shards
/// (sticky routing keeps each shard's batches shape-pure).
fn serve_pooled(artifact: &str, workers: usize) -> anyhow::Result<(StreamingSummary, f64)> {
    let pool = ServingPool::start(
        Path::new("artifacts"),
        config(artifact),
        PoolConfig { workers, ..PoolConfig::default() },
    )?;
    for key in 0..4u64 {
        pool.infer_keyed(key, request(0))?; // warmup per shard
    }
    let mut lat = StreamingSummary::default();
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for i in 0..REQUESTS {
        let key = (i % 4) as u64;
        pending.push((Instant::now(), pool.infer_keyed_async(key, request(i))?));
        if pending.len() == BATCH {
            for (t, rx) in pending.drain(..) {
                rx.recv()??;
                lat.record(t.elapsed());
            }
        }
    }
    for (t, rx) in pending.drain(..) {
        rx.recv()??;
        lat.record(t.elapsed());
    }
    let rps = throughput_rps(lat.count() as usize, t0.elapsed());
    pool.shutdown().ok();
    Ok((lat, rps))
}

fn main() -> anyhow::Result<()> {
    println!("== NMT online serving: stitched (Pallas) vs unfused attention ==");
    let (fused_out, fused_lat, fused_rps) = serve("attention_fused")?;
    let (unfused_out, unfused_lat, unfused_rps) = serve("attention_unfused")?;

    // The stitched kernel must be numerically equivalent to the
    // op-by-op graph — same guarantee the paper's codegen gives.
    let mut max_diff = 0f32;
    for (a, b) in fused_out.iter().zip(&unfused_out) {
        for (x, y) in a.iter().zip(b) {
            max_diff = max_diff.max((x - y).abs());
        }
    }
    println!("numeric agreement: max |fused - unfused| = {max_diff:.2e}");
    assert!(max_diff < 1e-3, "variants diverged");

    for (name, lat, rps) in [
        ("fused", &fused_lat, fused_rps),
        ("unfused", &unfused_lat, unfused_rps),
    ] {
        println!(
            "{name:<8} p50 {:.2} ms | p95 {:.2} ms | mean {:.2} ms | {:.0} req/s",
            lat.percentile_us(50.0) / 1e3,
            lat.percentile_us(95.0) / 1e3,
            lat.mean_us() / 1e3,
            rps,
        );
    }
    println!("({REQUESTS} requests, batch {BATCH}, seq {SEQ}, model {MODEL})");

    // The same fused artifact behind the sharded multi-worker pool:
    // sticky shape-key routing + per-shard bounded queues.
    println!("\n== Sharded serving pool (fused artifact, 4-key traffic) ==");
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
    for n in [1, workers] {
        let (lat, rps) = serve_pooled("attention_fused", n)?;
        println!(
            "{n} worker(s): p50 {:.2} ms | p95 {:.2} ms | {:.0} req/s",
            lat.percentile_us(50.0) / 1e3,
            lat.percentile_us(95.0) / 1e3,
            rps,
        );
    }
    Ok(())
}
