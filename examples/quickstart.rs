//! Quickstart: build a small computation, run the whole FusionStitching
//! pipeline on it, and inspect the result — the README's five-minute
//! tour of the public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fusion_stitching::coordinator::pipeline::{compile_module, FusionMode, PipelineConfig};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::hlo::instruction::ReduceKind;
use fusion_stitching::hlo::{GraphBuilder, Module, Shape};
use fusion_stitching::schedule::PerfLibrary;

fn main() -> anyhow::Result<()> {
    // 1. Author a computation with the shape-inferring graph builder —
    //    here, Figure 3's motivating pattern: a softmax stitched into a
    //    batched matmul.
    let mut b = GraphBuilder::new("entry");
    let scores = b.param("scores", Shape::f32(&[8, 64, 64]));
    let v = b.param("v", Shape::f32(&[8, 64, 32]));
    let m = b.reduce(scores, &[2], ReduceKind::Max);
    let mb = b.broadcast(m, &[8, 64, 64], &[0, 1]);
    let sh = b.sub(scores, mb);
    let e = b.exp(sh);
    let s = b.reduce(e, &[2], ReduceKind::Sum);
    let sb = b.broadcast(s, &[8, 64, 64], &[0, 1]);
    let p = b.div(e, sb);
    let out = b.batch_dot(p, v);
    let module = Module::new("figure3", b.finish(out));

    // 2. Compile it twice: once with the XLA-like baseline fusion, once
    //    with FusionStitching's deep fusion.
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    let cfg = PipelineConfig::default();
    let baseline = compile_module(&module, FusionMode::XlaBaseline, &mut lib, &cfg)?;
    let stitched = compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg)?;

    println!(
        "baseline: {} kernels, simulated {:.1} us",
        baseline.plan.generated_kernel_count(&module.entry),
        baseline.timing.total_us()
    );
    println!(
        "stitched: {} kernel(s), simulated {:.1} us",
        stitched.plan.generated_kernel_count(&module.entry),
        stitched.timing.total_us()
    );

    // 3. Inspect the stitched kernel: launch dims, shared-memory plan
    //    (ALLOC/SHARE annotations) and the per-op pseudo-IR.
    for kernel in &stitched.kernels {
        println!("\n{}", kernel.ir_text());
    }

    assert!(
        stitched.plan.generated_kernel_count(&module.entry)
            < baseline.plan.generated_kernel_count(&module.entry)
    );
    Ok(())
}
