//! Figure 1 regeneration as a standalone example: generate the synthetic
//! model corpus and print the accumulated footprint percentiles per op
//! class, in the same axes as the paper (x = log2 footprint in floats,
//! y = accumulated percentile).
//!
//! ```bash
//! cargo run --release --example corpus_stats -- [models]
//! ```

use fusion_stitching::corpus::generator::{generate, CorpusConfig};
use fusion_stitching::corpus::{percentiles, OpClass};

fn main() {
    let models = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000);
    let stats = generate(&CorpusConfig { models, ..Default::default() });
    println!(
        "Figure 1 — memory footprint distribution ({} op instances over {} synthetic models)",
        stats.total_instances(),
        models
    );
    let cuts: Vec<u32> = (4..=26).collect();
    print!("{:<8}", "log2(N)");
    for c in cuts.iter().step_by(2) {
        print!("{c:>7}");
    }
    println!();
    for class in OpClass::ALL {
        let p = percentiles(&stats.samples[&class], &cuts);
        print!("{:<8}", class.label());
        for v in p.iter().step_by(2) {
            print!("{:>6.1}%", 100.0 * v);
        }
        println!();
    }
    println!(
        "\nReading: most elementwise/reduce instances sit far left (small\n\
         footprints → launch-bound kernels), matmul/conv sit right — the\n\
         fine-granularity problem motivating FusionStitching (§1)."
    );
}
