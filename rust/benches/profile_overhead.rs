//! Flight-recorder overhead bench — the observability acceptance gate.
//!
//! Replays all six Table 2 models on the stitched VM under three
//! recorder states and compares per-run wall time (min over iters, the
//! noise-robust statistic):
//!
//! - **baseline** — no recorder installed (what PR 6 shipped);
//! - **disabled** — a sink is installed but switched off: the record
//!   path must collapse to one thread-local read (~0% gate);
//! - **enabled**  — sink + kernel profile armed: full span recording
//!   and per-group measurement (≤ 5% gate).
//!
//! Also reports the modeled-vs-measured divergence per fused group for
//! every model (the `KernelProfile` the enabled runs populated).
//! Results land in `BENCH_profile_overhead.json` at the repo root.
//! Smoke mode (`BENCH_SMOKE=1`, used by `make bench-profile` and CI)
//! shrinks iterations and reports without gating — short runs on noisy
//! shared runners cannot hold a 5% bound honestly.

#[path = "bench_util.rs"]
mod bench_util;

use fusion_stitching::coordinator::pipeline::geomean;
use fusion_stitching::coordinator::{compile_module, FusionMode, PipelineConfig};
use fusion_stitching::exec::{ExecArena, StitchedExecutable};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::hlo::Module;
use fusion_stitching::models;
use fusion_stitching::obs::{self, Json, KernelProfile, TraceConfig, TraceSink};
use fusion_stitching::schedule::PerfLibrary;
use std::path::PathBuf;

const GATE_ON: f64 = 1.05; // enabled / baseline
const GATE_OFF: f64 = 1.02; // disabled / baseline ("~0%", noise floor)

fn fill(n: usize, seed: u64) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let h = (i as u64).wrapping_mul(2654435761).wrapping_add(seed.wrapping_mul(97));
            ((h % 1000) as f32) / 1000.0 - 0.5
        })
        .collect()
}

fn inputs_for(module: &Module, seed: u64) -> Vec<Vec<f32>> {
    module
        .entry
        .parameters()
        .into_iter()
        .enumerate()
        .map(|(k, id)| {
            let elems = module.entry.get(id).shape.num_elements() as usize;
            fill(elems, seed + k as u64)
        })
        .collect()
}

struct Row {
    name: &'static str,
    baseline_us: f64,
    disabled_us: f64,
    enabled_us: f64,
    on_ratio: f64,
    off_ratio: f64,
    launches: u64,
    profile: KernelProfile,
}

fn time_replays(exe: &StitchedExecutable, refs: &[&[f32]], warmup: usize, iters: usize) -> f64 {
    let mut arena = ExecArena::default();
    let mut out = Vec::new();
    let (_, best) = bench_util::time_it(warmup, iters, || {
        exe.run_into(refs, &mut arena, &mut out).expect("replay failed")
    });
    best.as_secs_f64() * 1e6
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok() || std::env::args().any(|a| a == "--smoke");
    let (warmup, iters) = if smoke { (3usize, 25usize) } else { (20, 200) };
    let mode_name = if smoke { "smoke" } else { "full" };
    println!(
        "== flight-recorder overhead: baseline vs disabled vs enabled \
         ({mode_name}, min of {iters} iters) =="
    );
    println!(
        "{:<8} {:>12} {:>12} {:>12} {:>8} {:>8}",
        "model", "baseline_us", "disabled_us", "enabled_us", "off", "on"
    );

    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    let mut rows: Vec<Row> = Vec::new();
    for (meta, module) in models::all_benchmarks() {
        let mut cfg = PipelineConfig::default();
        cfg.deep.fuse_batch_dot = meta.fuse_batch_dot;
        let compiled = compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg)
            .unwrap_or_else(|e| panic!("{}: compile failed: {e:#}", meta.name));
        let exe = compiled
            .executable
            .clone()
            .unwrap_or_else(|| panic!("{}: did not lower: {:?}", meta.name, compiled.exec_error));
        let inputs = inputs_for(&module, 42);
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();

        // 1. Baseline: no recorder context on this thread at all.
        let baseline_us = time_replays(&exe, &refs, warmup, iters);

        // 2. Disabled: sink installed but off, no profile — the state a
        // server idles in when nobody asked for a trace.
        let disabled_us = {
            let sink = TraceSink::new(TraceConfig { enabled: false, capacity_per_worker: 1024 });
            let _g = obs::install(&sink, 0, None);
            time_replays(&exe, &refs, warmup, iters)
        };

        // 3. Enabled: spans recorded, profile measured — the state
        // `serve --trace-out` runs in.
        let enabled_us = {
            let sink = TraceSink::new(TraceConfig::default());
            let _g = obs::install(&sink, 0, Some(compiled.profile.clone()));
            time_replays(&exe, &refs, warmup, iters)
        };

        let on_ratio = enabled_us / baseline_us.max(1e-9);
        let off_ratio = disabled_us / baseline_us.max(1e-9);
        let profile = compiled.profile.snapshot();
        println!(
            "{:<8} {:>12.2} {:>12.2} {:>12.2} {:>7.3}x {:>7.3}x",
            meta.name, baseline_us, disabled_us, enabled_us, off_ratio, on_ratio
        );
        rows.push(Row {
            name: meta.name,
            baseline_us,
            disabled_us,
            enabled_us,
            on_ratio,
            off_ratio,
            launches: profile.total_launches(),
            profile,
        });
    }

    let on_geo = geomean(rows.iter().map(|r| r.on_ratio));
    let off_geo = geomean(rows.iter().map(|r| r.off_ratio));
    let pass = on_geo <= GATE_ON && off_geo <= GATE_OFF;
    println!(
        "geomean overhead: disabled {off_geo:.3}x (gate {GATE_OFF}), \
         enabled {on_geo:.3}x (gate {GATE_ON})"
    );

    let mut j = Json::new();
    j.begin_obj();
    j.field_str("bench", "profile_overhead");
    j.field_bool("smoke", smoke);
    j.field_uint("iters", iters as u64);
    j.key("models").begin_arr();
    for r in &rows {
        j.begin_obj();
        j.field_str("model", r.name);
        j.field_num("baseline_us", r.baseline_us);
        j.field_num("disabled_us", r.disabled_us);
        j.field_num("enabled_us", r.enabled_us);
        j.field_num("off_overhead", r.off_ratio);
        j.field_num("on_overhead", r.on_ratio);
        j.field_uint("launches", r.launches);
        j.key("profile");
        r.profile.write_json(&mut j);
        j.end_obj();
    }
    j.end_arr();
    j.field_num("geomean_off_overhead", off_geo);
    j.field_num("geomean_on_overhead", on_geo);
    j.key("gate")
        .begin_obj()
        .field_num("max_off", GATE_OFF)
        .field_num("max_on", GATE_ON)
        .field_bool("enforced", !smoke)
        .field_bool("pass", pass)
        .end_obj();
    j.end_obj();
    let json = j.finish();

    let out_path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => PathBuf::from(dir).join("..").join("BENCH_profile_overhead.json"),
        Err(_) => PathBuf::from("BENCH_profile_overhead.json"),
    };
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {}", out_path.display()),
        Err(e) => eprintln!("could not write {}: {e}", out_path.display()),
    }

    if !pass {
        if smoke {
            eprintln!(
                "NOTE: overhead above gate (smoke mode, not gated): \
                 disabled {off_geo:.3}x / enabled {on_geo:.3}x"
            );
        } else {
            eprintln!(
                "FAIL: recorder overhead gate: disabled {off_geo:.3}x (max {GATE_OFF}), \
                 enabled {on_geo:.3}x (max {GATE_ON})"
            );
            std::process::exit(1);
        }
    }
}
