//! Shape-class bucketing bench — the compile-amortization acceptance
//! gate, landing in `BENCH_shape_buckets.json`.
//!
//! One shape-heterogeneous trace (≥16 distinct row lengths, the
//! NMT-sequence-length scenario) is served twice through identical
//! stitched serving loops:
//!
//! - **exact** — `BucketPolicy::Exact`: every distinct length is its own
//!   shape class, so every new length pays a cold compile.
//! - **bucketed** — `BucketPolicy::PowerOfTwo`: lengths share padded
//!   canonical artifacts, so the whole trace compiles a handful of
//!   buckets and the rest of the traffic hits the cache.
//!
//! Gates (deterministic, enforced in smoke mode too): the bucketed leg
//! must pay at least [`COMPILE_REDUCTION`]× fewer cold compiles and
//! reach a strictly higher cache hit rate, its padding-waste ratio must
//! stay under [`WASTE_THRESHOLD`], and every request's live output
//! region must match the exact-shape leg bit for bit.

use fusion_stitching::coordinator::batcher::BatchPolicy;
use fusion_stitching::coordinator::buckets::BucketPolicy;
use fusion_stitching::coordinator::metrics::StreamingSummary;
use fusion_stitching::coordinator::pipeline::{FusionMode, PipelineConfig};
use fusion_stitching::coordinator::server::{CompileOptions, ServerConfig, WorkerStats};
use fusion_stitching::coordinator::ServingCoordinator;
use fusion_stitching::hlo::{GraphBuilder, Module, Shape};
use fusion_stitching::obs::Json;
use fusion_stitching::testutil::TempDir;
use std::path::PathBuf;
use std::time::Duration;

const BATCH: usize = 4;
/// The serving contract's maximum row — the largest bucket.
const MAX_LEN: usize = 128;
/// Distinct concrete row lengths in the trace.
const DISTINCT_LENGTHS: usize = 24;
/// The bucketed leg must pay at least this factor fewer cold compiles.
const COMPILE_REDUCTION: usize = 4;
/// Hard cap on the bucketed leg's padding-waste ratio.
const WASTE_THRESHOLD: f64 = 0.5;

/// Identity-ish artifact so the engine has something to parse; every
/// batch executes on the stitched backend, never on this text.
const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[4,3]{1,0})->(f32[4,3]{1,0})}

ENTRY main {
  p0 = f32[4,3]{1,0} parameter(0)
  sum = f32[4,3]{1,0} add(p0, p0)
  ROOT t = (f32[4,3]{1,0}) tuple(sum)
}
"#;

/// The specializer: `tanh(exp(x))` over a `[BATCH, len]` batch.
fn chain(len: usize) -> Module {
    let mut b = GraphBuilder::new("entry");
    let x = b.param("x", Shape::f32(&[BATCH as i64, len as i64]));
    let e = b.exp(x);
    let t = b.tanh(e);
    Module::new("chain", b.finish(t))
}

/// 24 distinct lengths spread over 17..=128 — every one below the
/// PowerOfTwo floor of 32, between 32 and 64, or between 64 and 128.
fn trace_lengths() -> Vec<usize> {
    (0..DISTINCT_LENGTHS).map(|i| 17 + i * (MAX_LEN - 17) / (DISTINCT_LENGTHS - 1)).collect()
}

fn fill(len: usize, seed: u64) -> Vec<f32> {
    (0..len)
        .map(|i| {
            let h = (i as u64).wrapping_mul(2654435761).wrapping_add(seed.wrapping_mul(97));
            ((h % 1000) as f32) / 1000.0 - 0.5
        })
        .collect()
}

struct LegResult {
    outputs: Vec<Vec<u32>>,
    lat: StreamingSummary,
    stats: WorkerStats,
}

/// Serve the whole trace (`passes` sequential sweeps over the length
/// set) through one stitched serving loop under `policy`.
fn run_leg(dir: &TempDir, policy: BucketPolicy, passes: usize) -> LegResult {
    let mut pipeline = PipelineConfig::default();
    pipeline.bucketing = policy.clone();
    let cfg = ServerConfig {
        artifact: "double".into(),
        batch: BATCH,
        in_elems_per_request: MAX_LEN,
        out_elems_per_request: MAX_LEN,
        input_dims: vec![BATCH as i64, MAX_LEN as i64],
        policy: BatchPolicy { max_batch: BATCH, max_wait: Duration::from_millis(1) },
        compile: Some(CompileOptions {
            module: chain(MAX_LEN),
            mode: FusionMode::FusionStitching,
            pipeline,
            use_stitched_backend: true,
            specialize: Some(chain as fn(usize) -> Module),
        }),
        buckets: Some(policy),
        trace: None,
        deadline: None,
        faults: None,
    };
    let srv = ServingCoordinator::start(dir.path(), cfg).expect("serving loop start");
    let mut outputs = Vec::new();
    let mut lat = StreamingSummary::default();
    for pass in 0..passes {
        for (k, &len) in trace_lengths().iter().enumerate() {
            let input = fill(len, (pass * DISTINCT_LENGTHS + k) as u64);
            let (out, latency) = srv.infer(input).expect("infer");
            assert_eq!(out.len(), len, "live region only");
            lat.record(latency);
            outputs.push(out.iter().map(|f| f.to_bits()).collect());
        }
    }
    let stats = srv.shutdown().expect("clean shutdown");
    LegResult { outputs, lat, stats }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok() || std::env::args().any(|a| a == "--smoke");
    let passes = if smoke { 2usize } else { 8 };
    let mode_name = if smoke { "smoke" } else { "full" };
    let requests = passes * DISTINCT_LENGTHS;
    println!(
        "== shape-class bucketing: {DISTINCT_LENGTHS} distinct lengths x {passes} passes \
         ({requests} requests, {mode_name}) =="
    );

    let dir = TempDir::new("shape-buckets-bench");
    std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).expect("artifact write");

    let exact = run_leg(&dir, BucketPolicy::Exact, passes);
    let bucketed = run_leg(&dir, BucketPolicy::PowerOfTwo { min: 32 }, passes);

    let mismatches = exact
        .outputs
        .iter()
        .zip(&bucketed.outputs)
        .filter(|(a, b)| a != b)
        .count();

    let cold_exact = exact.stats.cache_misses;
    let cold_bucketed = bucketed.stats.cache_misses;
    let hit_rate_exact = exact.stats.cache_hit_rate();
    let hit_rate_bucketed = bucketed.stats.cache_hit_rate();
    let waste = bucketed.stats.padding_waste_ratio();
    let p50_exact = exact.lat.percentiles_us(&[50.0])[0];
    let p50_bucketed = bucketed.lat.percentiles_us(&[50.0])[0];

    for (name, leg, p50) in
        [("exact", &exact, p50_exact), ("bucketed", &bucketed, p50_bucketed)]
    {
        println!(
            "{name:<9} cold compiles {:>3}  hits {:>3}  hit rate {:.3}  \
             waste {:.3}  p50 {:.0} us",
            leg.stats.cache_misses,
            leg.stats.cache_hits,
            leg.stats.cache_hit_rate(),
            leg.stats.padding_waste_ratio(),
            p50,
        );
    }

    let compile_gate = cold_exact >= COMPILE_REDUCTION * cold_bucketed && cold_bucketed > 0;
    let hit_gate = hit_rate_bucketed > hit_rate_exact;
    let waste_gate = waste > 0.0 && waste <= WASTE_THRESHOLD;
    let identity_gate = mismatches == 0;
    let pass = compile_gate && hit_gate && waste_gate && identity_gate;
    println!(
        "cold compiles {cold_exact} -> {cold_bucketed} ({:.1}x), value mismatches {mismatches}",
        cold_exact as f64 / cold_bucketed.max(1) as f64
    );

    let mut j = Json::new();
    j.begin_obj();
    j.field_str("bench", "shape_buckets");
    j.field_bool("smoke", smoke);
    j.field_uint("distinct_lengths", DISTINCT_LENGTHS as u64);
    j.field_uint("requests_per_leg", requests as u64);
    for (name, leg, p50) in
        [("exact", &exact, p50_exact), ("bucketed", &bucketed, p50_bucketed)]
    {
        j.key(name).begin_obj();
        j.field_uint("cold_compiles", leg.stats.cache_misses as u64);
        j.field_uint("cache_hits", leg.stats.cache_hits as u64);
        j.field_num("cache_hit_rate", leg.stats.cache_hit_rate());
        j.field_uint("padded_elems", leg.stats.padded_elems);
        j.field_uint("live_elems", leg.stats.live_elems);
        j.field_num("padding_waste_ratio", leg.stats.padding_waste_ratio());
        j.field_num("p50_latency_us", p50);
        j.end_obj();
    }
    j.field_num(
        "compile_reduction",
        cold_exact as f64 / cold_bucketed.max(1) as f64,
    );
    j.field_uint("value_mismatches", mismatches as u64);
    j.key("gate")
        .begin_obj()
        .field_bool("compile_reduction", compile_gate)
        .field_bool("hit_rate", hit_gate)
        .field_bool("waste_bounded", waste_gate)
        .field_bool("value_identity", identity_gate)
        .field_bool("pass", pass)
        .end_obj();
    j.end_obj();
    let json = j.finish();

    let out_path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => PathBuf::from(dir).join("..").join("BENCH_shape_buckets.json"),
        Err(_) => PathBuf::from("BENCH_shape_buckets.json"),
    };
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {}", out_path.display()),
        Err(e) => eprintln!("could not write {}: {e}", out_path.display()),
    }

    if !pass {
        eprintln!(
            "FAIL: shape-bucket gate: compile_reduction={compile_gate} \
             ({cold_exact} vs {cold_bucketed} cold compiles), hit_rate={hit_gate} \
             ({hit_rate_exact:.3} vs {hit_rate_bucketed:.3}), \
             waste_bounded={waste_gate} ({waste:.3} vs cap {WASTE_THRESHOLD}), \
             value_identity={identity_gate} ({mismatches} mismatches)"
        );
        std::process::exit(1);
    }
}
