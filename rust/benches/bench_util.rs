//! Shared helpers for the hand-rolled bench harnesses (criterion is not
//! available in this offline image; each bench is a `harness = false`
//! binary that times with `std::time::Instant` and prints the paper's
//! rows).

use std::time::{Duration, Instant};

/// Time `f` over `iters` iterations after `warmup` warmups; returns
/// (mean, min) per-iteration duration.
#[allow(dead_code)]
pub fn time_it<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> (Duration, Duration) {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut total = Duration::ZERO;
    let mut best = Duration::MAX;
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        let dt = t0.elapsed();
        total += dt;
        best = best.min(dt);
    }
    (total / iters as u32, best)
}

#[allow(dead_code)]
pub fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

#[allow(dead_code)]
fn main() {}
