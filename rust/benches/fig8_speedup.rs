//! Fig. 8 — FusionSpeedup (fusable portion), predicted E2E (the §6.4
//! empirical formula `1 + FusableRatio·(1 − 1/FusionSpeedup)`) and
//! measured E2E speedup per benchmark.
//!
//! Paper: FusionSpeedup 1.15 (W2V) … 3.5 (Speech), geomean 1.74; E2E
//! 5–20%, geomean 13%; predicted ≈ measured. Shapes asserted here:
//! every speedup ≥ 1, W2V among the smallest, predicted within 35% of
//! measured.

#[path = "bench_util.rs"]
mod bench_util;

use fusion_stitching::coordinator::pipeline::{evaluate, geomean, PipelineConfig};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::models;
use fusion_stitching::schedule::PerfLibrary;

fn main() {
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    let cfg = PipelineConfig::default();
    println!("== Fig. 8: speedups ==");
    println!(
        "{:<8} {:>14} {:>13} {:>12}",
        "model", "FusionSpeedup", "predictedE2E", "measuredE2E"
    );
    let mut fspeed = Vec::new();
    let mut e2e = Vec::new();
    for (meta, module) in models::all_benchmarks() {
        let r = evaluate(&meta, &module, &mut lib, &cfg).unwrap();
        println!(
            "{:<8} {:>14.2} {:>13.2} {:>12.2}",
            r.name, r.fusion_speedup, r.predicted_e2e, r.measured_e2e
        );
        assert!(r.fusion_speedup >= 1.0, "{}: fusable portion must not regress", r.name);
        assert!(r.measured_e2e >= 1.0, "{}: E2E must not regress", r.name);
        let rel = (r.predicted_e2e - r.measured_e2e).abs() / r.measured_e2e;
        assert!(rel < 0.40, "{}: prediction formula off by {:.0}%", r.name, rel * 100.0);
        fspeed.push(r.fusion_speedup);
        e2e.push(r.measured_e2e);
    }
    println!(
        "geomean FusionSpeedup {:.2} (paper 1.74) | geomean E2E {:.2} (paper 1.13)",
        geomean(fspeed),
        geomean(e2e)
    );
}
