//! Fusion-profit bench: greedy vs cost-guided fusion over the six
//! Table 2 workloads.
//!
//! Each model is compiled twice under FusionStitching — once with the
//! greedy Algorithm 1 plan (`--no-cost-fusion`) and once with the
//! cost-guided explorer refining it — then both plans are **executed**
//! on the stitched VM so the `LaunchLedger` reports real launches, not
//! estimates. Acceptance bar (enforced here): on every model the
//! cost-guided plan's modeled total time is ≤ greedy's and it executes
//! at most as many launches. Results go to `BENCH_fusion_profit.json`
//! at the repo root.
//!
//! `BENCH_SMOKE=1` (used by `make bench-fusion` and CI) keeps the same
//! six models — they are cheap — and only tags the output mode.

use fusion_stitching::coordinator::pipeline::{
    compile_module, geomean, FusionMode, PipelineConfig,
};
use fusion_stitching::exec::LaunchLedger;
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::hlo::Module;
use fusion_stitching::models;
use fusion_stitching::schedule::PerfLibrary;
use std::path::PathBuf;

fn fill(n: usize, seed: u64) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let h = (i as u64).wrapping_mul(2654435761).wrapping_add(seed.wrapping_mul(97));
            ((h % 1000) as f32) / 1000.0 - 0.5
        })
        .collect()
}

fn inputs_for(module: &Module, seed: u64) -> Vec<Vec<f32>> {
    module
        .entry
        .parameters()
        .into_iter()
        .enumerate()
        .map(|(k, id)| {
            let elems = module.entry.get(id).shape.num_elements() as usize;
            fill(elems, seed + k as u64)
        })
        .collect()
}

struct ModeRow {
    modeled_us: f64,
    kernels: usize,
    ledger: LaunchLedger,
    merges: usize,
    splits: usize,
    memo_hits: u64,
}

fn compile_and_run(
    module: &Module,
    fuse_batch_dot: bool,
    cost_fusion: bool,
    lib: &mut PerfLibrary,
) -> ModeRow {
    let mut cfg = PipelineConfig::default();
    cfg.deep.fuse_batch_dot = fuse_batch_dot;
    cfg.deep.cost_fusion = cost_fusion;
    let compiled = compile_module(module, FusionMode::FusionStitching, lib, &cfg)
        .unwrap_or_else(|e| panic!("{}: compile failed: {e:#}", module.name));
    let exe = compiled
        .executable
        .as_ref()
        .unwrap_or_else(|| panic!("{}: did not lower: {:?}", module.name, compiled.exec_error));
    let inputs = inputs_for(module, 42);
    let (_, ledger) = exe
        .run(&inputs)
        .unwrap_or_else(|e| panic!("{}: run failed: {e:#}", module.name));
    let (merges, splits, memo_hits) = compiled
        .explore
        .as_ref()
        .map(|x| (x.merges_accepted, x.splits_accepted, x.memo_hits))
        .unwrap_or((0, 0, 0));
    ModeRow {
        modeled_us: compiled.timing.total_us(),
        kernels: compiled.plan.generated_kernel_count(&module.entry),
        ledger,
        merges,
        splits,
        memo_hits,
    }
}

fn main() {
    let smoke =
        std::env::var("BENCH_SMOKE").is_ok() || std::env::args().any(|a| a == "--smoke");
    let mode_name = if smoke { "smoke" } else { "full" };
    println!("== Fusion profit: greedy vs cost-guided (executed on the stitched VM) ==");
    println!(
        "{:<8} {:>12} {:>12} {:>9} {:>9} {:>7} {:>7} {:>8}",
        "model", "greedy_us", "guided_us", "g_launch", "c_launch", "merges", "splits", "ratio"
    );

    let mut rows: Vec<(String, ModeRow, ModeRow)> = Vec::new();
    for (meta, module) in models::all_benchmarks() {
        // One shared library per model; the two modes key their tuned
        // plans separately (the config digest carries the explore flag).
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let greedy = compile_and_run(&module, meta.fuse_batch_dot, false, &mut lib);
        let guided = compile_and_run(&module, meta.fuse_batch_dot, true, &mut lib);

        assert!(
            guided.modeled_us <= greedy.modeled_us + 1e-6,
            "{}: cost-guided modeled time regressed: {} vs {}",
            meta.name,
            guided.modeled_us,
            greedy.modeled_us
        );
        assert!(
            guided.ledger.total_launches() <= greedy.ledger.total_launches(),
            "{}: cost-guided launched more: {} vs {}",
            meta.name,
            guided.ledger.total_launches(),
            greedy.ledger.total_launches()
        );

        let ratio = guided.modeled_us / greedy.modeled_us.max(1e-9);
        println!(
            "{:<8} {:>12.1} {:>12.1} {:>9} {:>9} {:>7} {:>7} {:>8.3}",
            meta.name,
            greedy.modeled_us,
            guided.modeled_us,
            greedy.ledger.total_launches(),
            guided.ledger.total_launches(),
            guided.merges,
            guided.splits,
            ratio
        );
        rows.push((meta.name.to_string(), greedy, guided));
    }

    let g_time = geomean(rows.iter().map(|(_, g, c)| c.modeled_us / g.modeled_us.max(1e-9)));
    let g_launch = geomean(rows.iter().map(|(_, g, c)| {
        c.ledger.total_launches() as f64 / g.ledger.total_launches().max(1) as f64
    }));
    println!(
        "geomean modeled-time ratio (guided/greedy): {g_time:.3}, launch ratio: {g_launch:.3}"
    );

    // ---- persist ----
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"fusion_profit\",\n");
    json.push_str(&format!("  \"mode\": \"{mode_name}\",\n"));
    json.push_str("  \"models\": [\n");
    for (k, (name, g, c)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{name}\", \
             \"greedy\": {{\"modeled_us\": {:.3}, \"kernels\": {}, \"launches\": {}}}, \
             \"cost_guided\": {{\"modeled_us\": {:.3}, \"kernels\": {}, \"launches\": {}, \
             \"merges\": {}, \"splits\": {}, \"memo_hits\": {}}}, \
             \"modeled_ratio\": {:.4}, \"launch_ratio\": {:.4}}}{}\n",
            g.modeled_us,
            g.kernels,
            g.ledger.total_launches(),
            c.modeled_us,
            c.kernels,
            c.ledger.total_launches(),
            c.merges,
            c.splits,
            c.memo_hits,
            c.modeled_us / g.modeled_us.max(1e-9),
            c.ledger.total_launches() as f64 / g.ledger.total_launches().max(1) as f64,
            if k + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!("  \"geomean_modeled_ratio\": {g_time:.4},\n"));
    json.push_str(&format!("  \"geomean_launch_ratio\": {g_launch:.4}\n"));
    json.push_str("}\n");

    let out_path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => PathBuf::from(dir).join("..").join("BENCH_fusion_profit.json"),
        Err(_) => PathBuf::from("BENCH_fusion_profit.json"),
    };
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {}", out_path.display()),
        Err(e) => eprintln!("could not write {}: {e}", out_path.display()),
    }
}
