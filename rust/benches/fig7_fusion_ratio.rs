//! Fig. 7 — the fusion ratio: #kernels(FusionStitching) / #kernels(XLA
//! baseline), library calls excluded (§6.3).
//!
//! Paper's series: LR/W2V/RNN/BiRNN/Speech/NMT with W2V worst (0.82),
//! Speech best (0.25), geomean ≈ 0.45 ("another 55% reduction of GPU
//! kernel launches"). The shape to reproduce: every ratio < 1, W2V the
//! highest, the complex graphs (Speech/NMT) the lowest.

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{ms, time_it};
use fusion_stitching::coordinator::pipeline::{
    compile_module, geomean, FusionMode, PipelineConfig,
};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::models;
use fusion_stitching::schedule::PerfLibrary;

fn main() {
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    println!("== Fig. 7: fusion ratio ==");
    println!(
        "{:<8} {:>8} {:>8} {:>8} {:>14} {:>14}",
        "model", "XLA", "FS", "ratio", "xla_compile", "fs_compile"
    );
    let mut ratios = Vec::new();
    for (meta, module) in models::all_benchmarks() {
        let mut cfg = PipelineConfig::default();
        cfg.deep.fuse_batch_dot = meta.fuse_batch_dot;
        let (tb, _) = time_it(1, 3, || {
            compile_module(&module, FusionMode::XlaBaseline, &mut lib, &cfg).unwrap()
        });
        let base = compile_module(&module, FusionMode::XlaBaseline, &mut lib, &cfg).unwrap();
        let (tf, _) = time_it(1, 3, || {
            compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap()
        });
        let fs = compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        let b = base.plan.generated_kernel_count(&module.entry);
        let f = fs.plan.generated_kernel_count(&module.entry);
        let ratio = f as f64 / b as f64;
        ratios.push(ratio);
        println!(
            "{:<8} {:>8} {:>8} {:>8.2} {:>12.1}ms {:>12.1}ms",
            meta.name,
            b,
            f,
            ratio,
            ms(tb),
            ms(tf)
        );
        assert!(ratio <= 1.0, "{}: FS must not launch more kernels", meta.name);
    }
    let g = geomean(ratios.iter().copied());
    println!("geomean: {g:.2}  (paper: ~0.45 — a 55% reduction)");
    assert!(g < 0.75, "geomean fusion ratio should show a large reduction");
}
