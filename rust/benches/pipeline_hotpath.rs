//! L3 hot-path bench — compile-time performance of the FusionStitching
//! pipeline itself (fusion → schedule planning → shm planning →
//! codegen), per benchmark and end-to-end, plus perf-library hit-rate.
//!
//! This is the §Perf target for L3 (DESIGN.md): the full six-benchmark
//! pipeline under 150 ms with a warm perf library. The paper makes the
//! same point about compilation speed: the schedule space is small and
//! the performance library amortizes across compilations (§4.4).

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{ms, time_it};
use fusion_stitching::coordinator::pipeline::{compile_module, FusionMode, PipelineConfig};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::models;
use fusion_stitching::schedule::PerfLibrary;
use std::time::Instant;

fn main() {
    println!("== L3 pipeline hot path (compile time per model) ==");
    println!(
        "{:<8} {:>7} {:>12} {:>12} {:>12}",
        "model", "ops", "cold_ms", "warm_mean", "warm_best"
    );
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    let mut warm_total = 0.0;
    for (meta, module) in models::all_benchmarks() {
        let mut cfg = PipelineConfig::default();
        cfg.deep.fuse_batch_dot = meta.fuse_batch_dot;
        let t0 = Instant::now();
        let _ = compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        let cold = t0.elapsed();
        let (mean, best) = time_it(1, 5, || {
            compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap()
        });
        warm_total += ms(mean);
        println!(
            "{:<8} {:>7} {:>10.1}ms {:>10.1}ms {:>10.1}ms",
            meta.name,
            module.entry.len(),
            ms(cold),
            ms(mean),
            ms(best)
        );
    }
    println!(
        "warm pipeline total {:.1}ms over 6 benchmarks | perf-library: {} entries, {:.0}% hit rate",
        warm_total,
        lib.len(),
        100.0 * lib.hit_rate()
    );
    assert!(warm_total < 500.0, "warm pipeline should stay well under 0.5s");
}
