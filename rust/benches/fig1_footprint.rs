//! Fig. 1 — accumulated percentile distribution of memory IO footprints
//! of the six most popular ops over a model corpus (§1).
//!
//! The paper measured 53,470 production models on PAI; we regenerate the
//! same plot over a seeded synthetic corpus (DESIGN.md substitutions).
//! Shapes asserted: all curves monotone, reaching ~100%; elementwise and
//! reduce instances are mostly small (the fine-granularity problem);
//! MatMul/Conv2D run larger than elementwise at the median.

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{ms, time_it};
use fusion_stitching::corpus::generator::{generate, CorpusConfig};
use fusion_stitching::corpus::{percentiles, OpClass};

fn main() {
    let cfg = CorpusConfig::default();
    let (t, _) = time_it(0, 3, || generate(&cfg));
    let stats = generate(&cfg);
    println!(
        "== Fig. 1: footprint percentiles ({} instances / {} models, corpus gen {:.0}ms) ==",
        stats.total_instances(),
        cfg.models,
        ms(t)
    );
    let cuts: Vec<u32> = (4..=26).step_by(2).collect();
    print!("{:<8}", "log2(N)");
    for c in &cuts {
        print!("{c:>7}");
    }
    println!();
    for class in OpClass::ALL {
        let series = &stats.samples[&class];
        let p = percentiles(series, &cuts);
        print!("{:<8}", class.label());
        for v in &p {
            print!("{:>6.1}%", 100.0 * v);
        }
        println!();
        for w in p.windows(2) {
            assert!(w[0] <= w[1] + 1e-12, "{}: non-monotone curve", class.label());
        }
        assert!(p.last().unwrap() > &0.99, "{}: curve must saturate", class.label());
    }

    let median = |c: OpClass| {
        let v = &stats.samples[&c];
        v[v.len() / 2]
    };
    assert!(
        median(OpClass::MatMul) > median(OpClass::Add),
        "MatMul footprints should exceed elementwise (paper's observation)"
    );
    let small_add = percentiles(&stats.samples[&OpClass::Add], &[20])[0];
    assert!(small_add > 0.5, "most elementwise instances must be small");
}
