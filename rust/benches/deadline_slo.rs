//! Deadline-SLO bench — slack-based admission vs. a no-deadline
//! baseline under a heavy-tailed, bursty arrival trace.
//!
//! A single-worker pool serves the depth-48 elementwise chain while an
//! open-loop client replays a seeded splitmix64 arrival schedule:
//! occasional long idle gaps (1 in 16) funding dense bursts that run at
//! ~1.9x the mean rate. Three legs:
//!
//!   1. `baseline_saturated` — no deadlines, 2x the measured service
//!      rate: the queue soaks the overload and p99 latency blows far
//!      past the deadline target.
//!   2. `deadline_saturated` — the same trace with a per-request
//!      deadline: slack admission sheds what cannot be served in time
//!      (structured `DeadlineInfeasible` replies, never a hang) and the
//!      admitted requests keep meeting the deadline at the p99.
//!   3. `deadline_moderate` — the same deadline at ~40% load: bursts
//!      alone must not cause meaningful shedding (bounded shed rate).
//!
//! Results land in `BENCH_deadline_slo.json` at the repo root. Smoke
//! mode (`BENCH_SMOKE=1`, used by `make bench-slo` and CI) shrinks the
//! trace; perf gates are enforced in full runs only, while the
//! zero-silent-timeout invariant is asserted in both modes.

use fusion_stitching::coordinator::batcher::BatchPolicy;
use fusion_stitching::coordinator::metrics::StreamingSummary;
use fusion_stitching::coordinator::{
    DeadlinePolicy, PoolConfig, Rejection, ServerConfig, ServingPool,
};
use fusion_stitching::testutil::TempDir;
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

const BATCH: usize = 4;
const IN_ELEMS: usize = 256;
const DEPTH: usize = 48;
/// Sticky shape key: one worker, one stream — admission, not routing,
/// is under test.
const KEY: u64 = 1;
const SEED: u64 = 0x5105_90A6;
/// Requests per leg.
const REQUESTS_FULL: usize = 1200;
const REQUESTS_SMOKE: usize = 240;

/// Same deep elementwise chain as the serving-throughput bench: `DEPTH`
/// ops over `f32[BATCH, IN_ELEMS]` cycling exp → tanh → add, so each
/// batch costs real interpreter CPU and the service time is stable
/// enough for slack prediction to have something to measure.
fn write_chain_artifact(dir: &std::path::Path) -> std::io::Result<()> {
    let shape = format!("f32[{BATCH},{IN_ELEMS}]{{1,0}}");
    let mut body = String::new();
    body.push_str(&format!("  p0 = {shape} parameter(0)\n"));
    let mut prev = "p0".to_string();
    for i in 0..DEPTH {
        let name = format!("t{i}");
        let line = match i % 3 {
            0 => format!("  {name} = {shape} exponential({prev})\n"),
            1 => format!("  {name} = {shape} tanh({prev})\n"),
            _ => format!("  {name} = {shape} add({prev}, {prev})\n"),
        };
        body.push_str(&line);
        prev = name;
    }
    body.push_str(&format!("  ROOT t = ({shape}) tuple({prev})\n"));
    let text = format!(
        "HloModule chain{DEPTH}, entry_computation_layout={{({shape})->({shape})}}\n\n\
         ENTRY main {{\n{body}}}\n"
    );
    std::fs::write(dir.join("chain.hlo.txt"), text)
}

fn server_config(deadline: Option<DeadlinePolicy>) -> ServerConfig {
    ServerConfig {
        artifact: "chain".into(),
        batch: BATCH,
        in_elems_per_request: IN_ELEMS,
        out_elems_per_request: IN_ELEMS,
        input_dims: vec![BATCH as i64, IN_ELEMS as i64],
        policy: BatchPolicy { max_batch: BATCH, max_wait: Duration::from_millis(1) },
        compile: None,
        buckets: None,
        trace: None,
        deadline,
        faults: None,
    }
}

fn request_input(i: usize) -> Vec<f32> {
    vec![0.01 * (i % 17) as f32; IN_ELEMS]
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Heavy-tailed gap schedule with the requested mean: 1 gap in 16 is an
/// 8x-mean idle stretch, the rest run at 8/15 of the mean — so bursts
/// arrive ~1.9x faster than the average rate while the long gaps keep
/// the overall mean exact.
fn arrival_gaps(n: usize, mean_us: f64, seed: u64) -> Vec<Duration> {
    let mut state = seed;
    (0..n)
        .map(|_| {
            let factor = if splitmix64(&mut state) % 16 == 0 { 8.0 } else { 8.0 / 15.0 };
            Duration::from_nanos((mean_us * factor * 1e3) as u64)
        })
        .collect()
}

/// Per-request service time (µs) at full batches: a saturated window of
/// async requests against a deadline-free pool, wall clock over count.
fn measure_service_us(dir: &std::path::Path) -> f64 {
    let pool = ServingPool::start(
        dir,
        server_config(None),
        PoolConfig { workers: 1, ..PoolConfig::default() },
    )
    .expect("measurement pool");
    let mut pending = Vec::new();
    let drain = |pending: &mut Vec<mpsc::Receiver<anyhow::Result<Vec<f32>>>>| {
        for rx in pending.drain(..) {
            rx.recv().expect("worker alive").expect("served");
        }
    };
    // Warm the buffers/artifact outside the timed window.
    for i in 0..2 * BATCH {
        pending.push(pool.infer_keyed_async(KEY, request_input(i)).expect("warmup"));
    }
    drain(&mut pending);

    let reqs = 96;
    let t0 = Instant::now();
    for i in 0..reqs {
        pending.push(pool.infer_keyed_async(KEY, request_input(i)).expect("submit"));
        if pending.len() == 2 * BATCH {
            drain(&mut pending);
        }
    }
    drain(&mut pending);
    let per_req = t0.elapsed().as_secs_f64() * 1e6 / reqs as f64;
    pool.shutdown().expect("shutdown");
    // Floor against clock granularity on very fast machines.
    per_req.max(20.0)
}

struct Leg {
    name: &'static str,
    mean_gap_us: f64,
    submitted: usize,
    served: u64,
    shed: u64,
    silent: u64,
    p50_us: f64,
    p95_us: f64,
    p99_us: f64,
    shed_rate: f64,
    misses: u64,
    miss_rate: f64,
}

/// Replay one arrival trace against a fresh pool. `policy` arms slack
/// admission (or leaves the historical no-shed semantics), `deadline`
/// is stamped per request. The submitter honors the absolute schedule;
/// a collector thread drains replies so in-flight depth follows the
/// trace, not a fixed window.
fn run_leg(
    dir: &std::path::Path,
    name: &'static str,
    n: usize,
    mean_gap_us: f64,
    policy: Option<DeadlinePolicy>,
    deadline: Option<Duration>,
) -> Leg {
    let pool = ServingPool::start(
        dir,
        server_config(policy),
        PoolConfig { workers: 1, queue_depth: 512, ..PoolConfig::default() },
    )
    .expect("pool start");

    // Deadline-free warmup: seeds the worker's measured exec summary so
    // admission decisions in the trace run on measurements, not the
    // bootstrap estimate, and keeps the cold first batch out of the leg.
    let mut pending = Vec::new();
    for i in 0..4 * BATCH {
        pending.push(pool.infer_keyed_async(KEY, request_input(i)).expect("warmup"));
        if pending.len() == BATCH {
            for rx in pending.drain(..) {
                rx.recv().expect("worker alive").expect("served");
            }
        }
    }

    let gaps = arrival_gaps(n, mean_gap_us, SEED);
    let (meta_tx, meta_rx) =
        mpsc::channel::<(Instant, mpsc::Receiver<anyhow::Result<Vec<f32>>>)>();
    let (lat, served, shed, silent) = std::thread::scope(|scope| {
        let collector = scope.spawn(move || {
            let mut lat = StreamingSummary::default();
            let (mut served, mut shed, mut silent) = (0u64, 0u64, 0u64);
            while let Ok((t, rx)) = meta_rx.recv() {
                match rx.recv_timeout(Duration::from_secs(60)) {
                    Ok(Ok(_)) => {
                        lat.record(t.elapsed());
                        served += 1;
                    }
                    Ok(Err(e)) => {
                        assert!(
                            e.downcast_ref::<Rejection>().is_some(),
                            "reply must be served or structurally shed: {e:#}"
                        );
                        shed += 1;
                    }
                    Err(_) => silent += 1,
                }
            }
            (lat, served, shed, silent)
        });
        let mut next = Instant::now();
        for (i, gap) in gaps.iter().enumerate() {
            next += *gap;
            let wait = next.saturating_duration_since(Instant::now());
            if !wait.is_zero() {
                std::thread::sleep(wait);
            }
            let t = Instant::now();
            let rx = pool
                .infer_keyed_async_with_deadline(KEY, request_input(i), deadline)
                .expect("submit");
            meta_tx.send((t, rx)).expect("collector alive");
        }
        drop(meta_tx);
        collector.join().expect("collector thread")
    });
    let stats = pool.shutdown().expect("shutdown");
    let ps = lat.percentiles_us(&[50.0, 95.0, 99.0]);
    // Warmup traffic carries no deadline, so misses are trace-only.
    let misses = stats.aggregate.deadline_misses;
    Leg {
        name,
        mean_gap_us,
        submitted: n,
        served,
        shed,
        silent,
        p50_us: ps[0],
        p95_us: ps[1],
        p99_us: ps[2],
        shed_rate: shed as f64 / n as f64,
        misses,
        miss_rate: misses as f64 / served.max(1) as f64,
    }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let n = if smoke { REQUESTS_SMOKE } else { REQUESTS_FULL };
    let dir = TempDir::new("deadline-slo");
    write_chain_artifact(dir.path()).expect("writing chain artifact");

    let svc_us = measure_service_us(dir.path());
    // A deadline the service can meet with room for ~3 queued batches,
    // floored against OS scheduling jitter; the overloaded baseline's
    // queue-soaked latency runs orders of magnitude past it.
    let deadline_us = (16.0 * svc_us).max(10_000.0);
    let deadline = Duration::from_micros(deadline_us as u64);
    let policy = || {
        Some(DeadlinePolicy {
            default_deadline: None,
            bootstrap_service_us: svc_us * BATCH as f64,
            ..DeadlinePolicy::default()
        })
    };

    println!(
        "== Deadline SLO: chain depth {DEPTH}, batch {BATCH}, {n} requests/leg, \
         service {svc_us:.0}us/req, deadline {deadline_us:.0}us =="
    );
    println!(
        "{:<20} {:>10} {:>7} {:>6} {:>10} {:>10} {:>10} {:>8} {:>8}",
        "leg", "submitted", "served", "shed", "p50_us", "p95_us", "p99_us", "shed%", "miss%"
    );
    let legs = [
        run_leg(dir.path(), "baseline_saturated", n, svc_us / 2.0, None, None),
        run_leg(dir.path(), "deadline_saturated", n, svc_us / 2.0, policy(), Some(deadline)),
        run_leg(dir.path(), "deadline_moderate", n, svc_us * 2.5, policy(), Some(deadline)),
    ];
    for leg in &legs {
        println!(
            "{:<20} {:>10} {:>7} {:>6} {:>10.1} {:>10.1} {:>10.1} {:>7.2}% {:>7.2}%",
            leg.name,
            leg.submitted,
            leg.served,
            leg.shed,
            leg.p50_us,
            leg.p95_us,
            leg.p99_us,
            100.0 * leg.shed_rate,
            100.0 * leg.miss_rate
        );
    }

    let [baseline, saturated, moderate] = &legs;
    // Zero silent timeouts is a correctness invariant, not a perf gate:
    // every submitted request must come back served or structurally
    // shed, in smoke mode too.
    for leg in &legs {
        assert_eq!(
            leg.served + leg.shed + leg.silent,
            leg.submitted as u64,
            "{}: reply accounting must cover the trace",
            leg.name
        );
        assert_eq!(leg.silent, 0, "{}: zero silent timeouts", leg.name);
    }
    // "p99 within deadline" for admitted requests == at most 1% of the
    // served requests replied past their deadline (worker-side signed
    // slack, immune to collector-thread skew).
    let admitted_p99_within = saturated.miss_rate <= 0.01;
    let baseline_misses_target = baseline.p99_us > deadline_us;
    let shed_bounded = moderate.shed_rate <= 0.05;
    println!(
        "admitted p99 within deadline at saturation: {admitted_p99_within} \
         (miss rate {:.3}%)",
        100.0 * saturated.miss_rate
    );
    println!(
        "no-deadline baseline misses the {deadline_us:.0}us target at p99: \
         {baseline_misses_target} (p99 {:.0}us)",
        baseline.p99_us
    );
    println!(
        "moderate-load shed rate bounded (<= 5%): {shed_bounded} ({:.2}%)",
        100.0 * moderate.shed_rate
    );

    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"workload\": {{\"artifact\": \"chain{DEPTH}\", \"batch\": {BATCH}, \
         \"in_elems_per_request\": {IN_ELEMS}, \"requests_per_leg\": {n}, \
         \"service_us_per_request\": {svc_us:.1}, \"deadline_us\": {deadline_us:.0}, \
         \"arrival\": \"splitmix64 heavy-tail (1/16 gaps 8x mean, rest 8/15x)\", \
         \"seed\": {SEED}, \"smoke\": {smoke}}},\n"
    ));
    json.push_str("  \"legs\": [\n");
    for (k, leg) in legs.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"mean_gap_us\": {:.1}, \"submitted\": {}, \
             \"served\": {}, \"shed\": {}, \"silent_timeouts\": {}, \"p50_us\": {:.1}, \
             \"p95_us\": {:.1}, \"p99_us\": {:.1}, \"shed_rate\": {:.4}, \
             \"deadline_misses\": {}, \"miss_rate\": {:.4}}}{}\n",
            leg.name,
            leg.mean_gap_us,
            leg.submitted,
            leg.served,
            leg.shed,
            leg.silent,
            leg.p50_us,
            leg.p95_us,
            leg.p99_us,
            leg.shed_rate,
            leg.misses,
            leg.miss_rate,
            if k + 1 < legs.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!(
        "  \"admitted_p99_within_deadline\": {admitted_p99_within},\n  \
         \"baseline_p99_misses_deadline\": {baseline_misses_target},\n  \
         \"moderate_shed_rate_bounded\": {shed_bounded}\n"
    ));
    json.push_str("}\n");

    let out_path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => PathBuf::from(dir).join("..").join("BENCH_deadline_slo.json"),
        Err(_) => PathBuf::from("BENCH_deadline_slo.json"),
    };
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {}", out_path.display()),
        Err(e) => eprintln!("could not write {}: {e}", out_path.display()),
    }

    // Perf gates, full runs only — smoke runs on starved CI cores
    // report without failing.
    let gates = [
        (admitted_p99_within, "admitted p99 must stay within the deadline at saturation"),
        (baseline_misses_target, "the no-deadline baseline must demonstrate the miss"),
        (shed_bounded, "moderate load must not shed more than 5%"),
    ];
    for (ok, what) in gates {
        if !ok {
            if smoke {
                eprintln!("NOTE: {what} (smoke mode, not gated)");
            } else {
                eprintln!("FAIL: {what}");
                std::process::exit(1);
            }
        }
    }
}
