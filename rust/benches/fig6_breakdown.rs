//! Fig. 6 — execution breakdown between MatMul/Conv (vendor library)
//! kernels and the fusable portion, per benchmark (§6.2).
//!
//! The paper reports the fusable component at 20–50% of execution for
//! its production-scale graphs; our benchmark stand-ins are smaller, so
//! the fusable share runs higher (documented in EXPERIMENTS.md). The
//! *structure* reproduced here: every workload has both portions, and
//! NMT — dominated by its seven projection/FFN matmuls — has the lowest
//! fusable share.

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{ms, time_it};
use fusion_stitching::coordinator::pipeline::{compile_module, FusionMode, PipelineConfig};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::models;
use fusion_stitching::schedule::PerfLibrary;

fn main() {
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    let cfg = PipelineConfig::default();
    println!("== Fig. 6: execution breakdown (XLA-baseline compile, simulated Pascal) ==");
    println!(
        "{:<8} {:>7} {:>7} {:>12} {:>12} {:>9} {:>12}",
        "model", "lib_k", "gen_k", "library_us", "fusable_us", "fusable%", "sim_wall"
    );
    let mut shares = Vec::new();
    for (meta, module) in models::all_benchmarks() {
        let compiled =
            compile_module(&module, FusionMode::XlaBaseline, &mut lib, &cfg).unwrap();
        let (t, _) = time_it(1, 5, || {
            compile_module(&module, FusionMode::XlaBaseline, &mut lib, &cfg).unwrap().timing
        });
        let timing = &compiled.timing;
        let share = timing.fusable_ratio();
        shares.push((meta.name, share));
        println!(
            "{:<8} {:>7} {:>7} {:>12.1} {:>12.1} {:>8.1}% {:>10.1}ms",
            meta.name,
            timing.library_kernels,
            timing.generated_kernels,
            timing.library_us,
            timing.fusable_us,
            100.0 * share,
            ms(t)
        );
        assert!(timing.library_us > 0.0 && timing.fusable_us > 0.0);
    }
    let nmt = shares.iter().find(|(n, _)| *n == "NMT").unwrap().1;
    assert!(
        shares.iter().all(|(n, s)| *n == "NMT" || *s >= nmt),
        "NMT should have the lowest fusable share (matmul-dominated)"
    );
}
