//! E2E serving bench — the paper's latency-critical online NMT use case
//! (§6.1) on the real runtime: AOT-compiled JAX/Pallas artifacts
//! executed by the Rust coordinator over the HLO-text interpreter
//! (`runtime::interp`, the PJRT-shaped CPU backend), fused (stitched
//! Pallas attention) vs unfused (op-by-op) variants, batched requests.
//!
//! Run `make artifacts` first. Reports per-variant latency percentiles
//! and throughput. Note: both artifact variants execute on the same
//! host interpreter, so this validates *numerics and the serving
//! path*; executed kernel-launch savings are measured by the
//! `launch_reduction` bench on the stitched VM (`exec`).

#[path = "bench_util.rs"]
mod bench_util;

use fusion_stitching::coordinator::batcher::BatchPolicy;
use fusion_stitching::coordinator::metrics::{throughput_rps, StreamingSummary};
use fusion_stitching::coordinator::{ServerConfig, ServingCoordinator};
use std::path::Path;
use std::time::{Duration, Instant};

const BATCH: usize = 8;
const SEQ: usize = 64;
const MODEL: usize = 512;
const DIM: usize = 64;
const REQUESTS: usize = 96;

fn bench_variant(artifact: &str) -> Option<(f64, f64, f64, usize)> {
    let dir = Path::new("artifacts");
    let cfg = ServerConfig {
        artifact: artifact.to_string(),
        batch: BATCH,
        in_elems_per_request: SEQ * MODEL,
        out_elems_per_request: SEQ * DIM,
        input_dims: vec![(BATCH * SEQ) as i64, MODEL as i64],
        policy: BatchPolicy { max_batch: BATCH, max_wait: Duration::from_millis(2) },
        compile: None,
        buckets: None,
        trace: None,
        deadline: None,
        faults: None,
    };
    let srv = ServingCoordinator::start(dir, cfg).ok()?;
    // warmup (first execution touches every buffer cold)
    let _ = srv.infer(vec![0.1; SEQ * MODEL]).ok()?;

    let mut lat = StreamingSummary::default();
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for i in 0..REQUESTS {
        let input = vec![0.01 * (i % 11) as f32; SEQ * MODEL];
        pending.push((Instant::now(), srv.infer_async(input).unwrap()));
        if pending.len() == BATCH {
            for (t, rx) in pending.drain(..) {
                rx.recv().unwrap().unwrap();
                lat.record(t.elapsed());
            }
        }
    }
    for (t, rx) in pending.drain(..) {
        rx.recv().unwrap().unwrap();
        lat.record(t.elapsed());
    }
    let wall = t0.elapsed();
    let stats = srv.shutdown().unwrap();
    let ps = lat.percentiles_us(&[50.0, 95.0]);
    Some((ps[0] / 1e3, ps[1] / 1e3, throughput_rps(lat.count() as usize, wall), stats.batches))
}

fn main() {
    println!("== E2E serving: NMT attention, fused (stitched Pallas) vs unfused ==");
    println!(
        "{:<20} {:>10} {:>10} {:>12} {:>9}",
        "artifact", "p50_ms", "p95_ms", "throughput", "batches"
    );
    let mut any = false;
    for artifact in ["attention_fused", "attention_unfused"] {
        match bench_variant(artifact) {
            Some((p50, p95, rps, batches)) => {
                any = true;
                println!(
                    "{artifact:<20} {p50:>10.2} {p95:>10.2} {rps:>9.0}r/s {batches:>9}"
                );
            }
            None => println!("{artifact:<20} — missing (run `make artifacts`)"),
        }
    }
    if !any {
        eprintln!("no artifacts found; skipping (run `make artifacts` first)");
    }
}
