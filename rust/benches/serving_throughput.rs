//! Serving-throughput bench — the multi-worker sharded pool vs the
//! single-stream serving loop, on an NMT-style latency-critical
//! workload (§6.1: small batches, heavy traffic).
//!
//! Four client threads stream requests under four distinct shape keys
//! (multi-tenant traffic) into a [`ServingPool`] at 1, 2 and 4 workers.
//! Sticky shape-key sharding keeps each worker's batches shape-pure, so
//! scaling comes from two places the single-worker loop cannot reach:
//! real parallelism across cores, and un-fragmented batches (one worker
//! fed interleaved shapes closes a batch at every key flip). Compile-once
//! serving stays on: every batch routes through the shared
//! [`SharedCompileService`], whose cache hits are concurrent and whose
//! one cold compile is single-flight.
//!
//! Results (aggregate requests/sec and p50/p95/p99 end-to-end latency
//! per worker count) are persisted to `BENCH_serving_throughput.json`
//! at the repo root. Smoke mode (`BENCH_SMOKE=1`, used by
//! `make bench-serving` and CI) shrinks the request volume.

use fusion_stitching::coordinator::batcher::BatchPolicy;
use fusion_stitching::coordinator::metrics::{throughput_rps, StreamingSummary};
use fusion_stitching::coordinator::server::CompileOptions;
use fusion_stitching::coordinator::{
    FusionMode, PipelineConfig, PoolConfig, ServerConfig, ServingPool,
};
use fusion_stitching::models;
use fusion_stitching::testutil::TempDir;
use std::path::PathBuf;
use std::time::{Duration, Instant};

const BATCH: usize = 4;
const IN_ELEMS: usize = 256;
const DEPTH: usize = 48;
const CLIENTS: usize = 4;
/// Requests submitted per client (per worker-count measurement).
const REQUESTS_FULL: usize = 2000;
const REQUESTS_SMOKE: usize = 300;
/// In-flight requests a client keeps open before collecting responses.
const WINDOW: usize = 16;

/// Write a deep elementwise-chain artifact: `DEPTH` ops over
/// `f32[BATCH, IN_ELEMS]`, cycling exp → tanh → add (numerically stable
/// under repetition). Executed op-by-op by the interpreter, each batch
/// costs real CPU work — the stand-in for the NMT attention block that
/// `make artifacts` would bake (this bench cannot assume jax).
fn write_chain_artifact(dir: &std::path::Path) -> std::io::Result<()> {
    let shape = format!("f32[{BATCH},{IN_ELEMS}]{{1,0}}");
    let mut body = String::new();
    body.push_str(&format!("  p0 = {shape} parameter(0)\n"));
    let mut prev = "p0".to_string();
    for i in 0..DEPTH {
        let name = format!("t{i}");
        let line = match i % 3 {
            0 => format!("  {name} = {shape} exponential({prev})\n"),
            1 => format!("  {name} = {shape} tanh({prev})\n"),
            _ => format!("  {name} = {shape} add({prev}, {prev})\n"),
        };
        body.push_str(&line);
        prev = name;
    }
    body.push_str(&format!("  ROOT t = ({shape}) tuple({prev})\n"));
    let text = format!(
        "HloModule chain{DEPTH}, entry_computation_layout={{({shape})->({shape})}}\n\n\
         ENTRY main {{\n{body}}}\n"
    );
    std::fs::write(dir.join("chain.hlo.txt"), text)
}

fn server_config() -> ServerConfig {
    // Compile-once serving over the NMT benchmark module, as the CLI's
    // serve command does; the pool's shared service answers every batch
    // after the single cold compile.
    let compile = models::by_name("NMT").map(|(meta, module)| {
        let mut pipeline = PipelineConfig::default();
        pipeline.deep.fuse_batch_dot = meta.fuse_batch_dot;
        CompileOptions {
            module,
            mode: FusionMode::FusionStitching,
            pipeline,
            use_stitched_backend: false,
            specialize: None,
        }
    });
    ServerConfig {
        artifact: "chain".into(),
        batch: BATCH,
        in_elems_per_request: IN_ELEMS,
        out_elems_per_request: IN_ELEMS,
        input_dims: vec![BATCH as i64, IN_ELEMS as i64],
        policy: BatchPolicy { max_batch: BATCH, max_wait: Duration::from_millis(1) },
        compile,
        buckets: None,
        trace: None,
        deadline: None,
        faults: None,
    }
}

struct Measurement {
    workers: usize,
    rps: f64,
    p50_us: f64,
    p95_us: f64,
    p99_us: f64,
    batches: usize,
    requests: usize,
    cache_hits: u64,
    cache_misses: u64,
    cold_compiles: u64,
}

/// Keys whose sticky routes cover as many shards as possible, one per
/// client — so at 4 workers each client stream owns a shard, and at 1
/// worker all four streams interleave into the same queue.
fn client_keys(pool: &ServingPool, n: usize) -> Vec<u64> {
    let mut keys = Vec::new();
    let mut shards_seen = std::collections::HashSet::new();
    for key in 0..4096u64 {
        if shards_seen.insert(pool.route(key)) {
            keys.push(key);
            if keys.len() == n {
                return keys;
            }
        }
    }
    // fewer shards than clients: reuse keys round-robin
    while keys.len() < n {
        keys.push(keys[keys.len() % shards_seen.len().max(1)]);
    }
    keys
}

fn run_one(dir: &std::path::Path, workers: usize, requests: usize) -> Measurement {
    let pool = ServingPool::start(
        dir,
        server_config(),
        PoolConfig { workers, queue_depth: 64, ..PoolConfig::default() },
    )
    .expect("pool start");
    let keys = client_keys(&pool, CLIENTS);

    // Warmup: one round-trip per key pays the cold compile (single
    // flight) and touches every shard's buffers outside the window.
    for &key in &keys {
        pool.infer_keyed(key, vec![0.1; IN_ELEMS]).expect("warmup");
    }
    // Baseline snapshot so warmup traffic is excluded from the
    // reported aggregates (keeps the JSON internally consistent with
    // clients x requests_per_client).
    let warm = pool.stats();

    let t0 = Instant::now();
    let lat = std::thread::scope(|scope| {
        let handles: Vec<_> = keys
            .iter()
            .map(|&key| {
                let pool = &pool;
                scope.spawn(move || {
                    let mut lat = StreamingSummary::default();
                    let mut pending = Vec::with_capacity(WINDOW);
                    for i in 0..requests {
                        let input = vec![0.01 * (i % 17) as f32; IN_ELEMS];
                        let submitted = Instant::now();
                        let rx = pool.infer_keyed_async(key, input).expect("submit");
                        pending.push((submitted, rx));
                        if pending.len() == WINDOW {
                            for (t, rx) in pending.drain(..) {
                                rx.recv().expect("response").expect("execution");
                                lat.record(t.elapsed());
                            }
                        }
                    }
                    for (t, rx) in pending.drain(..) {
                        rx.recv().expect("response").expect("execution");
                        lat.record(t.elapsed());
                    }
                    lat
                })
            })
            .collect();
        let mut merged = StreamingSummary::default();
        for h in handles {
            merged.merge(&h.join().expect("client thread"));
        }
        merged
    });
    let wall = t0.elapsed();
    let stats = pool.shutdown().expect("shutdown");
    let ps = lat.percentiles_us(&[50.0, 95.0, 99.0]);
    Measurement {
        workers,
        rps: throughput_rps(lat.count() as usize, wall),
        p50_us: ps[0],
        p95_us: ps[1],
        p99_us: ps[2],
        batches: stats.aggregate.batches - warm.aggregate.batches,
        requests: stats.aggregate.requests - warm.aggregate.requests,
        cache_hits: stats.cache.map(|c| c.hits).unwrap_or(0)
            - warm.cache.map(|c| c.hits).unwrap_or(0),
        cache_misses: stats.cache.map(|c| c.misses).unwrap_or(0)
            - warm.cache.map(|c| c.misses).unwrap_or(0),
        cold_compiles: stats.cold_compiles.unwrap_or(0),
    }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let requests = if smoke { REQUESTS_SMOKE } else { REQUESTS_FULL };
    let dir = TempDir::new("serving-throughput");
    write_chain_artifact(dir.path()).expect("writing chain artifact");

    println!(
        "== Serving throughput: sharded pool, {CLIENTS} client streams x {requests} requests \
         (chain depth {DEPTH}, batch {BATCH}) =="
    );
    println!(
        "{:<8} {:>12} {:>10} {:>10} {:>10} {:>9} {:>10}",
        "workers", "req/s", "p50_us", "p95_us", "p99_us", "batches", "cold"
    );
    let mut rows = Vec::new();
    for workers in [1usize, 2, 4] {
        let m = run_one(dir.path(), workers, requests);
        println!(
            "{:<8} {:>12.0} {:>10.1} {:>10.1} {:>10.1} {:>9} {:>10}",
            m.workers, m.rps, m.p50_us, m.p95_us, m.p99_us, m.batches, m.cold_compiles
        );
        rows.push(m);
    }
    let speedup = rows[2].rps / rows[0].rps.max(1e-9);
    let single_flight = rows.iter().all(|m| m.cold_compiles <= 1);
    println!("aggregate speedup 4 workers vs 1: {speedup:.2}x");
    println!(
        "single-flight cold compiles held: {} (per-run cold counts: {:?})",
        single_flight,
        rows.iter().map(|m| m.cold_compiles).collect::<Vec<_>>()
    );

    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"workload\": {{\"artifact\": \"chain{DEPTH}\", \"batch\": {BATCH}, \
         \"in_elems_per_request\": {IN_ELEMS}, \"clients\": {CLIENTS}, \
         \"requests_per_client\": {requests}, \"compile_once\": true, \
         \"smoke\": {smoke}}},\n"
    ));
    json.push_str("  \"results\": [\n");
    for (k, m) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"workers\": {}, \"rps\": {:.1}, \"p50_us\": {:.1}, \"p95_us\": {:.1}, \
             \"p99_us\": {:.1}, \"batches\": {}, \"requests\": {}, \"cache_hits\": {}, \
             \"cache_misses\": {}, \"cold_compiles\": {}}}{}\n",
            m.workers,
            m.rps,
            m.p50_us,
            m.p95_us,
            m.p99_us,
            m.batches,
            m.requests,
            m.cache_hits,
            m.cache_misses,
            m.cold_compiles,
            if k + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!("  \"speedup_4v1\": {speedup:.3},\n"));
    json.push_str(&format!("  \"single_flight_cold_compiles\": {single_flight}\n"));
    json.push_str("}\n");

    let out_path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => PathBuf::from(dir).join("..").join("BENCH_serving_throughput.json"),
        Err(_) => PathBuf::from("BENCH_serving_throughput.json"),
    };
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {}", out_path.display()),
        Err(e) => eprintln!("could not write {}: {e}", out_path.display()),
    }

    // Acceptance gate (full runs only): 4 workers must deliver >= 2x
    // the single worker's aggregate throughput. Smoke runs report
    // without gating — CI runners may have fewer than 4 cores, where
    // the parallelism half of the win physically cannot materialize.
    if speedup < 2.0 {
        if smoke {
            eprintln!(
                "NOTE: speedup {speedup:.2}x below the 2x target (smoke mode, not gated); \
                 see the JSON for the measured curve"
            );
        } else {
            eprintln!("FAIL: aggregate speedup {speedup:.2}x at 4 workers, need >= 2x");
            std::process::exit(1);
        }
    }
}
