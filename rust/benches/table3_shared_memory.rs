//! Table 3 — shared-memory statistics of FusionStitching-compiled
//! kernels: average/max bytes per kernel, how many kernels triggered
//! size shrinking (§5.1.2) against the 20 KB budget, and the shared
//! (reused) fraction of allocated space (§5.1.3).
//!
//! Paper's rows: LR/W2V tiny (≤ 288 B), Speech the heaviest (avg 9.5 KB,
//! max 16.4 KB, 3 shrinks), NMT with the highest shared ratio (0.17).
//! Shape asserted: LR/W2V ≤ RNN-class ≤ Speech/NMT usage, and NMT's
//! shared ratio > 0 (Figure 3 reuse).

#[path = "bench_util.rs"]
mod bench_util;

use fusion_stitching::coordinator::pipeline::{compile_module, FusionMode, PipelineConfig};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::models;
use fusion_stitching::schedule::PerfLibrary;

fn main() {
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    println!("== Table 3: shared memory statistics (20 KB kernel budget) ==");
    println!(
        "{:<8} {:>10} {:>10} {:>8} {:>13}",
        "model", "avg_B", "max_B", "#shrink", "shared_ratio"
    );
    let mut rows = Vec::new();
    for (meta, module) in models::all_benchmarks() {
        let mut cfg = PipelineConfig::default();
        cfg.deep.fuse_batch_dot = meta.fuse_batch_dot;
        let fs = compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        let (avg, max, shrinks, shared) = fs.shm_stats();
        println!(
            "{:<8} {:>10.0} {:>10} {:>8} {:>13.2}",
            meta.name, avg, max, shrinks, shared
        );
        // every kernel respects the budget
        for k in &fs.kernels {
            assert!(
                k.shm.total_bytes <= cfg.deep.device.shared_mem_kernel_limit,
                "{}: kernel over budget",
                meta.name
            );
        }
        rows.push((meta.name, avg, max, shared));
    }
    let get = |n: &str| rows.iter().find(|(m, ..)| *m == n).unwrap().clone();
    let (_, lr_avg, ..) = get("LR");
    let (_, _, nmt_max, nmt_shared) = get("NMT");
    let (_, _, speech_max, _) = get("Speech");
    assert!(lr_avg < 1024.0, "LR's smem use should be tiny");
    assert!(nmt_max > 1024 && speech_max > 1024, "complex graphs use real smem");
    assert!(nmt_shared > 0.0, "NMT must exhibit buffer reuse (Fig. 3)");
}
