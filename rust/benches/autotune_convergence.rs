//! Feedback-directed autotuning bench — the convergence acceptance gate.
//!
//! Two legs, both landing in `BENCH_autotune_convergence.json`:
//!
//! - **convergence** — replays all six Table 2 models on the stitched VM
//!   for several measurement epochs. Before each epoch the cost oracle
//!   is rebuilt from the perf library's measured store; after it, the
//!   epoch's wall-clock samples are written back. The per-epoch
//!   divergence (mean `|ln(oracle_estimate / measured_p50)|` over the
//!   launched groups) must *shrink*: epoch 0 compares the analytic GPU
//!   model against CPU-VM wall time (large), later epochs compare the
//!   measured overlay against fresh samples (noise floor).
//! - **hot_swap** — a live serving pool with the autotune thread armed
//!   and a seeded model/measurement contradiction: the background
//!   re-explore must swap the served module mid-traffic at least once
//!   with zero failed or rejected requests.
//!
//! Smoke mode (`BENCH_SMOKE=1`, used by `make bench-autotune` and CI)
//! shrinks epochs/replays and reports without gating — short runs on
//! noisy shared runners cannot hold the convergence bound honestly.

use fusion_stitching::coordinator::batcher::BatchPolicy;
use fusion_stitching::coordinator::metrics::trimmed_stats;
use fusion_stitching::coordinator::pipeline::geomean;
use fusion_stitching::coordinator::server::CompileOptions;
use fusion_stitching::coordinator::{
    compile_module, AutotuneConfig, CompiledModule, FusionMode, PipelineConfig, PoolConfig,
    ServerConfig, ServingPool, SharedCompileService,
};
use fusion_stitching::exec::ExecArena;
use fusion_stitching::hlo::{GraphBuilder, Module, ReduceKind, Shape};
use fusion_stitching::models;
use fusion_stitching::obs::{
    self, Json, KernelProfile, KernelProfileHandle, TraceConfig, TraceSink,
};
use fusion_stitching::schedule::{CostOracle, MeasuredCost, PerfLibrary};
use fusion_stitching::testutil::TempDir;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Identity-ish artifact so the pool's engine has something to parse;
/// batches execute on the stitched backend, never on this text.
const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[4,3]{1,0})->(f32[4,3]{1,0})}

ENTRY main {
  p0 = f32[4,3]{1,0} parameter(0)
  sum = f32[4,3]{1,0} add(p0, p0)
  ROOT t = (f32[4,3]{1,0}) tuple(sum)
}
"#;

fn fill(n: usize, seed: u64) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let h = (i as u64).wrapping_mul(2654435761).wrapping_add(seed.wrapping_mul(97));
            ((h % 1000) as f32) / 1000.0 - 0.5
        })
        .collect()
}

fn inputs_for(module: &Module, seed: u64) -> Vec<Vec<f32>> {
    module
        .entry
        .parameters()
        .into_iter()
        .enumerate()
        .map(|(k, id)| {
            let elems = module.entry.get(id).shape.num_elements() as usize;
            fill(elems, seed + k as u64)
        })
        .collect()
}

/// Mean `|ln(estimate / measured_p50)|` over the groups this epoch
/// actually launched and priced — the scalar the curve is made of.
fn epoch_divergence(oracle: &MeasuredCost, snap: &KernelProfile) -> Option<f64> {
    let mut sum = 0.0;
    let mut n = 0usize;
    for (fp, g) in snap.groups() {
        if g.launches == 0 || g.modeled_us <= 0.0 {
            continue;
        }
        let (_, p50, _) = trimmed_stats(g.measured_us.samples());
        if p50 <= 0.0 {
            continue;
        }
        let est = oracle.group_cost_us(fp, g.modeled_us).max(1e-9);
        sum += (est / p50).ln().abs();
        n += 1;
    }
    if n > 0 {
        Some(sum / n as f64)
    } else {
        None
    }
}

/// See `tests/autotune.rs`: the modeled-optimal plan keeps the wide
/// elementwise producer out of the scalar-rooted reduce group, so a
/// contradiction in the measured store forces a visibly different plan.
fn swap_module() -> Module {
    let mut b = GraphBuilder::new("entry");
    let x = b.param("x", Shape::f32(&[1024, 256]));
    let e = b.exp(x);
    let r = b.reduce(e, &[0, 1], ReduceKind::Sum);
    let t = b.tanh(r);
    Module::new("swapdemo", b.finish(t))
}

fn contradiction(artifact: &CompiledModule, wall_us: f64) -> KernelProfile {
    let seeded = artifact.profile.snapshot();
    let mut fed = KernelProfile::default();
    for (fp, g) in seeded.groups() {
        for _ in 0..16 {
            fed.record_launch(fp, g.tier, g.modeled_us, wall_us, 0, 0);
        }
    }
    fed
}

struct ModelCurve {
    name: &'static str,
    groups: usize,
    curve: Vec<(f64, usize)>, // (divergence, override count) per epoch
}

struct SwapResult {
    requests: u64,
    errors: u64,
    rejected: usize,
    generations: u64,
    swap_wait_ms: f64,
}

/// Serve one module through a pool with the autotuner armed until the
/// hot swap lands (or the deadline passes), then keep serving to prove
/// the swapped module answers traffic.
fn run_hot_swap_leg() -> SwapResult {
    let dir = TempDir::new("autotune-bench");
    std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).expect("artifact write");

    let module = swap_module();
    let in_elems = 1024 * 256;
    let cfg = ServerConfig {
        artifact: "double".into(),
        batch: 1,
        in_elems_per_request: in_elems,
        out_elems_per_request: 1,
        input_dims: vec![1024, 256],
        policy: BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
        compile: Some(CompileOptions {
            module: module.clone(),
            mode: FusionMode::FusionStitching,
            pipeline: PipelineConfig::default(),
            use_stitched_backend: true,
            specialize: None,
        }),
        buckets: None,
        trace: None,
        deadline: None,
        faults: None,
    };

    let service = Arc::new(SharedCompileService::new(PipelineConfig::default()));
    let (base, _) =
        service.compile(&module, FusionMode::FusionStitching).expect("warmup compile");
    assert!(base.executable.is_some(), "stitched serving needs a lowered module");
    assert!(service.absorb_profile(&contradiction(&base, 1e9)) > 0);

    // min_launches = MAX keeps the live write-back from diluting the
    // seeded contradiction mid-bench; the swap itself is the point here.
    let pool = ServingPool::start_with_service(
        dir.path(),
        cfg,
        PoolConfig {
            workers: 2,
            queue_depth: 16,
            autotune: Some(AutotuneConfig {
                interval: Duration::from_millis(5),
                min_launches: u64::MAX,
            }),
            ..PoolConfig::default()
        },
        service.clone(),
    )
    .expect("pool start");

    let input = vec![0.25f32; in_elems];
    let t0 = Instant::now();
    let deadline = t0 + Duration::from_secs(30);
    let mut requests = 0u64;
    let mut errors = 0u64;
    while service.generation() == 0 && Instant::now() < deadline {
        if pool.infer_keyed(requests, input.clone()).is_err() {
            errors += 1;
        }
        requests += 1;
    }
    let swap_wait_ms = t0.elapsed().as_secs_f64() * 1e3;
    for k in 0..16u64 {
        if pool.infer_keyed(1000 + k, input.clone()).is_err() {
            errors += 1;
        }
        requests += 1;
    }

    let generations = service.generation();
    let stats = pool.shutdown().expect("clean shutdown");
    SwapResult { requests, errors, rejected: stats.aggregate.rejected, generations, swap_wait_ms }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok() || std::env::args().any(|a| a == "--smoke");
    let (epochs, replays) = if smoke { (3usize, 12usize) } else { (6, 40) };
    let mode_name = if smoke { "smoke" } else { "full" };
    println!(
        "== feedback-directed autotuning: oracle convergence + hot swap \
         ({mode_name}, {epochs} epochs x {replays} replays) =="
    );

    // Leg 1: measured write-back shrinks the oracle's divergence.
    let mut curves: Vec<ModelCurve> = Vec::new();
    for (meta, module) in models::all_benchmarks() {
        let mut cfg = PipelineConfig::default();
        cfg.deep.fuse_batch_dot = meta.fuse_batch_dot;
        let mut lib = PerfLibrary::new(cfg.deep.device.clone());
        let compiled = compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg)
            .unwrap_or_else(|e| panic!("{}: compile failed: {e:#}", meta.name));
        let exe = compiled
            .executable
            .clone()
            .unwrap_or_else(|| panic!("{}: did not lower: {:?}", meta.name, compiled.exec_error));
        let inputs = inputs_for(&module, 42);
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();

        let mut cumulative = KernelProfile::default();
        let mut curve = Vec::new();
        for _ in 0..epochs {
            // The oracle the compiler would use *right now*, from the
            // samples written back so far (epoch 0: pure model).
            let oracle = MeasuredCost::from_library(&lib);
            let epoch_profile = KernelProfileHandle::new();
            {
                let sink = TraceSink::new(TraceConfig::default());
                let _g = obs::install(&sink, 0, Some(epoch_profile.clone()));
                let mut arena = ExecArena::default();
                let mut out = Vec::new();
                for _ in 0..replays {
                    exe.run_into(&refs, &mut arena, &mut out).expect("replay failed");
                }
            }
            let snap = epoch_profile.snapshot();
            let d = epoch_divergence(&oracle, &snap).unwrap_or(0.0);
            curve.push((d, oracle.override_count()));
            // Write back: the *cumulative* profile carries the monotone
            // launch counts the library's high-water absorb keys on.
            cumulative.merge(&snap);
            lib.absorb_profile(&cumulative);
        }
        let shown: Vec<String> = curve.iter().map(|(d, _)| format!("{d:.3}")).collect();
        println!(
            "{:<8} {:>2} groups  divergence/epoch: [{}]",
            meta.name,
            compiled.plan.generated_kernel_count(&module.entry),
            shown.join(", ")
        );
        curves.push(ModelCurve {
            name: meta.name,
            groups: compiled.plan.generated_kernel_count(&module.entry),
            curve,
        });
    }

    let first_geo = geomean(curves.iter().map(|c| c.curve[0].0.max(1e-6)));
    let last_geo = geomean(curves.iter().map(|c| c.curve[epochs - 1].0.max(1e-6)));
    let converged = last_geo < first_geo;
    println!(
        "geomean divergence: epoch 0 = {first_geo:.3}, epoch {} = {last_geo:.3} \
         ({})",
        epochs - 1,
        if converged { "shrinks" } else { "DID NOT SHRINK" }
    );

    // Leg 2: hot swap under live traffic.
    let swap = run_hot_swap_leg();
    println!(
        "hot swap: {} requests, {} errors, {} rejected, {} swap(s), first swap after {:.0} ms",
        swap.requests, swap.errors, swap.rejected, swap.generations, swap.swap_wait_ms
    );

    let swap_ok = swap.generations >= 1 && swap.errors == 0 && swap.rejected == 0;
    let pass = converged && swap_ok;

    let mut j = Json::new();
    j.begin_obj();
    j.field_str("bench", "autotune_convergence");
    j.field_bool("smoke", smoke);
    j.field_uint("epochs", epochs as u64);
    j.field_uint("replays_per_epoch", replays as u64);
    j.key("models").begin_arr();
    for c in &curves {
        j.begin_obj();
        j.field_str("model", c.name);
        j.field_uint("generated_kernels", c.groups as u64);
        j.key("divergence_per_epoch").begin_arr();
        for (d, overrides) in &c.curve {
            j.begin_obj();
            j.field_num("divergence", *d);
            j.field_uint("oracle_overrides", *overrides as u64);
            j.end_obj();
        }
        j.end_arr();
        j.field_num("first_divergence", c.curve[0].0);
        j.field_num("last_divergence", c.curve[epochs - 1].0);
        j.end_obj();
    }
    j.end_arr();
    j.field_num("geomean_first_divergence", first_geo);
    j.field_num("geomean_last_divergence", last_geo);
    j.key("hot_swap")
        .begin_obj()
        .field_uint("requests", swap.requests)
        .field_uint("errors", swap.errors)
        .field_uint("rejected", swap.rejected as u64)
        .field_uint("generations", swap.generations)
        .field_num("first_swap_ms", swap.swap_wait_ms)
        .field_bool("pass", swap_ok)
        .end_obj();
    j.key("gate")
        .begin_obj()
        .field_bool("converged", converged)
        .field_bool("enforced", !smoke)
        .field_bool("pass", pass)
        .end_obj();
    j.end_obj();
    let json = j.finish();

    let out_path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => PathBuf::from(dir).join("..").join("BENCH_autotune_convergence.json"),
        Err(_) => PathBuf::from("BENCH_autotune_convergence.json"),
    };
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {}", out_path.display()),
        Err(e) => eprintln!("could not write {}: {e}", out_path.display()),
    }

    if !pass {
        if smoke {
            eprintln!(
                "NOTE: gate not met (smoke mode, not gated): converged={converged} \
                 swap_ok={swap_ok}"
            );
        } else {
            eprintln!(
                "FAIL: autotune gate: converged={converged} \
                 (geomean {first_geo:.3} -> {last_geo:.3}), swap_ok={swap_ok} \
                 ({} swaps, {} errors, {} rejected)",
                swap.generations, swap.errors, swap.rejected
            );
            std::process::exit(1);
        }
    }
}
