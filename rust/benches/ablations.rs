//! Ablation study — isolating the contribution of each design choice
//! DESIGN.md calls out, over the six benchmarks:
//!
//! - **no ElementwiseFusion** (skip §3.2's intra-layer pass);
//! - **no BatchDot fusion** (the §2.1 user knob, off everywhere);
//! - **single-block tuning only** (no schedule search: always the §4.3
//!   fallback — isolates what tuning buys);
//! - **tiny shared-memory budget** (1 KB instead of 20 KB — isolates
//!   what the smem intermediary buys via the §5.1.2 feedback loop).
//!
//! Reported per ablation: geomean fusion ratio and geomean simulated
//! E2E speedup vs the XLA baseline.

#[path = "bench_util.rs"]
mod bench_util;

use fusion_stitching::coordinator::pipeline::{
    compile_module, geomean, FusionMode, PipelineConfig,
};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::models;
use fusion_stitching::schedule::PerfLibrary;

fn run(tag: &str, tweak: impl Fn(&mut PipelineConfig)) -> (f64, f64) {
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    let mut ratios = Vec::new();
    let mut e2e = Vec::new();
    for (meta, module) in models::all_benchmarks() {
        let mut cfg = PipelineConfig::default();
        cfg.deep.fuse_batch_dot = meta.fuse_batch_dot;
        tweak(&mut cfg);
        let base = compile_module(&module, FusionMode::XlaBaseline, &mut lib, &cfg).unwrap();
        let fs =
            compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        ratios.push(
            fs.plan.generated_kernel_count(&module.entry) as f64
                / base.plan.generated_kernel_count(&module.entry).max(1) as f64,
        );
        e2e.push(base.timing.total_us() / fs.timing.total_us().max(1e-9));
    }
    let (r, s) = (geomean(ratios), geomean(e2e));
    println!("{tag:<28} {r:>12.2} {s:>12.2}");
    (r, s)
}

fn main() {
    println!("== Ablations (geomean over the 6 benchmarks) ==");
    println!("{:<28} {:>12} {:>12}", "variant", "fusion_ratio", "e2e_speedup");

    let (full_r, full_s) = run("full FusionStitching", |_| {});

    let (no_ew_r, _) = run("no ElementwiseFusion", |cfg| {
        // intra-layer groups need ≥2 members; force the threshold to 0
        cfg.deep.elementwise.max_footprint_bytes = 0;
    });

    let (no_bd_r, _) = run("no BatchDot fusion", |cfg| {
        cfg.deep.fuse_batch_dot = false;
    });

    let (_one_block_r, one_block_s) = run("single-block schedules", |cfg| {
        cfg.deep.tuning.max_schedules_per_root = 1; // (0,1,Row) only
    });

    let (tiny_smem_r, _) = run("1 KB smem budget", |cfg| {
        cfg.deep.device.shared_mem_kernel_limit = 1024;
    });

    println!();
    // Each mechanism must contribute: removing it loses fusion and/or
    // speedup. (≥: ties allowed — a mechanism can be neutral on these
    // six graphs, but never negative.)
    assert!(no_ew_r >= full_r - 1e-9, "ElementwiseFusion should only help the ratio");
    assert!(no_bd_r >= full_r - 1e-9, "BatchDot fusion should only help the ratio");
    assert!(tiny_smem_r >= full_r - 1e-9, "smem budget gates stitched groups");
    assert!(
        one_block_s <= full_s + 1e-9,
        "schedule tuning must not hurt simulated E2E"
    );
    println!("full={full_r:.2}; each ablation keeps ratio ≥ full (mechanisms all contribute)");
}
