//! Launch-reduction bench — the paper's Fig. 7 *executed*, not
//! estimated.
//!
//! For every Table 2 benchmark the module is compiled under both
//! fusion modes, lowered to the stitched VM and **run**; the
//! `LaunchLedger` then reports how many kernel launches each plan
//! actually paid. A corpus section additionally measures deep fusion
//! against the true per-op baseline (the op-by-op interpreter) on
//! synthetic graphs. Results, including the geometric-mean ratio, are
//! persisted to `BENCH_launch_reduction.json` at the repo root.
//!
//! Smoke mode (`BENCH_SMOKE=1`, used by `make bench-launches` and CI)
//! restricts to the light models and a smaller corpus.

use fusion_stitching::coordinator::pipeline::{
    compile_module, geomean, FusionMode, PipelineConfig,
};
use fusion_stitching::corpus::generator::{generate_models, CorpusConfig};
use fusion_stitching::exec::{LaunchLedger, StitchedExecutable};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::hlo::printer::xla_text;
use fusion_stitching::hlo::Module;
use fusion_stitching::models;
use fusion_stitching::runtime::interp::HloProgram;
use fusion_stitching::schedule::PerfLibrary;
use std::path::PathBuf;

fn fill(n: usize, seed: u64) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let h = (i as u64).wrapping_mul(2654435761).wrapping_add(seed.wrapping_mul(97));
            ((h % 1000) as f32) / 1000.0 - 0.5
        })
        .collect()
}

fn inputs_for(module: &Module, seed: u64) -> Vec<Vec<f32>> {
    module
        .entry
        .parameters()
        .into_iter()
        .enumerate()
        .map(|(k, id)| {
            let elems = module.entry.get(id).shape.num_elements() as usize;
            fill(elems, seed + k as u64)
        })
        .collect()
}

/// Compile + lower one module; `Err` carries the reason (kept in the
/// JSON so skips are visible, never silent).
fn lower(
    module: &Module,
    mode: FusionMode,
    fuse_batch_dot: bool,
) -> Result<StitchedExecutable, String> {
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    let mut cfg = PipelineConfig::default();
    cfg.deep.fuse_batch_dot = fuse_batch_dot;
    let compiled = compile_module(module, mode, &mut lib, &cfg)
        .map_err(|e| format!("compile: {e:#}"))?;
    match compiled.executable {
        Some(exe) => Ok((*exe).clone()),
        None => Err(compiled.exec_error.unwrap_or_else(|| "did not lower".into())),
    }
}

struct ModelRow {
    name: String,
    per_op_kernels: usize,
    baseline: Option<LaunchLedger>,
    fs: Option<LaunchLedger>,
    error: Option<String>,
}

fn run_model(name: &str, module: &Module, fuse_batch_dot: bool, seed: u64) -> ModelRow {
    let per_op_kernels = module.entry.unfused_kernel_count();
    let inputs = inputs_for(module, seed);
    let mut row = ModelRow {
        name: name.to_string(),
        per_op_kernels,
        baseline: None,
        fs: None,
        error: None,
    };
    for (mode, slot) in [(FusionMode::XlaBaseline, 0usize), (FusionMode::FusionStitching, 1)] {
        let out = lower(module, mode, fuse_batch_dot)
            .and_then(|exe| exe.run(&inputs).map_err(|e| format!("run: {e:#}")));
        match out {
            Ok((_, ledger)) => {
                if slot == 0 {
                    row.baseline = Some(ledger);
                } else {
                    row.fs = Some(ledger);
                }
            }
            Err(e) => row.error = Some(format!("{mode:?}: {e}")),
        }
    }
    row
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok()
        || std::env::args().any(|a| a == "--smoke");
    let mode_name = if smoke { "smoke" } else { "full" };
    println!("== Launch reduction (executed): one launch per fused group ==");
    println!(
        "{:<8} {:>8} {:>10} {:>10} {:>8} {:>8} {:>8}",
        "model", "per-op", "baseline", "stitched", "gen", "lib", "ratio"
    );

    let wanted: &[&str] =
        if smoke { &["LR", "W2V", "Speech"] } else { &["LR", "W2V", "RNN", "BiRNN", "Speech", "NMT"] };
    let mut rows: Vec<ModelRow> = Vec::new();
    for (meta, module) in models::all_benchmarks() {
        if !wanted.contains(&meta.name) {
            continue;
        }
        let row = run_model(meta.name, &module, meta.fuse_batch_dot, 42);
        match (&row.baseline, &row.fs) {
            (Some(b), Some(f)) => {
                let ratio = f.total_launches() as f64 / b.total_launches().max(1) as f64;
                println!(
                    "{:<8} {:>8} {:>10} {:>10} {:>8} {:>8} {:>8.2}",
                    row.name,
                    row.per_op_kernels,
                    b.total_launches(),
                    f.total_launches(),
                    f.generated,
                    f.library,
                    ratio
                );
                assert!(
                    f.total_launches() <= b.total_launches(),
                    "{}: deep fusion must not launch more",
                    row.name
                );
                // Stitch-tier attribution must account for every
                // generated launch (plain + shm + global = generated).
                assert_eq!(
                    f.tier_plain + f.tier_shm + f.tier_global,
                    f.generated,
                    "{}: ledger tier attribution out of balance: {f}",
                    row.name
                );
            }
            _ => println!(
                "{:<8} — not executed: {}",
                row.name,
                row.error.as_deref().unwrap_or("unknown")
            ),
        }
        rows.push(row);
    }

    let ratios: Vec<f64> = rows
        .iter()
        .filter_map(|r| match (&r.baseline, &r.fs) {
            (Some(b), Some(f)) => {
                Some(f.total_launches() as f64 / b.total_launches().max(1) as f64)
            }
            _ => None,
        })
        .collect();
    let g = geomean(ratios.iter().copied());
    println!(
        "geomean stitched/baseline: {g:.3}  ({:.0}% launch reduction; paper Fig. 7: ~55%)",
        (1.0 - g) * 100.0
    );

    // ---- corpus section: deep fusion vs the true per-op baseline ----
    let corpus_cfg = CorpusConfig {
        seed: 946,
        models: if smoke { 8 } else { 24 },
        ops_per_model: (8, 24),
        max_width_log2: 6,
    };
    let mut per_op_total = 0u64;
    let mut fs_total = 0u64;
    let mut corpus_ratios: Vec<f64> = Vec::new();
    let mut corpus_graphs = 0usize;
    for (i, comp) in generate_models(&corpus_cfg).into_iter().enumerate() {
        let module = Module::new(comp.name.clone(), comp);
        let prog = match HloProgram::parse(&xla_text(&module)) {
            Ok(p) => p,
            Err(e) => {
                println!("corpus graph {i}: interpreter rejected: {e:#}");
                continue;
            }
        };
        let inputs = inputs_for(&module, 7000 + i as u64);
        if prog.execute(&inputs).is_err() {
            continue;
        }
        let per_op = prog.kernel_launches();
        let exe = match lower(&module, FusionMode::FusionStitching, false) {
            Ok(e) => e,
            Err(e) => {
                println!("corpus graph {i}: {e}");
                continue;
            }
        };
        let (_, ledger) = match exe.run(&inputs) {
            Ok(r) => r,
            Err(e) => {
                println!("corpus graph {i}: run failed: {e:#}");
                continue;
            }
        };
        per_op_total += per_op;
        fs_total += ledger.total_launches();
        corpus_ratios.push(ledger.total_launches() as f64 / per_op.max(1) as f64);
        corpus_graphs += 1;
    }
    let corpus_g = geomean(corpus_ratios.iter().copied());
    println!(
        "corpus ({corpus_graphs} graphs): per-op {per_op_total} launches -> stitched {fs_total} \
         (geomean ratio {corpus_g:.3})"
    );
    assert!(corpus_graphs > 0, "corpus section must execute");
    assert!(
        fs_total < per_op_total,
        "deep fusion must strictly reduce launches vs per-op: {fs_total} vs {per_op_total}"
    );

    // ---- persist ----
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"launch_reduction\",\n");
    json.push_str(&format!("  \"mode\": \"{mode_name}\",\n"));
    json.push_str("  \"models\": [\n");
    for (k, r) in rows.iter().enumerate() {
        let (bl, fs, gen, lib, ratio, executed) = match (&r.baseline, &r.fs) {
            (Some(b), Some(f)) => (
                b.total_launches(),
                f.total_launches(),
                f.generated,
                f.library,
                f.total_launches() as f64 / b.total_launches().max(1) as f64,
                true,
            ),
            _ => (0, 0, 0, 0, 0.0, false),
        };
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"per_op_kernels\": {}, \"baseline_launches\": {}, \
             \"fs_launches\": {}, \"generated\": {}, \"library\": {}, \"ratio\": {:.4}, \
             \"executed\": {}{}}}{}\n",
            r.name,
            r.per_op_kernels,
            bl,
            fs,
            gen,
            lib,
            ratio,
            executed,
            match &r.error {
                Some(e) => format!(", \"error\": \"{}\"", e.replace('"', "'").replace('\n', " ")),
                None => String::new(),
            },
            if k + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!("  \"geomean_ratio\": {g:.4},\n"));
    json.push_str(&format!("  \"reduction_pct\": {:.1},\n", (1.0 - g) * 100.0));
    json.push_str(&format!(
        "  \"corpus\": {{\"graphs\": {corpus_graphs}, \"per_op_launches\": {per_op_total}, \
         \"fs_launches\": {fs_total}, \"geomean_ratio\": {corpus_g:.4}}}\n"
    ));
    json.push_str("}\n");

    let out_path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => PathBuf::from(dir).join("..").join("BENCH_launch_reduction.json"),
        Err(_) => PathBuf::from("BENCH_launch_reduction.json"),
    };
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {}", out_path.display()),
        Err(e) => eprintln!("could not write {}: {e}", out_path.display()),
    }
}
