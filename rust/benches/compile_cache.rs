//! Compile-once serving bench: cold pipeline compile vs. compilation-
//! cache hit, per model (LR, RNN, NMT — the paper's serving-relevant
//! spread: small training graph, loopy training graph, the inference
//! workload).
//!
//! The acceptance bar for the cache: a hit (same module fingerprint +
//! fusion mode + device) must skip fusion/tuning/emission entirely and
//! come back ≥ 10× faster than the cold path.

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::time_it;
use fusion_stitching::coordinator::cache::CompileService;
use fusion_stitching::coordinator::pipeline::{FusionMode, PipelineConfig};
use fusion_stitching::models;
use std::time::Instant;

fn main() {
    println!("== compile cache: cold pipeline vs cache hit ==");
    println!(
        "{:<8} {:>7} {:>12} {:>12} {:>10}",
        "model", "ops", "cold", "cached", "speedup"
    );
    let mut worst_speedup = f64::INFINITY;
    for name in ["LR", "RNN", "NMT"] {
        let (meta, module) = models::by_name(name).unwrap();
        let mut cfg = PipelineConfig::default();
        cfg.deep.fuse_batch_dot = meta.fuse_batch_dot;
        let mut svc = CompileService::new(cfg);

        let t0 = Instant::now();
        let (_, hit) = svc.compile(&module, FusionMode::FusionStitching).unwrap();
        let cold = t0.elapsed();
        assert!(!hit, "first compile must be cold");

        let (_, cached_best) = time_it(3, 50, || {
            let (artifact, hit) = svc.compile(&module, FusionMode::FusionStitching).unwrap();
            assert!(hit, "repeat compile must hit the cache");
            artifact
        });

        let speedup = cold.as_secs_f64() / cached_best.as_secs_f64().max(1e-9);
        worst_speedup = worst_speedup.min(speedup);
        println!(
            "{:<8} {:>7} {:>10.2}ms {:>10.2}us {:>9.0}x",
            meta.name,
            module.entry.len(),
            cold.as_secs_f64() * 1e3,
            cached_best.as_secs_f64() * 1e6,
            speedup
        );
        let stats = svc.stats();
        assert_eq!(stats.misses, 1);
        assert!(stats.hits >= 50);
    }
    println!("worst-case speedup: {worst_speedup:.0}x (acceptance bar: >= 10x)");
    assert!(
        worst_speedup >= 10.0,
        "cached compile must be at least 10x faster than cold (got {worst_speedup:.1}x)"
    );
}
