//! VM wall-clock bench — the execute path itself, before vs after
//! memory planning.
//!
//! For every Table 2 benchmark the module is compiled once under
//! FusionStitching and executed two ways:
//!
//! - **boxed**: the PR-2 reference VM (`run_boxed`) — one `Vec<f32>`
//!   per value, tree-walking index arithmetic, single-threaded;
//! - **pooled**: the memory-planned VM (`run_into`) — flat arena with
//!   lifetime-disjoint reuse, compiled affine loads, block-parallel
//!   grid loops.
//!
//! Outputs must be bit-identical and the launch ledgers unchanged;
//! the headline gate is a geometric-mean wall-clock speedup across all
//! six models (>= 3x full, >= 2x smoke — CI pins `FUSION_VM_THREADS`
//! so the number is reproducible). Results are persisted to
//! `BENCH_vm_wallclock.json` at the repo root (uploaded as a CI
//! artifact by `make bench-vm`).

use fusion_stitching::coordinator::pipeline::{
    compile_module, geomean, FusionMode, PipelineConfig,
};
use fusion_stitching::exec::{ExecArena, StitchedExecutable};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::hlo::Module;
use fusion_stitching::models;
use fusion_stitching::schedule::PerfLibrary;
use std::path::PathBuf;
use std::time::Instant;

fn fill(n: usize, seed: u64) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let h = (i as u64).wrapping_mul(2654435761).wrapping_add(seed.wrapping_mul(97));
            ((h % 1000) as f32) / 1000.0 - 0.5
        })
        .collect()
}

fn inputs_for(module: &Module, seed: u64) -> Vec<Vec<f32>> {
    module
        .entry
        .parameters()
        .into_iter()
        .enumerate()
        .map(|(k, id)| {
            let elems = module.entry.get(id).shape.num_elements() as usize;
            fill(elems, seed + k as u64)
        })
        .collect()
}

fn lower(module: &Module, fuse_batch_dot: bool) -> StitchedExecutable {
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    let mut cfg = PipelineConfig::default();
    cfg.deep.fuse_batch_dot = fuse_batch_dot;
    let compiled = compile_module(module, FusionMode::FusionStitching, &mut lib, &cfg)
        .unwrap_or_else(|e| panic!("{}: compile failed: {e:#}", module.name));
    match compiled.executable {
        Some(exe) => (*exe).clone(),
        None => panic!("{}: did not lower: {:?}", module.name, compiled.exec_error),
    }
}

struct Row {
    name: String,
    boxed_us: f64,
    pooled_us: f64,
    speedup: f64,
    launches: u64,
    arena_bytes: usize,
    value_bytes: usize,
    reuse_ratio: f64,
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok()
        || std::env::args().any(|a| a == "--smoke");
    let mode_name = if smoke { "smoke" } else { "full" };
    let iters = if smoke { 2usize } else { 5 };
    let threads = fusion_stitching::exec::par::default_threads();
    println!("== VM wall-clock: boxed (PR-2) vs memory-planned/parallel ({threads} VM threads) ==");
    println!(
        "{:<8} {:>12} {:>12} {:>8} {:>9} {:>10} {:>7}",
        "model", "boxed_us", "pooled_us", "speedup", "launches", "arena_KiB", "reuse"
    );

    let mut rows: Vec<Row> = Vec::new();
    for (meta, module) in models::all_benchmarks() {
        let exe = lower(&module, meta.fuse_batch_dot);
        let inputs = inputs_for(&module, 42);
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();

        // Warmup both sides; the warmup runs double as the bit-identity
        // and ledger-equality check.
        let (boxed_out, boxed_ledger) = exe
            .run_boxed(&inputs)
            .unwrap_or_else(|e| panic!("{}: boxed run failed: {e:#}", meta.name));
        let mut arena = ExecArena::default();
        let mut pooled_out = Vec::new();
        let pooled_ledger = exe
            .run_into(&refs, &mut arena, &mut pooled_out)
            .unwrap_or_else(|e| panic!("{}: pooled run failed: {e:#}", meta.name));
        assert_eq!(
            pooled_ledger, boxed_ledger,
            "{}: the launch ledger must be unchanged",
            meta.name
        );
        assert_eq!(pooled_out.len(), boxed_out.len(), "{}: output size changed", meta.name);
        for (i, (a, b)) in pooled_out.iter().zip(&boxed_out).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "{}: element {i} differs: {a} vs {b}",
                meta.name
            );
        }

        // Best-of-N timing for each side (min is the stablest estimator
        // for cold-cache-free wall clock).
        let mut boxed_us = f64::INFINITY;
        for _ in 0..iters {
            let t0 = Instant::now();
            let _ = exe.run_boxed(&inputs).unwrap();
            boxed_us = boxed_us.min(t0.elapsed().as_secs_f64() * 1e6);
        }
        let mut pooled_us = f64::INFINITY;
        for _ in 0..iters {
            let t0 = Instant::now();
            let _ = exe.run_into(&refs, &mut arena, &mut pooled_out).unwrap();
            pooled_us = pooled_us.min(t0.elapsed().as_secs_f64() * 1e6);
        }
        // Steady state really was allocation-free.
        assert_eq!(arena.grows(), 1, "{}: pooled arena must not grow after warmup", meta.name);

        let stats = exe.mem.stats();
        let speedup = boxed_us / pooled_us.max(1e-9);
        println!(
            "{:<8} {:>12.0} {:>12.0} {:>7.2}x {:>9} {:>10.1} {:>6.2}x",
            meta.name,
            boxed_us,
            pooled_us,
            speedup,
            pooled_ledger.total_launches(),
            stats.arena_bytes as f64 / 1024.0,
            stats.reuse_ratio()
        );
        rows.push(Row {
            name: meta.name.to_string(),
            boxed_us,
            pooled_us,
            speedup,
            launches: pooled_ledger.total_launches(),
            arena_bytes: stats.arena_bytes,
            value_bytes: stats.value_bytes,
            reuse_ratio: stats.reuse_ratio(),
        });
    }

    let g = geomean(rows.iter().map(|r| r.speedup));
    println!("geomean speedup: {g:.2}x over the boxed PR-2 VM ({mode_name} mode)");

    // ---- persist ----
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"vm_wallclock\",\n");
    json.push_str(&format!("  \"mode\": \"{mode_name}\",\n"));
    json.push_str(&format!("  \"vm_threads\": {threads},\n"));
    json.push_str("  \"models\": [\n");
    for (k, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"boxed_us\": {:.1}, \"pooled_us\": {:.1}, \
             \"speedup\": {:.3}, \"launches\": {}, \"arena_bytes\": {}, \
             \"value_bytes\": {}, \"reuse_ratio\": {:.3}, \"bit_identical\": true}}{}\n",
            r.name,
            r.boxed_us,
            r.pooled_us,
            r.speedup,
            r.launches,
            r.arena_bytes,
            r.value_bytes,
            r.reuse_ratio,
            if k + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!("  \"geomean_speedup\": {g:.3}\n"));
    json.push_str("}\n");

    let out_path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => PathBuf::from(dir).join("..").join("BENCH_vm_wallclock.json"),
        Err(_) => PathBuf::from("BENCH_vm_wallclock.json"),
    };
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {}", out_path.display()),
        Err(e) => eprintln!("could not write {}: {e}", out_path.display()),
    }

    // The acceptance gate: the memory-planned VM must be decisively
    // faster across the whole model suite. Smoke mode (CI runners,
    // pinned low thread count) gates a lower bar.
    let bar = if smoke { 2.0 } else { 3.0 };
    assert!(
        g >= bar,
        "geomean wall-clock speedup {g:.2}x is below the {bar}x bar ({mode_name} mode)"
    );
}
