//! Global-memory stitching bench: launches saved by the third tier.
//!
//! The overflow corpus (interior reduce chunks provably over the
//! shared-memory budget) is compiled with the global tier on and off,
//! executed on the stitched VM, and the `LaunchLedger`s compared: the
//! stitched plan must pay strictly fewer launches, attribute them to
//! `tier_global`, and produce bit-identical outputs. A second section
//! records the static launch plans of the Table 2 benchmarks under both
//! settings. Results are persisted to `BENCH_global_stitch.json` at the
//! repo root (`make bench-global`).
//!
//! Smoke mode (`BENCH_SMOKE=1`) is accepted for CI symmetry with the
//! other benches; the overflow corpus is small enough to always run in
//! full.

use fusion_stitching::coordinator::pipeline::{
    compile_module, geomean, FusionMode, PipelineConfig,
};
use fusion_stitching::corpus::generator::generate_overflow_models;
use fusion_stitching::exec::StitchedExecutable;
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::hlo::Module;
use fusion_stitching::models;
use fusion_stitching::schedule::PerfLibrary;
use std::path::PathBuf;

fn fill(n: usize, seed: u64) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let h = (i as u64).wrapping_mul(2654435761).wrapping_add(seed.wrapping_mul(97));
            ((h % 1000) as f32) / 1000.0 - 0.5
        })
        .collect()
}

fn inputs_for(module: &Module, seed: u64) -> Vec<Vec<f32>> {
    module
        .entry
        .parameters()
        .into_iter()
        .enumerate()
        .map(|(k, id)| {
            let elems = module.entry.get(id).shape.num_elements() as usize;
            fill(elems, seed + k as u64)
        })
        .collect()
}

fn lower_gs(
    module: &Module,
    fuse_batch_dot: bool,
    global_stitch: bool,
) -> Result<StitchedExecutable, String> {
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    let mut cfg = PipelineConfig::default();
    cfg.deep.fuse_batch_dot = fuse_batch_dot;
    cfg.deep.global_stitch = global_stitch;
    let compiled = compile_module(module, FusionMode::FusionStitching, &mut lib, &cfg)
        .map_err(|e| format!("compile: {e:#}"))?;
    match compiled.executable {
        Some(exe) => Ok((*exe).clone()),
        None => Err(compiled.exec_error.unwrap_or_else(|| "did not lower".into())),
    }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok()
        || std::env::args().any(|a| a == "--smoke");
    let mode_name = if smoke { "smoke" } else { "full" };

    println!("== Global-memory stitching: launches saved by the third tier ==");
    println!(
        "{:<12} {:>8} {:>10} {:>8} {:>8} {:>8} {:>8}",
        "model", "split", "stitched", "shm", "global", "fences", "ratio"
    );

    // ---- overflow corpus: executed, ledger-verified ----
    struct Row {
        name: String,
        split: u64,
        stitched: u64,
        tier_shm: u64,
        tier_global: u64,
        fences: u64,
    }
    let mut rows: Vec<Row> = Vec::new();
    for (i, comp) in generate_overflow_models().into_iter().enumerate() {
        let module = Module::new(comp.name.clone(), comp);
        let inputs = inputs_for(&module, 42 + i as u64);
        let stitched = lower_gs(&module, false, true)
            .unwrap_or_else(|e| panic!("{}: {e}", module.name));
        let split = lower_gs(&module, false, false)
            .unwrap_or_else(|e| panic!("{}: {e}", module.name));
        let (s_out, s_ledger) = stitched
            .run(&inputs)
            .unwrap_or_else(|e| panic!("{}: stitched run: {e:#}", module.name));
        let (p_out, p_ledger) = split
            .run(&inputs)
            .unwrap_or_else(|e| panic!("{}: split run: {e:#}", module.name));

        // The gates the bench exists to hold.
        assert_eq!(s_out.len(), p_out.len(), "{}: output size", module.name);
        for (k, (a, b)) in s_out.iter().zip(&p_out).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "{}: element {k} differs: {a} vs {b}",
                module.name
            );
        }
        assert!(
            s_ledger.tier_global > 0,
            "{}: global tier must fire, ledger: {s_ledger}",
            module.name
        );
        assert!(s_ledger.fences > 0, "{}: fences must execute", module.name);
        assert!(
            s_ledger.total_launches() < p_ledger.total_launches(),
            "{}: global stitching must strictly reduce launches: {} vs {}",
            module.name,
            s_ledger.total_launches(),
            p_ledger.total_launches()
        );

        let ratio = s_ledger.total_launches() as f64 / p_ledger.total_launches().max(1) as f64;
        println!(
            "{:<12} {:>8} {:>10} {:>8} {:>8} {:>8} {:>8.2}",
            module.name,
            p_ledger.total_launches(),
            s_ledger.total_launches(),
            s_ledger.tier_shm,
            s_ledger.tier_global,
            s_ledger.fences,
            ratio
        );
        rows.push(Row {
            name: module.name.clone(),
            split: p_ledger.total_launches(),
            stitched: s_ledger.total_launches(),
            tier_shm: s_ledger.tier_shm,
            tier_global: s_ledger.tier_global,
            fences: s_ledger.fences,
        });
    }
    let g = geomean(
        rows.iter().map(|r| r.stitched as f64 / (r.split.max(1)) as f64),
    );
    println!(
        "geomean stitched/split: {g:.3}  ({:.0}% launch reduction on the overflow corpus)",
        (1.0 - g) * 100.0
    );

    // ---- Table 2 benchmarks: static plans under both settings ----
    struct Plan {
        name: String,
        split: u64,
        stitched: u64,
    }
    let mut plans: Vec<Plan> = Vec::new();
    for (meta, module) in models::all_benchmarks() {
        let stitched = lower_gs(&module, meta.fuse_batch_dot, true)
            .unwrap_or_else(|e| panic!("{}: {e}", meta.name));
        let split = lower_gs(&module, meta.fuse_batch_dot, false)
            .unwrap_or_else(|e| panic!("{}: {e}", meta.name));
        let s = stitched.generated_launches() + stitched.library_launches();
        let p = split.generated_launches() + split.library_launches();
        assert!(s <= p, "{}: stitched plans more launches ({s} vs {p})", meta.name);
        println!("{:<12} planned: split {p}, stitched {s}", meta.name);
        plans.push(Plan { name: meta.name.to_string(), split: p, stitched: s });
    }

    // ---- persist ----
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"global_stitch\",\n");
    json.push_str(&format!("  \"mode\": \"{mode_name}\",\n"));
    json.push_str("  \"overflow\": [\n");
    for (k, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"split_launches\": {}, \"stitched_launches\": {}, \
             \"tier_shm\": {}, \"tier_global\": {}, \"fences\": {}, \"ratio\": {:.4}}}{}\n",
            r.name,
            r.split,
            r.stitched,
            r.tier_shm,
            r.tier_global,
            r.fences,
            r.stitched as f64 / r.split.max(1) as f64,
            if k + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!("  \"geomean_ratio\": {g:.4},\n"));
    json.push_str(&format!("  \"reduction_pct\": {:.1},\n", (1.0 - g) * 100.0));
    json.push_str("  \"models\": [\n");
    for (k, p) in plans.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"split_planned\": {}, \"stitched_planned\": {}}}{}\n",
            p.name,
            p.split,
            p.stitched,
            if k + 1 < plans.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n");
    json.push_str("}\n");

    let out_path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => PathBuf::from(dir).join("..").join("BENCH_global_stitch.json"),
        Err(_) => PathBuf::from("BENCH_global_stitch.json"),
    };
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {}", out_path.display()),
        Err(e) => eprintln!("could not write {}: {e}", out_path.display()),
    }
}
