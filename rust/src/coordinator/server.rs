//! The online serving coordinator — the paper's latency-critical NMT use
//! case (§6.1: "batch size is small, and latency is critical … every
//! millisecond of performance improvement is of significance").
//!
//! A worker thread owns the PJRT executable; callers submit flattened
//! request rows and receive their slice of the batched output. Padding
//! fills partial batches (the artifact's batch dimension is baked in at
//! AOT time).

use super::batcher::{next_batch, BatchPolicy, Request};
use crate::runtime::Engine;
use anyhow::{anyhow, Context, Result};
use std::path::Path;
use std::sync::mpsc::{self, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server configuration: which artifact to serve and its baked shapes.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Artifact stem under `artifacts/` (e.g. "attention_fused").
    pub artifact: String,
    /// Baked batch size of the artifact (requests per execution).
    pub batch: usize,
    /// Flattened f32 elements per request in the input.
    pub in_elems_per_request: usize,
    /// Flattened f32 elements per request in the (first) output.
    pub out_elems_per_request: usize,
    /// Input dims of the whole batch (product = batch × in_elems).
    pub input_dims: Vec<i64>,
    pub policy: BatchPolicy,
}

/// Handle to the serving loop.
pub struct ServingCoordinator {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<WorkerStats>>,
    cfg: ServerConfig,
}

/// Worker-side counters.
#[derive(Debug, Default, Clone)]
pub struct WorkerStats {
    pub batches: usize,
    pub requests: usize,
    /// Execution time spent inside PJRT, per batch, microseconds.
    pub exec_us: Vec<f64>,
}

impl ServingCoordinator {
    /// Start the loop: spawns the worker, which owns the PJRT client and
    /// executable (the xla wrappers are not `Send`, so everything PJRT
    /// lives on the worker thread) and signals readiness back.
    pub fn start(artifact_dir: &Path, cfg: ServerConfig) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let wcfg = cfg.clone();
        let dir = artifact_dir.to_path_buf();
        let worker = std::thread::spawn(move || {
            let mut stats = WorkerStats::default();
            let engine = match Engine::new(&dir).and_then(|mut e| {
                e.load(&wcfg.artifact)?;
                Ok(e)
            }) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return stats;
                }
            };
            let model = engine.get(&wcfg.artifact).expect("loaded above");
            let batch_elems = wcfg.batch * wcfg.in_elems_per_request;
            while let Some(batch) = next_batch(&rx, &wcfg.policy) {
                // Assemble the padded batch input.
                let mut input = vec![0f32; batch_elems];
                for (i, req) in batch.iter().enumerate() {
                    let start = i * wcfg.in_elems_per_request;
                    let row = &req.input;
                    input[start..start + row.len().min(wcfg.in_elems_per_request)]
                        .copy_from_slice(&row[..row.len().min(wcfg.in_elems_per_request)]);
                }
                let t0 = Instant::now();
                let result = model.run_f32(&[(&input, &wcfg.input_dims)]);
                stats.exec_us.push(t0.elapsed().as_secs_f64() * 1e6);
                stats.batches += 1;
                stats.requests += batch.len();
                match result {
                    Ok(outputs) => {
                        let out = &outputs[0];
                        for (i, req) in batch.iter().enumerate() {
                            let start = i * wcfg.out_elems_per_request;
                            let end = start + wcfg.out_elems_per_request;
                            let slice = out
                                .get(start..end)
                                .map(<[f32]>::to_vec)
                                .ok_or_else(|| anyhow!("output shorter than expected"));
                            let _ = req.respond.send(slice);
                        }
                    }
                    Err(e) => {
                        for req in &batch {
                            let _ = req.respond.send(Err(anyhow!("execution failed: {e:#}")));
                        }
                    }
                }
            }
            stats
        });
        // Fail fast if the artifact is missing/bad.
        ready_rx
            .recv()
            .map_err(|_| anyhow!("worker died during startup"))
            .and_then(|r| r)
            .inspect_err(|_| {
                let _ = worker.thread();
            })?;
        Ok(ServingCoordinator { tx: Some(tx), worker: Some(worker), cfg })
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Submit one request and block for its output. Returns the output
    /// slice and the end-to-end latency.
    pub fn infer(&self, input: Vec<f32>) -> Result<(Vec<f32>, Duration)> {
        let (rtx, rrx) = mpsc::channel();
        let enqueued = Instant::now();
        self.tx
            .as_ref()
            .context("server stopped")?
            .send(Request { input, respond: rtx, enqueued })
            .map_err(|_| anyhow!("worker gone"))?;
        let out = rrx.recv().context("worker dropped response")??;
        Ok((out, enqueued.elapsed()))
    }

    /// Submit asynchronously; the caller holds the response channel.
    pub fn infer_async(
        &self,
        input: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .as_ref()
            .context("server stopped")?
            .send(Request { input, respond: rtx, enqueued: Instant::now() })
            .map_err(|_| anyhow!("worker gone"))?;
        Ok(rrx)
    }

    /// Stop accepting requests, drain, and return worker statistics.
    pub fn shutdown(mut self) -> Result<WorkerStats> {
        drop(self.tx.take());
        self.worker
            .take()
            .context("already shut down")?
            .join()
            .map_err(|_| anyhow!("worker panicked"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    /// Identity-ish artifact: doubles a [4, 3] batch (batch=4 requests of
    /// 3 elements each).
    const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[4,3]{1,0})->(f32[4,3]{1,0})}

ENTRY main {
  p0 = f32[4,3]{1,0} parameter(0)
  sum = f32[4,3]{1,0} add(p0, p0)
  ROOT t = (f32[4,3]{1,0}) tuple(sum)
}
"#;

    fn server(dir: &TempDir) -> ServingCoordinator {
        std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
        ServingCoordinator::start(
            dir.path(),
            ServerConfig {
                artifact: "double".into(),
                batch: 4,
                in_elems_per_request: 3,
                out_elems_per_request: 3,
                input_dims: vec![4, 3],
                policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
            },
        )
        .unwrap()
    }

    #[test]
    fn single_request_roundtrip() {
        let dir = TempDir::new("srv");
        let srv = server(&dir);
        let (out, lat) = srv.infer(vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(out, vec![2.0, 4.0, 6.0]);
        assert!(lat > Duration::ZERO);
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn concurrent_requests_share_batches() {
        let dir = TempDir::new("srv2");
        let srv = server(&dir);
        let pending: Vec<_> = (0..8)
            .map(|i| srv.infer_async(vec![i as f32, 0.0, 1.0]).unwrap())
            .collect();
        for (i, rx) in pending.into_iter().enumerate() {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out, vec![2.0 * i as f32, 0.0, 2.0]);
        }
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.requests, 8);
        // batching actually happened: fewer executions than requests
        assert!(stats.batches < 8, "batches = {}", stats.batches);
    }

    #[test]
    fn shutdown_drains() {
        let dir = TempDir::new("srv3");
        let srv = server(&dir);
        let rx = srv.infer_async(vec![5.0, 5.0, 5.0]).unwrap();
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.requests, 1);
        assert_eq!(rx.recv().unwrap().unwrap(), vec![10.0, 10.0, 10.0]);
    }
}
