//! The online serving coordinator — the paper's latency-critical NMT use
//! case (§6.1: "batch size is small, and latency is critical … every
//! millisecond of performance improvement is of significance").
//!
//! A worker thread owns the runtime executable; callers submit flattened
//! request rows and receive their slice of the batched output. Padding
//! fills partial batches (the artifact's batch dimension is baked in at
//! AOT time).
//!
//! **Compile-once serving:** when [`ServerConfig::compile`] is set, the
//! worker routes every batch through a shared
//! [`CompileService`] before executing it: the first batch
//! pays the full fusion → tuning → codegen pipeline for the module, and
//! every later batch with the same structural fingerprint is answered
//! from the [`super::cache::CompileCache`]. [`WorkerStats`] reports the
//! resulting hit/miss counts and per-batch compile latencies, so the
//! serving loop's cache hit-rate is directly observable.
//!
//! **Shape-class bucketing:** with [`ServerConfig::buckets`] set, shape
//! identity is a [`ShapeClass`] rather than an exact length — batches
//! are bucket-pure, rows are padded to the bucket's canonical length on
//! assembly and the live output region is sliced back per request, and
//! (with [`CompileOptions::specialize`]) each bucket compiles one
//! canonical artifact shared by every length in the bucket. `None`
//! keeps the historical exact-shape semantics bit-for-bit.

use super::batcher::{
    next_batch_admitted, BatchOutcome, BatchPolicy, Rejection, Request, SlackCheck,
};
use super::buckets::{BucketAdmission, BucketPolicy, ShapeClass};
use super::cache::{CompileService, SharedCompileService};
use super::faults::FaultPlan;
use super::metrics::StreamingSummary;
use super::pipeline::{CompiledModule, FusionMode, PipelineConfig};
use crate::exec::{ArenaStats, ExecArena, LaunchLedger, StitchedExecutable};
use crate::hlo::Module;
use crate::runtime::{Engine, LoadedModel};
use anyhow::{anyhow, bail, Context, Error, Result};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// What the serving loop compiles (once) per configured module.
#[derive(Debug, Clone)]
pub struct CompileOptions {
    /// The HLO module behind the served artifact (e.g. the NMT graph).
    pub module: Module,
    pub mode: FusionMode,
    /// Pipeline knobs for the compile service.
    ///
    /// Used only when [`ServingCoordinator::start`] creates the loop's
    /// own service. With
    /// [`ServingCoordinator::start_with_service`], the *shared
    /// service's* config governs every compile (a shared cache must be
    /// keyed against one config) and this field is ignored.
    pub pipeline: PipelineConfig,
    /// Execute batches on the compiled module's stitched-VM executable
    /// (one launch per fused group) instead of the text artifact's
    /// op-by-op interpreter. Requires the module's entry signature to
    /// match the serving contract: exactly one parameter of
    /// `batch × in_elems_per_request` elements, and a root of
    /// `batch × out_elems_per_request` elements — validated when the
    /// first batch compiles.
    pub use_stitched_backend: bool,
    /// Builds the served module at an arbitrary per-request row length
    /// (the batch dimension stays the contract's `batch`). Required for
    /// per-bucket artifacts under [`ServerConfig::buckets`]: each
    /// bucket compiles `specialize(canonical_len)` once and serves
    /// every length in the bucket from it. Must satisfy
    /// `specialize(in_elems_per_request) == module` structurally. A
    /// plain `fn` pointer (not a closure) so the options stay
    /// `Debug + Clone`.
    pub specialize: Option<fn(usize) -> Module>,
}

/// Deadline handling for the serving loop. Installing a policy turns
/// on slack admission: the batcher predicts whether a deadline-carrying
/// request can still be answered in time (queue wait so far + predicted
/// kernel service time + assembly overhead vs. its deadline) and
/// **sheds** hopeless requests with an immediate structured
/// [`Rejection::DeadlineInfeasible`] reply instead of letting them time
/// out silently. The service-time estimate prefers, in order: the
/// worker's measured per-batch execution p95, the cost oracle's modeled
/// module time (once a compile resolved), and `bootstrap_service_us`.
#[derive(Debug, Clone)]
pub struct DeadlinePolicy {
    /// Deadline stamped onto requests whose callers did not set one
    /// (`None`: such requests are never shed).
    pub default_deadline: Option<Duration>,
    /// Service-time estimate before any measurement or compile exists,
    /// microseconds.
    pub bootstrap_service_us: f64,
    /// Budgeted batch assembly + reply overhead, microseconds.
    pub assembly_overhead_us: f64,
}

impl Default for DeadlinePolicy {
    fn default() -> Self {
        DeadlinePolicy {
            default_deadline: None,
            bootstrap_service_us: 200.0,
            assembly_overhead_us: 50.0,
        }
    }
}

/// Server configuration: which artifact to serve and its baked shapes.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Artifact stem under `artifacts/` (e.g. "attention_fused").
    pub artifact: String,
    /// Baked batch size of the artifact (requests per execution).
    pub batch: usize,
    /// Flattened f32 elements per request in the input.
    pub in_elems_per_request: usize,
    /// Flattened f32 elements per request in the (first) output.
    pub out_elems_per_request: usize,
    /// Input dims of the whole batch (product = batch × in_elems).
    pub input_dims: Vec<i64>,
    pub policy: BatchPolicy,
    /// Compile-once serving: route each batch through the compilation
    /// cache for this module. `None` serves the artifact without
    /// touching the compiler.
    pub compile: Option<CompileOptions>,
    /// Flight recorder for this loop: when set, every worker installs
    /// the sink and records queue/batch/compile/launch/reply spans
    /// (see [`crate::obs`]). `None` serves untraced at zero cost.
    pub trace: Option<Arc<crate::obs::TraceSink>>,
    /// Shape-class bucketing policy. `Some(policy)`: shape keys are
    /// bucket keys, batches are bucket-pure, rows pad to the bucket's
    /// canonical length and rows longer than their claimed bucket's
    /// canonical length are rejected. `None`: historical opaque-key
    /// semantics — keys are whatever the caller submits, batches are
    /// key-pure, rows validate against `in_elems_per_request` — kept
    /// bit-for-bit for existing deployments.
    pub buckets: Option<BucketPolicy>,
    /// Deadline/slack-admission policy. `None` (the default) keeps the
    /// historical no-deadline semantics: nothing is ever shed.
    pub deadline: Option<DeadlinePolicy>,
    /// Fault-injection plan for tests/benches (see
    /// [`crate::coordinator::faults`]). Inert unless the non-default
    /// `faults` cargo feature is enabled; `None` in production.
    pub faults: Option<Arc<FaultPlan>>,
}

impl ServerConfig {
    /// Reject degenerate configurations before a worker thread ever
    /// spawns. Notably `policy.max_batch` *may* exceed `batch`: the
    /// worker splits an oversized collected batch into artifact-sized
    /// chunks instead of panicking on batch assembly (the defaults used
    /// to disagree — `BatchPolicy::max_batch = 8` vs test configs'
    /// `batch = 4` — and the old assembly sliced out of range).
    pub fn validate(&self) -> Result<()> {
        if self.batch == 0 {
            bail!("batch must be >= 1");
        }
        if self.in_elems_per_request == 0 || self.out_elems_per_request == 0 {
            bail!("per-request element counts must be >= 1");
        }
        if self.policy.max_batch == 0 {
            bail!("policy.max_batch must be >= 1");
        }
        if self.policy.max_wait.is_zero() {
            bail!("policy.max_wait must be non-zero");
        }
        let dims_product: i64 = self.input_dims.iter().product();
        let expect = (self.batch * self.in_elems_per_request) as i64;
        if dims_product != expect {
            bail!(
                "input_dims {:?} (product {dims_product}) disagree with \
                 batch {} x in_elems_per_request {} = {expect}",
                self.input_dims,
                self.batch,
                self.in_elems_per_request
            );
        }
        if let Some(policy) = &self.buckets {
            policy.validate()?;
            if let Some(opts) = &self.compile {
                // The bucket policy is part of the compile-cache
                // identity: a worker bucketing one way against a
                // service digesting another would share artifacts
                // across incompatible canonical shapes.
                if opts.pipeline.bucketing != *policy {
                    bail!(
                        "ServerConfig.buckets ({policy:?}) disagrees with \
                         CompileOptions.pipeline.bucketing ({:?}); the bucket \
                         policy must be folded into the compile config digest",
                        opts.pipeline.bucketing
                    );
                }
            }
        }
        Ok(())
    }

    /// The shape key a request of `len` input elements carries: its
    /// bucket key under [`ServerConfig::buckets`], or (historical
    /// semantics) the exact length.
    pub fn shape_key_for(&self, len: usize) -> u64 {
        match &self.buckets {
            Some(policy) => policy.bucket_key(len),
            None => len as u64,
        }
    }

    /// Output elements owed to a request of `in_len` input elements.
    /// The serving contract is proportional: a row carrying a fraction
    /// of `in_elems_per_request` owes the same fraction of
    /// `out_elems_per_request` (exactly the whole output when the
    /// contract is elementwise, `in == out`). Callers only pass
    /// `in_len` values the row validation already admitted.
    pub fn out_elems_for(&self, in_len: usize) -> usize {
        if self.in_elems_per_request == self.out_elems_per_request {
            in_len
        } else {
            (in_len * self.out_elems_per_request) / self.in_elems_per_request
        }
    }
}

/// Per-reason rejection counters, mirroring [`Rejection`]'s variants.
/// `oversized + bucket_mismatch + deadline + shed + compile_failed`
/// always equals [`WorkerStats::rejected`] for a single worker (and the
/// pool-merged aggregate).
#[derive(Debug, Default, Clone)]
pub struct RejectCounts {
    /// Rows longer than the unbucketed serving contract.
    pub oversized: u64,
    /// Rows that exceed their claimed bucket's canonical length.
    pub bucket_mismatch: u64,
    /// Requests shed by slack admission ([`Rejection::DeadlineInfeasible`]).
    pub deadline: u64,
    /// Requests shed by overload/teardown ([`Rejection::Shed`]).
    pub shed: u64,
    /// Requests answered with a compile fast-fail
    /// ([`Rejection::CompileFailed`]).
    pub compile_failed: u64,
}

impl RejectCounts {
    /// Sum over every reason.
    pub fn total(&self) -> u64 {
        self.oversized + self.bucket_mismatch + self.deadline + self.shed + self.compile_failed
    }

    /// Fold another worker's counts into this one.
    pub fn merge(&mut self, other: &RejectCounts) {
        self.oversized += other.oversized;
        self.bucket_mismatch += other.bucket_mismatch;
        self.deadline += other.deadline;
        self.shed += other.shed;
        self.compile_failed += other.compile_failed;
    }
}

/// Handle to the serving loop.
pub struct ServingCoordinator {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<WorkerStats>>,
    cfg: ServerConfig,
    service: Option<Arc<Mutex<CompileService>>>,
}

/// Worker-side counters. Latency series are bounded
/// [`StreamingSummary`]s, so a long-lived server's stats stay O(1) in
/// memory no matter how many batches it serves.
#[derive(Debug, Default, Clone)]
pub struct WorkerStats {
    pub batches: usize,
    pub requests: usize,
    /// Requests rejected before execution (e.g. rows longer than the
    /// serving contract's `in_elems_per_request`).
    pub rejected: usize,
    /// [`WorkerStats::rejected`] broken down by [`Rejection`] reason.
    pub rejects: RejectCounts,
    /// Requests that were *served* but replied after their deadline had
    /// already passed (slack admission mispredicted). Shed requests are
    /// counted under `rejects.deadline`, not here.
    pub deadline_misses: u64,
    /// Signed per-request slack at reply time, microseconds (positive:
    /// replied early; negative: a deadline miss). Only deadline-carrying
    /// requests record here.
    pub slack_us: StreamingSummary,
    /// Execution time spent inside the runtime, per batch, microseconds.
    pub exec_us: StreamingSummary,
    /// Compilation-cache hits observed on the serving path.
    pub cache_hits: usize,
    /// Compilation-cache misses (cold compiles) on the serving path.
    pub cache_misses: usize,
    /// Time spent obtaining the compiled plan, per batch, microseconds
    /// (cache hits make this collapse after the first batch).
    pub compile_us: StreamingSummary,
    /// Serving-path compiles that errored. After the first failure the
    /// worker stops retrying (a failing module would otherwise re-run
    /// the whole cold pipeline on every batch).
    pub compile_failures: usize,
    /// Kernel launches executed on the serving path (generated vs
    /// library), accumulated over every batch — the Fig. 7 counts as
    /// the serving loop actually paid them.
    pub launches: LaunchLedger,
    /// Batches executed on the stitched-VM backend (vs the op-by-op
    /// artifact interpreter).
    pub stitched_batches: usize,
    /// Stitched batches served from the pooled arena without any arena
    /// allocation — the steady-state zero-allocation gate. After the
    /// pooled arena reaches its plan's high-water mark (the first
    /// batch), every subsequent batch increments this.
    pub arena_reuses: u64,
    /// The served executable's memory-plan compression (arena bytes
    /// planned vs. the boxed VM's per-value footprint), set once the
    /// stitched backend resolves.
    pub arena: Option<ArenaStats>,
    /// Zero elements written into occupied batch rows to pad them up to
    /// their bucket's canonical length (batch *under-fill* — empty rows
    /// when fewer requests than `batch` arrive — is deliberately not
    /// counted here; it predates bucketing and is visible as
    /// `requests/batches`).
    pub padded_elems: u64,
    /// Request-supplied (live) elements assembled into batches — the
    /// denominator's other half for [`WorkerStats::padding_waste_ratio`].
    pub live_elems: u64,
    /// Request queue wait (enqueue → batch drain), per request,
    /// microseconds.
    pub queue_us: StreamingSummary,
    /// The served module's per-fused-group kernel profile, shared with
    /// the compiled artifact (set once the first compile resolves).
    /// Workers serving the same module share one profile, so `merge`
    /// keeps the first handle rather than double-counting.
    pub profile: Option<crate::obs::KernelProfileHandle>,
}

impl WorkerStats {
    /// Cache hit-rate over the serving run.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Fraction of assembled row elements that were padding, in
    /// `[0, 1)`: `padded / (padded + live)`. Zero under exact-shape
    /// serving (nothing pads) and when nothing was served.
    pub fn padding_waste_ratio(&self) -> f64 {
        let total = self.padded_elems + self.live_elems;
        if total == 0 {
            0.0
        } else {
            self.padded_elems as f64 / total as f64
        }
    }

    /// Fold another worker's counters into this one (the pool's
    /// aggregate view).
    pub fn merge(&mut self, other: &WorkerStats) {
        self.batches += other.batches;
        self.requests += other.requests;
        self.rejected += other.rejected;
        self.rejects.merge(&other.rejects);
        self.deadline_misses += other.deadline_misses;
        self.slack_us.merge(&other.slack_us);
        self.exec_us.merge(&other.exec_us);
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.compile_us.merge(&other.compile_us);
        self.compile_failures += other.compile_failures;
        self.launches.merge(&other.launches);
        self.stitched_batches += other.stitched_batches;
        self.arena_reuses += other.arena_reuses;
        self.padded_elems += other.padded_elems;
        self.live_elems += other.live_elems;
        if self.arena.is_none() {
            self.arena = other.arena;
        }
        self.queue_us.merge(&other.queue_us);
        if self.profile.is_none() {
            self.profile = other.profile.clone();
        }
    }

    /// Serialize with the shared JSON writer ([`crate::obs::Json`]) —
    /// the one stable stats form the Prometheus exporter, the benches
    /// and `serve` printing all read.
    pub fn write_json(&self, j: &mut crate::obs::Json) {
        j.begin_obj();
        j.field_uint("batches", self.batches as u64);
        j.field_uint("requests", self.requests as u64);
        j.field_uint("rejected", self.rejected as u64);
        j.key("rejects").begin_obj();
        j.field_uint("oversized", self.rejects.oversized);
        j.field_uint("bucket_mismatch", self.rejects.bucket_mismatch);
        j.field_uint("deadline", self.rejects.deadline);
        j.field_uint("shed", self.rejects.shed);
        j.field_uint("compile_failed", self.rejects.compile_failed);
        j.end_obj();
        j.field_uint("deadline_misses", self.deadline_misses);
        j.field_uint("cache_hits", self.cache_hits as u64);
        j.field_uint("cache_misses", self.cache_misses as u64);
        j.field_uint("compile_failures", self.compile_failures as u64);
        j.field_uint("stitched_batches", self.stitched_batches as u64);
        j.field_uint("arena_reuses", self.arena_reuses);
        j.field_uint("padded_elems", self.padded_elems);
        j.field_uint("live_elems", self.live_elems);
        j.field_num("padding_waste_ratio", self.padding_waste_ratio());
        if let Some(arena) = &self.arena {
            j.key("arena").begin_obj();
            j.field_uint("arena_bytes", arena.arena_bytes as u64);
            j.field_uint("value_bytes", arena.value_bytes as u64);
            j.field_num("reuse_ratio", arena.reuse_ratio());
            j.end_obj();
        }
        j.key("launches").begin_obj();
        j.field_uint("generated", self.launches.generated);
        j.field_uint("library", self.launches.library);
        j.field_uint("barriers", self.launches.barriers);
        j.field_uint("fences", self.launches.fences);
        j.field_uint("tier_plain", self.launches.tier_plain);
        j.field_uint("tier_shm", self.launches.tier_shm);
        j.field_uint("tier_global", self.launches.tier_global);
        j.end_obj();
        for (name, s) in [
            ("exec_us", &self.exec_us),
            ("compile_us", &self.compile_us),
            ("queue_us", &self.queue_us),
            ("slack_us", &self.slack_us),
        ] {
            let qs = s.percentiles_us(&[50.0, 95.0, 99.0]);
            j.key(name).begin_obj();
            j.field_uint("count", s.count());
            j.field_num("mean", s.mean_us());
            j.field_num("p50", qs[0]);
            j.field_num("p95", qs[1]);
            j.field_num("p99", qs[2]);
            j.end_obj();
        }
        if let Some(profile) = &self.profile {
            j.key("profile");
            profile.snapshot().write_json(j);
        }
        j.end_obj();
    }

    /// [`WorkerStats::write_json`] as a standalone document.
    pub fn to_json(&self) -> String {
        let mut j = crate::obs::Json::new();
        self.write_json(&mut j);
        j.finish()
    }
}

/// The compile front end a serving worker talks to: either the legacy
/// single-threaded [`CompileService`] behind one mutex (hits and cold
/// compiles both serialize), or the pool's [`SharedCompileService`]
/// whose hit path is concurrent and whose cold compiles are
/// single-flight per key.
#[derive(Clone)]
pub enum CompileBackend {
    Legacy(Arc<Mutex<CompileService>>),
    Shared(Arc<SharedCompileService>),
}

impl CompileBackend {
    fn compile(
        &self,
        module: &Module,
        mode: FusionMode,
    ) -> crate::Result<(Arc<CompiledModule>, bool)> {
        match self {
            CompileBackend::Legacy(svc) => {
                svc.lock().expect("compile service poisoned").compile(module, mode)
            }
            CompileBackend::Shared(svc) => svc.compile(module, mode),
        }
    }

    /// The per-pass trace of the most recent cold compile (None until
    /// one happened) — replayed as child spans of the compile span.
    fn last_trace(&self) -> Option<super::metrics::PassTrace> {
        match self {
            CompileBackend::Legacy(svc) => {
                svc.lock().expect("compile service poisoned").last_trace().cloned()
            }
            CompileBackend::Shared(svc) => svc.last_trace(),
        }
    }

    /// The hot-swap generation of the shared service (None for the
    /// legacy backend, which never swaps modules underneath a worker).
    fn generation(&self) -> Option<u64> {
        match self {
            CompileBackend::Legacy(_) => None,
            CompileBackend::Shared(svc) => Some(svc.generation()),
        }
    }
}

/// Check a compiled artifact's executable against the serving
/// contract before dispatching batches onto the stitched VM.
fn validate_stitched(
    plan: &std::sync::Arc<super::pipeline::CompiledModule>,
    in_elems: usize,
    out_elems: usize,
) -> Result<Arc<StitchedExecutable>> {
    let exe = plan.executable.clone().ok_or_else(|| {
        anyhow!("module did not lower: {}", plan.exec_error.clone().unwrap_or_default())
    })?;
    if exe.params.len() != 1 {
        bail!("stitched serving needs exactly 1 parameter, module has {}", exe.params.len());
    }
    if exe.params[0].elems != in_elems {
        bail!(
            "module parameter has {} elements, serving batch carries {}",
            exe.params[0].elems,
            in_elems
        );
    }
    if exe.root_elems != out_elems {
        bail!("module root has {} elements, serving expects {}", exe.root_elems, out_elems);
    }
    Ok(exe)
}

/// The serving loop body, shared by the single-worker
/// [`ServingCoordinator`] and every worker of a
/// [`super::pool::ServingPool`]: collect a bucket-pure batch (shape-pure
/// in the degenerate exact policy), make the compiled plan resident
/// (through whichever [`CompileBackend`] the caller wired up),
/// assemble, execute, reply.
///
/// Under [`ServerConfig::buckets`] the batch's key names a
/// [`ShapeClass`]; rows pad with zeros to the class's canonical length
/// on assembly and each request gets only its live output region back.
/// With [`CompileOptions::specialize`] the worker keeps one compiled
/// artifact per bucket (memoized in a per-worker map, invalidated on
/// hot-swap generation bumps); without it every bucket pads to the
/// contract length and executes the contract-shape backend.
///
/// Oversized *rows* (longer than their class's canonical length — the
/// contract's `in_elems_per_request` when unbucketed) are rejected
/// on their own response channel before assembly — the old code
/// silently truncated them and served corrupted output. Oversized
/// *batches* (the policy may collect more than the artifact's baked
/// `batch`) are split into artifact-sized chunks — the old code
/// panicked on a slice out of range.
///
/// When `live` is given, a snapshot of the counters is published after
/// every batch so the pool can report aggregate stats while serving.
///
/// `vm_threads` caps the stitched VM's block-parallel fan-out for this
/// worker (`0` = process default) — a pool divides cores between its
/// shards so shards × VM threads never oversubscribes the machine.
///
/// `shard` is this worker's id in the flight recorder's trace (one
/// ring/track per worker when [`ServerConfig::trace`] is set).
///
/// `depth` is the pool's per-shard queue-depth gauge: the submitter
/// increments it per enqueued request and this loop decrements it by
/// everything a collection round drained from the channel (served,
/// shed, or parked in the carry slot). `None` for the standalone
/// coordinator.
pub(crate) fn run_worker(
    model: &LoadedModel,
    rx: &Receiver<Request>,
    cfg: &ServerConfig,
    service: Option<&CompileBackend>,
    live: Option<&Mutex<WorkerStats>>,
    vm_threads: usize,
    shard: u32,
    depth: Option<&AtomicU64>,
) -> WorkerStats {
    // Install the flight recorder for this worker thread: every layer
    // below (compile service, stitched VM, interpreter) records spans
    // through the thread-local context for the rest of the loop.
    let _obs = cfg.trace.as_ref().map(|sink| crate::obs::install(sink, shard, None));
    let mut stats = WorkerStats::default();
    let batch_elems = cfg.batch * cfg.in_elems_per_request;
    let out_elems = cfg.batch * cfg.out_elems_per_request;
    let mut carry = None;
    let mut compile_failed = false;
    // The cost model's predicted module time (µs), set once a compile
    // resolves — the slack check's estimate until real measurements
    // accumulate.
    let mut modeled_service_us: Option<f64> = None;
    // Stitched-VM dispatch: resolved from the first successful compile
    // when requested (and signature-compatible).
    let mut stitched: Option<Arc<StitchedExecutable>> = None;
    let mut stitched_rejected = false;
    // Hot-swap watch: the shared service bumps its generation when the
    // background autotuner replaces the cached module; this worker then
    // re-resolves its stitched executable from the fresh artifact.
    let mut seen_generation: u64 = 0;
    // Shape-class bucketing: the bucket policy, the admission check the
    // batcher consults (oracle-derived when a compile config supplies
    // the device model), and the per-bucket compiled state when a
    // specializer builds canonical modules.
    let buckets = cfg.buckets.as_ref();
    let admission: Option<BucketAdmission> = buckets.map(|_| match &cfg.compile {
        Some(opts) => BucketAdmission::from_oracle(
            &crate::schedule::ModeledCost,
            &opts.pipeline.deep.device,
            cfg.batch,
            cfg.in_elems_per_request,
        ),
        None => BucketAdmission::default(),
    });
    struct BucketSlot {
        module: Module,
        stitched: Option<Arc<StitchedExecutable>>,
        rejected: bool,
    }
    let mut classes: std::collections::HashMap<u64, BucketSlot> = std::collections::HashMap::new();
    // Pooled per-worker execution state: the batch-assembly buffer, the
    // planned value arena and the output buffer all live for the
    // worker's lifetime, so the steady-state serving path performs zero
    // per-request allocations on the stitched backend.
    let mut arena = ExecArena::with_threads(vm_threads);
    let mut input: Vec<f32> = Vec::new();
    let mut stitched_out: Vec<f32> = Vec::new();
    loop {
        // Fault hook: injected worker panics fire between batches, so
        // the pool's containment drain covers whatever is still queued.
        if let Some(plan) = &cfg.faults {
            plan.fire_panic_point();
        }
        // Slack admission: the predicted service time for the next
        // batch, preferring measured execution p95 over the compiled
        // module's modeled time over the policy's bootstrap estimate.
        let slack = cfg.deadline.as_ref().map(|dp| SlackCheck {
            service_us: if stats.exec_us.count() >= 2 {
                stats.exec_us.percentiles_us(&[95.0])[0]
            } else {
                modeled_service_us.unwrap_or(dp.bootstrap_service_us)
            },
            assembly_us: dp.assembly_overhead_us,
        });
        let carry_before = carry.is_some() as usize;
        let Some(BatchOutcome { batch, shed }) =
            next_batch_admitted(rx, &cfg.policy, &mut carry, admission.as_ref(), slack.as_ref())
        else {
            break;
        };
        // Queue-depth accounting: everything that left the channel this
        // round — admitted, shed, or parked in the carry slot.
        if let Some(depth) = depth {
            let drained =
                (batch.len() + shed.len() + carry.is_some() as usize).saturating_sub(carry_before);
            depth.fetch_sub(drained as u64, Ordering::Relaxed);
        }
        // Infeasible requests get an immediate structured rejection
        // instead of timing out silently on the client side.
        if !shed.is_empty() {
            stats.rejected += shed.len();
            stats.rejects.deadline += shed.len() as u64;
            if let Some(live) = live {
                *live.lock().expect("live stats poisoned") = stats.clone();
            }
            let predicted =
                slack.as_ref().map_or(0.0, |s| s.lead().as_secs_f64() * 1e6);
            for req in shed {
                let _ = req.respond.send(Err(Error::new(Rejection::DeadlineInfeasible).context(
                    format!(
                        "shed: predicted service + assembly time {predicted:.0}us \
                         exceeds the request's remaining deadline slack"
                    ),
                )));
            }
        }
        if batch.is_empty() {
            continue;
        }
        // The batch's shape class: under bucketing, the claimed bucket
        // key resolved against the contract's maximum row; otherwise
        // the degenerate one-shape class of the contract itself.
        let class = buckets.map_or(ShapeClass::exact(cfg.in_elems_per_request), |p| {
            p.class_of_key(batch[0].shape_key, cfg.in_elems_per_request)
        });
        // Queue-wait accounting: every request waited from its enqueue
        // to this drain.
        let drained = Instant::now();
        for req in &batch {
            stats.queue_us.record(drained.saturating_duration_since(req.enqueued));
            crate::obs::record_between(
                crate::obs::SpanCat::Queue,
                "queue-wait",
                0,
                req.enqueued,
                drained,
            );
        }
        // Compile-once serving: make sure the kernel plans for this
        // module are resident before touching the batch.
        if let (Some(opts), Some(svc)) = (&cfg.compile, service) {
            if !compile_failed {
                // Hot-swap invalidation *before* resolving this batch's
                // module: a generation bump means resident artifacts are
                // new modules — drop every resolved executable (the
                // contract-shape one and every bucket slot's) and the
                // stale rejection verdicts, so they re-resolve from
                // fresh plans below. Batches already executing elsewhere
                // finish on the old Arc; nothing blocks or drops.
                let mut generation_bumped = false;
                if let Some(generation) = svc.generation() {
                    if generation != seen_generation {
                        seen_generation = generation;
                        stitched = None;
                        stitched_rejected = false;
                        for slot in classes.values_mut() {
                            slot.stitched = None;
                            slot.rejected = false;
                        }
                        generation_bumped = true;
                    }
                }
                // What this batch's shape class compiles: the bucket's
                // canonical specialization (memoized per worker) when a
                // specializer is configured, else the contract module.
                let slot = match (opts.specialize, buckets) {
                    (Some(spec), Some(_)) => Some(
                        classes.entry(batch[0].shape_key).or_insert_with(|| BucketSlot {
                            module: spec(class.canonical_len),
                            stitched: None,
                            rejected: false,
                        }),
                    ),
                    _ => None,
                };
                let module: &Module = match &slot {
                    Some(s) => &s.module,
                    None => &opts.module,
                };
                let t0 = Instant::now();
                match svc.compile(module, opts.mode) {
                    Ok((plan, hit)) => {
                        stats.compile_us.record_us(t0.elapsed().as_secs_f64() * 1e6);
                        modeled_service_us = Some(plan.timing.total_us());
                        if hit {
                            stats.cache_hits += 1;
                        } else {
                            stats.cache_misses += 1;
                            // Replay the cold compile's per-pass trace
                            // as child spans inside the compile window.
                            if crate::obs::active() {
                                if let Some(trace) = svc.last_trace() {
                                    crate::obs::record_passes(&trace.records, t0);
                                }
                            }
                        }
                        crate::obs::record_between(
                            crate::obs::SpanCat::Compile,
                            if hit { "cache-hit" } else { "cold-compile" },
                            0,
                            t0,
                            Instant::now(),
                        );
                        // Adopt the compiled module's kernel profile:
                        // launch spans below feed measured times into
                        // it. Re-adopt after a hot swap (the profile
                        // handle belongs to the new artifact).
                        if stats.profile.is_none() || generation_bumped {
                            stats.profile = Some(plan.profile.clone());
                            crate::obs::set_profile(plan.profile.clone());
                        }
                        if opts.use_stitched_backend {
                            match slot {
                                Some(s) if s.stitched.is_none() && !s.rejected => {
                                    // Bucket artifacts execute at the
                                    // bucket's canonical row length.
                                    let in_e = cfg.batch * class.canonical_len;
                                    let out_e =
                                        cfg.batch * cfg.out_elems_for(class.canonical_len);
                                    match validate_stitched(&plan, in_e, out_e) {
                                        Ok(exe) => {
                                            if stats.arena.is_none() {
                                                stats.arena = Some(exe.mem.stats());
                                            }
                                            s.stitched = Some(exe);
                                        }
                                        Err(e) => {
                                            s.rejected = true;
                                            eprintln!(
                                                "stitched backend unavailable for \
                                                 {class}, serving the artifact \
                                                 instead: {e:#}"
                                            );
                                        }
                                    }
                                }
                                None if stitched.is_none() && !stitched_rejected => {
                                    match validate_stitched(&plan, batch_elems, out_elems) {
                                        Ok(exe) => {
                                            stats.arena = Some(exe.mem.stats());
                                            stitched = Some(exe);
                                        }
                                        Err(e) => {
                                            stitched_rejected = true;
                                            eprintln!(
                                                "stitched backend unavailable, serving \
                                                 the artifact instead: {e:#}"
                                            );
                                        }
                                    }
                                }
                                _ => {}
                            }
                        }
                    }
                    Err(e) => {
                        // A structured fast-fail is the shared service's
                        // negative cache answering from backoff — not a
                        // fresh failure, and not worth a log line.
                        let fast_fail = e
                            .downcast_ref::<Rejection>()
                            .is_some_and(|r| matches!(r, Rejection::CompileFailed));
                        if !fast_fail {
                            stats.compile_failures += 1;
                        }
                        match svc {
                            CompileBackend::Legacy(_) => {
                                // No negative cache behind this backend:
                                // don't re-pay the full cold pipeline on
                                // every batch for a module that cannot
                                // compile; serve uncompiled and report.
                                compile_failed = true;
                                eprintln!("serving-path compile failed (disabling): {e:#}");
                            }
                            CompileBackend::Shared(_) => {
                                // The shared service's negative cache
                                // makes retries cheap (fast-fail inside
                                // the backoff window), so keep trying:
                                // the key recovers when a later compile
                                // succeeds. Batches serve on the
                                // artifact interpreter meanwhile.
                                if !fast_fail {
                                    eprintln!(
                                        "serving-path compile failed (will retry \
                                         after backoff): {e:#}"
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
        // Which executable serves this batch, and at what row strides: a
        // resolved bucket artifact executes at the class's canonical
        // length; everything else pads to the contract stride and runs
        // the contract-shape backend (stitched or interpreter) — so the
        // interpreter, whose input dims are baked, never sees a
        // non-contract buffer.
        let bucket_exe = buckets
            .and_then(|_| classes.get(&batch[0].shape_key))
            .and_then(|s| s.stitched.clone());
        let (active, row_in, row_out) = match bucket_exe {
            Some(exe) => {
                (Some(exe), class.canonical_len, cfg.out_elems_for(class.canonical_len))
            }
            None => (stitched.clone(), cfg.in_elems_per_request, cfg.out_elems_per_request),
        };
        // Reject rows that exceed the class's admissible range (the
        // serving contract itself when unbucketed) up front: the
        // truncated execution would silently return corrupted output,
        // and under bucketing a lying/colliding `shape_key` must not be
        // trusted.
        let (rejected, accepted): (Vec<Request>, Vec<Request>) =
            batch.into_iter().partition(|req| !class.admits(req.input.len()));
        if !rejected.is_empty() {
            stats.rejected += rejected.len();
            match buckets {
                Some(_) => stats.rejects.bucket_mismatch += rejected.len() as u64,
                None => stats.rejects.oversized += rejected.len() as u64,
            }
            // Count before replying, so a live-stats read right after
            // the error response already sees the rejection.
            if let Some(live) = live {
                *live.lock().expect("live stats poisoned") = stats.clone();
            }
            for req in rejected {
                let row = req.input.len();
                let _ = req.respond.send(Err(match buckets {
                    Some(_) => {
                        let cause = model
                            .validate_row(row, &class)
                            .expect_err("partition admitted an oversized row");
                        Error::new(Rejection::BucketMismatch).context(format!("{cause:#}"))
                    }
                    None => Error::new(Rejection::Oversized).context(format!(
                        "request row has {row} elements but the serving contract \
                         carries {} per request",
                        cfg.in_elems_per_request
                    )),
                }));
            }
        }
        // The policy may collect more requests than the artifact's
        // baked batch dimension: execute in artifact-sized chunks.
        let chunk_elems = cfg.batch * row_in;
        for chunk in accepted.chunks(cfg.batch) {
            // Assemble the padded chunk into the reused buffer (clear +
            // resize re-zeroes without reallocating). Rows shorter than
            // the stride are zero-padded; the per-row shortfall is the
            // padding-waste the bucket policy signed up for.
            let asm = crate::obs::begin();
            input.clear();
            input.resize(chunk_elems, 0f32);
            for (i, req) in chunk.iter().enumerate() {
                let start = i * row_in;
                input[start..start + req.input.len()].copy_from_slice(&req.input);
                stats.live_elems += req.input.len() as u64;
                stats.padded_elems += (row_in - req.input.len()) as u64;
            }
            crate::obs::record(crate::obs::SpanCat::Batch, "assemble", 0, asm);
            // Fault hook: injected slow kernels sleep inside the timed
            // execution window, so the delay lands in `exec_us` and
            // drives the slack estimate up like a real slowdown would.
            if let Some(plan) = &cfg.faults {
                plan.fire_execute();
            }
            let t0 = Instant::now();
            let mut artifact_out: Vec<Vec<f32>> = Vec::new();
            let result: Result<&[f32]> = match &active {
                Some(exe) => {
                    stats.stitched_batches += 1;
                    match exe.run_into(&[input.as_slice()], &mut arena, &mut stitched_out) {
                        Ok(ledger) => {
                            stats.launches.merge(&ledger);
                            stats.arena_reuses = arena.reuses();
                            Ok(stitched_out.as_slice())
                        }
                        Err(e) => Err(e),
                    }
                }
                None => {
                    let before = model.launch_ledger();
                    let r = model.run_f32(&[(&input, &cfg.input_dims)]);
                    stats.launches.merge(&model.launch_ledger().since(&before));
                    match r {
                        Ok(o) => {
                            artifact_out = o;
                            Ok(artifact_out[0].as_slice())
                        }
                        Err(e) => Err(e),
                    }
                }
            };
            stats.exec_us.record_us(t0.elapsed().as_secs_f64() * 1e6);
            stats.batches += 1;
            stats.requests += chunk.len();
            if let Some(plan) = &cfg.faults {
                plan.note_batch();
            }
            // Deadline outcome at reply time: signed slack for every
            // deadline-carrying request, a miss when the reply lands
            // late (the request is still answered — admission predicted
            // feasible, so the caller gets its output plus a counted
            // miss rather than a shed).
            let replied = Instant::now();
            for req in chunk.iter() {
                if let Some(d) = req.deadline {
                    let slack_us = if replied <= d {
                        (d - replied).as_secs_f64() * 1e6
                    } else {
                        stats.deadline_misses += 1;
                        -((replied - d).as_secs_f64() * 1e6)
                    };
                    stats.slack_us.record_us(slack_us);
                }
            }
            // Publish the snapshot *before* replying: a client that
            // reads pool stats right after its response must already
            // see its own request counted.
            if let Some(live) = live {
                *live.lock().expect("live stats poisoned") = stats.clone();
            }
            let reply = crate::obs::begin();
            match result {
                Ok(out) => {
                    for (i, req) in chunk.iter().enumerate() {
                        let start = i * row_out;
                        // Under bucketing each request gets only its
                        // *live* output region back (the padded tail is
                        // the bucket's, not the caller's); historical
                        // semantics return the full contract row.
                        let end = start
                            + match buckets {
                                Some(_) => cfg.out_elems_for(req.input.len()),
                                None => row_out,
                            };
                        let slice = out
                            .get(start..end)
                            .map(<[f32]>::to_vec)
                            .ok_or_else(|| anyhow!("output shorter than expected"));
                        let _ = req.respond.send(slice);
                    }
                }
                Err(e) => {
                    for req in chunk {
                        let _ = req.respond.send(Err(anyhow!("execution failed: {e:#}")));
                    }
                }
            }
            crate::obs::record(crate::obs::SpanCat::Reply, "reply", 0, reply);
        }
    }
    stats
}

impl ServingCoordinator {
    /// Start the loop: spawns the worker, which owns the runtime client
    /// and executable (kept on one thread so a non-`Send` PJRT backend
    /// can be swapped back in) and signals readiness back. When
    /// [`ServerConfig::compile`] is set, a fresh [`CompileService`] is
    /// created for the loop; use [`ServingCoordinator::start_with_service`]
    /// to share one cache across servers.
    pub fn start(artifact_dir: &Path, cfg: ServerConfig) -> Result<Self> {
        let service = cfg
            .compile
            .as_ref()
            .map(|o| Arc::new(Mutex::new(CompileService::new(o.pipeline.clone()))));
        Self::start_inner(artifact_dir, cfg, service)
    }

    /// Start the loop against a shared compilation cache (several
    /// serving loops — or a warmup job — can feed one service). All
    /// compiles run under the shared service's own `PipelineConfig`;
    /// [`CompileOptions::pipeline`] is ignored on this path.
    pub fn start_with_service(
        artifact_dir: &Path,
        cfg: ServerConfig,
        service: Arc<Mutex<CompileService>>,
    ) -> Result<Self> {
        Self::start_inner(artifact_dir, cfg, Some(service))
    }

    fn start_inner(
        artifact_dir: &Path,
        cfg: ServerConfig,
        service: Option<Arc<Mutex<CompileService>>>,
    ) -> Result<Self> {
        cfg.validate()?;
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let wcfg = cfg.clone();
        let backend = service.clone().map(CompileBackend::Legacy);
        let dir = artifact_dir.to_path_buf();
        let worker = std::thread::spawn(move || {
            let engine = match Engine::new(&dir).and_then(|mut e| {
                e.load(&wcfg.artifact)?;
                Ok(e)
            }) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return WorkerStats::default();
                }
            };
            let model = engine.get(&wcfg.artifact).expect("loaded above");
            // Single worker: the VM may use the whole machine.
            run_worker(model, &rx, &wcfg, backend.as_ref(), None, 0, 0, None)
        });
        // Fail fast if the artifact is missing/bad.
        ready_rx
            .recv()
            .map_err(|_| anyhow!("worker died during startup"))
            .and_then(|r| r)
            .inspect_err(|_| {
                let _ = worker.thread();
            })?;
        Ok(ServingCoordinator { tx: Some(tx), worker: Some(worker), cfg, service })
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// The compilation cache behind this loop (None without
    /// [`ServerConfig::compile`]).
    pub fn compile_service(&self) -> Option<&Arc<Mutex<CompileService>>> {
        self.service.as_ref()
    }

    /// Submit one request and block for its output. Returns the output
    /// slice and the end-to-end latency. The shape key is derived from
    /// the input length ([`ServerConfig::shape_key_for`]: the bucket
    /// key under [`ServerConfig::buckets`], the exact length otherwise).
    pub fn infer(&self, input: Vec<f32>) -> Result<(Vec<f32>, Duration)> {
        let (rtx, rrx) = mpsc::channel();
        let enqueued = Instant::now();
        let shape_key = self.cfg.shape_key_for(input.len());
        let deadline = self.default_deadline(enqueued);
        self.tx
            .as_ref()
            .context("server stopped")?
            .send(Request { input, shape_key, respond: rtx, enqueued, deadline })
            .map_err(|_| anyhow!("worker gone"))?;
        let out = rrx.recv().context("worker dropped response")??;
        Ok((out, enqueued.elapsed()))
    }

    /// Submit asynchronously; the caller holds the response channel.
    pub fn infer_async(
        &self,
        input: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        let (rtx, rrx) = mpsc::channel();
        let enqueued = Instant::now();
        let shape_key = self.cfg.shape_key_for(input.len());
        let deadline = self.default_deadline(enqueued);
        self.tx
            .as_ref()
            .context("server stopped")?
            .send(Request { input, shape_key, respond: rtx, enqueued, deadline })
            .map_err(|_| anyhow!("worker gone"))?;
        Ok(rrx)
    }

    /// Submit one request with an explicit per-request deadline and
    /// block for its output. The worker sheds the request with a
    /// structured [`Rejection::DeadlineInfeasible`] reply when its
    /// predicted service time would overrun the remaining slack.
    pub fn infer_with_deadline(
        &self,
        input: Vec<f32>,
        deadline: Duration,
    ) -> Result<(Vec<f32>, Duration)> {
        let enqueued = Instant::now();
        let rrx = self.infer_async_with_deadline(input, Some(deadline))?;
        let out = rrx.recv().context("worker dropped response")??;
        Ok((out, enqueued.elapsed()))
    }

    /// Submit asynchronously with an explicit deadline (`None` falls
    /// back to the configured [`DeadlinePolicy::default_deadline`]).
    pub fn infer_async_with_deadline(
        &self,
        input: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        let (rtx, rrx) = mpsc::channel();
        let enqueued = Instant::now();
        let shape_key = self.cfg.shape_key_for(input.len());
        let deadline = deadline
            .map(|d| enqueued + d)
            .or_else(|| self.default_deadline(enqueued));
        self.tx
            .as_ref()
            .context("server stopped")?
            .send(Request { input, shape_key, respond: rtx, enqueued, deadline })
            .map_err(|_| anyhow!("worker gone"))?;
        Ok(rrx)
    }

    /// The deadline the configured [`DeadlinePolicy`] stamps onto
    /// requests whose callers did not pick one.
    fn default_deadline(&self, enqueued: Instant) -> Option<Instant> {
        self.cfg
            .deadline
            .as_ref()
            .and_then(|d| d.default_deadline)
            .map(|d| enqueued + d)
    }

    /// Stop accepting requests, drain, and return worker statistics.
    pub fn shutdown(mut self) -> Result<WorkerStats> {
        drop(self.tx.take());
        self.worker
            .take()
            .context("already shut down")?
            .join()
            .map_err(|_| anyhow!("worker panicked"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    /// Identity-ish artifact: doubles a [4, 3] batch (batch=4 requests of
    /// 3 elements each).
    const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[4,3]{1,0})->(f32[4,3]{1,0})}

ENTRY main {
  p0 = f32[4,3]{1,0} parameter(0)
  sum = f32[4,3]{1,0} add(p0, p0)
  ROOT t = (f32[4,3]{1,0}) tuple(sum)
}
"#;

    fn config() -> ServerConfig {
        ServerConfig {
            artifact: "double".into(),
            batch: 4,
            in_elems_per_request: 3,
            out_elems_per_request: 3,
            input_dims: vec![4, 3],
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
            compile: None,
            trace: None,
            buckets: None,
            deadline: None,
            faults: None,
        }
    }

    fn server(dir: &TempDir) -> ServingCoordinator {
        std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
        ServingCoordinator::start(dir.path(), config()).unwrap()
    }

    #[test]
    fn single_request_roundtrip() {
        let dir = TempDir::new("srv");
        let srv = server(&dir);
        let (out, lat) = srv.infer(vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(out, vec![2.0, 4.0, 6.0]);
        assert!(lat > Duration::ZERO);
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn concurrent_requests_share_batches() {
        let dir = TempDir::new("srv2");
        let srv = server(&dir);
        let pending: Vec<_> = (0..8)
            .map(|i| srv.infer_async(vec![i as f32, 0.0, 1.0]).unwrap())
            .collect();
        for (i, rx) in pending.into_iter().enumerate() {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out, vec![2.0 * i as f32, 0.0, 2.0]);
        }
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.requests, 8);
        // batching actually happened: fewer executions than requests
        assert!(stats.batches < 8, "batches = {}", stats.batches);
    }

    #[test]
    fn shutdown_drains() {
        let dir = TempDir::new("srv3");
        let srv = server(&dir);
        let rx = srv.infer_async(vec![5.0, 5.0, 5.0]).unwrap();
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.requests, 1);
        assert_eq!(rx.recv().unwrap().unwrap(), vec![10.0, 10.0, 10.0]);
    }

    /// Regression: `BatchPolicy::max_batch > ServerConfig::batch` (the
    /// *defaults* disagree: policy default 8 vs artifact batch 4) used
    /// to panic with a slice out of range in batch assembly. The worker
    /// must split the collected batch into artifact-sized chunks and
    /// answer every request.
    #[test]
    fn oversized_policy_splits_batches_instead_of_panicking() {
        let dir = TempDir::new("srv-split");
        std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
        let mut cfg = config();
        // default-policy shape of the bug: collect up to 8, artifact batches 4
        cfg.policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) };
        let srv = ServingCoordinator::start(dir.path(), cfg).unwrap();
        let pending: Vec<_> = (0..8)
            .map(|i| srv.infer_async(vec![i as f32, 1.0, 2.0]).unwrap())
            .collect();
        for (i, rx) in pending.into_iter().enumerate() {
            let out = rx.recv().expect("worker must not die").unwrap();
            assert_eq!(out, vec![2.0 * i as f32, 2.0, 4.0]);
        }
        let stats = srv.shutdown().expect("worker must not panic");
        assert_eq!(stats.requests, 8);
        // an 8-request collection executes as two artifact-sized chunks
        assert!(stats.batches >= 2, "batches = {}", stats.batches);
    }

    /// Regression: rows longer than `in_elems_per_request` were silently
    /// truncated and served corrupted output; they must be rejected on
    /// their own channel while the rest of the batch still serves.
    #[test]
    fn oversized_row_is_rejected_not_truncated() {
        let dir = TempDir::new("srv-row");
        let srv = server(&dir);
        let too_long = srv.infer_async(vec![9.0, 9.0, 9.0, 9.0, 9.0]).unwrap();
        let ok = srv.infer_async(vec![1.0, 2.0, 3.0]).unwrap();
        let err = too_long.recv().unwrap().expect_err("oversized row must error");
        assert!(err.to_string().contains("5 elements"), "got: {err:#}");
        assert_eq!(ok.recv().unwrap().unwrap(), vec![2.0, 4.0, 6.0]);
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.requests, 1, "rejected rows are not served requests");
    }

    #[test]
    fn degenerate_configs_fail_at_startup() {
        let dir = TempDir::new("srv-val");
        std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
        let mut zero_batch = config();
        zero_batch.batch = 0;
        assert!(ServingCoordinator::start(dir.path(), zero_batch).is_err());
        let mut bad_dims = config();
        bad_dims.input_dims = vec![2, 3];
        let err = ServingCoordinator::start(dir.path(), bad_dims).unwrap_err();
        assert!(err.to_string().contains("input_dims"), "got: {err:#}");
        let mut zero_policy = config();
        zero_policy.policy.max_batch = 0;
        assert!(ServingCoordinator::start(dir.path(), zero_policy).is_err());
    }

    #[test]
    fn compile_once_serving_hits_cache_after_first_batch() {
        use crate::hlo::{GraphBuilder, Module, Shape};

        let dir = TempDir::new("srv4");
        std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();

        // A small stand-in for the served module — what the compile
        // service fingerprints and caches.
        let mut b = GraphBuilder::new("entry");
        let x = b.param("x", Shape::f32(&[4, 3]));
        let e = b.exp(x);
        let t = b.tanh(e);
        let module = Module::new("served", b.finish(t));

        let mut cfg = config();
        cfg.compile = Some(CompileOptions {
            module,
            mode: FusionMode::FusionStitching,
            pipeline: PipelineConfig::default(),
            use_stitched_backend: false,
            specialize: None,
        });
        let srv = ServingCoordinator::start(dir.path(), cfg).unwrap();

        // Sequential round-trips force separate batches.
        for i in 0..3 {
            let (out, _) = srv.infer(vec![i as f32; 3]).unwrap();
            assert_eq!(out, vec![2.0 * i as f32; 3]);
        }
        let service = srv.compile_service().unwrap().clone();
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.batches, 3);
        assert_eq!(stats.cache_misses, 1, "only the first batch compiles cold");
        assert_eq!(stats.cache_hits, 2);
        assert!(stats.cache_hit_rate() > 0.6);
        assert_eq!(stats.compile_us.count(), 3);
        // the service agrees with the worker's view
        let s = service.lock().unwrap().stats();
        assert_eq!((s.hits, s.misses), (2, 1));
        // op-by-op artifact serving records per-op launches
        assert!(stats.launches.generated >= 3, "{}", stats.launches);
        assert_eq!(stats.stitched_batches, 0);
    }

    /// Bucketed stitched serving: heterogeneous row lengths share
    /// per-bucket canonical artifacts, every request gets exactly its
    /// live region back, and the values match the unpadded math.
    #[test]
    fn bucketed_serving_pads_and_slices_value_identically() {
        use crate::hlo::{GraphBuilder, Module, Shape};

        fn spec(len: usize) -> Module {
            let mut b = GraphBuilder::new("entry");
            let x = b.param("x", Shape::f32(&[4, len as i64]));
            let e = b.exp(x);
            let t = b.tanh(e);
            Module::new("served", b.finish(t))
        }

        let dir = TempDir::new("srv-buckets");
        std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
        let policy = BucketPolicy::PowerOfTwo { min: 2 };
        let mut pipeline = PipelineConfig::default();
        pipeline.bucketing = policy.clone();
        let cfg = ServerConfig {
            artifact: "double".into(),
            batch: 4,
            in_elems_per_request: 4,
            out_elems_per_request: 4,
            input_dims: vec![4, 4],
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
            compile: Some(CompileOptions {
                module: spec(4),
                mode: FusionMode::FusionStitching,
                pipeline,
                use_stitched_backend: true,
                specialize: Some(spec as fn(usize) -> Module),
            }),
            trace: None,
            buckets: Some(policy),
            deadline: None,
            faults: None,
        };
        let srv = ServingCoordinator::start(dir.path(), cfg).unwrap();
        // Lengths 3 and 4 share bucket 4; length 2 has its own bucket.
        for len in [3usize, 4, 2, 3] {
            let input: Vec<f32> = (0..len).map(|i| 0.1 * (i + 1) as f32).collect();
            let (out, _) = srv.infer(input.clone()).unwrap();
            assert_eq!(out.len(), len, "live region only, no padded tail");
            for (i, (got, x)) in out.iter().zip(&input).enumerate() {
                let want = x.exp().tanh();
                assert!((got - want).abs() < 1e-6, "row[{i}]: {got} vs {want}");
            }
        }
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.requests, 4);
        assert_eq!(stats.stitched_batches, stats.batches, "all batches ran bucket artifacts");
        // Two buckets → two cold compiles; the other batches hit.
        assert_eq!(stats.cache_misses, 2, "one cold compile per bucket");
        assert_eq!(stats.cache_hits, 2);
        // The two length-3 rows each padded one element in a canonical-4 row.
        assert_eq!(stats.padded_elems, 2);
        assert_eq!(stats.live_elems, 3 + 4 + 2 + 3);
        let waste = stats.padding_waste_ratio();
        assert!(waste > 0.0 && waste < 0.2, "waste = {waste}");
    }

    #[test]
    fn stitched_backend_serves_the_compiled_module() {
        use crate::hlo::{GraphBuilder, Module, Shape};

        let dir = TempDir::new("srv5");
        std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();

        // The served module: tanh(exp(x)) over the whole [4, 3] batch —
        // signature-compatible with the serving contract.
        let mut b = GraphBuilder::new("entry");
        let x = b.param("x", Shape::f32(&[4, 3]));
        let e = b.exp(x);
        let t = b.tanh(e);
        let module = Module::new("served", b.finish(t));

        let mut cfg = config();
        cfg.compile = Some(CompileOptions {
            module,
            mode: FusionMode::FusionStitching,
            pipeline: PipelineConfig::default(),
            use_stitched_backend: true,
            specialize: None,
        });
        let srv = ServingCoordinator::start(dir.path(), cfg).unwrap();
        for i in 0..4 {
            let (out, _) = srv.infer(vec![0.1 * i as f32; 3]).unwrap();
            // batches execute the *module* on the stitched VM now
            let want = (0.1f32 * i as f32).exp().tanh();
            assert!((out[0] - want).abs() < 1e-6, "{} vs {want}", out[0]);
        }
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.stitched_batches, stats.batches);
        // Steady-state zero-allocation gate: after the first batch grew
        // the pooled arena, every later batch reused it.
        assert_eq!(
            stats.arena_reuses,
            stats.stitched_batches as u64 - 1,
            "every post-warmup batch must be served from the pooled arena"
        );
        // the memory plan's compression is surfaced in serving stats
        let arena = stats.arena.expect("stitched serving reports its arena plan");
        assert!(arena.arena_bytes > 0);
        assert!(arena.reuse_ratio() >= 1.0);
        // exp∘tanh fuses: exactly one generated launch per batch
        assert_eq!(stats.launches.generated as usize, stats.batches);
        assert_eq!(stats.launches.library, 0);
        // one request per batch here, so one launch per request
        let lpr = super::super::metrics::launches_per_request(&stats.launches, stats.requests);
        assert!((lpr - 1.0).abs() < 1e-9, "launches/request = {lpr}");
    }
}
