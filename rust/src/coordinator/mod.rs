//! The L3 coordinator: compilation pipeline driver, evaluation harness
//! and the NMT online-serving loop.
//!
//! - [`pipeline`] — `HloModule` → fusion → schedule planning → codegen →
//!   simulated timing (Fig. 4's three stages), for both the XLA baseline
//!   and FusionStitching, plus the per-benchmark evaluation report that
//!   regenerates Figs. 6–8 and Table 3.
//! - [`server`] / [`batcher`] — the latency-critical online NMT use case
//!   (§6.1): a thread-based serving loop with dynamic batching over the
//!   PJRT runtime.
//! - [`metrics`] — latency/throughput accounting for the serving loop.

pub mod batcher;
pub mod metrics;
pub mod pipeline;
pub mod server;

pub use pipeline::{compile_module, evaluate, CompiledModule, FusionMode, ModuleReport, PipelineConfig};
pub use server::{ServerConfig, ServingCoordinator};
