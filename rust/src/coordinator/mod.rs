//! The L3 coordinator: compilation pipeline driver, evaluation harness,
//! compilation cache and the NMT online-serving loop.
//!
//! - [`pipeline`] — `HloModule` → fusion → schedule planning → codegen →
//!   simulated timing (Fig. 4's three stages), for both the XLA baseline
//!   and FusionStitching, plus the per-benchmark evaluation report that
//!   regenerates Figs. 6–8 and Table 3.
//! - [`driver`] — the pass manager: the pipeline as named, instrumented
//!   passes with per-pass wall time and unit counts.
//! - [`cache`] — the compilation cache (structural-fingerprint keyed,
//!   bounded LRU) and the [`cache::CompileService`] front end that the
//!   serving loop uses to pay compilation cost exactly once.
//! - [`server`] / [`batcher`] — the latency-critical online NMT use case
//!   (§6.1): a thread-based serving loop with shape-keyed dynamic
//!   batching over the runtime.
//! - [`buckets`] — shape-class bucketing: the policy that folds nearby
//!   request shapes into one padded canonical shape so heterogeneous
//!   traffic shares compiled artifacts, plus the cost-modeled padding
//!   admission check.
//! - [`pool`] — the sharded multi-worker serving engine: N workers with
//!   sticky shape-key routing, bounded-queue backpressure, the
//!   concurrent single-flight compile service, and supervisor-driven
//!   worker respawn with rerouting while a shard is down.
//! - [`faults`] — the deterministic fault-injection harness (seeded
//!   compile failures, slow kernels, worker panics) behind the
//!   non-default `faults` cargo feature; inert no-ops otherwise.
//! - [`metrics`] — latency/throughput accounting for the serving loop
//!   plus the per-pass compile-time trace types.

pub mod batcher;
pub mod buckets;
pub mod cache;
pub mod driver;
pub mod faults;
pub mod metrics;
pub mod pipeline;
pub mod pool;
pub mod server;

pub use batcher::{BatchOutcome, BatchPolicy, Rejection, SlackCheck};
pub use buckets::{BucketAdmission, BucketPolicy, ShapeClass};
pub use cache::{CacheKey, CacheStats, CompileCache, CompileService, SharedCompileService};
pub use driver::{compile_module_traced, Pass, PassManager};
pub use faults::FaultPlan;
pub use metrics::{PassRecord, PassTrace, StreamingSummary};
pub use pipeline::{compile_module, evaluate, CompiledModule, FusionMode, ModuleReport, PipelineConfig};
pub use pool::{AutotuneConfig, PoolConfig, ServingPool, ServingStats};
pub use server::{
    CompileBackend, CompileOptions, DeadlinePolicy, RejectCounts, ServerConfig,
    ServingCoordinator, WorkerStats,
};
