//! The sharded multi-worker serving engine — the step from "a serving
//! loop" to "a serving system".
//!
//! The single-worker [`super::server::ServingCoordinator`] caps
//! throughput at one core, and its `Arc<Mutex<CompileService>>`
//! serializes even cache *hits*; the paper's motivating scenario
//! (§6.1, latency-critical online serving under heavy traffic) needs
//! the compile-once win to survive concurrency. [`ServingPool`] spawns
//! N workers and keeps them independent where it matters:
//!
//! - **Sticky sharding.** Requests route to a worker by `shape_key`
//!   (deterministic hash) — under [`ServerConfig::buckets`] the key is
//!   the *bucket* key, so one worker sees one shape-class stream: its
//!   batches stay bucket-pure (shape-pure in the degenerate exact
//!   policy; no carry churn from interleaved classes) and its stitched
//!   executables stay hot.
//! - **Backpressure.** Each worker has a *bounded* queue
//!   ([`std::sync::mpsc::sync_channel`]): submission blocks (or
//!   [`ServingPool::try_infer_async`] fails fast) when a shard falls
//!   behind, instead of queueing unboundedly.
//! - **Concurrent compile-once.** All workers share one
//!   [`SharedCompileService`]: hits are concurrent (read-lock + `Arc`
//!   clone), cold compiles are single-flight per fingerprint — N
//!   workers racing on one module pay exactly one pipeline run.
//! - **Live stats.** Each worker publishes a [`WorkerStats`] snapshot
//!   after every batch; [`ServingPool::stats`] merges them into a
//!   [`ServingStats`] aggregate readable while the pool serves.
//!
//! The artifact is parsed once up front ([`Engine::parse_artifact`])
//! and the same immutable program is registered into every worker's
//! engine, so starting a 16-worker pool does not re-parse the HLO text
//! 16 times.

use super::batcher::Request;
use super::cache::{CacheStats, SharedCompileService};
use super::server::{run_worker, CompileBackend, ServerConfig, WorkerStats};
use crate::runtime::Engine;
use anyhow::{anyhow, Context, Result};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Feedback-directed autotuning knobs (the `serve --autotune` path).
///
/// A background thread periodically writes the served module's measured
/// launch times back into the shared service's perf library and, when
/// the measured picture changed, re-runs cost-guided exploration under
/// the measured oracle ([`SharedCompileService::reexplore_and_swap`]).
/// A changed plan hot-swaps atomically: workers pick the new module up
/// on their next batch, in-flight batches finish on the old one.
#[derive(Debug, Clone)]
pub struct AutotuneConfig {
    /// How often the write-back/re-explore step wakes up.
    pub interval: Duration,
    /// Minimum launches a profile snapshot must carry before it is
    /// written back (avoids steering on a handful of noisy samples).
    pub min_launches: u64,
}

impl Default for AutotuneConfig {
    fn default() -> Self {
        AutotuneConfig { interval: Duration::from_millis(50), min_launches: 8 }
    }
}

/// Pool sizing and backpressure knobs.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Worker thread count; `0` means "available parallelism".
    pub workers: usize,
    /// Bound of each worker's request queue — the backpressure window.
    pub queue_depth: usize,
    /// Run the feedback-directed autotuning thread (requires
    /// [`ServerConfig::compile`]; ignored without it).
    pub autotune: Option<AutotuneConfig>,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig { workers: 0, queue_depth: 64, autotune: None }
    }
}

impl PoolConfig {
    fn resolved_workers(&self) -> usize {
        if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

/// Aggregate view over every worker, readable while the pool is live.
#[derive(Debug, Clone)]
pub struct ServingStats {
    /// Per-worker snapshots, indexed by shard.
    pub per_worker: Vec<WorkerStats>,
    /// Everything merged: counters summed, latency summaries folded,
    /// [`crate::exec::LaunchLedger`]s merged.
    pub aggregate: WorkerStats,
    /// The shared compile cache's counters (`None` when the pool
    /// serves without a compile service).
    pub cache: Option<CacheStats>,
    /// Cold pipeline runs the shared service actually executed — under
    /// single-flight this stays at one per distinct module no matter
    /// how many workers raced on it.
    pub cold_compiles: Option<u64>,
    /// The shared service's hot-swap generation: how many times the
    /// autotuner replaced the served module (`None` without a service).
    pub generation: Option<u64>,
}

impl ServingStats {
    pub fn workers(&self) -> usize {
        self.per_worker.len()
    }

    /// Serialize with the shared JSON writer ([`crate::obs::Json`]):
    /// aggregate + per-worker [`WorkerStats`] plus the shared cache's
    /// counters, in one stable form for exporters, benches and `serve`.
    pub fn write_json(&self, j: &mut crate::obs::Json) {
        j.begin_obj();
        j.field_uint("workers", self.per_worker.len() as u64);
        j.key("aggregate");
        self.aggregate.write_json(j);
        j.key("per_worker").begin_arr();
        for w in &self.per_worker {
            w.write_json(j);
        }
        j.end_arr();
        if let Some(cache) = &self.cache {
            j.key("cache").begin_obj();
            j.field_uint("hits", cache.hits);
            j.field_uint("misses", cache.misses);
            j.field_uint("evictions", cache.evictions);
            j.field_uint("insertions", cache.insertions);
            j.end_obj();
        }
        if let Some(cold) = self.cold_compiles {
            j.field_uint("cold_compiles", cold);
        }
        if let Some(generation) = self.generation {
            j.field_uint("generation", generation);
        }
        j.end_obj();
    }

    /// [`ServingStats::write_json`] as a standalone document.
    pub fn to_json(&self) -> String {
        let mut j = crate::obs::Json::new();
        self.write_json(&mut j);
        j.finish()
    }

    /// Lift one worker's stats into a pool-shaped view (the single
    /// worker [`super::server::ServingCoordinator`] reuses the pool's
    /// exporters this way).
    pub fn from_worker(stats: WorkerStats) -> ServingStats {
        ServingStats {
            per_worker: vec![stats.clone()],
            aggregate: stats,
            cache: None,
            cold_compiles: None,
            generation: None,
        }
    }
}

/// Handle to the sharded serving engine. See the module docs.
pub struct ServingPool {
    txs: Vec<SyncSender<Request>>,
    workers: Vec<JoinHandle<WorkerStats>>,
    live: Vec<Arc<Mutex<WorkerStats>>>,
    cfg: ServerConfig,
    service: Option<Arc<SharedCompileService>>,
    autotune_stop: Option<Arc<AtomicBool>>,
    autotune_thread: Option<JoinHandle<()>>,
}

impl ServingPool {
    /// Start the pool. When [`ServerConfig::compile`] is set, one
    /// [`SharedCompileService`] is created from its pipeline config and
    /// shared by every worker.
    pub fn start(artifact_dir: &Path, cfg: ServerConfig, pool: PoolConfig) -> Result<Self> {
        let service = cfg
            .compile
            .as_ref()
            .map(|o| Arc::new(SharedCompileService::new(o.pipeline.clone())));
        Self::start_inner(artifact_dir, cfg, pool, service)
    }

    /// Start the pool against an existing shared service (e.g. one
    /// pre-warmed by an offline compile job, or shared across pools).
    /// As with [`super::server::ServingCoordinator::start_with_service`],
    /// the *service's* pipeline config governs every compile.
    pub fn start_with_service(
        artifact_dir: &Path,
        cfg: ServerConfig,
        pool: PoolConfig,
        service: Arc<SharedCompileService>,
    ) -> Result<Self> {
        Self::start_inner(artifact_dir, cfg, pool, Some(service))
    }

    fn start_inner(
        artifact_dir: &Path,
        cfg: ServerConfig,
        pool: PoolConfig,
        service: Option<Arc<SharedCompileService>>,
    ) -> Result<Self> {
        cfg.validate()?;
        if pool.queue_depth == 0 {
            return Err(anyhow!("queue_depth must be >= 1"));
        }
        let n = pool.resolved_workers();
        // Divide the machine between the shards: each worker's stitched
        // VM gets its share of the cores, so N shards × T VM threads
        // never oversubscribes (a lone worker still goes wide).
        let vm_threads = (crate::exec::par::default_threads() / n).max(1);
        // Parse the artifact exactly once; every worker shares it. This
        // also fails fast — before any thread spawns — on a missing or
        // malformed artifact.
        let program = Engine::parse_artifact(artifact_dir, &cfg.artifact)
            .with_context(|| format!("loading artifact {:?}", cfg.artifact))?;
        let backend = service.clone().map(CompileBackend::Shared);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut txs = Vec::with_capacity(n);
        let mut workers = Vec::with_capacity(n);
        let mut live = Vec::with_capacity(n);
        for shard in 0..n {
            let (tx, rx): (SyncSender<Request>, Receiver<Request>) =
                mpsc::sync_channel(pool.queue_depth);
            let snapshot = Arc::new(Mutex::new(WorkerStats::default()));
            let wcfg = cfg.clone();
            let wprog = program.clone();
            let wbackend = backend.clone();
            let wsnapshot = snapshot.clone();
            let wready = ready_tx.clone();
            let dir = artifact_dir.to_path_buf();
            workers.push(std::thread::spawn(move || {
                let mut engine = match Engine::new(&dir) {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = wready.send(Err(e.context(format!("worker {shard} startup"))));
                        return WorkerStats::default();
                    }
                };
                engine.register_program(&wcfg.artifact, wprog);
                let _ = wready.send(Ok(()));
                let model = engine.get(&wcfg.artifact).expect("registered above");
                run_worker(
                    model,
                    &rx,
                    &wcfg,
                    wbackend.as_ref(),
                    Some(wsnapshot.as_ref()),
                    vm_threads,
                    shard as u32,
                )
            }));
            txs.push(tx);
            live.push(snapshot);
        }
        // Fail fast if any shard failed to come up; dropping `txs` on
        // the error path disconnects the healthy workers, which then
        // drain and exit.
        drop(ready_tx);
        for _ in 0..n {
            ready_rx.recv().map_err(|_| anyhow!("worker died during startup"))??;
        }
        // Feedback loop: a background thread writes measured launch
        // times back into the perf library and re-explores under the
        // measured oracle; a changed plan hot-swaps via the cache
        // generation (workers re-resolve on their next batch).
        let (autotune_stop, autotune_thread) = match (&pool.autotune, &service, &cfg.compile) {
            (Some(at), Some(svc), Some(opts)) => {
                let stop = Arc::new(AtomicBool::new(false));
                let tstop = stop.clone();
                let tsvc = svc.clone();
                let module = opts.module.clone();
                let mode = opts.mode;
                let at = at.clone();
                let handle = std::thread::spawn(move || {
                    let mut seen_epoch = 0u64;
                    while !tstop.load(Ordering::Relaxed) {
                        std::thread::sleep(at.interval);
                        if tstop.load(Ordering::Relaxed) {
                            break;
                        }
                        // Write-back: fold the resident module's launch
                        // spans into the library's measured entries.
                        if let Some(current) = tsvc.probe(&module, mode) {
                            let snap = current.profile.snapshot();
                            if snap.total_launches() >= at.min_launches {
                                tsvc.absorb_profile(&snap);
                            }
                        }
                        // Re-explore only when the measured picture
                        // actually moved since the last pass.
                        let epoch = tsvc.measured_epoch();
                        if epoch != 0 && epoch != seen_epoch {
                            seen_epoch = epoch;
                            let _ = tsvc.reexplore_and_swap(&module, mode);
                        }
                    }
                });
                (Some(stop), Some(handle))
            }
            _ => (None, None),
        };
        Ok(ServingPool { txs, workers, live, cfg, service, autotune_stop, autotune_thread })
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// The shared compile service behind the pool (`None` without
    /// [`ServerConfig::compile`]).
    pub fn compile_service(&self) -> Option<&Arc<SharedCompileService>> {
        self.service.as_ref()
    }

    /// Which shard serves `shape_key` — sticky and deterministic, so a
    /// shape's traffic always lands on the same worker. The SplitMix64
    /// finalizer spreads consecutive keys (shape keys are often input
    /// lengths) uniformly over shards.
    pub fn route(&self, shape_key: u64) -> usize {
        (super::metrics::splitmix64(shape_key) % self.txs.len() as u64) as usize
    }

    fn request(
        input: Vec<f32>,
        shape_key: u64,
    ) -> (Request, mpsc::Receiver<Result<Vec<f32>>>) {
        let (rtx, rrx) = mpsc::channel();
        (Request { input, shape_key, respond: rtx, enqueued: Instant::now() }, rrx)
    }

    /// Submit one request and block for its output (backpressure: the
    /// submission itself blocks while the shard's queue is full).
    /// Returns the output and the end-to-end latency. The shape key is
    /// derived from the input length ([`ServerConfig::shape_key_for`]:
    /// the bucket key under [`ServerConfig::buckets`], the exact length
    /// otherwise).
    pub fn infer(&self, input: Vec<f32>) -> Result<(Vec<f32>, Duration)> {
        let key = self.cfg.shape_key_for(input.len());
        self.infer_keyed(key, input)
    }

    /// [`ServingPool::infer`] with an explicit shape key (e.g. a
    /// truncated module fingerprint for multi-model traffic). Under
    /// [`ServerConfig::buckets`] the key is an explicit *bucket claim*
    /// and is validated worker-side: a row longer than the claimed
    /// bucket's canonical length is rejected, not trusted.
    pub fn infer_keyed(&self, shape_key: u64, input: Vec<f32>) -> Result<(Vec<f32>, Duration)> {
        let enqueued = Instant::now();
        let rrx = self.infer_keyed_async(shape_key, input)?;
        let out = rrx.recv().context("worker dropped response")??;
        Ok((out, enqueued.elapsed()))
    }

    /// Submit asynchronously; the caller holds the response channel.
    pub fn infer_async(&self, input: Vec<f32>) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        let key = self.cfg.shape_key_for(input.len());
        self.infer_keyed_async(key, input)
    }

    /// Async submit with an explicit shape key. Blocks while the
    /// shard's bounded queue is full.
    pub fn infer_keyed_async(
        &self,
        shape_key: u64,
        input: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        let shard = self.route(shape_key);
        let (req, rrx) = Self::request(input, shape_key);
        self.txs[shard].send(req).map_err(|_| anyhow!("worker {shard} gone"))?;
        Ok(rrx)
    }

    /// Non-blocking submit: fails fast with a "backpressure" error when
    /// the shard's queue is full, so callers can shed load instead of
    /// stalling.
    pub fn try_infer_async(
        &self,
        shape_key: u64,
        input: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        let shard = self.route(shape_key);
        let (req, rrx) = Self::request(input, shape_key);
        match self.txs[shard].try_send(req) {
            Ok(()) => Ok(rrx),
            Err(TrySendError::Full(_)) => {
                Err(anyhow!("backpressure: worker {shard} queue is full"))
            }
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("worker {shard} gone")),
        }
    }

    /// Merge every worker's latest snapshot (plus the shared cache's
    /// counters) into one [`ServingStats`] — readable while the pool
    /// is live; workers refresh their snapshot after every batch.
    pub fn stats(&self) -> ServingStats {
        let per_worker: Vec<WorkerStats> =
            self.live.iter().map(|w| w.lock().expect("live stats poisoned").clone()).collect();
        Self::merged(per_worker, self.service.as_deref())
    }

    fn merged(per_worker: Vec<WorkerStats>, service: Option<&SharedCompileService>) -> ServingStats {
        let mut aggregate = WorkerStats::default();
        for w in &per_worker {
            aggregate.merge(w);
        }
        ServingStats {
            per_worker,
            aggregate,
            cache: service.map(SharedCompileService::stats),
            cold_compiles: service.map(SharedCompileService::cold_compiles),
            generation: service.map(SharedCompileService::generation),
        }
    }

    /// Stop accepting requests, drain every shard, and return the
    /// final statistics.
    pub fn shutdown(self) -> Result<ServingStats> {
        if let Some(stop) = &self.autotune_stop {
            stop.store(true, Ordering::Relaxed);
        }
        if let Some(handle) = self.autotune_thread {
            handle.join().map_err(|_| anyhow!("autotune thread panicked"))?;
        }
        drop(self.txs);
        let mut per_worker = Vec::with_capacity(self.workers.len());
        for worker in self.workers {
            per_worker.push(worker.join().map_err(|_| anyhow!("worker panicked"))?);
        }
        Ok(Self::merged(per_worker, self.service.as_deref()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::testutil::TempDir;

    /// Doubles a [4, 3] batch (batch=4 requests of 3 elements each).
    const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[4,3]{1,0})->(f32[4,3]{1,0})}

ENTRY main {
  p0 = f32[4,3]{1,0} parameter(0)
  sum = f32[4,3]{1,0} add(p0, p0)
  ROOT t = (f32[4,3]{1,0}) tuple(sum)
}
"#;

    fn config() -> ServerConfig {
        ServerConfig {
            artifact: "double".into(),
            batch: 4,
            in_elems_per_request: 3,
            out_elems_per_request: 3,
            input_dims: vec![4, 3],
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
            compile: None,
            trace: None,
            buckets: None,
        }
    }

    fn pool(dir: &TempDir, workers: usize) -> ServingPool {
        std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
        ServingPool::start(
            dir.path(),
            config(),
            PoolConfig { workers, ..PoolConfig::default() },
        )
        .unwrap()
    }

    #[test]
    fn pool_serves_across_workers() {
        let dir = TempDir::new("pool1");
        let p = pool(&dir, 3);
        // 16 distinct shape keys spread over 3 shards; all must answer.
        let pending: Vec<_> = (0..16u64)
            .map(|k| (k, p.infer_keyed_async(k, vec![k as f32, 1.0, 2.0]).unwrap()))
            .collect();
        for (k, rx) in pending {
            assert_eq!(rx.recv().unwrap().unwrap(), vec![2.0 * k as f32, 2.0, 4.0]);
        }
        let stats = p.shutdown().unwrap();
        assert_eq!(stats.workers(), 3);
        assert_eq!(stats.aggregate.requests, 16);
        // sticky sharding actually spread the keys
        assert!(stats.per_worker.iter().filter(|w| w.requests > 0).count() >= 2);
    }

    #[test]
    fn routing_is_sticky_and_in_range() {
        let dir = TempDir::new("pool2");
        let p = pool(&dir, 4);
        for key in 0..64u64 {
            let a = p.route(key);
            assert_eq!(a, p.route(key), "routing must be deterministic");
            assert!(a < 4);
        }
        // consecutive keys don't all collapse onto one shard
        let shards: std::collections::HashSet<_> = (0..64u64).map(|k| p.route(k)).collect();
        assert!(shards.len() >= 3, "shards used: {shards:?}");
        p.shutdown().unwrap();
    }

    #[test]
    fn live_stats_are_readable_while_serving() {
        let dir = TempDir::new("pool3");
        let p = pool(&dir, 2);
        for i in 0..6u64 {
            let (out, _) = p.infer_keyed(i, vec![i as f32; 3]).unwrap();
            assert_eq!(out, vec![2.0 * i as f32; 3]);
        }
        // all six answered, so every worker has published its snapshot
        let live = p.stats();
        assert_eq!(live.aggregate.requests, 6);
        assert!(live.aggregate.batches >= 1);
        assert_eq!(live.workers(), 2);
        let fin = p.shutdown().unwrap();
        assert_eq!(fin.aggregate.requests, 6);
    }

    #[test]
    fn backpressure_bounds_the_queue() {
        let dir = TempDir::new("pool4");
        std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
        let mut cfg = config();
        // long batching window so the worker lingers in collection
        cfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(5) };
        let p = ServingPool::start(
            dir.path(),
            cfg,
            PoolConfig { workers: 1, queue_depth: 2, autotune: None },
        )
        .unwrap();
        // Flood one shard with try_send: the bounded queue must refuse
        // at least one submission long before 100k attempts (the worker
        // serves ~µs-scale batches while we submit at ~ns-scale).
        let mut receivers = Vec::new();
        let mut saw_full = false;
        for i in 0..100_000u64 {
            match p.try_infer_async(7, vec![i as f32, 0.0, 0.0]) {
                Ok(rx) => receivers.push(rx),
                Err(e) => {
                    assert!(e.to_string().contains("backpressure"), "got: {e:#}");
                    saw_full = true;
                    break;
                }
            }
        }
        assert!(saw_full, "bounded queue never pushed back");
        for rx in receivers {
            rx.recv().unwrap().unwrap();
        }
        p.shutdown().unwrap();
    }

    #[test]
    fn oversized_rows_rejected_poolwide() {
        let dir = TempDir::new("pool5");
        let p = pool(&dir, 2);
        let bad = p.infer_keyed(9, vec![0.0; 7]);
        assert!(bad.is_err(), "oversized row must error, not truncate");
        let stats = p.shutdown().unwrap();
        assert_eq!(stats.aggregate.rejected, 1);
    }
}
