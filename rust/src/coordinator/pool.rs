//! The sharded multi-worker serving engine — the step from "a serving
//! loop" to "a serving system".
//!
//! The single-worker [`super::server::ServingCoordinator`] caps
//! throughput at one core, and its `Arc<Mutex<CompileService>>`
//! serializes even cache *hits*; the paper's motivating scenario
//! (§6.1, latency-critical online serving under heavy traffic) needs
//! the compile-once win to survive concurrency. [`ServingPool`] spawns
//! N workers and keeps them independent where it matters:
//!
//! - **Sticky sharding.** Requests route to a worker by `shape_key`
//!   (deterministic hash) — under [`ServerConfig::buckets`] the key is
//!   the *bucket* key, so one worker sees one shape-class stream: its
//!   batches stay bucket-pure (shape-pure in the degenerate exact
//!   policy; no carry churn from interleaved classes) and its stitched
//!   executables stay hot.
//! - **Backpressure.** Each worker has a *bounded* queue
//!   ([`std::sync::mpsc::sync_channel`]): submission blocks (or
//!   [`ServingPool::try_infer_async`] fails fast) when a shard falls
//!   behind, instead of queueing unboundedly.
//! - **Concurrent compile-once.** All workers share one
//!   [`SharedCompileService`]: hits are concurrent (read-lock + `Arc`
//!   clone), cold compiles are single-flight per fingerprint — N
//!   workers racing on one module pay exactly one pipeline run.
//! - **Live stats.** Each worker publishes a [`WorkerStats`] snapshot
//!   after every batch; [`ServingPool::stats`] merges them into a
//!   [`ServingStats`] aggregate readable while the pool serves.
//! - **Fault containment.** A worker panic is caught on its own
//!   thread: queued requests get a structured [`Rejection::Shed`]
//!   reply, the incarnation's counters fold into the shard's durable
//!   accumulator, and a supervisor thread respawns the worker (up to
//!   [`PoolConfig::max_respawns`] times per shard). While a shard is
//!   down — respawning, or its budget exhausted — submissions *reroute*
//!   to the next live shard instead of erroring forever on the sticky
//!   key. Dropping the pool (or [`ServingPool::shutdown`]) drains every
//!   queue: every in-flight request is answered or shed, never left
//!   hanging on a client `recv`.
//!
//! The artifact is parsed once up front ([`Engine::parse_artifact`])
//! and the same immutable program is registered into every worker's
//! engine, so starting a 16-worker pool does not re-parse the HLO text
//! 16 times.

use super::batcher::{Rejection, Request};
use super::cache::{CacheStats, SharedCompileService};
use super::server::{run_worker, CompileBackend, ServerConfig, WorkerStats};
use crate::runtime::interp::HloProgram;
use crate::runtime::Engine;
use anyhow::{anyhow, Context, Error, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Lock a mutex, recovering the data from a poisoned lock. A worker
/// that panicked mid-publish leaves at worst a stale stats snapshot —
/// never an invariant violation worth propagating the panic for.
fn lock_tolerant<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Feedback-directed autotuning knobs (the `serve --autotune` path).
///
/// A background thread periodically writes the served module's measured
/// launch times back into the shared service's perf library and, when
/// the measured picture changed, re-runs cost-guided exploration under
/// the measured oracle ([`SharedCompileService::reexplore_and_swap`]).
/// A changed plan hot-swaps atomically: workers pick the new module up
/// on their next batch, in-flight batches finish on the old one.
#[derive(Debug, Clone)]
pub struct AutotuneConfig {
    /// How often the write-back/re-explore step wakes up.
    pub interval: Duration,
    /// Minimum launches a profile snapshot must carry before it is
    /// written back (avoids steering on a handful of noisy samples).
    pub min_launches: u64,
}

impl Default for AutotuneConfig {
    fn default() -> Self {
        AutotuneConfig { interval: Duration::from_millis(50), min_launches: 8 }
    }
}

/// Pool sizing and backpressure knobs.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Worker thread count; `0` means "available parallelism".
    pub workers: usize,
    /// Bound of each worker's request queue — the backpressure window.
    pub queue_depth: usize,
    /// Run the feedback-directed autotuning thread (requires
    /// [`ServerConfig::compile`]; ignored without it).
    pub autotune: Option<AutotuneConfig>,
    /// How many times the supervisor will respawn each shard's worker
    /// after a panic before marking the shard permanently down (its
    /// traffic then reroutes to live shards).
    pub max_respawns: u32,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig { workers: 0, queue_depth: 64, autotune: None, max_respawns: 3 }
    }
}

impl PoolConfig {
    fn resolved_workers(&self) -> usize {
        if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

/// Aggregate view over every worker, readable while the pool is live.
#[derive(Debug, Clone)]
pub struct ServingStats {
    /// Per-worker snapshots, indexed by shard. Each entry folds the
    /// shard's finished worker incarnations (clean exits and contained
    /// panics) together with its current incarnation's live snapshot.
    pub per_worker: Vec<WorkerStats>,
    /// Everything merged: counters summed, latency summaries folded,
    /// [`crate::exec::LaunchLedger`]s merged.
    pub aggregate: WorkerStats,
    /// The shared compile cache's counters (`None` when the pool
    /// serves without a compile service).
    pub cache: Option<CacheStats>,
    /// Cold pipeline runs the shared service actually executed — under
    /// single-flight this stays at one per distinct module no matter
    /// how many workers raced on it.
    pub cold_compiles: Option<u64>,
    /// The shared service's hot-swap generation: how many times the
    /// autotuner replaced the served module (`None` without a service).
    pub generation: Option<u64>,
    /// Workers the supervisor respawned after a contained panic.
    pub respawns: u64,
    /// Submissions that landed on a non-primary shard because the
    /// sticky shard was down (respawning or budget-exhausted).
    pub reroutes: u64,
    /// Current per-shard queue depth (requests submitted but not yet
    /// drained by the worker), indexed by shard.
    pub queue_depths: Vec<u64>,
    /// Shards currently without a live worker (mid-respawn, or their
    /// respawn budget is exhausted).
    pub shards_down: usize,
    /// Compile requests the shared service's negative cache answered
    /// with a fast-fail (`None` without a service).
    pub compile_fast_fails: Option<u64>,
}

impl ServingStats {
    pub fn workers(&self) -> usize {
        self.per_worker.len()
    }

    /// Serialize with the shared JSON writer ([`crate::obs::Json`]):
    /// aggregate + per-worker [`WorkerStats`] plus the shared cache's
    /// counters, in one stable form for exporters, benches and `serve`.
    pub fn write_json(&self, j: &mut crate::obs::Json) {
        j.begin_obj();
        j.field_uint("workers", self.per_worker.len() as u64);
        j.key("aggregate");
        self.aggregate.write_json(j);
        j.key("per_worker").begin_arr();
        for w in &self.per_worker {
            w.write_json(j);
        }
        j.end_arr();
        if let Some(cache) = &self.cache {
            j.key("cache").begin_obj();
            j.field_uint("hits", cache.hits);
            j.field_uint("misses", cache.misses);
            j.field_uint("evictions", cache.evictions);
            j.field_uint("insertions", cache.insertions);
            j.end_obj();
        }
        if let Some(cold) = self.cold_compiles {
            j.field_uint("cold_compiles", cold);
        }
        if let Some(generation) = self.generation {
            j.field_uint("generation", generation);
        }
        j.field_uint("respawns", self.respawns);
        j.field_uint("reroutes", self.reroutes);
        j.field_uint("shards_down", self.shards_down as u64);
        if let Some(fast) = self.compile_fast_fails {
            j.field_uint("compile_fast_fails", fast);
        }
        j.key("queue_depths").begin_arr();
        for d in &self.queue_depths {
            j.uint(*d);
        }
        j.end_arr();
        j.end_obj();
    }

    /// [`ServingStats::write_json`] as a standalone document.
    pub fn to_json(&self) -> String {
        let mut j = crate::obs::Json::new();
        self.write_json(&mut j);
        j.finish()
    }

    /// Lift one worker's stats into a pool-shaped view (the single
    /// worker [`super::server::ServingCoordinator`] reuses the pool's
    /// exporters this way).
    pub fn from_worker(stats: WorkerStats) -> ServingStats {
        ServingStats {
            per_worker: vec![stats.clone()],
            aggregate: stats,
            cache: None,
            cold_compiles: None,
            generation: None,
            respawns: 0,
            reroutes: 0,
            queue_depths: Vec::new(),
            shards_down: 0,
            compile_fast_fails: None,
        }
    }
}

/// The mutable routing state of one shard, guarded by one lock so a
/// submitter sees a consistent (channel, live-stats) pair and the
/// supervisor can swap both atomically on respawn.
struct ShardState {
    /// The live worker's bounded request queue; `None` while the shard
    /// is down (mid-respawn, or its budget is exhausted).
    tx: Option<SyncSender<Request>>,
    /// The live incarnation's stats snapshot (a fresh Arc per respawn;
    /// finished incarnations fold into [`Shard::done`]).
    live: Arc<Mutex<WorkerStats>>,
    /// Remaining respawn budget.
    respawns_left: u32,
}

/// One serving shard: routing state plus the durable counters that
/// survive worker incarnations.
///
/// Lock order across a shard is `done` → `state` → `live` (each lock
/// optional, never taken in reverse), so stats readers, the supervisor
/// and the fold-on-exit path cannot deadlock.
struct Shard {
    state: Mutex<ShardState>,
    /// Counters folded in from every finished worker incarnation —
    /// clean exits contribute their final return value, contained
    /// panics their last published live snapshot.
    done: Mutex<WorkerStats>,
    /// Queue-depth gauge: submitters increment before sending, the
    /// worker decrements by everything a collection round drained.
    depth: Arc<AtomicU64>,
}

/// Everything the submitters, workers and supervisor share.
struct PoolShared {
    shards: Vec<Shard>,
    cfg: ServerConfig,
    dir: PathBuf,
    program: Arc<HloProgram>,
    backend: Option<CompileBackend>,
    queue_depth: usize,
    vm_threads: usize,
    /// Set on teardown: the supervisor stops respawning.
    stopping: AtomicBool,
    respawns: AtomicU64,
    reroutes: AtomicU64,
    /// Join handles of every spawned worker incarnation (teardown joins
    /// them all; a panicked thread's join returns Err harmlessly).
    handles: Mutex<Vec<JoinHandle<()>>>,
    /// The supervisor's wake-up channel. Workers clone the sender
    /// transiently to report their shard down; teardown clears it so
    /// the supervisor's `recv` unblocks once the last worker exited.
    sup_tx: Mutex<Option<mpsc::Sender<usize>>>,
}

/// Report `shard_idx` down to the supervisor (no-op once teardown
/// cleared the channel).
fn notify_down(shared: &PoolShared, shard_idx: usize) {
    let tx = lock_tolerant(&shared.sup_tx).clone();
    if let Some(tx) = tx {
        let _ = tx.send(shard_idx);
    }
}

/// Fold a finished incarnation's stats into the shard's durable
/// accumulator: the worker's final return value on a clean exit, or
/// (after a panic, when the return value died with the stack) its last
/// published live snapshot. The live cell is zeroed under the same
/// locks so a stats reader never double-counts the folded portion.
fn fold_into_done(shard: &Shard, live: &Mutex<WorkerStats>, fin: Option<WorkerStats>) {
    let mut done = lock_tolerant(&shard.done);
    let mut live = lock_tolerant(live);
    let stats = fin.unwrap_or_else(|| live.clone());
    done.merge(&stats);
    *live = WorkerStats::default();
}

/// Spawn one worker incarnation for `shard_idx`, reading from `rx` and
/// publishing into `live`. `ready` carries the startup handshake for
/// the initial spawn; respawns pass `None` (a respawn that fails to
/// start reports the shard down again instead).
///
/// The worker body runs under `catch_unwind`: a panic — injected or
/// real — is contained to this incarnation. Its queued requests are
/// shed with a structured reply, its counters fold into the shard's
/// accumulator, and the supervisor is asked for a replacement.
fn spawn_worker(
    shared: &Arc<PoolShared>,
    shard_idx: usize,
    rx: Receiver<Request>,
    live: Arc<Mutex<WorkerStats>>,
    ready: Option<mpsc::Sender<Result<()>>>,
) -> JoinHandle<()> {
    let shared = shared.clone();
    std::thread::spawn(move || {
        let mut engine = match Engine::new(&shared.dir) {
            Ok(e) => e,
            Err(e) => {
                let e = e.context(format!("worker {shard_idx} startup"));
                match ready {
                    Some(tx) => {
                        let _ = tx.send(Err(e));
                    }
                    None => {
                        eprintln!("respawned worker {shard_idx} failed to start: {e:#}");
                        notify_down(&shared, shard_idx);
                    }
                }
                return;
            }
        };
        engine.register_program(&shared.cfg.artifact, shared.program.clone());
        if let Some(tx) = ready {
            let _ = tx.send(Ok(()));
        }
        let model = engine.get(&shared.cfg.artifact).expect("registered above");
        let shard = &shared.shards[shard_idx];
        let result = catch_unwind(AssertUnwindSafe(|| {
            run_worker(
                model,
                &rx,
                &shared.cfg,
                shared.backend.as_ref(),
                Some(live.as_ref()),
                shared.vm_threads,
                shard_idx as u32,
                Some(shard.depth.as_ref()),
            )
        }));
        match result {
            Ok(stats) => fold_into_done(shard, &live, Some(stats)),
            Err(_) => {
                fold_into_done(shard, &live, None);
                // Shed everything still queued with a structured reply
                // — the panicked loop will never serve it, and a
                // dropped channel would read as an anonymous failure
                // client-side.
                let mut drained = 0u64;
                while let Ok(req) = rx.try_recv() {
                    drained += 1;
                    let _ = req.respond.send(Err(Error::new(Rejection::Shed).context(format!(
                        "worker {shard_idx} panicked; request shed during respawn"
                    ))));
                }
                // Dropping the receiver now disconnects any submitter
                // still holding the old sender, so it reroutes instead
                // of queueing into the void.
                drop(rx);
                if drained > 0 {
                    shard.depth.fetch_sub(drained, Ordering::Relaxed);
                    let mut done = lock_tolerant(&shard.done);
                    done.rejected += drained as usize;
                    done.rejects.shed += drained;
                }
                eprintln!("serving worker {shard_idx} panicked; respawning");
                notify_down(&shared, shard_idx);
            }
        }
    })
}

/// The supervisor loop: each message names a shard whose worker died.
/// Within budget, install a fresh channel + live cell and respawn;
/// after the budget, mark the shard permanently down (its traffic
/// reroutes). Exits when every sender is gone — teardown clears the
/// pool's copy and the last worker's transient clone drops with it.
fn supervise(shared: Arc<PoolShared>, sup_rx: mpsc::Receiver<usize>) {
    while let Ok(idx) = sup_rx.recv() {
        if shared.stopping.load(Ordering::SeqCst) {
            continue;
        }
        let shard = &shared.shards[idx];
        let (tx, rx) = mpsc::sync_channel::<Request>(shared.queue_depth);
        let live = Arc::new(Mutex::new(WorkerStats::default()));
        {
            let mut state = lock_tolerant(&shard.state);
            if state.respawns_left == 0 {
                state.tx = None;
                eprintln!("worker {idx} exhausted its respawn budget; shard marked down");
                continue;
            }
            state.respawns_left -= 1;
            // Requests that died with the old channel leaked their
            // depth increments; the fresh channel starts empty.
            shard.depth.store(0, Ordering::Relaxed);
            state.tx = Some(tx);
            state.live = live.clone();
        }
        shared.respawns.fetch_add(1, Ordering::Relaxed);
        let handle = spawn_worker(&shared, idx, rx, live, None);
        lock_tolerant(&shared.handles).push(handle);
    }
}

/// Handle to the sharded serving engine. See the module docs.
pub struct ServingPool {
    shared: Arc<PoolShared>,
    supervisor: Option<JoinHandle<()>>,
    service: Option<Arc<SharedCompileService>>,
    autotune_stop: Option<Arc<AtomicBool>>,
    autotune_thread: Option<JoinHandle<()>>,
}

impl ServingPool {
    /// Start the pool. When [`ServerConfig::compile`] is set, one
    /// [`SharedCompileService`] is created from its pipeline config and
    /// shared by every worker.
    pub fn start(artifact_dir: &Path, cfg: ServerConfig, pool: PoolConfig) -> Result<Self> {
        let service = cfg
            .compile
            .as_ref()
            .map(|o| Arc::new(SharedCompileService::new(o.pipeline.clone())));
        Self::start_inner(artifact_dir, cfg, pool, service)
    }

    /// Start the pool against an existing shared service (e.g. one
    /// pre-warmed by an offline compile job, or shared across pools).
    /// As with [`super::server::ServingCoordinator::start_with_service`],
    /// the *service's* pipeline config governs every compile.
    pub fn start_with_service(
        artifact_dir: &Path,
        cfg: ServerConfig,
        pool: PoolConfig,
        service: Arc<SharedCompileService>,
    ) -> Result<Self> {
        Self::start_inner(artifact_dir, cfg, pool, Some(service))
    }

    fn start_inner(
        artifact_dir: &Path,
        cfg: ServerConfig,
        pool: PoolConfig,
        service: Option<Arc<SharedCompileService>>,
    ) -> Result<Self> {
        cfg.validate()?;
        if pool.queue_depth == 0 {
            return Err(anyhow!("queue_depth must be >= 1"));
        }
        let n = pool.resolved_workers();
        // Divide the machine between the shards: each worker's stitched
        // VM gets its share of the cores, so N shards × T VM threads
        // never oversubscribes (a lone worker still goes wide).
        let vm_threads = (crate::exec::par::default_threads() / n).max(1);
        // Parse the artifact exactly once; every worker shares it. This
        // also fails fast — before any thread spawns — on a missing or
        // malformed artifact.
        let program = Engine::parse_artifact(artifact_dir, &cfg.artifact)
            .with_context(|| format!("loading artifact {:?}", cfg.artifact))?;
        // Wire the shared service into the fault plan so injected
        // compile failures flow through the negative cache like real
        // ones.
        if let (Some(svc), Some(plan)) = (&service, &cfg.faults) {
            svc.set_fault_plan(Some(plan.clone()));
        }
        let backend = service.clone().map(CompileBackend::Shared);
        let (sup_tx, sup_rx) = mpsc::channel::<usize>();
        let mut shards = Vec::with_capacity(n);
        let mut inboxes = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = mpsc::sync_channel::<Request>(pool.queue_depth);
            let live = Arc::new(Mutex::new(WorkerStats::default()));
            shards.push(Shard {
                state: Mutex::new(ShardState {
                    tx: Some(tx),
                    live: live.clone(),
                    respawns_left: pool.max_respawns,
                }),
                done: Mutex::new(WorkerStats::default()),
                depth: Arc::new(AtomicU64::new(0)),
            });
            inboxes.push((rx, live));
        }
        let shared = Arc::new(PoolShared {
            shards,
            cfg,
            dir: artifact_dir.to_path_buf(),
            program,
            backend,
            queue_depth: pool.queue_depth,
            vm_threads,
            stopping: AtomicBool::new(false),
            respawns: AtomicU64::new(0),
            reroutes: AtomicU64::new(0),
            handles: Mutex::new(Vec::new()),
            sup_tx: Mutex::new(Some(sup_tx)),
        });
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        {
            let mut handles = lock_tolerant(&shared.handles);
            for (idx, (rx, live)) in inboxes.into_iter().enumerate() {
                handles.push(spawn_worker(&shared, idx, rx, live, Some(ready_tx.clone())));
            }
        }
        drop(ready_tx);
        // Fail fast if any shard failed to come up; tear the healthy
        // ones down before returning the error.
        let mut startup: Result<()> = Ok(());
        for _ in 0..n {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    startup = Err(e);
                    break;
                }
                Err(_) => {
                    startup = Err(anyhow!("worker died during startup"));
                    break;
                }
            }
        }
        if let Err(e) = startup {
            shared.stopping.store(true, Ordering::SeqCst);
            *lock_tolerant(&shared.sup_tx) = None;
            for shard in &shared.shards {
                lock_tolerant(&shard.state).tx = None;
            }
            let handles: Vec<_> = lock_tolerant(&shared.handles).drain(..).collect();
            for h in handles {
                let _ = h.join();
            }
            return Err(e);
        }
        let supervisor = {
            let shared = shared.clone();
            std::thread::spawn(move || supervise(shared, sup_rx))
        };
        // Feedback loop: a background thread writes measured launch
        // times back into the perf library and re-explores under the
        // measured oracle; a changed plan hot-swaps via the cache
        // generation (workers re-resolve on their next batch).
        let (autotune_stop, autotune_thread) =
            match (&pool.autotune, &service, &shared.cfg.compile) {
                (Some(at), Some(svc), Some(opts)) => {
                    let stop = Arc::new(AtomicBool::new(false));
                    let tstop = stop.clone();
                    let tsvc = svc.clone();
                    let module = opts.module.clone();
                    let mode = opts.mode;
                    let at = at.clone();
                    let handle = std::thread::spawn(move || {
                        let mut seen_epoch = 0u64;
                        while !tstop.load(Ordering::Relaxed) {
                            std::thread::sleep(at.interval);
                            if tstop.load(Ordering::Relaxed) {
                                break;
                            }
                            // Write-back: fold the resident module's launch
                            // spans into the library's measured entries.
                            if let Some(current) = tsvc.probe(&module, mode) {
                                let snap = current.profile.snapshot();
                                if snap.total_launches() >= at.min_launches {
                                    tsvc.absorb_profile(&snap);
                                }
                            }
                            // Re-explore only when the measured picture
                            // actually moved since the last pass.
                            let epoch = tsvc.measured_epoch();
                            if epoch != 0 && epoch != seen_epoch {
                                seen_epoch = epoch;
                                let _ = tsvc.reexplore_and_swap(&module, mode);
                            }
                        }
                    });
                    (Some(stop), Some(handle))
                }
                _ => (None, None),
            };
        Ok(ServingPool {
            shared,
            supervisor: Some(supervisor),
            service,
            autotune_stop,
            autotune_thread,
        })
    }

    pub fn config(&self) -> &ServerConfig {
        &self.shared.cfg
    }

    /// The shared compile service behind the pool (`None` without
    /// [`ServerConfig::compile`]).
    pub fn compile_service(&self) -> Option<&Arc<SharedCompileService>> {
        self.service.as_ref()
    }

    /// Which shard serves `shape_key` — sticky and deterministic, so a
    /// shape's traffic always lands on the same worker. The SplitMix64
    /// finalizer spreads consecutive keys (shape keys are often input
    /// lengths) uniformly over shards.
    pub fn route(&self, shape_key: u64) -> usize {
        (super::metrics::splitmix64(shape_key) % self.shared.shards.len() as u64) as usize
    }

    fn request(
        &self,
        input: Vec<f32>,
        shape_key: u64,
        deadline: Option<Duration>,
    ) -> (Request, mpsc::Receiver<Result<Vec<f32>>>) {
        let (rtx, rrx) = mpsc::channel();
        let enqueued = Instant::now();
        let deadline = deadline
            .or_else(|| self.shared.cfg.deadline.as_ref().and_then(|d| d.default_deadline))
            .map(|d| enqueued + d);
        (Request { input, shape_key, respond: rtx, enqueued, deadline }, rrx)
    }

    /// Deliver `req` to its sticky shard, rerouting past down shards.
    ///
    /// Probing starts at the key's primary shard and walks the ring; a
    /// shard without a live channel (mid-respawn or budget-exhausted)
    /// is skipped and the landing on a non-primary shard counts as a
    /// reroute. A *full* queue is backpressure, not death: blocking
    /// submission waits on the primary shard, non-blocking submission
    /// sheds with a structured [`Rejection::Shed`] — neither violates
    /// sticky routing for a merely-busy shard.
    fn submit(&self, mut req: Request, blocking: bool) -> Result<()> {
        let n = self.shared.shards.len();
        let primary = self.route(req.shape_key);
        for probe in 0..n {
            let idx = (primary + probe) % n;
            let shard = &self.shared.shards[idx];
            let tx = match lock_tolerant(&shard.state).tx.clone() {
                Some(tx) => tx,
                None => continue,
            };
            // Gauge before sending so the worker's decrement can never
            // observe the increment missing (transient overcount only).
            shard.depth.fetch_add(1, Ordering::Relaxed);
            let outcome = if blocking {
                tx.send(req).map_err(|mpsc::SendError(r)| (r, false))
            } else {
                match tx.try_send(req) {
                    Ok(()) => Ok(()),
                    Err(TrySendError::Full(r)) => Err((r, true)),
                    Err(TrySendError::Disconnected(r)) => Err((r, false)),
                }
            };
            match outcome {
                Ok(()) => {
                    if probe > 0 {
                        self.shared.reroutes.fetch_add(1, Ordering::Relaxed);
                    }
                    return Ok(());
                }
                Err((r, full)) => {
                    shard.depth.fetch_sub(1, Ordering::Relaxed);
                    if full {
                        return Err(Error::new(Rejection::Shed)
                            .context(format!("backpressure: worker {idx} queue is full")));
                    }
                    // Disconnected mid-submit (the worker died between
                    // the state read and the send): recover the request
                    // and probe the next shard.
                    req = r;
                }
            }
        }
        Err(anyhow!("no live worker shard available ({n} shards down or stopping)"))
    }

    /// Submit one request and block for its output (backpressure: the
    /// submission itself blocks while the shard's queue is full).
    /// Returns the output and the end-to-end latency. The shape key is
    /// derived from the input length ([`ServerConfig::shape_key_for`]:
    /// the bucket key under [`ServerConfig::buckets`], the exact length
    /// otherwise).
    pub fn infer(&self, input: Vec<f32>) -> Result<(Vec<f32>, Duration)> {
        let key = self.shared.cfg.shape_key_for(input.len());
        self.infer_keyed(key, input)
    }

    /// [`ServingPool::infer`] with an explicit per-request deadline:
    /// the request is answered within `deadline` or shed with a
    /// structured [`Rejection::DeadlineInfeasible`] (slack admission
    /// requires [`ServerConfig::deadline`] to be set; without a policy
    /// the deadline is recorded but never sheds).
    pub fn infer_with_deadline(
        &self,
        input: Vec<f32>,
        deadline: Duration,
    ) -> Result<(Vec<f32>, Duration)> {
        let key = self.shared.cfg.shape_key_for(input.len());
        self.infer_keyed_with_deadline(key, input, deadline)
    }

    /// [`ServingPool::infer`] with an explicit shape key (e.g. a
    /// truncated module fingerprint for multi-model traffic). Under
    /// [`ServerConfig::buckets`] the key is an explicit *bucket claim*
    /// and is validated worker-side: a row longer than the claimed
    /// bucket's canonical length is rejected, not trusted.
    pub fn infer_keyed(&self, shape_key: u64, input: Vec<f32>) -> Result<(Vec<f32>, Duration)> {
        let enqueued = Instant::now();
        let rrx = self.infer_keyed_async_with_deadline(shape_key, input, None)?;
        let out = rrx.recv().context("worker dropped response")??;
        Ok((out, enqueued.elapsed()))
    }

    /// [`ServingPool::infer_keyed`] with an explicit per-request
    /// deadline.
    pub fn infer_keyed_with_deadline(
        &self,
        shape_key: u64,
        input: Vec<f32>,
        deadline: Duration,
    ) -> Result<(Vec<f32>, Duration)> {
        let enqueued = Instant::now();
        let rrx = self.infer_keyed_async_with_deadline(shape_key, input, Some(deadline))?;
        let out = rrx.recv().context("worker dropped response")??;
        Ok((out, enqueued.elapsed()))
    }

    /// Submit asynchronously; the caller holds the response channel.
    pub fn infer_async(&self, input: Vec<f32>) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        let key = self.shared.cfg.shape_key_for(input.len());
        self.infer_keyed_async_with_deadline(key, input, None)
    }

    /// Async submit with an explicit shape key. Blocks while the
    /// shard's bounded queue is full.
    pub fn infer_keyed_async(
        &self,
        shape_key: u64,
        input: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        self.infer_keyed_async_with_deadline(shape_key, input, None)
    }

    /// Async submit with an explicit shape key and optional deadline
    /// (`None` falls back to [`DeadlinePolicy::default_deadline`] when
    /// a policy is configured).
    ///
    /// [`DeadlinePolicy::default_deadline`]: super::server::DeadlinePolicy::default_deadline
    pub fn infer_keyed_async_with_deadline(
        &self,
        shape_key: u64,
        input: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        let (req, rrx) = self.request(input, shape_key, deadline);
        self.submit(req, true)?;
        Ok(rrx)
    }

    /// Non-blocking submit: fails fast with a "backpressure" error when
    /// the shard's queue is full, so callers can shed load instead of
    /// stalling. A *down* shard (unlike a busy one) reroutes to the
    /// next live shard.
    pub fn try_infer_async(
        &self,
        shape_key: u64,
        input: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        let (req, rrx) = self.request(input, shape_key, None);
        self.submit(req, false)?;
        Ok(rrx)
    }

    /// Merge every worker's latest snapshot (plus the shared cache's
    /// counters) into one [`ServingStats`] — readable while the pool
    /// is live; workers refresh their snapshot after every batch.
    /// Per-shard entries fold finished incarnations' counters together
    /// with the live incarnation's.
    pub fn stats(&self) -> ServingStats {
        let mut per_worker = Vec::with_capacity(self.shared.shards.len());
        let mut queue_depths = Vec::with_capacity(self.shared.shards.len());
        let mut shards_down = 0;
        for shard in &self.shared.shards {
            // Lock order: done → state → live (see [`Shard`]). Holding
            // `done` across the live read keeps the fold-on-exit path
            // from being double-counted or missed mid-read.
            let done = lock_tolerant(&shard.done);
            let state = lock_tolerant(&shard.state);
            if state.tx.is_none() {
                shards_down += 1;
            }
            let live = lock_tolerant(&state.live).clone();
            drop(state);
            let mut w = done.clone();
            drop(done);
            w.merge(&live);
            per_worker.push(w);
            queue_depths.push(shard.depth.load(Ordering::Relaxed));
        }
        let mut stats = Self::merged(per_worker, self.service.as_deref());
        stats.respawns = self.shared.respawns.load(Ordering::Relaxed);
        stats.reroutes = self.shared.reroutes.load(Ordering::Relaxed);
        stats.queue_depths = queue_depths;
        stats.shards_down = shards_down;
        stats
    }

    fn merged(per_worker: Vec<WorkerStats>, service: Option<&SharedCompileService>) -> ServingStats {
        let mut aggregate = WorkerStats::default();
        for w in &per_worker {
            aggregate.merge(w);
        }
        ServingStats {
            per_worker,
            aggregate,
            cache: service.map(SharedCompileService::stats),
            cold_compiles: service.map(SharedCompileService::cold_compiles),
            generation: service.map(SharedCompileService::generation),
            respawns: 0,
            reroutes: 0,
            queue_depths: Vec::new(),
            shards_down: 0,
            compile_fast_fails: service.map(SharedCompileService::compile_fast_fails),
        }
    }

    /// Tear the serving machinery down in dependency order: stop the
    /// autotuner, tell the supervisor to stop respawning, close every
    /// shard's queue (workers drain what's left and exit), join the
    /// supervisor, then join every worker incarnation. Idempotent —
    /// [`ServingPool::shutdown`] calls it and `Drop` calls it again
    /// harmlessly.
    fn teardown(&mut self) {
        if let Some(stop) = self.autotune_stop.take() {
            stop.store(true, Ordering::Relaxed);
        }
        if let Some(handle) = self.autotune_thread.take() {
            let _ = handle.join();
        }
        self.shared.stopping.store(true, Ordering::SeqCst);
        *lock_tolerant(&self.shared.sup_tx) = None;
        for shard in &self.shared.shards {
            lock_tolerant(&shard.state).tx = None;
        }
        if let Some(sup) = self.supervisor.take() {
            let _ = sup.join();
        }
        // The supervisor may have installed a replacement channel while
        // we were clearing; with the supervisor gone this sweep is
        // final, and the fresh worker drains its (empty) queue and
        // exits like the rest.
        for shard in &self.shared.shards {
            lock_tolerant(&shard.state).tx = None;
        }
        let handles: Vec<_> = lock_tolerant(&self.shared.handles).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }

    /// Stop accepting requests, drain every shard, and return the
    /// final statistics. Worker panics during the run were contained
    /// and respawned, so shutdown itself cannot fail on them; the
    /// `Result` is kept for API stability.
    pub fn shutdown(mut self) -> Result<ServingStats> {
        self.teardown();
        let mut per_worker = Vec::with_capacity(self.shared.shards.len());
        let mut queue_depths = Vec::with_capacity(self.shared.shards.len());
        for shard in &self.shared.shards {
            // Every incarnation has exited and folded into `done`.
            per_worker.push(lock_tolerant(&shard.done).clone());
            queue_depths.push(shard.depth.load(Ordering::Relaxed));
        }
        let mut stats = Self::merged(per_worker, self.service.as_deref());
        stats.respawns = self.shared.respawns.load(Ordering::Relaxed);
        stats.reroutes = self.shared.reroutes.load(Ordering::Relaxed);
        stats.queue_depths = queue_depths;
        Ok(stats)
    }
}

impl Drop for ServingPool {
    /// Dropping the pool mid-load is a graceful shutdown: queues close,
    /// workers drain and answer everything still in flight, threads
    /// join. No client is ever left hanging on `recv`.
    fn drop(&mut self) {
        self.teardown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::testutil::TempDir;

    /// Doubles a [4, 3] batch (batch=4 requests of 3 elements each).
    const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[4,3]{1,0})->(f32[4,3]{1,0})}

ENTRY main {
  p0 = f32[4,3]{1,0} parameter(0)
  sum = f32[4,3]{1,0} add(p0, p0)
  ROOT t = (f32[4,3]{1,0}) tuple(sum)
}
"#;

    fn config() -> ServerConfig {
        ServerConfig {
            artifact: "double".into(),
            batch: 4,
            in_elems_per_request: 3,
            out_elems_per_request: 3,
            input_dims: vec![4, 3],
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
            compile: None,
            trace: None,
            buckets: None,
            deadline: None,
            faults: None,
        }
    }

    fn pool(dir: &TempDir, workers: usize) -> ServingPool {
        std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
        ServingPool::start(
            dir.path(),
            config(),
            PoolConfig { workers, ..PoolConfig::default() },
        )
        .unwrap()
    }

    #[test]
    fn pool_serves_across_workers() {
        let dir = TempDir::new("pool1");
        let p = pool(&dir, 3);
        // 16 distinct shape keys spread over 3 shards; all must answer.
        let pending: Vec<_> = (0..16u64)
            .map(|k| (k, p.infer_keyed_async(k, vec![k as f32, 1.0, 2.0]).unwrap()))
            .collect();
        for (k, rx) in pending {
            assert_eq!(rx.recv().unwrap().unwrap(), vec![2.0 * k as f32, 2.0, 4.0]);
        }
        let stats = p.shutdown().unwrap();
        assert_eq!(stats.workers(), 3);
        assert_eq!(stats.aggregate.requests, 16);
        // sticky sharding actually spread the keys
        assert!(stats.per_worker.iter().filter(|w| w.requests > 0).count() >= 2);
        // healthy run: nothing respawned or rerouted
        assert_eq!((stats.respawns, stats.reroutes), (0, 0));
    }

    #[test]
    fn routing_is_sticky_and_in_range() {
        let dir = TempDir::new("pool2");
        let p = pool(&dir, 4);
        for key in 0..64u64 {
            let a = p.route(key);
            assert_eq!(a, p.route(key), "routing must be deterministic");
            assert!(a < 4);
        }
        // consecutive keys don't all collapse onto one shard
        let shards: std::collections::HashSet<_> = (0..64u64).map(|k| p.route(k)).collect();
        assert!(shards.len() >= 3, "shards used: {shards:?}");
        p.shutdown().unwrap();
    }

    #[test]
    fn live_stats_are_readable_while_serving() {
        let dir = TempDir::new("pool3");
        let p = pool(&dir, 2);
        for i in 0..6u64 {
            let (out, _) = p.infer_keyed(i, vec![i as f32; 3]).unwrap();
            assert_eq!(out, vec![2.0 * i as f32; 3]);
        }
        // all six answered, so every worker has published its snapshot
        let live = p.stats();
        assert_eq!(live.aggregate.requests, 6);
        assert!(live.aggregate.batches >= 1);
        assert_eq!(live.workers(), 2);
        assert_eq!(live.queue_depths.len(), 2);
        assert_eq!(live.shards_down, 0);
        let fin = p.shutdown().unwrap();
        assert_eq!(fin.aggregate.requests, 6);
    }

    #[test]
    fn backpressure_bounds_the_queue() {
        let dir = TempDir::new("pool4");
        std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
        let mut cfg = config();
        // long batching window so the worker lingers in collection
        cfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(5) };
        let p = ServingPool::start(
            dir.path(),
            cfg,
            PoolConfig { workers: 1, queue_depth: 2, ..PoolConfig::default() },
        )
        .unwrap();
        // Flood one shard with try_send: the bounded queue must refuse
        // at least one submission long before 100k attempts (the worker
        // serves ~µs-scale batches while we submit at ~ns-scale).
        let mut receivers = Vec::new();
        let mut saw_full = false;
        for i in 0..100_000u64 {
            match p.try_infer_async(7, vec![i as f32, 0.0, 0.0]) {
                Ok(rx) => receivers.push(rx),
                Err(e) => {
                    assert!(e.to_string().contains("backpressure"), "got: {e:#}");
                    assert_eq!(
                        e.downcast_ref::<Rejection>(),
                        Some(&Rejection::Shed),
                        "backpressure errors carry the structured shed reason"
                    );
                    saw_full = true;
                    break;
                }
            }
        }
        assert!(saw_full, "bounded queue never pushed back");
        for rx in receivers {
            rx.recv().unwrap().unwrap();
        }
        p.shutdown().unwrap();
    }

    #[test]
    fn oversized_rows_rejected_poolwide() {
        let dir = TempDir::new("pool5");
        let p = pool(&dir, 2);
        let bad = p.infer_keyed(9, vec![0.0; 7]);
        assert!(bad.is_err(), "oversized row must error, not truncate");
        let stats = p.shutdown().unwrap();
        assert_eq!(stats.aggregate.rejected, 1);
        assert_eq!(stats.aggregate.rejects.oversized, 1);
    }
}
