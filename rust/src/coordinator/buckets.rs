//! Shape-class bucketing: the shape-identity abstraction that lets one
//! compiled artifact serve a whole *bucket* of nearby shapes.
//!
//! The exact-shape serving path compiles one artifact per concrete
//! input length, so shape-heterogeneous traffic (NMT sequence lengths,
//! speech frame counts) turns every new length into a cold compile and
//! a shape-pure shard — the production fusion problem the XLA
//! operator-fusion study (arXiv 2301.13062) flags as hardest. A
//! [`BucketPolicy`] maps a concrete row length to a [`ShapeClass`]: a
//! sticky bucket key plus the bucket's *canonical* (padded) length.
//! Every layer of the serving stack then keys on the class instead of
//! the raw length:
//!
//! - the [`crate::coordinator::pool::ServingPool`] routes on the bucket
//!   key, so shards stay bucket-pure instead of shape-pure;
//! - the batcher mixes same-bucket lengths into one batch
//!   ([`crate::coordinator::batcher::next_batch_bucketed`]), padding
//!   each row to the canonical length on the way in and slicing the
//!   live region back out of the output on the way off;
//! - the compile cache keys on the canonical module's fingerprint
//!   ([`crate::hlo::fingerprint::fingerprint_shape_class`]), so all
//!   lengths in a bucket hit one entry and one single-flight cold
//!   compile, with the policy itself folded into the config digest.
//!
//! [`BucketPolicy::Exact`] is the degenerate one-shape-per-bucket
//! policy: canonical length == concrete length, bit-for-bit the
//! historical exact-shape behavior.
//!
//! Whether a shorter row should be *admitted* into a bucket batch (pay
//! modeled padding compute) or demoted to its exact length (pay an
//! extra launch, and possibly a cold compile, later) is the
//! [`BucketAdmission`] check, derived through the
//! [`crate::schedule::CostOracle`] seam.

use crate::gpusim::cost::KernelDesc;
use crate::gpusim::DeviceConfig;
use crate::schedule::CostOracle;
use anyhow::{bail, Result};
use std::fmt;

/// How concrete row lengths on the batch-varying dimension map to
/// buckets. The bucket *key* is the bucket's canonical length, so keys
/// stay meaningful across layers (routing, batching, validation) and
/// the degenerate [`BucketPolicy::Exact`] reproduces the historical
/// `shape_key = input.len()` convention exactly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BucketPolicy {
    /// One shape per bucket: canonical length == concrete length. The
    /// degenerate policy; exact-shape serving, bit for bit.
    Exact,
    /// Round the varying dimension up to the next power of two, with a
    /// floor: lengths at or below `min` share the `min`-sized bucket
    /// (`min` must itself be a power of two).
    PowerOfTwo { min: usize },
    /// Explicit ascending length boundaries: a length lands in the
    /// first boundary that fits it. Lengths above the last boundary
    /// fall back to exact (one-shape) buckets rather than truncating.
    Boundaries(Vec<usize>),
}

impl BucketPolicy {
    /// Reject malformed policies before a serving loop adopts them.
    pub fn validate(&self) -> Result<()> {
        match self {
            BucketPolicy::Exact => Ok(()),
            BucketPolicy::PowerOfTwo { min } => {
                if *min == 0 || !min.is_power_of_two() {
                    bail!("PowerOfTwo bucket floor must be a power of two >= 1, got {min}");
                }
                Ok(())
            }
            BucketPolicy::Boundaries(bs) => {
                if bs.is_empty() {
                    bail!("Boundaries bucket policy needs at least one boundary");
                }
                if bs.windows(2).any(|w| w[0] >= w[1]) {
                    bail!("bucket boundaries must be strictly ascending, got {bs:?}");
                }
                if bs[0] == 0 {
                    bail!("bucket boundaries must be >= 1");
                }
                Ok(())
            }
        }
    }

    /// The canonical (padded) row length of the bucket containing
    /// `len` — what the bucket's artifact is compiled at and what every
    /// member row is padded to.
    pub fn canonical_len(&self, len: usize) -> usize {
        match self {
            BucketPolicy::Exact => len,
            BucketPolicy::PowerOfTwo { min } => len.max(*min).next_power_of_two(),
            BucketPolicy::Boundaries(bs) => {
                bs.iter().copied().find(|&b| b >= len).unwrap_or(len)
            }
        }
    }

    /// The sticky bucket key a request of `len` elements carries in
    /// `Request::shape_key`: the canonical length itself, so routing,
    /// batch purity and engine-side validation all read the same claim.
    pub fn bucket_key(&self, len: usize) -> u64 {
        self.canonical_len(len) as u64
    }

    /// The [`ShapeClass`] of a row of `len` elements, clamped to the
    /// serving contract's maximum row (`max_len`).
    pub fn class_of(&self, len: usize, max_len: usize) -> ShapeClass {
        self.class_of_key(self.bucket_key(len), max_len)
    }

    /// Resolve a *claimed* bucket key (what a request carries — clients
    /// may lie) into the class it names. The canonical length clamps to
    /// the serving contract's maximum row; whether the row actually
    /// fits the class is the engine's admissibility check
    /// ([`crate::runtime::LoadedModel::validate_row`]).
    pub fn class_of_key(&self, key: u64, max_len: usize) -> ShapeClass {
        ShapeClass { bucket: key, canonical_len: (key as usize).min(max_len) }
    }

    /// Deterministic digest of the policy — folded into the compile
    /// cache's config digest so artifacts compiled under different
    /// bucketing never share an entry (see
    /// [`crate::coordinator::cache::CacheKey`]).
    pub fn digest(&self) -> u64 {
        crate::schedule::perf_library::fnv1a(format!("{self:?}").as_bytes())
    }
}

impl Default for BucketPolicy {
    fn default() -> Self {
        BucketPolicy::Exact
    }
}

/// A request's shape identity under a bucket policy: the bucket it
/// claims plus the canonical row length every member of that bucket
/// executes at. The admissible range of the class is
/// `0..=canonical_len` — rows are padded *up* to the canonical length,
/// never truncated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShapeClass {
    /// The sticky bucket key (what `Request::shape_key` carries and the
    /// pool routes on).
    pub bucket: u64,
    /// Canonical row length the bucket's artifact is compiled at.
    pub canonical_len: usize,
}

impl ShapeClass {
    /// The degenerate one-shape class of exact-shape serving.
    pub fn exact(len: usize) -> Self {
        ShapeClass { bucket: len as u64, canonical_len: len }
    }

    /// Is a row of `len` elements admissible in this class?
    pub fn admits(&self, len: usize) -> bool {
        len <= self.canonical_len
    }

    /// Padding waste of a row of `len` elements executed at this
    /// class's canonical length, in `[0, 1)`.
    pub fn waste_ratio(&self, len: usize) -> f64 {
        if self.canonical_len == 0 {
            0.0
        } else {
            self.canonical_len.saturating_sub(len) as f64 / self.canonical_len as f64
        }
    }
}

impl fmt::Display for ShapeClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "bucket {} (canonical length {})", self.bucket, self.canonical_len)
    }
}

/// The modeled padding-waste vs. launch/compile-cost check of the
/// bucketed batcher: admit a shorter row into a bucket batch (pay
/// `wasted_elems × per_elem_us` of modeled padding compute) or demote
/// it to its exact length (pay one extra kernel launch — and possibly
/// a cold compile — when its exact batch ships later)?
///
/// Derived through the [`CostOracle`] seam
/// ([`BucketAdmission::from_oracle`]): the per-element cost comes from
/// the oracle's kernel-time estimate for one canonical batch, the
/// launch overhead from the device constants. The [`Default`] is fully
/// permissive (zero modeled padding cost), matching a policy of
/// "always pad" when no cost model is configured.
#[derive(Debug, Clone)]
pub struct BucketAdmission {
    /// Modeled cost of serving a demoted row in its own batch later:
    /// one kernel launch of overhead, µs.
    pub launch_overhead_us: f64,
    /// Modeled compute cost of one padded element, µs.
    pub per_elem_us: f64,
    /// Hard cap on an admitted row's padding-waste ratio, regardless of
    /// the cost comparison.
    pub max_waste_ratio: f64,
}

impl Default for BucketAdmission {
    fn default() -> Self {
        BucketAdmission { launch_overhead_us: 4.0, per_elem_us: 0.0, max_waste_ratio: 1.0 }
    }
}

impl BucketAdmission {
    /// Derive the admission constants from a cost oracle and device
    /// model, for batches of `batch × canonical_len` f32 elements. Any
    /// [`CostOracle`] works — the serving loop passes the modeled
    /// oracle; a measured overlay sharpens the estimate where samples
    /// exist.
    pub fn from_oracle(
        oracle: &dyn CostOracle,
        dev: &DeviceConfig,
        batch: usize,
        canonical_len: usize,
    ) -> Self {
        let elems = (batch * canonical_len).max(1) as u64;
        let desc = KernelDesc {
            bytes_read: elems * 4,
            bytes_written: elems * 4,
            flops: elems,
            blocks: elems.div_ceil(256).max(1),
            threads: 256,
            smem_bytes: 0,
            coalescing: 1.0,
            op_weight: 1.0,
        };
        let exec_us = (oracle.kernel_time_us(&desc, dev) - dev.launch_overhead_us).max(0.0);
        BucketAdmission {
            launch_overhead_us: dev.launch_overhead_us,
            per_elem_us: exec_us / elems as f64,
            max_waste_ratio: 1.0,
        }
    }

    /// Modeled wall time of one batch of `elems` assembled elements,
    /// µs: the launch overhead plus the per-element compute cost. The
    /// slack-admission path ([`crate::coordinator::server::DeadlinePolicy`])
    /// can use this as its bootstrap service estimate when the worker
    /// has neither measurements nor a compiled module's timing yet —
    /// the same constants that decide *padding* admission then also
    /// bound *deadline* admission, so the two checks never disagree
    /// about what a batch costs.
    pub fn predicted_batch_us(&self, elems: usize) -> f64 {
        self.launch_overhead_us + self.per_elem_us * elems as f64
    }

    /// Admit a row of `len` elements into a batch executing at
    /// `canonical_len`? Rows that fill the row (no waste) are always
    /// admitted; otherwise padding must be modeled cheaper than the
    /// extra launch a demotion costs, and under the waste cap.
    pub fn admits(&self, len: usize, canonical_len: usize) -> bool {
        let wasted = canonical_len.saturating_sub(len);
        if wasted == 0 {
            return true;
        }
        let ratio = wasted as f64 / canonical_len.max(1) as f64;
        ratio <= self.max_waste_ratio && wasted as f64 * self.per_elem_us <= self.launch_overhead_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::ModeledCost;

    #[test]
    fn exact_policy_is_the_identity() {
        let p = BucketPolicy::Exact;
        p.validate().unwrap();
        for len in [0usize, 1, 3, 17, 4096] {
            assert_eq!(p.canonical_len(len), len);
            assert_eq!(p.bucket_key(len), len as u64);
        }
        let class = p.class_of(17, 4096);
        assert_eq!(class, ShapeClass::exact(17));
        assert!(class.admits(17) && !class.admits(18));
        assert_eq!(class.waste_ratio(17), 0.0);
    }

    #[test]
    fn power_of_two_rounds_up_with_floor() {
        let p = BucketPolicy::PowerOfTwo { min: 16 };
        p.validate().unwrap();
        assert_eq!(p.canonical_len(1), 16);
        assert_eq!(p.canonical_len(16), 16);
        assert_eq!(p.canonical_len(17), 32);
        assert_eq!(p.canonical_len(32), 32);
        assert_eq!(p.canonical_len(33), 64);
        assert_eq!(p.canonical_len(100), 128);
        // 17 and 23 share one bucket; 33 sits in the next
        assert_eq!(p.bucket_key(17), p.bucket_key(23));
        assert_ne!(p.bucket_key(17), p.bucket_key(33));
    }

    #[test]
    fn boundaries_take_first_fit_and_fall_back_to_exact() {
        let p = BucketPolicy::Boundaries(vec![8, 24, 48]);
        p.validate().unwrap();
        assert_eq!(p.canonical_len(5), 8);
        assert_eq!(p.canonical_len(8), 8);
        assert_eq!(p.canonical_len(9), 24);
        assert_eq!(p.canonical_len(48), 48);
        // beyond the last boundary: exact, never truncated
        assert_eq!(p.canonical_len(50), 50);
    }

    #[test]
    fn malformed_policies_rejected() {
        assert!(BucketPolicy::PowerOfTwo { min: 0 }.validate().is_err());
        assert!(BucketPolicy::PowerOfTwo { min: 12 }.validate().is_err());
        assert!(BucketPolicy::Boundaries(vec![]).validate().is_err());
        assert!(BucketPolicy::Boundaries(vec![8, 8]).validate().is_err());
        assert!(BucketPolicy::Boundaries(vec![24, 8]).validate().is_err());
        assert!(BucketPolicy::Boundaries(vec![0, 8]).validate().is_err());
    }

    #[test]
    fn class_of_key_clamps_to_the_contract() {
        let p = BucketPolicy::PowerOfTwo { min: 16 };
        // a claimed bucket larger than the contract's maximum row clamps
        let class = p.class_of_key(1 << 20, 128);
        assert_eq!(class.canonical_len, 128);
        assert_eq!(class.bucket, 1 << 20);
        // honest keys resolve to their own bucket
        let class = p.class_of(40, 128);
        assert_eq!((class.bucket, class.canonical_len), (64, 64));
        assert!((class.waste_ratio(40) - 24.0 / 64.0).abs() < 1e-12);
    }

    #[test]
    fn policy_digest_distinguishes_policies() {
        let a = BucketPolicy::Exact.digest();
        let b = BucketPolicy::PowerOfTwo { min: 16 }.digest();
        let c = BucketPolicy::PowerOfTwo { min: 32 }.digest();
        let d = BucketPolicy::Boundaries(vec![8, 24]).digest();
        let all = [a, b, c, d];
        for i in 0..all.len() {
            for j in i + 1..all.len() {
                assert_ne!(all[i], all[j], "digests {i} and {j} collide");
            }
        }
        assert_eq!(a, BucketPolicy::Exact.digest(), "digest must be deterministic");
    }

    #[test]
    fn admission_trades_padding_against_launch_overhead() {
        // Permissive default: everything pads.
        let free = BucketAdmission::default();
        assert!(free.admits(1, 1 << 20));
        // Expensive padding: a row wasting more than the launch
        // overhead's worth of modeled compute is demoted.
        let tight =
            BucketAdmission { launch_overhead_us: 4.0, per_elem_us: 1.0, max_waste_ratio: 1.0 };
        assert!(tight.admits(62, 64), "2 wasted elements cost 2us < 4us launch");
        assert!(!tight.admits(32, 64), "32 wasted elements cost 32us > 4us launch");
        assert!(tight.admits(64, 64), "full rows always admit");
        // The hard waste cap binds even when padding is modeled cheap.
        let capped =
            BucketAdmission { launch_overhead_us: 4.0, per_elem_us: 0.0, max_waste_ratio: 0.25 };
        assert!(capped.admits(48, 64));
        assert!(!capped.admits(47, 64));
    }

    #[test]
    fn oracle_derived_admission_is_finite_and_permissive_for_small_buckets() {
        let dev = DeviceConfig::pascal();
        let adm = BucketAdmission::from_oracle(&ModeledCost, &dev, 4, 128);
        assert!(adm.per_elem_us.is_finite() && adm.per_elem_us >= 0.0);
        assert_eq!(adm.launch_overhead_us, dev.launch_overhead_us);
        // For small serving buckets the modeled padding cost of a few
        // dozen elements is far below one launch — everything admits.
        assert!(adm.admits(17, 128));
    }
}
