//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] describes *which* faults to inject (failing cold
//! compiles, artificially slow kernels, a worker panic) and *when*
//! (attempt/batch indices, a seed for jitter). The plan itself is plain
//! data and always compiles; the **hooks** the coordinator calls
//! ([`FaultPlan::fire_compile`], [`FaultPlan::fire_execute`],
//! [`FaultPlan::fire_panic_point`], [`FaultPlan::note_batch`]) are real
//! only under the non-default `faults` cargo feature and compile to
//! empty inlined bodies otherwise — production builds carry zero
//! fault-injection overhead.
//!
//! Everything is counted: each hook records how many faults it actually
//! injected, so tests can reconcile observed behavior (respawns, sheds,
//! fast-fails) against the injected ground truth. All state is atomic —
//! one plan is shared by every worker, the compile service, and the
//! test's assertions.
//!
//! The CLI accepts a plan as `--faults
//! "compile_fail=2,slow_from=16,slow_count=8,slow_us=200,panic_at=12,seed=42"`
//! (see [`FaultPlan::parse`]).

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// A window of artificially slow batches: every batch whose global
/// index falls in `[from_batch, from_batch + count)` sleeps for
/// `delay_us` plus seeded jitter before executing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlowKernels {
    /// First global batch index (see [`FaultPlan::note_batch`]) to slow.
    pub from_batch: u64,
    /// How many batches from `from_batch` on are slowed.
    pub count: u64,
    /// Base injected delay, microseconds.
    pub delay_us: u64,
    /// Upper bound on seeded per-batch jitter, microseconds (0 = none).
    pub jitter_us: u64,
}

/// A seeded, deterministic fault schedule. Construct with
/// [`FaultPlan::new`] + builder methods or [`FaultPlan::parse`], share
/// via `Arc` through `ServerConfig::faults`.
#[derive(Debug, Default)]
pub struct FaultPlan {
    seed: u64,
    /// Fail this many cold compile attempts before letting one succeed.
    compile_fail_first: u64,
    slow: Option<SlowKernels>,
    /// Panic one worker once its shard has executed this many batches.
    panic_after_batches: Option<u64>,

    // Live counters (shared across all holders of the plan).
    compile_attempts: AtomicU64,
    batches: AtomicU64,
    injected_compile_fails: AtomicU64,
    injected_slow: AtomicU64,
    injected_panics: AtomicU64,
    panicked: AtomicBool,
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..FaultPlan::default() }
    }

    /// Fail the first `n` cold compile attempts with an injected error.
    pub fn fail_compiles(mut self, n: u64) -> Self {
        self.compile_fail_first = n;
        self
    }

    /// Slow `count` batches starting at global batch `from`, by
    /// `delay_us` (+ up to `jitter_us` of seeded jitter) each.
    pub fn slow_kernels(mut self, from: u64, count: u64, delay_us: u64, jitter_us: u64) -> Self {
        self.slow = Some(SlowKernels { from_batch: from, count, delay_us, jitter_us });
        self
    }

    /// Panic one worker (exactly once, pool-wide) after `batches`
    /// batches have executed.
    pub fn panic_after(mut self, batches: u64) -> Self {
        self.panic_after_batches = Some(batches);
        self
    }

    /// Parse a comma-separated `key=value` spec, e.g.
    /// `"compile_fail=2,slow_from=16,slow_count=8,slow_us=200,panic_at=12,seed=42"`.
    /// Keys: `seed`, `compile_fail`, `slow_from`, `slow_count`,
    /// `slow_us`, `slow_jitter_us`, `panic_at`.
    pub fn parse(spec: &str) -> anyhow::Result<FaultPlan> {
        let mut plan = FaultPlan::new(0);
        let mut slow = SlowKernels { from_batch: 0, count: 0, delay_us: 0, jitter_us: 0 };
        let mut any_slow = false;
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault spec entry `{part}` is not key=value"))?;
            let n: u64 = v
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("fault spec `{part}`: bad number ({e})"))?;
            match k.trim() {
                "seed" => plan.seed = n,
                "compile_fail" => plan.compile_fail_first = n,
                "slow_from" => {
                    slow.from_batch = n;
                    any_slow = true;
                }
                "slow_count" => {
                    slow.count = n;
                    any_slow = true;
                }
                "slow_us" => {
                    slow.delay_us = n;
                    any_slow = true;
                }
                "slow_jitter_us" => {
                    slow.jitter_us = n;
                    any_slow = true;
                }
                "panic_at" => plan.panic_after_batches = Some(n),
                other => anyhow::bail!(
                    "unknown fault spec key `{other}` (expected seed, compile_fail, \
                     slow_from, slow_count, slow_us, slow_jitter_us, panic_at)"
                ),
            }
        }
        if any_slow {
            plan.slow = Some(slow);
        }
        Ok(plan)
    }

    /// `true` when this build actually injects faults (`faults`
    /// feature); `false` when the hooks are compiled-out no-ops.
    pub fn enabled() -> bool {
        cfg!(feature = "faults")
    }

    // -- counters (always available, so reconcile assertions and the
    //    CLI summary compile in every build) ---------------------------

    pub fn injected_compile_fails(&self) -> u64 {
        self.injected_compile_fails.load(Ordering::Relaxed)
    }

    /// Total cold compile attempts the hook has seen (injected failures
    /// and pass-throughs alike).
    pub fn compile_attempts(&self) -> u64 {
        self.compile_attempts.load(Ordering::Relaxed)
    }

    pub fn injected_slow(&self) -> u64 {
        self.injected_slow.load(Ordering::Relaxed)
    }

    pub fn injected_panics(&self) -> u64 {
        self.injected_panics.load(Ordering::Relaxed)
    }

    pub fn batches_noted(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn has_panicked(&self) -> bool {
        self.panicked.load(Ordering::Relaxed)
    }

    // -- hooks ---------------------------------------------------------

    /// Called by the compile service's leader before running a real
    /// cold compile. Fails the first `compile_fail` attempts.
    #[cfg(feature = "faults")]
    pub fn fire_compile(&self) -> anyhow::Result<()> {
        let attempt = self.compile_attempts.fetch_add(1, Ordering::Relaxed);
        if attempt < self.compile_fail_first {
            self.injected_compile_fails.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!(
                "injected compile fault (attempt {} of {} scheduled failures, seed {})",
                attempt + 1,
                self.compile_fail_first,
                self.seed
            );
        }
        Ok(())
    }

    #[cfg(not(feature = "faults"))]
    #[inline(always)]
    pub fn fire_compile(&self) -> anyhow::Result<()> {
        Ok(())
    }

    /// Called by the worker immediately before executing a batch:
    /// sleeps when the global batch index falls in the slow window.
    #[cfg(feature = "faults")]
    pub fn fire_execute(&self) {
        if let Some(s) = self.slow {
            let b = self.batches.load(Ordering::Relaxed);
            if b >= s.from_batch && b < s.from_batch.saturating_add(s.count) {
                self.injected_slow.fetch_add(1, Ordering::Relaxed);
                let jitter = if s.jitter_us == 0 {
                    0
                } else {
                    super::metrics::splitmix64(self.seed ^ b) % s.jitter_us
                };
                std::thread::sleep(std::time::Duration::from_micros(s.delay_us + jitter));
            }
        }
    }

    #[cfg(not(feature = "faults"))]
    #[inline(always)]
    pub fn fire_execute(&self) {}

    /// Called by the worker at the top of its loop, *before* collecting
    /// a batch — so an injected panic never takes in-hand requests down
    /// with it; the supervisor's drain only has to cover the queue.
    /// Panics exactly once pool-wide.
    #[cfg(feature = "faults")]
    pub fn fire_panic_point(&self) {
        if let Some(at) = self.panic_after_batches {
            if self.batches.load(Ordering::Relaxed) >= at
                && !self.panicked.swap(true, Ordering::SeqCst)
            {
                self.injected_panics.fetch_add(1, Ordering::Relaxed);
                panic!("injected worker panic after {at} batches (seed {})", self.seed);
            }
        }
    }

    #[cfg(not(feature = "faults"))]
    #[inline(always)]
    pub fn fire_panic_point(&self) {}

    /// Called by the worker after each executed batch; advances the
    /// global batch index that `slow_from`/`panic_at` are relative to.
    #[cfg(feature = "faults")]
    pub fn note_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    #[cfg(not(feature = "faults"))]
    #[inline(always)]
    pub fn note_batch(&self) {}
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "FaultPlan(seed={}", self.seed)?;
        if self.compile_fail_first > 0 {
            write!(f, ", compile_fail={}", self.compile_fail_first)?;
        }
        if let Some(s) = self.slow {
            write!(
                f,
                ", slow[{}..{}]={}us(+{}us jitter)",
                s.from_batch,
                s.from_batch.saturating_add(s.count),
                s.delay_us,
                s.jitter_us
            )?;
        }
        if let Some(at) = self.panic_after_batches {
            write!(f, ", panic_at={at}")?;
        }
        write!(f, ", {})", if Self::enabled() { "armed" } else { "hooks compiled out" })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_every_key() {
        let plan = FaultPlan::parse(
            "compile_fail=2, slow_from=16, slow_count=8, slow_us=200, \
             slow_jitter_us=50, panic_at=12, seed=42",
        )
        .unwrap();
        assert_eq!(plan.compile_fail_first, 2);
        assert_eq!(
            plan.slow,
            Some(SlowKernels { from_batch: 16, count: 8, delay_us: 200, jitter_us: 50 })
        );
        assert_eq!(plan.panic_after_batches, Some(12));
        assert_eq!(plan.seed, 42);
        let shown = plan.to_string();
        assert!(shown.contains("compile_fail=2"), "{shown}");
        assert!(shown.contains("panic_at=12"), "{shown}");
    }

    #[test]
    fn parse_rejects_unknown_keys_and_bad_numbers() {
        assert!(FaultPlan::parse("explode=1").is_err());
        assert!(FaultPlan::parse("panic_at=soon").is_err());
        assert!(FaultPlan::parse("panic_at").is_err());
        assert!(FaultPlan::parse("").unwrap().panic_after_batches.is_none());
    }

    #[cfg(feature = "faults")]
    #[test]
    fn compile_hook_fails_exactly_the_first_n_attempts() {
        let plan = FaultPlan::new(1).fail_compiles(2);
        assert!(plan.fire_compile().is_err());
        assert!(plan.fire_compile().is_err());
        assert!(plan.fire_compile().is_ok());
        assert!(plan.fire_compile().is_ok());
        assert_eq!(plan.injected_compile_fails(), 2);
    }

    #[cfg(feature = "faults")]
    #[test]
    fn panic_point_fires_exactly_once() {
        let plan = FaultPlan::new(0).panic_after(0);
        let caught =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| plan.fire_panic_point()));
        assert!(caught.is_err(), "armed panic point must fire");
        // Second call must NOT panic again.
        plan.fire_panic_point();
        assert_eq!(plan.injected_panics(), 1);
        assert!(plan.has_panicked());
    }

    #[cfg(not(feature = "faults"))]
    #[test]
    fn hooks_are_inert_without_the_feature() {
        let plan = FaultPlan::new(0).fail_compiles(10).panic_after(0);
        assert!(plan.fire_compile().is_ok());
        plan.fire_panic_point();
        plan.fire_execute();
        plan.note_batch();
        assert_eq!(plan.injected_compile_fails(), 0);
        assert_eq!(plan.injected_panics(), 0);
        assert_eq!(plan.batches_noted(), 0);
        assert!(!FaultPlan::enabled());
    }
}
