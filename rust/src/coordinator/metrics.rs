//! Latency/throughput accounting for the serving loop, plus the
//! per-pass compile-time instrumentation recorded by
//! [`crate::coordinator::driver::PassManager`] and the launch-count
//! accounting of the execution backends.

use std::fmt;
use std::time::Duration;

/// Executed kernel-launch counters, re-exported here because serving
/// stats ([`crate::coordinator::server::WorkerStats`]) report them next
/// to latency: `generated` vs `library` launches per Fig. 7.
pub use crate::exec::LaunchLedger;

/// One serving run's launch efficiency: executed launches per request —
/// the quantity deep fusion shrinks (Fig. 7, measured not estimated).
pub fn launches_per_request(ledger: &LaunchLedger, requests: usize) -> f64 {
    if requests == 0 {
        0.0
    } else {
        ledger.total_launches() as f64 / requests as f64
    }
}

/// One instrumented pipeline pass execution: wall time plus the number
/// of work units (kernel-granularity items) before and after. For the
/// fusion pass the unit counts are the unfused vs. fused kernel counts;
/// for emission they are groups in vs. kernel plans out.
#[derive(Debug, Clone)]
pub struct PassRecord {
    pub name: &'static str,
    pub wall_us: f64,
    pub units_before: usize,
    pub units_after: usize,
}

/// The trace of one pipeline run: every pass, in execution order.
#[derive(Debug, Clone, Default)]
pub struct PassTrace {
    pub records: Vec<PassRecord>,
}

impl PassTrace {
    pub fn record(&mut self, name: &'static str, wall_us: f64, before: usize, after: usize) {
        self.records.push(PassRecord { name, wall_us, units_before: before, units_after: after });
    }

    /// Total wall time across all passes, microseconds.
    pub fn total_us(&self) -> f64 {
        self.records.iter().map(|r| r.wall_us).sum()
    }

    /// Wall time of one pass by name (0 if it did not run).
    pub fn pass_us(&self, name: &str) -> f64 {
        self.records.iter().filter(|r| r.name == name).map(|r| r.wall_us).sum()
    }
}

impl fmt::Display for PassTrace {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{:<18} {:>10} {:>8} {:>8}", "pass", "wall_us", "before", "after")?;
        for r in &self.records {
            writeln!(
                f,
                "{:<18} {:>10.1} {:>8} {:>8}",
                r.name, r.wall_us, r.units_before, r.units_after
            )?;
        }
        write!(f, "total {:.1} us", self.total_us())
    }
}

/// Collects request latencies and derives the usual percentiles.
#[derive(Debug, Default, Clone)]
pub struct LatencyRecorder {
    samples_us: Vec<f64>,
}

impl LatencyRecorder {
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_secs_f64() * 1e6);
    }

    pub fn record_us(&mut self, us: f64) {
        self.samples_us.push(us);
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<f64>() / self.samples_us.len() as f64
    }

    /// Percentile in [0, 100], nearest-rank.
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        let mut v = self.samples_us.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * v.len() as f64).ceil().max(1.0) as usize;
        v[rank.min(v.len()) - 1]
    }

    /// Requests per second given the wall-clock window of the run.
    pub fn throughput_rps(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.samples_us.len() as f64 / wall.as_secs_f64()
    }

    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.samples_us.extend_from_slice(&other.samples_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(vals: &[f64]) -> LatencyRecorder {
        let mut r = LatencyRecorder::default();
        for &v in vals {
            r.record_us(v);
        }
        r
    }

    #[test]
    fn mean_and_percentiles() {
        let r = rec(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert!((r.mean_us() - 22.0).abs() < 1e-9);
        assert_eq!(r.percentile_us(50.0), 3.0);
        assert_eq!(r.percentile_us(99.0), 100.0);
        assert_eq!(r.percentile_us(100.0), 100.0);
    }

    #[test]
    fn empty_is_safe() {
        let r = LatencyRecorder::default();
        assert_eq!(r.mean_us(), 0.0);
        assert_eq!(r.percentile_us(50.0), 0.0);
        assert!(r.is_empty());
    }

    #[test]
    fn throughput() {
        let r = rec(&[1.0; 10]);
        assert!((r.throughput_rps(Duration::from_secs(2)) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn merge_combines() {
        let mut a = rec(&[1.0, 2.0]);
        let b = rec(&[3.0]);
        a.merge(&b);
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn launches_per_request_basics() {
        let ledger = LaunchLedger { generated: 6, library: 2, ..Default::default() };
        assert!((launches_per_request(&ledger, 4) - 2.0).abs() < 1e-12);
        assert_eq!(launches_per_request(&ledger, 0), 0.0);
    }

    #[test]
    fn pass_trace_totals_and_lookup() {
        let mut t = PassTrace::default();
        t.record("fusion", 120.0, 40, 12);
        t.record("simulate", 30.0, 12, 12);
        assert_eq!(t.records.len(), 2);
        assert!((t.total_us() - 150.0).abs() < 1e-9);
        assert_eq!(t.pass_us("fusion"), 120.0);
        assert_eq!(t.pass_us("nope"), 0.0);
        let text = t.to_string();
        assert!(text.contains("fusion") && text.contains("total"));
    }
}
