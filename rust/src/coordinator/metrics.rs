//! Latency/throughput accounting for the serving loop, plus the
//! per-pass compile-time instrumentation recorded by
//! [`crate::coordinator::driver::PassManager`] and the launch-count
//! accounting of the execution backends.

use std::fmt;
use std::time::Duration;

/// Executed kernel-launch counters, re-exported here because serving
/// stats ([`crate::coordinator::server::WorkerStats`]) report them next
/// to latency: `generated` vs `library` launches per Fig. 7.
pub use crate::exec::LaunchLedger;

/// One serving run's launch efficiency: executed launches per request —
/// the quantity deep fusion shrinks (Fig. 7, measured not estimated).
pub fn launches_per_request(ledger: &LaunchLedger, requests: usize) -> f64 {
    if requests == 0 {
        0.0
    } else {
        ledger.total_launches() as f64 / requests as f64
    }
}

/// One instrumented pipeline pass execution: wall time plus the number
/// of work units (kernel-granularity items) before and after. For the
/// fusion pass the unit counts are the unfused vs. fused kernel counts;
/// for emission they are groups in vs. kernel plans out.
#[derive(Debug, Clone)]
pub struct PassRecord {
    pub name: &'static str,
    pub wall_us: f64,
    pub units_before: usize,
    pub units_after: usize,
}

/// The trace of one pipeline run: every pass, in execution order.
#[derive(Debug, Clone, Default)]
pub struct PassTrace {
    pub records: Vec<PassRecord>,
}

impl PassTrace {
    pub fn record(&mut self, name: &'static str, wall_us: f64, before: usize, after: usize) {
        self.records.push(PassRecord { name, wall_us, units_before: before, units_after: after });
    }

    /// Total wall time across all passes, microseconds.
    pub fn total_us(&self) -> f64 {
        self.records.iter().map(|r| r.wall_us).sum()
    }

    /// Wall time of one pass by name (0 if it did not run).
    pub fn pass_us(&self, name: &str) -> f64 {
        self.records.iter().filter(|r| r.name == name).map(|r| r.wall_us).sum()
    }
}

impl fmt::Display for PassTrace {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{:<18} {:>10} {:>8} {:>8}", "pass", "wall_us", "before", "after")?;
        for r in &self.records {
            writeln!(
                f,
                "{:<18} {:>10.1} {:>8} {:>8}",
                r.name, r.wall_us, r.units_before, r.units_after
            )?;
        }
        write!(f, "total {:.1} us", self.total_us())
    }
}

/// Reservoir capacity of a [`StreamingSummary`] — enough samples for
/// stable p99 estimates while bounding a long-lived server's memory.
pub const SUMMARY_RESERVOIR: usize = 512;

/// Nearest-rank percentile (`p` in [0, 100]) over an **already sorted**
/// sample set. Callers sort once per snapshot and then read as many
/// percentiles as they need at O(1) each; the previous implementation
/// re-cloned and re-sorted the samples on every call, so printing
/// p50/p95/p99 per worker paid three clone+sorts.
fn percentile_of_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil().max(1.0) as usize;
    sorted[rank.min(sorted.len()) - 1]
}

/// Requests (or any completed unit) per second over a wall-clock
/// window. Free function so bench harnesses and the serving CLI can
/// derive throughput from a plain completion count — the bounded
/// [`StreamingSummary`] replaced the unbounded `LatencyRecorder` that
/// used to carry this as a method.
pub fn throughput_rps(completed: usize, wall: Duration) -> f64 {
    if wall.is_zero() {
        return 0.0;
    }
    completed as f64 / wall.as_secs_f64()
}

/// SplitMix64 finalizer: the one integer mixer behind both the
/// summary reservoir's deterministic sampling and the pool's sticky
/// shard routing ([`crate::coordinator::pool::ServingPool::route`]).
pub(crate) fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Bounded streaming summary of a latency series: exact count/sum/
/// first/min/max plus a fixed-size reservoir for percentile estimates.
///
/// The serving workers used to push every per-batch latency into an
/// unbounded `Vec<f64>`, which grows forever on a long-lived server;
/// this keeps O(1) memory no matter how many batches are served. The
/// reservoir uses Vitter's Algorithm R with a deterministic SplitMix64
/// step (the offline image carries no rand crate, and determinism keeps
/// tests stable): every sample has an equal chance of residency once
/// the reservoir is full.
#[derive(Debug, Clone)]
pub struct StreamingSummary {
    count: u64,
    sum_us: f64,
    first_us: f64,
    min_us: f64,
    max_us: f64,
    reservoir: Vec<f64>,
    rng: u64,
}

impl Default for StreamingSummary {
    fn default() -> Self {
        StreamingSummary {
            count: 0,
            sum_us: 0.0,
            first_us: 0.0,
            min_us: f64::INFINITY,
            max_us: 0.0,
            reservoir: Vec::new(),
            rng: 0x9e37_79b9_7f4a_7c15,
        }
    }
}

impl StreamingSummary {
    pub fn record(&mut self, d: Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    pub fn record_us(&mut self, us: f64) {
        if self.count == 0 {
            self.first_us = us;
        }
        self.count += 1;
        self.sum_us += us;
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
        if self.reservoir.len() < SUMMARY_RESERVOIR {
            self.reservoir.push(us);
        } else {
            // Algorithm R: replace a random slot with probability k/n.
            let slot = (self.next_rng() % self.count) as usize;
            if slot < SUMMARY_RESERVOIR {
                self.reservoir[slot] = us;
            }
        }
    }

    fn next_rng(&mut self) -> u64 {
        // Deterministic, no external dependency: advance the state and
        // finalize with the shared mixer.
        self.rng = self.rng.wrapping_add(1);
        splitmix64(self.rng)
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum_us(&self) -> f64 {
        self.sum_us
    }

    /// The very first recorded sample (the cold compile, for the
    /// serving path's compile-latency series).
    pub fn first_us(&self) -> f64 {
        self.first_us
    }

    pub fn min_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min_us
        }
    }

    pub fn max_us(&self) -> f64 {
        self.max_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us / self.count as f64
        }
    }

    /// Mean of every sample after the first — the warm tail of a series
    /// whose head is a cold outlier.
    pub fn warm_mean_us(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            (self.sum_us - self.first_us) / (self.count - 1) as f64
        }
    }

    /// Percentile in [0, 100], nearest-rank over the reservoir.
    ///
    /// One-shot convenience that sorts a copy of the reservoir; reading
    /// several percentiles of the same snapshot should go through
    /// [`Self::percentiles_us`], which sorts once for the whole batch.
    pub fn percentile_us(&self, p: f64) -> f64 {
        self.percentiles_us(&[p])[0]
    }

    /// Nearest-rank percentiles for every `p` in `ps`, sorting the
    /// reservoir once. This is the snapshot-friendly read path: the
    /// stats printers and the Prometheus exporter ask for a handful of
    /// quantiles per series and pay a single O(n log n) sort.
    pub fn percentiles_us(&self, ps: &[f64]) -> Vec<f64> {
        let mut sorted = self.reservoir.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ps.iter().map(|&p| percentile_of_sorted(&sorted, p)).collect()
    }

    /// The raw reservoir — every retained sample, in arrival order.
    /// The perf library's measured write-back store min-k-merges these
    /// per fused group, and the divergence report derives its trimmed
    /// spread from them.
    pub fn samples(&self) -> &[f64] {
        &self.reservoir
    }

    /// Fold `other` into `self` (pool shutdown merges worker summaries).
    /// Exact moments combine exactly. When the combined reservoirs
    /// exceed [`SUMMARY_RESERVOIR`], each side's share of the merged
    /// reservoir is proportional to its true *sample count* — not its
    /// reservoir length — so a low-traffic worker cannot skew the
    /// aggregate percentiles (sticky sharding makes uneven worker
    /// loads the normal case).
    pub fn merge(&mut self, other: &StreamingSummary) {
        if other.count == 0 {
            return;
        }
        let self_count = self.count;
        if self_count == 0 {
            self.first_us = other.first_us;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
        if self.reservoir.len() + other.reservoir.len() <= SUMMARY_RESERVOIR {
            self.reservoir.extend_from_slice(&other.reservoir);
            return;
        }
        fn take_strided(v: &[f64], n: usize) -> Vec<f64> {
            if v.len() <= n {
                return v.to_vec();
            }
            (0..n).map(|i| v[i * v.len() / n]).collect()
        }
        let total = (self_count + other.count) as f64;
        let want_other = ((SUMMARY_RESERVOIR as f64 * other.count as f64 / total).round()
            as usize)
            .min(other.reservoir.len());
        let want_self = (SUMMARY_RESERVOIR - want_other).min(self.reservoir.len());
        let mut merged = take_strided(&self.reservoir, want_self);
        merged.extend(take_strided(&other.reservoir, want_other));
        self.reservoir = merged;
    }
}

/// Outlier-trimmed (min, p50, max) of a sample set: sort a copy, drop
/// `len/8` from each end, report the spread of what remains. The same
/// trim rule the perf library's measured estimates use, exposed here so
/// the divergence report and the `obs` CLI describe samples the way the
/// autotuner consumes them. Returns zeros on an empty set.
pub fn trimmed_stats(samples: &[f64]) -> (f64, f64, f64) {
    if samples.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let trim = sorted.len() / 8;
    let kept = &sorted[trim..sorted.len() - trim];
    (kept[0], kept[kept.len() / 2], kept[kept.len() - 1])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_percentiles() {
        let mut s = StreamingSummary::default();
        for v in [1.0, 2.0, 3.0, 4.0, 100.0] {
            s.record_us(v);
        }
        assert!((s.mean_us() - 22.0).abs() < 1e-9);
        assert_eq!(s.percentile_us(50.0), 3.0);
        assert_eq!(s.percentile_us(99.0), 100.0);
        assert_eq!(s.percentile_us(100.0), 100.0);
        // the batched form sorts once and agrees with one-shot reads
        assert_eq!(s.percentiles_us(&[50.0, 99.0, 100.0]), vec![3.0, 100.0, 100.0]);
    }

    #[test]
    fn throughput_is_a_free_function() {
        assert!((throughput_rps(10, Duration::from_secs(2)) - 5.0).abs() < 1e-9);
        assert_eq!(throughput_rps(10, Duration::ZERO), 0.0);
        assert_eq!(throughput_rps(0, Duration::from_secs(1)), 0.0);
    }

    #[test]
    fn launches_per_request_basics() {
        let ledger = LaunchLedger { generated: 6, library: 2, ..Default::default() };
        assert!((launches_per_request(&ledger, 4) - 2.0).abs() < 1e-12);
        assert_eq!(launches_per_request(&ledger, 0), 0.0);
    }

    #[test]
    fn streaming_summary_is_bounded_and_accurate() {
        let mut s = StreamingSummary::default();
        for i in 0..10_000u64 {
            s.record_us(i as f64);
        }
        assert_eq!(s.count(), 10_000);
        assert_eq!(s.first_us(), 0.0);
        assert_eq!(s.min_us(), 0.0);
        assert_eq!(s.max_us(), 9999.0);
        assert!((s.mean_us() - 4999.5).abs() < 1e-9);
        // memory stays bounded no matter how many samples stream in
        assert!(s.reservoir.len() <= SUMMARY_RESERVOIR);
        // reservoir percentiles track the true distribution loosely
        let p50 = s.percentile_us(50.0);
        assert!((2000.0..8000.0).contains(&p50), "p50 = {p50}");
        let p99 = s.percentile_us(99.0);
        assert!(p99 > s.percentile_us(50.0));
    }

    #[test]
    fn streaming_summary_empty_and_warm_mean() {
        let s = StreamingSummary::default();
        assert_eq!((s.count(), s.mean_us(), s.percentile_us(50.0)), (0, 0.0, 0.0));
        assert_eq!(s.min_us(), 0.0);
        let mut s = StreamingSummary::default();
        s.record_us(1000.0); // cold
        s.record_us(10.0);
        s.record_us(20.0);
        assert_eq!(s.first_us(), 1000.0);
        assert!((s.warm_mean_us() - 15.0).abs() < 1e-9);
    }

    #[test]
    fn streaming_summary_merge_combines_exact_moments() {
        let mut a = StreamingSummary::default();
        let mut b = StreamingSummary::default();
        for i in 0..100 {
            a.record_us(i as f64);
        }
        for i in 100..300 {
            b.record_us(i as f64);
        }
        a.merge(&b);
        assert_eq!(a.count(), 300);
        assert_eq!(a.min_us(), 0.0);
        assert_eq!(a.max_us(), 299.0);
        assert!((a.mean_us() - 149.5).abs() < 1e-9);
        assert!(a.reservoir.len() <= SUMMARY_RESERVOIR);
        // merging into an empty summary adopts the donor's cold sample
        let mut c = StreamingSummary::default();
        c.merge(&a);
        assert_eq!(c.first_us(), a.first_us());
    }

    #[test]
    fn merge_weights_reservoir_by_sample_count() {
        // A heavy worker (100k samples near 1000µs) absorbs a light one
        // (600 samples at 5µs): the light side's residency must track
        // its ~0.6% traffic share, not its reservoir length.
        let mut heavy = StreamingSummary::default();
        for i in 0..100_000u64 {
            heavy.record_us(1000.0 + (i % 100) as f64);
        }
        let mut light = StreamingSummary::default();
        for _ in 0..600 {
            light.record_us(5.0);
        }
        heavy.merge(&light);
        assert_eq!(heavy.count(), 100_600);
        let light_slots = heavy.reservoir.iter().filter(|v| **v < 100.0).count();
        assert!(light_slots <= 16, "light worker holds {light_slots}/512 slots");
        // percentiles stay in the heavy worker's range
        assert!(heavy.percentile_us(50.0) >= 1000.0);
    }

    #[test]
    fn trimmed_stats_drop_the_tails() {
        assert_eq!(trimmed_stats(&[]), (0.0, 0.0, 0.0));
        assert_eq!(trimmed_stats(&[5.0]), (5.0, 5.0, 5.0));
        // 16 samples: one crazy outlier each side gets trimmed (16/8 = 2)
        let mut v: Vec<f64> = (0..14).map(|i| 10.0 + i as f64).collect();
        v.push(0.001);
        v.push(9999.0);
        let (min, p50, max) = trimmed_stats(&v);
        assert!(min >= 10.0, "low outlier must be trimmed, got {min}");
        assert!(max <= 23.0, "high outlier must be trimmed, got {max}");
        assert!((10.0..=23.0).contains(&p50));
    }

    #[test]
    fn pass_trace_totals_and_lookup() {
        let mut t = PassTrace::default();
        t.record("fusion", 120.0, 40, 12);
        t.record("simulate", 30.0, 12, 12);
        assert_eq!(t.records.len(), 2);
        assert!((t.total_us() - 150.0).abs() < 1e-9);
        assert_eq!(t.pass_us("fusion"), 120.0);
        assert_eq!(t.pass_us("nope"), 0.0);
        let text = t.to_string();
        assert!(text.contains("fusion") && text.contains("total"));
    }
}
