//! Latency/throughput accounting for the serving loop.

use std::time::Duration;

/// Collects request latencies and derives the usual percentiles.
#[derive(Debug, Default, Clone)]
pub struct LatencyRecorder {
    samples_us: Vec<f64>,
}

impl LatencyRecorder {
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_secs_f64() * 1e6);
    }

    pub fn record_us(&mut self, us: f64) {
        self.samples_us.push(us);
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<f64>() / self.samples_us.len() as f64
    }

    /// Percentile in [0, 100], nearest-rank.
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        let mut v = self.samples_us.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * v.len() as f64).ceil().max(1.0) as usize;
        v[rank.min(v.len()) - 1]
    }

    /// Requests per second given the wall-clock window of the run.
    pub fn throughput_rps(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.samples_us.len() as f64 / wall.as_secs_f64()
    }

    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.samples_us.extend_from_slice(&other.samples_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(vals: &[f64]) -> LatencyRecorder {
        let mut r = LatencyRecorder::default();
        for &v in vals {
            r.record_us(v);
        }
        r
    }

    #[test]
    fn mean_and_percentiles() {
        let r = rec(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert!((r.mean_us() - 22.0).abs() < 1e-9);
        assert_eq!(r.percentile_us(50.0), 3.0);
        assert_eq!(r.percentile_us(99.0), 100.0);
        assert_eq!(r.percentile_us(100.0), 100.0);
    }

    #[test]
    fn empty_is_safe() {
        let r = LatencyRecorder::default();
        assert_eq!(r.mean_us(), 0.0);
        assert_eq!(r.percentile_us(50.0), 0.0);
        assert!(r.is_empty());
    }

    #[test]
    fn throughput() {
        let r = rec(&[1.0; 10]);
        assert!((r.throughput_rps(Duration::from_secs(2)) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn merge_combines() {
        let mut a = rec(&[1.0, 2.0]);
        let b = rec(&[3.0]);
        a.merge(&b);
        assert_eq!(a.len(), 3);
    }
}
