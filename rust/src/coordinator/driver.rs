//! The pass manager: the compilation pipeline as named, instrumented
//! passes.
//!
//! The original `compile_module` ran fusion → tuning → shared-memory
//! planning → emission → simulation as one opaque function. This module
//! factors that sequence into a [`PassManager`] of named [`Pass`]es so
//! that
//!
//! - every pass reports wall time and before/after unit counts into a
//!   [`PassTrace`] ([`crate::coordinator::metrics`]),
//! - the schedule-and-emit pass can consult the persisted tuned-plan
//!   store in [`PerfLibrary`] (keyed by the module
//!   [`crate::hlo::Fingerprint`]) and skip re-tuning groups it has seen
//!   before, and
//! - callers that only want the compiled artifact keep the old
//!   single-call shape via [`crate::coordinator::pipeline::compile_module`].
//!
//! Pipeline order (see DESIGN.md for the full dataflow diagram):
//!
//! ```text
//! HloModule ──fingerprint──▶ fusion ──validate──▶ schedule+emit ──▶ simulate
//!                 │                                     ▲
//!                 └──── tuned-plan store (PerfLibrary) ─┘
//! ```

use crate::codegen::emitter::emit_group;
use crate::codegen::KernelPlan;
use crate::exec::{lower_to_exec, StitchedExecutable};
use crate::fusion::{
    deep_fusion_with_oracle, explore_fusion_with_oracle, xla_baseline_fusion, ExploreStats,
    FusionPlan, GroupKind,
};
use crate::gpusim::executor::{simulate_module, ModuleTiming, SimKernel};
use crate::hlo::{fingerprint_module, Computation, Fingerprint, InstrId, Module, Opcode};
use crate::schedule::{
    tune, CostOracle, CostSource, MeasuredCost, ModeledCost, PerfLibrary, Schedule, TunedPlan,
    TuningConfig,
};
use anyhow::anyhow;
use std::collections::HashSet;
use std::sync::Arc;
use std::time::Instant;

use super::metrics::PassTrace;
use super::pipeline::{CompiledModule, FusionMode, PipelineConfig};

/// The named pipeline passes, in the order the standard pipeline runs
/// them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pass {
    /// Canonicalize + fingerprint the module (cache / perf-library key).
    Fingerprint,
    /// Partition the graph into kernel groups (baseline or deep fusion).
    Fusion,
    /// Cost-guided refinement of the greedy plan: merge/split moves are
    /// kept only when the modeled time improves, within the greedy
    /// plan's launch budget. Runs for `FusionStitching` unless
    /// `cost_fusion` is off (`--no-cost-fusion`); a no-op otherwise.
    FusionExplore,
    /// Check the partition covers every instruction acyclically.
    ValidatePlan,
    /// Tune each generated group (reusing persisted tuned plans where
    /// the fingerprint matches) and emit its kernel plan.
    ScheduleAndEmit,
    /// Project all kernels onto the analytical GPU model.
    Simulate,
    /// Lower the emitted kernel plans into the stitched VM's executable
    /// (one launch per fused group). Modules using ops outside the VM's
    /// subset compile without an executable (the reason is recorded).
    LowerToExec,
}

impl Pass {
    pub fn name(self) -> &'static str {
        match self {
            Pass::Fingerprint => "fingerprint",
            Pass::Fusion => "fusion",
            Pass::FusionExplore => "fusion-explore",
            Pass::ValidatePlan => "validate-plan",
            Pass::ScheduleAndEmit => "schedule-emit",
            Pass::Simulate => "simulate",
            Pass::LowerToExec => "lower-exec",
        }
    }
}

/// Mutable state threaded through the passes.
struct CompileState {
    fingerprint: Option<Fingerprint>,
    plan: Option<FusionPlan>,
    explore: Option<ExploreStats>,
    kernels: Vec<KernelPlan>,
    generated_group_ids: Vec<usize>,
    sim: Vec<SimKernel>,
    timing: Option<ModuleTiming>,
    executable: Option<Arc<StitchedExecutable>>,
    exec_error: Option<String>,
}

/// Runs a pass sequence over one module, recording a [`PassTrace`].
#[derive(Debug, Clone)]
pub struct PassManager {
    passes: Vec<Pass>,
}

impl PassManager {
    /// The standard pass pipeline.
    pub fn standard() -> Self {
        PassManager {
            passes: vec![
                Pass::Fingerprint,
                Pass::Fusion,
                Pass::FusionExplore,
                Pass::ValidatePlan,
                Pass::ScheduleAndEmit,
                Pass::Simulate,
                Pass::LowerToExec,
            ],
        }
    }

    /// The pass sequence this manager runs.
    pub fn passes(&self) -> &[Pass] {
        &self.passes
    }

    /// Compile `module` under `mode`, returning the artifact plus the
    /// per-pass trace.
    pub fn run(
        &self,
        module: &Module,
        mode: FusionMode,
        lib: &mut PerfLibrary,
        cfg: &PipelineConfig,
    ) -> crate::Result<(CompiledModule, PassTrace)> {
        let comp = &module.entry;
        let mut st = CompileState {
            fingerprint: None,
            plan: None,
            explore: None,
            kernels: Vec::new(),
            generated_group_ids: Vec::new(),
            sim: Vec::new(),
            timing: None,
            executable: None,
            exec_error: None,
        };
        let mut trace = PassTrace::default();

        // Resolve the cost seam once for the whole compile: the analytic
        // model, or a measured overlay snapshot of the perf library's
        // launch-span write-backs (the serving pool's autotune path).
        let measured;
        let oracle: &dyn CostOracle = match cfg.cost_source {
            CostSource::Modeled => &ModeledCost,
            CostSource::Measured => {
                measured = MeasuredCost::from_library(lib);
                &measured
            }
        };

        for &pass in &self.passes {
            let before = self.units(pass, &st, comp, true);
            let t0 = Instant::now();
            match pass {
                Pass::Fingerprint => {
                    st.fingerprint = Some(fingerprint_module(module));
                }
                Pass::Fusion => {
                    st.plan = Some(match mode {
                        FusionMode::XlaBaseline => xla_baseline_fusion(comp),
                        FusionMode::FusionStitching => {
                            deep_fusion_with_oracle(comp, lib, &cfg.deep, oracle).0
                        }
                    });
                }
                Pass::FusionExplore => {
                    if mode == FusionMode::FusionStitching && cfg.deep.cost_fusion {
                        let plan = st
                            .plan
                            .take()
                            .ok_or_else(|| anyhow!("fusion-explore needs the fusion pass"))?;
                        let (refined, stats) =
                            explore_fusion_with_oracle(comp, &plan, lib, &cfg.deep, oracle);
                        st.plan = Some(refined);
                        st.explore = Some(stats);
                    }
                }
                Pass::ValidatePlan => {
                    self.plan_of(&st)?.validate(comp)?;
                }
                Pass::ScheduleAndEmit => {
                    self.schedule_and_emit(module, mode, lib, cfg, &mut st)?;
                }
                Pass::Simulate => {
                    st.timing = Some(simulate_module(&st.sim, &cfg.deep.device, cfg.lib_efficiency));
                }
                Pass::LowerToExec => {
                    let plan = self.plan_of(&st)?;
                    match lower_to_exec(module, plan, &st.kernels, &st.generated_group_ids) {
                        Ok(exe) => st.executable = Some(Arc::new(exe)),
                        Err(e) => st.exec_error = Some(format!("{e:#}")),
                    }
                }
            }
            let wall_us = t0.elapsed().as_secs_f64() * 1e6;
            let after = self.units(pass, &st, comp, false);
            trace.record(pass.name(), wall_us, before, after);
        }

        // Seed the kernel profile with every lowered kernel's group
        // fingerprint, tier and modeled cost, so the obs layer's
        // modeled-vs-measured join is complete before the first launch.
        let profile = crate::obs::KernelProfileHandle::new();
        if let Some(exe) = &st.executable {
            for launch in &exe.launches {
                if let crate::exec::Launch::Kernel(k) = launch {
                    profile.seed(k.group_fp, k.stitch_tier(), k.modeled_us);
                }
            }
        }

        let compiled = CompiledModule {
            name: module.name.clone(),
            mode,
            fingerprint: st
                .fingerprint
                .ok_or_else(|| anyhow!("pipeline ran without the fingerprint pass"))?,
            plan: st.plan.ok_or_else(|| anyhow!("pipeline ran without the fusion pass"))?,
            explore: st.explore,
            kernels: st.kernels,
            generated_group_ids: st.generated_group_ids,
            timing: st.timing.ok_or_else(|| anyhow!("pipeline ran without the simulate pass"))?,
            executable: st.executable,
            exec_error: st.exec_error,
            profile,
        };
        Ok((compiled, trace))
    }

    fn plan_of<'s>(&self, st: &'s CompileState) -> crate::Result<&'s FusionPlan> {
        st.plan.as_ref().ok_or_else(|| anyhow!("fusion pass has not run"))
    }

    /// Work-unit count a pass transforms: kernel-granularity items.
    fn units(&self, pass: Pass, st: &CompileState, comp: &Computation, before: bool) -> usize {
        match pass {
            Pass::Fingerprint => comp.len(),
            Pass::Fusion => {
                if before {
                    comp.unfused_kernel_count()
                } else {
                    st.plan.as_ref().map_or(0, |p| p.groups.len())
                }
            }
            Pass::FusionExplore => st.plan.as_ref().map_or(0, |p| p.groups.len()),
            Pass::ValidatePlan => st.plan.as_ref().map_or(0, |p| p.groups.len()),
            Pass::ScheduleAndEmit => {
                if before {
                    st.plan
                        .as_ref()
                        .map_or(0, |p| p.groups.iter().filter(|g| g.is_generated_kernel(comp)).count())
                } else {
                    st.kernels.len()
                }
            }
            Pass::Simulate => st.sim.len(),
            Pass::LowerToExec => {
                if before {
                    st.kernels.len()
                } else {
                    st.executable.as_ref().map_or(0, |e| e.launches.len())
                }
            }
        }
    }

    fn schedule_and_emit(
        &self,
        module: &Module,
        mode: FusionMode,
        lib: &mut PerfLibrary,
        cfg: &PipelineConfig,
        st: &mut CompileState,
    ) -> crate::Result<()> {
        let comp = &module.entry;
        let dev = cfg.deep.device.clone();
        let fp = st
            .fingerprint
            .ok_or_else(|| anyhow!("schedule-emit needs the fingerprint pass"))?;
        let plan = st.plan.clone().ok_or_else(|| anyhow!("schedule-emit needs the fusion pass"))?;

        for group in &plan.groups {
            match group.kind {
                GroupKind::Library => {
                    let id = *group.members.iter().next().unwrap();
                    let (flops, bytes) = library_call_cost(comp, id);
                    st.sim.push(SimKernel::Library { flops, bytes });
                }
                _ => {
                    if !group.is_generated_kernel(comp) {
                        continue;
                    }
                    let tkey = tuned_key(fp, mode, cfg, comp, group);
                    // Peek + validate first; only a plan that actually
                    // gets reused counts as a tuned-store hit.
                    let cached = lib
                        .tuned_peek(&tkey)
                        .filter(|p| tuned_plan_matches(p, &group.members, &group.roots))
                        .cloned();
                    let tuned = match cached {
                        Some(p) => {
                            lib.tuned_mark_reused();
                            p
                        }
                        None => {
                            let p = tune_group(comp, &group.members, &group.roots, lib, &cfg.deep.tuning)
                                .ok_or_else(|| {
                                    anyhow!(
                                        "group {} of {} is unschedulable (roots {:?})",
                                        group.id,
                                        module.name,
                                        group.roots
                                    )
                                })?;
                            lib.tuned_insert(tkey, p.clone());
                            p
                        }
                    };
                    let kplan = emit_group(
                        comp,
                        &group.members,
                        &group.roots,
                        &tuned,
                        &dev,
                        &format!("{}_k{}", module.name, group.id),
                    )?;
                    st.sim.push(SimKernel::Generated(kplan.to_kernel_desc(
                        comp,
                        &group.members,
                        &tuned,
                    )));
                    st.generated_group_ids.push(group.id);
                    st.kernels.push(kplan);
                }
            }
        }
        Ok(())
    }
}

/// Compile one module through the standard pass pipeline, returning the
/// artifact and the instrumented per-pass trace.
pub fn compile_module_traced(
    module: &Module,
    mode: FusionMode,
    lib: &mut PerfLibrary,
    cfg: &PipelineConfig,
) -> crate::Result<(CompiledModule, PassTrace)> {
    PassManager::standard().run(module, mode, lib, cfg)
}

/// Persisted-tuned-plan key: module fingerprint + everything else that
/// shapes the group partition (fusion mode, batch-dot policy, device) +
/// the group id within the deterministic partition + an *id-sensitive*
/// digest of the group's concrete instructions.
///
/// The module fingerprint is id-invariant by design, but a persisted
/// [`TunedPlan`] stores raw [`InstrId`]s — so the key must also pin the
/// concrete numbering and the device the plan was tuned for. Otherwise
/// a renumbered structural twin (same fingerprint, different id →
/// instruction mapping) or a different cost model could silently adopt
/// schedules meant for other instructions.
fn tuned_key(
    fp: Fingerprint,
    mode: FusionMode,
    cfg: &PipelineConfig,
    comp: &Computation,
    group: &crate::fusion::FusionGroup,
) -> String {
    format!(
        "{}|{:?}|bd{}|dev:{}|c{:016x}|g{}|i{:016x}",
        fp.to_hex(),
        mode,
        cfg.deep.fuse_batch_dot as u8,
        cfg.deep.device.name,
        config_digest(cfg),
        group.id,
        group_digest(comp, &group.members)
    )
}

/// FNV-1a digest of every remaining pipeline knob that shapes a
/// compiled artifact: the tuning space, elementwise-fusion thresholds,
/// library efficiency, the full device constants (not just the device
/// name), and the shape-class bucket policy (two runs bucketing
/// differently pad to different canonical shapes, so their artifacts
/// must never share a key). Shared by [`tuned_key`] and
/// [`crate::coordinator::cache::CacheKey`], so plans tuned under one
/// configuration are never adopted under another.
pub(crate) fn config_digest(cfg: &PipelineConfig) -> u64 {
    crate::schedule::perf_library::fnv1a(
        format!(
            "{:?}|{:?}|{}|{:?}|xf{}|gs{}|cs{:?}|bk{:?}",
            cfg.deep.tuning,
            cfg.deep.elementwise,
            cfg.lib_efficiency,
            cfg.deep.device,
            cfg.deep.cost_fusion as u8,
            cfg.deep.global_stitch as u8,
            cfg.cost_source,
            cfg.bucketing
        )
        .as_bytes(),
    )
}

/// FNV-1a over the group's member instructions *including their ids and
/// operand ids* — deliberately not renumbering-invariant (see
/// [`tuned_key`]).
fn group_digest(comp: &Computation, members: &HashSet<InstrId>) -> u64 {
    use crate::schedule::perf_library::{fnv1a_fold, FNV_SEED};
    fn mix(h: u64, v: u64) -> u64 {
        fnv1a_fold(h, &v.to_le_bytes())
    }
    let mut ordered: Vec<InstrId> = members.iter().copied().collect();
    ordered.sort_unstable();
    let mut h: u64 = FNV_SEED;
    for id in ordered {
        let i = comp.get(id);
        h = mix(h, id.0 as u64);
        h = mix(h, i.opcode as u64);
        h = mix(h, i.shape.dims.len() as u64);
        for &d in &i.shape.dims {
            h = mix(h, d as u64);
        }
        for &op in &i.operands {
            h = mix(h, op.0 as u64);
        }
    }
    h
}

/// Sanity check before trusting a persisted plan: it must cover exactly
/// this group's members and roots (guards against key collisions and
/// stale stores).
fn tuned_plan_matches(plan: &TunedPlan, members: &HashSet<InstrId>, roots: &[InstrId]) -> bool {
    plan.blocks >= 1
        && plan.root_schedules.len() == roots.len()
        && plan.root_schedules.iter().all(|(id, _)| roots.contains(id))
        && plan.assignment.len() == members.len()
        && plan.assignment.keys().all(|id| members.contains(id))
}

/// Tune a group, falling back to the always-valid single-block Row
/// schedule (§4.3) when the enumerated space rejects everything — this
/// covers baseline singleton groups of awkward ops.
fn tune_group(
    comp: &Computation,
    members: &HashSet<InstrId>,
    roots: &[InstrId],
    lib: &mut PerfLibrary,
    tuning: &TuningConfig,
) -> Option<TunedPlan> {
    if let Some(plan) = tune(comp, members, roots, lib, tuning) {
        return Some(plan);
    }
    // Fallback: propagate (0, 1, Row) from all roots.
    let combo: Vec<(InstrId, Schedule)> =
        roots.iter().map(|&r| (r, Schedule::fallback())).collect();
    let prop = crate::schedule::propagate(comp, members, &combo).ok()?;
    let mut est = 0.0;
    for (&id, s) in &prop.assignment {
        if let crate::schedule::OpSchedule::Scheduled(s) = s {
            est += lib.lookup(comp, id, *s, 128);
        }
    }
    Some(TunedPlan {
        root_schedules: combo,
        assignment: prop.assignment.into_iter().collect(),
        blocks: prop.blocks,
        threads: 128,
        est_exec_us: est,
    })
}

/// FLOPs + bytes moved of a vendor library call.
fn library_call_cost(comp: &Computation, id: InstrId) -> (u64, u64) {
    let instr = comp.get(id);
    let out_elems = instr.shape.num_elements() as u64;
    let bytes: u64 = instr.shape.byte_size() as u64
        + comp
            .operand_shapes(id)
            .iter()
            .map(|s| s.byte_size() as u64)
            .sum::<u64>();
    let flops = match instr.opcode {
        Opcode::Dot => {
            let k = comp.operand_shapes(id)[0].dims.last().copied().unwrap_or(1) as u64;
            2 * out_elems * k
        }
        Opcode::Convolution => {
            let f = comp.operand_shapes(id)[1];
            let window = (f.dims[0] * f.dims[1] * f.dims[2]) as u64;
            2 * out_elems * window
        }
        // Opaque custom calls (cuDNN RNN cells etc.): assume moderately
        // compute-dense.
        _ => 16 * out_elems,
    };
    (flops, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::DeviceConfig;
    use crate::models;

    fn setup() -> (PerfLibrary, PipelineConfig) {
        (PerfLibrary::new(DeviceConfig::pascal()), PipelineConfig::default())
    }

    #[test]
    fn standard_pipeline_traces_every_pass() {
        let (mut lib, cfg) = setup();
        let (_, module) = models::by_name("LR").unwrap();
        let (compiled, trace) =
            compile_module_traced(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        let names: Vec<&str> = trace.records.iter().map(|r| r.name).collect();
        assert_eq!(
            names,
            vec![
                "fingerprint",
                "fusion",
                "fusion-explore",
                "validate-plan",
                "schedule-emit",
                "simulate",
                "lower-exec"
            ]
        );
        assert!(trace.records.iter().all(|r| r.wall_us >= 0.0));
        assert!(trace.total_us() > 0.0);
        assert_eq!(compiled.fingerprint, crate::hlo::fingerprint_module(&module));
        assert!(!compiled.kernels.is_empty());
        let exe = compiled.executable.as_ref().unwrap_or_else(|| {
            panic!("LR must lower to an executable: {:?}", compiled.exec_error)
        });
        assert!(exe.launches.len() >= compiled.kernels.len());
    }

    #[test]
    fn fusion_pass_reduces_unit_count() {
        let (mut lib, cfg) = setup();
        let (_, module) = models::by_name("NMT").unwrap();
        let (_, trace) =
            compile_module_traced(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        let fusion = trace.records.iter().find(|r| r.name == "fusion").unwrap();
        assert!(
            fusion.units_after < fusion.units_before,
            "fusion should shrink the kernel partition: {} -> {}",
            fusion.units_before,
            fusion.units_after
        );
        let emit = trace.records.iter().find(|r| r.name == "schedule-emit").unwrap();
        assert_eq!(emit.units_before, emit.units_after, "every generated group emits");
    }

    #[test]
    fn tuned_plans_are_reused_across_compilations() {
        let (mut lib, cfg) = setup();
        let (_, module) = models::by_name("RNN").unwrap();
        let (a, _) =
            compile_module_traced(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        assert!(lib.tuned_len() > 0, "first compile must populate the tuned store");
        assert_eq!(lib.tuned_hits(), 0);
        let (b, _) =
            compile_module_traced(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        assert!(lib.tuned_hits() > 0, "second compile must reuse tuned plans");
        // reuse must not change the produced kernels
        let ir_a: Vec<String> = a.kernels.iter().map(|k| k.ir_text()).collect();
        let ir_b: Vec<String> = b.kernels.iter().map(|k| k.ir_text()).collect();
        assert_eq!(ir_a, ir_b);
    }

    #[test]
    fn tuned_store_survives_disk_roundtrip() {
        let (mut lib, cfg) = setup();
        let (_, module) = models::by_name("LR").unwrap();
        let _ = compile_module_traced(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        let dir = crate::testutil::TempDir::new("drv");
        let path = dir.path().join("perf.tsv");
        lib.save(&path).unwrap();

        let mut lib2 = PerfLibrary::load(&path, DeviceConfig::pascal());
        assert_eq!(lib2.tuned_len(), lib.tuned_len());
        let _ = compile_module_traced(&module, FusionMode::FusionStitching, &mut lib2, &cfg).unwrap();
        assert!(lib2.tuned_hits() > 0, "fresh process must hit the persisted tuned plans");
    }

    #[test]
    fn renumbered_twin_does_not_adopt_tuned_plans() {
        // Two structural twins share a fingerprint but number their
        // instructions differently; persisted plans hold raw InstrIds,
        // so the id-sensitive digest in the key must force a re-tune.
        use crate::hlo::{GraphBuilder, Module, Shape};
        let (mut lib, cfg) = setup();

        let mut b1 = GraphBuilder::new("e");
        let x = b1.param("x", Shape::f32(&[64, 32]));
        let y = b1.param("y", Shape::f32(&[64, 32]));
        let e = b1.exp(x);
        let t = b1.tanh(y);
        let s = b1.add(e, t);
        let m1 = Module::new("m1", b1.finish(s));

        let mut b2 = GraphBuilder::new("e");
        let x = b2.param("x", Shape::f32(&[64, 32]));
        let y = b2.param("y", Shape::f32(&[64, 32]));
        let t = b2.tanh(y); // ids of exp/tanh swapped vs m1
        let e = b2.exp(x);
        let s = b2.add(e, t);
        let m2 = Module::new("m2", b2.finish(s));

        let (a, _) =
            compile_module_traced(&m1, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        let (b, _) =
            compile_module_traced(&m2, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        assert_eq!(a.fingerprint, b.fingerprint, "twins share the structural fingerprint");
        assert_eq!(lib.tuned_hits(), 0, "but tuned plans must not transfer across numberings");
    }

    #[test]
    fn explore_pass_runs_by_default_and_respects_the_escape_hatch() {
        let (mut lib, cfg) = setup();
        let (_, module) = models::by_name("Speech").unwrap();
        let (on, _) =
            compile_module_traced(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        assert!(on.explore.is_some(), "cost-guided exploration is on by default");

        let mut off_cfg = cfg.clone();
        off_cfg.deep.cost_fusion = false;
        let (off, _) =
            compile_module_traced(&module, FusionMode::FusionStitching, &mut lib, &off_cfg)
                .unwrap();
        assert!(off.explore.is_none(), "--no-cost-fusion must skip exploration");

        // The acceptance bar, per module: modeled time never worse, and
        // never more generated kernels than greedy.
        assert!(on.timing.total_us() <= off.timing.total_us() + 1e-6);
        assert!(
            on.plan.generated_kernel_count(&module.entry)
                <= off.plan.generated_kernel_count(&module.entry)
        );

        // Baseline mode never explores.
        let (base, _) =
            compile_module_traced(&module, FusionMode::XlaBaseline, &mut lib, &cfg).unwrap();
        assert!(base.explore.is_none());
    }

    #[test]
    fn modes_do_not_share_tuned_entries() {
        let (mut lib, cfg) = setup();
        let (_, module) = models::by_name("LR").unwrap();
        let _ = compile_module_traced(&module, FusionMode::XlaBaseline, &mut lib, &cfg).unwrap();
        let after_baseline = lib.tuned_len();
        let _ = compile_module_traced(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        assert!(lib.tuned_len() > after_baseline, "each mode gets its own tuned entries");
    }
}
