//! Dynamic batching for the online serving loop.
//!
//! The paper's NMT online use case (§6.1) is latency-critical with small
//! batches; the batcher trades a bounded wait for batching efficiency:
//! a batch closes when it reaches `max_batch` requests or when
//! `max_wait` has elapsed since its first request.
//!
//! Every request carries a `shape_key`. Since the shape-class bucketing
//! refactor the key names a *bucket* (see
//! [`crate::coordinator::buckets::BucketPolicy`]), not necessarily one
//! exact shape: batches are **bucket-pure**, not shape-pure. Requests
//! whose concrete lengths differ may share a batch as long as they fall
//! in the same bucket; the serving loop pads each row with zeros up to
//! the bucket's canonical length on the way into the batch buffer and
//! slices each request's live output region back out on the way off, so
//! mixed-length batches stay value-identical to exact-shape execution.
//! The collectors ([`next_batch_keyed`], [`next_batch_bucketed`]) never
//! mix *keys* inside one batch — different buckets need different
//! compiled artifacts — and carry the first mismatched request over to
//! seed the next batch, so nothing is dropped or reordered across
//! buckets. With the degenerate one-shape-per-bucket policy
//! (`BucketPolicy::Exact`, or no policy at all) keys are exact lengths
//! and the historical shape-pure behavior holds bit-for-bit.
//!
//! [`next_batch_bucketed`] additionally applies a
//! [`crate::coordinator::buckets::BucketAdmission`] check: a row whose
//! modeled padding waste exceeds the cost of a separate launch is
//! *demoted* — its key is rewritten to its exact length so it ships in
//! its own exact-shape batch instead of being padded.
//!
//! # Deadlines and slack admission
//!
//! Requests may carry a **deadline** ([`Request::deadline`]). The
//! deadline-aware collector ([`next_batch_admitted`]) runs a per-row
//! feasibility check against a [`SlackCheck`] — the predicted kernel
//! service time plus batch-assembly overhead, supplied by the worker
//! from measured latencies or the cost oracle. A row whose deadline
//! cannot be met even if the batch shipped *right now* is **shed**
//! (returned separately so the worker replies with a structured
//! [`Rejection::DeadlineInfeasible`] instead of a silent timeout), and
//! an admitted deadline tightens the batch window so the batch flushes
//! early rather than letting slack go negative while it waits for
//! stragglers.

use super::buckets::BucketAdmission;
use std::fmt;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// One enqueued inference request.
pub struct Request {
    /// Flattened input row(s) for this request.
    pub input: Vec<f32>,
    /// Shape-class identity of the input: requests with different keys
    /// never share a batch. Under a bucket policy this is the bucket
    /// key ([`crate::coordinator::buckets::BucketPolicy::bucket_key`]);
    /// without one the serving loop derives it from the input length
    /// (anything stable per shape works, e.g. a truncated
    /// [`crate::hlo::Fingerprint`]).
    pub shape_key: u64,
    /// Where to send the flattened output.
    pub respond: std::sync::mpsc::Sender<anyhow::Result<Vec<f32>>>,
    /// Enqueue timestamp (for latency accounting).
    pub enqueued: Instant,
    /// Absolute reply deadline, if the client set one. Requests without
    /// a deadline are never shed by slack admission and do not tighten
    /// the batch window.
    pub deadline: Option<Instant>,
}

/// Structured rejection reasons. Every fail-fast reply the coordinator
/// sends carries one of these at the root of its error chain, so
/// clients can branch on `err.downcast_ref::<Rejection>()` instead of
/// string-matching, and the Prometheus exposition can label
/// `fusion_rejected_total` by reason.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rejection {
    /// The request row exceeds the serving contract's stride.
    Oversized,
    /// The row does not fit the bucket it claimed.
    BucketMismatch,
    /// Slack admission: the deadline cannot be met even if the request
    /// shipped immediately, given the predicted service time.
    DeadlineInfeasible,
    /// Load shedding: dropped without execution (backpressure, or a
    /// queue drained while its worker was down).
    Shed,
    /// The compile service is fast-failing this key after repeated
    /// compile failures (negative-result cache within backoff).
    CompileFailed,
}

impl Rejection {
    /// Stable label used by the Prometheus exposition
    /// (`fusion_rejected_total{reason="..."}`).
    pub fn reason(&self) -> &'static str {
        match self {
            Rejection::Oversized => "oversized",
            Rejection::BucketMismatch => "bucket_mismatch",
            Rejection::DeadlineInfeasible => "deadline",
            Rejection::Shed => "shed",
            Rejection::CompileFailed => "compile_failed",
        }
    }
}

impl fmt::Display for Rejection {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let msg = match self {
            Rejection::Oversized => "request rejected: row exceeds serving contract",
            Rejection::BucketMismatch => "request rejected: row does not fit its bucket",
            Rejection::DeadlineInfeasible => "request shed: deadline infeasible",
            Rejection::Shed => "request shed: load shedding",
            Rejection::CompileFailed => "request rejected: compile fast-fail",
        };
        f.write_str(msg)
    }
}

impl std::error::Error for Rejection {}

/// Per-shard feasibility inputs for slack admission: the worker's
/// current estimate of how long one batch takes to execute
/// (`service_us`, from measured exec latencies, the cost oracle's
/// modeled kernel time, or the policy's bootstrap value — in that order
/// of preference) plus the budgeted batch-assembly/reply overhead.
#[derive(Debug, Clone, Copy)]
pub struct SlackCheck {
    /// Predicted batch execution time, microseconds.
    pub service_us: f64,
    /// Budgeted batch assembly + reply overhead, microseconds.
    pub assembly_us: f64,
}

impl SlackCheck {
    /// Total lead time a request needs between shipping and its reply.
    pub fn lead(&self) -> Duration {
        Duration::from_secs_f64((self.service_us + self.assembly_us).max(0.0) / 1e6)
    }

    /// The latest instant a batch containing a request with `deadline`
    /// may ship and still meet it. `None` means the deadline predates
    /// even a zero-wait ship (hopeless).
    pub fn latest_ship(&self, deadline: Instant) -> Option<Instant> {
        deadline.checked_sub(self.lead())
    }

    /// Can `deadline` still be met if the batch ships at `now`?
    pub fn feasible(&self, deadline: Instant, now: Instant) -> bool {
        self.latest_ship(deadline).is_some_and(|t| t >= now)
    }
}

/// Result of a deadline-aware collection round: the batch to execute
/// plus the rows shed as deadline-infeasible. The worker must reply to
/// every shed row with a structured rejection — shedding is fail-fast,
/// never a silent drop.
pub struct BatchOutcome {
    pub batch: Vec<Request>,
    pub shed: Vec<Request>,
}

/// Collect the next batch from `rx` under `policy`, ignoring shape
/// keys. Blocks for the first request; then fills up to `max_batch`
/// until `max_wait` expires. Returns `None` once the channel is closed
/// and drained.
pub fn next_batch(rx: &Receiver<Request>, policy: &BatchPolicy) -> Option<Vec<Request>> {
    collect(rx, policy, &mut None, false, None, None).map(|o| o.batch)
}

/// Like [`next_batch`], but a batch only contains requests sharing one
/// `shape_key`. A request with a different key closes the batch and is
/// stashed in `carry` — pass the same `carry` slot on every call so it
/// seeds the next batch.
pub fn next_batch_keyed(
    rx: &Receiver<Request>,
    policy: &BatchPolicy,
    carry: &mut Option<Request>,
) -> Option<Vec<Request>> {
    collect(rx, policy, carry, true, None, None).map(|o| o.batch)
}

/// Like [`next_batch_keyed`], but for bucket keys: before a request
/// joins (or seeds) a batch, `admission` decides whether padding it to
/// its claimed bucket's canonical length is worth it. A row the check
/// refuses is demoted — its `shape_key` is rewritten to its exact
/// length, so the ordinary key-purity rule carries it into an
/// exact-shape batch of its own. `admission: None` admits everything
/// (pure bucket-purity collection).
pub fn next_batch_bucketed(
    rx: &Receiver<Request>,
    policy: &BatchPolicy,
    carry: &mut Option<Request>,
    admission: Option<&BucketAdmission>,
) -> Option<Vec<Request>> {
    collect(rx, policy, carry, true, admission, None).map(|o| o.batch)
}

/// The deadline-aware keyed/bucketed collector. Behaves like
/// [`next_batch_bucketed`] plus slack admission under `slack`:
///
/// - a deadline-carrying row that is infeasible *now* goes into
///   [`BatchOutcome::shed`] instead of the batch;
/// - an admitted deadline tightens the batch window to its latest
///   feasible ship time, flushing the batch early instead of letting
///   slack go negative;
/// - rows without deadlines are unaffected.
///
/// Returns `None` only when the channel is closed, drained, *and*
/// nothing was shed this round (a final all-shed round still returns
/// `Some` with an empty batch so the worker can send the rejections).
pub fn next_batch_admitted(
    rx: &Receiver<Request>,
    policy: &BatchPolicy,
    carry: &mut Option<Request>,
    admission: Option<&BucketAdmission>,
    slack: Option<&SlackCheck>,
) -> Option<BatchOutcome> {
    collect(rx, policy, carry, true, admission, slack)
}

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Demote `req` to an exact-shape key if the admission check refuses to
/// pad it to its claimed bucket. Demotion terminates: an exact key has
/// zero padding waste, which every admission policy accepts.
fn maybe_demote(req: &mut Request, admission: Option<&BucketAdmission>) {
    if let Some(adm) = admission {
        let len = req.input.len();
        if !adm.admits(len, req.shape_key as usize) {
            req.shape_key = len as u64;
        }
    }
}

/// Is `req` hopeless under `slack` — i.e. would it miss its deadline
/// even if its batch shipped this instant?
fn infeasible(req: &Request, slack: Option<&SlackCheck>, now: Instant) -> bool {
    match (slack, req.deadline) {
        (Some(sl), Some(d)) => !sl.feasible(d, now),
        _ => false,
    }
}

fn collect(
    rx: &Receiver<Request>,
    policy: &BatchPolicy,
    carry: &mut Option<Request>,
    keyed: bool,
    admission: Option<&BucketAdmission>,
    slack: Option<&SlackCheck>,
) -> Option<BatchOutcome> {
    let mut shed: Vec<Request> = Vec::new();
    // Seed loop: find a feasible first request, shedding hopeless ones.
    let first = loop {
        let cand = match carry.take() {
            Some(r) => Some(r),
            None => rx.recv().ok(),
        };
        let Some(mut cand) = cand else {
            // Channel closed. A round that only shed still has replies
            // to send, so it must surface; a truly empty round is the
            // shutdown signal.
            return if shed.is_empty() {
                None
            } else {
                Some(BatchOutcome { batch: Vec::new(), shed })
            };
        };
        maybe_demote(&mut cand, admission);
        if infeasible(&cand, slack, Instant::now()) {
            shed.push(cand);
            continue;
        }
        break cand;
    };
    let key = first.shape_key;
    let now = Instant::now();
    // The window is bounded by the *seed's arrival time*, whether it
    // came from the carry slot or sat queued in the channel: a request
    // that already waited through (part of) its budget gets only what
    // is left of it, never a fresh full window.
    let mut window = (first.enqueued + policy.max_wait).max(now);
    // An admitted deadline caps the window at its latest feasible ship
    // time: better to flush a small batch early than to shed later.
    if let (Some(sl), Some(d)) = (slack, first.deadline) {
        if let Some(ship) = sl.latest_ship(d) {
            window = window.min(ship).max(now);
        }
    }
    let mut batch = vec![first];
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= window {
            break;
        }
        match rx.recv_timeout(window - now) {
            Ok(mut req) => {
                maybe_demote(&mut req, admission);
                if keyed && req.shape_key != key {
                    *carry = Some(req);
                    break;
                }
                if infeasible(&req, slack, Instant::now()) {
                    shed.push(req);
                    continue;
                }
                if let (Some(sl), Some(d)) = (slack, req.deadline) {
                    if let Some(ship) = sl.latest_ship(d) {
                        window = window.min(ship);
                    }
                }
                batch.push(req);
            }
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(BatchOutcome { batch, shed })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(v: f32) -> (Request, mpsc::Receiver<anyhow::Result<Vec<f32>>>) {
        keyed_req(v, 1)
    }

    fn keyed_req(v: f32, key: u64) -> (Request, mpsc::Receiver<anyhow::Result<Vec<f32>>>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                input: vec![v],
                shape_key: key,
                respond: tx,
                enqueued: Instant::now(),
                deadline: None,
            },
            rx,
        )
    }

    #[test]
    fn batch_fills_to_capacity() {
        let (tx, rx) = mpsc::channel();
        let mut receivers = Vec::new();
        for i in 0..5 {
            let (r, rr) = req(i as f32);
            receivers.push(rr);
            tx.send(r).unwrap();
        }
        let policy = BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(50) };
        let batch = next_batch(&rx, &policy).unwrap();
        assert_eq!(batch.len(), 3);
        let batch2 = next_batch(&rx, &policy).unwrap();
        assert_eq!(batch2.len(), 2);
    }

    #[test]
    fn deadline_closes_partial_batch() {
        let (tx, rx) = mpsc::channel();
        let (r, _rr) = req(1.0);
        tx.send(r).unwrap();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) };
        let start = Instant::now();
        let batch = next_batch(&rx, &policy).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(start.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn closed_channel_returns_none() {
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    /// Regression: a request that sat queued in the channel (not the
    /// carry slot) while the worker was busy must not re-arm a fresh
    /// full `max_wait` window — its own arrival time bounds the window,
    /// so a stale first request ships (near-)immediately.
    #[test]
    fn queued_request_does_not_rearm_a_fresh_window() {
        let (tx, rx) = mpsc::channel();
        let (r, _keep) = keyed_req(1.0, 7);
        tx.send(r).unwrap();
        // Simulate the worker being busy past the request's whole window.
        std::thread::sleep(Duration::from_millis(12));
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) };
        let start = Instant::now();
        let batch = next_batch(&rx, &policy).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(
            start.elapsed() < Duration::from_millis(5),
            "stale first request re-armed a fresh window: {:?}",
            start.elapsed()
        );
        drop(tx);
    }

    #[test]
    fn keyed_batches_never_mix_shapes() {
        let (tx, rx) = mpsc::channel();
        let mut receivers = Vec::new();
        for (v, key) in [(0.0, 7), (1.0, 7), (2.0, 9), (3.0, 9)] {
            let (r, rr) = keyed_req(v, key);
            receivers.push(rr);
            tx.send(r).unwrap();
        }
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20) };
        let mut carry = None;
        let a = next_batch_keyed(&rx, &policy, &mut carry).unwrap();
        assert_eq!(a.len(), 2);
        assert!(a.iter().all(|r| r.shape_key == 7));
        assert!(carry.is_some(), "mismatched request must be carried, not dropped");
        drop(tx);
        let b = next_batch_keyed(&rx, &policy, &mut carry).unwrap();
        assert_eq!(b.len(), 2);
        assert!(b.iter().all(|r| r.shape_key == 9));
        assert!(carry.is_none());
        assert!(next_batch_keyed(&rx, &policy, &mut carry).is_none());
    }

    /// A carried request whose `max_wait` budget was already consumed
    /// while it sat behind the previous batch must still ship — as a
    /// singleton batch, immediately — never be dropped or stall.
    #[test]
    fn carried_request_with_spent_budget_ships_as_singleton() {
        let (tx, rx) = mpsc::channel();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) };
        let (r, _keep) = keyed_req(1.0, 42);
        // Let the request sit past its whole window, as if it had been
        // carried behind a long previous batch.
        std::thread::sleep(Duration::from_millis(10));
        let mut carry = Some(r);
        let start = Instant::now();
        let batch = next_batch_keyed(&rx, &policy, &mut carry).unwrap();
        assert_eq!(batch.len(), 1, "spent-budget carry ships alone");
        assert_eq!(batch[0].shape_key, 42);
        assert!(carry.is_none());
        // no fresh max_wait window was granted
        assert!(start.elapsed() < Duration::from_millis(5), "{:?}", start.elapsed());
        drop(tx);
    }

    /// Carrying across shape keys preserves arrival order within each
    /// key and loses nothing, even when keys alternate every request
    /// (the worst case for the carry slot).
    #[test]
    fn alternating_keys_preserve_order_and_drop_nothing() {
        let (tx, rx) = mpsc::channel();
        let mut receivers = Vec::new();
        // keys alternate A/B/A/B… with increasing payloads per key
        for i in 0..8 {
            let (r, rr) = keyed_req(i as f32, 100 + (i % 2) as u64);
            receivers.push(rr);
            tx.send(r).unwrap();
        }
        drop(tx);
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) };
        let mut carry = None;
        let mut seen: Vec<(u64, Vec<f32>)> = Vec::new();
        while let Some(batch) = next_batch_keyed(&rx, &policy, &mut carry) {
            let key = batch[0].shape_key;
            assert!(batch.iter().all(|r| r.shape_key == key), "batches stay shape-pure");
            seen.push((key, batch.iter().map(|r| r.input[0]).collect()));
        }
        assert!(carry.is_none(), "nothing may remain in the carry slot");
        let total: usize = seen.iter().map(|(_, v)| v.len()).sum();
        assert_eq!(total, 8, "no request may be dropped: {seen:?}");
        // within each key, payloads must come out in arrival order
        for key in [100u64, 101] {
            let ordered: Vec<f32> =
                seen.iter().filter(|(k, _)| *k == key).flat_map(|(_, v)| v.clone()).collect();
            let mut sorted = ordered.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            assert_eq!(ordered, sorted, "key {key} reordered: {ordered:?}");
        }
    }

    /// Property test over deterministic pseudo-random interleavings of
    /// >= 3 shape keys: whatever the arrival pattern, chained carries
    /// must (a) keep every batch key-pure, (b) drop nothing, and
    /// (c) preserve arrival order within each key.
    #[test]
    fn interleaved_keys_property_nothing_dropped_or_reordered() {
        // splitmix64: deterministic sequences, no RNG dependency.
        fn splitmix64(state: &mut u64) -> u64 {
            *state = state.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = *state;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        }
        let keys = [300u64, 301, 302, 303];
        for seed in 0..8u64 {
            let mut state = seed.wrapping_mul(0x5851F42D4C957F2D) + 1;
            let (tx, rx) = mpsc::channel();
            let mut receivers = Vec::new();
            let mut sent: Vec<(u64, f32)> = Vec::new();
            for i in 0..40 {
                let key = keys[(splitmix64(&mut state) % keys.len() as u64) as usize];
                let (r, rr) = keyed_req(i as f32, key);
                receivers.push(rr);
                sent.push((key, i as f32));
                tx.send(r).unwrap();
            }
            drop(tx);
            let policy = BatchPolicy { max_batch: 5, max_wait: Duration::from_millis(10) };
            let mut carry = None;
            let mut got: Vec<(u64, f32)> = Vec::new();
            while let Some(batch) = next_batch_keyed(&rx, &policy, &mut carry) {
                let key = batch[0].shape_key;
                assert!(
                    batch.iter().all(|r| r.shape_key == key),
                    "seed {seed}: batch mixes keys"
                );
                got.extend(batch.iter().map(|r| (key, r.input[0])));
            }
            assert!(carry.is_none(), "seed {seed}: carry slot not drained");
            assert_eq!(got.len(), sent.len(), "seed {seed}: requests dropped");
            for key in keys {
                let sent_k: Vec<f32> =
                    sent.iter().filter(|(k, _)| *k == key).map(|(_, v)| *v).collect();
                let got_k: Vec<f32> =
                    got.iter().filter(|(k, _)| *k == key).map(|(_, v)| *v).collect();
                assert_eq!(got_k, sent_k, "seed {seed}: key {key} lost or reordered");
            }
        }
    }

    /// A bucketed collector with an aggressive admission policy demotes
    /// a short row: its key is rewritten to the exact length, it leaves
    /// the bucket batch, and it ships in its own exact-shape batch.
    #[test]
    fn admission_demotes_wasteful_rows_to_exact_batches() {
        let (tx, rx) = mpsc::channel();
        let mk = |vals: Vec<f32>, key: u64| {
            let (resp, rr) = mpsc::channel();
            (
                Request {
                    input: vals,
                    shape_key: key,
                    respond: resp,
                    enqueued: Instant::now(),
                    deadline: None,
                },
                rr,
            )
        };
        // Both claim bucket 8; the 2-element row wastes 6/8 of its slot.
        let (full, _r1) = mk(vec![0.0; 8], 8);
        let (short, _r2) = mk(vec![1.0; 2], 8);
        tx.send(full).unwrap();
        tx.send(short).unwrap();
        drop(tx);
        // per_elem_us 1.0 vs launch 4.0: 6 wasted elements > 4us launch.
        let adm =
            BucketAdmission { launch_overhead_us: 4.0, per_elem_us: 1.0, max_waste_ratio: 1.0 };
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) };
        let mut carry = None;
        let a = next_batch_bucketed(&rx, &policy, &mut carry, Some(&adm)).unwrap();
        assert_eq!(a.len(), 1, "demoted row must not share the bucket batch");
        assert_eq!(a[0].shape_key, 8);
        let b = next_batch_bucketed(&rx, &policy, &mut carry, Some(&adm)).unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].shape_key, 2, "demoted key is rewritten to the exact length");
        assert!(next_batch_bucketed(&rx, &policy, &mut carry, Some(&adm)).is_none());
    }

    /// With a permissive admission policy, different lengths sharing a
    /// bucket key mix into one batch (bucket purity, not shape purity).
    #[test]
    fn bucketed_batches_mix_lengths_within_one_bucket() {
        let (tx, rx) = mpsc::channel();
        let mk = |vals: Vec<f32>, key: u64| {
            let (resp, rr) = mpsc::channel();
            (
                Request {
                    input: vals,
                    shape_key: key,
                    respond: resp,
                    enqueued: Instant::now(),
                    deadline: None,
                },
                rr,
            )
        };
        let (a, _r1) = mk(vec![0.0; 8], 8);
        let (b, _r2) = mk(vec![1.0; 5], 8);
        let (c, _r3) = mk(vec![2.0; 3], 8);
        for r in [a, b, c] {
            tx.send(r).unwrap();
        }
        drop(tx);
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) };
        let mut carry = None;
        let batch = next_batch_bucketed(&rx, &policy, &mut carry, None).unwrap();
        assert_eq!(batch.len(), 3, "same-bucket lengths must share one batch");
        let lens: Vec<usize> = batch.iter().map(|r| r.input.len()).collect();
        assert_eq!(lens, vec![8, 5, 3]);
    }

    #[test]
    fn carry_survives_channel_close() {
        let (tx, rx) = mpsc::channel();
        let (r1, _k1) = keyed_req(0.0, 1);
        let (r2, _k2) = keyed_req(1.0, 2);
        tx.send(r1).unwrap();
        tx.send(r2).unwrap();
        drop(tx);
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) };
        let mut carry = None;
        let a = next_batch_keyed(&rx, &policy, &mut carry).unwrap();
        assert_eq!(a[0].shape_key, 1);
        // the carried key-2 request still comes out after the channel died
        let b = next_batch_keyed(&rx, &policy, &mut carry).unwrap();
        assert_eq!(b[0].shape_key, 2);
        assert!(next_batch_keyed(&rx, &policy, &mut carry).is_none());
    }

    // --- slack admission -------------------------------------------------

    fn deadline_req(
        v: f32,
        key: u64,
        deadline: Instant,
    ) -> (Request, mpsc::Receiver<anyhow::Result<Vec<f32>>>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                input: vec![v],
                shape_key: key,
                respond: tx,
                enqueued: Instant::now(),
                deadline: Some(deadline),
            },
            rx,
        )
    }

    /// A deadline that cannot be met even by an immediate ship is shed,
    /// not batched — and the shed row surfaces even when it was the
    /// only request of the round.
    #[test]
    fn hopeless_deadline_is_shed_not_batched() {
        let (tx, rx) = mpsc::channel();
        // 10ms of predicted service vs a deadline 1ms out: hopeless.
        let slack = SlackCheck { service_us: 10_000.0, assembly_us: 0.0 };
        let (r, _keep) = deadline_req(1.0, 7, Instant::now() + Duration::from_millis(1));
        let (ok, _keep2) = keyed_req(2.0, 7);
        tx.send(r).unwrap();
        tx.send(ok).unwrap();
        drop(tx);
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) };
        let mut carry = None;
        let out = next_batch_admitted(&rx, &policy, &mut carry, None, Some(&slack)).unwrap();
        assert_eq!(out.shed.len(), 1, "hopeless deadline must be shed");
        assert_eq!(out.batch.len(), 1, "deadline-free request still ships");
        assert_eq!(out.batch[0].input[0], 2.0);
        assert!(next_batch_admitted(&rx, &policy, &mut carry, None, Some(&slack)).is_none());
    }

    /// An admitted tight deadline tightens the batch window: the batch
    /// flushes at the latest feasible ship time instead of waiting out
    /// the full `max_wait`.
    #[test]
    fn tight_deadline_flushes_batch_early() {
        let (tx, rx) = mpsc::channel();
        let slack = SlackCheck { service_us: 0.0, assembly_us: 0.0 };
        // Feasible, but only ~3ms of slack vs a 100ms batch window.
        let (r, _keep) = deadline_req(1.0, 7, Instant::now() + Duration::from_millis(3));
        tx.send(r).unwrap();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(100) };
        let mut carry = None;
        let start = Instant::now();
        let out = next_batch_admitted(&rx, &policy, &mut carry, None, Some(&slack)).unwrap();
        assert_eq!(out.batch.len(), 1);
        assert!(out.shed.is_empty());
        assert!(
            start.elapsed() < Duration::from_millis(60),
            "deadline did not tighten the window: {:?}",
            start.elapsed()
        );
        drop(tx);
    }

    /// Without a slack check, deadlines are inert: nothing is shed and
    /// the window is the ordinary arrival-bounded one.
    #[test]
    fn deadlines_are_inert_without_slack_check() {
        let (tx, rx) = mpsc::channel();
        // Already-expired deadline, but no slack check installed.
        let (r, _keep) = deadline_req(1.0, 7, Instant::now() - Duration::from_millis(5));
        tx.send(r).unwrap();
        drop(tx);
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) };
        let mut carry = None;
        let out = next_batch_admitted(&rx, &policy, &mut carry, None, None).unwrap();
        assert_eq!(out.batch.len(), 1);
        assert!(out.shed.is_empty());
    }
}
