//! Dynamic batching for the online serving loop.
//!
//! The paper's NMT online use case (§6.1) is latency-critical with small
//! batches; the batcher trades a bounded wait for batching efficiency:
//! a batch closes when it reaches `max_batch` requests or when
//! `max_wait` has elapsed since its first request.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// One enqueued inference request.
pub struct Request {
    /// Flattened input row(s) for this request.
    pub input: Vec<f32>,
    /// Where to send the flattened output.
    pub respond: std::sync::mpsc::Sender<anyhow::Result<Vec<f32>>>,
    /// Enqueue timestamp (for latency accounting).
    pub enqueued: Instant,
}

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Collect the next batch from `rx` under `policy`. Blocks for the first
/// request; then fills up to `max_batch` until `max_wait` expires.
/// Returns `None` once the channel is closed and drained.
pub fn next_batch(rx: &Receiver<Request>, policy: &BatchPolicy) -> Option<Vec<Request>> {
    let first = rx.recv().ok()?;
    let deadline = Instant::now() + policy.max_wait;
    let mut batch = vec![first];
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(req) => batch.push(req),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(v: f32) -> (Request, mpsc::Receiver<anyhow::Result<Vec<f32>>>) {
        let (tx, rx) = mpsc::channel();
        (Request { input: vec![v], respond: tx, enqueued: Instant::now() }, rx)
    }

    #[test]
    fn batch_fills_to_capacity() {
        let (tx, rx) = mpsc::channel();
        let mut receivers = Vec::new();
        for i in 0..5 {
            let (r, rr) = req(i as f32);
            receivers.push(rr);
            tx.send(r).unwrap();
        }
        let policy = BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(50) };
        let batch = next_batch(&rx, &policy).unwrap();
        assert_eq!(batch.len(), 3);
        let batch2 = next_batch(&rx, &policy).unwrap();
        assert_eq!(batch2.len(), 2);
    }

    #[test]
    fn deadline_closes_partial_batch() {
        let (tx, rx) = mpsc::channel();
        let (r, _rr) = req(1.0);
        tx.send(r).unwrap();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) };
        let start = Instant::now();
        let batch = next_batch(&rx, &policy).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(start.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn closed_channel_returns_none() {
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }
}
