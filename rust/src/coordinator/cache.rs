//! The compilation cache: compile once, serve every identical request
//! after that from memory.
//!
//! The paper's motivation for all of this machinery is a *serving*
//! system: fusion + tuning cost must be paid once per computation and
//! amortized over latency-critical traffic (§6.1). [`CompileCache`] is
//! a bounded LRU keyed by [`CacheKey`] — the module's structural
//! [`Fingerprint`] plus everything else that shapes the artifact
//! (fusion mode, device, batch-dot policy). [`CompileService`] bundles
//! the cache with a [`PerfLibrary`] and a [`PipelineConfig`] into the
//! one-stop compile front end that the serving loop
//! ([`crate::coordinator::server`]) talks to.
//!
//! Under shape-class bucketing
//! ([`crate::coordinator::buckets::BucketPolicy`]) the cache is keyed
//! on the *bucket's canonical* fingerprint — [`CacheKey::for_class`]
//! fingerprints the module specialized to the bucket's canonical row
//! length — so every concrete shape in a bucket hits one entry and one
//! single-flight cold compile. The bucket policy itself is folded into
//! `config_digest` (via [`PipelineConfig::bucketing`]), so two runs
//! bucketing differently never share artifacts.
//!
//! ```
//! use fusion_stitching::coordinator::cache::CompileService;
//! use fusion_stitching::coordinator::pipeline::{FusionMode, PipelineConfig};
//! use fusion_stitching::hlo::{GraphBuilder, Module, Shape};
//!
//! let mut b = GraphBuilder::new("entry");
//! let x = b.param("x", Shape::f32(&[32, 16]));
//! let e = b.exp(x);
//! let t = b.tanh(e);
//! let module = Module::new("demo", b.finish(t));
//!
//! let mut svc = CompileService::new(PipelineConfig::default());
//! let (cold, hit_a) = svc.compile(&module, FusionMode::FusionStitching).unwrap();
//! let (warm, hit_b) = svc.compile(&module, FusionMode::FusionStitching).unwrap();
//! assert!(!hit_a && hit_b, "second compile must be a cache hit");
//! assert!(std::sync::Arc::ptr_eq(&cold, &warm), "hits share the artifact");
//! assert_eq!(svc.stats().hits, 1);
//! ```

use crate::hlo::{fingerprint_module, Fingerprint, Module};
use crate::schedule::PerfLibrary;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use super::batcher::Rejection;
use super::driver::compile_module_traced;
use super::faults::FaultPlan;
use super::metrics::PassTrace;
use super::pipeline::{CompiledModule, FusionMode, PipelineConfig};

/// Everything that determines a compiled artifact — the memo key.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// Structural hash of the module (ids/names do not matter).
    pub fingerprint: Fingerprint,
    pub mode: FusionMode,
    /// Device name — artifacts are tuned against one cost model.
    pub device: String,
    /// The §2.1 user knob that changes the partition.
    pub fuse_batch_dot: bool,
    /// Digest of every remaining pipeline knob (tuning space,
    /// elementwise thresholds, library efficiency, full device
    /// constants) — two configs differing in any of them never share
    /// an entry.
    pub config_digest: u64,
}

impl CacheKey {
    pub fn new(module: &Module, mode: FusionMode, cfg: &PipelineConfig) -> Self {
        CacheKey {
            fingerprint: fingerprint_module(module),
            mode,
            device: cfg.deep.device.name.clone(),
            fuse_batch_dot: cfg.deep.fuse_batch_dot,
            config_digest: super::driver::config_digest(cfg),
        }
    }

    /// The key of a whole *shape class*: when a `specialize` builder is
    /// available, the fingerprint is taken from the module specialized
    /// to the class's canonical row length
    /// ([`crate::hlo::fingerprint_shape_class`]), so every concrete
    /// shape in the bucket maps to the one canonical entry. Without a
    /// builder this degenerates to [`CacheKey::new`] on the concrete
    /// module — exact-shape keying, bit for bit.
    pub fn for_class(
        module: &Module,
        class: &super::buckets::ShapeClass,
        specialize: Option<fn(usize) -> Module>,
        mode: FusionMode,
        cfg: &PipelineConfig,
    ) -> Self {
        let fingerprint = match specialize {
            Some(spec) => crate::hlo::fingerprint_shape_class(spec, class.canonical_len),
            None => fingerprint_module(module),
        };
        CacheKey {
            fingerprint,
            mode,
            device: cfg.deep.device.name.clone(),
            fuse_batch_dot: cfg.deep.fuse_batch_dot,
            config_digest: super::driver::config_digest(cfg),
        }
    }
}

/// Hit/miss/eviction counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub insertions: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Hit/miss/eviction counters behind atomics, so the read-mostly hit
/// path ([`CompileCache::get`] takes `&self`) can count under a shared
/// `RwLock` read guard.
#[derive(Debug, Default)]
struct AtomicCacheStats {
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    insertions: AtomicU64,
}

impl AtomicCacheStats {
    fn snapshot(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            insertions: self.insertions.load(Ordering::Relaxed),
        }
    }
}

/// One resident artifact plus its LRU recency stamp. The stamp is an
/// atomic so a *hit* — the serving hot path — needs no exclusive access
/// to the cache.
#[derive(Debug)]
struct Entry {
    value: Arc<CompiledModule>,
    last_used: AtomicU64,
}

/// A bounded LRU cache of compiled modules. Values are `Arc`s so the
/// serving loop can hold an artifact while the cache evicts it.
///
/// Lookups take `&self` (recency/stats are atomics): behind an
/// `RwLock`, any number of serving workers hit concurrently while
/// insertions alone need the write guard — see
/// [`SharedCompileService`].
#[derive(Debug)]
pub struct CompileCache {
    map: HashMap<CacheKey, Entry>,
    capacity: usize,
    tick: AtomicU64,
    stats: AtomicCacheStats,
}

impl CompileCache {
    /// `capacity` is the maximum number of resident artifacts (≥ 1).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "cache capacity must be at least 1");
        CompileCache {
            map: HashMap::new(),
            capacity,
            tick: AtomicU64::new(0),
            stats: AtomicCacheStats::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        self.stats.snapshot()
    }

    /// Look up an artifact, refreshing its recency on hit.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<CompiledModule>> {
        match self.probe(key) {
            Some(value) => {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                Some(value)
            }
            None => {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Like [`CompileCache::get`], but without touching the hit/miss
    /// counters — for double-checks inside the single-flight protocol,
    /// which would otherwise count one request several times.
    pub fn probe(&self, key: &CacheKey) -> Option<Arc<CompiledModule>> {
        let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        self.map.get(key).map(|entry| {
            entry.last_used.store(tick, Ordering::Relaxed);
            entry.value.clone()
        })
    }

    /// Insert an artifact, evicting the least-recently-used entry when
    /// the cache is full.
    pub fn insert(&mut self, key: CacheKey, value: Arc<CompiledModule>) {
        let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        if !self.map.contains_key(&key) && self.map.len() >= self.capacity {
            if let Some(victim) = self
                .map
                .iter()
                .min_by_key(|(_, entry)| entry.last_used.load(Ordering::Relaxed))
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&victim);
                self.stats.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.stats.insertions.fetch_add(1, Ordering::Relaxed);
        self.map.insert(key, Entry { value, last_used: AtomicU64::new(tick) });
    }

    /// Replace the artifact under `key` in place — the hot-swap path.
    /// Displacing a resident artifact counts as an *eviction* (the old
    /// module leaves residency), never as a miss: no lookup failed, so
    /// hit-rate dashboards must not dip when autotuning swaps a module.
    pub fn replace(&mut self, key: CacheKey, value: Arc<CompiledModule>) {
        let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        if self.map.contains_key(&key) {
            self.stats.evictions.fetch_add(1, Ordering::Relaxed);
        } else if self.map.len() >= self.capacity {
            if let Some(victim) = self
                .map
                .iter()
                .min_by_key(|(_, entry)| entry.last_used.load(Ordering::Relaxed))
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&victim);
                self.stats.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.stats.insertions.fetch_add(1, Ordering::Relaxed);
        self.map.insert(key, Entry { value, last_used: AtomicU64::new(tick) });
    }

    /// Drop every resident artifact. Each dropped entry counts as an
    /// eviction, and the hit/miss/insertion counters *survive* — a
    /// clear resets residency, not history, so hit-rate dashboards stay
    /// truthful across cache flushes.
    pub fn clear(&mut self) {
        let dropped = self.map.len() as u64;
        self.map.clear();
        self.stats.evictions.fetch_add(dropped, Ordering::Relaxed);
    }
}

/// The compile front end for serving: cache + perf library + config.
///
/// [`CompileService::compile`] answers from the cache when the module's
/// fingerprint (and mode/device) has been seen, and otherwise runs the
/// full instrumented pipeline, keeping the pass trace of the last cold
/// compile for inspection.
#[derive(Debug)]
pub struct CompileService {
    cache: CompileCache,
    lib: PerfLibrary,
    cfg: PipelineConfig,
    last_trace: Option<PassTrace>,
}

/// Default number of resident artifacts per service.
pub const DEFAULT_CACHE_CAPACITY: usize = 64;

impl CompileService {
    pub fn new(cfg: PipelineConfig) -> Self {
        Self::with_capacity(cfg, DEFAULT_CACHE_CAPACITY)
    }

    pub fn with_capacity(cfg: PipelineConfig, capacity: usize) -> Self {
        let lib = PerfLibrary::new(cfg.deep.device.clone());
        CompileService { cache: CompileCache::new(capacity), lib, cfg, last_trace: None }
    }

    /// Compile (or fetch) `module` under `mode`. Returns the artifact
    /// and whether it was served from the cache.
    pub fn compile(
        &mut self,
        module: &Module,
        mode: FusionMode,
    ) -> crate::Result<(Arc<CompiledModule>, bool)> {
        let key = CacheKey::new(module, mode, &self.cfg);
        if let Some(hit) = self.cache.get(&key) {
            return Ok((hit, true));
        }
        let (compiled, trace) = compile_module_traced(module, mode, &mut self.lib, &self.cfg)?;
        self.last_trace = Some(trace);
        let artifact = Arc::new(compiled);
        self.cache.insert(key, artifact.clone());
        Ok((artifact, false))
    }

    pub fn stats(&self) -> CacheStats {
        self.cache.stats()
    }

    pub fn cache(&self) -> &CompileCache {
        &self.cache
    }

    pub fn cache_mut(&mut self) -> &mut CompileCache {
        &mut self.cache
    }

    /// The perf library backing tuning (tuned plans persist here by
    /// fingerprint; see [`PerfLibrary::tuned_insert`]).
    pub fn perf_library(&self) -> &PerfLibrary {
        &self.lib
    }

    pub fn perf_library_mut(&mut self) -> &mut PerfLibrary {
        &mut self.lib
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Pass trace of the most recent *cold* compile.
    pub fn last_trace(&self) -> Option<&PassTrace> {
        self.last_trace.as_ref()
    }
}

/// Mutable compiler state: only *cold* compiles touch it, so it sits
/// behind its own mutex that the hit path never takes.
#[derive(Debug)]
struct CompilerState {
    lib: PerfLibrary,
    last_trace: Option<PassTrace>,
}

/// One in-flight cold compile: waiters block on the condvar until the
/// leader flips the flag.
type InflightSlot = Arc<(Mutex<bool>, Condvar)>;

/// Default negative-cache backoff: first retry after this long.
pub const DEFAULT_FAIL_BACKOFF: Duration = Duration::from_millis(100);
/// Default negative-cache backoff ceiling.
pub const DEFAULT_FAIL_BACKOFF_CAP: Duration = Duration::from_secs(5);

/// One persistently failing compile key: how often it failed, when it
/// last failed, how long to fast-fail before the next real retry, and
/// the error message to echo back meanwhile.
#[derive(Debug, Clone)]
struct FailEntry {
    failures: u32,
    last: Instant,
    backoff: Duration,
    error: String,
}

/// Panic-safe cleanup for the single-flight leader: whatever way the
/// leader exits — success, compile error, or a panic inside the
/// pipeline — the in-flight entry is removed and every waiter is
/// released (on failure one of them retries as the new leader). Without
/// this, a panicking compile would leave waiters blocked on the condvar
/// forever and their shards permanently stuck.
struct FlightGuard<'a> {
    svc: &'a SharedCompileService,
    key: CacheKey,
    slot: InflightSlot,
}

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        let mut inflight =
            self.svc.inflight.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        inflight.remove(&self.key);
        drop(inflight);
        let (done, cv) = &*self.slot;
        *done.lock().unwrap_or_else(std::sync::PoisonError::into_inner) = true;
        cv.notify_all();
    }
}

/// The concurrent compile front end for the multi-worker serving pool
/// ([`crate::coordinator::pool::ServingPool`]).
///
/// [`CompileService`] serializes *every* request — including cache
/// hits — behind whatever mutex the caller wraps it in, which caps
/// serving throughput at one core. This service splits the paths:
///
/// - **Hits** take only the `RwLock` *read* guard (recency and
///   counters are atomics inside [`CompileCache`]), so any number of
///   workers fetch the same hot artifact concurrently and share it by
///   `Arc` clone.
/// - **Cold compiles** are *single-flight per key*: the first worker to
///   miss becomes the leader and runs the pipeline; every other worker
///   that misses the same key blocks on the leader's slot and then
///   reads the freshly inserted artifact — two workers can never
///   redundantly cold-compile one fingerprint.
/// - The pipeline itself (which mutates the [`PerfLibrary`]) runs under
///   a separate compiler mutex that the hit path never touches.
#[derive(Debug)]
pub struct SharedCompileService {
    cache: RwLock<CompileCache>,
    inflight: Mutex<HashMap<CacheKey, InflightSlot>>,
    compiler: Mutex<CompilerState>,
    cfg: PipelineConfig,
    /// Cold pipeline runs actually executed (≤ misses under
    /// contention — the single-flight test gates on this). Background
    /// autotune recompiles count here exactly once each.
    cold_compiles: AtomicU64,
    /// Bumped on every successful hot-swap
    /// ([`SharedCompileService::reexplore_and_swap`]). Serving workers
    /// watch this to invalidate per-worker derived state (resolved
    /// stitched backends) without any lock on the hit path.
    generation: AtomicU64,
    /// Negative-result cache: keys whose compiles keep failing fast-fail
    /// (with the cached error) until an exponential backoff expires,
    /// instead of re-running the whole pipeline on every batch.
    failed: Mutex<HashMap<CacheKey, FailEntry>>,
    /// How many compile calls were answered by the negative cache.
    fast_fails: AtomicU64,
    /// (base, cap) of the exponential failure backoff.
    fail_backoff: Mutex<(Duration, Duration)>,
    /// Optional fault-injection plan; the single-flight leader consults
    /// it before running a real cold compile. Inert unless the `faults`
    /// cargo feature is enabled.
    faults: Mutex<Option<Arc<FaultPlan>>>,
}

impl SharedCompileService {
    pub fn new(cfg: PipelineConfig) -> Self {
        Self::with_capacity(cfg, DEFAULT_CACHE_CAPACITY)
    }

    pub fn with_capacity(cfg: PipelineConfig, capacity: usize) -> Self {
        let lib = PerfLibrary::new(cfg.deep.device.clone());
        SharedCompileService {
            cache: RwLock::new(CompileCache::new(capacity)),
            inflight: Mutex::new(HashMap::new()),
            compiler: Mutex::new(CompilerState { lib, last_trace: None }),
            cfg,
            cold_compiles: AtomicU64::new(0),
            generation: AtomicU64::new(0),
            failed: Mutex::new(HashMap::new()),
            fast_fails: AtomicU64::new(0),
            fail_backoff: Mutex::new((DEFAULT_FAIL_BACKOFF, DEFAULT_FAIL_BACKOFF_CAP)),
            faults: Mutex::new(None),
        }
    }

    /// Install (or clear) a fault-injection plan. The single-flight
    /// leader calls its compile hook before each real cold compile;
    /// without the `faults` cargo feature the hook is a no-op.
    pub fn set_fault_plan(&self, plan: Option<Arc<FaultPlan>>) {
        *self.faults.lock().unwrap_or_else(std::sync::PoisonError::into_inner) = plan;
    }

    /// Override the negative-cache backoff (base, cap) — tests use tiny
    /// values so fast-fail → retry → recovery runs deterministically in
    /// milliseconds.
    pub fn set_failure_backoff(&self, base: Duration, cap: Duration) {
        *self.fail_backoff.lock().unwrap_or_else(std::sync::PoisonError::into_inner) =
            (base, cap.max(base));
    }

    /// How many compile calls the negative cache answered with an
    /// immediate structured failure instead of a pipeline run.
    pub fn compile_fast_fails(&self) -> u64 {
        self.fast_fails.load(Ordering::Relaxed)
    }

    /// Number of keys currently tracked as failing.
    pub fn negative_entries(&self) -> usize {
        self.failed.lock().unwrap_or_else(std::sync::PoisonError::into_inner).len()
    }

    /// If `key` is inside its failure backoff window, the structured
    /// fast-fail error to return. `None` means "try a real compile"
    /// (never failed, or the backoff expired).
    fn negative_lookup(&self, key: &CacheKey) -> Option<anyhow::Error> {
        let failed = self.failed.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let entry = failed.get(key)?;
        if entry.last.elapsed() >= entry.backoff {
            return None; // backoff expired: let the caller retry for real
        }
        let remaining = entry.backoff.saturating_sub(entry.last.elapsed());
        Some(anyhow::Error::new(Rejection::CompileFailed).context(format!(
            "compile fast-fail ({} failure{} so far, next retry in {:?}): {}",
            entry.failures,
            if entry.failures == 1 { "" } else { "s" },
            remaining,
            entry.error
        )))
    }

    /// Record a real compile failure for `key`: bump its failure count
    /// and double its backoff (up to the cap).
    fn record_failure(&self, key: &CacheKey, err: &anyhow::Error) {
        let (base, cap) =
            *self.fail_backoff.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let mut failed = self.failed.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let entry = failed.entry(key.clone()).or_insert(FailEntry {
            failures: 0,
            last: Instant::now(),
            backoff: base,
            error: String::new(),
        });
        entry.failures += 1;
        entry.last = Instant::now();
        entry.backoff = if entry.failures <= 1 { base } else { (entry.backoff * 2).min(cap) };
        entry.error = format!("{err:#}");
    }

    /// A compile for `key` succeeded: forget any failure history.
    fn clear_failure(&self, key: &CacheKey) {
        self.failed.lock().unwrap_or_else(std::sync::PoisonError::into_inner).remove(key);
    }

    /// Compile (or fetch) `module` under `mode`. Returns the artifact
    /// and whether it was served from the cache. Safe to call from any
    /// number of threads; see the type docs for the locking discipline.
    pub fn compile(
        &self,
        module: &Module,
        mode: FusionMode,
    ) -> crate::Result<(Arc<CompiledModule>, bool)> {
        let key = CacheKey::new(module, mode, &self.cfg);
        // Hot path: a shared read guard and an Arc clone, nothing else.
        if let Some(hit) = self.cache.read().expect("cache poisoned").get(&key) {
            return Ok((hit, true));
        }
        loop {
            // Negative cache: a key inside its failure backoff window
            // fast-fails with the cached error instead of re-running
            // the pipeline (also breaks the thundering herd when a
            // failing leader releases its waiters).
            if let Some(err) = self.negative_lookup(&key) {
                self.fast_fails.fetch_add(1, Ordering::Relaxed);
                return Err(err);
            }
            enum Role {
                Leader(InflightSlot),
                Waiter(InflightSlot),
            }
            let role = {
                let mut inflight = self.inflight.lock().expect("inflight poisoned");
                // Double-check under the inflight lock: a leader may
                // have inserted the artifact since our miss.
                if let Some(hit) = self.cache.read().expect("cache poisoned").probe(&key) {
                    return Ok((hit, true));
                }
                match inflight.get(&key) {
                    Some(slot) => Role::Waiter(slot.clone()),
                    None => {
                        let slot: InflightSlot = Arc::new((Mutex::new(false), Condvar::new()));
                        inflight.insert(key.clone(), slot.clone());
                        Role::Leader(slot)
                    }
                }
            };
            match role {
                Role::Leader(slot) => {
                    // The guard's Drop removes the in-flight entry and
                    // wakes every waiter *whatever happens* — success,
                    // compile error, or a panic inside the pipeline.
                    // It runs after the cache insert below, so waiters
                    // re-probing find the artifact (or retry as the new
                    // leader on failure).
                    let _guard = FlightGuard { svc: self, key: key.clone(), slot };
                    let result = {
                        // Recover from poisoning: a previous leader's
                        // panic must not take every future compile
                        // down with it (the perf library only carries
                        // advisory tuning data).
                        let mut state = self
                            .compiler
                            .lock()
                            .unwrap_or_else(std::sync::PoisonError::into_inner);
                        // Fault injection (inert without the `faults`
                        // feature): an injected failure skips the real
                        // pipeline and does not count as a cold compile.
                        let injected = self
                            .faults
                            .lock()
                            .unwrap_or_else(std::sync::PoisonError::into_inner)
                            .as_ref()
                            .map_or(Ok(()), |plan| plan.fire_compile());
                        match injected {
                            Err(e) => Err(e),
                            Ok(()) => {
                                self.cold_compiles.fetch_add(1, Ordering::Relaxed);
                                compile_module_traced(module, mode, &mut state.lib, &self.cfg)
                                    .map(|(compiled, trace)| {
                                        state.last_trace = Some(trace);
                                        Arc::new(compiled)
                                    })
                            }
                        }
                    };
                    match &result {
                        Ok(artifact) => {
                            self.cache
                                .write()
                                .expect("cache poisoned")
                                .insert(key.clone(), artifact.clone());
                            self.clear_failure(&key);
                        }
                        Err(e) => self.record_failure(&key, e),
                    }
                    return result.map(|artifact| (artifact, false));
                }
                Role::Waiter(slot) => {
                    let (done, cv) = &*slot;
                    let mut finished =
                        done.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                    while !*finished {
                        finished = cv
                            .wait(finished)
                            .unwrap_or_else(std::sync::PoisonError::into_inner);
                    }
                    // Loop: the artifact is now resident (or the leader
                    // failed and this thread takes over the compile).
                }
            }
        }
    }

    pub fn stats(&self) -> CacheStats {
        self.cache.read().expect("cache poisoned").stats()
    }

    /// Number of cold pipeline runs actually executed — under
    /// single-flight this stays at one per distinct key no matter how
    /// many workers race on it.
    pub fn cold_compiles(&self) -> u64 {
        self.cold_compiles.load(Ordering::Relaxed)
    }

    pub fn cache_len(&self) -> usize {
        self.cache.read().expect("cache poisoned").len()
    }

    /// The hot-swap generation: how many times
    /// [`Self::reexplore_and_swap`] replaced a resident module.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Relaxed)
    }

    /// Write a measured launch-span snapshot back into the perf
    /// library's persistent measured store (keyed by device-signed group
    /// fingerprint). Returns how many *new* launches the snapshot
    /// contributed; absorbing the same snapshot twice is a no-op.
    pub fn absorb_profile(&self, profile: &crate::obs::KernelProfile) -> u64 {
        let mut state = self.compiler.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        state.lib.absorb_profile(profile)
    }

    /// Monotone counter of measured write-back activity (total launches
    /// absorbed across all groups) — the autotune loop's cheap "is there
    /// anything new to act on?" gate.
    pub fn measured_epoch(&self) -> u64 {
        let state = self.compiler.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        state.lib.measured_epoch()
    }

    /// Fetch the resident artifact for `module`/`mode` without touching
    /// the hit/miss counters (the autotune loop polls with this).
    pub fn probe(&self, module: &Module, mode: FusionMode) -> Option<Arc<CompiledModule>> {
        let key = CacheKey::new(module, mode, &self.cfg);
        self.cache.read().expect("cache poisoned").probe(&key)
    }

    /// Feedback-directed recompile + atomic hot-swap.
    ///
    /// Re-runs the full pipeline with
    /// [`crate::schedule::CostSource::Measured`] — exploration consults
    /// the perf library's wall-clock overlays instead of trusting the
    /// analytic model — and, when the refined plan's
    /// [`crate::fusion::FusionPlan::digest`] differs from the resident
    /// artifact's, atomically replaces the cache entry *under the
    /// original modeled key* and bumps the generation. Serving workers
    /// pick the new module up on their next batch; in-flight batches
    /// finish on the `Arc` they already hold, so nothing blocks or
    /// drops.
    ///
    /// Returns `Ok(None)` when there is nothing to do (no resident
    /// artifact, no measured data yet, or the measured plan is
    /// unchanged); `Ok(Some(new))` after a swap.
    pub fn reexplore_and_swap(
        &self,
        module: &Module,
        mode: FusionMode,
    ) -> crate::Result<Option<Arc<CompiledModule>>> {
        let key = CacheKey::new(module, mode, &self.cfg);
        let Some(current) = self.cache.read().expect("cache poisoned").probe(&key) else {
            return Ok(None);
        };
        let mut measured_cfg = self.cfg.clone();
        measured_cfg.cost_source = crate::schedule::CostSource::Measured;
        let artifact = {
            let mut state =
                self.compiler.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            if state.lib.measured_len() == 0 {
                return Ok(None); // no wall-clock feedback to act on yet
            }
            self.cold_compiles.fetch_add(1, Ordering::Relaxed);
            let (compiled, trace) = compile_module_traced(module, mode, &mut state.lib, &measured_cfg)?;
            state.last_trace = Some(trace);
            Arc::new(compiled)
        };
        if artifact.plan.digest() == current.plan.digest() {
            return Ok(None); // measured feedback agrees with the resident plan
        }
        // Swap under the *modeled* key: serving lookups keep using the
        // unchanged key and atomically start receiving the new module.
        self.cache.write().expect("cache poisoned").replace(key, artifact.clone());
        self.generation.fetch_add(1, Ordering::Relaxed);
        Ok(Some(artifact))
    }

    /// Drop every resident artifact (see [`CompileCache::clear`] for
    /// the stats semantics).
    pub fn clear(&self) {
        self.cache.write().expect("cache poisoned").clear();
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Pass trace of the most recent cold compile (cloned out of the
    /// compiler mutex; tolerant of a previous leader's panic).
    pub fn last_trace(&self) -> Option<PassTrace> {
        self.compiler
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .last_trace
            .clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::{GraphBuilder, Shape};

    fn tiny_module(dim: i64) -> Module {
        let mut b = GraphBuilder::new("entry");
        let x = b.param("x", Shape::f32(&[dim, 16]));
        let e = b.exp(x);
        let t = b.tanh(e);
        Module::new(format!("m{dim}"), b.finish(t))
    }

    #[test]
    fn hit_returns_same_arc_and_counts() {
        let mut svc = CompileService::new(PipelineConfig::default());
        let m = tiny_module(8);
        let (a, hit_a) = svc.compile(&m, FusionMode::FusionStitching).unwrap();
        let (b, hit_b) = svc.compile(&m, FusionMode::FusionStitching).unwrap();
        assert!(!hit_a && hit_b);
        assert!(Arc::ptr_eq(&a, &b));
        let s = svc.stats();
        assert_eq!((s.hits, s.misses, s.insertions), (1, 1, 1));
        assert!(s.hit_rate() > 0.49 && s.hit_rate() < 0.51);
    }

    #[test]
    fn different_modes_are_different_entries() {
        let mut svc = CompileService::new(PipelineConfig::default());
        let m = tiny_module(8);
        let (_, h1) = svc.compile(&m, FusionMode::FusionStitching).unwrap();
        let (_, h2) = svc.compile(&m, FusionMode::XlaBaseline).unwrap();
        assert!(!h1 && !h2);
        assert_eq!(svc.cache().len(), 2);
    }

    #[test]
    fn renamed_module_still_hits() {
        // The whole point of fingerprinting: identity is structural.
        let mut svc = CompileService::new(PipelineConfig::default());
        let m1 = tiny_module(8);
        let mut m2 = tiny_module(8);
        m2.name = "a_totally_different_deployment_label".into();
        for id in m2.entry.ids().collect::<Vec<_>>() {
            m2.entry.get_mut(id).name = format!("other_{}", id.0);
        }
        let (_, h1) = svc.compile(&m1, FusionMode::FusionStitching).unwrap();
        let (_, h2) = svc.compile(&m2, FusionMode::FusionStitching).unwrap();
        assert!(!h1);
        assert!(h2, "renamed module must hit the cache");
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut svc = CompileService::with_capacity(PipelineConfig::default(), 2);
        let (m1, m2, m3) = (tiny_module(4), tiny_module(8), tiny_module(16));
        svc.compile(&m1, FusionMode::FusionStitching).unwrap();
        svc.compile(&m2, FusionMode::FusionStitching).unwrap();
        // touch m1 so m2 becomes the LRU victim
        let (_, h) = svc.compile(&m1, FusionMode::FusionStitching).unwrap();
        assert!(h);
        svc.compile(&m3, FusionMode::FusionStitching).unwrap(); // evicts m2
        assert_eq!(svc.cache().len(), 2);
        assert_eq!(svc.stats().evictions, 1);
        let (_, h1) = svc.compile(&m1, FusionMode::FusionStitching).unwrap();
        let (_, h2) = svc.compile(&m2, FusionMode::FusionStitching).unwrap();
        assert!(h1, "m1 must have survived");
        assert!(!h2, "m2 must have been evicted");
    }

    #[test]
    fn clear_counts_evictions_and_keeps_stats() {
        let mut svc = CompileService::new(PipelineConfig::default());
        let (m1, m2) = (tiny_module(4), tiny_module(8));
        svc.compile(&m1, FusionMode::FusionStitching).unwrap();
        svc.compile(&m2, FusionMode::FusionStitching).unwrap();
        svc.compile(&m1, FusionMode::FusionStitching).unwrap(); // hit
        let before = svc.stats();
        assert_eq!((before.hits, before.misses, before.insertions), (1, 2, 2));

        svc.cache_mut().clear();
        assert!(svc.cache().is_empty());
        let after = svc.stats();
        // dropped residents count as evictions; history survives
        assert_eq!(after.evictions, before.evictions + 2);
        assert_eq!((after.hits, after.misses, after.insertions), (1, 2, 2));

        // post-clear lookups keep counting against the same history
        let (_, hit) = svc.compile(&m1, FusionMode::FusionStitching).unwrap();
        assert!(!hit, "cleared entries must recompile");
        assert_eq!(svc.stats().misses, 3);
        assert!(svc.stats().hit_rate() > 0.0, "hit-rate must not reset to zero");
    }

    #[test]
    fn shared_service_hits_without_exclusive_access() {
        let svc = SharedCompileService::new(PipelineConfig::default());
        let m = tiny_module(8);
        let (cold, hit_a) = svc.compile(&m, FusionMode::FusionStitching).unwrap();
        let (warm, hit_b) = svc.compile(&m, FusionMode::FusionStitching).unwrap();
        assert!(!hit_a && hit_b);
        assert!(Arc::ptr_eq(&cold, &warm));
        assert_eq!(svc.cold_compiles(), 1);
        assert_eq!(svc.stats().hits, 1);
        assert!(svc.last_trace().is_some());
    }

    #[test]
    fn shared_service_single_flight_under_contention() {
        // N threads race on one fingerprint through a barrier: exactly
        // one cold compile may run; everyone shares the same Arc.
        let svc = Arc::new(SharedCompileService::new(PipelineConfig::default()));
        let n = 8;
        let barrier = Arc::new(std::sync::Barrier::new(n));
        let results: Vec<_> = (0..n)
            .map(|_| {
                let svc = svc.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    let m = tiny_module(16);
                    barrier.wait();
                    svc.compile(&m, FusionMode::FusionStitching).unwrap()
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect();
        assert_eq!(svc.cold_compiles(), 1, "single-flight: one pipeline run total");
        let cold_count = results.iter().filter(|(_, hit)| !hit).count();
        assert_eq!(cold_count, 1, "exactly one caller observes the miss");
        for (artifact, _) in &results[1..] {
            assert!(Arc::ptr_eq(artifact, &results[0].0), "all callers share the artifact");
        }
    }

    #[test]
    fn shared_service_distinct_keys_compile_independently() {
        let svc = Arc::new(SharedCompileService::new(PipelineConfig::default()));
        let handles: Vec<_> = [4i64, 8, 16, 32]
            .into_iter()
            .map(|dim| {
                let svc = svc.clone();
                std::thread::spawn(move || {
                    svc.compile(&tiny_module(dim), FusionMode::FusionStitching).unwrap()
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(svc.cold_compiles(), 4);
        assert_eq!(svc.cache_len(), 4);
    }

    #[test]
    fn replace_counts_eviction_not_miss() {
        let svc = SharedCompileService::new(PipelineConfig::default());
        let m = tiny_module(8);
        let (artifact, _) = svc.compile(&m, FusionMode::FusionStitching).unwrap();
        let key = CacheKey::new(&m, FusionMode::FusionStitching, svc.config());
        let before = svc.stats();
        svc.cache.write().unwrap().replace(key, artifact.clone());
        let after = svc.stats();
        assert_eq!(after.evictions, before.evictions + 1, "swap displaces the old artifact");
        assert_eq!(after.misses, before.misses, "a swap is not a lookup failure");
        assert_eq!(after.insertions, before.insertions + 1);
        assert_eq!(svc.cache_len(), 1);
    }

    #[test]
    fn reexplore_without_measured_data_is_a_no_op() {
        let svc = SharedCompileService::new(PipelineConfig::default());
        let m = tiny_module(8);
        svc.compile(&m, FusionMode::FusionStitching).unwrap();
        assert_eq!(svc.cold_compiles(), 1);
        let swapped = svc.reexplore_and_swap(&m, FusionMode::FusionStitching).unwrap();
        assert!(swapped.is_none());
        assert_eq!(svc.cold_compiles(), 1, "no measured data → no background recompile");
        assert_eq!(svc.generation(), 0);
    }

    #[test]
    fn reexplore_with_agreeing_measurements_recompiles_once_without_swap() {
        let svc = SharedCompileService::new(PipelineConfig::default());
        let m = tiny_module(8);
        let (artifact, _) = svc.compile(&m, FusionMode::FusionStitching).unwrap();
        // Wall-clock samples that agree with the model: the measured
        // re-explore must reach the same plan and swap nothing.
        let seeded = artifact.profile.snapshot();
        let mut fed = crate::obs::KernelProfile::default();
        for (fp, g) in seeded.groups() {
            for _ in 0..16 {
                fed.record_launch(fp, g.tier, g.modeled_us, g.modeled_us.max(1.0), 0, 0);
            }
        }
        assert!(svc.absorb_profile(&fed) > 0, "write-back must land");
        let before = svc.stats();
        let swapped = svc.reexplore_and_swap(&m, FusionMode::FusionStitching).unwrap();
        assert!(swapped.is_none(), "agreeing measurements must not change the plan");
        assert_eq!(svc.cold_compiles(), 2, "exactly one background recompile");
        assert_eq!(svc.generation(), 0);
        let after = svc.stats();
        assert_eq!(after.misses, before.misses, "background recompile bypasses miss counting");
        assert_eq!(after.evictions, before.evictions);
    }

    /// Negative-result caching: a failing key fast-fails (structured
    /// `Rejection::CompileFailed`, no pipeline run) while inside its
    /// backoff window, retries for real once the backoff expires, and a
    /// success wipes the failure history.
    #[cfg(feature = "faults")]
    #[test]
    fn failing_compile_key_fast_fails_then_recovers() {
        let svc = SharedCompileService::new(PipelineConfig::default());
        svc.set_failure_backoff(Duration::from_millis(40), Duration::from_millis(200));
        svc.set_fault_plan(Some(Arc::new(FaultPlan::new(7).fail_compiles(1))));
        let m = tiny_module(8);

        // Real attempt #1: injected failure, recorded in the negative cache.
        svc.compile(&m, FusionMode::FusionStitching).unwrap_err();
        assert_eq!(svc.cold_compiles(), 0, "injected failure skips the pipeline");
        assert_eq!(svc.negative_entries(), 1);

        // Within the backoff window: structured fast-fail, still no pipeline.
        let e = svc.compile(&m, FusionMode::FusionStitching).unwrap_err();
        assert_eq!(
            e.downcast_ref::<Rejection>(),
            Some(&Rejection::CompileFailed),
            "fast-fail must carry a structured reason: {e:#}"
        );
        assert_eq!(svc.compile_fast_fails(), 1);
        assert_eq!(svc.cold_compiles(), 0);

        // Past the backoff: a real retry runs and (plan exhausted) succeeds.
        std::thread::sleep(Duration::from_millis(45));
        let (artifact, hit) = svc.compile(&m, FusionMode::FusionStitching).unwrap();
        assert!(!hit);
        assert_eq!(svc.cold_compiles(), 1);
        assert_eq!(svc.negative_entries(), 0, "success clears the failure history");

        let (again, hit2) = svc.compile(&m, FusionMode::FusionStitching).unwrap();
        assert!(hit2);
        assert!(Arc::ptr_eq(&artifact, &again));
    }

    /// Each real failure doubles the backoff up to the cap.
    #[cfg(feature = "faults")]
    #[test]
    fn repeated_failures_grow_the_backoff_exponentially() {
        let svc = SharedCompileService::new(PipelineConfig::default());
        svc.set_failure_backoff(Duration::from_millis(5), Duration::from_millis(40));
        svc.set_fault_plan(Some(Arc::new(FaultPlan::new(0).fail_compiles(u64::MAX))));
        let m = tiny_module(8);
        for expected_ms in [5u64, 10, 20, 40, 40] {
            svc.compile(&m, FusionMode::FusionStitching).unwrap_err();
            let backoff = {
                let failed =
                    svc.failed.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                failed.values().next().expect("entry recorded").backoff
            };
            assert_eq!(backoff, Duration::from_millis(expected_ms));
            // Wait the window out so the next attempt is real, not a
            // fast-fail (which would not grow the backoff).
            std::thread::sleep(backoff + Duration::from_millis(3));
        }
        assert_eq!(svc.cold_compiles(), 0, "injected failures never run the pipeline");
    }

    #[test]
    fn cold_compile_records_a_trace() {
        let mut svc = CompileService::new(PipelineConfig::default());
        assert!(svc.last_trace().is_none());
        svc.compile(&tiny_module(8), FusionMode::FusionStitching).unwrap();
        let trace = svc.last_trace().expect("cold compile leaves a trace");
        assert!(trace.total_us() > 0.0);
        assert!(trace.records.iter().any(|r| r.name == "fingerprint"));
    }
}
