//! The compilation cache: compile once, serve every identical request
//! after that from memory.
//!
//! The paper's motivation for all of this machinery is a *serving*
//! system: fusion + tuning cost must be paid once per computation and
//! amortized over latency-critical traffic (§6.1). [`CompileCache`] is
//! a bounded LRU keyed by [`CacheKey`] — the module's structural
//! [`Fingerprint`] plus everything else that shapes the artifact
//! (fusion mode, device, batch-dot policy). [`CompileService`] bundles
//! the cache with a [`PerfLibrary`] and a [`PipelineConfig`] into the
//! one-stop compile front end that the serving loop
//! ([`crate::coordinator::server`]) talks to.
//!
//! ```
//! use fusion_stitching::coordinator::cache::CompileService;
//! use fusion_stitching::coordinator::pipeline::{FusionMode, PipelineConfig};
//! use fusion_stitching::hlo::{GraphBuilder, Module, Shape};
//!
//! let mut b = GraphBuilder::new("entry");
//! let x = b.param("x", Shape::f32(&[32, 16]));
//! let e = b.exp(x);
//! let t = b.tanh(e);
//! let module = Module::new("demo", b.finish(t));
//!
//! let mut svc = CompileService::new(PipelineConfig::default());
//! let (cold, hit_a) = svc.compile(&module, FusionMode::FusionStitching).unwrap();
//! let (warm, hit_b) = svc.compile(&module, FusionMode::FusionStitching).unwrap();
//! assert!(!hit_a && hit_b, "second compile must be a cache hit");
//! assert!(std::sync::Arc::ptr_eq(&cold, &warm), "hits share the artifact");
//! assert_eq!(svc.stats().hits, 1);
//! ```

use crate::hlo::{fingerprint_module, Fingerprint, Module};
use crate::schedule::PerfLibrary;
use std::collections::HashMap;
use std::sync::Arc;

use super::driver::compile_module_traced;
use super::metrics::PassTrace;
use super::pipeline::{CompiledModule, FusionMode, PipelineConfig};

/// Everything that determines a compiled artifact — the memo key.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// Structural hash of the module (ids/names do not matter).
    pub fingerprint: Fingerprint,
    pub mode: FusionMode,
    /// Device name — artifacts are tuned against one cost model.
    pub device: String,
    /// The §2.1 user knob that changes the partition.
    pub fuse_batch_dot: bool,
    /// Digest of every remaining pipeline knob (tuning space,
    /// elementwise thresholds, library efficiency, full device
    /// constants) — two configs differing in any of them never share
    /// an entry.
    pub config_digest: u64,
}

impl CacheKey {
    pub fn new(module: &Module, mode: FusionMode, cfg: &PipelineConfig) -> Self {
        CacheKey {
            fingerprint: fingerprint_module(module),
            mode,
            device: cfg.deep.device.name.clone(),
            fuse_batch_dot: cfg.deep.fuse_batch_dot,
            config_digest: super::driver::config_digest(cfg),
        }
    }
}

/// Hit/miss/eviction counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub insertions: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A bounded LRU cache of compiled modules. Values are `Arc`s so the
/// serving loop can hold an artifact while the cache evicts it.
#[derive(Debug)]
pub struct CompileCache {
    map: HashMap<CacheKey, (Arc<CompiledModule>, u64)>,
    capacity: usize,
    tick: u64,
    stats: CacheStats,
}

impl CompileCache {
    /// `capacity` is the maximum number of resident artifacts (≥ 1).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "cache capacity must be at least 1");
        CompileCache { map: HashMap::new(), capacity, tick: 0, stats: CacheStats::default() }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Look up an artifact, refreshing its recency on hit.
    pub fn get(&mut self, key: &CacheKey) -> Option<Arc<CompiledModule>> {
        self.tick += 1;
        match self.map.get_mut(key) {
            Some((value, last_used)) => {
                *last_used = self.tick;
                self.stats.hits += 1;
                Some(value.clone())
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert an artifact, evicting the least-recently-used entry when
    /// the cache is full.
    pub fn insert(&mut self, key: CacheKey, value: Arc<CompiledModule>) {
        self.tick += 1;
        if !self.map.contains_key(&key) && self.map.len() >= self.capacity {
            if let Some(victim) = self
                .map
                .iter()
                .min_by_key(|(_, (_, last_used))| *last_used)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&victim);
                self.stats.evictions += 1;
            }
        }
        self.stats.insertions += 1;
        self.map.insert(key, (value, self.tick));
    }

    pub fn clear(&mut self) {
        self.map.clear();
    }
}

/// The compile front end for serving: cache + perf library + config.
///
/// [`CompileService::compile`] answers from the cache when the module's
/// fingerprint (and mode/device) has been seen, and otherwise runs the
/// full instrumented pipeline, keeping the pass trace of the last cold
/// compile for inspection.
#[derive(Debug)]
pub struct CompileService {
    cache: CompileCache,
    lib: PerfLibrary,
    cfg: PipelineConfig,
    last_trace: Option<PassTrace>,
}

/// Default number of resident artifacts per service.
pub const DEFAULT_CACHE_CAPACITY: usize = 64;

impl CompileService {
    pub fn new(cfg: PipelineConfig) -> Self {
        Self::with_capacity(cfg, DEFAULT_CACHE_CAPACITY)
    }

    pub fn with_capacity(cfg: PipelineConfig, capacity: usize) -> Self {
        let lib = PerfLibrary::new(cfg.deep.device.clone());
        CompileService { cache: CompileCache::new(capacity), lib, cfg, last_trace: None }
    }

    /// Compile (or fetch) `module` under `mode`. Returns the artifact
    /// and whether it was served from the cache.
    pub fn compile(
        &mut self,
        module: &Module,
        mode: FusionMode,
    ) -> crate::Result<(Arc<CompiledModule>, bool)> {
        let key = CacheKey::new(module, mode, &self.cfg);
        if let Some(hit) = self.cache.get(&key) {
            return Ok((hit, true));
        }
        let (compiled, trace) = compile_module_traced(module, mode, &mut self.lib, &self.cfg)?;
        self.last_trace = Some(trace);
        let artifact = Arc::new(compiled);
        self.cache.insert(key, artifact.clone());
        Ok((artifact, false))
    }

    pub fn stats(&self) -> CacheStats {
        self.cache.stats()
    }

    pub fn cache(&self) -> &CompileCache {
        &self.cache
    }

    pub fn cache_mut(&mut self) -> &mut CompileCache {
        &mut self.cache
    }

    /// The perf library backing tuning (tuned plans persist here by
    /// fingerprint; see [`PerfLibrary::tuned_insert`]).
    pub fn perf_library(&self) -> &PerfLibrary {
        &self.lib
    }

    pub fn perf_library_mut(&mut self) -> &mut PerfLibrary {
        &mut self.lib
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Pass trace of the most recent *cold* compile.
    pub fn last_trace(&self) -> Option<&PassTrace> {
        self.last_trace.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::{GraphBuilder, Shape};

    fn tiny_module(dim: i64) -> Module {
        let mut b = GraphBuilder::new("entry");
        let x = b.param("x", Shape::f32(&[dim, 16]));
        let e = b.exp(x);
        let t = b.tanh(e);
        Module::new(format!("m{dim}"), b.finish(t))
    }

    #[test]
    fn hit_returns_same_arc_and_counts() {
        let mut svc = CompileService::new(PipelineConfig::default());
        let m = tiny_module(8);
        let (a, hit_a) = svc.compile(&m, FusionMode::FusionStitching).unwrap();
        let (b, hit_b) = svc.compile(&m, FusionMode::FusionStitching).unwrap();
        assert!(!hit_a && hit_b);
        assert!(Arc::ptr_eq(&a, &b));
        let s = svc.stats();
        assert_eq!((s.hits, s.misses, s.insertions), (1, 1, 1));
        assert!(s.hit_rate() > 0.49 && s.hit_rate() < 0.51);
    }

    #[test]
    fn different_modes_are_different_entries() {
        let mut svc = CompileService::new(PipelineConfig::default());
        let m = tiny_module(8);
        let (_, h1) = svc.compile(&m, FusionMode::FusionStitching).unwrap();
        let (_, h2) = svc.compile(&m, FusionMode::XlaBaseline).unwrap();
        assert!(!h1 && !h2);
        assert_eq!(svc.cache().len(), 2);
    }

    #[test]
    fn renamed_module_still_hits() {
        // The whole point of fingerprinting: identity is structural.
        let mut svc = CompileService::new(PipelineConfig::default());
        let m1 = tiny_module(8);
        let mut m2 = tiny_module(8);
        m2.name = "a_totally_different_deployment_label".into();
        for id in m2.entry.ids().collect::<Vec<_>>() {
            m2.entry.get_mut(id).name = format!("other_{}", id.0);
        }
        let (_, h1) = svc.compile(&m1, FusionMode::FusionStitching).unwrap();
        let (_, h2) = svc.compile(&m2, FusionMode::FusionStitching).unwrap();
        assert!(!h1);
        assert!(h2, "renamed module must hit the cache");
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut svc = CompileService::with_capacity(PipelineConfig::default(), 2);
        let (m1, m2, m3) = (tiny_module(4), tiny_module(8), tiny_module(16));
        svc.compile(&m1, FusionMode::FusionStitching).unwrap();
        svc.compile(&m2, FusionMode::FusionStitching).unwrap();
        // touch m1 so m2 becomes the LRU victim
        let (_, h) = svc.compile(&m1, FusionMode::FusionStitching).unwrap();
        assert!(h);
        svc.compile(&m3, FusionMode::FusionStitching).unwrap(); // evicts m2
        assert_eq!(svc.cache().len(), 2);
        assert_eq!(svc.stats().evictions, 1);
        let (_, h1) = svc.compile(&m1, FusionMode::FusionStitching).unwrap();
        let (_, h2) = svc.compile(&m2, FusionMode::FusionStitching).unwrap();
        assert!(h1, "m1 must have survived");
        assert!(!h2, "m2 must have been evicted");
    }

    #[test]
    fn cold_compile_records_a_trace() {
        let mut svc = CompileService::new(PipelineConfig::default());
        assert!(svc.last_trace().is_none());
        svc.compile(&tiny_module(8), FusionMode::FusionStitching).unwrap();
        let trace = svc.last_trace().expect("cold compile leaves a trace");
        assert!(trace.total_us() > 0.0);
        assert!(trace.records.iter().any(|r| r.name == "fingerprint"));
    }
}
