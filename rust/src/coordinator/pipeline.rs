//! The compilation pipeline (Fig. 4) and the evaluation harness.
//!
//! `compile_module` runs one module through the instrumented pass
//! pipeline of [`crate::coordinator::driver`] (fingerprint → fusion →
//! validation → schedule planning + code generation → simulation);
//! `evaluate` runs a benchmark under both the XLA baseline and
//! FusionStitching and derives every number the paper's evaluation
//! reports: Fig. 6 (execution breakdown), Fig. 7 (fusion ratio), Fig. 8
//! (FusionSpeedup / predicted E2E / measured E2E) and Table 3
//! (shared-memory statistics).

use crate::codegen::KernelPlan;
use crate::exec::StitchedExecutable;
use crate::fusion::{DeepFusionConfig, ExploreStats, FusionPlan};
use crate::gpusim::executor::ModuleTiming;
use crate::hlo::{Fingerprint, Module};
use crate::models::ModelMeta;
use crate::schedule::PerfLibrary;
use std::sync::Arc;

/// Which fusion pass compiles the module.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FusionMode {
    XlaBaseline,
    FusionStitching,
}

/// Pipeline knobs.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub deep: DeepFusionConfig,
    /// Fraction of peak the vendor library achieves (cuBLAS/cuDNN class).
    pub lib_efficiency: f64,
    /// Which [`crate::schedule::CostOracle`] fusion consumes: the
    /// analytic model (default, bit-identical to the historical path) or
    /// the measured overlay built from the perf library's launch-span
    /// write-backs — the serving pool's background re-explore compiles
    /// with `Measured`.
    pub cost_source: crate::schedule::CostSource,
    /// Serving-level shape-class policy
    /// ([`crate::coordinator::buckets::BucketPolicy`]), recorded here
    /// so it participates in the compile-cache identity: artifacts
    /// compiled for a bucket's canonical shape must never be shared
    /// with a run under a different bucketing. Compilation itself stays
    /// shape-driven by the module; the policy only changes *which*
    /// canonical module gets compiled. The default (`Exact`) is the
    /// degenerate one-shape-per-bucket policy and leaves the digest's
    /// inputs — and hence all historical cache keys — unchanged in
    /// meaning.
    pub bucketing: super::buckets::BucketPolicy,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            deep: DeepFusionConfig::default(),
            lib_efficiency: 0.70,
            cost_source: crate::schedule::CostSource::Modeled,
            bucketing: super::buckets::BucketPolicy::Exact,
        }
    }
}

/// A fully compiled module: the kernel partition, per-kernel plans and
/// the simulated execution timing. `Clone` is cheap enough to allow
/// cached artifacts to be shared by value, though the
/// [`crate::coordinator::cache::CompileCache`] hands out `Arc`s.
#[derive(Debug, Clone)]
pub struct CompiledModule {
    pub name: String,
    pub mode: FusionMode,
    /// Structural fingerprint of the source module — the cache identity.
    pub fingerprint: Fingerprint,
    pub plan: FusionPlan,
    /// What cost-guided exploration did to the greedy plan (`None` when
    /// the pass was skipped: baseline mode or `--no-cost-fusion`).
    pub explore: Option<ExploreStats>,
    /// Kernel plans for generated (non-library) groups, aligned with
    /// `generated_group_ids`.
    pub kernels: Vec<KernelPlan>,
    pub generated_group_ids: Vec<usize>,
    pub timing: ModuleTiming,
    /// The lowered stitched-VM executable — one launch per fused group
    /// (`None` when the module uses ops outside the VM's subset; see
    /// `exec_error`). Cached artifacts carry it, so cache hits skip
    /// lowering along with everything else.
    pub executable: Option<Arc<StitchedExecutable>>,
    /// Why lowering was skipped, when it was.
    pub exec_error: Option<String>,
    /// Measured per-fused-group launch profile, seeded at compile time
    /// with every lowered kernel's fingerprint + modeled cost and fed
    /// by the VM on each launch (shared: every executor of this module
    /// writes the same profile, so serving stats and the
    /// modeled-vs-measured divergence report see all traffic).
    pub profile: crate::obs::KernelProfileHandle,
}

impl CompiledModule {
    /// The executable's memory-plan compression: arena bytes actually
    /// planned vs. the sum of all value sizes (what the boxed VM
    /// allocated per run), plus the derived reuse ratio. `None` when
    /// the module did not lower.
    pub fn arena_stats(&self) -> Option<crate::exec::ArenaStats> {
        self.executable.as_ref().map(|e| e.mem.stats())
    }

    /// Table 3 row: (avg shm bytes, max shm bytes, #kernels that shrank,
    /// average shared ratio over kernels that allocate).
    pub fn shm_stats(&self) -> (f64, usize, usize, f64) {
        if self.kernels.is_empty() {
            return (0.0, 0, 0, 0.0);
        }
        let total: usize = self.kernels.iter().map(|k| k.shm.total_bytes).sum();
        let max = self.kernels.iter().map(|k| k.shm.total_bytes).max().unwrap_or(0);
        let shrinks = self.kernels.iter().filter(|k| k.shm.shrink_triggered()).count();
        let alloc_kernels: Vec<&KernelPlan> =
            self.kernels.iter().filter(|k| k.shm.total_bytes > 0).collect();
        let shared_ratio = if alloc_kernels.is_empty() {
            0.0
        } else {
            alloc_kernels.iter().map(|k| k.shm.shared_ratio()).sum::<f64>()
                / alloc_kernels.len() as f64
        };
        (total as f64 / self.kernels.len() as f64, max, shrinks, shared_ratio)
    }
}

/// Compile one module under the chosen fusion mode through the standard
/// pass pipeline (see [`crate::coordinator::driver`] for the pass list
/// and for [`crate::coordinator::driver::compile_module_traced`], which
/// additionally returns the per-pass instrumentation).
pub fn compile_module(
    module: &Module,
    mode: FusionMode,
    lib: &mut PerfLibrary,
    cfg: &PipelineConfig,
) -> crate::Result<CompiledModule> {
    super::driver::compile_module_traced(module, mode, lib, cfg).map(|(compiled, _)| compiled)
}

// ---------------------------------------------------------------------
// Evaluation harness (Figs. 6–8, Table 3)
// ---------------------------------------------------------------------

/// Everything the paper reports for one benchmark.
#[derive(Debug, Clone)]
pub struct ModuleReport {
    pub name: &'static str,
    // Fig. 7
    pub baseline_kernels: usize,
    pub fs_kernels: usize,
    pub fusion_ratio: f64,
    // Fig. 6
    pub library_us: f64,
    pub baseline_fusable_us: f64,
    pub fusable_ratio: f64,
    // Fig. 8
    pub fs_fusable_us: f64,
    pub fusion_speedup: f64,
    pub predicted_e2e: f64,
    pub measured_e2e: f64,
    // Table 3
    pub shm_avg_bytes: f64,
    pub shm_max_bytes: usize,
    pub shm_shrinks: usize,
    pub shm_shared_ratio: f64,
}

/// Run one benchmark under both modes and derive the paper's metrics.
pub fn evaluate(
    meta: &ModelMeta,
    module: &Module,
    lib: &mut PerfLibrary,
    cfg: &PipelineConfig,
) -> crate::Result<ModuleReport> {
    let mut cfg = cfg.clone();
    cfg.deep.fuse_batch_dot = meta.fuse_batch_dot;

    let base = compile_module(module, FusionMode::XlaBaseline, lib, &cfg)?;
    let fs = compile_module(module, FusionMode::FusionStitching, lib, &cfg)?;

    let baseline_kernels = base.plan.generated_kernel_count(&module.entry);
    let fs_kernels = fs.plan.generated_kernel_count(&module.entry);
    let fusion_ratio = fs_kernels as f64 / baseline_kernels.max(1) as f64;

    let fusable_ratio = base.timing.fusable_ratio();
    let fusion_speedup = base.timing.fusable_us / fs.timing.fusable_us.max(1e-9);
    // §6.4's empirical prediction formula.
    let predicted_e2e = 1.0 + fusable_ratio * (1.0 - 1.0 / fusion_speedup);
    let measured_e2e = base.timing.total_us() / fs.timing.total_us().max(1e-9);

    let (shm_avg_bytes, shm_max_bytes, shm_shrinks, shm_shared_ratio) = fs.shm_stats();

    Ok(ModuleReport {
        name: meta.name,
        baseline_kernels,
        fs_kernels,
        fusion_ratio,
        library_us: base.timing.library_us,
        baseline_fusable_us: base.timing.fusable_us,
        fusable_ratio,
        fs_fusable_us: fs.timing.fusable_us,
        fusion_speedup,
        predicted_e2e,
        measured_e2e,
        shm_avg_bytes,
        shm_max_bytes,
        shm_shrinks,
        shm_shared_ratio,
    })
}

/// Geometric mean helper used by the headline claims ("another 55%
/// reduction … geometric mean", "average speedup 1.74").
pub fn geomean(xs: impl IntoIterator<Item = f64>) -> f64 {
    let mut log_sum = 0.0;
    let mut n = 0usize;
    for x in xs {
        log_sum += x.max(1e-12).ln();
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        (log_sum / n as f64).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::DeviceConfig;
    use crate::models;

    fn quick_eval(name: &str) -> ModuleReport {
        let (meta, module) = models::by_name(name).unwrap();
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        evaluate(&meta, &module, &mut lib, &PipelineConfig::default()).unwrap()
    }

    #[test]
    fn nmt_fusion_ratio_below_one() {
        let r = quick_eval("NMT");
        assert!(r.fusion_ratio < 1.0, "ratio = {}", r.fusion_ratio);
        assert!(r.fs_kernels >= 1);
        assert!(r.fusion_speedup > 1.0, "speedup = {}", r.fusion_speedup);
    }

    #[test]
    fn lr_compiles_both_modes() {
        let (_, module) = models::by_name("LR").unwrap();
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let cfg = PipelineConfig::default();
        let base = compile_module(&module, FusionMode::XlaBaseline, &mut lib, &cfg).unwrap();
        let fs = compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        assert!(fs.plan.generated_kernel_count(&module.entry)
            <= base.plan.generated_kernel_count(&module.entry));
        assert_eq!(base.timing.library_kernels, fs.timing.library_kernels);
        // the memory plan's compression is observable on the artifact
        let stats = fs.arena_stats().expect("LR lowers to an executable");
        assert!(stats.arena_bytes > 0);
        assert!(stats.value_bytes >= stats.arena_bytes);
        assert!(stats.reuse_ratio() >= 1.0);
    }

    #[test]
    fn predicted_tracks_measured() {
        // Fig. 8's observation: the launch/footprint model makes the
        // empirical formula a good predictor.
        let r = quick_eval("LR");
        assert!((r.predicted_e2e - r.measured_e2e).abs() / r.measured_e2e < 0.35,
            "predicted {} vs measured {}", r.predicted_e2e, r.measured_e2e);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean([4.0, 1.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(std::iter::empty::<f64>()), 0.0);
    }
}
