//! Artifact registry + typed execution wrapper over compiled models.

use super::client::Runtime;
use super::interp::HloProgram;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled artifact ready to execute.
pub struct LoadedModel {
    pub name: String,
    exe: HloProgram,
}

impl LoadedModel {
    /// Execute with f32 inputs given as `(data, dims)` pairs; returns the
    /// flattened f32 outputs (artifacts are lowered with
    /// `return_tuple=True`, so the root is usually a tuple; each tuple
    /// element becomes one output buffer).
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let buffers: Vec<Vec<f32>> = inputs
            .iter()
            .map(|(data, dims)| -> Result<Vec<f32>> {
                let expect: i64 = dims.iter().product();
                if expect != data.len() as i64 {
                    bail!("input length {} does not match dims {dims:?}", data.len());
                }
                Ok(data.to_vec())
            })
            .collect::<Result<_>>()?;
        self.exe.execute(&buffers)
    }
}

/// The artifact registry: loads every `*.hlo.txt` under `artifacts/` and
/// serves compiled executables by stem name (e.g. `attention_fused`).
pub struct Engine {
    rt: Runtime,
    models: HashMap<String, LoadedModel>,
    dir: PathBuf,
}

impl Engine {
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        Ok(Engine { rt: Runtime::cpu()?, models: HashMap::new(), dir: artifact_dir.to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.rt.platform()
    }

    /// Load (and compile) one artifact by stem; idempotent.
    pub fn load(&mut self, stem: &str) -> Result<&LoadedModel> {
        if !self.models.contains_key(stem) {
            let path = self.dir.join(format!("{stem}.hlo.txt"));
            let exe = self.rt.load_hlo_text(&path)?;
            self.models.insert(stem.to_string(), LoadedModel { name: stem.to_string(), exe });
        }
        Ok(&self.models[stem])
    }

    pub fn get(&self, stem: &str) -> Option<&LoadedModel> {
        self.models.get(stem)
    }

    /// Load every artifact in the directory. Returns loaded stems.
    pub fn load_all(&mut self) -> Result<Vec<String>> {
        let mut stems = Vec::new();
        for entry in std::fs::read_dir(&self.dir)
            .with_context(|| format!("reading artifact dir {}", self.dir.display()))?
        {
            let path = entry?.path();
            if let Some(name) = path.file_name().and_then(|s| s.to_str()) {
                if let Some(stem) = name.strip_suffix(".hlo.txt") {
                    let stem = stem.to_string();
                    self.load(&stem)?;
                    stems.push(stem);
                }
            }
        }
        stems.sort();
        Ok(stems)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    /// A tiny hand-written HLO module: f(x) = (x + x,) over f32[2].
    /// Validates the full load→compile→execute path without python.
    const ADD_HLO: &str = r#"HloModule add_self, entry_computation_layout={(f32[2]{0})->(f32[2]{0})}

ENTRY main {
  p0 = f32[2]{0} parameter(0)
  sum = f32[2]{0} add(p0, p0)
  ROOT t = (f32[2]{0}) tuple(sum)
}
"#;

    #[test]
    fn roundtrip_hand_written_hlo() {
        let dir = TempDir::new("engine");
        std::fs::write(dir.path().join("add_self.hlo.txt"), ADD_HLO).unwrap();
        let mut engine = Engine::new(dir.path()).unwrap();
        let stems = engine.load_all().unwrap();
        assert_eq!(stems, vec!["add_self"]);
        let model = engine.get("add_self").unwrap();
        let out = model.run_f32(&[(&[1.5f32, -2.0], &[2])]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], vec![3.0f32, -4.0]);
    }

    #[test]
    fn input_shape_mismatch_rejected() {
        let dir = TempDir::new("engine2");
        std::fs::write(dir.path().join("add_self.hlo.txt"), ADD_HLO).unwrap();
        let mut engine = Engine::new(dir.path()).unwrap();
        engine.load("add_self").unwrap();
        let model = engine.get("add_self").unwrap();
        assert!(model.run_f32(&[(&[1.0f32, 2.0, 3.0], &[2])]).is_err());
    }
}
