//! Artifact registry + typed execution wrapper over compiled models.
//!
//! The engine serves models behind one `run_f32` surface and
//! dispatches per program between two backends:
//!
//! - the **op-by-op interpreter** ([`super::interp::HloProgram`]) for
//!   `*.hlo.txt` artifacts — one kernel launch per instruction, the
//!   paper's fine-granularity baseline;
//! - the **stitched VM** ([`crate::exec::StitchedExecutable`]) for
//!   compiled modules registered via [`Engine::register_stitched`] —
//!   one launch per fused group.
//!
//! Either way a cumulative [`LaunchLedger`] is kept per model, so the
//! serving loop can report real launch counts
//! ([`crate::coordinator::server::WorkerStats`]).

use super::client::Runtime;
use super::interp::HloProgram;
use crate::exec::{ExecArena, LaunchLedger, StitchedExecutable};
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Which executor backs a loaded model. Both backends are immutable
/// and `Arc`-shared: a multi-worker serving pool parses/compiles an
/// artifact once and registers the same program into every worker's
/// engine ([`Engine::register_program`]).
enum Backend {
    /// Op-by-op HLO-text interpreter (per-op launches).
    Interp(Arc<HloProgram>),
    /// Stitched VM executable (one launch per fused group).
    Stitched(Arc<StitchedExecutable>),
}

/// A compiled artifact ready to execute.
pub struct LoadedModel {
    pub name: String,
    backend: Backend,
    ledger: RefCell<LaunchLedger>,
    /// Pooled execution state for the stitched backend: the planned
    /// value arena plus per-thread scratch, reused across `run_f32`
    /// calls so steady-state execution performs no arena allocations.
    arena: RefCell<ExecArena>,
}

impl LoadedModel {
    /// Execute with f32 inputs given as `(data, dims)` pairs; returns the
    /// flattened f32 outputs (text artifacts are lowered with
    /// `return_tuple=True`, so the root is usually a tuple; each tuple
    /// element becomes one output buffer).
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        for (data, dims) in inputs {
            let expect: i64 = dims.iter().product();
            if expect != data.len() as i64 {
                bail!("input length {} does not match dims {dims:?}", data.len());
            }
        }
        match &self.backend {
            Backend::Interp(prog) => {
                // One coarse launch span per interpreted run: the HLO
                // interpreter executes op-at-a-time with no per-launch
                // timing, so the whole batch is the smallest span the
                // flight recorder can attribute here (the stitched
                // backend records per-kernel spans inside `run_into`).
                let span = crate::obs::begin();
                let buffers: Vec<Vec<f32>> =
                    inputs.iter().map(|(data, _)| data.to_vec()).collect();
                let out = prog.execute(&buffers)?;
                let (generated, library) = prog.launch_profile();
                let mut ledger = self.ledger.borrow_mut();
                ledger.generated += generated;
                ledger.library += library;
                crate::obs::record(crate::obs::SpanCat::Launch, "interp-batch", 0, span);
                Ok(out)
            }
            Backend::Stitched(exe) => {
                // No input clone: slices go straight into the pooled
                // arena (written exactly once per run).
                let refs: Vec<&[f32]> = inputs.iter().map(|(data, _)| *data).collect();
                let mut arena = self.arena.borrow_mut();
                let mut out = Vec::new();
                let run_ledger = exe.run_into(&refs, &mut arena, &mut out)?;
                self.ledger.borrow_mut().merge(&run_ledger);
                Ok(vec![out])
            }
        }
    }

    /// Engine-side admissibility check under shape-class bucketing: a
    /// request may only execute if its row fits inside its claimed
    /// bucket's canonical length (shorter rows are padded up; longer
    /// rows would be silently truncated by the batch assembly, so a
    /// lying or colliding `shape_key` must be rejected here, not
    /// trusted). The serving loop counts the rejection in
    /// [`crate::coordinator::server::WorkerStats::rejected`].
    pub fn validate_row(
        &self,
        row_len: usize,
        class: &crate::coordinator::buckets::ShapeClass,
    ) -> Result<()> {
        if !class.admits(row_len) {
            bail!(
                "request row has {row_len} elements but claims {class}; \
                 admissible rows carry at most {} elements",
                class.canonical_len
            );
        }
        Ok(())
    }

    /// Which executor backs this model.
    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            Backend::Interp(_) => "interp",
            Backend::Stitched(_) => "stitched",
        }
    }

    /// Cumulative launch counts across every `run_f32` on this model.
    pub fn launch_ledger(&self) -> LaunchLedger {
        *self.ledger.borrow()
    }
}

/// The artifact registry: loads every `*.hlo.txt` under `artifacts/`
/// (interpreter backend) and serves compiled executables by stem name
/// (e.g. `attention_fused`); stitched executables register directly.
pub struct Engine {
    rt: Runtime,
    models: HashMap<String, LoadedModel>,
    dir: PathBuf,
}

impl Engine {
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        Ok(Engine { rt: Runtime::cpu()?, models: HashMap::new(), dir: artifact_dir.to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.rt.platform()
    }

    /// Load (and compile) one text artifact by stem; idempotent.
    pub fn load(&mut self, stem: &str) -> Result<&LoadedModel> {
        if !self.models.contains_key(stem) {
            let path = self.dir.join(format!("{stem}.hlo.txt"));
            let exe = self.rt.load_hlo_text(&path)?;
            self.register_program(stem, Arc::new(exe));
        }
        Ok(&self.models[stem])
    }

    /// Parse one text artifact into a shareable program *without*
    /// registering it anywhere: a serving pool parses once up front
    /// (failing fast before any worker spawns) and registers the same
    /// `Arc` into every worker's engine via [`Engine::register_program`],
    /// instead of re-parsing the artifact N times.
    pub fn parse_artifact(artifact_dir: &Path, stem: &str) -> Result<Arc<HloProgram>> {
        let rt = Runtime::cpu()?;
        let path = artifact_dir.join(format!("{stem}.hlo.txt"));
        Ok(Arc::new(rt.load_hlo_text(&path)?))
    }

    /// Register an already-parsed interpreter program under `stem`
    /// (replacing any model of the same name). The per-model
    /// [`LaunchLedger`] stays local to this engine even when the
    /// program `Arc` is shared across engines.
    pub fn register_program(&mut self, stem: &str, prog: Arc<HloProgram>) {
        self.models.insert(
            stem.to_string(),
            LoadedModel {
                name: stem.to_string(),
                backend: Backend::Interp(prog),
                ledger: RefCell::new(LaunchLedger::default()),
                arena: RefCell::new(ExecArena::default()),
            },
        );
    }

    /// Register a stitched-VM executable under `stem` (replacing any
    /// artifact of the same name): subsequent `run_f32` calls execute
    /// one launch per fused group instead of one per op.
    pub fn register_stitched(&mut self, stem: &str, exe: Arc<StitchedExecutable>) {
        self.models.insert(
            stem.to_string(),
            LoadedModel {
                name: stem.to_string(),
                backend: Backend::Stitched(exe),
                ledger: RefCell::new(LaunchLedger::default()),
                arena: RefCell::new(ExecArena::default()),
            },
        );
    }

    pub fn get(&self, stem: &str) -> Option<&LoadedModel> {
        self.models.get(stem)
    }

    /// Load every artifact in the directory. Returns loaded stems.
    pub fn load_all(&mut self) -> Result<Vec<String>> {
        let mut stems = Vec::new();
        for entry in std::fs::read_dir(&self.dir)
            .with_context(|| format!("reading artifact dir {}", self.dir.display()))?
        {
            let path = entry?.path();
            if let Some(name) = path.file_name().and_then(|s| s.to_str()) {
                if let Some(stem) = name.strip_suffix(".hlo.txt") {
                    let stem = stem.to_string();
                    self.load(&stem)?;
                    stems.push(stem);
                }
            }
        }
        stems.sort();
        Ok(stems)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    /// A tiny hand-written HLO module: f(x) = (x + x,) over f32[2].
    /// Validates the full load→compile→execute path without python.
    const ADD_HLO: &str = r#"HloModule add_self, entry_computation_layout={(f32[2]{0})->(f32[2]{0})}

ENTRY main {
  p0 = f32[2]{0} parameter(0)
  sum = f32[2]{0} add(p0, p0)
  ROOT t = (f32[2]{0}) tuple(sum)
}
"#;

    #[test]
    fn roundtrip_hand_written_hlo() {
        let dir = TempDir::new("engine");
        std::fs::write(dir.path().join("add_self.hlo.txt"), ADD_HLO).unwrap();
        let mut engine = Engine::new(dir.path()).unwrap();
        let stems = engine.load_all().unwrap();
        assert_eq!(stems, vec!["add_self"]);
        let model = engine.get("add_self").unwrap();
        assert_eq!(model.backend_name(), "interp");
        let out = model.run_f32(&[(&[1.5f32, -2.0], &[2])]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], vec![3.0f32, -4.0]);
        // one generated launch (the add) recorded per execution
        assert_eq!(model.launch_ledger().generated, 1);
        model.run_f32(&[(&[0.0f32, 0.0], &[2])]).unwrap();
        assert_eq!(model.launch_ledger().generated, 2);
    }

    #[test]
    fn input_shape_mismatch_rejected() {
        let dir = TempDir::new("engine2");
        std::fs::write(dir.path().join("add_self.hlo.txt"), ADD_HLO).unwrap();
        let mut engine = Engine::new(dir.path()).unwrap();
        engine.load("add_self").unwrap();
        let model = engine.get("add_self").unwrap();
        assert!(model.run_f32(&[(&[1.0f32, 2.0, 3.0], &[2])]).is_err());
    }

    #[test]
    fn validate_row_rejects_rows_beyond_the_claimed_bucket() {
        use crate::coordinator::buckets::ShapeClass;
        let dir = TempDir::new("engine-validate");
        std::fs::write(dir.path().join("add_self.hlo.txt"), ADD_HLO).unwrap();
        let mut engine = Engine::new(dir.path()).unwrap();
        engine.load("add_self").unwrap();
        let model = engine.get("add_self").unwrap();
        let class = ShapeClass { bucket: 32, canonical_len: 32 };
        assert!(model.validate_row(32, &class).is_ok());
        assert!(model.validate_row(5, &class).is_ok(), "short rows pad, never reject");
        let err = model.validate_row(33, &class).unwrap_err().to_string();
        assert!(err.contains("33 elements"), "{err}");
        assert!(err.contains("bucket 32"), "{err}");
    }

    #[test]
    fn shared_program_keeps_per_engine_ledgers() {
        let dir = TempDir::new("engine-share");
        std::fs::write(dir.path().join("add_self.hlo.txt"), ADD_HLO).unwrap();
        let prog = Engine::parse_artifact(dir.path(), "add_self").unwrap();
        let mut e1 = Engine::new(dir.path()).unwrap();
        let mut e2 = Engine::new(dir.path()).unwrap();
        e1.register_program("add_self", prog.clone());
        e2.register_program("add_self", prog);
        e1.get("add_self").unwrap().run_f32(&[(&[1.0f32, 2.0], &[2])]).unwrap();
        e1.get("add_self").unwrap().run_f32(&[(&[1.0f32, 2.0], &[2])]).unwrap();
        e2.get("add_self").unwrap().run_f32(&[(&[3.0f32, 4.0], &[2])]).unwrap();
        // one shared program, independent launch accounting per engine
        assert_eq!(e1.get("add_self").unwrap().launch_ledger().generated, 2);
        assert_eq!(e2.get("add_self").unwrap().launch_ledger().generated, 1);
    }

    #[test]
    fn stitched_backend_dispatches_and_counts_launches() {
        use crate::coordinator::pipeline::{compile_module, FusionMode, PipelineConfig};
        use crate::gpusim::DeviceConfig;
        use crate::hlo::{GraphBuilder, Module, Shape};
        use crate::schedule::PerfLibrary;

        let mut b = GraphBuilder::new("entry");
        let x = b.param("x", Shape::f32(&[4, 3]));
        let e = b.exp(x);
        let t = b.tanh(e);
        let module = Module::new("served", b.finish(t));
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let compiled = compile_module(
            &module,
            FusionMode::FusionStitching,
            &mut lib,
            &PipelineConfig::default(),
        )
        .unwrap();
        let exe = compiled.executable.clone().expect("must lower");

        let dir = TempDir::new("engine3");
        let mut engine = Engine::new(dir.path()).unwrap();
        engine.register_stitched("served", exe);
        let model = engine.get("served").unwrap();
        assert_eq!(model.backend_name(), "stitched");
        let input: Vec<f32> = (0..12).map(|i| i as f32 * 0.1).collect();
        let out = model.run_f32(&[(&input, &[4, 3])]).unwrap();
        assert!((out[0][0] - (0.0f32).exp().tanh()).abs() < 1e-6);
        let ledger = model.launch_ledger();
        // exp∘tanh fuses into one generated kernel launch
        assert_eq!(ledger.generated, 1);
        assert_eq!(ledger.library, 0);
    }
}
