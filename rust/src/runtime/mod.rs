//! The execution runtime: loads AOT-compiled HLO artifacts (produced
//! once by `python/compile/aot.py` from the JAX/Pallas layers) and
//! executes them from Rust. Python never runs on this path.
//!
//! - [`client`] — the runtime client surface (PJRT-shaped API).
//! - [`interp`] — the dependency-free HLO-text interpreter backing it.
//! - [`engine`] — the artifact registry serving compiled models by name.

pub mod client;
pub mod engine;
pub mod interp;

pub use client::Runtime;
pub use engine::{Engine, LoadedModel};
