//! The PJRT runtime: loads AOT-compiled HLO artifacts (produced once by
//! `python/compile/aot.py` from the JAX/Pallas layers) and executes them
//! from Rust. Python never runs on this path.

pub mod client;
pub mod engine;

pub use client::Runtime;
pub use engine::{Engine, LoadedModel};
