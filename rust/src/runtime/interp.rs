//! A minimal interpreter for XLA HLO **text** artifacts.
//!
//! The original runtime layer wrapped a PJRT CPU client through the
//! `xla` (xla_extension) bindings. That crate needs a multi-gigabyte
//! C++ `xla_extension` install at build time, which the offline image
//! does not carry — so the numeric hot path is served by this small,
//! dependency-free interpreter instead. It understands the subset of
//! HLO text that `python/compile/aot.py` emits for the paper's
//! artifacts (flat f32 graphs of parameters, elementwise ops, tuples)
//! and executes them exactly; anything outside the subset fails loudly
//! at load time. Swapping a real PJRT backend back in only touches
//! [`super::client`] — the [`HloProgram`] API is shaped like a loaded
//! executable on purpose.
//!
//! Scope note: full-size artifacts freshly lowered by jax (the
//! attention/layernorm pairs) use a wider opcode set (`dot`, `reduce`
//! with regions, `call`, `convert`, …) than this interpreter carries —
//! executing those requires the real PJRT backend, which is why the
//! artifact-dependent tests/benches skip cleanly when `artifacts/` is
//! absent. The serving-loop and engine tests here use artifacts within
//! the subset.
//!
//! ```
//! use fusion_stitching::runtime::interp::HloProgram;
//! let text = "HloModule double\n\nENTRY main {\n  p0 = f32[2]{0} parameter(0)\n  s = f32[2]{0} add(p0, p0)\n  ROOT t = (f32[2]{0}) tuple(s)\n}\n";
//! let prog = HloProgram::parse(text).unwrap();
//! let out = prog.execute(&[vec![1.0, 2.5]]).unwrap();
//! assert_eq!(out, vec![vec![2.0, 5.0]]);
//! ```

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

/// The supported operation subset. Everything is dense f32.
#[derive(Debug, Clone, PartialEq)]
enum Op {
    Parameter(usize),
    Constant(f32),
    Add,
    Subtract,
    Multiply,
    Divide,
    Maximum,
    Minimum,
    Exp,
    Log,
    Tanh,
    Sqrt,
    Rsqrt,
    Negate,
    Abs,
    Copy,
    /// Splat a scalar (or pass an equal-sized operand through).
    Broadcast,
    Tuple,
}

#[derive(Debug, Clone)]
struct Instr {
    name: String,
    op: Op,
    /// Output element count; 0 for tuples (their shape is the operands').
    elems: usize,
    operands: Vec<usize>,
}

/// A parsed, executable HLO-text module.
#[derive(Debug, Clone)]
pub struct HloProgram {
    name: String,
    instrs: Vec<Instr>,
    /// Instruction indices of parameters, ordered by parameter number.
    params: Vec<usize>,
    root: usize,
}

impl HloProgram {
    /// Module name from the `HloModule` header.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Number of entry parameters.
    pub fn param_count(&self) -> usize {
        self.params.len()
    }

    /// Parse the ENTRY computation of an HLO text module.
    pub fn parse(text: &str) -> Result<Self> {
        let mut name = String::from("module");
        let mut instrs: Vec<Instr> = Vec::new();
        let mut index: HashMap<String, usize> = HashMap::new();
        let mut params: Vec<(usize, usize)> = Vec::new(); // (param number, instr idx)
        let mut root: Option<usize> = None;
        let mut in_entry = false;

        for raw in text.lines() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with("//") {
                continue;
            }
            if let Some(rest) = line.strip_prefix("HloModule ") {
                name = rest.split([',', ' ']).next().unwrap_or("module").to_string();
                continue;
            }
            if line.starts_with("ENTRY ") {
                in_entry = true;
                continue;
            }
            if !in_entry {
                continue;
            }
            if line == "}" {
                in_entry = false;
                continue;
            }
            let (is_root, instr) =
                parse_instruction(line, &index).with_context(|| format!("in line: {line}"))?;
            let idx = instrs.len();
            if let Op::Parameter(n) = instr.op {
                params.push((n, idx));
            }
            if is_root {
                root = Some(idx);
            }
            index.insert(instr.name.clone(), idx);
            instrs.push(instr);
        }

        let root = root.ok_or_else(|| anyhow!("module {name} has no ROOT instruction"))?;
        params.sort_by_key(|&(n, _)| n);
        let params = params.into_iter().map(|(_, i)| i).collect();
        Ok(HloProgram { name, instrs, params, root })
    }

    /// Execute with one flattened f32 buffer per parameter. Returns the
    /// flattened output buffers: the root tuple's element values, or a
    /// single buffer for a non-tuple root.
    pub fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.params.len() {
            bail!("expected {} inputs, got {}", self.params.len(), inputs.len());
        }
        let mut values: Vec<Option<Vec<f32>>> = vec![None; self.instrs.len()];
        for (slot, input) in self.params.iter().zip(inputs) {
            let want = self.instrs[*slot].elems;
            if want != 0 && input.len() != want {
                bail!(
                    "parameter {} expects {} elements, got {}",
                    self.instrs[*slot].name,
                    want,
                    input.len()
                );
            }
            values[*slot] = Some(input.clone());
        }

        for (i, instr) in self.instrs.iter().enumerate() {
            if values[i].is_some() || instr.op == Op::Tuple {
                continue;
            }
            let v = self.eval(instr, &values)?;
            values[i] = Some(v);
        }

        let root = &self.instrs[self.root];
        let gather = |ix: usize| -> Result<Vec<f32>> {
            values[ix]
                .clone()
                .ok_or_else(|| anyhow!("value of {} never computed", self.instrs[ix].name))
        };
        if root.op == Op::Tuple {
            root.operands.iter().map(|&o| gather(o)).collect()
        } else {
            Ok(vec![gather(self.root)?])
        }
    }

    fn eval(&self, instr: &Instr, values: &[Option<Vec<f32>>]) -> Result<Vec<f32>> {
        let arg = |k: usize| -> Result<&Vec<f32>> {
            let ix = *instr
                .operands
                .get(k)
                .ok_or_else(|| anyhow!("{} missing operand {k}", instr.name))?;
            values[ix]
                .as_ref()
                .ok_or_else(|| anyhow!("operand of {} not yet computed", instr.name))
        };
        let unary = |f: fn(f32) -> f32| -> Result<Vec<f32>> {
            Ok(arg(0)?.iter().map(|&x| f(x)).collect())
        };
        let binary = |f: fn(f32, f32) -> f32| -> Result<Vec<f32>> {
            let (a, b) = (arg(0)?, arg(1)?);
            if a.len() != b.len() {
                bail!("{}: operand length mismatch {} vs {}", instr.name, a.len(), b.len());
            }
            Ok(a.iter().zip(b).map(|(&x, &y)| f(x, y)).collect())
        };
        match instr.op {
            Op::Parameter(_) => bail!("parameter {} was not bound", instr.name),
            Op::Constant(c) => {
                Ok(vec![c; instr.elems.max(1)])
            }
            Op::Add => binary(|x, y| x + y),
            Op::Subtract => binary(|x, y| x - y),
            Op::Multiply => binary(|x, y| x * y),
            Op::Divide => binary(|x, y| x / y),
            Op::Maximum => binary(f32::max),
            Op::Minimum => binary(f32::min),
            Op::Exp => unary(f32::exp),
            Op::Log => unary(f32::ln),
            Op::Tanh => unary(f32::tanh),
            Op::Sqrt => unary(f32::sqrt),
            Op::Rsqrt => unary(|x| 1.0 / x.sqrt()),
            Op::Negate => unary(|x| -x),
            Op::Abs => unary(f32::abs),
            Op::Copy => Ok(arg(0)?.clone()),
            Op::Broadcast => {
                let a = arg(0)?;
                if instr.elems != 0 && a.len() == instr.elems {
                    Ok(a.clone())
                } else if a.len() == 1 {
                    Ok(vec![a[0]; instr.elems.max(1)])
                } else {
                    bail!(
                        "{}: unsupported broadcast {} -> {} elements",
                        instr.name,
                        a.len(),
                        instr.elems
                    )
                }
            }
            Op::Tuple => bail!("tuple {} is not a value", instr.name),
        }
    }
}

/// Opcode keywords recognised in artifact text, longest-match first.
const OPCODES: &[(&str, fn(&str) -> Result<Op>)] = &[
    ("parameter", |args| Ok(Op::Parameter(args.trim().parse()?))),
    ("constant", |args| Ok(Op::Constant(args.trim().parse()?))),
    ("add", |_| Ok(Op::Add)),
    ("subtract", |_| Ok(Op::Subtract)),
    ("multiply", |_| Ok(Op::Multiply)),
    ("divide", |_| Ok(Op::Divide)),
    ("maximum", |_| Ok(Op::Maximum)),
    ("minimum", |_| Ok(Op::Minimum)),
    ("exponential", |_| Ok(Op::Exp)),
    ("log", |_| Ok(Op::Log)),
    ("tanh", |_| Ok(Op::Tanh)),
    ("sqrt", |_| Ok(Op::Sqrt)),
    ("rsqrt", |_| Ok(Op::Rsqrt)),
    ("negate", |_| Ok(Op::Negate)),
    ("abs", |_| Ok(Op::Abs)),
    ("copy", |_| Ok(Op::Copy)),
    ("broadcast", |_| Ok(Op::Broadcast)),
    ("tuple", |_| Ok(Op::Tuple)),
];

/// Parse one `name = shape opcode(operands)[, metadata]` line.
fn parse_instruction(line: &str, index: &HashMap<String, usize>) -> Result<(bool, Instr)> {
    let (lhs, rhs) = line.split_once('=').ok_or_else(|| anyhow!("no '='"))?;
    let lhs = lhs.trim();
    let (is_root, name) = match lhs.strip_prefix("ROOT ") {
        Some(n) => (true, n.trim()),
        None => (false, lhs),
    };
    let rhs = rhs.trim();

    // Locate `<opcode>(` preceded by whitespace; the prefix is the shape.
    let mut found: Option<(usize, &str, fn(&str) -> Result<Op>)> = None;
    for &(kw, build) in OPCODES {
        let pat = format!("{kw}(");
        let mut from = 0;
        while let Some(rel) = rhs[from..].find(&pat) {
            let pos = from + rel;
            let preceded_ok =
                pos == 0 || rhs[..pos].chars().next_back().map_or(false, char::is_whitespace);
            let better = match found {
                None => true,
                Some((p, k, _)) => pos < p || (pos == p && kw.len() > k.len()),
            };
            if preceded_ok && better {
                found = Some((pos, kw, build));
            }
            from = pos + pat.len();
        }
    }
    let (pos, kw, build) = found.ok_or_else(|| anyhow!("no supported opcode found"))?;

    let shape_text = rhs[..pos].trim();
    let elems = shape_elements(shape_text);

    let args_start = pos + kw.len() + 1;
    let args_end = rhs[args_start..]
        .find(')')
        .map(|r| args_start + r)
        .ok_or_else(|| anyhow!("unclosed operand list"))?;
    let args = &rhs[args_start..args_end];

    let op = build(args)?;
    let operands: Vec<usize> = match op {
        Op::Parameter(_) | Op::Constant(_) => Vec::new(),
        _ => args
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(|t| {
                // Operands may be printed as `name` or `shape name`.
                let t = t.rsplit(' ').next().unwrap_or(t);
                index
                    .get(t)
                    .copied()
                    .ok_or_else(|| anyhow!("unknown operand {t} (forward refs unsupported)"))
            })
            .collect::<Result<_>>()?,
    };

    Ok((is_root, Instr { name: name.to_string(), op, elems, operands }))
}

/// Element count of an `f32[...]`-style shape string; 0 when the shape is
/// a tuple or malformed (then the operands' sizes govern).
fn shape_elements(shape: &str) -> usize {
    let Some(open) = shape.find('[') else { return 0 };
    if shape.starts_with('(') {
        return 0; // tuple shape
    }
    let Some(close) = shape[open..].find(']').map(|r| open + r) else { return 0 };
    let body = &shape[open + 1..close];
    if body.trim().is_empty() {
        return 1; // scalar f32[]
    }
    body.split(',')
        .map(|d| d.trim().parse::<usize>().unwrap_or(0))
        .product()
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOUBLE: &str = r#"HloModule double, entry_computation_layout={(f32[4,3]{1,0})->(f32[4,3]{1,0})}

ENTRY main {
  p0 = f32[4,3]{1,0} parameter(0)
  sum = f32[4,3]{1,0} add(p0, p0)
  ROOT t = (f32[4,3]{1,0}) tuple(sum)
}
"#;

    #[test]
    fn parses_and_doubles() {
        let prog = HloProgram::parse(DOUBLE).unwrap();
        assert_eq!(prog.name(), "double");
        assert_eq!(prog.param_count(), 1);
        let input: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let out = prog.execute(&[input.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], input.iter().map(|x| 2.0 * x).collect::<Vec<f32>>());
    }

    #[test]
    fn elementwise_chain_and_constants() {
        let text = "HloModule m\nENTRY e {\n  p0 = f32[3]{0} parameter(0)\n  c = f32[] constant(2.5)\n  cb = f32[3]{0} broadcast(c)\n  m = f32[3]{0} multiply(p0, cb)\n  ROOT t = f32[3]{0} tanh(m)\n}\n";
        let prog = HloProgram::parse(text).unwrap();
        let out = prog.execute(&[vec![0.0, 1.0, -1.0]]).unwrap();
        assert_eq!(out[0][0], 0.0);
        assert!((out[0][1] - (2.5f32).tanh()).abs() < 1e-6);
        assert!((out[0][2] - (-2.5f32).tanh()).abs() < 1e-6);
    }

    #[test]
    fn non_tuple_root_returns_single_output() {
        let text = "HloModule m\nENTRY e {\n  p0 = f32[2]{0} parameter(0)\n  ROOT n = f32[2]{0} negate(p0)\n}\n";
        let prog = HloProgram::parse(text).unwrap();
        let out = prog.execute(&[vec![1.0, -2.0]]).unwrap();
        assert_eq!(out, vec![vec![-1.0, 2.0]]);
    }

    #[test]
    fn wrong_arity_rejected() {
        let prog = HloProgram::parse(DOUBLE).unwrap();
        assert!(prog.execute(&[]).is_err());
        assert!(prog.execute(&[vec![0.0; 5]]).is_err());
    }

    #[test]
    fn unsupported_opcode_rejected() {
        let text = "HloModule m\nENTRY e {\n  p0 = f32[2]{0} parameter(0)\n  ROOT d = f32[2,2]{1,0} dot(p0, p0)\n}\n";
        assert!(HloProgram::parse(text).is_err());
    }

    #[test]
    fn multi_parameter_order_follows_parameter_numbers() {
        let text = "HloModule m\nENTRY e {\n  b = f32[2]{0} parameter(1)\n  a = f32[2]{0} parameter(0)\n  ROOT s = f32[2]{0} subtract(a, b)\n}\n";
        let prog = HloProgram::parse(text).unwrap();
        let out = prog.execute(&[vec![5.0, 5.0], vec![2.0, 3.0]]).unwrap();
        assert_eq!(out[0], vec![3.0, 2.0]);
    }
}
