//! A minimal interpreter for XLA HLO **text** artifacts — the op-by-op
//! execution baseline.
//!
//! The original runtime layer wrapped a PJRT CPU client through the
//! `xla` (xla_extension) bindings. That crate needs a multi-gigabyte
//! C++ `xla_extension` install at build time, which the offline image
//! does not carry — so the numeric hot path is served by this small,
//! dependency-free interpreter instead. Swapping a real PJRT backend
//! back in only touches [`super::client`] — the [`HloProgram`] API is
//! shaped like a loaded executable on purpose.
//!
//! Besides serving artifacts, the interpreter is the **per-op
//! baseline** of the stitched execution subsystem
//! ([`crate::exec`]): it executes one instruction at a time — the
//! kernel-per-op world of the paper's §1 — and
//! [`HloProgram::launch_profile`] reports how many kernel launches
//! that costs, which the differential harness compares against the
//! stitched VM's [`crate::exec::LaunchLedger`].
//!
//! Supported subset (everything dense f32; `pred` values are 0.0/1.0):
//! parameters, constants, the elementwise set (add/sub/mul/div/max/min/
//! power/exp/log/tanh/sigmoid/sqrt/rsqrt/negate/abs/copy), `compare`
//! (greater-than), `select`, dimension-mapped `broadcast`, `reshape`,
//! `reduce` (sum/max/min/mean/prod over explicit dims), `dot`,
//! `convolution` (NHWC/HWIO, stride 1, SAME) and `tuple` roots — the
//! full opcode set the corpus generator emits
//! ([`crate::corpus::generator`], printed via
//! [`crate::hlo::printer::xla_text`]). Anything else fails loudly at
//! load time, as before. The numeric kernels (`dot`, `conv`, reduce
//! combiners) are shared with the stitched VM so both backends are
//! bit-identical where they overlap.
//!
//! ```
//! use fusion_stitching::runtime::interp::HloProgram;
//! let text = "HloModule double\n\nENTRY main {\n  p0 = f32[2]{0} parameter(0)\n  s = f32[2]{0} add(p0, p0)\n  ROOT t = (f32[2]{0}) tuple(s)\n}\n";
//! let prog = HloProgram::parse(text).unwrap();
//! let out = prog.execute(&[vec![1.0, 2.5]]).unwrap();
//! assert_eq!(out, vec![vec![2.0, 5.0]]);
//! ```

use crate::exec::machine::{conv2d_same, dot, reduce_combine, reduce_finish, reduce_init};
use crate::hlo::instruction::ReduceKind;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

/// The supported operation subset. Everything is dense f32.
#[derive(Debug, Clone, PartialEq)]
enum Op {
    Parameter(usize),
    Constant(f32),
    Add,
    Subtract,
    Multiply,
    Divide,
    Maximum,
    Minimum,
    Power,
    Exp,
    Log,
    Tanh,
    Sigmoid,
    Sqrt,
    Rsqrt,
    Negate,
    Abs,
    Copy,
    /// Greater-than comparison (0.0 / 1.0 result).
    Compare,
    Select,
    /// Dimension-mapped broadcast when `dimensions={...}` is given;
    /// otherwise splat a scalar / pass an equal-sized operand through.
    Broadcast,
    Reshape,
    /// Reduce over `Instr::reduce_dims` with `Instr::reduce_kind`.
    Reduce,
    Dot,
    Convolution,
    Tuple,
}

#[derive(Debug, Clone)]
struct Instr {
    name: String,
    op: Op,
    /// Output element count; 0 for tuples (their shape is the operands').
    elems: usize,
    /// Output dims; empty for scalars and tuples.
    dims: Vec<i64>,
    operands: Vec<usize>,
    /// `reduce`: dims being collapsed (ascending).
    reduce_dims: Vec<usize>,
    /// `reduce`: combiner.
    reduce_kind: Option<ReduceKind>,
    /// `broadcast`: XLA `broadcast_dimensions` (operand dim i → output
    /// dim `bcast_dims[i]`), when given.
    bcast_dims: Option<Vec<usize>>,
}

/// A parsed, executable HLO-text module.
#[derive(Debug, Clone)]
pub struct HloProgram {
    name: String,
    instrs: Vec<Instr>,
    /// Instruction indices of parameters, ordered by parameter number.
    params: Vec<usize>,
    root: usize,
}

impl HloProgram {
    /// Module name from the `HloModule` header.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Number of entry parameters.
    pub fn param_count(&self) -> usize {
        self.params.len()
    }

    /// Kernel launches one execution costs in the op-by-op world:
    /// `(generated, library)` — every non-free instruction is one
    /// launch, `dot`/`convolution` go to the vendor library.
    pub fn launch_profile(&self) -> (u64, u64) {
        let mut generated = 0u64;
        let mut library = 0u64;
        for i in &self.instrs {
            match i.op {
                Op::Parameter(_) | Op::Constant(_) | Op::Tuple => {}
                Op::Dot | Op::Convolution => library += 1,
                _ => generated += 1,
            }
        }
        (generated, library)
    }

    /// Total launches per execution (generated + library).
    pub fn kernel_launches(&self) -> u64 {
        let (g, l) = self.launch_profile();
        g + l
    }

    /// Parse the ENTRY computation of an HLO text module.
    pub fn parse(text: &str) -> Result<Self> {
        let mut name = String::from("module");
        let mut instrs: Vec<Instr> = Vec::new();
        let mut index: HashMap<String, usize> = HashMap::new();
        let mut params: Vec<(usize, usize)> = Vec::new(); // (param number, instr idx)
        let mut root: Option<usize> = None;
        let mut in_entry = false;

        for raw in text.lines() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with("//") {
                continue;
            }
            if let Some(rest) = line.strip_prefix("HloModule ") {
                name = rest.split([',', ' ']).next().unwrap_or("module").to_string();
                continue;
            }
            if line.starts_with("ENTRY ") {
                in_entry = true;
                continue;
            }
            if !in_entry {
                continue;
            }
            if line == "}" {
                in_entry = false;
                continue;
            }
            let (is_root, instr) =
                parse_instruction(line, &index).with_context(|| format!("in line: {line}"))?;
            let idx = instrs.len();
            if let Op::Parameter(n) = instr.op {
                params.push((n, idx));
            }
            if is_root {
                root = Some(idx);
            }
            index.insert(instr.name.clone(), idx);
            instrs.push(instr);
        }

        let root = root.ok_or_else(|| anyhow!("module {name} has no ROOT instruction"))?;
        params.sort_by_key(|&(n, _)| n);
        let params = params.into_iter().map(|(_, i)| i).collect();
        Ok(HloProgram { name, instrs, params, root })
    }

    /// Execute with one flattened f32 buffer per parameter. Returns the
    /// flattened output buffers: the root tuple's element values, or a
    /// single buffer for a non-tuple root.
    pub fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.params.len() {
            bail!("expected {} inputs, got {}", self.params.len(), inputs.len());
        }
        let mut values: Vec<Option<Vec<f32>>> = vec![None; self.instrs.len()];
        for (slot, input) in self.params.iter().zip(inputs) {
            let want = self.instrs[*slot].elems;
            if want != 0 && input.len() != want {
                bail!(
                    "parameter {} expects {} elements, got {}",
                    self.instrs[*slot].name,
                    want,
                    input.len()
                );
            }
            values[*slot] = Some(input.clone());
        }

        for (i, instr) in self.instrs.iter().enumerate() {
            if values[i].is_some() || instr.op == Op::Tuple {
                continue;
            }
            let v = self.eval(instr, &values)?;
            values[i] = Some(v);
        }

        let root = &self.instrs[self.root];
        let gather = |ix: usize| -> Result<Vec<f32>> {
            values[ix]
                .clone()
                .ok_or_else(|| anyhow!("value of {} never computed", self.instrs[ix].name))
        };
        if root.op == Op::Tuple {
            root.operands.iter().map(|&o| gather(o)).collect()
        } else {
            Ok(vec![gather(self.root)?])
        }
    }

    fn operand_dims(&self, instr: &Instr, k: usize) -> &[i64] {
        &self.instrs[instr.operands[k]].dims
    }

    fn eval(&self, instr: &Instr, values: &[Option<Vec<f32>>]) -> Result<Vec<f32>> {
        let arg = |k: usize| -> Result<&Vec<f32>> {
            let ix = *instr
                .operands
                .get(k)
                .ok_or_else(|| anyhow!("{} missing operand {k}", instr.name))?;
            values[ix]
                .as_ref()
                .ok_or_else(|| anyhow!("operand of {} not yet computed", instr.name))
        };
        let unary = |f: fn(f32) -> f32| -> Result<Vec<f32>> {
            Ok(arg(0)?.iter().map(|&x| f(x)).collect())
        };
        let binary = |f: fn(f32, f32) -> f32| -> Result<Vec<f32>> {
            let (a, b) = (arg(0)?, arg(1)?);
            if a.len() != b.len() {
                bail!("{}: operand length mismatch {} vs {}", instr.name, a.len(), b.len());
            }
            Ok(a.iter().zip(b).map(|(&x, &y)| f(x, y)).collect())
        };
        match instr.op {
            Op::Parameter(_) => bail!("parameter {} was not bound", instr.name),
            Op::Constant(c) => Ok(vec![c; instr.elems.max(1)]),
            Op::Add => binary(|x, y| x + y),
            Op::Subtract => binary(|x, y| x - y),
            Op::Multiply => binary(|x, y| x * y),
            Op::Divide => binary(|x, y| x / y),
            Op::Maximum => binary(f32::max),
            Op::Minimum => binary(f32::min),
            Op::Power => binary(f32::powf),
            Op::Compare => binary(|x, y| if x > y { 1.0 } else { 0.0 }),
            Op::Exp => unary(f32::exp),
            Op::Log => unary(f32::ln),
            Op::Tanh => unary(f32::tanh),
            Op::Sigmoid => unary(|x| 1.0 / (1.0 + (-x).exp())),
            Op::Sqrt => unary(f32::sqrt),
            Op::Rsqrt => unary(|x| 1.0 / x.sqrt()),
            Op::Negate => unary(|x| -x),
            Op::Abs => unary(f32::abs),
            Op::Copy => Ok(arg(0)?.clone()),
            Op::Select => {
                let (p, t, f) = (arg(0)?, arg(1)?, arg(2)?);
                if p.len() != t.len() || t.len() != f.len() {
                    bail!("{}: select operand length mismatch", instr.name);
                }
                Ok(p.iter()
                    .zip(t.iter().zip(f))
                    .map(|(&c, (&x, &y))| if c != 0.0 { x } else { y })
                    .collect())
            }
            Op::Reshape => {
                let a = arg(0)?;
                if instr.elems != 0 && a.len() != instr.elems {
                    bail!("{}: reshape element mismatch {} -> {}", instr.name, a.len(), instr.elems);
                }
                Ok(a.clone())
            }
            Op::Broadcast => {
                let a = arg(0)?;
                if let Some(bdims) = &instr.bcast_dims {
                    let in_dims = self.operand_dims(instr, 0).to_vec();
                    let out_dims = &instr.dims;
                    let mut out = vec![0f32; instr.elems.max(1)];
                    for (lin, slot) in out.iter_mut().enumerate() {
                        let out_idx = delinearize(lin as i64, out_dims);
                        let in_idx: Vec<i64> = bdims.iter().map(|&d| out_idx[d]).collect();
                        let src = linearize(&in_idx, &in_dims) as usize;
                        *slot = *a.get(src).ok_or_else(|| {
                            anyhow!("{}: broadcast source index out of range", instr.name)
                        })?;
                    }
                    Ok(out)
                } else if instr.elems != 0 && a.len() == instr.elems {
                    Ok(a.clone())
                } else if a.len() == 1 {
                    Ok(vec![a[0]; instr.elems.max(1)])
                } else {
                    bail!(
                        "{}: unsupported broadcast {} -> {} elements",
                        instr.name,
                        a.len(),
                        instr.elems
                    )
                }
            }
            Op::Reduce => {
                let a = arg(0)?;
                let in_dims = self.operand_dims(instr, 0).to_vec();
                let kind = instr
                    .reduce_kind
                    .ok_or_else(|| anyhow!("{}: reduce without kind", instr.name))?;
                let dims = &instr.reduce_dims;
                if dims.is_empty() {
                    bail!("{}: reduce without dimensions", instr.name);
                }
                let kept: Vec<usize> =
                    (0..in_dims.len()).filter(|d| !dims.contains(d)).collect();
                let out_dims: Vec<i64> = kept.iter().map(|&d| in_dims[d]).collect();
                let out_elems: i64 = out_dims.iter().product::<i64>().max(1);
                let sizes: Vec<i64> = dims.iter().map(|&d| in_dims[d]).collect();
                let n: i64 = sizes.iter().product::<i64>().max(1);
                let mut out = vec![0f32; out_elems as usize];
                let mut in_idx = vec![0i64; in_dims.len()];
                for (lin, slot) in out.iter_mut().enumerate() {
                    let out_idx = delinearize(lin as i64, &out_dims);
                    for (k, &d) in kept.iter().enumerate() {
                        in_idx[d] = out_idx[k];
                    }
                    let mut acc = reduce_init(kind);
                    for it in 0..n {
                        let sub = delinearize(it, &sizes);
                        for (j, &d) in dims.iter().enumerate() {
                            in_idx[d] = sub[j];
                        }
                        let v = a[linearize(&in_idx, &in_dims) as usize];
                        acc = reduce_combine(kind, acc, v);
                    }
                    *slot = reduce_finish(kind, acc, n);
                }
                Ok(out)
            }
            Op::Dot => {
                let (a, b) = (arg(0)?, arg(1)?);
                let a_dims = self.operand_dims(instr, 0).to_vec();
                let b_dims = self.operand_dims(instr, 1).to_vec();
                if instr.dims.len() < 2 {
                    bail!("{}: dot needs rank >= 2", instr.name);
                }
                Ok(dot(a, &a_dims, b, &b_dims, &instr.dims))
            }
            Op::Convolution => {
                let (x, w) = (arg(0)?, arg(1)?);
                let x_dims = self.operand_dims(instr, 0).to_vec();
                let w_dims = self.operand_dims(instr, 1).to_vec();
                if x_dims.len() != 4 || w_dims.len() != 4 {
                    bail!("{}: convolution expects NHWC x HWIO", instr.name);
                }
                Ok(conv2d_same(x, &x_dims, w, &w_dims, &instr.dims))
            }
            Op::Tuple => bail!("tuple {} is not a value", instr.name),
        }
    }
}

/// Row-major linear offset of `idx` within `dims` (shared convention
/// with the stitched VM's [`crate::exec::bytecode::linearize`]).
fn linearize(idx: &[i64], dims: &[i64]) -> i64 {
    crate::exec::bytecode::linearize(idx, dims)
}

fn delinearize(lin: i64, dims: &[i64]) -> Vec<i64> {
    crate::exec::bytecode::delinearize(lin, dims)
}

/// Opcode keywords recognised in artifact text, longest-match first.
const OPCODES: &[(&str, fn(&str) -> Result<Op>)] = &[
    ("parameter", |args| Ok(Op::Parameter(args.trim().parse()?))),
    ("constant", |args| Ok(Op::Constant(args.trim().parse()?))),
    ("add", |_| Ok(Op::Add)),
    ("subtract", |_| Ok(Op::Subtract)),
    ("multiply", |_| Ok(Op::Multiply)),
    ("divide", |_| Ok(Op::Divide)),
    ("maximum", |_| Ok(Op::Maximum)),
    ("minimum", |_| Ok(Op::Minimum)),
    ("power", |_| Ok(Op::Power)),
    ("exponential", |_| Ok(Op::Exp)),
    ("log", |_| Ok(Op::Log)),
    ("tanh", |_| Ok(Op::Tanh)),
    ("sigmoid", |_| Ok(Op::Sigmoid)),
    ("sqrt", |_| Ok(Op::Sqrt)),
    ("rsqrt", |_| Ok(Op::Rsqrt)),
    ("negate", |_| Ok(Op::Negate)),
    ("abs", |_| Ok(Op::Abs)),
    ("copy", |_| Ok(Op::Copy)),
    ("compare", |_| Ok(Op::Compare)),
    ("select", |_| Ok(Op::Select)),
    ("broadcast", |_| Ok(Op::Broadcast)),
    ("reshape", |_| Ok(Op::Reshape)),
    ("reduce", |_| Ok(Op::Reduce)),
    ("dot", |_| Ok(Op::Dot)),
    ("convolution", |_| Ok(Op::Convolution)),
    ("tuple", |_| Ok(Op::Tuple)),
];

/// Parse one `name = shape opcode(operands)[, attributes]` line.
fn parse_instruction(line: &str, index: &HashMap<String, usize>) -> Result<(bool, Instr)> {
    let (lhs, rhs) = line.split_once('=').ok_or_else(|| anyhow!("no '='"))?;
    let lhs = lhs.trim();
    let (is_root, name) = match lhs.strip_prefix("ROOT ") {
        Some(n) => (true, n.trim()),
        None => (false, lhs),
    };
    let rhs = rhs.trim();

    // Locate `<opcode>(` preceded by whitespace; the prefix is the shape.
    let mut found: Option<(usize, &str, fn(&str) -> Result<Op>)> = None;
    for &(kw, build) in OPCODES {
        let pat = format!("{kw}(");
        let mut from = 0;
        while let Some(rel) = rhs[from..].find(&pat) {
            let pos = from + rel;
            let preceded_ok =
                pos == 0 || rhs[..pos].chars().next_back().map_or(false, char::is_whitespace);
            let better = match found {
                None => true,
                Some((p, k, _)) => pos < p || (pos == p && kw.len() > k.len()),
            };
            if preceded_ok && better {
                found = Some((pos, kw, build));
            }
            from = pos + pat.len();
        }
    }
    let (pos, kw, build) = found.ok_or_else(|| anyhow!("no supported opcode found"))?;

    let shape_text = rhs[..pos].trim();
    let dims = shape_dims(shape_text);
    let elems = shape_elems(shape_text, &dims);

    let args_start = pos + kw.len() + 1;
    let args_end = rhs[args_start..]
        .find(')')
        .map(|r| args_start + r)
        .ok_or_else(|| anyhow!("unclosed operand list"))?;
    let args = &rhs[args_start..args_end];
    let attrs_text = &rhs[args_end + 1..];

    let op = build(args)?;
    let operands: Vec<usize> = match op {
        Op::Parameter(_) | Op::Constant(_) => Vec::new(),
        _ => args
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(|t| {
                // Operands may be printed as `name` or `shape name`.
                let t = t.rsplit(' ').next().unwrap_or(t);
                index
                    .get(t)
                    .copied()
                    .ok_or_else(|| anyhow!("unknown operand {t} (forward refs unsupported)"))
            })
            .collect::<Result<_>>()?,
    };

    let attr_dims = parse_dimensions(attrs_text);
    let mut instr = Instr {
        name: name.to_string(),
        op: op.clone(),
        elems,
        dims,
        operands,
        reduce_dims: Vec::new(),
        reduce_kind: None,
        bcast_dims: None,
    };
    match op {
        Op::Reduce => {
            instr.reduce_dims = attr_dims
                .ok_or_else(|| anyhow!("reduce needs a dimensions={{...}} attribute"))?;
            instr.reduce_kind = Some(parse_kind(attrs_text)?);
        }
        Op::Broadcast => instr.bcast_dims = attr_dims,
        _ => {}
    }
    Ok((is_root, instr))
}

/// Extract `dimensions={a,b,...}` from the attribute tail, if present.
fn parse_dimensions(attrs: &str) -> Option<Vec<usize>> {
    let start = attrs.find("dimensions={")? + "dimensions={".len();
    let end = attrs[start..].find('}')? + start;
    let body = &attrs[start..end];
    if body.trim().is_empty() {
        return Some(Vec::new());
    }
    body.split(',')
        .map(|t| t.trim().parse::<usize>().ok())
        .collect::<Option<Vec<usize>>>()
}

/// Extract the reduce combiner from a `kind=Xxx` attribute.
fn parse_kind(attrs: &str) -> Result<ReduceKind> {
    let start = attrs.find("kind=").ok_or_else(|| anyhow!("reduce needs kind="))? + 5;
    let word: String = attrs[start..]
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric())
        .collect();
    match word.as_str() {
        "Sum" | "sum" => Ok(ReduceKind::Sum),
        "Max" | "max" => Ok(ReduceKind::Max),
        "Min" | "min" => Ok(ReduceKind::Min),
        "Mean" | "mean" => Ok(ReduceKind::Mean),
        "Prod" | "prod" => Ok(ReduceKind::Prod),
        other => bail!("unknown reduce kind {other}"),
    }
}

/// Dims of an `f32[...]`-style shape string; empty when the shape is a
/// scalar, a tuple, or malformed.
fn shape_dims(shape: &str) -> Vec<i64> {
    if shape.starts_with('(') {
        return Vec::new(); // tuple shape
    }
    let Some(open) = shape.find('[') else { return Vec::new() };
    let Some(close) = shape[open..].find(']').map(|r| open + r) else { return Vec::new() };
    let body = &shape[open + 1..close];
    if body.trim().is_empty() {
        return Vec::new(); // scalar f32[]
    }
    body.split(',').map(|d| d.trim().parse::<i64>().unwrap_or(0)).collect()
}

/// Element count of the shape; 0 when the shape is a tuple or malformed
/// (then the operands' sizes govern).
fn shape_elems(shape: &str, dims: &[i64]) -> usize {
    if shape.starts_with('(') || !shape.contains('[') {
        return 0;
    }
    if dims.is_empty() {
        return 1; // scalar
    }
    dims.iter().product::<i64>().max(0) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOUBLE: &str = r#"HloModule double, entry_computation_layout={(f32[4,3]{1,0})->(f32[4,3]{1,0})}

ENTRY main {
  p0 = f32[4,3]{1,0} parameter(0)
  sum = f32[4,3]{1,0} add(p0, p0)
  ROOT t = (f32[4,3]{1,0}) tuple(sum)
}
"#;

    #[test]
    fn parses_and_doubles() {
        let prog = HloProgram::parse(DOUBLE).unwrap();
        assert_eq!(prog.name(), "double");
        assert_eq!(prog.param_count(), 1);
        let input: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let out = prog.execute(&[input.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], input.iter().map(|x| 2.0 * x).collect::<Vec<f32>>());
        // op-by-op: the add is one generated launch
        assert_eq!(prog.launch_profile(), (1, 0));
    }

    #[test]
    fn elementwise_chain_and_constants() {
        let text = "HloModule m\nENTRY e {\n  p0 = f32[3]{0} parameter(0)\n  c = f32[] constant(2.5)\n  cb = f32[3]{0} broadcast(c)\n  m = f32[3]{0} multiply(p0, cb)\n  ROOT t = f32[3]{0} tanh(m)\n}\n";
        let prog = HloProgram::parse(text).unwrap();
        let out = prog.execute(&[vec![0.0, 1.0, -1.0]]).unwrap();
        assert_eq!(out[0][0], 0.0);
        assert!((out[0][1] - (2.5f32).tanh()).abs() < 1e-6);
        assert!((out[0][2] - (-2.5f32).tanh()).abs() < 1e-6);
    }

    #[test]
    fn non_tuple_root_returns_single_output() {
        let text = "HloModule m\nENTRY e {\n  p0 = f32[2]{0} parameter(0)\n  ROOT n = f32[2]{0} negate(p0)\n}\n";
        let prog = HloProgram::parse(text).unwrap();
        let out = prog.execute(&[vec![1.0, -2.0]]).unwrap();
        assert_eq!(out, vec![vec![-1.0, 2.0]]);
    }

    #[test]
    fn wrong_arity_rejected() {
        let prog = HloProgram::parse(DOUBLE).unwrap();
        assert!(prog.execute(&[]).is_err());
        assert!(prog.execute(&[vec![0.0; 5]]).is_err());
    }

    #[test]
    fn unsupported_opcode_rejected() {
        let text = "HloModule m\nENTRY e {\n  p0 = f32[2]{0} parameter(0)\n  ROOT d = f32[2,2]{1,0} batch-dot(p0, p0)\n}\n";
        assert!(HloProgram::parse(text).is_err());
    }

    #[test]
    fn multi_parameter_order_follows_parameter_numbers() {
        let text = "HloModule m\nENTRY e {\n  b = f32[2]{0} parameter(1)\n  a = f32[2]{0} parameter(0)\n  ROOT s = f32[2]{0} subtract(a, b)\n}\n";
        let prog = HloProgram::parse(text).unwrap();
        let out = prog.execute(&[vec![5.0, 5.0], vec![2.0, 3.0]]).unwrap();
        assert_eq!(out[0], vec![3.0, 2.0]);
    }

    #[test]
    fn power_select_compare() {
        let text = "HloModule m\nENTRY e {\n  a = f32[3] parameter(0)\n  b = f32[3] parameter(1)\n  p = f32[3] power(a, b)\n  g = pred[3] compare(p, b)\n  ROOT s = f32[3] select(g, p, a)\n}\n";
        let prog = HloProgram::parse(text).unwrap();
        let out =
            prog.execute(&[vec![2.0, 3.0, 0.5], vec![2.0, 1.0, 2.0]]).unwrap();
        // p = [4, 3, 0.25]; g = p > b = [1, 1, 0]; s = [4, 3, 0.5]
        assert_eq!(out[0], vec![4.0, 3.0, 0.5]);
    }

    #[test]
    fn dimension_mapped_broadcast() {
        // [3] broadcast into [2, 3] along dim 1
        let text = "HloModule m\nENTRY e {\n  a = f32[3] parameter(0)\n  ROOT b = f32[2,3] broadcast(a), dimensions={1}\n}\n";
        let prog = HloProgram::parse(text).unwrap();
        let out = prog.execute(&[vec![1.0, 2.0, 3.0]]).unwrap();
        assert_eq!(out[0], vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
        // [2] broadcast into [2, 3] along dim 0
        let text2 = "HloModule m\nENTRY e {\n  a = f32[2] parameter(0)\n  ROOT b = f32[2,3] broadcast(a), dimensions={0}\n}\n";
        let prog2 = HloProgram::parse(text2).unwrap();
        let out2 = prog2.execute(&[vec![5.0, 7.0]]).unwrap();
        assert_eq!(out2[0], vec![5.0, 5.0, 5.0, 7.0, 7.0, 7.0]);
    }

    #[test]
    fn reduce_kinds() {
        let text = "HloModule m\nENTRY e {\n  a = f32[2,3] parameter(0)\n  ROOT r = f32[2] reduce(a), dimensions={1}, kind=Sum\n}\n";
        let prog = HloProgram::parse(text).unwrap();
        let out = prog.execute(&[vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]]).unwrap();
        assert_eq!(out[0], vec![6.0, 15.0]);
        let text2 = "HloModule m\nENTRY e {\n  a = f32[2,3] parameter(0)\n  ROOT r = f32[3] reduce(a), dimensions={0}, kind=Max\n}\n";
        let out2 = HloProgram::parse(text2)
            .unwrap()
            .execute(&[vec![1.0, 5.0, 3.0, 4.0, 2.0, 6.0]])
            .unwrap();
        assert_eq!(out2[0], vec![4.0, 5.0, 6.0]);
        let text3 = "HloModule m\nENTRY e {\n  a = f32[4] parameter(0)\n  ROOT r = f32[] reduce(a), dimensions={0}, kind=Mean\n}\n";
        let out3 =
            HloProgram::parse(text3).unwrap().execute(&[vec![1.0, 2.0, 3.0, 6.0]]).unwrap();
        assert_eq!(out3[0], vec![3.0]);
    }

    #[test]
    fn dot_and_reshape() {
        let text = "HloModule m\nENTRY e {\n  a = f32[2,3] parameter(0)\n  b = f32[3,2] parameter(1)\n  d = f32[2,2] dot(a, b)\n  ROOT r = f32[4] reshape(d)\n}\n";
        let prog = HloProgram::parse(text).unwrap();
        let out = prog
            .execute(&[
                vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
                vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0],
            ])
            .unwrap();
        // row0 = [1+3, 2+3] = [4, 5]; row1 = [4+6, 5+6] = [10, 11]
        assert_eq!(out[0], vec![4.0, 5.0, 10.0, 11.0]);
        assert_eq!(prog.launch_profile(), (1, 1));
    }

    #[test]
    fn reduce_without_dimensions_fails_loudly() {
        let text = "HloModule m\nENTRY e {\n  a = f32[2,3] parameter(0)\n  ROOT r = f32[2] reduce(a)\n}\n";
        assert!(HloProgram::parse(text).is_err());
    }
}
