//! The runtime client: loads HLO **text** artifacts for execution.
//!
//! HLO text is the interchange format: jax ≥ 0.5 serializes
//! `HloModuleProto`s with 64-bit instruction ids that older PJRT
//! bindings reject; the text form round-trips cleanly and stays
//! human-diffable. Execution is handled by the dependency-free
//! interpreter in [`super::interp`] (see its module docs for why the
//! PJRT C++ bindings are not linked in this image); this wrapper keeps
//! the PJRT-client surface (`cpu()`, `platform()`, `device_count()`,
//! `load_hlo_text()`) so a real backend can be swapped back in without
//! touching callers.

use super::interp::HloProgram;
use anyhow::{Context, Result};
use std::path::Path;

/// Owns the execution backend. One per process; executables share it.
pub struct Runtime {
    platform: &'static str,
}

impl Runtime {
    /// Create the CPU runtime (the paper's GPU backend is simulated by
    /// [`crate::gpusim`]; numerics run on the host CPU).
    pub fn cpu() -> Result<Self> {
        Ok(Runtime { platform: "cpu" })
    }

    pub fn platform(&self) -> String {
        self.platform.to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    /// Load an HLO-text artifact and prepare it for execution.
    pub fn load_hlo_text(&self, path: &Path) -> Result<HloProgram> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading HLO text {}", path.display()))?;
        HloProgram::parse(&text)
            .with_context(|| format!("parsing HLO text {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().unwrap();
        assert_eq!(rt.platform(), "cpu");
        assert!(rt.device_count() >= 1);
    }

    #[test]
    fn missing_artifact_errors() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.load_hlo_text(Path::new("/nonexistent/x.hlo.txt")).is_err());
    }
}
