//! PJRT CPU client wrapper.
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 serializes
//! `HloModuleProto`s with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see /opt/xla-example/README.md).

use anyhow::{Context, Result};
use std::path::Path;

/// Owns the PJRT client. One per process; executables share it.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client (the paper's GPU backend is simulated;
    /// numerics run on the XLA CPU backend).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it to an executable.
    pub fn load_hlo_text(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().unwrap();
        assert_eq!(rt.platform(), "cpu");
        assert!(rt.device_count() >= 1);
    }

    #[test]
    fn missing_artifact_errors() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.load_hlo_text(Path::new("/nonexistent/x.hlo.txt")).is_err());
    }
}
