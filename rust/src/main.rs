//! FusionStitching CLI — the leader entrypoint.
//!
//! ```text
//! fusion-stitching report [--perf-lib <path>] [--no-cost-fusion]
//! fusion-stitching compile <model|file.hlo> [--mode baseline|stitching] [--ir] [--no-cost-fusion]
//! fusion-stitching corpus [--models N]               # Fig. 1 percentile table
//! fusion-stitching serve [--requests N] [--demo] [--workers N] [--autotune]
//!                        [--deadline-ms N] [--faults SPEC]
//!                        [--trace-out t.json] [--prom-out m.prom]
//! fusion-stitching obs [--model NAME|--all] [--runs N] [--replay-into-library]
//!                      [--trace-out t.json] [--prom-out m.prom]
//! ```
//!
//! `serve --trace-out` arms the flight recorder
//! ([`fusion_stitching::obs`]) for the whole serving run and writes a
//! Chrome trace-event JSON (load it in Perfetto / `chrome://tracing`);
//! `--prom-out` writes a Prometheus text exposition of every serving
//! counter. `obs` profiles the stitched VM offline: it compiles
//! benchmark models, replays them under the recorder, and prints the
//! modeled-vs-measured divergence per fused group.
//!
//! `serve --autotune` runs the feedback loop: a background thread
//! writes measured VM launch times back into the perf library and
//! re-explores fusion under the measured cost oracle, hot-swapping the
//! served module when the plan changes. `obs --replay-into-library`
//! does the offline equivalent — it folds the replayed profile into the
//! perf library's measured entries (persist with `--perf-lib`).
//!
//! `serve --deadline-ms N` gives every request an N-millisecond
//! deadline: the batcher sheds requests whose predicted service time
//! would overrun their slack (a structured `DeadlineInfeasible` reply,
//! not a silent timeout), and the run summary reports sheds and
//! deadline misses. `--faults SPEC` (e.g.
//! `seed=7,fail_compiles=2,panic_after=3`) arms the deterministic
//! fault-injection harness — inert unless the crate was built with the
//! non-default `faults` cargo feature.
//!
//! `--no-cost-fusion` disables the cost-guided fusion-exploration pass
//! (merge/split refinement of the greedy plan), reverting to pure
//! greedy deep fusion. `--autotune` still measures and writes back
//! under `--no-cost-fusion`, but without the exploration pass a
//! re-explore cannot change the greedy plan, so no swap ever fires.
//!
//! (Hand-rolled argument parsing: the offline image carries no clap.)

use fusion_stitching::coordinator::pipeline::{evaluate, geomean, FusionMode, PipelineConfig};
use fusion_stitching::coordinator::{
    DeadlinePolicy, FaultPlan, ServerConfig, ServingCoordinator,
};
use fusion_stitching::corpus::generator::{self, CorpusConfig};
use fusion_stitching::corpus::{percentiles, OpClass};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::hlo::parser::parse_module;
use fusion_stitching::models;
use fusion_stitching::schedule::PerfLibrary;
use std::path::PathBuf;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("report") => cmd_report(&args[1..]),
        Some("compile") => cmd_compile(&args[1..]),
        Some("corpus") => cmd_corpus(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("obs") => cmd_obs(&args[1..]),
        _ => {
            eprintln!(
                "usage: fusion-stitching <report|compile|corpus|serve|obs> [options]\n\
                 \x20 report   — reproduce Figs 6/7/8 + Table 3 over the Table 2 benchmarks\n\
                 \x20 compile  — run one model/file through the pipeline\n\
                 \x20 corpus   — regenerate Fig. 1's footprint distribution\n\
                 \x20 serve    — NMT online-serving demo over the PJRT runtime\n\
                 \x20            [--demo] serves a built-in module (no `make artifacts` needed)\n\
                 \x20            [--trace-out t.json] [--prom-out m.prom] arm the flight recorder\n\
                 \x20            [--autotune] measured write-back + re-explore + hot-swap\n\
                 \x20            [--deadline-ms N] per-request deadline + slack-based shedding\n\
                 \x20            [--faults SPEC] deterministic fault injection (needs `faults` feature)\n\
                 \x20 obs      — offline kernel profiler: replay benchmark models under the\n\
                 \x20            flight recorder, report modeled-vs-measured divergence\n\
                 \x20            [--replay-into-library] fold measured times into --perf-lib"
            );
            2
        }
    };
    std::process::exit(code);
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).map(String::as_str)
}

fn perf_library(args: &[String]) -> PerfLibrary {
    match flag_value(args, "--perf-lib") {
        Some(p) => PerfLibrary::load(std::path::Path::new(p), DeviceConfig::pascal()),
        None => PerfLibrary::new(DeviceConfig::pascal()),
    }
}

/// The shared pipeline configuration, honoring `--no-cost-fusion`.
fn pipeline_config(args: &[String]) -> PipelineConfig {
    let mut cfg = PipelineConfig::default();
    cfg.deep.cost_fusion = !args.iter().any(|a| a == "--no-cost-fusion");
    cfg
}

fn cmd_report(args: &[String]) -> i32 {
    let mut lib = perf_library(args);
    let cfg = pipeline_config(args);
    let mut reports = Vec::new();
    for (meta, module) in models::all_benchmarks() {
        match evaluate(&meta, &module, &mut lib, &cfg) {
            Ok(r) => reports.push(r),
            Err(e) => {
                eprintln!("{}: {e:#}", meta.name);
                return 1;
            }
        }
    }

    println!("== Fig. 7: fusion ratio (#kernels FS / #kernels XLA, library calls excluded) ==");
    println!("{:<8} {:>10} {:>10} {:>8}", "model", "XLA", "FS", "ratio");
    for r in &reports {
        println!(
            "{:<8} {:>10} {:>10} {:>8.2}",
            r.name, r.baseline_kernels, r.fs_kernels, r.fusion_ratio
        );
    }
    println!(
        "geomean fusion ratio: {:.2} (paper: ~0.45 — 55% reduction)\n",
        geomean(reports.iter().map(|r| r.fusion_ratio))
    );

    println!("== Fig. 6: execution breakdown (simulated) ==");
    println!("{:<8} {:>12} {:>12} {:>10}", "model", "library_us", "fusable_us", "fusable%");
    for r in &reports {
        println!(
            "{:<8} {:>12.1} {:>12.1} {:>9.1}%",
            r.name,
            r.library_us,
            r.baseline_fusable_us,
            100.0 * r.fusable_ratio
        );
    }
    println!();

    println!("== Fig. 8: speedups ==");
    println!(
        "{:<8} {:>13} {:>13} {:>13}",
        "model", "FusionSpeedup", "predictedE2E", "measuredE2E"
    );
    for r in &reports {
        println!(
            "{:<8} {:>13.2} {:>13.2} {:>13.2}",
            r.name, r.fusion_speedup, r.predicted_e2e, r.measured_e2e
        );
    }
    println!(
        "geomean FusionSpeedup: {:.2} (paper: 1.74), geomean E2E: {:.2} (paper: 1.13)\n",
        geomean(reports.iter().map(|r| r.fusion_speedup)),
        geomean(reports.iter().map(|r| r.measured_e2e))
    );

    println!("== Table 3: shared memory statistics ==");
    println!(
        "{:<8} {:>10} {:>10} {:>8} {:>12}",
        "model", "avg_B", "max_B", "#shrink", "shared_ratio"
    );
    for r in &reports {
        println!(
            "{:<8} {:>10.0} {:>10} {:>8} {:>12.2}",
            r.name, r.shm_avg_bytes, r.shm_max_bytes, r.shm_shrinks, r.shm_shared_ratio
        );
    }

    if let Some(p) = flag_value(args, "--perf-lib") {
        if let Err(e) = lib.save(std::path::Path::new(p)) {
            eprintln!("saving perf library: {e:#}");
        }
    }
    0
}

fn cmd_compile(args: &[String]) -> i32 {
    let Some(target) = args.first() else {
        eprintln!("compile: need a model name (LR/W2V/RNN/BiRNN/Speech/NMT) or .hlo file");
        return 2;
    };
    let mode = match flag_value(args, "--mode") {
        Some("baseline") => FusionMode::XlaBaseline,
        _ => FusionMode::FusionStitching,
    };
    let module = if target.ends_with(".hlo") || target.ends_with(".txt") {
        match std::fs::read_to_string(target)
            .map_err(anyhow::Error::from)
            .and_then(|t| parse_module(&t))
        {
            Ok(m) => m,
            Err(e) => {
                eprintln!("parsing {target}: {e:#}");
                return 1;
            }
        }
    } else {
        match models::by_name(target) {
            Some((_, m)) => m,
            None => {
                eprintln!("unknown model {target}");
                return 2;
            }
        }
    };
    let mut lib = perf_library(args);
    match fusion_stitching::coordinator::compile_module_traced(
        &module,
        mode,
        &mut lib,
        &pipeline_config(args),
    ) {
        Ok((compiled, trace)) => {
            println!(
                "{}: {:?} → {} generated kernels, {} library calls, simulated {:.1} us",
                compiled.name,
                mode,
                compiled.plan.generated_kernel_count(&module.entry),
                compiled.plan.library_call_count(),
                compiled.timing.total_us()
            );
            println!("fingerprint: {}", compiled.fingerprint);
            if let Some(x) = &compiled.explore {
                println!(
                    "explore: {} merges + {} splits accepted ({} / {} tried), modeled {:.1} -> {:.1} us, memo hits {}",
                    x.merges_accepted,
                    x.splits_accepted,
                    x.merges_tried,
                    x.splits_tried,
                    x.modeled_before_us,
                    x.modeled_after_us,
                    x.memo_hits
                );
            }
            if args.iter().any(|a| a == "--passes") {
                println!("{trace}");
            }
            let (avg, max, shrinks, shared) = compiled.shm_stats();
            println!(
                "shm: avg {avg:.0} B, max {max} B, #shrink {shrinks}, shared ratio {shared:.2}"
            );
            if args.iter().any(|a| a == "--ir") {
                for k in &compiled.kernels {
                    println!("\n{}", k.ir_text());
                }
            }
            if args.iter().any(|a| a == "--groups") {
                for (g, k) in compiled.generated_group_ids.iter().zip(&compiled.kernels) {
                    let grp = &compiled.plan.groups[*g];
                    let names: Vec<String> = {
                        let mut m: Vec<_> = grp.members.iter().copied().collect();
                        m.sort();
                        m.iter()
                            .map(|&i| format!("{}:{}", i.0, module.entry.get(i).opcode))
                            .collect()
                    };
                    println!(
                        "group {g}: kind={:?} blocks={} threads={} est={:.2}us smem={}B members=[{}]",
                        grp.kind, k.blocks, k.threads, k.est_exec_us, k.shm.total_bytes,
                        names.join(", ")
                    );
                }
            }
            0
        }
        Err(e) => {
            eprintln!("compile failed: {e:#}");
            1
        }
    }
}

fn cmd_corpus(args: &[String]) -> i32 {
    let models_n = flag_value(args, "--models").and_then(|v| v.parse().ok()).unwrap_or(800);
    let stats = generator::generate(&CorpusConfig { models: models_n, ..Default::default() });
    println!(
        "== Fig. 1: accumulated percentile of op memory footprints ({} instances over {} models) ==",
        stats.total_instances(),
        models_n
    );
    let cuts: Vec<u32> = (4..=26).step_by(2).collect();
    print!("{:<8}", "log2(N)");
    for c in &cuts {
        print!("{c:>7}");
    }
    println!();
    for class in OpClass::ALL {
        let series = &stats.samples[&class];
        let p = percentiles(series, &cuts);
        print!("{:<8}", class.label());
        for v in p {
            print!("{:>6.1}%", 100.0 * v);
        }
        println!();
    }
    0
}

fn cmd_serve(args: &[String]) -> i32 {
    use fusion_stitching::coordinator::batcher::BatchPolicy;
    use fusion_stitching::coordinator::metrics::{throughput_rps, StreamingSummary};
    use fusion_stitching::coordinator::server::CompileOptions;
    use fusion_stitching::obs::{TraceConfig, TraceSink};

    let requests: usize =
        flag_value(args, "--requests").and_then(|v| v.parse().ok()).unwrap_or(64);
    let artifact = flag_value(args, "--artifact").unwrap_or("attention_fused").to_string();
    let dir = PathBuf::from(flag_value(args, "--artifacts-dir").unwrap_or("artifacts"));
    // --workers N routes through the sharded ServingPool (N=0: one per
    // available core); absent, the single-worker coordinator serves.
    let mut workers: Option<usize> = flag_value(args, "--workers").and_then(|v| v.parse().ok());
    // --autotune arms the feedback loop; it lives on the pool, so the
    // flag alone implies a one-worker pool.
    let autotune = args.iter().any(|a| a == "--autotune");
    if autotune && workers.is_none() {
        workers = Some(1);
    }
    // Arm the flight recorder only when an export was requested: the
    // per-launch record path is cheap but not free.
    let trace_out = flag_value(args, "--trace-out").map(str::to_string);
    let prom_out = flag_value(args, "--prom-out").map(str::to_string);
    let sink = (trace_out.is_some() || prom_out.is_some())
        .then(|| TraceSink::new(TraceConfig::default()));

    // --deadline-ms N: every request carries an N-ms deadline and the
    // batcher sheds rows whose predicted service would overrun it.
    let deadline = flag_value(args, "--deadline-ms")
        .and_then(|v| v.parse::<u64>().ok())
        .map(|ms| DeadlinePolicy {
            default_deadline: Some(std::time::Duration::from_millis(ms)),
            ..DeadlinePolicy::default()
        });
    // --faults SPEC: seeded fault plan (inert without the cargo feature).
    let faults = match flag_value(args, "--faults") {
        Some(spec) => match FaultPlan::parse(spec) {
            Ok(plan) => {
                if !FaultPlan::enabled() {
                    eprintln!(
                        "warning: --faults given but the `faults` cargo feature is off; \
                         the plan is inert (rebuild with `--features faults`)"
                    );
                }
                Some(std::sync::Arc::new(plan))
            }
            Err(e) => {
                eprintln!("parsing --faults spec: {e:#}");
                return 2;
            }
        },
        None => None,
    };

    // --demo: self-contained serving that needs no `make artifacts` —
    // writes a tiny interpreter artifact and serves a stitched
    // tanh(exp(x)) module on top, so a trace export exercises every
    // span category (including tier-tagged VM launches). CI's
    // Chrome-trace smoke validation runs exactly this.
    let cfg = if args.iter().any(|a| a == "--demo") {
        use fusion_stitching::hlo::{GraphBuilder, Module, Shape};
        const DEMO_HLO: &str = "HloModule demo, entry_computation_layout={(f32[4,3]{1,0})->(f32[4,3]{1,0})}\n\n\
             ENTRY main {\n\
             \x20 p0 = f32[4,3]{1,0} parameter(0)\n\
             \x20 sum = f32[4,3]{1,0} add(p0, p0)\n\
             \x20 ROOT t = (f32[4,3]{1,0}) tuple(sum)\n\
             }\n";
        if let Err(e) = std::fs::create_dir_all(&dir)
            .and_then(|()| std::fs::write(dir.join("demo.hlo.txt"), DEMO_HLO))
        {
            eprintln!("writing demo artifact: {e}");
            return 1;
        }
        let mut b = GraphBuilder::new("entry");
        let x = b.param("x", Shape::f32(&[4, 3]));
        let e = b.exp(x);
        let t = b.tanh(e);
        ServerConfig {
            artifact: "demo".into(),
            batch: 4,
            in_elems_per_request: 3,
            out_elems_per_request: 3,
            input_dims: vec![4, 3],
            policy: BatchPolicy::default(),
            compile: Some(CompileOptions {
                module: Module::new("demo", b.finish(t)),
                mode: FusionMode::FusionStitching,
                pipeline: pipeline_config(args),
                use_stitched_backend: true,
                specialize: None,
            }),
            buckets: None,
            trace: sink.clone(),
            deadline: deadline.clone(),
            faults: faults.clone(),
        }
    } else {
        // Compile-once serving: every batch routes through the
        // compilation cache for the NMT module; the first pays
        // fusion+tuning, the rest hit. Shapes baked by
        // python/compile/aot.py for the NMT attention block.
        let compile = models::by_name("NMT").map(|(meta, module)| {
            let mut pipeline = pipeline_config(args);
            pipeline.deep.fuse_batch_dot = meta.fuse_batch_dot;
            CompileOptions {
                module,
                mode: FusionMode::FusionStitching,
                pipeline,
                use_stitched_backend: false,
                specialize: None,
            }
        });
        let (batch, seq, model_d, out_d) = (8usize, 64usize, 512usize, 64usize);
        ServerConfig {
            artifact,
            batch,
            in_elems_per_request: seq * model_d,
            out_elems_per_request: seq * out_d,
            input_dims: vec![(batch * seq) as i64, model_d as i64],
            policy: BatchPolicy::default(),
            compile,
            buckets: None,
            trace: sink.clone(),
            deadline,
            faults,
        }
    };
    if let Some(n) = workers {
        return serve_pool(&dir, cfg, n, autotune, requests, sink, trace_out, prom_out);
    }
    let srv = match ServingCoordinator::start(&dir, cfg.clone()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("starting server (run `make artifacts` first?): {e:#}");
            return 1;
        }
    };
    let mut lat = StreamingSummary::default();
    let mut shed = 0usize;
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..requests {
        let input = vec![0.01 * (i % 7) as f32; cfg.in_elems_per_request];
        pending.push((std::time::Instant::now(), srv.infer_async(input).unwrap()));
        if pending.len() >= cfg.batch {
            for (t, rx) in pending.drain(..) {
                // Under --deadline-ms a reply may be a structured shed;
                // count it rather than crashing the client loop.
                match rx.recv().unwrap() {
                    Ok(_) => lat.record(t.elapsed()),
                    Err(_) => shed += 1,
                }
            }
        }
    }
    for (t, rx) in pending.drain(..) {
        match rx.recv().unwrap() {
            Ok(_) => lat.record(t.elapsed()),
            Err(_) => shed += 1,
        }
    }
    let wall = t0.elapsed();
    let stats = srv.shutdown().unwrap();
    let ps = lat.percentiles_us(&[50.0, 95.0]);
    println!(
        "served {} requests in {} batches: p50 {:.2} ms, p95 {:.2} ms, throughput {:.0} req/s",
        stats.requests,
        stats.batches,
        ps[0] / 1e3,
        ps[1] / 1e3,
        throughput_rps(lat.count() as usize, wall),
    );
    if stats.launches.total_launches() > 0 {
        println!(
            "executed {} ({:.1} launches/request{})",
            stats.launches,
            fusion_stitching::coordinator::metrics::launches_per_request(
                &stats.launches,
                stats.requests
            ),
            if stats.stitched_batches > 0 { ", stitched backend" } else { "" },
        );
    }
    if stats.cache_hits + stats.cache_misses > 0 {
        println!(
            "compile cache: {} hits / {} misses (hit-rate {:.0}%), cold {:.0} us, warm {:.1} us",
            stats.cache_hits,
            stats.cache_misses,
            100.0 * stats.cache_hit_rate(),
            stats.compile_us.first_us(),
            stats.compile_us.warm_mean_us(),
        );
    }
    if shed > 0 || stats.deadline_misses > 0 {
        println!(
            "deadlines: {} request(s) shed with a structured reply, {} admitted miss(es)",
            shed, stats.deadline_misses
        );
    }
    let agg = fusion_stitching::coordinator::ServingStats::from_worker(stats);
    write_observability(sink.as_ref(), trace_out.as_deref(), prom_out.as_deref(), &agg);
    0
}

/// `serve --workers N`: the sharded multi-worker pool. Requests cycle
/// over a few shape keys so the sticky router exercises every shard.
fn serve_pool(
    dir: &std::path::Path,
    cfg: fusion_stitching::coordinator::ServerConfig,
    workers: usize,
    autotune: bool,
    requests: usize,
    sink: Option<std::sync::Arc<fusion_stitching::obs::TraceSink>>,
    trace_out: Option<String>,
    prom_out: Option<String>,
) -> i32 {
    use fusion_stitching::coordinator::metrics::{throughput_rps, StreamingSummary};
    use fusion_stitching::coordinator::{AutotuneConfig, PoolConfig, ServingPool};

    let (in_elems, batch) = (cfg.in_elems_per_request, cfg.batch);
    let pool_cfg = PoolConfig {
        workers,
        autotune: autotune.then(AutotuneConfig::default),
        ..PoolConfig::default()
    };
    let pool = match ServingPool::start(dir, cfg, pool_cfg) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("starting pool (run `make artifacts` first?): {e:#}");
            return 1;
        }
    };
    let mut lat = StreamingSummary::default();
    let mut shed = 0usize;
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..requests {
        let input = vec![0.01 * (i % 7) as f32; in_elems];
        // cycle a few shape keys so the sticky router exercises shards
        let key = (i % 8) as u64;
        // Submission itself can shed (backpressure / shard respawning);
        // replies can carry a structured deadline shed. Count both.
        match pool.infer_keyed_async(key, input) {
            Ok(rx) => pending.push((std::time::Instant::now(), rx)),
            Err(_) => shed += 1,
        }
        if pending.len() >= batch {
            for (t, rx) in pending.drain(..) {
                match rx.recv() {
                    Ok(Ok(_)) => lat.record(t.elapsed()),
                    _ => shed += 1,
                }
            }
        }
    }
    for (t, rx) in pending.drain(..) {
        match rx.recv() {
            Ok(Ok(_)) => lat.record(t.elapsed()),
            _ => shed += 1,
        }
    }
    let wall = t0.elapsed();
    let stats = pool.shutdown().unwrap();
    let ps = lat.percentiles_us(&[50.0, 95.0]);
    println!(
        "pool({} workers) served {} requests in {} batches: p50 {:.2} ms, p95 {:.2} ms, {:.0} req/s",
        stats.workers(),
        stats.aggregate.requests,
        stats.aggregate.batches,
        ps[0] / 1e3,
        ps[1] / 1e3,
        throughput_rps(lat.count() as usize, wall),
    );
    if let (Some(cache), Some(cold)) = (&stats.cache, stats.cold_compiles) {
        println!(
            "shared compile cache: {} hits / {} misses, {} cold pipeline runs (single-flight)",
            cache.hits, cache.misses, cold
        );
    }
    if let Some(generation) = stats.generation {
        if generation > 0 {
            println!("autotune: hot-swapped the served module {generation} time(s)");
        }
    }
    if shed > 0 || stats.aggregate.deadline_misses > 0 || stats.respawns > 0 {
        println!(
            "robustness: {} shed, {} deadline miss(es), {} worker respawn(s), {} reroute(s)",
            shed, stats.aggregate.deadline_misses, stats.respawns, stats.reroutes
        );
    }
    write_observability(sink.as_ref(), trace_out.as_deref(), prom_out.as_deref(), &stats);
    0
}

/// Deterministic pseudo-random input buffers for a module's parameters
/// (same scheme the VM benches use — values in [-0.5, 0.5)).
fn inputs_for(module: &fusion_stitching::hlo::Module, seed: u64) -> Vec<Vec<f32>> {
    module
        .entry
        .parameters()
        .into_iter()
        .enumerate()
        .map(|(k, id)| {
            let elems = module.entry.get(id).shape.num_elements() as usize;
            (0..elems)
                .map(|i| {
                    let h = (i as u64)
                        .wrapping_mul(2654435761)
                        .wrapping_add((seed + k as u64).wrapping_mul(97));
                    ((h % 1000) as f32) / 1000.0 - 0.5
                })
                .collect()
        })
        .collect()
}

/// Shared exporter tail for `serve` / `serve --workers` / `obs`: write
/// the Chrome trace and the Prometheus exposition where asked, check
/// the launch spans against the ledger, and print the per-group
/// modeled-vs-measured divergence.
fn write_observability(
    sink: Option<&std::sync::Arc<fusion_stitching::obs::TraceSink>>,
    trace_out: Option<&str>,
    prom_out: Option<&str>,
    stats: &fusion_stitching::coordinator::ServingStats,
) {
    use fusion_stitching::obs;
    let Some(sink) = sink else { return };
    let snap = sink.snapshot();
    if let Some(path) = trace_out {
        match std::fs::write(path, obs::chrome_trace(&snap)) {
            Ok(()) => println!(
                "trace: {} spans ({} dropped) -> {path} (open in Perfetto / chrome://tracing)",
                snap.events.len(),
                snap.dropped
            ),
            Err(e) => eprintln!("writing {path}: {e}"),
        }
    }
    if let Some(path) = prom_out {
        match std::fs::write(path, obs::prometheus(stats, Some(sink.dropped_events()))) {
            Ok(()) => println!("prometheus exposition -> {path}"),
            Err(e) => eprintln!("writing {path}: {e}"),
        }
    }
    // Every generated launch the workers counted must surface as exactly
    // one tier-labelled span (short only when the ring dropped events).
    let (plain, shm, global) = snap.launch_tier_counts();
    let ledger = &stats.aggregate.launches;
    if ledger.generated > 0 {
        println!(
            "launch spans: plain {plain} + shm {shm} + global {global} = {} vs ledger generated {} ({} dropped)",
            plain + shm + global,
            ledger.generated,
            snap.dropped
        );
    }
    print_divergence(stats);
}

/// Per-fused-group modeled-vs-measured table from the aggregate profile
/// (workers serving one module share a single profile handle, so the
/// aggregate covers all traffic without double counting).
fn print_divergence(stats: &fusion_stitching::coordinator::ServingStats) {
    use fusion_stitching::obs::tier_label;
    let Some(profile) = &stats.aggregate.profile else { return };
    let snap = profile.snapshot();
    if snap.is_empty() {
        return;
    }
    println!("== modeled vs measured, per fused group (worst divergence first) ==");
    println!(
        "{:<16}   {:>6} {:>9} {:>12} {:>12} {:>7} {:>7} {:>10} {:>10} {:>10}",
        "fingerprint", "tier", "launches", "modeled_us", "measured_us", "ratio", "samples",
        "tmin_us", "tp50_us", "tmax_us"
    );
    for row in snap.divergence() {
        println!(
            "{:016x}   {:>6} {:>9} {:>12.3} {:>12.3} {:>7.2} {:>7} {:>10.3} {:>10.3} {:>10.3}",
            row.fp,
            tier_label(row.tier),
            row.launches,
            row.modeled_us,
            row.measured_mean_us,
            row.ratio,
            row.samples,
            row.trimmed_min_us,
            row.trimmed_p50_us,
            row.trimmed_max_us
        );
    }
}

/// `obs` — the offline kernel profiler: compile benchmark models to the
/// stitched VM, replay them under the flight recorder, and report the
/// modeled-vs-measured divergence per fused group (plus the optional
/// Chrome-trace / Prometheus exports, one trace lane per model).
fn cmd_obs(args: &[String]) -> i32 {
    use fusion_stitching::coordinator::pipeline::compile_module;
    use fusion_stitching::coordinator::{ServingStats, WorkerStats};
    use fusion_stitching::exec::ExecArena;
    use fusion_stitching::obs::{self, TraceConfig, TraceSink};

    let runs: usize = flag_value(args, "--runs").and_then(|v| v.parse().ok()).unwrap_or(32);
    let model_filter = flag_value(args, "--model");
    let trace_out = flag_value(args, "--trace-out");
    let prom_out = flag_value(args, "--prom-out");

    let sink = TraceSink::new(TraceConfig::default());
    let mut lib = perf_library(args);
    let base_cfg = pipeline_config(args);
    // One synthetic worker's counters feed the Prometheus exposition.
    let mut stats = WorkerStats::default();
    let mut profiled = 0usize;

    println!("== kernel profiler: {runs} replay(s) per model, stitched VM ==");
    for (lane, (meta, module)) in models::all_benchmarks().into_iter().enumerate() {
        if let Some(want) = model_filter {
            if !meta.name.eq_ignore_ascii_case(want) {
                continue;
            }
        }
        let mut cfg = base_cfg.clone();
        cfg.deep.fuse_batch_dot = meta.fuse_batch_dot;
        let compiled = match compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("{}: compile failed: {e:#}", meta.name);
                return 1;
            }
        };
        let Some(exe) = compiled.executable.clone() else {
            println!("{}: no stitched executable ({:?}), skipped", meta.name, compiled.exec_error);
            continue;
        };
        let _g = obs::install(&sink, lane as u32, Some(compiled.profile.clone()));
        let inputs = inputs_for(&module, 42);
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut arena = ExecArena::default();
        let mut out = Vec::new();
        let mut ledger = fusion_stitching::exec::LaunchLedger::default();
        for _ in 0..runs {
            match exe.run_into(&refs, &mut arena, &mut out) {
                Ok(run) => ledger.merge(&run),
                Err(e) => {
                    eprintln!("{}: execution failed: {e:#}", meta.name);
                    return 1;
                }
            }
        }
        stats.requests += runs;
        stats.batches += runs;
        stats.stitched_batches += runs;
        stats.launches.merge(&ledger);
        stats.arena_reuses += arena.reuses();
        if stats.arena.is_none() {
            stats.arena = compiled.arena_stats();
        }
        if stats.profile.is_none() {
            stats.profile = Some(compiled.profile.clone());
        } else if let Some(p) = &stats.profile {
            // fold later models into the first handle so the aggregate
            // divergence table covers every replayed group
            let snap = compiled.profile.snapshot();
            p.merge_from(&snap);
        }
        profiled += 1;

        let snap = compiled.profile.snapshot();
        println!(
            "{}: {} groups, {} generated launches (plain {} / shm {} / global {})",
            meta.name,
            snap.len(),
            ledger.generated,
            ledger.tier_plain,
            ledger.tier_shm,
            ledger.tier_global
        );
        for row in snap.divergence() {
            println!(
                "  {:016x} {:>6} x{:<5} modeled {:>9.3} us, measured {:>9.3} us, ratio {:.2} \
                 ({} samples, trimmed {:.3}/{:.3}/{:.3} us)",
                row.fp,
                fusion_stitching::obs::tier_label(row.tier),
                row.launches,
                row.modeled_us,
                row.measured_mean_us,
                row.ratio,
                row.samples,
                row.trimmed_min_us,
                row.trimmed_p50_us,
                row.trimmed_max_us
            );
        }
    }
    if profiled == 0 {
        eprintln!("no model profiled (unknown --model name?)");
        return 2;
    }
    // --replay-into-library: fold the replayed profile into the perf
    // library's measured entries, so a later compile (or `serve
    // --autotune`) starts from these wall-clock samples instead of the
    // cold analytic model.
    if args.iter().any(|a| a == "--replay-into-library") {
        if let Some(profile) = &stats.profile {
            let snap = profile.snapshot();
            let absorbed = lib.absorb_profile(&snap);
            println!(
                "replayed {} launches into the perf library ({} measured entries)",
                absorbed,
                lib.measured_len()
            );
        }
        match flag_value(args, "--perf-lib") {
            Some(p) => {
                if let Err(e) = lib.save(std::path::Path::new(p)) {
                    eprintln!("saving perf library: {e:#}");
                    return 1;
                }
            }
            None => eprintln!("--replay-into-library without --perf-lib: entries not persisted"),
        }
    }
    let agg = ServingStats::from_worker(stats);
    write_observability(Some(&sink), trace_out, prom_out, &agg);
    0
}
