//! FusionStitching CLI — the leader entrypoint.
//!
//! ```text
//! fusion-stitching report [--perf-lib <path>] [--no-cost-fusion]
//! fusion-stitching compile <model|file.hlo> [--mode baseline|stitching] [--ir] [--no-cost-fusion]
//! fusion-stitching corpus [--models N]               # Fig. 1 percentile table
//! fusion-stitching serve [--requests N]              # NMT online serving demo
//! ```
//!
//! `--no-cost-fusion` disables the cost-guided fusion-exploration pass
//! (merge/split refinement of the greedy plan), reverting to pure
//! greedy deep fusion.
//!
//! (Hand-rolled argument parsing: the offline image carries no clap.)

use fusion_stitching::coordinator::pipeline::{evaluate, geomean, FusionMode, PipelineConfig};
use fusion_stitching::coordinator::{ServerConfig, ServingCoordinator};
use fusion_stitching::corpus::generator::{self, CorpusConfig};
use fusion_stitching::corpus::{percentiles, OpClass};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::hlo::parser::parse_module;
use fusion_stitching::models;
use fusion_stitching::schedule::PerfLibrary;
use std::path::PathBuf;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("report") => cmd_report(&args[1..]),
        Some("compile") => cmd_compile(&args[1..]),
        Some("corpus") => cmd_corpus(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        _ => {
            eprintln!(
                "usage: fusion-stitching <report|compile|corpus|serve> [options]\n\
                 \x20 report   — reproduce Figs 6/7/8 + Table 3 over the Table 2 benchmarks\n\
                 \x20 compile  — run one model/file through the pipeline\n\
                 \x20 corpus   — regenerate Fig. 1's footprint distribution\n\
                 \x20 serve    — NMT online-serving demo over the PJRT runtime"
            );
            2
        }
    };
    std::process::exit(code);
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).map(String::as_str)
}

fn perf_library(args: &[String]) -> PerfLibrary {
    match flag_value(args, "--perf-lib") {
        Some(p) => PerfLibrary::load(std::path::Path::new(p), DeviceConfig::pascal()),
        None => PerfLibrary::new(DeviceConfig::pascal()),
    }
}

/// The shared pipeline configuration, honoring `--no-cost-fusion`.
fn pipeline_config(args: &[String]) -> PipelineConfig {
    let mut cfg = PipelineConfig::default();
    cfg.deep.cost_fusion = !args.iter().any(|a| a == "--no-cost-fusion");
    cfg
}

fn cmd_report(args: &[String]) -> i32 {
    let mut lib = perf_library(args);
    let cfg = pipeline_config(args);
    let mut reports = Vec::new();
    for (meta, module) in models::all_benchmarks() {
        match evaluate(&meta, &module, &mut lib, &cfg) {
            Ok(r) => reports.push(r),
            Err(e) => {
                eprintln!("{}: {e:#}", meta.name);
                return 1;
            }
        }
    }

    println!("== Fig. 7: fusion ratio (#kernels FS / #kernels XLA, library calls excluded) ==");
    println!("{:<8} {:>10} {:>10} {:>8}", "model", "XLA", "FS", "ratio");
    for r in &reports {
        println!(
            "{:<8} {:>10} {:>10} {:>8.2}",
            r.name, r.baseline_kernels, r.fs_kernels, r.fusion_ratio
        );
    }
    println!(
        "geomean fusion ratio: {:.2} (paper: ~0.45 — 55% reduction)\n",
        geomean(reports.iter().map(|r| r.fusion_ratio))
    );

    println!("== Fig. 6: execution breakdown (simulated) ==");
    println!("{:<8} {:>12} {:>12} {:>10}", "model", "library_us", "fusable_us", "fusable%");
    for r in &reports {
        println!(
            "{:<8} {:>12.1} {:>12.1} {:>9.1}%",
            r.name,
            r.library_us,
            r.baseline_fusable_us,
            100.0 * r.fusable_ratio
        );
    }
    println!();

    println!("== Fig. 8: speedups ==");
    println!(
        "{:<8} {:>13} {:>13} {:>13}",
        "model", "FusionSpeedup", "predictedE2E", "measuredE2E"
    );
    for r in &reports {
        println!(
            "{:<8} {:>13.2} {:>13.2} {:>13.2}",
            r.name, r.fusion_speedup, r.predicted_e2e, r.measured_e2e
        );
    }
    println!(
        "geomean FusionSpeedup: {:.2} (paper: 1.74), geomean E2E: {:.2} (paper: 1.13)\n",
        geomean(reports.iter().map(|r| r.fusion_speedup)),
        geomean(reports.iter().map(|r| r.measured_e2e))
    );

    println!("== Table 3: shared memory statistics ==");
    println!(
        "{:<8} {:>10} {:>10} {:>8} {:>12}",
        "model", "avg_B", "max_B", "#shrink", "shared_ratio"
    );
    for r in &reports {
        println!(
            "{:<8} {:>10.0} {:>10} {:>8} {:>12.2}",
            r.name, r.shm_avg_bytes, r.shm_max_bytes, r.shm_shrinks, r.shm_shared_ratio
        );
    }

    if let Some(p) = flag_value(args, "--perf-lib") {
        if let Err(e) = lib.save(std::path::Path::new(p)) {
            eprintln!("saving perf library: {e:#}");
        }
    }
    0
}

fn cmd_compile(args: &[String]) -> i32 {
    let Some(target) = args.first() else {
        eprintln!("compile: need a model name (LR/W2V/RNN/BiRNN/Speech/NMT) or .hlo file");
        return 2;
    };
    let mode = match flag_value(args, "--mode") {
        Some("baseline") => FusionMode::XlaBaseline,
        _ => FusionMode::FusionStitching,
    };
    let module = if target.ends_with(".hlo") || target.ends_with(".txt") {
        match std::fs::read_to_string(target)
            .map_err(anyhow::Error::from)
            .and_then(|t| parse_module(&t))
        {
            Ok(m) => m,
            Err(e) => {
                eprintln!("parsing {target}: {e:#}");
                return 1;
            }
        }
    } else {
        match models::by_name(target) {
            Some((_, m)) => m,
            None => {
                eprintln!("unknown model {target}");
                return 2;
            }
        }
    };
    let mut lib = perf_library(args);
    match fusion_stitching::coordinator::compile_module_traced(
        &module,
        mode,
        &mut lib,
        &pipeline_config(args),
    ) {
        Ok((compiled, trace)) => {
            println!(
                "{}: {:?} → {} generated kernels, {} library calls, simulated {:.1} us",
                compiled.name,
                mode,
                compiled.plan.generated_kernel_count(&module.entry),
                compiled.plan.library_call_count(),
                compiled.timing.total_us()
            );
            println!("fingerprint: {}", compiled.fingerprint);
            if let Some(x) = &compiled.explore {
                println!(
                    "explore: {} merges + {} splits accepted ({} / {} tried), modeled {:.1} -> {:.1} us, memo hits {}",
                    x.merges_accepted,
                    x.splits_accepted,
                    x.merges_tried,
                    x.splits_tried,
                    x.modeled_before_us,
                    x.modeled_after_us,
                    x.memo_hits
                );
            }
            if args.iter().any(|a| a == "--passes") {
                println!("{trace}");
            }
            let (avg, max, shrinks, shared) = compiled.shm_stats();
            println!(
                "shm: avg {avg:.0} B, max {max} B, #shrink {shrinks}, shared ratio {shared:.2}"
            );
            if args.iter().any(|a| a == "--ir") {
                for k in &compiled.kernels {
                    println!("\n{}", k.ir_text());
                }
            }
            if args.iter().any(|a| a == "--groups") {
                for (g, k) in compiled.generated_group_ids.iter().zip(&compiled.kernels) {
                    let grp = &compiled.plan.groups[*g];
                    let names: Vec<String> = {
                        let mut m: Vec<_> = grp.members.iter().copied().collect();
                        m.sort();
                        m.iter()
                            .map(|&i| format!("{}:{}", i.0, module.entry.get(i).opcode))
                            .collect()
                    };
                    println!(
                        "group {g}: kind={:?} blocks={} threads={} est={:.2}us smem={}B members=[{}]",
                        grp.kind, k.blocks, k.threads, k.est_exec_us, k.shm.total_bytes,
                        names.join(", ")
                    );
                }
            }
            0
        }
        Err(e) => {
            eprintln!("compile failed: {e:#}");
            1
        }
    }
}

fn cmd_corpus(args: &[String]) -> i32 {
    let models_n = flag_value(args, "--models").and_then(|v| v.parse().ok()).unwrap_or(800);
    let stats = generator::generate(&CorpusConfig { models: models_n, ..Default::default() });
    println!(
        "== Fig. 1: accumulated percentile of op memory footprints ({} instances over {} models) ==",
        stats.total_instances(),
        models_n
    );
    let cuts: Vec<u32> = (4..=26).step_by(2).collect();
    print!("{:<8}", "log2(N)");
    for c in &cuts {
        print!("{c:>7}");
    }
    println!();
    for class in OpClass::ALL {
        let series = &stats.samples[&class];
        let p = percentiles(series, &cuts);
        print!("{:<8}", class.label());
        for v in p {
            print!("{:>6.1}%", 100.0 * v);
        }
        println!();
    }
    0
}

fn cmd_serve(args: &[String]) -> i32 {
    use fusion_stitching::coordinator::batcher::BatchPolicy;
    use fusion_stitching::coordinator::metrics::LatencyRecorder;
    use fusion_stitching::coordinator::server::CompileOptions;

    let requests: usize =
        flag_value(args, "--requests").and_then(|v| v.parse().ok()).unwrap_or(64);
    let artifact = flag_value(args, "--artifact").unwrap_or("attention_fused").to_string();
    let dir = PathBuf::from(flag_value(args, "--artifacts-dir").unwrap_or("artifacts"));
    // --workers N routes through the sharded ServingPool (N=0: one per
    // available core); absent, the single-worker coordinator serves.
    let workers: Option<usize> = flag_value(args, "--workers").and_then(|v| v.parse().ok());

    // Compile-once serving: every batch routes through the compilation
    // cache for the NMT module; the first pays fusion+tuning, the rest hit.
    let compile = models::by_name("NMT").map(|(meta, module)| {
        let mut pipeline = pipeline_config(args);
        pipeline.deep.fuse_batch_dot = meta.fuse_batch_dot;
        CompileOptions {
            module,
            mode: FusionMode::FusionStitching,
            pipeline,
            use_stitched_backend: false,
        }
    });

    // Shapes baked by python/compile/aot.py for the NMT attention block.
    let (batch, seq, model_d, out_d) = (8usize, 64usize, 512usize, 64usize);
    let cfg = ServerConfig {
        artifact,
        batch,
        in_elems_per_request: seq * model_d,
        out_elems_per_request: seq * out_d,
        input_dims: vec![(batch * seq) as i64, model_d as i64],
        policy: BatchPolicy::default(),
        compile,
    };
    if let Some(n) = workers {
        return serve_pool(&dir, cfg, n, requests);
    }
    let srv = match ServingCoordinator::start(&dir, cfg.clone()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("starting server (run `make artifacts` first?): {e:#}");
            return 1;
        }
    };
    let mut lat = LatencyRecorder::default();
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..requests {
        let input = vec![0.01 * (i % 7) as f32; cfg.in_elems_per_request];
        pending.push((std::time::Instant::now(), srv.infer_async(input).unwrap()));
        if pending.len() >= cfg.batch {
            for (t, rx) in pending.drain(..) {
                rx.recv().unwrap().unwrap();
                lat.record(t.elapsed());
            }
        }
    }
    for (t, rx) in pending.drain(..) {
        rx.recv().unwrap().unwrap();
        lat.record(t.elapsed());
    }
    let wall = t0.elapsed();
    let stats = srv.shutdown().unwrap();
    println!(
        "served {} requests in {} batches: p50 {:.2} ms, p95 {:.2} ms, throughput {:.0} req/s",
        stats.requests,
        stats.batches,
        lat.percentile_us(50.0) / 1e3,
        lat.percentile_us(95.0) / 1e3,
        lat.throughput_rps(wall),
    );
    if stats.launches.total_launches() > 0 {
        println!(
            "executed {} ({:.1} launches/request{})",
            stats.launches,
            fusion_stitching::coordinator::metrics::launches_per_request(
                &stats.launches,
                stats.requests
            ),
            if stats.stitched_batches > 0 { ", stitched backend" } else { "" },
        );
    }
    if stats.cache_hits + stats.cache_misses > 0 {
        println!(
            "compile cache: {} hits / {} misses (hit-rate {:.0}%), cold {:.0} us, warm {:.1} us",
            stats.cache_hits,
            stats.cache_misses,
            100.0 * stats.cache_hit_rate(),
            stats.compile_us.first_us(),
            stats.compile_us.warm_mean_us(),
        );
    }
    0
}

/// `serve --workers N`: the sharded multi-worker pool. Requests cycle
/// over a few shape keys so the sticky router exercises every shard.
fn serve_pool(
    dir: &std::path::Path,
    cfg: fusion_stitching::coordinator::ServerConfig,
    workers: usize,
    requests: usize,
) -> i32 {
    use fusion_stitching::coordinator::metrics::LatencyRecorder;
    use fusion_stitching::coordinator::{PoolConfig, ServingPool};

    let (in_elems, batch) = (cfg.in_elems_per_request, cfg.batch);
    let pool = match ServingPool::start(
        dir,
        cfg,
        PoolConfig { workers, ..PoolConfig::default() },
    ) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("starting pool (run `make artifacts` first?): {e:#}");
            return 1;
        }
    };
    let mut lat = LatencyRecorder::default();
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..requests {
        let input = vec![0.01 * (i % 7) as f32; in_elems];
        // cycle a few shape keys so the sticky router exercises shards
        let key = (i % 8) as u64;
        pending.push((std::time::Instant::now(), pool.infer_keyed_async(key, input).unwrap()));
        if pending.len() >= batch {
            for (t, rx) in pending.drain(..) {
                rx.recv().unwrap().unwrap();
                lat.record(t.elapsed());
            }
        }
    }
    for (t, rx) in pending.drain(..) {
        rx.recv().unwrap().unwrap();
        lat.record(t.elapsed());
    }
    let wall = t0.elapsed();
    let stats = pool.shutdown().unwrap();
    println!(
        "pool({} workers) served {} requests in {} batches: p50 {:.2} ms, p95 {:.2} ms, {:.0} req/s",
        stats.workers(),
        stats.aggregate.requests,
        stats.aggregate.batches,
        lat.percentile_us(50.0) / 1e3,
        lat.percentile_us(95.0) / 1e3,
        lat.throughput_rps(wall),
    );
    if let (Some(cache), Some(cold)) = (&stats.cache, stats.cold_compiles) {
        println!(
            "shared compile cache: {} hits / {} misses, {} cold pipeline runs (single-flight)",
            cache.hits, cache.misses, cold
        );
    }
    0
}
