//! BiRNN — bidirectional LSTM classifier (Table 2, aymericdamien's
//! `bidirectional_rnn`, default configuration: MNIST sequence, hidden
//! 128 per direction, batch 128).
//!
//! Two LSTM cell bodies in separate while-loop frames (forward /
//! backward, the way TF's `bidirectional_dynamic_rnn` emits two loops),
//! concatenated at top level into a 2H feature for the classifier.

use super::rnn::lstm_cell;
use super::{dense, softmax};
use crate::hlo::instruction::ReduceKind;
use crate::hlo::{GraphBuilder, Module, Shape};

pub const BATCH: i64 = 128;
pub const INPUT: i64 = 28;
pub const HIDDEN: i64 = 128;
pub const CLASSES: i64 = 10;

pub fn build() -> Module {
    let mut b = GraphBuilder::new("birnn_entry");
    let x_fwd = b.param("x_fwd", Shape::f32(&[BATCH, INPUT]));
    let x_bwd = b.param("x_bwd", Shape::f32(&[BATCH, INPUT]));
    let h0f = b.param("h0f", Shape::f32(&[BATCH, HIDDEN]));
    let c0f = b.param("c0f", Shape::f32(&[BATCH, HIDDEN]));
    let h0b = b.param("h0b", Shape::f32(&[BATCH, HIDDEN]));
    let c0b = b.param("c0b", Shape::f32(&[BATCH, HIDDEN]));
    let wf = b.param("w_fwd", Shape::f32(&[INPUT + HIDDEN, 4 * HIDDEN]));
    let bf = b.param("b_fwd", Shape::f32(&[4 * HIDDEN]));
    let wb = b.param("w_bwd", Shape::f32(&[INPUT + HIDDEN, 4 * HIDDEN]));
    let bb = b.param("b_bwd", Shape::f32(&[4 * HIDDEN]));
    let w_out = b.param("w_out", Shape::f32(&[2 * HIDDEN, CLASSES]));
    let b_out = b.param("b_out", Shape::f32(&[CLASSES]));
    let y = b.param("y", Shape::f32(&[BATCH, CLASSES]));

    // Forward loop body (frame 1).
    b.set_frame(1);
    let (hf, _cf) = lstm_cell(&mut b, x_fwd, h0f, c0f, wf, bf);

    // Backward loop body (frame 2).
    b.set_frame(2);
    let (hb, _cb) = lstm_cell(&mut b, x_bwd, h0b, c0b, wb, bb);

    // Join at top level: concat(h_fwd, h_bwd) → classifier.
    b.set_frame(0);
    let hf0 = b.copy(hf);
    let hb0 = b.copy(hb);
    let feat = b.concat(&[hf0, hb0], 1); // [B, 2H]
    let logits = dense(&mut b, feat, w_out, b_out);
    let probs = softmax(&mut b, logits);
    let logp = b.log(probs);
    let yl = b.mul(y, logp);
    let nll = b.neg(yl);
    let loss = b.reduce(nll, &[0, 1], ReduceKind::Mean);
    Module::new("BiRNN", b.finish(loss))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::FramePartition;
    use crate::hlo::verifier::verify_module;
    use crate::hlo::Opcode;

    #[test]
    fn builds_and_verifies() {
        verify_module(&build()).unwrap();
    }

    #[test]
    fn two_direction_frames() {
        let m = build();
        let fp = FramePartition::build(&m.entry);
        assert_eq!(fp.frames(), vec![0, 1, 2]);
        assert!(fp.members(1).len() >= 10);
        assert!(fp.members(2).len() >= 10);
    }

    #[test]
    fn concat_joins_directions() {
        let m = build();
        let concat2h = m
            .entry
            .instructions()
            .filter(|i| {
                i.opcode == Opcode::Concatenate && i.shape.dims == vec![BATCH, 2 * HIDDEN]
            })
            .count();
        assert_eq!(concat2h, 1);
    }

    #[test]
    fn larger_than_rnn() {
        assert!(build().entry.len() > super::super::rnn::build().entry.len());
    }
}
