//! The six benchmark workloads of Table 2, reconstructed as HLO graphs.
//!
//! `LR`, `W2V`, `RNN`, `BiRNN` mirror the public aymericdamien
//! TensorFlow-Examples models (default configurations) the paper uses;
//! `Speech` and `NMT` are representative stand-ins for the paper's
//! in-house applications, built to exercise the same op mixes the paper
//! describes (Speech: complex reduce/transpose/concat/elementwise
//! interactions; NMT: attention with the Figure 3 softmax → BatchDot
//! pattern and high shared-memory reuse). See DESIGN.md substitutions.
//!
//! Shared building blocks (dense layers, layer norm, softmax, update
//! rules) live here so the models stay faithful *and* short.

pub mod birnn;
pub mod lr;
pub mod nmt;
pub mod rnn;
pub mod speech;
pub mod w2v;

use crate::hlo::instruction::ReduceKind;
use crate::hlo::{GraphBuilder, InstrId, Module};

/// Benchmark category (Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Category {
    Training,
    Inference,
}

/// Metadata row of Table 2 plus per-model pipeline settings.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: &'static str,
    pub category: Category,
    pub description: &'static str,
    /// §2.1: whether BatchMatMul joins fused kernels is left to the user;
    /// profitable for NMT's marginal batched shapes, off elsewhere.
    pub fuse_batch_dot: bool,
}

/// Build every benchmark with its metadata — the driver for all
/// experiments.
pub fn all_benchmarks() -> Vec<(ModelMeta, Module)> {
    vec![
        (
            ModelMeta {
                name: "LR",
                category: Category::Training,
                description: "Logistic Regression",
                fuse_batch_dot: false,
            },
            lr::build(),
        ),
        (
            ModelMeta {
                name: "W2V",
                category: Category::Training,
                description: "Word2Vector",
                fuse_batch_dot: false,
            },
            w2v::build(),
        ),
        (
            ModelMeta {
                name: "RNN",
                category: Category::Training,
                description: "Recurrent Neural Network",
                fuse_batch_dot: false,
            },
            rnn::build(),
        ),
        (
            ModelMeta {
                name: "BiRNN",
                category: Category::Training,
                description: "Bidirectional RNN",
                fuse_batch_dot: false,
            },
            birnn::build(),
        ),
        (
            ModelMeta {
                name: "Speech",
                category: Category::Training,
                description: "Speech Recognition",
                fuse_batch_dot: false,
            },
            speech::build(),
        ),
        (
            ModelMeta {
                name: "NMT",
                category: Category::Inference,
                description: "Neural Machine Translation",
                fuse_batch_dot: true,
            },
            nmt::build(),
        ),
    ]
}

/// Look one benchmark up by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<(ModelMeta, Module)> {
    all_benchmarks().into_iter().find(|(m, _)| m.name.eq_ignore_ascii_case(name))
}

// ---------------------------------------------------------------------
// Shared building blocks
// ---------------------------------------------------------------------

/// `dot(x, w) + b` with `b` broadcast over rows — the library-call dense
/// layer (cuBLAS in the paper).
pub(crate) fn dense(b: &mut GraphBuilder, x: InstrId, w: InstrId, bias: InstrId) -> InstrId {
    let y = b.dot(x, w);
    let dims = b.peek().get(y).shape.dims.clone();
    let bb = b.broadcast(bias, &dims, &[dims.len() - 1]);
    b.add(y, bb)
}

/// Numerically-stable softmax over the last dim (the Figure 3 inner
/// pattern: max-reduce → sub → exp → sum-reduce → div).
pub(crate) fn softmax(b: &mut GraphBuilder, x: InstrId) -> InstrId {
    let dims = b.peek().get(x).shape.dims.clone();
    let rank = dims.len();
    let bdims: Vec<usize> = (0..rank - 1).collect();
    let m = b.reduce(x, &[rank - 1], ReduceKind::Max);
    let mb = b.broadcast(m, &dims, &bdims);
    let sh = b.sub(x, mb);
    let e = b.exp(sh);
    let s = b.reduce(e, &[rank - 1], ReduceKind::Sum);
    let sb = b.broadcast(s, &dims, &bdims);
    b.div(e, sb)
}

/// Layer normalization over the last dim: mean/variance reduces plus an
/// rsqrt-normalized elementwise tail with learned scale/shift.
pub(crate) fn layer_norm(
    b: &mut GraphBuilder,
    x: InstrId,
    gamma: InstrId,
    beta: InstrId,
) -> InstrId {
    let dims = b.peek().get(x).shape.dims.clone();
    let rank = dims.len();
    let bdims: Vec<usize> = (0..rank - 1).collect();
    let mu = b.reduce(x, &[rank - 1], ReduceKind::Mean);
    let mub = b.broadcast(mu, &dims, &bdims);
    let centered = b.sub(x, mub);
    let sq = b.mul(centered, centered);
    let var = b.reduce(sq, &[rank - 1], ReduceKind::Mean);
    let varb = b.broadcast(var, &dims, &bdims);
    let rs = b.rsqrt(varb);
    let normed = b.mul(centered, rs);
    let gb = b.broadcast(gamma, &dims, &[rank - 1]);
    let bb = b.broadcast(beta, &dims, &[rank - 1]);
    let scaled = b.mul(normed, gb);
    b.add(scaled, bb)
}

/// SGD update `w ← w − lr·g` — the fine-grained weight-accumulation
/// pattern `ElementwiseFusion` targets (§3.2).
pub(crate) fn sgd_update(b: &mut GraphBuilder, w: InstrId, g: InstrId, lr: InstrId) -> InstrId {
    let dims = b.peek().get(w).shape.dims.clone();
    let lrb = b.broadcast(lr, &dims, &[]);
    let step = b.mul(g, lrb);
    b.sub(w, step)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::verifier::verify_module;

    #[test]
    fn all_benchmarks_verify() {
        for (meta, module) in all_benchmarks() {
            verify_module(&module)
                .unwrap_or_else(|e| panic!("{} failed verification: {e}", meta.name));
            assert!(module.entry.len() > 10, "{} suspiciously small", meta.name);
        }
    }

    #[test]
    fn table2_rows_present() {
        let names: Vec<&str> = all_benchmarks().iter().map(|(m, _)| m.name).collect();
        assert_eq!(names, vec!["LR", "W2V", "RNN", "BiRNN", "Speech", "NMT"]);
        let cats: Vec<Category> = all_benchmarks().iter().map(|(m, _)| m.category).collect();
        assert_eq!(cats.iter().filter(|c| **c == Category::Training).count(), 5);
        assert_eq!(cats.iter().filter(|c| **c == Category::Inference).count(), 1);
    }

    #[test]
    fn every_benchmark_has_library_calls_and_fusable_ops() {
        // Fig. 6 needs both portions present in every workload.
        for (meta, module) in all_benchmarks() {
            let lib =
                module.entry.instructions().filter(|i| i.opcode.is_library_call()).count();
            let fusable =
                module.entry.instructions().filter(|i| i.opcode.is_fusable()).count();
            assert!(lib > 0, "{} has no library calls", meta.name);
            assert!(fusable > 3, "{} has too few fusable ops", meta.name);
        }
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("nmt").is_some());
        assert!(by_name("Speech").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn helper_softmax_shapes() {
        let mut b = GraphBuilder::new("h");
        let x = b.param("x", crate::hlo::Shape::f32(&[4, 16]));
        let s = softmax(&mut b, x);
        assert_eq!(b.peek().get(s).shape.dims, vec![4, 16]);
    }

    #[test]
    fn helper_layer_norm_shapes() {
        let mut b = GraphBuilder::new("h");
        let x = b.param("x", crate::hlo::Shape::f32(&[4, 16]));
        let g = b.param("g", crate::hlo::Shape::f32(&[16]));
        let be = b.param("b", crate::hlo::Shape::f32(&[16]));
        let s = layer_norm(&mut b, x, g, be);
        assert_eq!(b.peek().get(s).shape.dims, vec![4, 16]);
    }
}
