//! LR — logistic regression training step (Table 2, aymericdamien's
//! TensorFlow-Examples `logistic_regression`, default configuration:
//! MNIST, batch 128, 784 → 10, softmax cross-entropy + SGD).
//!
//! One training iteration: forward dense layer (library dot), softmax
//! cross-entropy loss, analytic gradients, SGD parameter updates. The
//! update tail is the fine-grained elementwise pattern whose launch
//! overhead motivates intra-layer fusion.

use super::{dense, sgd_update, softmax};
use crate::hlo::instruction::ReduceKind;
use crate::hlo::{GraphBuilder, Module, Shape};

pub const BATCH: i64 = 128;
pub const FEATURES: i64 = 784;
pub const CLASSES: i64 = 10;

pub fn build() -> Module {
    let mut b = GraphBuilder::new("lr_entry");
    let x = b.param("x", Shape::f32(&[BATCH, FEATURES]));
    let y = b.param("y", Shape::f32(&[BATCH, CLASSES])); // one-hot labels
    let w = b.param("w", Shape::f32(&[FEATURES, CLASSES]));
    let bias = b.param("b", Shape::f32(&[CLASSES]));
    let lr = b.param("lr", Shape::f32(&[]));

    // Forward: logits = x·W + b, probs = softmax(logits).
    let logits = dense(&mut b, x, w, bias);
    let probs = softmax(&mut b, logits);

    // Loss: mean cross-entropy −Σ y·log p (kept in the graph: its value
    // is an output the session fetches every step).
    let logp = b.log(probs);
    let yl = b.mul(y, logp);
    let nll = b.neg(yl);
    let loss = b.reduce(nll, &[0, 1], ReduceKind::Mean);

    // Backward: dlogits = (probs − y) / batch.
    let diff = b.sub(probs, y);
    let inv_batch = b.constant(Shape::f32(&[]));
    let invb = b.broadcast(inv_batch, &[BATCH, CLASSES], &[]);
    let dlogits = b.mul(diff, invb);

    // dW = xᵀ · dlogits (library matmul); db = Σ_rows dlogits.
    let xt = b.transpose(x, &[1, 0]);
    let dw = b.dot(xt, dlogits);
    let db = b.reduce(dlogits, &[0], ReduceKind::Sum);

    // SGD updates — small same-shape elementwise ops in one span layer.
    let w_new = sgd_update(&mut b, w, dw, lr);
    let b_new = sgd_update(&mut b, bias, db, lr);

    // Keep all outputs live via a cheap combine onto the loss scalar.
    let wsum = b.reduce(w_new, &[0, 1], ReduceKind::Sum);
    let bsum = b.reduce(b_new, &[0], ReduceKind::Sum);
    let t1 = b.add(loss, wsum);
    let root = b.add(t1, bsum);
    Module::new("LR", b.finish(root))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::verifier::verify_module;
    use crate::hlo::Opcode;

    #[test]
    fn builds_and_verifies() {
        let m = build();
        verify_module(&m).unwrap();
    }

    #[test]
    fn has_two_library_matmuls() {
        let m = build();
        let dots =
            m.entry.instructions().filter(|i| i.opcode == Opcode::Dot).count();
        assert_eq!(dots, 2); // forward + dW
    }

    #[test]
    fn update_tail_is_fine_grained() {
        // The SGD update ops all produce parameter-shaped outputs —
        // small tensors, the launch-bound regime of Fig. 1.
        let m = build();
        let small = m
            .entry
            .instructions()
            .filter(|i| i.opcode.is_elementwise() && i.shape.num_elements() <= FEATURES * CLASSES)
            .count();
        assert!(small >= 4, "expected several fine-grained update ops, got {small}");
    }
}
