//! RNN — LSTM sequence classifier (Table 2, aymericdamien's
//! `recurrent_network`, default configuration: MNIST rows as a 28-step
//! sequence, hidden 128, batch 128).
//!
//! The LSTM cell body lives inside a while-loop frame (frame 1), the way
//! TF emits `tf.while_loop` — exercising the paper's frame-context
//! preprocessing (§3.1). The cell mixes a library matmul with slices and
//! a sigmoid/tanh elementwise tail; the classifier and loss sit in the
//! top-level frame.

use super::{dense, softmax};
use crate::hlo::instruction::ReduceKind;
use crate::hlo::{GraphBuilder, InstrId, Module, Shape};

pub const BATCH: i64 = 128;
pub const INPUT: i64 = 28;
pub const HIDDEN: i64 = 128;
pub const CLASSES: i64 = 10;

/// One LSTM cell step: `[B, I] × [B, H] → [B, H]` (new h and c).
/// Returns `(h_new, c_new)`.
pub(crate) fn lstm_cell(
    b: &mut GraphBuilder,
    x_t: InstrId,
    h_prev: InstrId,
    c_prev: InstrId,
    w: InstrId,    // [(I+H), 4H]
    bias: InstrId, // [4H]
) -> (InstrId, InstrId) {
    let xh = b.concat(&[x_t, h_prev], 1); // [B, I+H]
    let gates = dense(b, xh, w, bias); // [B, 4H] — library matmul
    let h = HIDDEN;
    let i_g = b.slice(gates, &[0, 0], &[BATCH, h]);
    let f_g = b.slice(gates, &[0, h], &[BATCH, 2 * h]);
    let g_g = b.slice(gates, &[0, 2 * h], &[BATCH, 3 * h]);
    let o_g = b.slice(gates, &[0, 3 * h], &[BATCH, 4 * h]);
    let i_s = b.sigmoid(i_g);
    let f_s = b.sigmoid(f_g);
    let g_t = b.tanh(g_g);
    let o_s = b.sigmoid(o_g);
    let fc = b.mul(f_s, c_prev);
    let ig = b.mul(i_s, g_t);
    let c_new = b.add(fc, ig);
    let c_t = b.tanh(c_new);
    let h_new = b.mul(o_s, c_t);
    (h_new, c_new)
}

pub fn build() -> Module {
    let mut b = GraphBuilder::new("rnn_entry");
    let x = b.param("x", Shape::f32(&[BATCH, INPUT])); // current row x_t
    let h0 = b.param("h", Shape::f32(&[BATCH, HIDDEN]));
    let c0 = b.param("c", Shape::f32(&[BATCH, HIDDEN]));
    let w = b.param("w_lstm", Shape::f32(&[INPUT + HIDDEN, 4 * HIDDEN]));
    let bias = b.param("b_lstm", Shape::f32(&[4 * HIDDEN]));
    let w_out = b.param("w_out", Shape::f32(&[HIDDEN, CLASSES]));
    let b_out = b.param("b_out", Shape::f32(&[CLASSES]));
    let y = b.param("y", Shape::f32(&[BATCH, CLASSES]));

    // While-loop body: one LSTM step (frame 1, the way tf.while_loop
    // partitions the graph).
    b.set_frame(1);
    let (h1, c1) = lstm_cell(&mut b, x, h0, c0, w, bias);
    let _ = c1;

    // Classifier + loss back at top level.
    b.set_frame(0);
    let h_final = b.copy(h1);
    let logits = dense(&mut b, h_final, w_out, b_out);
    let probs = softmax(&mut b, logits);
    let logp = b.log(probs);
    let yl = b.mul(y, logp);
    let nll = b.neg(yl);
    let loss = b.reduce(nll, &[0, 1], ReduceKind::Mean);
    Module::new("RNN", b.finish(loss))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::FramePartition;
    use crate::hlo::verifier::verify_module;
    use crate::hlo::Opcode;

    #[test]
    fn builds_and_verifies() {
        verify_module(&build()).unwrap();
    }

    #[test]
    fn cell_lives_in_while_frame() {
        let m = build();
        let fp = FramePartition::build(&m.entry);
        assert_eq!(fp.frames(), vec![0, 1]);
        assert!(fp.members(1).len() >= 10, "LSTM cell body should be in frame 1");
        assert_eq!(fp.parent(1), Some(0));
    }

    #[test]
    fn gate_tail_shapes() {
        let m = build();
        // four slices of [B, H] each (the gates)
        let slices = m
            .entry
            .instructions()
            .filter(|i| i.opcode == Opcode::Slice && i.shape.dims == vec![BATCH, HIDDEN])
            .count();
        assert_eq!(slices, 4);
    }
}
