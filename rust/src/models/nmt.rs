//! NMT — attention-based neural machine translation inference (Table 2
//! stand-in for the paper's in-house transformer-style NMT; see DESIGN.md
//! substitutions; cf. Vaswani et al. 2017 / Xiong et al. 2018, which the
//! paper cites as its basis).
//!
//! The *online* use case: small batch, latency-critical. One decoder
//! block: Q/K/V projections (library matmuls), scaled dot-product
//! attention whose batched dots have workload-specific marginal shapes —
//! the case where "cuBLAS kernels do not deliver satisfactory
//! performance" (§2.1) and `fuse_batch_dot = true` pays — a GELU FFN,
//! residuals and layer norms. The softmax → BatchDot core is exactly
//! Figure 3, including the shared-memory reuse measured in Table 3 (NMT
//! shared ratio 0.17).

use super::{layer_norm, softmax};
use crate::hlo::{GraphBuilder, InstrId, Module, Shape};

pub const BATCH: i64 = 8; // heads × beam — small, latency-critical
pub const SEQ: i64 = 64;
pub const DIM: i64 = 64; // per-head dim
pub const MODEL: i64 = 512;
pub const FFN: i64 = 1024;
pub const VOCAB: i64 = 512;

pub fn build() -> Module {
    let mut b = GraphBuilder::new("nmt_entry");
    let hidden = b.param("hidden", Shape::f32(&[BATCH * SEQ, MODEL]));
    let wq = b.param("wq", Shape::f32(&[MODEL, DIM]));
    let wk = b.param("wk", Shape::f32(&[MODEL, DIM]));
    let wv = b.param("wv", Shape::f32(&[MODEL, DIM]));
    let wo = b.param("wo", Shape::f32(&[DIM, MODEL]));
    let ln1_g = b.param("ln1_g", Shape::f32(&[MODEL]));
    let ln1_b = b.param("ln1_b", Shape::f32(&[MODEL]));
    let ln2_g = b.param("ln2_g", Shape::f32(&[MODEL]));
    let ln2_b = b.param("ln2_b", Shape::f32(&[MODEL]));
    let w1 = b.param("w_ffn1", Shape::f32(&[MODEL, FFN]));
    let w2 = b.param("w_ffn2", Shape::f32(&[FFN, MODEL]));
    let w_vocab = b.param("w_vocab", Shape::f32(&[MODEL, VOCAB]));

    // --- projections (library matmuls, LC-layer) ---
    let q2 = b.dot(hidden, wq); // [B*S, D]
    let k2 = b.dot(hidden, wk);
    let v2 = b.dot(hidden, wv);
    let q = b.reshape(q2, &[BATCH, SEQ, DIM]);
    let k = b.reshape(k2, &[BATCH, SEQ, DIM]);
    let v = b.reshape(v2, &[BATCH, SEQ, DIM]);

    // --- scaled dot-product attention: the Figure 3 subgraph ---
    let kt = b.transpose(k, &[0, 2, 1]); // [B, D, S]
    let scores = b.batch_dot(q, kt); // [B, S, S] — marginal batched shape
    let scale = b.constant(Shape::f32(&[]));
    let scaleb = b.broadcast(scale, &[BATCH, SEQ, SEQ], &[]);
    let scaled = b.mul(scores, scaleb);
    let probs = softmax(&mut b, scaled); // max/exp/sum/div with smem reuse
    let ctx = b.batch_dot(probs, v); // [B, S, D] — Dot.1 in Figure 3

    // --- output projection + residual + layer norm ---
    let ctx2 = b.reshape(ctx, &[BATCH * SEQ, DIM]);
    let proj = b.dot(ctx2, wo); // library
    let res1 = b.add(hidden, proj);
    let ln1 = layer_norm(&mut b, res1, ln1_g, ln1_b);

    // --- GELU FFN ---
    let f1 = b.dot(ln1, w1); // library
    let g = gelu(&mut b, f1);
    let f2 = b.dot(g, w2); // library
    let res2 = b.add(ln1, f2);
    let ln2 = layer_norm(&mut b, res2, ln2_g, ln2_b);

    // --- vocab logits + softmax for the next token ---
    let logits = b.dot(ln2, w_vocab); // [B*S, V]
    let last = b.reshape(logits, &[BATCH, SEQ, VOCAB]);
    let out_probs = softmax(&mut b, last);
    let root = b.log(out_probs);
    Module::new("NMT", b.finish(root))
}

/// tanh-approximation GELU: the expensive-elementwise chain
/// (mul/pow/tanh) typical of transformer FFNs.
fn gelu(b: &mut GraphBuilder, x: InstrId) -> InstrId {
    let dims = b.peek().get(x).shape.dims.clone();
    let c0 = b.constant(Shape::f32(&[])); // 0.7978845608…
    let c1 = b.constant(Shape::f32(&[])); // 0.044715
    let half = b.constant(Shape::f32(&[])); // 0.5
    let onec = b.constant(Shape::f32(&[]));
    let c0b = b.broadcast(c0, &dims, &[]);
    let c1b = b.broadcast(c1, &dims, &[]);
    let halfb = b.broadcast(half, &dims, &[]);
    let oneb = b.broadcast(onec, &dims, &[]);
    let x2 = b.mul(x, x);
    let x3 = b.mul(x2, x);
    let inner = b.mul(c1b, x3);
    let sum = b.add(x, inner);
    let arg = b.mul(c0b, sum);
    let t = b.tanh(arg);
    let onep = b.add(oneb, t);
    let halfx = b.mul(halfb, x);
    b.mul(halfx, onep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::verifier::verify_module;
    use crate::hlo::Opcode;

    #[test]
    fn builds_and_verifies() {
        verify_module(&build()).unwrap();
    }

    #[test]
    fn figure3_pattern_embedded() {
        let m = build();
        let bdots =
            m.entry.instructions().filter(|i| i.opcode == Opcode::BatchDot).count();
        assert_eq!(bdots, 2, "scores and context batched dots");
        // two softmaxes (attention + vocab) → 4 reduces + 2 divides at least
        let reduces = m.entry.instructions().filter(|i| i.opcode.is_reduce()).count();
        assert!(reduces >= 8, "attention softmax, vocab softmax, 2 layer norms");
    }

    #[test]
    fn library_calls_delimit_regions() {
        let m = build();
        let dots = m.entry.instructions().filter(|i| i.opcode == Opcode::Dot).count();
        assert_eq!(dots, 7); // q,k,v,wo,ffn1,ffn2,vocab
    }

    #[test]
    fn expensive_elementwise_present() {
        // exp/div/tanh in softmax+gelu — the smem candidates of §5.1.1.
        let m = build();
        let expensive = m
            .entry
            .instructions()
            .filter(|i| i.opcode.is_expensive_elementwise())
            .count();
        assert!(expensive >= 6, "got {expensive}");
    }
}
