//! W2V — word2vec skip-gram with negative sampling (Table 2,
//! aymericdamien's `word2vec`, default configuration: embedding 200,
//! batch 128, NCE loss).
//!
//! The op mix here is deliberately XLA-friendly — simple
//! producer/consumer elementwise chains around the embedding matmuls —
//! which is why the paper measures its *highest* fusion ratio (0.82) on
//! W2V: XLA already fuses most of it, leaving little extra for
//! FusionStitching.

use super::{sgd_update};
use crate::hlo::instruction::ReduceKind;
use crate::hlo::{GraphBuilder, Module, Shape};

pub const BATCH: i64 = 128;
pub const EMBED: i64 = 200;
pub const NEG: i64 = 64; // negative samples

pub fn build() -> Module {
    let mut b = GraphBuilder::new("w2v_entry");
    // Gathered embedding rows arrive as dense parameters (embedding
    // lookup itself is a host-side gather in the TF graph).
    let center = b.param("center", Shape::f32(&[BATCH, EMBED]));
    let context = b.param("context", Shape::f32(&[BATCH, EMBED]));
    let negatives = b.param("negatives", Shape::f32(&[NEG, EMBED]));
    let lr = b.param("lr", Shape::f32(&[]));

    // Positive logits: row-wise dot(center, context) = Σ_d c·v.
    let cc = b.mul(center, context);
    let pos_logit = b.reduce(cc, &[1], ReduceKind::Sum); // [BATCH]

    // Negative logits: center · negativesᵀ (library matmul).
    let negt = b.transpose(negatives, &[1, 0]);
    let neg_logit = b.dot(center, negt); // [BATCH, NEG]

    // NCE loss pieces: log σ(pos) + Σ log σ(−neg).
    let pos_sig = b.sigmoid(pos_logit);
    let pos_log = b.log(pos_sig);
    let neg_neg = b.neg(neg_logit);
    let neg_sig = b.sigmoid(neg_neg);
    let neg_log = b.log(neg_sig);
    let neg_sum = b.reduce(neg_log, &[1], ReduceKind::Sum); // [BATCH]
    let per_ex = b.add(pos_log, neg_sum);
    let nper = b.neg(per_ex);
    let loss = b.reduce(nper, &[0], ReduceKind::Mean);

    // Gradients (simplified analytic forms, same shapes as TF emits).
    // d_pos = σ(pos) − 1, scales context rows into center grads.
    let onec = b.constant(Shape::f32(&[]));
    let ones = b.broadcast(onec, &[BATCH], &[]);
    let dpos = b.sub(pos_sig, ones); // [BATCH]
    let dposb = b.broadcast(dpos, &[BATCH, EMBED], &[0]);
    let gcenter_pos = b.mul(dposb, context);

    // d_neg = σ(neg), matmul back into embedding space (library).
    let dneg = b.sigmoid(neg_logit); // [BATCH, NEG]
    let gcenter_neg = b.dot(dneg, negatives); // [BATCH, EMBED]

    let gcenter = b.add(gcenter_pos, gcenter_neg);
    let gcontext = b.mul(dposb, center);

    // SGD updates — same-layer fine-grained elementwise ops.
    let c_new = sgd_update(&mut b, center, gcenter, lr);
    let v_new = sgd_update(&mut b, context, gcontext, lr);

    let csum = b.reduce(c_new, &[0, 1], ReduceKind::Sum);
    let vsum = b.reduce(v_new, &[0, 1], ReduceKind::Sum);
    let t = b.add(csum, vsum);
    let root = b.add(loss, t);
    Module::new("W2V", b.finish(root))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::verifier::verify_module;
    use crate::hlo::Opcode;

    #[test]
    fn builds_and_verifies() {
        verify_module(&build()).unwrap();
    }

    #[test]
    fn has_library_matmuls() {
        let m = build();
        let dots = m.entry.instructions().filter(|i| i.opcode == Opcode::Dot).count();
        assert_eq!(dots, 2);
    }

    #[test]
    fn mostly_simple_chains() {
        // The XLA-friendliness property: elementwise ops dominate, few
        // shape modulations or interior reduces.
        let m = build();
        let ew = m.entry.instructions().filter(|i| i.opcode.is_elementwise()).count();
        let shape_mod =
            m.entry.instructions().filter(|i| i.opcode.is_shape_modulation()).count();
        assert!(ew > shape_mod, "W2V should be elementwise-dominated");
    }
}
