//! Speech — speech-recognition training step (Table 2 stand-in for the
//! paper's in-house application training on consumer-device voice
//! samples; see DESIGN.md substitutions).
//!
//! Built to exhibit exactly what §6.3 credits for the paper's *best*
//! fusion ratio (0.25) on Speech: "complex interaction patterns among
//! reduce, transpose, concat, and elementwise ops" — a conv/cuDNN
//! frontend, time/feature-major transposes between stages, per-frame
//! feature normalization (reduce + rsqrt tails), skip concats, masked
//! pooling, and a log-softmax CTC-style head. Shared-memory pressure is
//! intentionally high (Table 3: Speech averages ~9.5 KB and triggers
//! shrinking).

use super::{layer_norm, softmax};
use crate::hlo::instruction::ReduceKind;
use crate::hlo::{GraphBuilder, InstrId, Module, Shape};

pub const BATCH: i64 = 16;
pub const TIME: i64 = 96;
pub const MEL: i64 = 64;
pub const FEAT: i64 = 128;
pub const VOCAB: i64 = 48;

pub fn build() -> Module {
    let mut b = GraphBuilder::new("speech_entry");
    // Log-mel spectrogram input, NHWC for the conv frontend.
    let spec = b.param("spec", Shape::f32(&[BATCH, TIME, MEL, 1]));
    let conv_w1 = b.param("conv_w1", Shape::f32(&[3, 3, 1, 8]));
    let conv_w2 = b.param("conv_w2", Shape::f32(&[3, 3, 8, 2]));
    let ln1_g = b.param("ln1_g", Shape::f32(&[FEAT]));
    let ln1_b = b.param("ln1_b", Shape::f32(&[FEAT]));
    let ln2_g = b.param("ln2_g", Shape::f32(&[2 * FEAT]));
    let ln2_b = b.param("ln2_b", Shape::f32(&[2 * FEAT]));
    let w_head = b.param("w_head", Shape::f32(&[2 * FEAT, VOCAB]));
    let b_head = b.param("b_head", Shape::f32(&[VOCAB]));
    let labels = b.param("labels", Shape::f32(&[BATCH, TIME, VOCAB]));

    // --- cuDNN conv frontend (LC-layers) ---
    let c1 = b.conv2d(spec, conv_w1); // [B, T, MEL, 8]
    let r1 = relu(&mut b, c1);
    let c2 = b.conv2d(r1, conv_w2); // [B, T, MEL, 2]
    let r2 = relu(&mut b, c2);

    // --- fold channels into features: [B, T, MEL*2] = [B, T, FEAT] ---
    let folded = b.reshape(r2, &[BATCH, TIME, FEAT]);

    // Per-utterance global mean/variance normalization over time —
    // *column* reduction (major dim), the XLA weak spot §1 names.
    let tmean = b.reduce(folded, &[1], ReduceKind::Mean); // [B, FEAT]
    let tmb = b.broadcast(tmean, &[BATCH, TIME, FEAT], &[0, 2]);
    let centered = b.sub(folded, tmb);
    let sq = b.mul(centered, centered);
    let tvar = b.reduce(sq, &[1], ReduceKind::Mean); // [B, FEAT]
    let tvb = b.broadcast(tvar, &[BATCH, TIME, FEAT], &[0, 2]);
    let rstd = b.rsqrt(tvb);
    let cmvn = b.mul(centered, rstd);

    // --- layer-norm + gated elementwise block, time-major transposes ---
    let ln1 = layer_norm(&mut b, cmvn, ln1_g, ln1_b); // [B, T, F]
    let tmaj = b.transpose(ln1, &[1, 0, 2]); // [T, B, F] time-major
    let gate = b.sigmoid(tmaj);
    let cand = b.tanh(tmaj);
    let gated = b.mul(gate, cand);
    let back = b.transpose(gated, &[1, 0, 2]); // [B, T, F]

    // --- skip concat: [B, T, 2F] (the concat/elementwise interaction) ---
    let skip = b.concat(&[back, cmvn], 2);
    let ln2 = layer_norm(&mut b, skip, ln2_g, ln2_b); // [B, T, 2F]

    // --- masked statistics pooling over time (more column reduces) ---
    let gmax = b.reduce(ln2, &[1], ReduceKind::Max); // [B, 2F]
    let gmean = b.reduce(ln2, &[1], ReduceKind::Mean); // [B, 2F]
    let pooled = b.add(gmax, gmean);
    let pool_n = b.tanh(pooled);

    // --- CTC-style head: per-frame vocab logits + log-softmax ---
    let flat = b.reshape(ln2, &[BATCH * TIME, 2 * FEAT]);
    let logits2 = b.dot(flat, w_head); // library matmul
    let hb = b.broadcast(b_head, &[BATCH * TIME, VOCAB], &[1]);
    let logits = b.add(logits2, hb);
    let frames = b.reshape(logits, &[BATCH, TIME, VOCAB]);
    let probs = softmax(&mut b, frames);
    let logp = b.log(probs);
    let yl = b.mul(labels, logp);
    let nll = b.neg(yl);
    let loss = b.reduce(nll, &[0, 1, 2], ReduceKind::Mean);

    // keep the pooled embedding alive (multi-task: speaker head)
    let psum = b.reduce(pool_n, &[0, 1], ReduceKind::Sum);
    let root = b.add(loss, psum);
    Module::new("Speech", b.finish(root))
}

fn relu(b: &mut GraphBuilder, x: InstrId) -> InstrId {
    let dims = b.peek().get(x).shape.dims.clone();
    let zc = b.constant(Shape::f32(&[]));
    let zeros = b.broadcast(zc, &dims, &[]);
    b.max(x, zeros)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::verifier::verify_module;
    use crate::hlo::Opcode;

    #[test]
    fn builds_and_verifies() {
        verify_module(&build()).unwrap();
    }

    #[test]
    fn has_the_section63_op_mix() {
        // "complex interaction patterns among reduce, transpose, concat,
        // and elementwise ops"
        let m = build();
        let count = |f: &dyn Fn(Opcode) -> bool| {
            m.entry.instructions().filter(|i| f(i.opcode)).count()
        };
        assert!(count(&|o| o.is_reduce()) >= 7, "many reduces");
        assert!(count(&|o| o == Opcode::Transpose) >= 2, "transposes");
        assert!(count(&|o| o == Opcode::Concatenate) >= 1, "concat");
        assert!(count(&|o| o.is_elementwise()) >= 15, "elementwise");
        assert!(count(&|o| o == Opcode::Convolution) == 2, "cuDNN frontend");
    }

    #[test]
    fn column_reductions_present() {
        // reduces over dim 1 of rank-3 tensors (time axis) — the
        // column-reduction weak spot.
        let m = build();
        let col = m
            .entry
            .instructions()
            .filter(|i| {
                i.opcode == Opcode::Reduce
                    && i.attrs.reduce_dims.as_ref() == Some(&vec![1])
            })
            .count();
        assert!(col >= 4, "got {col}");
    }

    #[test]
    fn is_the_largest_training_graph() {
        let speech = build().entry.len();
        assert!(speech > super::super::birnn::build().entry.len());
    }
}
