//! Analytical GPU cost model — the substrate standing in for the paper's
//! physical Pascal GPU + `nvprof` (see DESIGN.md §2 substitutions).
//!
//! The paper's pipeline consumes GPU measurements in two places:
//! 1. the performance library (§4.4) fills misses by compiling a CUDA
//!    kernel and timing it with nvprof — we fill misses from
//!    [`cost::kernel_time_us`] instead;
//! 2. the evaluation (Figs. 6/8) times whole modules — we aggregate
//!    per-kernel estimates plus launch overheads in [`executor`].
//!
//! The model is deliberately simple and deterministic: a roofline over
//! memory bandwidth and FLOPs with occupancy/coalescing/launch terms.
//! Absolute numbers are not claimed; *relative* behaviour (more blocks →
//! better until saturation, column-schedule reductions pay a coalescing
//! penalty, tiny kernels are launch-bound) is what the paper's decisions
//! need.

pub mod cost;
pub mod device;
pub mod executor;

pub use cost::{kernel_time_us, KernelDesc};
pub use device::DeviceConfig;
pub use executor::{simulate_module, ModuleTiming};
