//! Per-kernel analytical cost: a roofline with launch, occupancy and
//! coalescing terms.
//!
//! Since the feedback-directed autotuning PR, no fusion/tuning pass
//! calls these functions directly: every consumer goes through the
//! [`crate::schedule::CostOracle`] seam, for which this module is the
//! default ([`crate::schedule::ModeledCost`]) answer. Measured
//! wall-clock overlays ([`crate::schedule::MeasuredCost`]) replace
//! these estimates per fused group where the serving path has written
//! back enough samples — the model remains the authority for cold
//! fingerprints and per-(op, schedule) lookups.

use super::device::DeviceConfig;

/// Resource description of one GPU kernel launch. Constructed by the
/// performance library from (opcode, shape, schedule) keys, or by the
/// executor for library calls.
#[derive(Debug, Clone)]
pub struct KernelDesc {
    /// Bytes read from DRAM.
    pub bytes_read: u64,
    /// Bytes written to DRAM.
    pub bytes_written: u64,
    /// FP operations executed.
    pub flops: u64,
    /// Grid size (thread blocks).
    pub blocks: u64,
    /// Threads per block.
    pub threads: u32,
    /// Shared memory per block, bytes.
    pub smem_bytes: usize,
    /// Memory access efficiency in (0, 1]: 1.0 = fully coalesced.
    pub coalescing: f64,
    /// Per-element instruction weight (transcendentals cost more than
    /// adds on the SFU): multiplies `flops` into "effective flops".
    pub op_weight: f64,
}

impl KernelDesc {
    pub fn effective_flops(&self) -> f64 {
        self.flops as f64 * self.op_weight.max(1.0)
    }
}

/// Execution-only time (no launch overhead) — the quantity the paper's
/// performance library stores per schedule key.
pub fn kernel_exec_time_us(desc: &KernelDesc, dev: &DeviceConfig) -> f64 {
    let occ = dev.occupancy(desc.blocks, desc.threads, desc.smem_bytes);
    let mem_bytes = (desc.bytes_read + desc.bytes_written) as f64;
    let eff_bw = dev.dram_bw_bytes_per_us * dev.bw_efficiency * desc.coalescing.clamp(0.05, 1.0);
    // Memory system saturates only with enough parallelism in flight:
    // sqrt softens the penalty vs compute (latency hiding needs fewer
    // warps for streaming loads).
    let mem_time = mem_bytes / (eff_bw * occ.sqrt());
    let comp_time = desc.effective_flops() / (dev.peak_flops_per_us * occ);
    mem_time.max(comp_time).max(0.2) // floor: even a null kernel has ~0.2us of work
}

/// Full kernel time including the launch overhead — what E2E timing sums.
pub fn kernel_time_us(desc: &KernelDesc, dev: &DeviceConfig) -> f64 {
    dev.launch_overhead_us + kernel_exec_time_us(desc, dev)
}

/// Library-call cost (cuBLAS/cuDNN in the paper): modelled as a highly
/// optimized compute-bound kernel at `lib_efficiency` of peak, with a
/// bandwidth floor.
pub fn library_call_time_us(
    flops: u64,
    bytes: u64,
    dev: &DeviceConfig,
    lib_efficiency: f64,
) -> f64 {
    let comp = flops as f64 / (dev.peak_flops_per_us * lib_efficiency.clamp(0.05, 1.0));
    let mem = bytes as f64 / (dev.dram_bw_bytes_per_us * dev.bw_efficiency);
    dev.launch_overhead_us + comp.max(mem).max(0.2)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn desc(bytes: u64, blocks: u64) -> KernelDesc {
        KernelDesc {
            bytes_read: bytes,
            bytes_written: bytes / 2,
            flops: bytes / 4,
            blocks,
            threads: 256,
            smem_bytes: 0,
            coalescing: 1.0,
            op_weight: 1.0,
        }
    }

    #[test]
    fn tiny_kernels_are_launch_bound() {
        let dev = DeviceConfig::pascal();
        let d = desc(4096, 4);
        let t = kernel_time_us(&d, &dev);
        // launch overhead dominates: the fine-granularity problem (§1).
        assert!(dev.launch_overhead_us / t > 0.5, "t = {t}");
    }

    #[test]
    fn more_blocks_is_faster_until_saturation() {
        let dev = DeviceConfig::pascal();
        let big = 64 * 1024 * 1024u64;
        let t1 = kernel_exec_time_us(&desc(big, 1), &dev);
        let t56 = kernel_exec_time_us(&desc(big, 56), &dev);
        let t4096 = kernel_exec_time_us(&desc(big, 4096), &dev);
        assert!(t1 > t56, "{t1} vs {t56}");
        assert!(t56 > t4096, "{t56} vs {t4096}");
    }

    #[test]
    fn poor_coalescing_costs() {
        let dev = DeviceConfig::pascal();
        let mut d = desc(16 * 1024 * 1024, 2048);
        let good = kernel_exec_time_us(&d, &dev);
        d.coalescing = 0.4;
        let bad = kernel_exec_time_us(&d, &dev);
        assert!(bad > 2.0 * good);
    }

    #[test]
    fn smem_heavy_kernels_cost_more() {
        // The occupancy clamp must reach the cost: same traffic, same
        // grid, but 20 KB/block strangles residency (3 blocks/SM).
        let dev = DeviceConfig::pascal();
        let mut d = desc(64 * 1024 * 1024, 4096);
        let light = kernel_exec_time_us(&d, &dev);
        d.smem_bytes = 20 * 1024;
        let heavy = kernel_exec_time_us(&d, &dev);
        assert!(heavy > light, "heavy {heavy} vs light {light}");
    }

    #[test]
    fn expensive_ops_weigh_more() {
        let dev = DeviceConfig::pascal();
        let mut d = desc(1024 * 1024, 2048);
        d.flops = 100_000_000; // compute bound
        let cheap = kernel_exec_time_us(&d, &dev);
        d.op_weight = 8.0;
        let exp = kernel_exec_time_us(&d, &dev);
        assert!(exp > 4.0 * cheap);
    }

    #[test]
    fn library_call_bounded_by_peak() {
        let dev = DeviceConfig::pascal();
        let t = library_call_time_us(9_300_000_000, 1024, &dev, 0.8);
        // 9.3 GFLOP at 80% of 9.3 TFLOP/s ≈ 1250us + launch
        assert!((t - (1250.0 + dev.launch_overhead_us)).abs() < 10.0, "t = {t}");
    }
}
