//! Device configuration for the analytical GPU model.


/// Parameters of the simulated GPU. Defaults model the paper's testbed:
/// "a Pascal GPU, with 3584 cores and 64KB shared memory per SM"
/// (a P100/GP100-class part).
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    pub name: String,
    /// Number of streaming multiprocessors.
    pub sm_count: u32,
    /// CUDA cores per SM (FP32 lanes).
    pub cores_per_sm: u32,
    /// Shared memory per SM in bytes (the paper: 64 KB).
    pub shared_mem_per_sm: usize,
    /// Shared-memory budget FusionStitching allows one kernel (§6.5: the
    /// paper sets an upper limit, currently 20 KB).
    pub shared_mem_kernel_limit: usize,
    /// Peak DRAM bandwidth, bytes/us (P100 HBM2 ≈ 732 GB/s).
    pub dram_bw_bytes_per_us: f64,
    /// Achievable fraction of peak bandwidth for well-coalesced access.
    pub bw_efficiency: f64,
    /// Peak FP32 throughput, flops/us (P100 ≈ 9.3 TFLOP/s).
    pub peak_flops_per_us: f64,
    /// Fixed kernel launch overhead in us (driver + dispatch; the paper's
    /// motivation: fine-grained ops are launch-bound).
    pub launch_overhead_us: f64,
    /// Warp size.
    pub warp_size: u32,
    /// Max threads per block.
    pub max_threads_per_block: u32,
    /// Max resident blocks per SM.
    pub max_blocks_per_sm: u32,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        DeviceConfig::pascal()
    }
}

impl DeviceConfig {
    /// The paper's testbed: Pascal, 3584 cores, 64 KB smem/SM.
    pub fn pascal() -> Self {
        DeviceConfig {
            name: "sim-pascal".into(),
            sm_count: 56,
            cores_per_sm: 64,
            shared_mem_per_sm: 64 * 1024,
            shared_mem_kernel_limit: 20 * 1024,
            dram_bw_bytes_per_us: 732_000.0,
            bw_efficiency: 0.75,
            peak_flops_per_us: 9_300_000.0,
            launch_overhead_us: 4.0,
            warp_size: 32,
            max_threads_per_block: 1024,
            max_blocks_per_sm: 32,
        }
    }

    /// Total CUDA cores (sanity: pascal() gives the paper's 3584).
    pub fn total_cores(&self) -> u32 {
        self.sm_count * self.cores_per_sm
    }

    /// How many blocks of a kernel using `smem_bytes` of shared memory
    /// can be resident on one SM at once. Shared memory is the limiter
    /// FusionStitching actually stresses: stitched kernels trade DRAM
    /// traffic for per-block shared buffers.
    pub fn resident_blocks_per_sm(&self, smem_bytes: usize) -> u64 {
        let by_smem = if smem_bytes == 0 {
            self.max_blocks_per_sm as u64
        } else {
            ((self.shared_mem_per_sm / smem_bytes) as u64).max(1)
        };
        by_smem.min(self.max_blocks_per_sm as u64)
    }

    /// Fraction of the machine kept busy by `blocks` thread blocks of
    /// `threads` threads each, each holding `smem_bytes` of shared
    /// memory. Small grids underutilize (the motivation for enlarging
    /// kernel granularity).
    ///
    /// Model: SM *coverage* (each resident block occupies one SM) scaled
    /// by a latency-hiding bonus (more resident warps per SM hide more
    /// memory latency, up to the 64-slot limit) and a thread-count
    /// efficiency (blocks below ~4 warps cannot fill the FP32 pipes).
    /// Shared memory caps how many blocks an SM can host concurrently,
    /// so smem-heavy kernels keep fewer warps in flight.
    pub fn occupancy(&self, blocks: u64, threads: u32, smem_bytes: usize) -> f64 {
        let coverage = (blocks as f64 / self.sm_count as f64).min(1.0);
        let warps_per_block = (threads.max(1)).div_ceil(self.warp_size) as f64;
        let resident =
            blocks.min(self.sm_count as u64 * self.resident_blocks_per_sm(smem_bytes));
        let warp_slots = (self.sm_count as f64) * 64.0;
        let warp_occ = ((resident as f64 * warps_per_block) / warp_slots).min(1.0);
        let thread_eff = (threads as f64 / 128.0).clamp(0.25, 1.0);
        (coverage * (0.5 + 0.5 * warp_occ) * thread_eff).clamp(1e-4, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pascal_matches_paper() {
        let d = DeviceConfig::pascal();
        assert_eq!(d.total_cores(), 3584);
        assert_eq!(d.shared_mem_per_sm, 65536);
        assert_eq!(d.shared_mem_kernel_limit, 20 * 1024);
    }

    #[test]
    fn occupancy_monotone_in_blocks() {
        let d = DeviceConfig::pascal();
        let o1 = d.occupancy(1, 256, 0);
        let o8 = d.occupancy(8, 256, 0);
        let o1000 = d.occupancy(1000, 256, 0);
        let o100k = d.occupancy(100_000, 256, 0);
        assert!(o1 < o8 && o8 < o1000);
        assert!(o1000 <= o100k);
        assert!(o100k <= 1.0);
    }

    #[test]
    fn high_smem_kernel_scores_lower_occupancy_than_low_smem_twin() {
        // Regression: `KernelDesc.smem_bytes` must constrain occupancy.
        // At 20 KB/block only 3 blocks fit a 64 KB SM, so a large grid
        // keeps far fewer warps in flight than its smem-free twin.
        let d = DeviceConfig::pascal();
        let low = d.occupancy(4096, 64, 0);
        let high = d.occupancy(4096, 64, 20 * 1024);
        assert!(
            high < low,
            "smem-heavy kernel must lose occupancy: {high} vs {low}"
        );
        // tiny allocations leave residency unconstrained
        assert_eq!(d.occupancy(4096, 64, 512), low);
    }

    #[test]
    fn resident_blocks_capped_by_smem() {
        let d = DeviceConfig::pascal();
        assert_eq!(d.resident_blocks_per_sm(0), d.max_blocks_per_sm as u64);
        assert_eq!(d.resident_blocks_per_sm(20 * 1024), 3);
        // a block demanding more than the SM holds still "runs" alone
        assert_eq!(d.resident_blocks_per_sm(128 * 1024), 1);
    }
}
