//! Module-level simulated timing: aggregates per-kernel estimates the way
//! `nvprof` aggregates real kernels in the paper's evaluation (§6).

use super::cost::{kernel_time_us, library_call_time_us, KernelDesc};
use super::device::DeviceConfig;

/// One launched kernel in a simulated module execution.
#[derive(Debug, Clone)]
pub enum SimKernel {
    /// A generated (possibly fused) kernel.
    Generated(KernelDesc),
    /// A vendor library call (cuBLAS/cuDNN class): flops + bytes moved.
    Library { flops: u64, bytes: u64 },
}

/// Timing breakdown of one simulated module execution — the quantities
/// behind Figs. 6 and 8.
#[derive(Debug, Clone, Default)]
pub struct ModuleTiming {
    /// Time spent in generated (fusable-portion) kernels, us.
    pub fusable_us: f64,
    /// Time spent in library calls, us.
    pub library_us: f64,
    /// Number of generated kernel launches (the Fig. 7 numerator or
    /// denominator, library calls excluded per §6.3).
    pub generated_kernels: usize,
    /// Number of library-call launches.
    pub library_kernels: usize,
}

impl ModuleTiming {
    pub fn total_us(&self) -> f64 {
        self.fusable_us + self.library_us
    }

    /// The paper's FusableRatio: execution-time share of the fusable
    /// (non-MatMul/Conv) portion (§6.4).
    pub fn fusable_ratio(&self) -> f64 {
        if self.total_us() == 0.0 {
            0.0
        } else {
            self.fusable_us / self.total_us()
        }
    }
}

/// Simulate executing a sequence of kernels on `dev`.
/// `lib_efficiency` is the fraction of peak the vendor library achieves.
pub fn simulate_module(kernels: &[SimKernel], dev: &DeviceConfig, lib_efficiency: f64) -> ModuleTiming {
    let mut t = ModuleTiming::default();
    for k in kernels {
        match k {
            SimKernel::Generated(desc) => {
                t.fusable_us += kernel_time_us(desc, dev);
                t.generated_kernels += 1;
            }
            SimKernel::Library { flops, bytes } => {
                t.library_us += library_call_time_us(*flops, *bytes, dev, lib_efficiency);
                t.library_kernels += 1;
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(bytes: u64) -> SimKernel {
        SimKernel::Generated(KernelDesc {
            bytes_read: bytes,
            bytes_written: bytes,
            flops: bytes / 4,
            blocks: 128,
            threads: 256,
            smem_bytes: 0,
            coalescing: 1.0,
            op_weight: 1.0,
        })
    }

    #[test]
    fn breakdown_accounts_both_portions() {
        let dev = DeviceConfig::pascal();
        let kernels = vec![
            gen(1 << 20),
            gen(1 << 20),
            SimKernel::Library { flops: 1 << 30, bytes: 1 << 22 },
        ];
        let t = simulate_module(&kernels, &dev, 0.8);
        assert_eq!(t.generated_kernels, 2);
        assert_eq!(t.library_kernels, 1);
        assert!(t.fusable_us > 0.0 && t.library_us > 0.0);
        assert!((t.fusable_ratio() - t.fusable_us / t.total_us()).abs() < 1e-12);
    }

    #[test]
    fn fewer_launches_is_faster_for_tiny_kernels() {
        // The paper's core claim: fusing N launch-bound kernels into one
        // wins on launch overhead alone.
        let dev = DeviceConfig::pascal();
        let many: Vec<SimKernel> = (0..10).map(|_| gen(4096)).collect();
        let one = vec![gen(40960)];
        let t_many = simulate_module(&many, &dev, 0.8);
        let t_one = simulate_module(&one, &dev, 0.8);
        assert!(t_one.total_us() < t_many.total_us() / 3.0);
    }
}
