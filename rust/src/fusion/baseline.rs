//! The XLA-like fusion baseline — the comparison target of every
//! experiment (§6.1: "Our evaluation baseline is the XLA implementation
//! of fusion and code generation").
//!
//! Reimplements the static `ShouldFuse` discipline of XLA's
//! `GpuInstructionFusion` circa TF 1.7, whose known exceptions motivate
//! the paper (§1): expensive elementwise ops are not duplicated, column
//! reductions and layout transposes stay unfused, batched matmuls are
//! left alone, and reductions can only ever be fusion *roots* (input
//! fusion), never interior producers — because the single parallel loop
//! emitter composes ops by thread only.

use super::plan::FusionPlan;
use crate::hlo::{Computation, InstrId, Opcode};
use std::collections::HashSet;

/// Run the baseline pass and return the kernel partition.
pub fn xla_baseline_fusion(comp: &Computation) -> FusionPlan {
    // group_id per instruction; start with every non-free op a singleton.
    let n = comp.len();
    let mut group: Vec<Option<usize>> = vec![None; n];
    let mut next_group = 0usize;
    for id in comp.ids() {
        if !comp.get(id).opcode.is_free() && !comp.get(id).opcode.is_library_call() {
            group[id.0] = Some(next_group);
            next_group += 1;
        }
    }

    // Walk producers in reverse topological order, trying to fuse each
    // into its consumer's group (greedy, like XLA's reverse-post-order
    // instruction fusion).
    for idx in (0..n).rev() {
        let producer = InstrId(idx);
        if group[producer.0].is_none() {
            continue;
        }
        let users: Vec<InstrId> = comp.users(producer).to_vec();
        if users.is_empty() {
            continue;
        }
        // All users must already sit in one common group (no multi-output
        // fusion in the baseline) …
        let target = match group[users[0].0] {
            Some(g) if users.iter().all(|u| group[u.0] == Some(g)) => g,
            _ => continue,
        };
        if !should_fuse(comp, producer, &users, &group, target) {
            continue;
        }
        // … and fusing must not create an inter-group cycle: no operand
        // of the producer may transitively depend on a member of the
        // target group other than through the producer itself.
        if creates_cycle(comp, producer, &group, target) {
            continue;
        }
        group[producer.0] = Some(target);
    }

    assemble(comp, group)
}

/// XLA's static `ShouldFuse` rules (the baseline's whole intelligence).
fn should_fuse(
    comp: &Computation,
    producer: InstrId,
    users: &[InstrId],
    group: &[Option<usize>],
    target: usize,
) -> bool {
    let p = comp.get(producer);
    // Never fuse across library calls, and never fuse the library call.
    if p.opcode.is_library_call() {
        return false;
    }
    // While-loop bodies are separate computations in XLA: no kernel
    // straddles frames.
    if users.iter().any(|&u| comp.get(u).frame != p.frame) {
        return false;
    }
    // Batched matmuls are exceptions XLA leaves alone (§1).
    if p.opcode == Opcode::BatchDot {
        return false;
    }
    // Consumers must all be fusable kernels themselves.
    for &u in users {
        let uo = comp.get(u).opcode;
        if uo.is_library_call() || uo == Opcode::BatchDot {
            return false;
        }
    }
    // Reduce may be a fusion root but not an interior producer: the
    // single loop emitter cannot compose a reduction's value into a
    // consumer loop body (that is exactly what IrEmitterStitched adds).
    if p.opcode.is_reduce() {
        return false;
    }
    // Layout-changing transposes stay unfused (the elemental emitter
    // would serialize uncoalesced reads into every consumer thread).
    if p.opcode == Opcode::Transpose {
        let identity = p.min_trans_dim().is_none();
        if !identity {
            return false;
        }
    }
    // Gather-class data movement isn't loop-fusable.
    if matches!(
        p.opcode,
        Opcode::Gather | Opcode::DynamicSlice | Opcode::DynamicUpdateSlice | Opcode::Pad
    ) {
        return false;
    }
    // Expensive elementwise ops are not duplicated into multiple
    // consumers (XLA's duplication rule); with a single consumer they
    // fuse fine.
    if p.opcode.is_expensive_elementwise() && users.len() > 1 {
        return false;
    }
    // The target group must not already contain a reduce interior to the
    // new producer's path — conservatively, baseline groups contain at
    // most one reduce and it must be a root.
    let _ = (group, target);
    true
}

fn creates_cycle(
    comp: &Computation,
    producer: InstrId,
    group: &[Option<usize>],
    target: usize,
) -> bool {
    // DFS down from the producer's operands: reaching a member of
    // `target` means a path group → … → producer exists outside the
    // group.
    let mut stack: Vec<InstrId> = comp.get(producer).operands.clone();
    let mut seen: HashSet<InstrId> = HashSet::new();
    while let Some(id) = stack.pop() {
        if !seen.insert(id) {
            continue;
        }
        if group[id.0] == Some(target) {
            return true;
        }
        stack.extend(comp.get(id).operands.iter().copied());
    }
    false
}

fn assemble(comp: &Computation, group: Vec<Option<usize>>) -> FusionPlan {
    use std::collections::HashMap;
    let mut members: HashMap<usize, Vec<InstrId>> = HashMap::new();
    for id in comp.ids() {
        if let Some(g) = group[id.0] {
            members.entry(g).or_default().push(id);
        }
    }
    let mut groups: Vec<(Vec<InstrId>, Vec<InstrId>)> = Vec::new();
    for (_, m) in members {
        let mset: HashSet<InstrId> = m.iter().copied().collect();
        let roots: Vec<InstrId> = m
            .iter()
            .copied()
            .filter(|&id| {
                comp.users(id).iter().any(|u| !mset.contains(u)) || comp.users(id).is_empty()
            })
            .collect();
        groups.push((m, roots));
    }
    // Deterministic order for reproducible reports.
    groups.sort_by_key(|(m, _)| m.iter().map(|i| i.0).min().unwrap());
    FusionPlan::from_groups(comp, groups)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::{GraphBuilder, Shape};

    #[test]
    fn elementwise_chain_fuses_to_one_kernel() {
        let mut b = GraphBuilder::new("chain");
        let x = b.param("x", Shape::f32(&[128]));
        let a = b.add(x, x);
        let e = b.exp(a);
        let t = b.tanh(e);
        let comp = b.finish(t);
        let plan = xla_baseline_fusion(&comp);
        plan.validate(&comp).unwrap();
        assert_eq!(plan.generated_kernel_count(&comp), 1);
    }

    #[test]
    fn reduce_is_root_only() {
        // x -> exp -> reduce -> tanh : exp fuses into reduce (input
        // fusion), but reduce cannot fuse into tanh → 2 kernels.
        let mut b = GraphBuilder::new("r");
        let x = b.param("x", Shape::f32(&[64, 32]));
        let e = b.exp(x);
        let r = b.reduce(e, &[1], ReduceKind::Sum);
        let t = b.tanh(r);
        let comp = b.finish(t);
        let plan = xla_baseline_fusion(&comp);
        plan.validate(&comp).unwrap();
        assert_eq!(plan.generated_kernel_count(&comp), 2);
        // exp and reduce share a group
        assert_eq!(
            plan.group_of(e).unwrap().id,
            plan.group_of(r).unwrap().id
        );
    }

    #[test]
    fn softmax_needs_three_baseline_kernels() {
        // The Figure 3 inner pattern: max-reduce / exp+sum-reduce / div.
        let mut b = GraphBuilder::new("sm");
        let x = b.param("x", Shape::f32(&[8, 64]));
        let m = b.reduce(x, &[1], ReduceKind::Max);
        let mb = b.broadcast(m, &[8, 64], &[0]);
        let sh = b.sub(x, mb);
        let e = b.exp(sh);
        let s = b.reduce(e, &[1], ReduceKind::Sum);
        let sb = b.broadcast(s, &[8, 64], &[0]);
        let p = b.div(e, sb);
        let comp = b.finish(p);
        let plan = xla_baseline_fusion(&comp);
        plan.validate(&comp).unwrap();
        // exp has two users (sum-reduce and divide) and is expensive →
        // not duplicated; reduces are roots only. XLA ends up with ≥3
        // kernels where FusionStitching gets 1.
        assert!(plan.generated_kernel_count(&comp) >= 3);
    }

    #[test]
    fn transpose_stays_unfused() {
        let mut b = GraphBuilder::new("t");
        let x = b.param("x", Shape::f32(&[64, 32]));
        let t = b.transpose(x, &[1, 0]);
        let e = b.exp(t);
        let comp = b.finish(e);
        let plan = xla_baseline_fusion(&comp);
        assert_eq!(plan.generated_kernel_count(&comp), 2);
    }

    #[test]
    fn batch_dot_stays_unfused() {
        let mut b = GraphBuilder::new("bd");
        let x = b.param("x", Shape::f32(&[4, 8, 8]));
        let y = b.param("y", Shape::f32(&[4, 8, 8]));
        let d = b.batch_dot(x, y);
        let e = b.exp(d);
        let comp = b.finish(e);
        let plan = xla_baseline_fusion(&comp);
        assert_eq!(plan.generated_kernel_count(&comp), 2);
        let _ = d;
    }

    #[test]
    fn library_call_delimits() {
        let mut b = GraphBuilder::new("lc");
        let x = b.param("x", Shape::f32(&[16, 16]));
        let w = b.param("w", Shape::f32(&[16, 16]));
        let a = b.add(x, x);
        let d = b.dot(a, w);
        let e = b.exp(d);
        let comp = b.finish(e);
        let plan = xla_baseline_fusion(&comp);
        plan.validate(&comp).unwrap();
        assert_eq!(plan.library_call_count(), 1);
        assert_eq!(plan.generated_kernel_count(&comp), 2); // add, exp
    }

    #[test]
    fn cheap_producer_with_diverging_users_not_fused_without_mof() {
        // broadcast consumed by two different groups: baseline (no
        // multi-output fusion) leaves it standalone.
        let mut b = GraphBuilder::new("div");
        let x = b.param("x", Shape::f32(&[8]));
        let bc = b.broadcast(x, &[4, 8], &[1]);
        let e = b.exp(bc);
        let r = b.reduce(bc, &[0], ReduceKind::Sum);
        let rb = b.broadcast(r, &[4, 8], &[1]);
        let out = b.add(e, rb);
        let comp = b.finish(out);
        let plan = xla_baseline_fusion(&comp);
        plan.validate(&comp).unwrap();
        assert!(plan.generated_kernel_count(&comp) >= 2);
    }
}
