//! Cost-guided fusion exploration — profitability-driven refinement of
//! the greedy deep-fusion plan.
//!
//! Algorithm 1 grows groups greedily: it admits an instruction whenever
//! `SchdConsistent` accepts it. The follow-up FusionStitching work
//! (arXiv:2009.10924) makes fusion decisions cost-driven instead: every
//! candidate grouping is scored fused-vs-unfused through the analytical
//! GPU model, and the plan is refined until the modeled time stops
//! improving. This module implements that exploration loop over a
//! completed greedy plan:
//!
//! - **merge**: adjacent producer/consumer groups are merged when the
//!   merged kernel's modeled time (launch overhead + tuned
//!   `kernel_exec_time_us`, shared-memory residency included) beats the
//!   two separate kernels. With global stitching on
//!   ([`DeepFusionConfig::global_stitch`]), a merge whose intermediates
//!   overflow shared memory is costed as DRAM spill traffic plus one
//!   grid fence per spill ([`GLOBAL_FENCE_US`]) instead of being ruled
//!   out — the third stitching tier, which beats a split whenever the
//!   fence is cheaper than the saved launch;
//! - **split**: a group is split at a span-layer boundary when the two
//!   halves are modeled faster than the whole — but only while the plan
//!   stays within the greedy plan's launch budget, so a cost-guided
//!   plan never executes more kernel launches than the greedy one;
//! - **memoization**: every evaluated grouping's modeled cost is stored
//!   in the [`PerfLibrary`] keyed by the group's structural fingerprint
//!   (device signature folded in by the library), so serving recompiles
//!   replay exploration verdicts instead of re-tuning every candidate.
//!
//! The refined plan is re-validated by the driver's `validate-plan`
//! pass; moves are constructed to preserve the partition invariants
//! (same-frame groups, inter-group acyclicity) by themselves.

use super::deep::DeepFusionConfig;
use super::plan::{FusionPlan, GroupKind};
use crate::analysis::SpanAnalysis;
use crate::codegen::kernel_plan::fused_kernel_desc;
use crate::codegen::shm_planner::{plan_shared_memory, plan_shared_memory_spill};
use crate::gpusim::DeviceConfig;
use crate::hlo::{Computation, InstrId, Opcode};
use crate::schedule::{tune_with_oracle, CostOracle, ModeledCost, PerfLibrary, TuningConfig};
use std::collections::{BTreeSet, HashMap, HashSet};

/// Bound on refinement rounds: each round retries merges and splits over
/// the whole plan; small graphs converge in one or two.
const MAX_ROUNDS: usize = 3;

/// Modeled cost of one grid-wide fence (cooperative-launch
/// `grid.sync`), charged per spilled intermediate when costing a
/// global-tier group. Cheaper than a kernel launch
/// (`DeviceConfig::pascal` models 4.0us of launch overhead), so the
/// model prefers one fenced kernel over two launches whenever the
/// spill's DRAM round trip doesn't dominate.
pub const GLOBAL_FENCE_US: f64 = 1.0;

/// What exploration did to the greedy plan.
#[derive(Debug, Clone, Default)]
pub struct ExploreStats {
    pub merges_tried: usize,
    pub merges_accepted: usize,
    pub splits_tried: usize,
    pub splits_accepted: usize,
    /// Group-cost evaluations answered by the perf-library memo.
    pub memo_hits: u64,
    /// Modeled time of the plan's generated kernels before/after
    /// refinement (groups the model cannot schedule are excluded from
    /// both sums, so the two are comparable).
    pub modeled_before_us: f64,
    pub modeled_after_us: f64,
}

/// Structural fingerprint of a fused group: member opcodes, shapes,
/// frames and internal/external connectivity, independent of absolute
/// instruction ids (members are canonicalized to their sorted-id rank).
/// Two structurally identical groups — e.g. the same attention block
/// recompiled in a serving process — share a fingerprint, which is what
/// lets the exploration memo carry across compilations.
pub fn group_fingerprint(comp: &Computation, members: &HashSet<InstrId>) -> u64 {
    use crate::schedule::perf_library::{fnv1a_fold, FNV_SEED};
    fn mix(h: u64, v: u64) -> u64 {
        fnv1a_fold(h, &v.to_le_bytes())
    }
    let mut ordered: Vec<InstrId> = members.iter().copied().collect();
    ordered.sort_unstable();
    let rank: HashMap<InstrId, u64> =
        ordered.iter().enumerate().map(|(k, &id)| (id, k as u64)).collect();
    let mut h: u64 = FNV_SEED;
    for &id in &ordered {
        let i = comp.get(id);
        h = mix(h, i.opcode as u64);
        h = mix(h, i.frame as u64);
        // Attrs (reduce dims/kind, transpose perm, broadcast dims, …)
        // change how a group schedules and costs — twins differing only
        // in attrs must not share a memo entry.
        h = mix(h, crate::schedule::perf_library::fnv1a(format!("{:?}", i.attrs).as_bytes()));
        h = mix(h, i.shape.dtype as u64);
        h = mix(h, i.shape.dims.len() as u64);
        for &d in &i.shape.dims {
            h = mix(h, d as u64);
        }
        for &op in &i.operands {
            match rank.get(&op) {
                Some(&k) => {
                    h = mix(h, 1);
                    h = mix(h, k);
                }
                None => {
                    let o = comp.get(op);
                    h = mix(h, 2);
                    h = mix(h, o.shape.dtype as u64);
                    h = mix(h, o.shape.dims.len() as u64);
                    for &d in &o.shape.dims {
                        h = mix(h, d as u64);
                    }
                }
            }
        }
        // Root-ness (whether the value escapes) changes the kernel's
        // DRAM traffic, so it is part of the identity.
        let escapes =
            comp.users(id).iter().any(|u| !members.contains(u)) || comp.users(id).is_empty();
        h = mix(h, escapes as u64);
    }
    h
}

/// Output-producing members of a member set (values that escape).
fn roots_of(comp: &Computation, members: &HashSet<InstrId>) -> Vec<InstrId> {
    let mut r: Vec<InstrId> = members
        .iter()
        .copied()
        .filter(|&id| {
            comp.users(id).iter().any(|u| !members.contains(u)) || comp.users(id).is_empty()
        })
        .collect();
    r.sort_unstable();
    r
}

/// The exploration engine: owns the tuning resources and the per-run
/// cost cache layered over the persistent perf-library memo.
struct Explorer<'a> {
    lib: &'a mut PerfLibrary,
    tuning: TuningConfig,
    cfg_sig: u64,
    dev: DeviceConfig,
    global_stitch: bool,
    /// Cost seam: the analytic model, or a measured overlay during the
    /// serving pool's feedback-directed re-explore.
    oracle: &'a dyn CostOracle,
    stats: ExploreStats,
    /// In-process cache: fingerprint → modeled cost (INFINITY when the
    /// grouping is unschedulable).
    cache: HashMap<u64, f64>,
}

impl<'a> Explorer<'a> {
    fn new(lib: &'a mut PerfLibrary, cfg: &DeepFusionConfig, oracle: &'a dyn CostOracle) -> Self {
        // The modeled cost depends on the tuning space AND on the
        // device the pipeline models with (`cfg.device`), which need
        // not be the device the library was constructed under — so the
        // memo key carries digests of both alongside the fingerprint.
        // The global-stitch flag changes costs too (spill vs INFINITY),
        // so it is part of the signature.
        let sig = crate::schedule::perf_library::fnv1a(
            format!("{:?}|{:?}|gs{}", cfg.tuning, cfg.device, cfg.global_stitch as u8).as_bytes(),
        );
        Explorer {
            lib,
            tuning: cfg.tuning.clone(),
            cfg_sig: sig,
            dev: cfg.device.clone(),
            global_stitch: cfg.global_stitch,
            oracle,
            stats: ExploreStats::default(),
            cache: HashMap::new(),
        }
    }

    /// Modeled wall time of `members` as one fused kernel: one launch
    /// overhead plus the tuned schedule's execution time with the
    /// group's shared-memory residency. With global stitching on,
    /// overflowing intermediates cost DRAM spill traffic plus one grid
    /// fence each instead of failing; with it off, `f64::INFINITY` when
    /// no shared-memory plan exists. Unschedulable groupings are
    /// `f64::INFINITY` either way — such groupings are never created
    /// and existing ones are left untouched (the driver falls back to
    /// per-op baseline kernels for them).
    fn cost_of(&mut self, comp: &Computation, members: &HashSet<InstrId>) -> f64 {
        let fp = group_fingerprint(comp, members);
        if let Some(&v) = self.cache.get(&fp) {
            return v;
        }
        // The cost-source tag (`m` for the model, `w<epoch>` for a
        // measured overlay) is part of the memo identity: a verdict
        // reached under measured feedback must not be replayed by a
        // purely modeled compile, and each write-back epoch re-evaluates
        // rather than inheriting stale overlays.
        let key = format!(
            "xg{:016x}|t{:016x}|c{}",
            fp,
            self.cfg_sig,
            self.oracle.source_tag()
        );
        if let Some(v) = self.lib.explore_lookup(&key) {
            self.stats.memo_hits += 1;
            self.cache.insert(fp, v);
            return v;
        }
        let roots = roots_of(comp, members);
        let modeled = match tune_with_oracle(comp, members, &roots, self.lib, &self.tuning, self.oracle)
        {
            Some(plan) if self.global_stitch => {
                let shm = plan_shared_memory_spill(comp, members, &roots, &plan, &self.dev);
                let mut desc = fused_kernel_desc(comp, members, &plan);
                desc.smem_bytes = shm.total_bytes;
                // Spilled intermediates round-trip through DRAM and
                // cost one grid-wide fence each (mirrors
                // `KernelPlan::to_kernel_desc`).
                for &id in &shm.spilled {
                    let bytes = comp.get(id).shape.byte_size() as u64;
                    desc.bytes_read += bytes;
                    desc.bytes_written += bytes;
                }
                self.oracle.kernel_time_us(&desc, &self.dev)
                    + shm.spilled.len() as f64 * GLOBAL_FENCE_US
            }
            Some(plan) => match plan_shared_memory(comp, members, &roots, &plan, &self.dev) {
                Ok(shm) => {
                    let mut desc = fused_kernel_desc(comp, members, &plan);
                    desc.smem_bytes = shm.total_bytes;
                    self.oracle.kernel_time_us(&desc, &self.dev)
                }
                Err(_) => f64::INFINITY,
            },
            None => f64::INFINITY,
        };
        // Measured overlay applies at group granularity (that is the
        // unit the VM launches and times); unschedulable groupings stay
        // infinite no matter what was measured.
        let v = if modeled.is_finite() {
            self.oracle.group_cost_us(fp, modeled)
        } else {
            modeled
        };
        self.lib.explore_insert(&key, v);
        self.cache.insert(fp, v);
        v
    }
}

/// Can this group participate in merge/split moves at all?
fn movable(comp: &Computation, members: &HashSet<InstrId>, cfg: &DeepFusionConfig) -> bool {
    members.iter().all(|&id| {
        let op = comp.get(id).opcode;
        op.is_fusable() && (op != Opcode::BatchDot || cfg.fuse_batch_dot)
    })
}

/// Would merging producer group `gi` into consumer group `gj` close a
/// dependency cycle through a third group? True when some external
/// operand of `gj` transitively depends on a member of `gi`.
fn merge_creates_cycle(
    comp: &Computation,
    gi: &HashSet<InstrId>,
    gj: &HashSet<InstrId>,
) -> bool {
    let producers: Vec<InstrId> = gi.iter().copied().collect();
    for &m in gj {
        for &op in &comp.get(m).operands {
            if gi.contains(&op) || gj.contains(&op) {
                continue;
            }
            if producers.iter().any(|&a| comp.depends_on(op, a)) {
                return true;
            }
        }
    }
    false
}

/// Refine `plan` (the greedy deep-fusion output) with cost-guided
/// merge/split moves. The returned plan launches at most as many
/// generated kernels as the input and never models slower.
pub fn explore_fusion(
    comp: &Computation,
    plan: &FusionPlan,
    lib: &mut PerfLibrary,
    cfg: &DeepFusionConfig,
) -> (FusionPlan, ExploreStats) {
    explore_fusion_with_oracle(comp, plan, lib, cfg, &ModeledCost)
}

/// [`explore_fusion`] with every group cost routed through `oracle`.
/// The serving pool's background autotune step re-runs this with a
/// [`crate::schedule::MeasuredCost`] overlay built from launch-span
/// write-backs, then hot-swaps the compiled module when the refined
/// plan differs.
pub fn explore_fusion_with_oracle(
    comp: &Computation,
    plan: &FusionPlan,
    lib: &mut PerfLibrary,
    cfg: &DeepFusionConfig,
    oracle: &dyn CostOracle,
) -> (FusionPlan, ExploreStats) {
    let spans = SpanAnalysis::run(comp);
    let mut ex = Explorer::new(lib, cfg, oracle);

    // Working set: every non-library group (library calls are pinned —
    // they are the roofs fusion may not cross). `None` = merged away.
    let mut groups: Vec<Option<HashSet<InstrId>>> = plan
        .groups
        .iter()
        .filter(|g| g.kind != GroupKind::Library)
        .map(|g| Some(g.members.clone()))
        .collect();
    // The launch budget: cost-guided plans must never execute more
    // generated launches than the greedy plan.
    let budget = groups.iter().flatten().count();
    let mut live = budget;

    for members in groups.iter().flatten() {
        let c = ex.cost_of(comp, members);
        if c.is_finite() {
            ex.stats.modeled_before_us += c;
        }
    }

    for _round in 0..MAX_ROUNDS {
        let mut changed = false;

        // ---- merge pass: producer/consumer adjacency ----
        //
        // Each sweep walks every consumer group once; an accepted merge
        // updates the owner map in place and moves on (the enlarged
        // group is revisited on the next sweep), so the pass costs
        // O(sweeps × pairs) instead of restarting the scan per merge.
        loop {
            let mut merged_one = false;
            let mut owner: HashMap<InstrId, usize> = groups
                .iter()
                .enumerate()
                .flat_map(|(gi, g)| {
                    g.iter().flat_map(move |m| m.iter().map(move |&id| (id, gi)))
                })
                .collect();
            let mut order: Vec<usize> = (0..groups.len()).filter(|&g| groups[g].is_some()).collect();
            order.sort_by_key(|&g| groups[g].as_ref().unwrap().iter().min().copied());
            for &j in &order {
                let Some(gj) = groups[j].clone() else { continue };
                if !movable(comp, &gj, cfg) {
                    continue;
                }
                let mut consumed: Vec<InstrId> = gj.iter().copied().collect();
                consumed.sort_unstable();
                let mut feeders: BTreeSet<usize> = BTreeSet::new();
                for &m in &consumed {
                    for &op in &comp.get(m).operands {
                        if let Some(&i) = owner.get(&op) {
                            if i != j {
                                feeders.insert(i);
                            }
                        }
                    }
                }
                for i in feeders {
                    let Some(gi) = groups[i].clone() else { continue };
                    if !movable(comp, &gi, cfg) {
                        continue;
                    }
                    let fi = comp.get(*gi.iter().next().unwrap()).frame;
                    let fj = comp.get(*gj.iter().next().unwrap()).frame;
                    if fi != fj {
                        continue;
                    }
                    ex.stats.merges_tried += 1;
                    if merge_creates_cycle(comp, &gi, &gj) {
                        continue;
                    }
                    // Both sides must themselves be schedulable: a group
                    // the tuner rejects runs on the driver's fallback
                    // plan, whose simulated time the model never saw —
                    // comparing against `∞` would accept any merge and
                    // could regress the real modeled total.
                    let c_apart = ex.cost_of(comp, &gi) + ex.cost_of(comp, &gj);
                    if !c_apart.is_finite() {
                        continue;
                    }
                    let merged: HashSet<InstrId> = gi.union(&gj).copied().collect();
                    let c_merged = ex.cost_of(comp, &merged);
                    if c_merged + 1e-9 < c_apart {
                        for &id in &gi {
                            owner.insert(id, j);
                        }
                        groups[j] = Some(merged);
                        groups[i] = None;
                        live -= 1;
                        ex.stats.merges_accepted += 1;
                        merged_one = true;
                        changed = true;
                        // This consumer's member set changed — move on;
                        // further feeders are picked up next sweep.
                        break;
                    }
                }
            }
            if !merged_one {
                break;
            }
        }

        // ---- split pass: span-layer cuts, within the launch budget ----
        for g in 0..groups.len() {
            if live >= budget {
                break; // no headroom: a split would exceed greedy's launches
            }
            let Some(members) = groups[g].clone() else { continue };
            if members.len() < 2 || !movable(comp, &members, cfg) {
                continue;
            }
            let whole = ex.cost_of(comp, &members);
            if !whole.is_finite() {
                continue;
            }
            // Candidate cuts: between distinct span layers. Producers
            // carry strictly larger spans than their users, so every
            // cross-cut edge points high→low and both halves stay
            // acyclic against the rest of the plan.
            let cuts: BTreeSet<u32> = members.iter().map(|&id| spans.span_of(id)).collect();
            for &cut in cuts.iter().skip(1) {
                ex.stats.splits_tried += 1;
                let hi: HashSet<InstrId> =
                    members.iter().copied().filter(|&id| spans.span_of(id) >= cut).collect();
                let lo: HashSet<InstrId> =
                    members.iter().copied().filter(|&id| spans.span_of(id) < cut).collect();
                let has_kernel = |part: &HashSet<InstrId>| {
                    part.iter().any(|&id| !comp.get(id).opcode.is_free())
                };
                if hi.is_empty() || lo.is_empty() || !has_kernel(&hi) || !has_kernel(&lo) {
                    continue;
                }
                // Spans order edges within one frame only; a detour
                // through another frame (lo → X → hi) would still close
                // a cycle against the internal hi → lo edges, so run
                // the same external-dependency check merges use.
                if merge_creates_cycle(comp, &lo, &hi) {
                    continue;
                }
                let c_hi = ex.cost_of(comp, &hi);
                let c_lo = ex.cost_of(comp, &lo);
                if c_hi.is_finite() && c_lo.is_finite() && c_hi + c_lo + 1e-9 < whole {
                    groups[g] = Some(hi);
                    groups.push(Some(lo));
                    live += 1;
                    ex.stats.splits_accepted += 1;
                    changed = true;
                    break;
                }
            }
        }

        if !changed {
            break;
        }
    }

    let final_groups: Vec<(Vec<InstrId>, Vec<InstrId>)> = {
        let mut with_key: Vec<(InstrId, Vec<InstrId>, Vec<InstrId>)> = groups
            .into_iter()
            .flatten()
            .map(|members| {
                let roots = roots_of(comp, &members);
                let mut m: Vec<InstrId> = members.iter().copied().collect();
                m.sort_unstable();
                (m[0], m, roots)
            })
            .collect();
        // Deterministic group ids: order by least member.
        with_key.sort_by_key(|(k, _, _)| *k);
        with_key.into_iter().map(|(_, m, r)| (m, r)).collect()
    };
    for (members, _) in &final_groups {
        let set: HashSet<InstrId> = members.iter().copied().collect();
        let c = ex.cost_of(comp, &set);
        if c.is_finite() {
            ex.stats.modeled_after_us += c;
        }
    }
    let stats = ex.stats;
    let refined = FusionPlan::from_groups(comp, final_groups);
    debug_assert!(refined.validate(comp).is_ok());
    (refined, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::deep::deep_fusion;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::{GraphBuilder, Shape};

    fn cfg() -> DeepFusionConfig {
        DeepFusionConfig::default()
    }

    #[test]
    fn merges_adjacent_singletons_when_profitable() {
        // Two launch-bound singleton kernels in a chain: one merged
        // kernel saves a launch and the boundary round trip.
        let mut b = GraphBuilder::new("chain");
        let x = b.param("x", Shape::f32(&[64, 64]));
        let e = b.exp(x);
        let t = b.tanh(e);
        let comp = b.finish(t);
        // Hand-build the unfused plan (each op its own kernel).
        let plan = FusionPlan::from_groups(&comp, vec![]);
        assert_eq!(plan.generated_kernel_count(&comp), 2);
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let (refined, stats) = explore_fusion(&comp, &plan, &mut lib, &cfg());
        refined.validate(&comp).unwrap();
        assert_eq!(refined.generated_kernel_count(&comp), 1, "chain should merge");
        assert!(stats.merges_accepted >= 1);
        assert!(stats.modeled_after_us < stats.modeled_before_us);
    }

    #[test]
    fn never_exceeds_greedy_launch_budget() {
        // Whatever exploration does, the refined plan may not launch
        // more generated kernels than its input.
        let mut b = GraphBuilder::new("mix");
        let x = b.param("x", Shape::f32(&[4096, 64]));
        let e = b.exp(x);
        let r = b.reduce(e, &[0, 1], ReduceKind::Sum); // scalar root
        let t = b.tanh(r);
        let comp = b.finish(t);
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let (greedy, _) = deep_fusion(&comp, &mut lib, &cfg());
        let before = greedy.generated_kernel_count(&comp);
        let (refined, _) = explore_fusion(&comp, &greedy, &mut lib, &cfg());
        refined.validate(&comp).unwrap();
        assert!(
            refined.generated_kernel_count(&comp) <= before,
            "{} > {}",
            refined.generated_kernel_count(&comp),
            before
        );
    }

    #[test]
    fn split_rescues_a_serialized_group_when_budget_allows() {
        // A scalar-rooted reduce pins its group to one block; with a
        // heavy transcendental chain fused in, all that compute runs at
        // ~2% occupancy and the modeled time explodes. Splitting the
        // chain off lets it run at full occupancy for one extra launch.
        // A disconnected mergeable chain provides the launch headroom
        // (the budget guarantees refined launches ≤ greedy launches).
        let mut b = GraphBuilder::new("rescue");
        let x = b.param("x", Shape::f32(&[2048, 2048]));
        let e = b.exp(x);
        let t = b.tanh(e);
        let g = b.sigmoid(t);
        let r = b.reduce(g, &[0, 1], ReduceKind::Sum); // scalar sink
        let _ = r;
        let y = b.param("y", Shape::f32(&[64]));
        let a1 = b.exp(y);
        let a2 = b.tanh(a1);
        let out = b.add(a2, a2);
        let comp = b.finish(out);

        // Hand-build a bad plan: {e, t, g, r} fused at one block; the
        // a1/a2/out chain left as singletons (merge fodder).
        let members = vec![(vec![e, t, g, r], vec![r])];
        let plan = FusionPlan::from_groups(&comp, members);
        let before = plan.generated_kernel_count(&comp);
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let (refined, stats) = explore_fusion(&comp, &plan, &mut lib, &cfg());
        refined.validate(&comp).unwrap();
        assert!(refined.generated_kernel_count(&comp) <= before);
        assert!(stats.merges_accepted >= 1, "chain should merge: {stats:?}");
        // The serialized group should be split once merge headroom
        // exists (the one-block kernel dominates the modeled time).
        assert!(stats.splits_accepted >= 1, "serialized group should split: {stats:?}");
        assert!(stats.modeled_after_us < stats.modeled_before_us);
    }

    #[test]
    fn global_stitch_merges_an_overflowing_chain() {
        // The overflow-corpus chains have an interior reduce whose
        // per-block chunk exceeds pascal's 20KB budget under every legal
        // schedule, so shared-memory stitching alone cannot merge across
        // it. With global stitching on, the explorer costs the spill
        // (the same DRAM round trip the split pays at the kernel
        // boundary anyway) plus one grid fence (1us) against the saved
        // launch (4us) and accepts the merge; with it off the
        // overflowing merge costs INFINITY and is refused.
        for comp in crate::corpus::generate_overflow_models() {
            let plan = FusionPlan::from_groups(&comp, vec![]);
            let before = plan.generated_kernel_count(&comp);

            let mut lib_on = PerfLibrary::new(DeviceConfig::pascal());
            let (on, on_stats) = explore_fusion(&comp, &plan, &mut lib_on, &cfg());
            on.validate(&comp).unwrap();

            let mut lib_off = PerfLibrary::new(DeviceConfig::pascal());
            let off_cfg = DeepFusionConfig { global_stitch: false, ..Default::default() };
            let (off, _) = explore_fusion(&comp, &plan, &mut lib_off, &off_cfg);
            off.validate(&comp).unwrap();

            assert!(
                on.generated_kernel_count(&comp) < off.generated_kernel_count(&comp),
                "{}: global tier must enable a merge shm stitching cannot: on={} off={}",
                comp.name,
                on.generated_kernel_count(&comp),
                off.generated_kernel_count(&comp)
            );
            assert!(off.generated_kernel_count(&comp) <= before, "{}", comp.name);
            assert!(on_stats.merges_accepted >= 1, "{}", comp.name);
        }
    }

    #[test]
    fn exploration_memoizes_group_costs() {
        let mut b = GraphBuilder::new("memo");
        let x = b.param("x", Shape::f32(&[64, 64]));
        let e = b.exp(x);
        let t = b.tanh(e);
        let comp = b.finish(t);
        let plan = FusionPlan::from_groups(&comp, vec![]);
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let (_, first) = explore_fusion(&comp, &plan, &mut lib, &cfg());
        assert_eq!(first.memo_hits, 0, "cold run misses the memo");
        assert!(lib.explore_len() > 0, "cold run must populate the memo");
        let (_, second) = explore_fusion(&comp, &plan, &mut lib, &cfg());
        assert!(second.memo_hits > 0, "recompile must replay memoized verdicts");
    }

    #[test]
    fn group_fingerprint_is_id_invariant() {
        // Structural twins with different instruction numbering share a
        // group fingerprint — the property the serving memo relies on.
        let mut b1 = GraphBuilder::new("a");
        let x = b1.param("x", Shape::f32(&[32, 16]));
        let e1 = b1.exp(x);
        let t1 = b1.tanh(e1);
        let c1 = b1.finish(t1);

        let mut b2 = GraphBuilder::new("b");
        let p = b2.param("p", Shape::f32(&[8]));
        let pad = b2.exp(p); // shift ids
        let x2 = b2.param("x", Shape::f32(&[32, 16]));
        let e2 = b2.exp(x2);
        let t2 = b2.tanh(e2);
        let a = b2.add(pad, pad);
        let _ = a;
        let c2 = b2.finish(t2);

        let g1: HashSet<InstrId> = [e1, t1].into_iter().collect();
        let g2: HashSet<InstrId> = [e2, t2].into_iter().collect();
        assert_eq!(group_fingerprint(&c1, &g1), group_fingerprint(&c2, &g2));

        // and a different shape changes it
        let mut b3 = GraphBuilder::new("c");
        let x3 = b3.param("x", Shape::f32(&[32, 32]));
        let e3 = b3.exp(x3);
        let t3 = b3.tanh(e3);
        let c3 = b3.finish(t3);
        let g3: HashSet<InstrId> = [e3, t3].into_iter().collect();
        assert_ne!(group_fingerprint(&c1, &g1), group_fingerprint(&c3, &g3));
    }
}
