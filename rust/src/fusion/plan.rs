//! Fusion plan: a partition of a computation's instructions into kernel
//! groups.
//!
//! Unlike XLA (which rewrites the graph with nested fusion computations),
//! we keep the original graph immutable and overlay a group assignment —
//! every downstream pass (scheduling, shared-memory planning, codegen,
//! simulation) operates per group on the original instructions. Kernel
//! counting for Fig. 7 falls directly out of the partition.

use crate::hlo::{Computation, InstrId};
use std::collections::{HashMap, HashSet};

/// What kind of kernel a group lowers to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupKind {
    /// Single parallel loop emitter (XLA-style thread composition only).
    Loop,
    /// Block composition: multiple emitters stitched through shared
    /// memory (`IrEmitterStitched`).
    Stitched,
    /// A vendor library call (cuBLAS/cuDNN) — excluded from the Fig. 7
    /// kernel counts.
    Library,
}

/// One fused kernel.
#[derive(Debug, Clone)]
pub struct FusionGroup {
    pub id: usize,
    /// Member instructions (includes the roots).
    pub members: HashSet<InstrId>,
    /// Output-producing members (fusion roots). For single-root groups
    /// this is the classic `fusion_root`.
    pub roots: Vec<InstrId>,
    pub kind: GroupKind,
}

impl FusionGroup {
    /// Does this group launch a generated GPU kernel? Library calls and
    /// all-free groups do not count toward the fusion ratio (§6.3
    /// "excluding library call kernels").
    pub fn is_generated_kernel(&self, comp: &Computation) -> bool {
        self.kind != GroupKind::Library
            && self.members.iter().any(|&id| !comp.get(id).opcode.is_free())
    }
}

/// The complete partition.
#[derive(Debug, Clone, Default)]
pub struct FusionPlan {
    pub groups: Vec<FusionGroup>,
    instr_to_group: HashMap<InstrId, usize>,
}

impl FusionPlan {
    /// Build a plan from group member sets; instructions not covered by
    /// any set become singleton groups (their own kernels), and library
    /// calls become `Library` groups. This "completion" guarantees the
    /// partition covers every non-free instruction exactly once.
    pub fn from_groups(comp: &Computation, groups: Vec<(Vec<InstrId>, Vec<InstrId>)>) -> Self {
        let mut plan = FusionPlan::default();
        for (members, roots) in groups {
            plan.push_group(comp, members, roots);
        }
        // Completion: cover the rest.
        let covered: HashSet<InstrId> = plan.instr_to_group.keys().copied().collect();
        for id in comp.ids() {
            let instr = comp.get(id);
            if covered.contains(&id) || instr.opcode.is_free() {
                continue;
            }
            plan.push_group(comp, vec![id], vec![id]);
        }
        plan
    }

    fn push_group(&mut self, comp: &Computation, members: Vec<InstrId>, roots: Vec<InstrId>) {
        let gid = self.groups.len();
        let member_set: HashSet<InstrId> = members.iter().copied().collect();
        assert!(!member_set.is_empty(), "empty fusion group");
        for &m in &member_set {
            let prev = self.instr_to_group.insert(m, gid);
            assert!(prev.is_none(), "instruction {m} in two groups");
        }
        let kind = if member_set.len() == 1
            && comp.get(*member_set.iter().next().unwrap()).opcode.is_library_call()
        {
            GroupKind::Library
        } else if needs_stitching(comp, &member_set) {
            GroupKind::Stitched
        } else {
            GroupKind::Loop
        };
        debug_assert!(!roots.is_empty());
        self.groups.push(FusionGroup { id: gid, members: member_set, roots, kind });
    }

    pub fn group_of(&self, id: InstrId) -> Option<&FusionGroup> {
        self.instr_to_group.get(&id).map(|&g| &self.groups[g])
    }

    /// Generated-kernel launches (the Fig. 7 count, library calls
    /// excluded).
    pub fn generated_kernel_count(&self, comp: &Computation) -> usize {
        self.groups.iter().filter(|g| g.is_generated_kernel(comp)).count()
    }

    /// Library-call launches.
    pub fn library_call_count(&self) -> usize {
        self.groups.iter().filter(|g| g.kind == GroupKind::Library).count()
    }

    /// Order-independent identity of the partition itself: an FNV digest
    /// over every group's sorted member and root ids (kind excluded — it
    /// is derived from membership). Two plans partitioning the same
    /// computation the same way share a digest regardless of group
    /// numbering; the serving pool's hot-swap step compares digests to
    /// decide whether a measured re-explore actually changed the plan.
    pub fn digest(&self) -> u64 {
        use crate::schedule::perf_library::{fnv1a_fold, FNV_SEED};
        fn mix(h: u64, v: u64) -> u64 {
            fnv1a_fold(h, &v.to_le_bytes())
        }
        let mut groups: Vec<(Vec<u64>, Vec<u64>)> = self
            .groups
            .iter()
            .map(|g| {
                let mut m: Vec<u64> = g.members.iter().map(|id| id.0 as u64).collect();
                m.sort_unstable();
                let mut r: Vec<u64> = g.roots.iter().map(|id| id.0 as u64).collect();
                r.sort_unstable();
                (m, r)
            })
            .collect();
        groups.sort();
        let mut h = FNV_SEED;
        for (members, roots) in groups {
            h = mix(h, 0x67); // group marker
            h = mix(h, members.len() as u64);
            for v in members {
                h = mix(h, v);
            }
            h = mix(h, roots.len() as u64);
            for v in roots {
                h = mix(h, v);
            }
        }
        h
    }

    /// Partition sanity: every non-free instruction in exactly one group,
    /// all groups acyclic w.r.t. each other (no group both feeds and
    /// consumes another). Used by tests and debug assertions.
    pub fn validate(&self, comp: &Computation) -> crate::Result<()> {
        for id in comp.ids() {
            if !comp.get(id).opcode.is_free() && self.group_of(id).is_none() {
                anyhow::bail!("instruction {id} not covered by any group");
            }
        }
        // Inter-group acyclicity: contract groups and look for a cycle.
        let gcount = self.groups.len();
        let mut edges: HashSet<(usize, usize)> = HashSet::new();
        for id in comp.ids() {
            let Some(gu) = self.instr_to_group.get(&id) else { continue };
            for &op in &comp.get(id).operands {
                if let Some(gp) = self.instr_to_group.get(&op) {
                    if gp != gu {
                        edges.insert((*gp, *gu));
                    }
                }
            }
        }
        // Kahn's algorithm over the contracted DAG.
        let mut indeg = vec![0usize; gcount];
        for &(_, b) in &edges {
            indeg[b] += 1;
        }
        let mut queue: Vec<usize> = (0..gcount).filter(|&g| indeg[g] == 0).collect();
        let mut seen = 0;
        while let Some(g) = queue.pop() {
            seen += 1;
            for &(a, b) in &edges {
                if a == g {
                    indeg[b] -= 1;
                    if indeg[b] == 0 {
                        queue.push(b);
                    }
                }
            }
        }
        if seen != gcount {
            anyhow::bail!("fusion plan has an inter-group cycle");
        }
        Ok(())
    }
}

/// A group needs block composition when it cannot be emitted as one
/// parallel loop: any internal reduce/batch-dot producer, or any
/// schedule-bearing op mix beyond pure thread composition (§2, Fig. 2).
fn needs_stitching(comp: &Computation, members: &HashSet<InstrId>) -> bool {
    members.iter().any(|&id| {
        let i = comp.get(id);
        let is_root_like = comp.users(id).iter().all(|u| !members.contains(u));
        (i.opcode.is_reduce() || i.opcode == crate::hlo::Opcode::BatchDot) && !is_root_like
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::{GraphBuilder, Shape};

    fn softmax_graph() -> (Computation, Vec<InstrId>) {
        let mut b = GraphBuilder::new("sm");
        let x = b.param("x", Shape::f32(&[8, 64]));
        let m = b.reduce(x, &[1], ReduceKind::Max);
        let mb = b.broadcast(m, &[8, 64], &[0]);
        let sh = b.sub(x, mb);
        let e = b.exp(sh);
        let s = b.reduce(e, &[1], ReduceKind::Sum);
        let sb = b.broadcast(s, &[8, 64], &[0]);
        let p = b.div(e, sb);
        let comp = b.finish(p);
        (comp, vec![m, mb, sh, e, s, sb, p])
    }

    #[test]
    fn completion_covers_all() {
        let (comp, ids) = softmax_graph();
        // Group only {exp, sum-reduce}; the rest become singletons.
        let plan = FusionPlan::from_groups(&comp, vec![(vec![ids[3], ids[4]], vec![ids[4]])]);
        plan.validate(&comp).unwrap();
        // 1 fused group + 5 singleton kernels
        assert_eq!(plan.generated_kernel_count(&comp), 6);
        assert_eq!(plan.library_call_count(), 0);
    }

    #[test]
    fn stitched_kind_detected() {
        let (comp, ids) = softmax_graph();
        let all = ids.clone();
        let plan = FusionPlan::from_groups(&comp, vec![(all, vec![ids[6]])]);
        assert_eq!(plan.groups[0].kind, GroupKind::Stitched);
        assert_eq!(plan.generated_kernel_count(&comp), 1);
    }

    #[test]
    fn loop_kind_for_pure_elementwise() {
        let mut b = GraphBuilder::new("ew");
        let x = b.param("x", Shape::f32(&[32]));
        let e = b.exp(x);
        let t = b.tanh(e);
        let comp = b.finish(t);
        let plan = FusionPlan::from_groups(&comp, vec![(vec![e, t], vec![t])]);
        assert_eq!(plan.groups[0].kind, GroupKind::Loop);
    }

    #[test]
    fn library_groups_excluded_from_count() {
        let mut b = GraphBuilder::new("lib");
        let x = b.param("x", Shape::f32(&[4, 4]));
        let w = b.param("w", Shape::f32(&[4, 4]));
        let d = b.dot(x, w);
        let e = b.exp(d);
        let comp = b.finish(e);
        let plan = FusionPlan::from_groups(&comp, vec![]);
        assert_eq!(plan.library_call_count(), 1);
        assert_eq!(plan.generated_kernel_count(&comp), 1); // just exp
        let _ = d;
    }

    #[test]
    #[should_panic(expected = "two groups")]
    fn overlapping_groups_panic() {
        let (comp, ids) = softmax_graph();
        let _ = FusionPlan::from_groups(
            &comp,
            vec![
                (vec![ids[3], ids[4]], vec![ids[4]]),
                (vec![ids[4], ids[6]], vec![ids[6]]),
            ],
        );
    }

    #[test]
    fn cycle_detection() {
        // a -> b -> c with groups {a, c} and {b}: group cycle.
        let mut bld = GraphBuilder::new("cyc");
        let x = bld.param("x", Shape::f32(&[4]));
        let a = bld.exp(x);
        let b = bld.tanh(a);
        let c = bld.neg(b);
        let comp = bld.finish(c);
        let plan = FusionPlan::from_groups(&comp, vec![(vec![a, c], vec![c])]);
        assert!(plan.validate(&comp).is_err());
    }
}
