//! `SchdConsistent` — the fusion feasibility gate (§3.2), wired to
//! schedule planning (§4) and the shared-memory feedback loop (§5.1.2).
//!
//! A fusion candidate is accepted only if
//! 1. it does not close a dependency cycle through given-up instructions;
//! 2. it extends a producer/consumer chain into the current group;
//! 3. an optimized schedule is resolvable for the enlarged group
//!    ([`crate::schedule::tuning`]); and
//! 4. the enlarged group's shared-memory requirement fits the kernel
//!    budget after best-effort shrinking ([`crate::codegen::shm_planner`]).
//!    Planning failure feeds back as a rejection — the paper's
//!    granularity-control mechanism.

use crate::codegen::shm_planner::{plan_shared_memory, ShmError};
use crate::gpusim::DeviceConfig;
use crate::hlo::{Computation, InstrId};
use crate::schedule::{tune_with_oracle, CostOracle, ModeledCost, PerfLibrary, TunedPlan, TuningConfig};
use std::collections::HashSet;

/// The checker owns the tuning resources shared across fusion decisions.
pub struct ScheduleConsistencyChecker<'a> {
    pub lib: &'a mut PerfLibrary,
    pub tuning: TuningConfig,
    pub dev: DeviceConfig,
    /// The cost seam every estimate below routes through
    /// ([`crate::schedule::oracle`]); [`ModeledCost`] by default.
    pub oracle: &'a dyn CostOracle,
    /// Statistics: how many candidates the shared-memory feedback path
    /// rejected (visible in reports).
    pub shm_rejections: usize,
    /// How many candidates schedule resolution rejected.
    pub schedule_rejections: usize,
    /// How many candidates the performance heuristic rejected.
    pub profit_rejections: usize,
    /// Memoized standalone kernel cost per instruction.
    singleton_cost: std::collections::HashMap<InstrId, f64>,
}

impl<'a> ScheduleConsistencyChecker<'a> {
    pub fn new(lib: &'a mut PerfLibrary, tuning: TuningConfig, dev: DeviceConfig) -> Self {
        Self::with_oracle(lib, tuning, dev, &ModeledCost)
    }

    /// A checker whose cost estimates route through `oracle` (the
    /// measured re-explore path); [`Self::new`] is this with
    /// [`ModeledCost`].
    pub fn with_oracle(
        lib: &'a mut PerfLibrary,
        tuning: TuningConfig,
        dev: DeviceConfig,
        oracle: &'a dyn CostOracle,
    ) -> Self {
        ScheduleConsistencyChecker {
            lib,
            tuning,
            dev,
            oracle,
            shm_rejections: 0,
            schedule_rejections: 0,
            profit_rejections: 0,
            singleton_cost: std::collections::HashMap::new(),
        }
    }

    /// Estimated wall time of the fused kernel described by `plan` over
    /// `members`: boundary DRAM traffic + accumulated flops + one launch
    /// (internal values stay on chip).
    pub fn fused_time(
        &self,
        comp: &Computation,
        members: &HashSet<InstrId>,
        plan: &TunedPlan,
    ) -> f64 {
        let desc = crate::codegen::kernel_plan::fused_kernel_desc(comp, members, plan);
        self.oracle.kernel_time_us(&desc, &self.dev)
    }

    /// Estimated cost of launching `id` as its own kernel (its tuned
    /// standalone time plus one launch overhead) — what fusion saves.
    pub fn standalone_cost(&mut self, comp: &Computation, id: InstrId) -> f64 {
        if let Some(&c) = self.singleton_cost.get(&id) {
            return c;
        }
        let members: HashSet<InstrId> = [id].into_iter().collect();
        let exec = tune_with_oracle(comp, &members, &[id], self.lib, &self.tuning, self.oracle)
            .map(|p| p.est_exec_us)
            .unwrap_or_else(|| {
                self.oracle.schedule_cost_us(
                    self.lib,
                    comp,
                    id,
                    crate::schedule::Schedule::fallback(),
                    128,
                )
            });
        let cost = exec + self.dev.launch_overhead_us;
        self.singleton_cost.insert(id, cost);
        cost
    }

    /// The full `SchdConsistent` predicate of Algorithm 1. `hlo` is the
    /// candidate; `fused` the instructions already in the group (root
    /// included); `giveup` the rejected set; `current_cost` the estimated
    /// execution time of the group as it stands — Fig. 4's "performance
    /// heuristics regarding current fusion plan" feedback. Returns the
    /// tuned plan of the *enlarged* group on success so the caller can
    /// carry its cost forward.
    pub fn schd_consistent(
        &mut self,
        comp: &Computation,
        roots: &[InstrId],
        hlo: InstrId,
        fused: &HashSet<InstrId>,
        giveup: &HashSet<InstrId>,
        current_cost: f64,
    ) -> Option<TunedPlan> {
        let instr = comp.get(hlo);
        // Only the paper's four fusable categories enter groups.
        if !instr.opcode.is_fusable() {
            return None;
        }
        // Frame discipline: a kernel cannot straddle while-loop bodies.
        if let Some(&r) = roots.first() {
            if comp.get(r).frame != instr.frame {
                return None;
            }
        }
        // (1) user in giveup → fusing would risk a cyclic dependency.
        if comp.users(hlo).iter().any(|u| giveup.contains(u)) {
            return None;
        }
        // (2) producer/consumer only: some user must already be fused.
        if !comp.users(hlo).iter().any(|u| fused.contains(u)) {
            return None;
        }
        // (3) + (4): resolve a schedule and a shared-memory plan.
        let mut enlarged = fused.clone();
        enlarged.insert(hlo);
        let plan = self.check_group(comp, &enlarged, roots)?;
        // (5) performance feedback: the fused kernel (boundary-traffic
        // model, one launch) must not cost more than the current kernel
        // plus the candidate as its own launch. This is what keeps a
        // scalar-rooted (single-block) kernel from eating a highly
        // parallel producer.
        let new_time = self.fused_time(comp, &enlarged, &plan);
        let budget = current_cost + self.standalone_cost(comp, hlo);
        if new_time > budget {
            self.profit_rejections += 1;
            return None;
        }
        Some(plan)
    }

    /// Conditions (3)+(4) alone — used both by `schd_consistent` and by
    /// `ElementwiseFusion` when validating an intra-layer group.
    pub fn check_group(
        &mut self,
        comp: &Computation,
        members: &HashSet<InstrId>,
        roots: &[InstrId],
    ) -> Option<TunedPlan> {
        let plan = match tune_with_oracle(comp, members, roots, self.lib, &self.tuning, self.oracle)
        {
            Some(p) => p,
            None => {
                self.schedule_rejections += 1;
                return None;
            }
        };
        match plan_shared_memory(comp, members, roots, &plan, &self.dev) {
            Ok(_) => Some(plan),
            Err(ShmError::Exceeded { .. }) => {
                // §5.1.2: "a feedback signal is generated back to
                // ScheduleConsistencyChecker … to trigger other fusion
                // decisions."
                self.shm_rejections += 1;
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::{GraphBuilder, Shape};

    fn checker_dev() -> (PerfLibrary, TuningConfig, DeviceConfig) {
        (
            PerfLibrary::new(DeviceConfig::pascal()),
            TuningConfig::default(),
            DeviceConfig::pascal(),
        )
    }

    #[test]
    fn accepts_producer_of_fused_user() {
        let mut b = GraphBuilder::new("ok");
        let x = b.param("x", Shape::f32(&[64, 64]));
        let e = b.exp(x);
        let t = b.tanh(e);
        let comp = b.finish(t);
        let (mut lib, cfg, dev) = checker_dev();
        let mut ck = ScheduleConsistencyChecker::new(&mut lib, cfg, dev);
        let fused: HashSet<InstrId> = [t].into_iter().collect();
        let giveup = HashSet::new();
        assert!(ck.schd_consistent(&comp, &[t], e, &fused, &giveup, 1e9).is_some());
    }

    #[test]
    fn rejects_non_consumer_relationship() {
        // sibling (no fused user) → leave for ElementwiseFusion.
        let mut b = GraphBuilder::new("sib");
        let x = b.param("x", Shape::f32(&[64]));
        let e = b.exp(x);
        let t = b.tanh(x);
        let comp = b.finish(t);
        let (mut lib, cfg, dev) = checker_dev();
        let mut ck = ScheduleConsistencyChecker::new(&mut lib, cfg, dev);
        let fused: HashSet<InstrId> = [t].into_iter().collect();
        assert!(ck.schd_consistent(&comp, &[t], e, &fused, &HashSet::new(), 1e9).is_none());
    }

    #[test]
    fn rejects_user_in_giveup() {
        let mut b = GraphBuilder::new("gu");
        let x = b.param("x", Shape::f32(&[64]));
        let e = b.exp(x);
        let s = b.sigmoid(e);
        let t = b.tanh(s);
        let comp = b.finish(t);
        let (mut lib, cfg, dev) = checker_dev();
        let mut ck = ScheduleConsistencyChecker::new(&mut lib, cfg, dev);
        let fused: HashSet<InstrId> = [t].into_iter().collect();
        let giveup: HashSet<InstrId> = [s].into_iter().collect();
        assert!(ck.schd_consistent(&comp, &[t], e, &fused, &giveup, 1e9).is_none());
    }

    #[test]
    fn rejects_library_call() {
        let mut b = GraphBuilder::new("lc");
        let x = b.param("x", Shape::f32(&[8, 8]));
        let w = b.param("w", Shape::f32(&[8, 8]));
        let d = b.dot(x, w);
        let e = b.exp(d);
        let comp = b.finish(e);
        let (mut lib, cfg, dev) = checker_dev();
        let mut ck = ScheduleConsistencyChecker::new(&mut lib, cfg, dev);
        let fused: HashSet<InstrId> = [e].into_iter().collect();
        assert!(ck.schd_consistent(&comp, &[e], d, &fused, &HashSet::new(), 1e9).is_none());
    }

    #[test]
    fn shm_budget_feedback_rejects_oversized_group() {
        // A non-root reduce forces a mandatory shared buffer per block;
        // a scalar root (full reduce) pins the grid to one block, so the
        // interior reduce's chunk is its whole 32 KB output — over the
        // 20 KB budget, and shrinking cannot drop mandatory allocations.
        let mut b = GraphBuilder::new("big");
        let x = b.param("x", Shape::f32(&[64, 8192]));
        let e = b.exp(x);
        let r1 = b.reduce(e, &[0], ReduceKind::Sum); // [8192] interior
        let t = b.tanh(r1);
        let rr = b.reduce(t, &[0], ReduceKind::Sum); // scalar root
        let comp = b.finish(rr);
        let (mut lib, cfg, dev) = checker_dev();
        let mut ck = ScheduleConsistencyChecker::new(&mut lib, cfg, dev);
        let members: HashSet<InstrId> = [e, r1, t, rr].into_iter().collect();
        let plan = ck.check_group(&comp, &members, &[rr]);
        assert!(plan.is_none(), "mandatory interior reduce buffer must blow the budget");
        assert!(ck.shm_rejections > 0);
    }
}
