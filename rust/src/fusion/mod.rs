//! Op fusion — §3 of the paper.
//!
//! - [`plan`] — the fusion plan representation: a partition of a
//!   computation's instructions into kernel groups.
//! - [`baseline`] — the XLA-like `GpuInstructionFusion` baseline with its
//!   static `ShouldFuse` rules (the paper's comparison target, §6.1).
//! - [`elementwise`] — intra-layer `ElementwiseFusion` of independent
//!   fine-grained ops (§3.2).
//! - [`consistency`] — `SchdConsistent`: the schedule/shared-memory
//!   feasibility gate, including the §5.1.2 feedback loop.
//! - [`deep`] — the layered subgraph fusion of Algorithm 1 driven by
//!   Work/Span layers.
//! - [`explore`] — cost-guided merge/split refinement of the greedy
//!   plan (the arXiv:2009.10924 exploration loop), memoized in the
//!   performance library.

pub mod baseline;
pub mod consistency;
pub mod deep;
pub mod elementwise;
pub mod explore;
pub mod plan;

pub use baseline::xla_baseline_fusion;
pub use consistency::ScheduleConsistencyChecker;
pub use deep::{deep_fusion, deep_fusion_with_oracle, DeepFusionConfig, DeepFusionStats};
pub use explore::{explore_fusion, explore_fusion_with_oracle, group_fingerprint, ExploreStats};
pub use plan::{FusionGroup, FusionPlan, GroupKind};
