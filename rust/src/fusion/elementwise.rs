//! Intra-layer `ElementwiseFusion` — §3.2.
//!
//! Targets same-layer instructions *without* producer/consumer
//! relationships — primarily the many small weight-accumulation ops in
//! training graphs, each often < 10 µs, where fusing N launches into one
//! removes N−1 launch overheads.
//!
//! Grouping follows the paper's two factors:
//! 1. schedule compatibility — "elementwise instructions within a layer
//!    naturally fall into a few groups according to output shapes";
//! 2. fused memory footprint — a tunable threshold bounds group size to
//!    avoid extra-large multi-output computations.

use crate::hlo::{Computation, InstrId, Shape};
use std::collections::{BTreeMap, HashSet};

/// Configuration for intra-layer fusion.
#[derive(Debug, Clone)]
pub struct ElementwiseFusionConfig {
    /// Max fused IO footprint per group, bytes (the paper's tunable
    /// threshold parameter).
    pub max_footprint_bytes: usize,
    /// Max outputs per fused computation.
    pub max_outputs: usize,
}

impl Default for ElementwiseFusionConfig {
    fn default() -> Self {
        ElementwiseFusionConfig { max_footprint_bytes: 64 << 20, max_outputs: 32 }
    }
}

/// Partition the given same-layer instructions into multi-root fusion
/// seeds. `available` must all be elementwise, un-grouped, and on the
/// same Work/Span layer (the caller guarantees layer membership).
/// Returns groups of ≥ 2 instructions; singletons stay un-fused here.
pub fn elementwise_fusion(
    comp: &Computation,
    available: &[InstrId],
    cfg: &ElementwiseFusionConfig,
) -> Vec<Vec<InstrId>> {
    // Factor 1: bucket by output shape (schedule compatibility — equal
    // shapes trivially share every candidate schedule).
    let mut buckets: BTreeMap<String, Vec<InstrId>> = BTreeMap::new();
    for &id in available {
        let instr = comp.get(id);
        debug_assert!(instr.opcode.is_elementwise());
        buckets.entry(shape_key(&instr.shape)).or_default().push(id);
    }

    // Factor 2: split each bucket by the footprint threshold. Membership
    // additionally requires mutual independence: same-frame Work/Span
    // layers guarantee it, but cross-frame paths can still link two
    // same-layer ops, so we check transitively.
    let mut groups = Vec::new();
    for (_, ids) in buckets {
        let mut current: Vec<InstrId> = Vec::new();
        let mut current_bytes = 0usize;
        for id in ids {
            if current
                .iter()
                .any(|&m| comp.depends_on(id, m) || comp.depends_on(m, id))
            {
                continue; // dependent sibling: leave for subgraph fusion
            }
            let fp = footprint_bytes(comp, id);
            let would_overflow = !current.is_empty()
                && (current_bytes + fp > cfg.max_footprint_bytes
                    || current.len() >= cfg.max_outputs);
            if would_overflow {
                if current.len() >= 2 {
                    groups.push(std::mem::take(&mut current));
                } else {
                    current.clear();
                }
                current_bytes = 0;
            }
            current_bytes += fp;
            current.push(id);
        }
        if current.len() >= 2 {
            groups.push(current);
        }
    }
    groups
}

/// Instructions in a layer eligible for intra-layer fusion: elementwise,
/// fusable, not already claimed by another group, and mutually
/// independent (same layer ⇒ guaranteed by Work/Span, asserted in debug).
pub fn eligible(
    comp: &Computation,
    layer: &[InstrId],
    claimed: &HashSet<InstrId>,
) -> Vec<InstrId> {
    layer
        .iter()
        .copied()
        .filter(|&id| {
            let i = comp.get(id);
            i.opcode.is_elementwise() && !claimed.contains(&id)
        })
        .collect()
}

fn shape_key(s: &Shape) -> String {
    s.to_string()
}

fn footprint_bytes(comp: &Computation, id: InstrId) -> usize {
    let i = comp.get(id);
    i.shape.byte_size()
        + i.operands.iter().map(|&o| comp.get(o).shape.byte_size()).sum::<usize>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::{GraphBuilder, Shape};

    #[test]
    fn groups_by_shape() {
        let mut b = GraphBuilder::new("ew");
        let x = b.param("x", Shape::f32(&[64]));
        let y = b.param("y", Shape::f32(&[64]));
        let z = b.param("z", Shape::f32(&[32]));
        let a1 = b.add(x, y); // [64]
        let a2 = b.mul(x, y); // [64]
        let a3 = b.exp(z); // [32] — different shape
        let comp = b.finish(a1);
        let groups = elementwise_fusion(
            &comp,
            &[a1, a2, a3],
            &ElementwiseFusionConfig::default(),
        );
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0], vec![a1, a2]);
    }

    #[test]
    fn footprint_threshold_splits_groups() {
        let mut b = GraphBuilder::new("fp");
        let x = b.param("x", Shape::f32(&[1024]));
        let adds: Vec<InstrId> = (0..6).map(|_| b.add(x, x)).collect();
        let comp = b.finish(adds[0]);
        // each add: out 4 KB + 2×4 KB operands = 12 KB; cap at 25 KB → 2 per group
        let cfg = ElementwiseFusionConfig { max_footprint_bytes: 25_000, max_outputs: 32 };
        let groups = elementwise_fusion(&comp, &adds, &cfg);
        assert_eq!(groups.len(), 3);
        for g in &groups {
            assert_eq!(g.len(), 2);
        }
    }

    #[test]
    fn max_outputs_respected() {
        let mut b = GraphBuilder::new("mo");
        let x = b.param("x", Shape::f32(&[8]));
        let adds: Vec<InstrId> = (0..10).map(|_| b.add(x, x)).collect();
        let comp = b.finish(adds[0]);
        let cfg = ElementwiseFusionConfig { max_footprint_bytes: usize::MAX, max_outputs: 4 };
        let groups = elementwise_fusion(&comp, &adds, &cfg);
        assert!(groups.iter().all(|g| g.len() <= 4));
        let total: usize = groups.iter().map(Vec::len).sum();
        assert!(total >= 8, "most ops should still be grouped");
    }

    #[test]
    fn singletons_not_grouped() {
        let mut b = GraphBuilder::new("one");
        let x = b.param("x", Shape::f32(&[64]));
        let a = b.exp(x);
        let comp = b.finish(a);
        let groups =
            elementwise_fusion(&comp, &[a], &ElementwiseFusionConfig::default());
        assert!(groups.is_empty());
    }

    #[test]
    fn eligible_filters_claimed_and_non_elementwise() {
        let mut b = GraphBuilder::new("el");
        let x = b.param("x", Shape::f32(&[4, 4]));
        let a = b.exp(x);
        let t = b.transpose(x, &[1, 0]);
        let m = b.tanh(x);
        let comp = b.finish(m);
        let claimed: HashSet<InstrId> = [m].into_iter().collect();
        let e = eligible(&comp, &[a, t, m], &claimed);
        assert_eq!(e, vec![a]);
    }
}
