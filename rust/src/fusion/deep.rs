//! Deep fusion — §3.2 / Algorithm 1.
//!
//! Driven by Work/Span layers: within each while-frame, walk root layers
//! from the graph output upward; at each layer first run intra-layer
//! `ElementwiseFusion`, then grow every fusion seed across subsequent
//! layers up to the next library-call layer (the *roof*), admitting an
//! instruction whenever `SchdConsistent` accepts it and giving it up
//! otherwise (which poisons its producers to avoid dependency cycles).

use super::consistency::ScheduleConsistencyChecker;
use super::elementwise::{elementwise_fusion, eligible, ElementwiseFusionConfig};
use super::plan::FusionPlan;
use crate::analysis::{FramePartition, SpanAnalysis};
use crate::codegen::shm_planner::plan_shared_memory;
use crate::gpusim::DeviceConfig;
use crate::hlo::{Computation, InstrId, Opcode};
use crate::schedule::{CostOracle, ModeledCost, PerfLibrary, TuningConfig};
use std::collections::HashSet;

/// Deep-fusion configuration.
#[derive(Debug, Clone)]
pub struct DeepFusionConfig {
    /// Whether BatchMatMul ops join fused kernels — workload-dependent
    /// and left to the user in the paper (§2.1).
    pub fuse_batch_dot: bool,
    /// Run the cost-guided exploration pass ([`super::explore`]) over
    /// the greedy plan (on by default; `--no-cost-fusion` disables).
    pub cost_fusion: bool,
    /// Allow the exploration pass to form global-tier groups: when a
    /// merged group's intermediates overflow the shared-memory budget,
    /// cost them as DRAM spills behind a grid fence instead of ruling
    /// the merge out (on by default; the differential suite compares
    /// both settings).
    pub global_stitch: bool,
    pub elementwise: ElementwiseFusionConfig,
    pub tuning: TuningConfig,
    pub device: DeviceConfig,
}

impl Default for DeepFusionConfig {
    fn default() -> Self {
        DeepFusionConfig {
            fuse_batch_dot: true,
            cost_fusion: true,
            global_stitch: true,
            elementwise: ElementwiseFusionConfig::default(),
            tuning: TuningConfig::default(),
            device: DeviceConfig::pascal(),
        }
    }
}

/// Statistics reported alongside the plan.
#[derive(Debug, Clone, Default)]
pub struct DeepFusionStats {
    pub seeds: usize,
    pub accepted: usize,
    pub given_up: usize,
    pub schedule_rejections: usize,
    pub shm_rejections: usize,
    /// With cost-guided fusion on, every completed multi-op group is
    /// scored fused-vs-unfused through `gpusim::cost`: modeled time of
    /// the stitched kernels…
    pub modeled_fused_us: f64,
    /// …vs the same members launched as standalone baseline kernels
    /// (tuned per op, launch overhead each). The gap is the modeled
    /// profit greedy fusion claims; the exploration pass then audits it
    /// group by group.
    pub modeled_unfused_us: f64,
}

/// Run deep fusion over `comp`, producing the kernel partition.
pub fn deep_fusion(
    comp: &Computation,
    lib: &mut PerfLibrary,
    cfg: &DeepFusionConfig,
) -> (FusionPlan, DeepFusionStats) {
    deep_fusion_with_oracle(comp, lib, cfg, &ModeledCost)
}

/// [`deep_fusion`] with every cost estimate routed through `oracle` —
/// the serving path's measured re-explore runs this with a
/// [`crate::schedule::MeasuredCost`] overlay.
pub fn deep_fusion_with_oracle(
    comp: &Computation,
    lib: &mut PerfLibrary,
    cfg: &DeepFusionConfig,
    oracle: &dyn CostOracle,
) -> (FusionPlan, DeepFusionStats) {
    let spans = SpanAnalysis::run(comp);
    let frames = FramePartition::build(comp);
    let mut checker = ScheduleConsistencyChecker::with_oracle(
        lib,
        cfg.tuning.clone(),
        cfg.device.clone(),
        oracle,
    );
    let mut stats = DeepFusionStats::default();

    let mut claimed: HashSet<InstrId> = HashSet::new();
    let mut groups: Vec<(Vec<InstrId>, Vec<InstrId>)> = Vec::new();

    for frame in frames.frames() {
        let critical = spans.critical_path(frame);
        let lc_spans = spans.lc_layers(comp, frame);
        for root_span in 0..=critical {
            let layer: Vec<InstrId> = spans.layer(frame, root_span).to_vec();
            // The roof: the next library-call layer above this root
            // layer (§3.2 — fusion never crosses it).
            let roof = lc_spans
                .iter()
                .copied()
                .find(|&s| s > root_span)
                .unwrap_or(critical + 1);

            // Step 1: intra-layer ElementwiseFusion.
            let avail = eligible(comp, &layer, &claimed);
            for seed in elementwise_fusion(comp, &avail, &cfg.elementwise) {
                let members: HashSet<InstrId> = seed.iter().copied().collect();
                let Some(seed_plan) = checker.check_group(comp, &members, &seed) else {
                    continue; // incompatible grids — leave them singleton
                };
                stats.seeds += 1;
                let seed_cost = checker.fused_time(comp, &members, &seed_plan);
                let fused = grow(
                    comp, &spans, frame, roof, seed.clone(), members, seed_cost,
                    &mut checker, &claimed, cfg, &mut stats,
                );
                finalize(comp, fused, &mut claimed, &mut groups, &mut checker, cfg, &mut stats);
            }

            // Step 2: every remaining fusable instruction in the layer
            // seeds subgraph fusion (Algorithm 1).
            for &root in &layer {
                if claimed.contains(&root) {
                    continue;
                }
                let opcode = comp.get(root).opcode;
                if !opcode.is_fusable() || (opcode == Opcode::BatchDot && !cfg.fuse_batch_dot) {
                    continue;
                }
                stats.seeds += 1;
                let members: HashSet<InstrId> = [root].into_iter().collect();
                let seed_cost = checker.standalone_cost(comp, root);
                let fused = grow(
                    comp, &spans, frame, roof, vec![root], members, seed_cost, &mut checker,
                    &claimed, cfg, &mut stats,
                );
                if fused.len() >= 2 {
                    finalize(comp, fused, &mut claimed, &mut groups, &mut checker, cfg, &mut stats);
                } else {
                    // A seed that grew nothing stays a singleton kernel;
                    // leaving it unclaimed lets a *later* root layer pull
                    // it in as a producer.
                }
            }
        }
    }

    // Post-pass: absorb stragglers. Algorithm 1 never fuses instructions
    // sharing a span layer with a library call (the roof itself), which
    // strands e.g. the bias broadcast that happens to sit next to its
    // matmul. Any unclaimed fusable op whose users all live in a single
    // same-frame group joins it when the enlarged group still checks out.
    let mut changed = true;
    while changed {
        changed = false;
        for id in comp.ids() {
            let instr = comp.get(id);
            if claimed.contains(&id)
                || !instr.opcode.is_fusable()
                || (instr.opcode.is_free() && instr.opcode != Opcode::Bitcast)
                || (instr.opcode == Opcode::BatchDot && !cfg.fuse_batch_dot)
            {
                continue;
            }
            let users = comp.users(id);
            if users.is_empty() {
                continue;
            }
            let Some(gidx) = groups.iter().position(|(members, _)| {
                users.iter().all(|u| members.contains(u))
            }) else {
                continue;
            };
            if comp.get(groups[gidx].0[0]).frame != instr.frame {
                continue;
            }
            // No cycles: the producer must not itself depend on a member.
            if groups[gidx].0.iter().any(|&m| comp.depends_on(id, m)) {
                continue;
            }
            let mut enlarged: HashSet<InstrId> =
                groups[gidx].0.iter().copied().collect();
            enlarged.insert(id);
            if checker.check_group(comp, &enlarged, &groups[gidx].1).is_some() {
                groups[gidx].0.push(id);
                groups[gidx].0.sort_unstable();
                claimed.insert(id);
                stats.accepted += 1;
                changed = true;
            }
        }
    }

    stats.schedule_rejections = checker.schedule_rejections;
    stats.shm_rejections = checker.shm_rejections;
    let plan = FusionPlan::from_groups(comp, groups);
    debug_assert!(plan.validate(comp).is_ok());
    (plan, stats)
}

/// Algorithm 1: grow `fused` (seeded at the root layer) layer by layer
/// up to (excluding) `roof`.
#[allow(clippy::too_many_arguments)]
#[allow(clippy::too_many_arguments)]
fn grow(
    comp: &Computation,
    spans: &SpanAnalysis,
    frame: u32,
    roof: u32,
    roots: Vec<InstrId>,
    mut fused: HashSet<InstrId>,
    mut current_cost: f64,
    checker: &mut ScheduleConsistencyChecker<'_>,
    claimed: &HashSet<InstrId>,
    cfg: &DeepFusionConfig,
    stats: &mut DeepFusionStats,
) -> HashSet<InstrId> {
    let curr_span = roots.iter().map(|&r| spans.span_of(r)).min().unwrap_or(0);
    let mut giveup: HashSet<InstrId> = HashSet::new();
    for l in curr_span + 1..roof {
        for &hlo in spans.layer(frame, l) {
            if claimed.contains(&hlo) || fused.contains(&hlo) {
                continue;
            }
            let opcode = comp.get(hlo).opcode;
            // Free ops never launch kernels, but bitcasts must still join
            // groups: they carry producer/consumer connectivity (the
            // Figure 3 `Divide.1 → Bitcast.1 → Dot.1` chain).
            if opcode.is_free() && opcode != Opcode::Bitcast {
                continue;
            }
            if opcode == Opcode::BatchDot && !cfg.fuse_batch_dot {
                giveup.insert(hlo);
                continue;
            }
            match checker.schd_consistent(comp, &roots, hlo, &fused, &giveup, current_cost) {
                Some(plan) => {
                    fused.insert(hlo);
                    current_cost = checker.fused_time(comp, &fused, &plan);
                    stats.accepted += 1;
                }
                None => {
                    giveup.insert(hlo);
                    stats.given_up += 1;
                }
            }
        }
    }
    fused
}

/// Claim the grown group and record it with its final root set (members
/// whose values escape the group). When cost-guided fusion is on, every
/// completed multi-op group is also scored fused-vs-unfused through
/// `gpusim::cost` — the modeled profit the exploration pass audits.
fn finalize(
    comp: &Computation,
    fused: HashSet<InstrId>,
    claimed: &mut HashSet<InstrId>,
    groups: &mut Vec<(Vec<InstrId>, Vec<InstrId>)>,
    checker: &mut ScheduleConsistencyChecker<'_>,
    cfg: &DeepFusionConfig,
    stats: &mut DeepFusionStats,
) {
    let roots: Vec<InstrId> = {
        let mut r: Vec<InstrId> = fused
            .iter()
            .copied()
            .filter(|&id| {
                comp.users(id).iter().any(|u| !fused.contains(u)) || comp.users(id).is_empty()
            })
            .collect();
        r.sort_unstable();
        r
    };
    if fused.len() >= 2 && cfg.cost_fusion {
        // Stats-only scoring, with the same model the explorer uses
        // (tuned schedule + shared-memory residency) so the two report
        // comparable numbers. The re-tune (against the final root set)
        // must not leak into the candidate-rejection counters, which
        // count *fusion decisions*, not bookkeeping.
        let (sched_rej, shm_rej) = (checker.schedule_rejections, checker.shm_rejections);
        let scored = checker.check_group(comp, &fused, &roots).and_then(|plan| {
            plan_shared_memory(comp, &fused, &roots, &plan, &checker.dev)
                .ok()
                .map(|shm| (plan, shm.total_bytes))
        });
        checker.schedule_rejections = sched_rej;
        checker.shm_rejections = shm_rej;
        if let Some((plan, smem_bytes)) = scored {
            let mut desc =
                crate::codegen::kernel_plan::fused_kernel_desc(comp, &fused, &plan);
            desc.smem_bytes = smem_bytes;
            stats.modeled_fused_us += checker.oracle.kernel_time_us(&desc, &checker.dev);
            stats.modeled_unfused_us += fused
                .iter()
                .filter(|&&id| !comp.get(id).opcode.is_free())
                .map(|&id| checker.standalone_cost(comp, id))
                .sum::<f64>();
        }
    }
    claimed.extend(fused.iter().copied());
    let mut members: Vec<InstrId> = fused.into_iter().collect();
    members.sort_unstable();
    groups.push((members, roots));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::baseline::xla_baseline_fusion;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::{GraphBuilder, Shape};

    fn run(comp: &Computation) -> (FusionPlan, DeepFusionStats) {
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        deep_fusion(comp, &mut lib, &DeepFusionConfig::default())
    }

    /// The headline behaviour: the Figure 3 pattern becomes ONE stitched
    /// kernel where the XLA baseline needs several.
    #[test]
    fn figure3_fuses_to_single_kernel() {
        let mut b = GraphBuilder::new("fig3");
        let scores = b.param("scores", Shape::f32(&[8, 64, 64]));
        let v = b.param("v", Shape::f32(&[8, 64, 32]));
        let m = b.reduce(scores, &[2], ReduceKind::Max);
        let mb = b.broadcast(m, &[8, 64, 64], &[0, 1]);
        let sh = b.sub(scores, mb);
        let e = b.exp(sh);
        let s = b.reduce(e, &[2], ReduceKind::Sum);
        let sb = b.broadcast(s, &[8, 64, 64], &[0, 1]);
        let p = b.div(e, sb);
        let bc = b.bitcast(p, &[8, 64, 64]);
        let out = b.batch_dot(bc, v);
        let comp = b.finish(out);

        let (plan, stats) = run(&comp);
        plan.validate(&comp).unwrap();
        let deep_kernels = plan.generated_kernel_count(&comp);
        let baseline = xla_baseline_fusion(&comp);
        let base_kernels = baseline.generated_kernel_count(&comp);
        assert_eq!(deep_kernels, 1, "FusionStitching should stitch the whole pattern");
        assert!(base_kernels >= 3, "baseline needs several kernels, got {base_kernels}");
        // Completed groups are scored fused-vs-unfused through the cost
        // model; stitching the whole pattern must model as profitable.
        assert!(stats.modeled_fused_us > 0.0);
        assert!(
            stats.modeled_fused_us < stats.modeled_unfused_us,
            "fused {} !< unfused {}",
            stats.modeled_fused_us,
            stats.modeled_unfused_us
        );
    }

    #[test]
    fn does_not_fuse_across_library_calls() {
        let mut b = GraphBuilder::new("lc");
        let x = b.param("x", Shape::f32(&[32, 32]));
        let w = b.param("w", Shape::f32(&[32, 32]));
        let e = b.exp(x);
        let d = b.dot(e, w); // LC-layer
        let t = b.tanh(d);
        let u = b.sigmoid(t);
        let comp = b.finish(u);
        let (plan, _) = run(&comp);
        plan.validate(&comp).unwrap();
        // exp | dot | tanh+sigmoid → 2 generated kernels + 1 library call
        assert_eq!(plan.library_call_count(), 1);
        assert_eq!(plan.generated_kernel_count(&comp), 2);
        assert_eq!(plan.group_of(t).unwrap().id, plan.group_of(u).unwrap().id);
        assert_ne!(plan.group_of(e).unwrap().id, plan.group_of(t).unwrap().id);
    }

    #[test]
    fn intra_layer_elementwise_fused() {
        // Four independent same-shape accumulation ops (the training-graph
        // pattern §3.2 calls out) → one multi-root kernel.
        let mut b = GraphBuilder::new("acc");
        let w1 = b.param("w1", Shape::f32(&[256]));
        let g1 = b.param("g1", Shape::f32(&[256]));
        let w2 = b.param("w2", Shape::f32(&[256]));
        let g2 = b.param("g2", Shape::f32(&[256]));
        let u1 = b.add(w1, g1);
        let u2 = b.add(w2, g2);
        let u3 = b.mul(w1, g2);
        let u4 = b.sub(w2, g1);
        let comp = b.finish(u1);
        let (plan, _) = run(&comp);
        plan.validate(&comp).unwrap();
        let g = plan.group_of(u1).unwrap().id;
        assert_eq!(plan.group_of(u2).unwrap().id, g);
        assert_eq!(plan.group_of(u3).unwrap().id, g);
        assert_eq!(plan.group_of(u4).unwrap().id, g);
        assert_eq!(plan.generated_kernel_count(&comp), 1);
    }

    #[test]
    fn batch_dot_fusion_is_configurable() {
        let mut b = GraphBuilder::new("bd");
        let x = b.param("x", Shape::f32(&[4, 16, 16]));
        let y = b.param("y", Shape::f32(&[4, 16, 16]));
        let e = b.exp(x);
        let d = b.batch_dot(e, y);
        let comp = b.finish(d);

        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let (plan_on, _) =
            deep_fusion(&comp, &mut lib, &DeepFusionConfig { fuse_batch_dot: true, ..Default::default() });
        assert_eq!(plan_on.generated_kernel_count(&comp), 1);

        let (plan_off, _) =
            deep_fusion(&comp, &mut lib, &DeepFusionConfig { fuse_batch_dot: false, ..Default::default() });
        assert_eq!(plan_off.generated_kernel_count(&comp), 2);
    }

    #[test]
    fn deep_never_worse_than_unfused(){
        // Kernel count after deep fusion ≤ number of non-free ops.
        let mut b = GraphBuilder::new("mono");
        let x = b.param("x", Shape::f32(&[16, 64]));
        let e = b.exp(x);
        let r = b.reduce(e, &[1], ReduceKind::Sum);
        let rb = b.broadcast(r, &[16, 64], &[0]);
        let d = b.div(e, rb);
        let t = b.tanh(d);
        let comp = b.finish(t);
        let (plan, _) = run(&comp);
        plan.validate(&comp).unwrap();
        assert!(plan.generated_kernel_count(&comp) <= comp.unfused_kernel_count());
        assert_eq!(plan.generated_kernel_count(&comp), 1, "softmax-like chain should stitch");
    }

    #[test]
    fn frames_not_mixed() {
        let mut b = GraphBuilder::new("fr");
        let x = b.param("x", Shape::f32(&[64]));
        let e = b.exp(x);
        b.set_frame(1);
        let t = b.tanh(e);
        let s = b.sigmoid(t);
        b.set_frame(0);
        let out = b.copy(s);
        let comp = b.finish(out);
        let (plan, _) = run(&comp);
        plan.validate(&comp).unwrap();
        // tanh+sigmoid fuse inside frame 1; exp stays in frame 0.
        assert_eq!(plan.group_of(t).unwrap().id, plan.group_of(s).unwrap().id);
        assert_ne!(plan.group_of(e).unwrap().id, plan.group_of(t).unwrap().id);
    }
}
