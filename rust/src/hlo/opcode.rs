//! Instruction opcodes and the paper's op-category predicates.
//!
//! §2.1 of the paper considers four op categories inside fusable
//! subgraphs: (1) elementwise, (2) shape modulation (`Reshape`, `Bitcast`,
//! `Transpose`, `Broadcast`), (3) reduction, (4) `BatchMatMul`. Library
//! calls (`Dot`/`Conv`/`CustomCall`) delimit the fusable regions
//! (LC-layers, §3.2).

use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Opcode {
    // ---- graph plumbing ----
    Parameter,
    Constant,
    Iota,
    Tuple,
    GetTupleElement,

    // ---- cheap elementwise (unary) ----
    Abs,
    Negate,
    Sign,
    Floor,
    Ceil,
    Not,
    Copy,

    // ---- expensive elementwise (unary) — §5.1.1 "expensive" set ----
    Exp,
    Log,
    Sqrt,
    Rsqrt,
    Tanh,
    Sigmoid,
    Erf,

    // ---- cheap elementwise (binary) ----
    Add,
    Subtract,
    Multiply,
    Maximum,
    Minimum,
    Compare,
    And,
    Or,

    // ---- expensive elementwise (binary) ----
    Divide,
    Power,
    Remainder,

    // ---- elementwise (ternary) ----
    Select,
    Clamp,

    // ---- shape modulation ----
    Reshape,
    Bitcast,
    Transpose,
    Broadcast,
    Slice,
    Concatenate,
    Pad,
    Gather,
    DynamicSlice,
    DynamicUpdateSlice,

    // ---- reductions ----
    Reduce,
    ReduceWindow,

    // ---- fusable contraction (§2.1: workload-specific BatchMatMul) ----
    BatchDot,

    // ---- library calls (LC-layers; never fused, §3.2) ----
    Dot,
    Convolution,
    CustomCall,

    // ---- control flow ----
    While,
}

impl Opcode {
    /// Elementwise ops compute each output element from the corresponding
    /// input element(s): the paper's category (1).
    pub fn is_elementwise(self) -> bool {
        use Opcode::*;
        matches!(
            self,
            Abs | Negate
                | Sign
                | Floor
                | Ceil
                | Not
                | Copy
                | Exp
                | Log
                | Sqrt
                | Rsqrt
                | Tanh
                | Sigmoid
                | Erf
                | Add
                | Subtract
                | Multiply
                | Maximum
                | Minimum
                | Compare
                | And
                | Or
                | Divide
                | Power
                | Remainder
                | Select
                | Clamp
        )
    }

    /// The paper's "expensive elementwise" set (§5.1.1): transcendental
    /// and division ops whose recomputation under thread composition is
    /// what shared-memory stitching avoids.
    pub fn is_expensive_elementwise(self) -> bool {
        use Opcode::*;
        matches!(
            self,
            Exp | Log | Sqrt | Rsqrt | Tanh | Sigmoid | Erf | Divide | Power | Remainder
        )
    }

    /// Shape modulation ops: category (2). They move/reinterpret data
    /// without computing on it.
    pub fn is_shape_modulation(self) -> bool {
        use Opcode::*;
        matches!(
            self,
            Reshape
                | Bitcast
                | Transpose
                | Broadcast
                | Slice
                | Concatenate
                | Pad
                | Gather
                | DynamicSlice
                | DynamicUpdateSlice
        )
    }

    /// Reduction ops: category (3). `Reduce` collapses a set of dims.
    pub fn is_reduce(self) -> bool {
        matches!(self, Opcode::Reduce | Opcode::ReduceWindow)
    }

    /// Library calls delimit fusable regions (§3.2: "we do not fuse across
    /// library calls"). `Dot`/`Convolution` go to cuBLAS/cuDNN in the
    /// paper; `CustomCall` covers everything else opaque.
    pub fn is_library_call(self) -> bool {
        matches!(self, Opcode::Dot | Opcode::Convolution | Opcode::CustomCall)
    }

    /// Fusable by FusionStitching: one of the paper's four categories.
    pub fn is_fusable(self) -> bool {
        self.is_elementwise()
            || self.is_shape_modulation()
            || self.is_reduce()
            || self == Opcode::BatchDot
    }

    /// Ops that produce no GPU kernel of their own (graph plumbing /
    /// zero-cost reinterpretation). Used when counting kernels (Fig. 7).
    pub fn is_free(self) -> bool {
        use Opcode::*;
        matches!(
            self,
            Parameter | Constant | Tuple | GetTupleElement | Bitcast | While
        )
    }

    /// Ops the schedule tuner may bypass when they strictly modulate
    /// shapes (§4.3, first optimization): computationally trivial,
    /// inlined via thread composition with negligible loss.
    pub fn is_trivially_inlinable(self) -> bool {
        use Opcode::*;
        matches!(self, Reshape | Bitcast | Broadcast | Copy | Iota)
    }

    /// Number of operands for fixed-arity ops; `None` for variadic.
    pub fn arity(self) -> Option<usize> {
        use Opcode::*;
        match self {
            Parameter | Constant | Iota => Some(0),
            Abs | Negate | Sign | Floor | Ceil | Not | Copy | Exp | Log | Sqrt | Rsqrt | Tanh
            | Sigmoid | Erf | Reshape | Bitcast | Transpose | Broadcast | Slice | Reduce
            | ReduceWindow | GetTupleElement | Pad => Some(1),
            Add | Subtract | Multiply | Maximum | Minimum | Compare | And | Or | Divide
            | Power | Remainder | BatchDot | Dot | Gather | DynamicSlice => Some(2),
            Select | Clamp | DynamicUpdateSlice => Some(3),
            Convolution => Some(2),
            Tuple | Concatenate | CustomCall | While => None,
        }
    }
}

impl fmt::Display for Opcode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn categories_are_disjoint_on_core_ops() {
        for op in [
            Opcode::Add,
            Opcode::Exp,
            Opcode::Reshape,
            Opcode::Transpose,
            Opcode::Reduce,
            Opcode::BatchDot,
            Opcode::Dot,
        ] {
            let cats = [
                op.is_elementwise(),
                op.is_shape_modulation(),
                op.is_reduce(),
                op == Opcode::BatchDot,
                op.is_library_call(),
            ];
            assert_eq!(
                cats.iter().filter(|&&c| c).count(),
                1,
                "{op} should be in exactly one category"
            );
        }
    }

    #[test]
    fn expensive_is_subset_of_elementwise() {
        for op in [Opcode::Exp, Opcode::Divide, Opcode::Tanh, Opcode::Power] {
            assert!(op.is_expensive_elementwise());
            assert!(op.is_elementwise());
        }
        assert!(!Opcode::Add.is_expensive_elementwise());
        assert!(!Opcode::Multiply.is_expensive_elementwise());
    }

    #[test]
    fn library_calls_not_fusable() {
        for op in [Opcode::Dot, Opcode::Convolution, Opcode::CustomCall] {
            assert!(op.is_library_call());
            assert!(!op.is_fusable());
        }
        assert!(Opcode::BatchDot.is_fusable());
    }

    #[test]
    fn free_ops() {
        assert!(Opcode::Parameter.is_free());
        assert!(Opcode::Bitcast.is_free());
        assert!(!Opcode::Reshape.is_free());
        assert!(!Opcode::Add.is_free());
    }

    #[test]
    fn arity() {
        assert_eq!(Opcode::Add.arity(), Some(2));
        assert_eq!(Opcode::Exp.arity(), Some(1));
        assert_eq!(Opcode::Select.arity(), Some(3));
        assert_eq!(Opcode::Concatenate.arity(), None);
    }
}
