//! Canonicalization + structural fingerprinting of HLO graphs.
//!
//! Compile-once serving needs a cache key that identifies a computation
//! by *structure*, not by identity: two modules describing the same
//! dataflow graph must collide even when their instruction ids, textual
//! names or construction order differ. The fingerprint here is a
//! 128-bit FNV-1a hash over a canonical encoding of the graph:
//!
//! - every instruction hashes its opcode, output shape (dtype + dims),
//!   the op attributes that affect semantics, its while-frame, and the
//!   *hashes* of its operands (in operand order — operand position is
//!   semantic);
//! - instruction ids and names never enter the hash, so renumbering or
//!   renaming cannot change it;
//! - the module fingerprint combines the graph outputs (as an unordered
//!   multiset of hashes, with the designated root distinguished), the
//!   instruction count and the node-hash multiset — so value-sharing
//!   differences (one shared `exp` vs. two duplicated `exp`s) produce
//!   different fingerprints even though the outputs agree.
//!
//! Everything downstream keys on [`Fingerprint`]: the
//! [`crate::coordinator::cache::CompileCache`] uses it (together with
//! the fusion mode and device) as the memo key, and
//! [`crate::schedule::PerfLibrary`] persists tuned group schedules
//! under fingerprint-derived keys so tuning work survives across
//! processes.
//!
//! ```
//! use fusion_stitching::hlo::fingerprint::fingerprint_module;
//! use fusion_stitching::hlo::{GraphBuilder, Module, Shape};
//!
//! let build = |tag: &str| {
//!     let mut b = GraphBuilder::new(tag);
//!     let x = b.param("x", Shape::f32(&[8, 16]));
//!     let e = b.exp(x);
//!     let t = b.tanh(e);
//!     Module::new(tag, b.finish(t))
//! };
//! // Same structure, different module/instruction names → same hash.
//! assert_eq!(fingerprint_module(&build("a")), fingerprint_module(&build("b")));
//! ```

use super::computation::{Computation, InstrId};
use super::instruction::Instruction;
use super::module::Module;
use std::fmt;

/// A 128-bit structural hash of a computation graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint(pub u128);

impl Fingerprint {
    /// The low 64 bits — enough for in-memory tables where 128-bit keys
    /// are inconvenient.
    pub fn short(&self) -> u64 {
        self.0 as u64
    }

    /// Canonical 32-hex-digit rendering (used in perf-library keys and
    /// logs).
    pub fn to_hex(&self) -> String {
        format!("{:032x}", self.0)
    }
}

impl fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:032x}", self.0)
    }
}

// 128-bit FNV-1a — deterministic, dependency-free, and fast enough for
// graphs of a few hundred instructions.
const FNV_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
const FNV_PRIME: u128 = 0x0000000001000000000000000000013b;

#[derive(Clone, Copy)]
struct Fnv(u128);

impl Fnv {
    fn new() -> Self {
        Fnv(FNV_OFFSET)
    }

    fn byte(&mut self, b: u8) {
        self.0 ^= b as u128;
        self.0 = self.0.wrapping_mul(FNV_PRIME);
    }

    fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.byte(b);
        }
    }

    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    fn u128(&mut self, v: u128) {
        self.bytes(&v.to_le_bytes());
    }

    fn i64(&mut self, v: i64) {
        self.bytes(&v.to_le_bytes());
    }

    fn usize_list(&mut self, tag: u8, xs: &[usize]) {
        self.byte(tag);
        self.u64(xs.len() as u64);
        for &x in xs {
            self.u64(x as u64);
        }
    }

    fn i64_list(&mut self, tag: u8, xs: &[i64]) {
        self.byte(tag);
        self.u64(xs.len() as u64);
        for &x in xs {
            self.i64(x);
        }
    }

    fn finish(self) -> u128 {
        self.0
    }
}

/// Structural hash of one instruction given its operands' hashes.
fn instruction_hash(instr: &Instruction, operand_hashes: &[u128]) -> u128 {
    let mut h = Fnv::new();
    h.byte(instr.opcode as u8);
    // Output shape: dtype tag + dims.
    h.byte(instr.shape.dtype.byte_size() as u8);
    h.bytes(instr.shape.dtype.to_string().as_bytes());
    h.i64_list(b'S', &instr.shape.dims);
    // While-frame context: fusion never crosses frames, so structure
    // inside different frames is distinct structure.
    h.u64(instr.frame as u64);
    // Semantic attributes only — never the instruction name.
    let a = &instr.attrs;
    if let Some(n) = a.parameter_number {
        h.byte(b'p');
        h.u64(n as u64);
    }
    if let Some(p) = &a.transpose_perm {
        h.usize_list(b't', p);
    }
    if let Some(d) = &a.reduce_dims {
        h.usize_list(b'r', d);
    }
    if let Some(k) = a.reduce_kind {
        h.byte(b'k');
        h.byte(k as u8);
    }
    if let Some(d) = &a.broadcast_dims {
        h.usize_list(b'b', d);
    }
    if let Some(d) = a.concat_dim {
        h.byte(b'c');
        h.u64(d as u64);
    }
    if let Some(s) = &a.slice_starts {
        h.i64_list(b's', s);
    }
    if let Some(l) = &a.slice_limits {
        h.i64_list(b'l', l);
    }
    if let Some(t) = &a.custom_call_target {
        h.byte(b'x');
        h.bytes(t.as_bytes());
    }
    if let Some(i) = a.tuple_index {
        h.byte(b'i');
        h.u64(i as u64);
    }
    // Operands in order — position is semantic (subtract, slice, …).
    h.byte(b'O');
    h.u64(operand_hashes.len() as u64);
    for &oh in operand_hashes {
        h.u128(oh);
    }
    h.finish()
}

/// Per-instruction structural hashes, indexed by [`InstrId`]. Computed
/// in one topological sweep (operands always precede users in the
/// arena).
pub fn instruction_hashes(comp: &Computation) -> Vec<u128> {
    let mut hashes: Vec<u128> = Vec::with_capacity(comp.len());
    for id in comp.ids() {
        let instr = comp.get(id);
        let op_hashes: Vec<u128> = instr.operands.iter().map(|o| hashes[o.0]).collect();
        hashes.push(instruction_hash(instr, &op_hashes));
    }
    hashes
}

/// Fingerprint a whole computation (see the module docs for what the
/// hash covers).
pub fn fingerprint_computation(comp: &Computation) -> Fingerprint {
    let hashes = instruction_hashes(comp);
    let mut h = Fnv::new();
    h.u64(comp.len() as u64);

    // Node multiset: wrapping sums are order-independent, so the id
    // numbering cannot leak in, while duplicated subgraphs (no sharing)
    // still shift the sum relative to shared ones.
    let mut node_sum: u128 = 0;
    let mut node_xor: u128 = 0;
    for &nh in &hashes {
        node_sum = node_sum.wrapping_add(nh);
        node_xor ^= nh.rotate_left((nh % 127) as u32);
    }
    h.u128(node_sum);
    h.u128(node_xor);

    // Outputs as a sorted (id-independent) list; the designated root is
    // hashed separately because it is semantically distinguished.
    let mut out_hashes: Vec<u128> = comp.outputs().iter().map(|o| hashes[o.0]).collect();
    out_hashes.sort_unstable();
    h.byte(b'R');
    h.u64(out_hashes.len() as u64);
    for oh in out_hashes {
        h.u128(oh);
    }
    if comp.has_root() {
        h.byte(b'r');
        h.u128(hashes[comp.root().0]);
    }
    Fingerprint(h.finish())
}

/// Fingerprint a module (its entry computation; the module *name* is
/// deliberately excluded — serving replicas deploy the same graph under
/// different labels).
pub fn fingerprint_module(module: &Module) -> Fingerprint {
    fingerprint_computation(&module.entry)
}

/// The cache identity of a whole *shape class*: the fingerprint of the
/// module specialized to the bucket's canonical row length.
///
/// Under shape-class bucketing
/// ([`crate::coordinator::buckets::BucketPolicy`]) every concrete
/// length in a bucket executes the one artifact compiled at the
/// bucket's canonical length, so the cache must key on the *canonical*
/// module's structure, not on whatever concrete shape a request
/// happened to arrive with. A shape change propagates through the whole
/// graph (shape inference re-derives every downstream dim), so the only
/// faithful canonical fingerprint is the fingerprint of the actually
/// specialized module — `specialize` builds it, exactly as the serving
/// loop will for compilation, and this fingerprints it. Two lengths in
/// one bucket therefore collide (same canonical module); lengths
/// straddling a bucket boundary do not.
pub fn fingerprint_shape_class(
    specialize: impl FnOnce(usize) -> Module,
    canonical_len: usize,
) -> Fingerprint {
    fingerprint_module(&specialize(canonical_len))
}

/// A canonical, id-independent instruction order: topological
/// (operands first), with ties broken by structural hash. Two
/// renumberings of the same graph produce the same *sequence of
/// structural hashes* under this order — which is what "canonical" has
/// to mean when ids themselves are arbitrary.
pub fn canonical_order(comp: &Computation) -> Vec<InstrId> {
    let hashes = instruction_hashes(comp);
    let mut order: Vec<InstrId> = comp.ids().collect();
    // Sort by (depth-from-leaves, hash): depth keeps the order
    // topological, the hash removes id dependence inside a depth level.
    let mut depth = vec![0usize; comp.len()];
    for id in comp.ids() {
        let d = comp
            .get(id)
            .operands
            .iter()
            .map(|o| depth[o.0] + 1)
            .max()
            .unwrap_or(0);
        depth[id.0] = d;
    }
    order.sort_by_key(|id| (depth[id.0], hashes[id.0], id.0));
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::{GraphBuilder, Shape};

    fn softmax_like(name: &str) -> Computation {
        let mut b = GraphBuilder::new(name);
        let x = b.param("x", Shape::f32(&[8, 64]));
        let m = b.reduce(x, &[1], ReduceKind::Max);
        let mb = b.broadcast(m, &[8, 64], &[0]);
        let sh = b.sub(x, mb);
        let e = b.exp(sh);
        b.finish(e)
    }

    #[test]
    fn deterministic_and_name_invariant() {
        let a = softmax_like("a");
        let mut b = softmax_like("completely_different");
        // rename every instruction
        for id in b.ids().collect::<Vec<_>>() {
            b.get_mut(id).name = format!("renamed_{}", id.0);
        }
        assert_eq!(fingerprint_computation(&a), fingerprint_computation(&b));
    }

    #[test]
    fn id_numbering_invariant() {
        // Same dataflow, different construction interleaving → different
        // instruction ids for the same logical nodes.
        let mut b1 = GraphBuilder::new("g1");
        let x1 = b1.param("x", Shape::f32(&[16]));
        let y1 = b1.param("y", Shape::f32(&[16]));
        let e1 = b1.exp(x1);
        let t1 = b1.tanh(y1);
        let s1 = b1.add(e1, t1);
        let c1 = b1.finish(s1);

        let mut b2 = GraphBuilder::new("g2");
        let x2 = b2.param("x", Shape::f32(&[16]));
        let y2 = b2.param("y", Shape::f32(&[16]));
        let t2 = b2.tanh(y2); // built before the exp this time
        let e2 = b2.exp(x2);
        let s2 = b2.add(e2, t2);
        let c2 = b2.finish(s2);

        assert_eq!(fingerprint_computation(&c1), fingerprint_computation(&c2));
    }

    #[test]
    fn shape_change_changes_hash() {
        let a = softmax_like("a");
        let mut b = GraphBuilder::new("b");
        let x = b.param("x", Shape::f32(&[8, 128])); // wider
        let m = b.reduce(x, &[1], ReduceKind::Max);
        let mb = b.broadcast(m, &[8, 128], &[0]);
        let sh = b.sub(x, mb);
        let e = b.exp(sh);
        let c = b.finish(e);
        assert_ne!(fingerprint_computation(&a), fingerprint_computation(&c));
    }

    #[test]
    fn opcode_change_changes_hash() {
        let a = softmax_like("a");
        let mut b = GraphBuilder::new("b");
        let x = b.param("x", Shape::f32(&[8, 64]));
        let m = b.reduce(x, &[1], ReduceKind::Sum); // Max → Sum
        let mb = b.broadcast(m, &[8, 64], &[0]);
        let sh = b.sub(x, mb);
        let e = b.exp(sh);
        let c = b.finish(e);
        assert_ne!(fingerprint_computation(&a), fingerprint_computation(&c));
    }

    #[test]
    fn operand_order_is_semantic() {
        let mk = |swap: bool| {
            let mut b = GraphBuilder::new("s");
            let x = b.param("x", Shape::f32(&[4]));
            let y = b.param("y", Shape::f32(&[4]));
            let d = if swap { b.sub(y, x) } else { b.sub(x, y) };
            b.finish(d)
        };
        assert_ne!(
            fingerprint_computation(&mk(false)),
            fingerprint_computation(&mk(true))
        );
    }

    #[test]
    fn sharing_differs_from_duplication() {
        // add(exp(x), exp(x)) with one shared exp vs two duplicate exps:
        // same outputs, different graphs → different fingerprints.
        let mut b1 = GraphBuilder::new("shared");
        let x1 = b1.param("x", Shape::f32(&[4]));
        let e1 = b1.exp(x1);
        let s1 = b1.add(e1, e1);
        let c1 = b1.finish(s1);

        let mut b2 = GraphBuilder::new("dup");
        let x2 = b2.param("x", Shape::f32(&[4]));
        let ea = b2.exp(x2);
        let eb = b2.exp(x2);
        let s2 = b2.add(ea, eb);
        let c2 = b2.finish(s2);

        assert_ne!(fingerprint_computation(&c1), fingerprint_computation(&c2));
    }

    #[test]
    fn canonical_order_is_topological_and_stable() {
        let c = softmax_like("a");
        let order = canonical_order(&c);
        assert_eq!(order.len(), c.len());
        let pos = |id: InstrId| order.iter().position(|&x| x == id).unwrap();
        for id in c.ids() {
            for &op in &c.get(id).operands {
                assert!(pos(op) < pos(id), "operand after user in canonical order");
            }
        }
        assert_eq!(order, canonical_order(&c));
    }

    #[test]
    fn shape_class_fingerprint_collides_within_a_bucket() {
        use crate::hlo::Module;
        fn chain(len: usize) -> Module {
            let mut b = GraphBuilder::new("chain");
            let x = b.param("x", Shape::f32(&[4, len as i64]));
            let e = b.exp(x);
            let t = b.tanh(e);
            Module::new("chain", b.finish(t))
        }
        // Two concrete lengths sharing a canonical length share the hash…
        let a = fingerprint_shape_class(chain, 32);
        let b = fingerprint_shape_class(chain, 32);
        assert_eq!(a, b);
        // …and it is exactly the canonical module's ordinary fingerprint.
        assert_eq!(a, fingerprint_module(&chain(32)));
        // Different canonical lengths are different classes.
        assert_ne!(a, fingerprint_shape_class(chain, 64));
    }

    #[test]
    fn hex_rendering_is_32_digits() {
        let fp = fingerprint_computation(&softmax_like("a"));
        assert_eq!(fp.to_hex().len(), 32);
        assert_eq!(fp.to_string(), fp.to_hex());
        assert_eq!(fp.short(), fp.0 as u64);
    }
}
