//! Tensor shapes and element types.

use std::fmt;

/// Element type of a tensor. Covers the dtypes that occur in the paper's
/// workloads (training + inference graphs on GPUs circa TF 1.7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    Pred,
    S32,
    S64,
    F16,
    BF16,
    F32,
    F64,
}

impl DType {
    /// Size of one element in bytes.
    pub fn byte_size(self) -> usize {
        match self {
            DType::Pred => 1,
            DType::F16 | DType::BF16 => 2,
            DType::S32 | DType::F32 => 4,
            DType::S64 | DType::F64 => 8,
        }
    }

    /// Whether this is a floating point type.
    pub fn is_float(self) -> bool {
        matches!(self, DType::F16 | DType::BF16 | DType::F32 | DType::F64)
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            DType::Pred => "pred",
            DType::S32 => "s32",
            DType::S64 => "s64",
            DType::F16 => "f16",
            DType::BF16 => "bf16",
            DType::F32 => "f32",
            DType::F64 => "f64",
        };
        write!(f, "{s}")
    }
}

/// A dense array shape: element type plus dimensions, row-major
/// (most-significant dimension first), matching XLA's default layout.
///
/// Rank-0 (scalar) shapes have empty `dims`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape {
    pub dtype: DType,
    pub dims: Vec<i64>,
}

impl Shape {
    pub fn new(dtype: DType, dims: Vec<i64>) -> Self {
        debug_assert!(dims.iter().all(|&d| d >= 0), "negative dim in {dims:?}");
        Shape { dtype, dims }
    }

    /// Shorthand for an f32 shape — the dominant dtype in the paper's
    /// workloads and in our benchmark graphs.
    pub fn f32(dims: &[i64]) -> Self {
        Shape::new(DType::F32, dims.to_vec())
    }

    pub fn scalar(dtype: DType) -> Self {
        Shape::new(dtype, vec![])
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Total number of elements (1 for scalars).
    pub fn num_elements(&self) -> i64 {
        self.dims.iter().product()
    }

    /// Total byte size of the dense array.
    pub fn byte_size(&self) -> usize {
        self.num_elements() as usize * self.dtype.byte_size()
    }

    /// True if this shape has the same element count as `other` (the
    /// reshape/bitcast legality condition).
    pub fn same_elements(&self, other: &Shape) -> bool {
        self.num_elements() == other.num_elements()
    }

    /// Row-major strides, in elements.
    pub fn strides(&self) -> Vec<i64> {
        let mut strides = vec![1i64; self.rank()];
        for i in (0..self.rank().saturating_sub(1)).rev() {
            strides[i] = strides[i + 1] * self.dims[i + 1];
        }
        strides
    }

    /// Row-major linear index decomposition: which multi-index does flat
    /// index `linear` correspond to. Used by schedule propagation through
    /// `Reshape` (§4.2) and by tests.
    pub fn delinearize(&self, mut linear: i64) -> Vec<i64> {
        let mut idx = vec![0i64; self.rank()];
        for (i, s) in self.strides().iter().enumerate() {
            idx[i] = linear / s;
            linear %= s;
        }
        idx
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let dims: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        write!(f, "{}[{}]", self.dtype, dims.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.byte_size(), 4);
        assert_eq!(DType::F16.byte_size(), 2);
        assert_eq!(DType::BF16.byte_size(), 2);
        assert_eq!(DType::Pred.byte_size(), 1);
        assert_eq!(DType::S64.byte_size(), 8);
        assert!(DType::BF16.is_float());
        assert!(!DType::S32.is_float());
    }

    #[test]
    fn shape_basics() {
        let s = Shape::f32(&[2, 3, 4]);
        assert_eq!(s.rank(), 3);
        assert_eq!(s.num_elements(), 24);
        assert_eq!(s.byte_size(), 96);
        assert_eq!(s.to_string(), "f32[2,3,4]");
    }

    #[test]
    fn scalar_shape() {
        let s = Shape::scalar(DType::F32);
        assert_eq!(s.rank(), 0);
        assert_eq!(s.num_elements(), 1);
        assert_eq!(s.byte_size(), 4);
    }

    #[test]
    fn strides_row_major() {
        let s = Shape::f32(&[2, 3, 4]);
        assert_eq!(s.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn delinearize_roundtrip() {
        let s = Shape::f32(&[2, 3, 4]);
        let idx = s.delinearize(17);
        assert_eq!(idx, vec![1, 1, 1]);
        // linearize back
        let lin: i64 = idx.iter().zip(s.strides()).map(|(i, st)| i * st).sum();
        assert_eq!(lin, 17);
    }

    #[test]
    fn same_elements() {
        assert!(Shape::f32(&[6, 4]).same_elements(&Shape::f32(&[2, 12])));
        assert!(!Shape::f32(&[6, 4]).same_elements(&Shape::f32(&[5, 5])));
    }
}
