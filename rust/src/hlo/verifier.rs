//! Structural verifier for computations.
//!
//! Run after parsing and after every pass in debug builds; catches the
//! invariants the rest of the pipeline assumes (arity, attrs matching
//! opcodes, shape consistency for the ops with inferable shapes).

use super::computation::Computation;
use super::module::Module;
use super::opcode::Opcode;
use super::shape::Shape;
use anyhow::{bail, Result};

/// Verify a whole module.
pub fn verify_module(m: &Module) -> Result<()> {
    verify_computation(&m.entry)
}

/// Verify one computation.
pub fn verify_computation(c: &Computation) -> Result<()> {
    if !c.has_root() {
        bail!("computation {} has no root", c.name);
    }
    for instr in c.instructions() {
        let id = instr.id;
        // arity
        if let Some(arity) = instr.opcode.arity() {
            if instr.operands.len() != arity {
                bail!("{id}: {} expects {arity} operands, got {}", instr.opcode, instr.operands.len());
            }
        }
        // operand existence + ordering
        for &op in &instr.operands {
            if op.0 >= id.0 {
                bail!("{id}: operand {op} does not precede it");
            }
        }
        let operand_shapes: Vec<&Shape> = c.operand_shapes(id);
        match instr.opcode {
            Opcode::Parameter => {
                if instr.attrs.parameter_number.is_none() {
                    bail!("{id}: parameter without parameter_number");
                }
            }
            op if op.is_elementwise() => {
                // all operand dims must equal output dims (explicit
                // broadcast discipline)
                for s in &operand_shapes {
                    if s.dims != instr.shape.dims {
                        bail!(
                            "{id}: elementwise {op} operand shape {s} != output {}",
                            instr.shape
                        );
                    }
                }
            }
            Opcode::Reshape | Opcode::Bitcast => {
                if !operand_shapes[0].same_elements(&instr.shape) {
                    bail!("{id}: reshape/bitcast element count mismatch");
                }
            }
            Opcode::Transpose => {
                let perm = instr
                    .attrs
                    .transpose_perm
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("{id}: transpose without perm"))?;
                if perm.len() != operand_shapes[0].rank() {
                    bail!("{id}: transpose perm rank mismatch");
                }
                let expect: Vec<i64> = perm.iter().map(|&p| operand_shapes[0].dims[p]).collect();
                if expect != instr.shape.dims {
                    bail!("{id}: transpose output shape mismatch");
                }
            }
            Opcode::Broadcast => {
                let bd = instr
                    .attrs
                    .broadcast_dims
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("{id}: broadcast without bdims"))?;
                if bd.len() != operand_shapes[0].rank() {
                    bail!("{id}: broadcast dims rank mismatch");
                }
                for (i, &d) in bd.iter().enumerate() {
                    if d >= instr.shape.rank() || operand_shapes[0].dims[i] != instr.shape.dims[d] {
                        bail!("{id}: broadcast dim mapping invalid");
                    }
                }
            }
            Opcode::Reduce => {
                let dims = instr
                    .attrs
                    .reduce_dims
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("{id}: reduce without dims"))?;
                if instr.attrs.reduce_kind.is_none() {
                    bail!("{id}: reduce without kind");
                }
                let in_rank = operand_shapes[0].rank();
                if dims.iter().any(|&d| d >= in_rank) {
                    bail!("{id}: reduce dim out of range");
                }
                if instr.shape.rank() != in_rank - dims.len() {
                    bail!("{id}: reduce output rank mismatch");
                }
            }
            Opcode::Concatenate => {
                if instr.attrs.concat_dim.is_none() {
                    bail!("{id}: concat without cdim");
                }
                if instr.operands.is_empty() {
                    bail!("{id}: concat with no operands");
                }
            }
            Opcode::Slice => {
                if instr.attrs.slice_starts.is_none() || instr.attrs.slice_limits.is_none() {
                    bail!("{id}: slice without bounds");
                }
            }
            Opcode::BatchDot | Opcode::Dot => {
                let (a, b) = (&operand_shapes[0], &operand_shapes[1]);
                let r = a.rank();
                if r < 2 || b.rank() != r || a.dims[r - 1] != b.dims[r - 2] {
                    bail!("{id}: dot shape mismatch {a} x {b}");
                }
            }
            Opcode::CustomCall => {
                if instr.attrs.custom_call_target.is_none() {
                    bail!("{id}: custom-call without target");
                }
            }
            _ => {}
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::builder::GraphBuilder;
    use crate::hlo::computation::InstrId;
    use crate::hlo::instruction::{Attrs, ReduceKind};
    use crate::hlo::shape::DType;

    #[test]
    fn builder_output_verifies() {
        let mut b = GraphBuilder::new("ok");
        let x = b.param("x", Shape::f32(&[4, 8]));
        let t = b.transpose(x, &[1, 0]);
        let r = b.reduce(t, &[0], ReduceKind::Sum);
        let c = b.finish(r);
        verify_computation(&c).unwrap();
    }

    #[test]
    fn catches_bad_transpose_shape() {
        let mut c = Computation::new("bad");
        let p = c.add(
            "p",
            Opcode::Parameter,
            Shape::f32(&[2, 3]),
            vec![],
            Attrs { parameter_number: Some(0), ..Default::default() },
            0,
        );
        let t = c.add(
            "t",
            Opcode::Transpose,
            Shape::f32(&[2, 3]), // wrong: should be [3,2]
            vec![p],
            Attrs { transpose_perm: Some(vec![1, 0]), ..Default::default() },
            0,
        );
        c.set_root(t);
        assert!(verify_computation(&c).is_err());
    }

    #[test]
    fn catches_missing_param_number() {
        let mut c = Computation::new("bad");
        let p = c.add("p", Opcode::Parameter, Shape::scalar(DType::F32), vec![], Attrs::default(), 0);
        c.set_root(p);
        assert!(verify_computation(&c).is_err());
    }

    #[test]
    fn catches_elementwise_mismatch() {
        let mut c = Computation::new("bad");
        let p0 = c.add(
            "p0",
            Opcode::Parameter,
            Shape::f32(&[2]),
            vec![],
            Attrs { parameter_number: Some(0), ..Default::default() },
            0,
        );
        let p1 = c.add(
            "p1",
            Opcode::Parameter,
            Shape::f32(&[3]),
            vec![],
            Attrs { parameter_number: Some(1), ..Default::default() },
            0,
        );
        let a = c.add("a", Opcode::Add, Shape::f32(&[2]), vec![p0, p1], Attrs::default(), 0);
        c.set_root(a);
        assert!(verify_computation(&c).is_err());
    }

    #[test]
    fn catches_missing_root() {
        let c = Computation::new("noroot");
        assert!(verify_computation(&c).is_err());
        let _ = InstrId(0);
    }
}
