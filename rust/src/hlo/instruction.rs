//! HLO instructions: opcode + output shape + operands + op attributes.

use super::computation::InstrId;
use super::opcode::Opcode;
use super::shape::Shape;
use std::fmt;

/// Reduction kind. The paper's Figure 1 groups mean/sum/min/max under a
/// collective "reduce" line; we keep the kind explicit for codegen.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReduceKind {
    Sum,
    Max,
    Min,
    Mean,
    Prod,
}

impl fmt::Display for ReduceKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

/// While-loop frame context id (§3.1: nodes are partitioned into frame
/// contexts before Work/Span analysis). Frame 0 is the top-level graph.
pub type FrameId = u32;

/// Optional per-op attributes. Only the fields relevant to an opcode are
/// populated; the verifier enforces this.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Attrs {
    /// `Transpose`: output dim `i` reads input dim `perm[i]`.
    pub transpose_perm: Option<Vec<usize>>,
    /// `Reduce`: input dims being collapsed (sorted ascending).
    pub reduce_dims: Option<Vec<usize>>,
    /// `Reduce`: combiner.
    pub reduce_kind: Option<ReduceKind>,
    /// `Broadcast`: which output dims the operand dims map to
    /// (XLA `broadcast_dimensions`), sorted ascending.
    pub broadcast_dims: Option<Vec<usize>>,
    /// `Concatenate`: dimension along which operands are joined.
    pub concat_dim: Option<usize>,
    /// `Slice`: start index per dim.
    pub slice_starts: Option<Vec<i64>>,
    /// `Slice`: limit index per dim.
    pub slice_limits: Option<Vec<i64>>,
    /// `CustomCall`: opaque target name (e.g. "cudnn_lstm").
    pub custom_call_target: Option<String>,
    /// `Parameter`: position in the entry signature.
    pub parameter_number: Option<usize>,
    /// `GetTupleElement`: tuple index.
    pub tuple_index: Option<usize>,
}

/// One HLO instruction. Instructions live in a [`super::Computation`]
/// arena and reference operands by [`InstrId`].
#[derive(Debug, Clone, PartialEq)]
pub struct Instruction {
    pub id: InstrId,
    pub name: String,
    pub opcode: Opcode,
    pub shape: Shape,
    pub operands: Vec<InstrId>,
    pub attrs: Attrs,
    /// While-loop frame context (0 = top level).
    pub frame: FrameId,
}

impl Instruction {
    /// Memory IO footprint in number of elements: output plus all operand
    /// elements. This is the metric of the paper's Figure 1 ("memory IO
    /// footprint size in number of floats").
    ///
    /// Note this intentionally counts *instruction-local* IO; buffer
    /// sharing across a fused kernel is accounted separately by
    /// [`crate::analysis::footprint`].
    pub fn io_footprint_elements(&self, operand_shapes: &[&Shape]) -> i64 {
        self.shape.num_elements() + operand_shapes.iter().map(|s| s.num_elements()).sum::<i64>()
    }

    /// For `Reduce`: the smallest reduced input dimension index
    /// (`min_reduce_dim` in Table 1). Panics if not a reduce.
    pub fn min_reduce_dim(&self) -> usize {
        *self
            .attrs
            .reduce_dims
            .as_ref()
            .expect("reduce_dims on non-reduce")
            .iter()
            .min()
            .expect("empty reduce_dims")
    }

    /// For `Reduce`: the largest reduced input dimension index.
    pub fn max_reduce_dim(&self) -> usize {
        *self
            .attrs
            .reduce_dims
            .as_ref()
            .expect("reduce_dims on non-reduce")
            .iter()
            .max()
            .expect("empty reduce_dims")
    }

    /// For `Transpose`: smallest dim index that actually moves
    /// (`min_trans_dim` in Table 1). `None` if the permutation is identity.
    pub fn min_trans_dim(&self) -> Option<usize> {
        let perm = self.attrs.transpose_perm.as_ref().expect("perm on non-transpose");
        perm.iter().enumerate().filter(|(i, &p)| *i != p).map(|(i, _)| i).min()
    }

    /// For `Transpose`: largest dim index that actually moves.
    pub fn max_trans_dim(&self) -> Option<usize> {
        let perm = self.attrs.transpose_perm.as_ref().expect("perm on non-transpose");
        perm.iter().enumerate().filter(|(i, &p)| *i != p).map(|(i, _)| i).max()
    }
}

impl fmt::Display for Instruction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "%{} = {} {}", self.name, self.shape, self.opcode)?;
        if !self.operands.is_empty() {
            let ops: Vec<String> = self.operands.iter().map(|o| format!("%{}", o.0)).collect();
            write!(f, "({})", ops.join(", "))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::computation::InstrId;

    fn reduce_instr(dims: Vec<usize>) -> Instruction {
        Instruction {
            id: InstrId(0),
            name: "r".into(),
            opcode: Opcode::Reduce,
            shape: Shape::f32(&[2, 3]),
            operands: vec![InstrId(1)],
            attrs: Attrs {
                reduce_dims: Some(dims),
                reduce_kind: Some(ReduceKind::Sum),
                ..Default::default()
            },
            frame: 0,
        }
    }

    #[test]
    fn reduce_dim_bounds() {
        let r = reduce_instr(vec![2, 4, 3]);
        assert_eq!(r.min_reduce_dim(), 2);
        assert_eq!(r.max_reduce_dim(), 4);
    }

    #[test]
    fn transpose_dim_bounds() {
        let t = Instruction {
            id: InstrId(0),
            name: "t".into(),
            opcode: Opcode::Transpose,
            shape: Shape::f32(&[4, 3, 2]),
            operands: vec![InstrId(1)],
            attrs: Attrs { transpose_perm: Some(vec![0, 2, 1]), ..Default::default() },
            frame: 0,
        };
        assert_eq!(t.min_trans_dim(), Some(1));
        assert_eq!(t.max_trans_dim(), Some(2));
    }

    #[test]
    fn identity_transpose_has_no_moving_dims() {
        let t = Instruction {
            id: InstrId(0),
            name: "t".into(),
            opcode: Opcode::Transpose,
            shape: Shape::f32(&[4, 3]),
            operands: vec![InstrId(1)],
            attrs: Attrs { transpose_perm: Some(vec![0, 1]), ..Default::default() },
            frame: 0,
        };
        assert_eq!(t.min_trans_dim(), None);
        assert_eq!(t.max_trans_dim(), None);
    }

    #[test]
    fn io_footprint() {
        let r = reduce_instr(vec![0]);
        let in_shape = Shape::f32(&[10, 2, 3]);
        assert_eq!(r.io_footprint_elements(&[&in_shape]), 6 + 60);
    }
}
