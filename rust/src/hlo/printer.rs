//! Textual form of modules/computations, loosely modelled on XLA's HLO
//! text syntax. Round-trips with [`super::parser`].
//!
//! Example:
//! ```text
//! module softmax {
//!   entry {
//!     %0 = f32[8,64,64] parameter(0) {name=scores}
//!     %1 = f32[8,64] reduce(%0) {dims=[2], kind=Max}
//!     ...
//!     root %7
//!   }
//! }
//! ```

use super::computation::Computation;
use super::instruction::Instruction;
use super::module::Module;
use super::opcode::Opcode;
use std::fmt::Write;

pub fn print_module(m: &Module) -> String {
    let mut out = String::new();
    writeln!(out, "module {} {{", m.name).unwrap();
    out.push_str(&print_computation(&m.entry, 1));
    out.push_str("}\n");
    out
}

pub fn print_computation(c: &Computation, indent: usize) -> String {
    let pad = "  ".repeat(indent);
    let mut out = String::new();
    writeln!(out, "{pad}entry {{").unwrap();
    for instr in c.instructions() {
        writeln!(out, "{pad}  {}", print_instruction(instr)).unwrap();
    }
    if c.has_root() {
        writeln!(out, "{pad}  root %{}", c.root().0).unwrap();
    }
    writeln!(out, "{pad}}}").unwrap();
    out
}

pub fn print_instruction(i: &Instruction) -> String {
    let mut s = format!("%{} = {} {}", i.id.0, i.shape, opcode_keyword(i.opcode));
    let ops: Vec<String> = i.operands.iter().map(|o| format!("%{}", o.0)).collect();
    s.push_str(&format!("({})", ops.join(", ")));
    let mut attrs: Vec<String> = Vec::new();
    if let Some(n) = i.attrs.parameter_number {
        attrs.push(format!("num={n}"));
    }
    if let Some(p) = &i.attrs.transpose_perm {
        attrs.push(format!("perm={p:?}"));
    }
    if let Some(d) = &i.attrs.reduce_dims {
        attrs.push(format!("dims={d:?}"));
    }
    if let Some(k) = &i.attrs.reduce_kind {
        attrs.push(format!("kind={k}"));
    }
    if let Some(d) = &i.attrs.broadcast_dims {
        attrs.push(format!("bdims={d:?}"));
    }
    if let Some(d) = i.attrs.concat_dim {
        attrs.push(format!("cdim={d}"));
    }
    if let Some(st) = &i.attrs.slice_starts {
        attrs.push(format!("starts={st:?}"));
    }
    if let Some(li) = &i.attrs.slice_limits {
        attrs.push(format!("limits={li:?}"));
    }
    if let Some(t) = &i.attrs.custom_call_target {
        attrs.push(format!("target=\"{t}\""));
    }
    if i.frame != 0 {
        attrs.push(format!("frame={}", i.frame));
    }
    attrs.push(format!("name={}", i.name));
    if !attrs.is_empty() {
        s.push_str(&format!(" {{{}}}", attrs.join(", ")));
    }
    s
}

/// Print `m` in the XLA-flavoured text dialect the op-by-op runtime
/// interpreter executes ([`crate::runtime::interp::HloProgram`]): an
/// `ENTRY` block of `name = shape opcode(operands)` lines with
/// `dimensions={...}` / `kind=` attributes. This is the bridge that
/// lets any in-memory graph (e.g. the corpus generator's) run on the
/// interpreter as the per-op baseline of the stitched-execution
/// differential harness.
///
/// Valueless IR constants print as `constant(1)` — the same 1.0 fill
/// the stitched VM materializes, so both backends agree.
pub fn xla_text(m: &Module) -> String {
    let name: String = m
        .name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect();
    let mut out = format!("HloModule {name}\n\nENTRY main {{\n");
    let c = &m.entry;
    let root = if c.has_root() { Some(c.root()) } else { None };
    for instr in c.instructions() {
        let prefix = if root == Some(instr.id) { "ROOT " } else { "" };
        let mut line = format!(
            "  {prefix}v{} = {} {}(",
            instr.id.0,
            instr.shape,
            opcode_keyword(instr.opcode)
        );
        match instr.opcode {
            Opcode::Parameter => {
                line.push_str(&instr.attrs.parameter_number.unwrap_or(0).to_string());
            }
            Opcode::Constant => line.push('1'),
            _ => {
                let ops: Vec<String> =
                    instr.operands.iter().map(|o| format!("v{}", o.0)).collect();
                line.push_str(&ops.join(", "));
            }
        }
        line.push(')');
        let mut attrs: Vec<String> = Vec::new();
        if let Some(d) = &instr.attrs.reduce_dims {
            attrs.push(format!("dimensions={{{}}}", join_usize(d)));
        }
        if let Some(k) = &instr.attrs.reduce_kind {
            attrs.push(format!("kind={k}"));
        }
        if let Some(d) = &instr.attrs.broadcast_dims {
            attrs.push(format!("dimensions={{{}}}", join_usize(d)));
        }
        if let Some(p) = &instr.attrs.transpose_perm {
            attrs.push(format!("dimensions={{{}}}", join_usize(p)));
        }
        for a in attrs {
            line.push_str(", ");
            line.push_str(&a);
        }
        out.push_str(&line);
        out.push('\n');
    }
    out.push_str("}\n");
    out
}

fn join_usize(xs: &[usize]) -> String {
    xs.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
}

pub(crate) fn opcode_keyword(op: Opcode) -> &'static str {
    use Opcode::*;
    match op {
        Parameter => "parameter",
        Constant => "constant",
        Iota => "iota",
        Tuple => "tuple",
        GetTupleElement => "get-tuple-element",
        Abs => "abs",
        Negate => "negate",
        Sign => "sign",
        Floor => "floor",
        Ceil => "ceil",
        Not => "not",
        Copy => "copy",
        Exp => "exponential",
        Log => "log",
        Sqrt => "sqrt",
        Rsqrt => "rsqrt",
        Tanh => "tanh",
        Sigmoid => "sigmoid",
        Erf => "erf",
        Add => "add",
        Subtract => "subtract",
        Multiply => "multiply",
        Maximum => "maximum",
        Minimum => "minimum",
        Compare => "compare",
        And => "and",
        Or => "or",
        Divide => "divide",
        Power => "power",
        Remainder => "remainder",
        Select => "select",
        Clamp => "clamp",
        Reshape => "reshape",
        Bitcast => "bitcast",
        Transpose => "transpose",
        Broadcast => "broadcast",
        Slice => "slice",
        Concatenate => "concatenate",
        Pad => "pad",
        Gather => "gather",
        DynamicSlice => "dynamic-slice",
        DynamicUpdateSlice => "dynamic-update-slice",
        Reduce => "reduce",
        ReduceWindow => "reduce-window",
        BatchDot => "batch-dot",
        Dot => "dot",
        Convolution => "convolution",
        CustomCall => "custom-call",
        While => "while",
    }
}

pub(crate) fn keyword_opcode(kw: &str) -> Option<Opcode> {
    use Opcode::*;
    Some(match kw {
        "parameter" => Parameter,
        "constant" => Constant,
        "iota" => Iota,
        "tuple" => Tuple,
        "get-tuple-element" => GetTupleElement,
        "abs" => Abs,
        "negate" => Negate,
        "sign" => Sign,
        "floor" => Floor,
        "ceil" => Ceil,
        "not" => Not,
        "copy" => Copy,
        "exponential" => Exp,
        "log" => Log,
        "sqrt" => Sqrt,
        "rsqrt" => Rsqrt,
        "tanh" => Tanh,
        "sigmoid" => Sigmoid,
        "erf" => Erf,
        "add" => Add,
        "subtract" => Subtract,
        "multiply" => Multiply,
        "maximum" => Maximum,
        "minimum" => Minimum,
        "compare" => Compare,
        "and" => And,
        "or" => Or,
        "divide" => Divide,
        "power" => Power,
        "remainder" => Remainder,
        "select" => Select,
        "clamp" => Clamp,
        "reshape" => Reshape,
        "bitcast" => Bitcast,
        "transpose" => Transpose,
        "broadcast" => Broadcast,
        "slice" => Slice,
        "concatenate" => Concatenate,
        "pad" => Pad,
        "gather" => Gather,
        "dynamic-slice" => DynamicSlice,
        "dynamic-update-slice" => DynamicUpdateSlice,
        "reduce" => Reduce,
        "reduce-window" => ReduceWindow,
        "batch-dot" => BatchDot,
        "dot" => Dot,
        "convolution" => Convolution,
        "custom-call" => CustomCall,
        "while" => While,
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::builder::GraphBuilder;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::shape::Shape;

    #[test]
    fn print_contains_all_instructions() {
        let mut b = GraphBuilder::new("p");
        let x = b.param("x", Shape::f32(&[4, 4]));
        let r = b.reduce(x, &[1], ReduceKind::Sum);
        let m = Module::new("m", b.finish(r));
        let text = print_module(&m);
        assert!(text.contains("parameter"));
        assert!(text.contains("reduce"));
        assert!(text.contains("dims=[1]"));
        assert!(text.contains("root %1"));
    }

    #[test]
    fn xla_text_executes_on_the_interpreter() {
        let mut b = GraphBuilder::new("roundtrip");
        let x = b.param("x", Shape::f32(&[2, 4]));
        let bias = b.param("bias", Shape::f32(&[4]));
        let bb = b.broadcast(bias, &[2, 4], &[1]);
        let a = b.add(x, bb);
        let t = b.tanh(a);
        let r = b.reduce(t, &[1], ReduceKind::Sum);
        let m = Module::new("roundtrip", b.finish(r));
        let text = xla_text(&m);
        assert!(text.contains("ENTRY main"), "{text}");
        assert!(text.contains("dimensions={1}"), "{text}");
        assert!(text.contains("kind=Sum"), "{text}");
        let prog = crate::runtime::interp::HloProgram::parse(&text).unwrap();
        let out = prog
            .execute(&[vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0], vec![0.5; 4]])
            .unwrap();
        let expect: f32 = (0..4).map(|i| (i as f32 + 0.5).tanh()).sum();
        assert!((out[0][0] - expect).abs() < 1e-6);
    }

    #[test]
    fn opcode_keyword_roundtrip() {
        for op in [
            Opcode::Exp,
            Opcode::Reduce,
            Opcode::BatchDot,
            Opcode::GetTupleElement,
            Opcode::DynamicUpdateSlice,
        ] {
            assert_eq!(keyword_opcode(opcode_keyword(op)), Some(op));
        }
        assert_eq!(keyword_opcode("bogus"), None);
    }
}
