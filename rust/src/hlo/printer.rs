//! Textual form of modules/computations, loosely modelled on XLA's HLO
//! text syntax. Round-trips with [`super::parser`].
//!
//! Example:
//! ```text
//! module softmax {
//!   entry {
//!     %0 = f32[8,64,64] parameter(0) {name=scores}
//!     %1 = f32[8,64] reduce(%0) {dims=[2], kind=Max}
//!     ...
//!     root %7
//!   }
//! }
//! ```

use super::computation::Computation;
use super::instruction::Instruction;
use super::module::Module;
use super::opcode::Opcode;
use std::fmt::Write;

pub fn print_module(m: &Module) -> String {
    let mut out = String::new();
    writeln!(out, "module {} {{", m.name).unwrap();
    out.push_str(&print_computation(&m.entry, 1));
    out.push_str("}\n");
    out
}

pub fn print_computation(c: &Computation, indent: usize) -> String {
    let pad = "  ".repeat(indent);
    let mut out = String::new();
    writeln!(out, "{pad}entry {{").unwrap();
    for instr in c.instructions() {
        writeln!(out, "{pad}  {}", print_instruction(instr)).unwrap();
    }
    if c.has_root() {
        writeln!(out, "{pad}  root %{}", c.root().0).unwrap();
    }
    writeln!(out, "{pad}}}").unwrap();
    out
}

pub fn print_instruction(i: &Instruction) -> String {
    let mut s = format!("%{} = {} {}", i.id.0, i.shape, opcode_keyword(i.opcode));
    let ops: Vec<String> = i.operands.iter().map(|o| format!("%{}", o.0)).collect();
    s.push_str(&format!("({})", ops.join(", ")));
    let mut attrs: Vec<String> = Vec::new();
    if let Some(n) = i.attrs.parameter_number {
        attrs.push(format!("num={n}"));
    }
    if let Some(p) = &i.attrs.transpose_perm {
        attrs.push(format!("perm={p:?}"));
    }
    if let Some(d) = &i.attrs.reduce_dims {
        attrs.push(format!("dims={d:?}"));
    }
    if let Some(k) = &i.attrs.reduce_kind {
        attrs.push(format!("kind={k}"));
    }
    if let Some(d) = &i.attrs.broadcast_dims {
        attrs.push(format!("bdims={d:?}"));
    }
    if let Some(d) = i.attrs.concat_dim {
        attrs.push(format!("cdim={d}"));
    }
    if let Some(st) = &i.attrs.slice_starts {
        attrs.push(format!("starts={st:?}"));
    }
    if let Some(li) = &i.attrs.slice_limits {
        attrs.push(format!("limits={li:?}"));
    }
    if let Some(t) = &i.attrs.custom_call_target {
        attrs.push(format!("target=\"{t}\""));
    }
    if i.frame != 0 {
        attrs.push(format!("frame={}", i.frame));
    }
    attrs.push(format!("name={}", i.name));
    if !attrs.is_empty() {
        s.push_str(&format!(" {{{}}}", attrs.join(", ")));
    }
    s
}

pub(crate) fn opcode_keyword(op: Opcode) -> &'static str {
    use Opcode::*;
    match op {
        Parameter => "parameter",
        Constant => "constant",
        Iota => "iota",
        Tuple => "tuple",
        GetTupleElement => "get-tuple-element",
        Abs => "abs",
        Negate => "negate",
        Sign => "sign",
        Floor => "floor",
        Ceil => "ceil",
        Not => "not",
        Copy => "copy",
        Exp => "exponential",
        Log => "log",
        Sqrt => "sqrt",
        Rsqrt => "rsqrt",
        Tanh => "tanh",
        Sigmoid => "sigmoid",
        Erf => "erf",
        Add => "add",
        Subtract => "subtract",
        Multiply => "multiply",
        Maximum => "maximum",
        Minimum => "minimum",
        Compare => "compare",
        And => "and",
        Or => "or",
        Divide => "divide",
        Power => "power",
        Remainder => "remainder",
        Select => "select",
        Clamp => "clamp",
        Reshape => "reshape",
        Bitcast => "bitcast",
        Transpose => "transpose",
        Broadcast => "broadcast",
        Slice => "slice",
        Concatenate => "concatenate",
        Pad => "pad",
        Gather => "gather",
        DynamicSlice => "dynamic-slice",
        DynamicUpdateSlice => "dynamic-update-slice",
        Reduce => "reduce",
        ReduceWindow => "reduce-window",
        BatchDot => "batch-dot",
        Dot => "dot",
        Convolution => "convolution",
        CustomCall => "custom-call",
        While => "while",
    }
}

pub(crate) fn keyword_opcode(kw: &str) -> Option<Opcode> {
    use Opcode::*;
    Some(match kw {
        "parameter" => Parameter,
        "constant" => Constant,
        "iota" => Iota,
        "tuple" => Tuple,
        "get-tuple-element" => GetTupleElement,
        "abs" => Abs,
        "negate" => Negate,
        "sign" => Sign,
        "floor" => Floor,
        "ceil" => Ceil,
        "not" => Not,
        "copy" => Copy,
        "exponential" => Exp,
        "log" => Log,
        "sqrt" => Sqrt,
        "rsqrt" => Rsqrt,
        "tanh" => Tanh,
        "sigmoid" => Sigmoid,
        "erf" => Erf,
        "add" => Add,
        "subtract" => Subtract,
        "multiply" => Multiply,
        "maximum" => Maximum,
        "minimum" => Minimum,
        "compare" => Compare,
        "and" => And,
        "or" => Or,
        "divide" => Divide,
        "power" => Power,
        "remainder" => Remainder,
        "select" => Select,
        "clamp" => Clamp,
        "reshape" => Reshape,
        "bitcast" => Bitcast,
        "transpose" => Transpose,
        "broadcast" => Broadcast,
        "slice" => Slice,
        "concatenate" => Concatenate,
        "pad" => Pad,
        "gather" => Gather,
        "dynamic-slice" => DynamicSlice,
        "dynamic-update-slice" => DynamicUpdateSlice,
        "reduce" => Reduce,
        "reduce-window" => ReduceWindow,
        "batch-dot" => BatchDot,
        "dot" => Dot,
        "convolution" => Convolution,
        "custom-call" => CustomCall,
        "while" => While,
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::builder::GraphBuilder;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::shape::Shape;

    #[test]
    fn print_contains_all_instructions() {
        let mut b = GraphBuilder::new("p");
        let x = b.param("x", Shape::f32(&[4, 4]));
        let r = b.reduce(x, &[1], ReduceKind::Sum);
        let m = Module::new("m", b.finish(r));
        let text = print_module(&m);
        assert!(text.contains("parameter"));
        assert!(text.contains("reduce"));
        assert!(text.contains("dims=[1]"));
        assert!(text.contains("root %1"));
    }

    #[test]
    fn opcode_keyword_roundtrip() {
        for op in [
            Opcode::Exp,
            Opcode::Reduce,
            Opcode::BatchDot,
            Opcode::GetTupleElement,
            Opcode::DynamicUpdateSlice,
        ] {
            assert_eq!(keyword_opcode(opcode_keyword(op)), Some(op));
        }
        assert_eq!(keyword_opcode("bogus"), None);
    }
}
