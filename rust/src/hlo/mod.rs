//! HLO-like intermediate representation.
//!
//! Mirrors the subset of XLA's `HloModule` that FusionStitching operates
//! on: a flat, SSA-style instruction arena per computation, with the four
//! op categories the paper considers (§2.1): elementwise, shape
//! modulation, reduction and batched matmul — plus parameters, constants,
//! library calls (Dot/Conv/CustomCall) and while-frame tags.

pub mod builder;
pub mod computation;
pub mod fingerprint;
pub mod instruction;
pub mod module;
pub mod opcode;
pub mod parser;
pub mod printer;
pub mod shape;
pub mod verifier;

pub use builder::GraphBuilder;
pub use computation::{Computation, InstrId};
pub use fingerprint::{
    fingerprint_computation, fingerprint_module, fingerprint_shape_class, Fingerprint,
};
pub use instruction::{Instruction, ReduceKind};
pub use module::Module;
pub use opcode::Opcode;
pub use shape::{DType, Shape};
