//! Ergonomic graph construction with shape inference.
//!
//! The benchmark models (Table 2) and all tests build graphs through this
//! builder; it infers output shapes and panics on malformed graphs so that
//! model definitions stay short and honest.

use super::computation::{Computation, InstrId};
use super::instruction::{Attrs, FrameId, ReduceKind};
use super::opcode::Opcode;
use super::shape::{DType, Shape};

/// Builder over a [`Computation`]. Consumed by `finish()`.
pub struct GraphBuilder {
    comp: Computation,
    frame: FrameId,
    next_param: usize,
    fresh: usize,
}

impl GraphBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        GraphBuilder { comp: Computation::new(name), frame: 0, next_param: 0, fresh: 0 }
    }

    /// Set the while-loop frame context for subsequently added ops (§3.1).
    pub fn set_frame(&mut self, frame: FrameId) {
        self.frame = frame;
    }

    pub fn frame(&self) -> FrameId {
        self.frame
    }

    fn name(&mut self, base: &str) -> String {
        self.fresh += 1;
        format!("{base}.{}", self.fresh)
    }

    fn shape_of(&self, id: InstrId) -> &Shape {
        &self.comp.get(id).shape
    }

    fn push(&mut self, base: &str, op: Opcode, shape: Shape, operands: Vec<InstrId>, attrs: Attrs) -> InstrId {
        let name = self.name(base);
        self.comp.add(name, op, shape, operands, attrs, self.frame)
    }

    // ---- leaves ----

    pub fn param(&mut self, name: &str, shape: Shape) -> InstrId {
        let n = self.next_param;
        self.next_param += 1;
        self.comp.add(
            name,
            Opcode::Parameter,
            shape,
            vec![],
            Attrs { parameter_number: Some(n), ..Default::default() },
            self.frame,
        )
    }

    pub fn constant(&mut self, shape: Shape) -> InstrId {
        self.push("const", Opcode::Constant, shape, vec![], Attrs::default())
    }

    pub fn scalar(&mut self, dtype: DType) -> InstrId {
        self.constant(Shape::scalar(dtype))
    }

    pub fn iota(&mut self, shape: Shape) -> InstrId {
        self.push("iota", Opcode::Iota, shape, vec![], Attrs::default())
    }

    // ---- elementwise unary ----

    fn unary(&mut self, op: Opcode, x: InstrId) -> InstrId {
        let shape = self.shape_of(x).clone();
        self.push(&op.to_string().to_lowercase(), op, shape, vec![x], Attrs::default())
    }

    pub fn exp(&mut self, x: InstrId) -> InstrId {
        self.unary(Opcode::Exp, x)
    }
    pub fn log(&mut self, x: InstrId) -> InstrId {
        self.unary(Opcode::Log, x)
    }
    pub fn tanh(&mut self, x: InstrId) -> InstrId {
        self.unary(Opcode::Tanh, x)
    }
    pub fn sigmoid(&mut self, x: InstrId) -> InstrId {
        self.unary(Opcode::Sigmoid, x)
    }
    pub fn sqrt(&mut self, x: InstrId) -> InstrId {
        self.unary(Opcode::Sqrt, x)
    }
    pub fn rsqrt(&mut self, x: InstrId) -> InstrId {
        self.unary(Opcode::Rsqrt, x)
    }
    pub fn neg(&mut self, x: InstrId) -> InstrId {
        self.unary(Opcode::Negate, x)
    }
    pub fn abs(&mut self, x: InstrId) -> InstrId {
        self.unary(Opcode::Abs, x)
    }
    pub fn copy(&mut self, x: InstrId) -> InstrId {
        self.unary(Opcode::Copy, x)
    }
    pub fn erf(&mut self, x: InstrId) -> InstrId {
        self.unary(Opcode::Erf, x)
    }

    // ---- elementwise binary (shapes must match exactly; broadcast
    //      explicitly with `broadcast`) ----

    fn binary(&mut self, op: Opcode, a: InstrId, b: InstrId) -> InstrId {
        let sa = self.shape_of(a).clone();
        let sb = self.shape_of(b);
        assert_eq!(&sa, sb, "binary {op} shape mismatch: {sa} vs {sb}");
        self.push(&op.to_string().to_lowercase(), op, sa, vec![a, b], Attrs::default())
    }

    pub fn add(&mut self, a: InstrId, b: InstrId) -> InstrId {
        self.binary(Opcode::Add, a, b)
    }
    pub fn sub(&mut self, a: InstrId, b: InstrId) -> InstrId {
        self.binary(Opcode::Subtract, a, b)
    }
    pub fn mul(&mut self, a: InstrId, b: InstrId) -> InstrId {
        self.binary(Opcode::Multiply, a, b)
    }
    pub fn div(&mut self, a: InstrId, b: InstrId) -> InstrId {
        self.binary(Opcode::Divide, a, b)
    }
    pub fn pow(&mut self, a: InstrId, b: InstrId) -> InstrId {
        self.binary(Opcode::Power, a, b)
    }
    pub fn max(&mut self, a: InstrId, b: InstrId) -> InstrId {
        self.binary(Opcode::Maximum, a, b)
    }
    pub fn min(&mut self, a: InstrId, b: InstrId) -> InstrId {
        self.binary(Opcode::Minimum, a, b)
    }

    pub fn compare(&mut self, a: InstrId, b: InstrId) -> InstrId {
        let sa = self.shape_of(a).clone();
        assert_eq!(&sa.dims, &self.shape_of(b).dims);
        let shape = Shape::new(DType::Pred, sa.dims);
        self.push("compare", Opcode::Compare, shape, vec![a, b], Attrs::default())
    }

    pub fn select(&mut self, pred: InstrId, on_true: InstrId, on_false: InstrId) -> InstrId {
        let st = self.shape_of(on_true).clone();
        assert_eq!(&st, self.shape_of(on_false));
        self.push("select", Opcode::Select, st, vec![pred, on_true, on_false], Attrs::default())
    }

    // ---- shape modulation ----

    pub fn reshape(&mut self, x: InstrId, dims: &[i64]) -> InstrId {
        let sx = self.shape_of(x);
        let out = Shape::new(sx.dtype, dims.to_vec());
        assert!(
            sx.same_elements(&out),
            "reshape element mismatch: {sx} -> {out}"
        );
        self.push("reshape", Opcode::Reshape, out, vec![x], Attrs::default())
    }

    pub fn bitcast(&mut self, x: InstrId, dims: &[i64]) -> InstrId {
        let sx = self.shape_of(x);
        let out = Shape::new(sx.dtype, dims.to_vec());
        assert!(sx.same_elements(&out), "bitcast element mismatch: {sx} -> {out}");
        self.push("bitcast", Opcode::Bitcast, out, vec![x], Attrs::default())
    }

    pub fn transpose(&mut self, x: InstrId, perm: &[usize]) -> InstrId {
        let sx = self.shape_of(x);
        assert_eq!(perm.len(), sx.rank(), "perm rank mismatch");
        let mut sorted = perm.to_vec();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..sx.rank()).collect::<Vec<_>>(), "not a permutation: {perm:?}");
        let dims: Vec<i64> = perm.iter().map(|&p| sx.dims[p]).collect();
        let out = Shape::new(sx.dtype, dims);
        self.push(
            "transpose",
            Opcode::Transpose,
            out,
            vec![x],
            Attrs { transpose_perm: Some(perm.to_vec()), ..Default::default() },
        )
    }

    /// Broadcast `x` into `out_dims`; `bcast_dims[i]` is the output dim
    /// that input dim `i` maps to (XLA semantics).
    pub fn broadcast(&mut self, x: InstrId, out_dims: &[i64], bcast_dims: &[usize]) -> InstrId {
        let sx = self.shape_of(x);
        assert_eq!(bcast_dims.len(), sx.rank(), "broadcast_dims rank mismatch");
        for (i, &d) in bcast_dims.iter().enumerate() {
            assert!(d < out_dims.len());
            assert_eq!(sx.dims[i], out_dims[d], "broadcast dim size mismatch at {i}");
        }
        assert!(bcast_dims.windows(2).all(|w| w[0] < w[1]), "broadcast_dims must be sorted");
        let out = Shape::new(sx.dtype, out_dims.to_vec());
        self.push(
            "broadcast",
            Opcode::Broadcast,
            out,
            vec![x],
            Attrs { broadcast_dims: Some(bcast_dims.to_vec()), ..Default::default() },
        )
    }

    pub fn concat(&mut self, xs: &[InstrId], dim: usize) -> InstrId {
        assert!(!xs.is_empty());
        let first = self.shape_of(xs[0]).clone();
        let mut dims = first.dims.clone();
        let mut total = 0;
        for &x in xs {
            let sx = self.shape_of(x);
            assert_eq!(sx.rank(), first.rank());
            for (i, (&a, &b)) in sx.dims.iter().zip(&first.dims).enumerate() {
                if i != dim {
                    assert_eq!(a, b, "concat non-joined dim mismatch");
                }
            }
            total += sx.dims[dim];
        }
        dims[dim] = total;
        let out = Shape::new(first.dtype, dims);
        self.push(
            "concat",
            Opcode::Concatenate,
            out,
            xs.to_vec(),
            Attrs { concat_dim: Some(dim), ..Default::default() },
        )
    }

    pub fn slice(&mut self, x: InstrId, starts: &[i64], limits: &[i64]) -> InstrId {
        let sx = self.shape_of(x);
        assert_eq!(starts.len(), sx.rank());
        assert_eq!(limits.len(), sx.rank());
        let dims: Vec<i64> = starts
            .iter()
            .zip(limits)
            .zip(&sx.dims)
            .map(|((&s, &l), &d)| {
                assert!(0 <= s && s <= l && l <= d, "slice bounds out of range");
                l - s
            })
            .collect();
        let out = Shape::new(sx.dtype, dims);
        self.push(
            "slice",
            Opcode::Slice,
            out,
            vec![x],
            Attrs {
                slice_starts: Some(starts.to_vec()),
                slice_limits: Some(limits.to_vec()),
                ..Default::default()
            },
        )
    }

    // ---- reduce ----

    pub fn reduce(&mut self, x: InstrId, dims: &[usize], kind: ReduceKind) -> InstrId {
        let sx = self.shape_of(x);
        assert!(!dims.is_empty(), "reduce needs at least one dim");
        let mut sorted = dims.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), dims.len(), "duplicate reduce dims");
        assert!(*sorted.last().unwrap() < sx.rank(), "reduce dim out of range");
        let out_dims: Vec<i64> = sx
            .dims
            .iter()
            .enumerate()
            .filter(|(i, _)| !sorted.contains(i))
            .map(|(_, &d)| d)
            .collect();
        let out = Shape::new(sx.dtype, out_dims);
        self.push(
            "reduce",
            Opcode::Reduce,
            out,
            vec![x],
            Attrs { reduce_dims: Some(sorted), reduce_kind: Some(kind), ..Default::default() },
        )
    }

    // ---- contractions ----

    /// Batched matmul: `[..., m, k] x [..., k, n] -> [..., m, n]`.
    /// Fusable (§2.1) — kept inside the graph, unlike `dot`.
    pub fn batch_dot(&mut self, a: InstrId, b: InstrId) -> InstrId {
        let shape = self.contract_shape(a, b);
        self.push("batch_dot", Opcode::BatchDot, shape, vec![a, b], Attrs::default())
    }

    /// Library matmul (cuBLAS in the paper): an LC-layer delimiter.
    pub fn dot(&mut self, a: InstrId, b: InstrId) -> InstrId {
        let shape = self.contract_shape(a, b);
        self.push("dot", Opcode::Dot, shape, vec![a, b], Attrs::default())
    }

    fn contract_shape(&self, a: InstrId, b: InstrId) -> Shape {
        let sa = self.shape_of(a);
        let sb = self.shape_of(b);
        assert!(sa.rank() >= 2 && sb.rank() == sa.rank(), "contract rank mismatch: {sa} x {sb}");
        let r = sa.rank();
        assert_eq!(sa.dims[r - 1], sb.dims[r - 2], "contract inner dim mismatch: {sa} x {sb}");
        assert_eq!(sa.dims[..r - 2], sb.dims[..r - 2], "batch dims mismatch: {sa} x {sb}");
        let mut dims = sa.dims.clone();
        dims[r - 1] = sb.dims[r - 1];
        Shape::new(sa.dtype, dims)
    }

    /// Library convolution (cuDNN in the paper). NHWC input, HWIO filter,
    /// stride 1, SAME padding — enough fidelity for cost accounting.
    pub fn conv2d(&mut self, input: InstrId, filter: InstrId) -> InstrId {
        let si = self.shape_of(input);
        let sf = self.shape_of(filter);
        assert_eq!(si.rank(), 4, "conv2d input must be NHWC");
        assert_eq!(sf.rank(), 4, "conv2d filter must be HWIO");
        assert_eq!(si.dims[3], sf.dims[2], "conv2d channel mismatch");
        let out = Shape::new(si.dtype, vec![si.dims[0], si.dims[1], si.dims[2], sf.dims[3]]);
        self.push("conv2d", Opcode::Convolution, out, vec![input, filter], Attrs::default())
    }

    /// Opaque library call (e.g. a cuDNN RNN cell).
    pub fn custom_call(&mut self, target: &str, operands: &[InstrId], shape: Shape) -> InstrId {
        self.push(
            "custom_call",
            Opcode::CustomCall,
            shape,
            operands.to_vec(),
            Attrs { custom_call_target: Some(target.to_string()), ..Default::default() },
        )
    }

    // ---- finish ----

    pub fn finish(mut self, root: InstrId) -> Computation {
        self.comp.set_root(root);
        self.comp
    }

    /// Access the computation under construction (read-only), e.g. for
    /// shape queries inside model definitions.
    pub fn peek(&self) -> &Computation {
        &self.comp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_pattern_shapes() {
        // The Figure 3 motivating pattern (simplified): softmax over the
        // last dim of [B, S, S] followed by a batched dot with [B, S, D].
        let mut b = GraphBuilder::new("softmax_bmm");
        let scores = b.param("scores", Shape::f32(&[8, 64, 64]));
        let v = b.param("v", Shape::f32(&[8, 64, 32]));
        let m = b.reduce(scores, &[2], ReduceKind::Max);
        let mb = b.broadcast(m, &[8, 64, 64], &[0, 1]);
        let shifted = b.sub(scores, mb);
        let e = b.exp(shifted);
        let s = b.reduce(e, &[2], ReduceKind::Sum);
        let sb = b.broadcast(s, &[8, 64, 64], &[0, 1]);
        let p = b.div(e, sb);
        let out = b.batch_dot(p, v);
        let comp = b.finish(out);
        assert_eq!(comp.get(out).shape, Shape::f32(&[8, 64, 32]));
        assert_eq!(comp.get(m).shape, Shape::f32(&[8, 64]));
    }

    #[test]
    fn transpose_shape() {
        let mut b = GraphBuilder::new("t");
        let x = b.param("x", Shape::f32(&[2, 3, 4]));
        let t = b.transpose(x, &[2, 0, 1]);
        assert_eq!(b.peek().get(t).shape.dims, vec![4, 2, 3]);
    }

    #[test]
    fn reduce_removes_dims() {
        let mut b = GraphBuilder::new("r");
        let x = b.param("x", Shape::f32(&[2, 3, 4, 5]));
        let r = b.reduce(x, &[1, 3], ReduceKind::Sum);
        assert_eq!(b.peek().get(r).shape.dims, vec![2, 4]);
    }

    #[test]
    fn concat_shapes() {
        let mut b = GraphBuilder::new("c");
        let x = b.param("x", Shape::f32(&[2, 3]));
        let y = b.param("y", Shape::f32(&[2, 5]));
        let c = b.concat(&[x, y], 1);
        assert_eq!(b.peek().get(c).shape.dims, vec![2, 8]);
    }

    #[test]
    fn slice_shape() {
        let mut b = GraphBuilder::new("s");
        let x = b.param("x", Shape::f32(&[4, 6]));
        let s = b.slice(x, &[1, 2], &[3, 6]);
        assert_eq!(b.peek().get(s).shape.dims, vec![2, 4]);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn binary_shape_mismatch_panics() {
        let mut b = GraphBuilder::new("bad");
        let x = b.param("x", Shape::f32(&[2]));
        let y = b.param("y", Shape::f32(&[3]));
        b.add(x, y);
    }

    #[test]
    #[should_panic(expected = "inner dim mismatch")]
    fn dot_inner_mismatch_panics() {
        let mut b = GraphBuilder::new("bad");
        let x = b.param("x", Shape::f32(&[2, 3]));
        let y = b.param("y", Shape::f32(&[4, 2]));
        b.dot(x, y);
    }

    #[test]
    fn conv2d_shape() {
        let mut b = GraphBuilder::new("conv");
        let x = b.param("x", Shape::f32(&[8, 28, 28, 3]));
        let w = b.param("w", Shape::f32(&[3, 3, 3, 16]));
        let c = b.conv2d(x, w);
        assert_eq!(b.peek().get(c).shape.dims, vec![8, 28, 28, 16]);
    }
}
