//! An `HloModule`-like container: one entry computation plus metadata.

use super::computation::Computation;
use std::fmt;

/// A compilation unit. The paper's pipeline takes an `HloModule` as input
/// (Fig. 4); our `Module` wraps the entry computation and carries the
/// workload name used in reports.
#[derive(Debug, Clone)]
pub struct Module {
    pub name: String,
    pub entry: Computation,
}

impl Module {
    pub fn new(name: impl Into<String>, entry: Computation) -> Self {
        Module { name: name.into(), entry }
    }
}

impl fmt::Display for Module {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", crate::hlo::printer::print_module(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::builder::GraphBuilder;
    use crate::hlo::shape::Shape;

    #[test]
    fn module_holds_entry() {
        let mut b = GraphBuilder::new("entry");
        let x = b.param("x", Shape::f32(&[4]));
        let y = b.exp(x);
        let m = Module::new("test", b.finish(y));
        assert_eq!(m.name, "test");
        assert_eq!(m.entry.len(), 2);
    }
}
