//! The computation arena: an append-only SSA graph of instructions.
//!
//! Append-only construction gives us a free topological order (operands
//! always precede users), which every pass in the pipeline relies on.

use super::instruction::{Attrs, FrameId, Instruction};
use super::opcode::Opcode;
use super::shape::Shape;
use std::fmt;

/// Index of an instruction inside its [`Computation`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct InstrId(pub usize);

impl fmt::Display for InstrId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "#{}", self.0)
    }
}

/// A computation: a DAG of instructions with a designated root (output).
#[derive(Debug, Clone)]
pub struct Computation {
    pub name: String,
    instrs: Vec<Instruction>,
    /// users[i] = ids of instructions that consume instruction i.
    users: Vec<Vec<InstrId>>,
    root: Option<InstrId>,
}

impl Computation {
    pub fn new(name: impl Into<String>) -> Self {
        Computation { name: name.into(), instrs: Vec::new(), users: Vec::new(), root: None }
    }

    /// Append an instruction. Operand ids must already exist (this is what
    /// keeps instruction order topological). Returns the new id.
    pub fn add(
        &mut self,
        name: impl Into<String>,
        opcode: Opcode,
        shape: Shape,
        operands: Vec<InstrId>,
        attrs: Attrs,
        frame: FrameId,
    ) -> InstrId {
        let id = InstrId(self.instrs.len());
        for op in &operands {
            assert!(op.0 < id.0, "operand {op} does not precede {id} (append-only invariant)");
            self.users[op.0].push(id);
        }
        self.instrs.push(Instruction {
            id,
            name: name.into(),
            opcode,
            shape,
            operands,
            attrs,
            frame,
        });
        self.users.push(Vec::new());
        id
    }

    pub fn get(&self, id: InstrId) -> &Instruction {
        &self.instrs[id.0]
    }

    pub fn get_mut(&mut self, id: InstrId) -> &mut Instruction {
        &mut self.instrs[id.0]
    }

    /// Instructions that consume `id`'s value.
    pub fn users(&self, id: InstrId) -> &[InstrId] {
        &self.users[id.0]
    }

    pub fn set_root(&mut self, id: InstrId) {
        assert!(id.0 < self.instrs.len());
        self.root = Some(id);
    }

    pub fn root(&self) -> InstrId {
        self.root.expect("computation has no root")
    }

    pub fn has_root(&self) -> bool {
        self.root.is_some()
    }

    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// All ids in topological (construction) order.
    pub fn ids(&self) -> impl Iterator<Item = InstrId> + '_ {
        (0..self.instrs.len()).map(InstrId)
    }

    pub fn instructions(&self) -> impl Iterator<Item = &Instruction> {
        self.instrs.iter()
    }

    /// Shapes of `id`'s operands, in operand order.
    pub fn operand_shapes(&self, id: InstrId) -> Vec<&Shape> {
        self.get(id).operands.iter().map(|&o| &self.get(o).shape).collect()
    }

    /// Ids of instructions with no users (graph outputs). The root is
    /// always included even if it has users.
    pub fn outputs(&self) -> Vec<InstrId> {
        let mut outs: Vec<InstrId> =
            self.ids().filter(|&id| self.users(id).is_empty()).collect();
        if let Some(r) = self.root {
            if !outs.contains(&r) {
                outs.push(r);
            }
        }
        outs
    }

    /// Parameters in parameter-number order.
    pub fn parameters(&self) -> Vec<InstrId> {
        let mut params: Vec<InstrId> =
            self.ids().filter(|&id| self.get(id).opcode == Opcode::Parameter).collect();
        params.sort_by_key(|&id| self.get(id).attrs.parameter_number.unwrap_or(usize::MAX));
        params
    }

    /// Depth-first post-order from the root (operands before users),
    /// restricted to instructions reachable from the root.
    pub fn post_order_from_root(&self) -> Vec<InstrId> {
        let mut visited = vec![false; self.instrs.len()];
        let mut order = Vec::new();
        let mut stack = vec![(self.root(), false)];
        while let Some((id, expanded)) = stack.pop() {
            if expanded {
                order.push(id);
                continue;
            }
            if visited[id.0] {
                continue;
            }
            visited[id.0] = true;
            stack.push((id, true));
            for &op in &self.get(id).operands {
                if !visited[op.0] {
                    stack.push((op, false));
                }
            }
        }
        order
    }

    /// True if `a` transitively depends on `b` (i.e. `b` is reachable from
    /// `a` through operand edges).
    pub fn depends_on(&self, a: InstrId, b: InstrId) -> bool {
        if a == b {
            return true;
        }
        // operands always have smaller ids, so walk down only.
        let mut seen = vec![false; a.0 + 1];
        let mut stack = vec![a];
        while let Some(id) = stack.pop() {
            if id == b {
                return true;
            }
            for &op in &self.get(id).operands {
                if op.0 >= b.0 && !seen[op.0] {
                    seen[op.0] = true;
                    stack.push(op);
                }
            }
        }
        false
    }

    /// Number of GPU kernels this computation launches *before any fusion*:
    /// every non-free instruction is one kernel (the paper's fine-granularity
    /// problem, §1).
    pub fn unfused_kernel_count(&self) -> usize {
        self.instructions().filter(|i| !i.opcode.is_free()).count()
    }
}

impl fmt::Display for Computation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{} {{", self.name)?;
        for instr in &self.instrs {
            writeln!(f, "  {instr}")?;
        }
        writeln!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::shape::DType;

    fn simple() -> Computation {
        // p0, p1 -> add -> exp (root)
        let mut c = Computation::new("t");
        let s = Shape::f32(&[4]);
        let p0 = c.add("p0", Opcode::Parameter, s.clone(), vec![], Attrs::default(), 0);
        let p1 = c.add("p1", Opcode::Parameter, s.clone(), vec![], Attrs::default(), 0);
        let add = c.add("add", Opcode::Add, s.clone(), vec![p0, p1], Attrs::default(), 0);
        let exp = c.add("exp", Opcode::Exp, s, vec![add], Attrs::default(), 0);
        c.set_root(exp);
        c
    }

    #[test]
    fn users_maintained() {
        let c = simple();
        assert_eq!(c.users(InstrId(0)), &[InstrId(2)]);
        assert_eq!(c.users(InstrId(2)), &[InstrId(3)]);
        assert!(c.users(InstrId(3)).is_empty());
    }

    #[test]
    fn outputs_and_params() {
        let c = simple();
        assert_eq!(c.outputs(), vec![InstrId(3)]);
        assert_eq!(c.parameters().len(), 2);
    }

    #[test]
    fn post_order_operands_first() {
        let c = simple();
        let order = c.post_order_from_root();
        let pos = |id: InstrId| order.iter().position(|&x| x == id).unwrap();
        assert!(pos(InstrId(0)) < pos(InstrId(2)));
        assert!(pos(InstrId(2)) < pos(InstrId(3)));
        assert_eq!(order.len(), 4);
    }

    #[test]
    fn depends_on() {
        let c = simple();
        assert!(c.depends_on(InstrId(3), InstrId(0)));
        assert!(c.depends_on(InstrId(3), InstrId(3)));
        assert!(!c.depends_on(InstrId(0), InstrId(3)));
        assert!(!c.depends_on(InstrId(0), InstrId(1)));
    }

    #[test]
    #[should_panic(expected = "append-only")]
    fn forward_reference_panics() {
        let mut c = Computation::new("bad");
        c.add(
            "x",
            Opcode::Exp,
            Shape::scalar(DType::F32),
            vec![InstrId(5)],
            Attrs::default(),
            0,
        );
    }

    #[test]
    fn unfused_kernel_count_excludes_free_ops() {
        let c = simple();
        // add + exp are kernels; parameters are free.
        assert_eq!(c.unfused_kernel_count(), 2);
    }
}
