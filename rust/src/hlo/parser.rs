//! Parser for the textual form produced by [`super::printer`].
//!
//! Used by the CLI (`fusion-stitching compile <file>`) and round-trip
//! tests. The grammar is deliberately small; see the printer docs.

use super::computation::{Computation, InstrId};
use super::instruction::{Attrs, ReduceKind};
use super::module::Module;
use super::printer::keyword_opcode;
use super::shape::{DType, Shape};
use anyhow::{anyhow, bail, Result};

/// Parse a module from its textual form.
pub fn parse_module(text: &str) -> Result<Module> {
    let mut lines = text.lines().map(str::trim).filter(|l| !l.is_empty() && !l.starts_with("//"));
    let header = lines.next().ok_or_else(|| anyhow!("empty module text"))?;
    let name = header
        .strip_prefix("module ")
        .and_then(|r| r.strip_suffix('{'))
        .map(str::trim)
        .ok_or_else(|| anyhow!("bad module header: {header}"))?;

    let mut comp = Computation::new("entry");
    let mut root: Option<InstrId> = None;
    for line in lines {
        if line == "entry {" || line == "}" {
            continue;
        }
        if let Some(r) = line.strip_prefix("root %") {
            root = Some(InstrId(r.trim().parse()?));
            continue;
        }
        parse_instruction(line, &mut comp)?;
    }
    let root = root.ok_or_else(|| anyhow!("module has no root"))?;
    comp.set_root(root);
    Ok(Module::new(name, comp))
}

fn parse_instruction(line: &str, comp: &mut Computation) -> Result<()> {
    // %<id> = <shape> <opcode>(<operands>) {<attrs>}
    let (lhs, rhs) = line.split_once('=').ok_or_else(|| anyhow!("no '=' in: {line}"))?;
    let id: usize = lhs.trim().strip_prefix('%').ok_or_else(|| anyhow!("bad lhs: {lhs}"))?.parse()?;
    if id != comp.len() {
        bail!("instruction ids must be dense and in order (got %{id}, expected %{})", comp.len());
    }
    let rhs = rhs.trim();
    let (shape_str, rest) = rhs.split_once(' ').ok_or_else(|| anyhow!("bad rhs: {rhs}"))?;
    let shape = parse_shape(shape_str)?;

    let open = rest.find('(').ok_or_else(|| anyhow!("no operand list in: {rest}"))?;
    let close = rest.find(')').ok_or_else(|| anyhow!("unclosed operand list in: {rest}"))?;
    let opcode = keyword_opcode(rest[..open].trim())
        .ok_or_else(|| anyhow!("unknown opcode: {}", &rest[..open]))?;
    let operands: Vec<InstrId> = rest[open + 1..close]
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| -> Result<InstrId> {
            Ok(InstrId(s.strip_prefix('%').ok_or_else(|| anyhow!("bad operand {s}"))?.parse()?))
        })
        .collect::<Result<_>>()?;

    let mut attrs = Attrs::default();
    let mut frame = 0;
    let mut name = format!("i{id}");
    if let Some(abrace) = rest[close..].find('{') {
        let astr = &rest[close + abrace + 1..rest.rfind('}').unwrap_or(rest.len())];
        for kv in split_attrs(astr) {
            let (k, v) = kv.split_once('=').ok_or_else(|| anyhow!("bad attr: {kv}"))?;
            let (k, v) = (k.trim(), v.trim());
            match k {
                "num" => attrs.parameter_number = Some(v.parse()?),
                "perm" => attrs.transpose_perm = Some(parse_usize_list(v)?),
                "dims" => attrs.reduce_dims = Some(parse_usize_list(v)?),
                "kind" => attrs.reduce_kind = Some(parse_reduce_kind(v)?),
                "bdims" => attrs.broadcast_dims = Some(parse_usize_list(v)?),
                "cdim" => attrs.concat_dim = Some(v.parse()?),
                "starts" => attrs.slice_starts = Some(parse_i64_list(v)?),
                "limits" => attrs.slice_limits = Some(parse_i64_list(v)?),
                "target" => attrs.custom_call_target = Some(v.trim_matches('"').to_string()),
                "frame" => frame = v.parse()?,
                "name" => name = v.to_string(),
                "idx" => attrs.tuple_index = Some(v.parse()?),
                other => bail!("unknown attr key: {other}"),
            }
        }
    }
    comp.add(name, opcode, shape, operands, attrs, frame);
    Ok(())
}

fn split_attrs(s: &str) -> Vec<&str> {
    // split on commas that are not inside [...] brackets
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, ch) in s.char_indices() {
        match ch {
            '[' => depth += 1,
            ']' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                out.push(s[start..i].trim());
                start = i + 1;
            }
            _ => {}
        }
    }
    let last = s[start..].trim();
    if !last.is_empty() {
        out.push(last);
    }
    out
}

pub fn parse_shape(s: &str) -> Result<Shape> {
    let open = s.find('[').ok_or_else(|| anyhow!("bad shape: {s}"))?;
    let close = s.find(']').ok_or_else(|| anyhow!("bad shape: {s}"))?;
    let dtype = match &s[..open] {
        "pred" => DType::Pred,
        "s32" => DType::S32,
        "s64" => DType::S64,
        "f16" => DType::F16,
        "bf16" => DType::BF16,
        "f32" => DType::F32,
        "f64" => DType::F64,
        other => bail!("unknown dtype: {other}"),
    };
    let dims: Vec<i64> = s[open + 1..close]
        .split(',')
        .map(str::trim)
        .filter(|d| !d.is_empty())
        .map(|d| d.parse::<i64>().map_err(Into::into))
        .collect::<Result<_>>()?;
    Ok(Shape::new(dtype, dims))
}

fn parse_usize_list(s: &str) -> Result<Vec<usize>> {
    s.trim_matches(['[', ']'])
        .split(',')
        .map(str::trim)
        .filter(|x| !x.is_empty())
        .map(|x| x.parse::<usize>().map_err(Into::into))
        .collect()
}

fn parse_i64_list(s: &str) -> Result<Vec<i64>> {
    s.trim_matches(['[', ']'])
        .split(',')
        .map(str::trim)
        .filter(|x| !x.is_empty())
        .map(|x| x.parse::<i64>().map_err(Into::into))
        .collect()
}

fn parse_reduce_kind(s: &str) -> Result<ReduceKind> {
    Ok(match s {
        "Sum" => ReduceKind::Sum,
        "Max" => ReduceKind::Max,
        "Min" => ReduceKind::Min,
        "Mean" => ReduceKind::Mean,
        "Prod" => ReduceKind::Prod,
        other => bail!("unknown reduce kind: {other}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::builder::GraphBuilder;
    use crate::hlo::printer::print_module;

    #[test]
    fn shape_parse() {
        assert_eq!(parse_shape("f32[2,3]").unwrap(), Shape::f32(&[2, 3]));
        assert_eq!(parse_shape("pred[]").unwrap(), Shape::scalar(DType::Pred));
        assert!(parse_shape("zzz[2]").is_err());
    }

    #[test]
    fn roundtrip_softmax_pattern() {
        let mut b = GraphBuilder::new("rt");
        let scores = b.param("scores", Shape::f32(&[8, 64, 64]));
        let v = b.param("v", Shape::f32(&[8, 64, 32]));
        let m = b.reduce(scores, &[2], ReduceKind::Max);
        let mb = b.broadcast(m, &[8, 64, 64], &[0, 1]);
        let sh = b.sub(scores, mb);
        let e = b.exp(sh);
        let s = b.reduce(e, &[2], ReduceKind::Sum);
        let sb = b.broadcast(s, &[8, 64, 64], &[0, 1]);
        let p = b.div(e, sb);
        let t = b.transpose(p, &[0, 2, 1]);
        let sl = b.slice(t, &[0, 0, 0], &[8, 32, 64]);
        let cc = b.concat(&[sl, sl], 1);
        let out = b.batch_dot(p, v);
        let _ = (cc, out);
        let module = Module::new("rt", b.finish(out));
        let text = print_module(&module);
        let parsed = parse_module(&text).unwrap();
        assert_eq!(parsed.entry.len(), module.entry.len());
        for id in module.entry.ids() {
            let a = module.entry.get(id);
            let b2 = parsed.entry.get(id);
            assert_eq!(a.opcode, b2.opcode, "opcode mismatch at {id}");
            assert_eq!(a.shape, b2.shape, "shape mismatch at {id}");
            assert_eq!(a.operands, b2.operands, "operands mismatch at {id}");
            assert_eq!(a.attrs, b2.attrs, "attrs mismatch at {id}");
        }
        assert_eq!(parsed.entry.root(), module.entry.root());
    }

    #[test]
    fn rejects_out_of_order_ids() {
        let text = "module m {\nentry {\n%1 = f32[2] parameter(0) {num=0}\nroot %1\n}\n}";
        assert!(parse_module(text).is_err());
    }

    #[test]
    fn rejects_missing_root() {
        let text = "module m {\nentry {\n%0 = f32[2] parameter() {num=0}\n}\n}";
        assert!(parse_module(text).is_err());
    }
}
