//! While-loop frame contexts — §3.1's preprocessing step.
//!
//! Practical Tensorflow graphs contain large, possibly nested while
//! loops, which break standard Work/Span analysis (it assumes a DAG).
//! The paper partitions all nodes into subgraphs, one per frame context,
//! and analyses each independently. Our IR carries the frame as an
//! instruction tag (assigned by the graph builder / frontend); this
//! module derives the partition and its nesting structure.

use crate::hlo::{Computation, InstrId};
use std::collections::BTreeMap;

/// The frame partition of a computation.
#[derive(Debug, Clone)]
pub struct FramePartition {
    /// frame → member instruction ids (id order).
    members: BTreeMap<u32, Vec<InstrId>>,
    /// frame → parent frame, for nested loops. A frame's parent is the
    /// frame of the first external producer feeding into it (frames are
    /// entered from their enclosing context); top-level frames have no
    /// parent.
    parent: BTreeMap<u32, Option<u32>>,
}

impl FramePartition {
    pub fn build(comp: &Computation) -> FramePartition {
        let mut members: BTreeMap<u32, Vec<InstrId>> = BTreeMap::new();
        for id in comp.ids() {
            members.entry(comp.get(id).frame).or_default().push(id);
        }
        let mut parent: BTreeMap<u32, Option<u32>> = BTreeMap::new();
        for (&frame, ids) in &members {
            // Frame 0 is by definition the top-level graph.
            if frame == 0 {
                parent.insert(0, None);
                continue;
            }
            let mut p = None;
            'outer: for &id in ids {
                for &op in &comp.get(id).operands {
                    let of = comp.get(op).frame;
                    if of != frame {
                        p = Some(of);
                        break 'outer;
                    }
                }
            }
            parent.insert(frame, p);
        }
        FramePartition { members, parent }
    }

    pub fn frames(&self) -> Vec<u32> {
        self.members.keys().copied().collect()
    }

    pub fn members(&self, frame: u32) -> &[InstrId] {
        self.members.get(&frame).map(Vec::as_slice).unwrap_or(&[])
    }

    pub fn parent(&self, frame: u32) -> Option<u32> {
        self.parent.get(&frame).copied().flatten()
    }

    /// Number of frame contexts.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Instructions whose operands cross into this frame from another —
    /// the frame's entry values (loop-carried inputs).
    pub fn entries(&self, comp: &Computation, frame: u32) -> Vec<InstrId> {
        self.members(frame)
            .iter()
            .copied()
            .filter(|&id| {
                comp.get(id).operands.iter().any(|&op| comp.get(op).frame != frame)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::{GraphBuilder, Shape};

    fn nested() -> Computation {
        let mut b = GraphBuilder::new("nested");
        let x = b.param("x", Shape::f32(&[8]));
        let e = b.exp(x); // frame 0
        b.set_frame(1); // outer while body
        let t = b.tanh(e);
        b.set_frame(2); // inner while body
        let s = b.sigmoid(t);
        let s2 = b.sqrt(s);
        b.set_frame(1);
        let m = b.neg(s2);
        b.set_frame(0);
        let out = b.copy(m);
        b.finish(out)
    }

    #[test]
    fn partition_members() {
        let c = nested();
        let fp = FramePartition::build(&c);
        assert_eq!(fp.frames(), vec![0, 1, 2]);
        assert_eq!(fp.members(0).len(), 3); // param, exp, copy
        assert_eq!(fp.members(1).len(), 2); // tanh, neg
        assert_eq!(fp.members(2).len(), 2); // sigmoid, sqrt
    }

    #[test]
    fn nesting_parents() {
        let c = nested();
        let fp = FramePartition::build(&c);
        assert_eq!(fp.parent(0), None);
        assert_eq!(fp.parent(1), Some(0));
        assert_eq!(fp.parent(2), Some(1));
    }

    #[test]
    fn frame_entries() {
        let c = nested();
        let fp = FramePartition::build(&c);
        let e1 = fp.entries(&c, 1);
        assert_eq!(e1.len(), 2); // tanh consumes frame-0 exp; neg consumes frame-2 sqrt
    }

    #[test]
    fn single_frame_graph() {
        let mut b = GraphBuilder::new("flat");
        let x = b.param("x", Shape::f32(&[4]));
        let y = b.exp(x);
        let c = b.finish(y);
        let fp = FramePartition::build(&c);
        assert_eq!(fp.len(), 1);
        assert_eq!(fp.parent(0), None);
    }
}
