//! Graph analyses feeding fusion, scheduling and codegen.
//!
//! - [`span`] — Work/Span (critical path) analysis (§3.1).
//! - [`frames`] — while-loop frame-context partitioning (§3.1).
//! - [`dominance`] — dominance tree for shared-memory space sharing (§5.1.3).
//! - [`footprint`] — memory IO footprint accounting (Fig. 1, fusion
//!   thresholds).

pub mod dominance;
pub mod footprint;
pub mod frames;
pub mod span;

pub use dominance::DominatorTree;
pub use footprint::{group_footprint_bytes, instr_footprint_elements};
pub use frames::FramePartition;
pub use span::SpanAnalysis;
