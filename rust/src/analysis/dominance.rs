//! Dominance tree over the dataflow graph — §5.1.3 (space sharing).
//!
//! The paper builds a dominance tree *starting from the root instruction*
//! and walks it with a dataflow analysis to let later ops reuse shared
//! memory buffers of ops they dominate (e.g. `Reduce.2` reuses
//! `Reduce.1`'s buffer in Figure 3).
//!
//! We treat the fused computation as a flow graph rooted at the fusion
//! root with edges root → operands; `a` dominates `b` iff every
//! root-to-`b` path passes through `a`. Classic Cooper–Harvey–Kennedy
//! iterative algorithm over the reverse post-order.

use crate::hlo::{Computation, InstrId};
use std::collections::{HashMap, HashSet};

/// Immediate-dominator tree for a (sub)graph of a computation.
#[derive(Debug, Clone)]
pub struct DominatorTree {
    root: InstrId,
    /// node → immediate dominator. The root maps to itself.
    idom: HashMap<InstrId, InstrId>,
    /// reverse post-order position used during construction.
    rpo_pos: HashMap<InstrId, usize>,
}

impl DominatorTree {
    /// Build the tree for the subgraph reachable from `root` through
    /// operand edges, optionally restricted to `scope` (a fusion group).
    /// Operands outside the scope are treated as external leaves and
    /// excluded.
    pub fn build(comp: &Computation, root: InstrId, scope: Option<&HashSet<InstrId>>) -> Self {
        let in_scope =
            |id: InstrId| scope.map(|s| s.contains(&id)).unwrap_or(true);
        assert!(in_scope(root), "root must be in scope");

        // DFS for reverse post-order from root via operand edges.
        let mut post: Vec<InstrId> = Vec::new();
        let mut seen: HashSet<InstrId> = HashSet::new();
        let mut stack = vec![(root, false)];
        while let Some((id, expanded)) = stack.pop() {
            if expanded {
                post.push(id);
                continue;
            }
            if !seen.insert(id) {
                continue;
            }
            stack.push((id, true));
            for &op in &comp.get(id).operands {
                if in_scope(op) && !seen.contains(&op) {
                    stack.push((op, false));
                }
            }
        }
        post.reverse(); // now RPO: root first
        let rpo_pos: HashMap<InstrId, usize> =
            post.iter().enumerate().map(|(i, &id)| (id, i)).collect();

        // Predecessors in the flow graph = users (within scope & reachable).
        let preds = |id: InstrId| -> Vec<InstrId> {
            comp.users(id)
                .iter()
                .copied()
                .filter(|u| rpo_pos.contains_key(u))
                .collect()
        };

        let mut idom: HashMap<InstrId, InstrId> = HashMap::new();
        idom.insert(root, root);
        let mut changed = true;
        while changed {
            changed = false;
            for &b in post.iter().skip(1) {
                let mut new_idom: Option<InstrId> = None;
                for p in preds(b) {
                    if idom.contains_key(&p) {
                        new_idom = Some(match new_idom {
                            None => p,
                            Some(cur) => intersect(&idom, &rpo_pos, p, cur),
                        });
                    }
                }
                if let Some(ni) = new_idom {
                    if idom.get(&b) != Some(&ni) {
                        idom.insert(b, ni);
                        changed = true;
                    }
                }
            }
        }

        DominatorTree { root, idom, rpo_pos }
    }

    pub fn root(&self) -> InstrId {
        self.root
    }

    /// Immediate dominator of `id` (`None` for the root or unreachable
    /// nodes).
    pub fn idom(&self, id: InstrId) -> Option<InstrId> {
        if id == self.root {
            return None;
        }
        self.idom.get(&id).copied()
    }

    /// Whether `a` dominates `b` (reflexive).
    pub fn dominates(&self, a: InstrId, b: InstrId) -> bool {
        let mut cur = b;
        loop {
            if cur == a {
                return true;
            }
            match self.idom(cur) {
                Some(d) if d != cur => cur = d,
                _ => return false,
            }
        }
    }

    /// Nodes covered by the tree (reachable from root within scope).
    pub fn nodes(&self) -> impl Iterator<Item = InstrId> + '_ {
        self.rpo_pos.keys().copied()
    }
}

fn intersect(
    idom: &HashMap<InstrId, InstrId>,
    rpo: &HashMap<InstrId, usize>,
    mut a: InstrId,
    mut b: InstrId,
) -> InstrId {
    while a != b {
        while rpo[&a] > rpo[&b] {
            a = idom[&a];
        }
        while rpo[&b] > rpo[&a] {
            b = idom[&b];
        }
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::{GraphBuilder, Shape};

    /// The Figure 3 sharing relations: in softmax, `divide` dominates
    /// `exponential` (every root path to exp goes through div), and the
    /// second reduce dominates the first.
    #[test]
    fn figure3_dominance() {
        let mut b = GraphBuilder::new("fig3");
        let scores = b.param("scores", Shape::f32(&[8, 64, 64]));
        let v = b.param("v", Shape::f32(&[8, 64, 32]));
        let m = b.reduce(scores, &[2], ReduceKind::Max); // Reduce.1
        let mb = b.broadcast(m, &[8, 64, 64], &[0, 1]);
        let sh = b.sub(scores, mb);
        let e = b.exp(sh); // Exponential.1
        let s = b.reduce(e, &[2], ReduceKind::Sum); // Reduce.2
        let sb = b.broadcast(s, &[8, 64, 64], &[0, 1]);
        let p = b.div(e, sb); // Divide.1
        let out = b.batch_dot(p, v);
        let comp = b.finish(out);

        let dt = DominatorTree::build(&comp, out, None);
        assert!(dt.dominates(p, e), "Divide.1 should dominate Exponential.1");
        assert!(dt.dominates(out, p));
        assert!(!dt.dominates(s, e), "exp also reaches root via divide directly");
        assert!(!dt.dominates(e, p));
        // In the *stable* softmax (max-subtraction), the subtract path
        // bypasses Reduce.2, so unlike the paper's Figure 3 sketch the
        // sum-reduce does not dominate the max-reduce; its broadcast does.
        assert!(!dt.dominates(s, m));
        assert!(dt.dominates(mb, m));
    }

    #[test]
    fn chain_dominance_is_total() {
        let mut b = GraphBuilder::new("chain");
        let x = b.param("x", Shape::f32(&[4]));
        let a = b.exp(x);
        let c = b.tanh(a);
        let d = b.neg(c);
        let comp = b.finish(d);
        let dt = DominatorTree::build(&comp, d, None);
        assert!(dt.dominates(d, x));
        assert!(dt.dominates(c, a));
        assert_eq!(dt.idom(a), Some(c));
        assert_eq!(dt.idom(d), None);
    }

    #[test]
    fn diamond_joins_at_root() {
        // root = a + b, both consume x: neither a nor b dominates x.
        let mut b = GraphBuilder::new("diamond");
        let x = b.param("x", Shape::f32(&[4]));
        let l = b.exp(x);
        let r = b.tanh(x);
        let sum = b.add(l, r);
        let comp = b.finish(sum);
        let dt = DominatorTree::build(&comp, sum, None);
        assert!(!dt.dominates(l, x));
        assert!(!dt.dominates(r, x));
        assert_eq!(dt.idom(x), Some(sum));
    }

    #[test]
    fn scoped_build_excludes_external() {
        let mut b = GraphBuilder::new("scoped");
        let x = b.param("x", Shape::f32(&[4]));
        let e = b.exp(x);
        let t = b.tanh(e);
        let comp = b.finish(t);
        let scope: HashSet<InstrId> = [e, t].into_iter().collect();
        let dt = DominatorTree::build(&comp, t, Some(&scope));
        let nodes: Vec<InstrId> = dt.nodes().collect();
        assert!(nodes.contains(&e) && nodes.contains(&t));
        assert!(!nodes.contains(&x));
    }
}
