//! Memory IO footprint accounting.
//!
//! Two consumers:
//! - Figure 1 regenerates the footprint percentile distribution over a
//!   model corpus using [`instr_footprint_elements`] (the paper measures
//!   "memory IO footprint size in number of floats").
//! - The fusion pass bounds fused-kernel size with
//!   [`group_footprint_bytes`] (§3.2: "the other factor is the fused
//!   memory footprint", controlled by a tunable threshold).

use crate::hlo::{Computation, InstrId};
use std::collections::HashSet;

/// IO footprint of one instruction in elements: output + all operands.
pub fn instr_footprint_elements(comp: &Computation, id: InstrId) -> i64 {
    let i = comp.get(id);
    i.shape.num_elements()
        + i.operands.iter().map(|&o| comp.get(o).shape.num_elements()).sum::<i64>()
}

/// IO footprint of a *fused group* in bytes: bytes flowing across the
/// kernel boundary — external operands read plus outputs written
/// (values consumed outside the group or being group roots). Internal
/// intermediates stay in registers/shared memory and do not count; this
/// is exactly the footprint reduction fusion buys (§4.1 objective (1)).
pub fn group_footprint_bytes(comp: &Computation, members: &HashSet<InstrId>) -> usize {
    let mut inputs: HashSet<InstrId> = HashSet::new();
    let mut output_bytes = 0usize;
    for &id in members {
        let instr = comp.get(id);
        for &op in &instr.operands {
            if !members.contains(&op) {
                inputs.insert(op);
            }
        }
        let escapes = comp.users(id).iter().any(|u| !members.contains(u))
            || comp.users(id).is_empty();
        if escapes {
            output_bytes += instr.shape.byte_size();
        }
    }
    let input_bytes: usize = inputs.iter().map(|&i| comp.get(i).shape.byte_size()).sum();
    input_bytes + output_bytes
}

/// Number of outputs a fused group exposes (multi-output fusion control).
pub fn group_output_count(comp: &Computation, members: &HashSet<InstrId>) -> usize {
    members
        .iter()
        .filter(|&&id| {
            comp.users(id).iter().any(|u| !members.contains(u)) || comp.users(id).is_empty()
        })
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::{GraphBuilder, Shape};

    #[test]
    fn instr_footprint() {
        let mut b = GraphBuilder::new("f");
        let x = b.param("x", Shape::f32(&[100]));
        let y = b.param("y", Shape::f32(&[100]));
        let s = b.add(x, y);
        let comp = b.finish(s);
        assert_eq!(instr_footprint_elements(&comp, s), 300);
        assert_eq!(instr_footprint_elements(&comp, x), 100);
    }

    #[test]
    fn fused_group_footprint_smaller_than_sum() {
        // x -> exp -> tanh -> out: fusing exp+tanh removes the
        // intermediate from the footprint.
        let mut b = GraphBuilder::new("g");
        let x = b.param("x", Shape::f32(&[256]));
        let e = b.exp(x);
        let t = b.tanh(e);
        let comp = b.finish(t);
        let members: HashSet<InstrId> = [e, t].into_iter().collect();
        let fused = group_footprint_bytes(&comp, &members);
        // unfused: exp reads 256 writes 256; tanh reads 256 writes 256 = 4096 B
        // fused: read x (1024 B) + write t (1024 B) = 2048 B
        assert_eq!(fused, 2048);
    }

    #[test]
    fn multi_output_group() {
        let mut b = GraphBuilder::new("g");
        let x = b.param("x", Shape::f32(&[8]));
        let e = b.exp(x);
        let t = b.tanh(e); // escapes (root)
        let s = b.sigmoid(e); // dead-end => also an output
        let _ = s;
        let comp = b.finish(t);
        let members: HashSet<InstrId> = [e, t, s].into_iter().collect();
        assert_eq!(group_output_count(&comp, &members), 2);
        // inputs: x (32 B); outputs: t + s (64 B)
        assert_eq!(group_footprint_bytes(&comp, &members), 96);
    }
}
