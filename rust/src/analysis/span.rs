//! Work/Span (critical path) analysis — §3.1 of the paper.
//!
//! Each instruction gets a *span*: the root has span 0; any other
//! instruction's span is `max(span of its users) + 1`. Instructions with
//! the same span form a *layer* with no data dependences among them
//! (Figure 3's circled numbers). The maximum span is the critical path
//! length.
//!
//! Spans are computed **per frame context** (see [`super::frames`]):
//! standard Work/Span assumes an acyclic graph, and practical TF graphs
//! contain (nested) while loops, so each frame is analysed independently.
//! Edges that cross frames are ignored for span purposes, mirroring the
//! paper's preprocessing step.

use crate::hlo::{Computation, InstrId};
use std::collections::BTreeMap;

/// Result of Work/Span analysis over one computation.
#[derive(Debug, Clone)]
pub struct SpanAnalysis {
    /// span[i] — layer number of instruction `i` within its frame.
    span: Vec<u32>,
    /// (frame, span) → instruction ids, each list in id order.
    layers: BTreeMap<(u32, u32), Vec<InstrId>>,
    /// frame → critical path length (max span in the frame).
    critical_path: BTreeMap<u32, u32>,
    /// total work: sum over non-free instructions of output elements.
    work_elements: i64,
}

impl SpanAnalysis {
    /// Run the analysis. Sinks (instructions with no same-frame users)
    /// anchor span 0 of their frame, which makes the root span 0 per the
    /// paper and handles multi-output graphs gracefully.
    pub fn run(comp: &Computation) -> SpanAnalysis {
        let n = comp.len();
        let mut span = vec![0u32; n];
        // Instructions are stored topologically (operands first), so a
        // reverse scan sees every user before its producer.
        for idx in (0..n).rev() {
            let id = InstrId(idx);
            let frame = comp.get(id).frame;
            let mut s = 0u32;
            for &u in comp.users(id) {
                if comp.get(u).frame == frame {
                    s = s.max(span[u.0] + 1);
                }
            }
            span[idx] = s;
        }

        let mut layers: BTreeMap<(u32, u32), Vec<InstrId>> = BTreeMap::new();
        let mut critical_path: BTreeMap<u32, u32> = BTreeMap::new();
        for id in comp.ids() {
            let frame = comp.get(id).frame;
            layers.entry((frame, span[id.0])).or_default().push(id);
            let e = critical_path.entry(frame).or_insert(0);
            *e = (*e).max(span[id.0]);
        }

        let work_elements = comp
            .instructions()
            .filter(|i| !i.opcode.is_free())
            .map(|i| i.shape.num_elements())
            .sum();

        SpanAnalysis { span, layers, critical_path, work_elements }
    }

    pub fn span_of(&self, id: InstrId) -> u32 {
        self.span[id.0]
    }

    /// Instructions in layer `(frame, span)`, in id order. Empty slice if
    /// the layer does not exist.
    pub fn layer(&self, frame: u32, span: u32) -> &[InstrId] {
        self.layers.get(&(frame, span)).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Critical path length of `frame` (0 if frame absent).
    pub fn critical_path(&self, frame: u32) -> u32 {
        self.critical_path.get(&frame).copied().unwrap_or(0)
    }

    /// All frames present, ascending.
    pub fn frames(&self) -> Vec<u32> {
        self.critical_path.keys().copied().collect()
    }

    /// Total parallel work in elements (the "Work" half of Work/Span).
    pub fn work_elements(&self) -> i64 {
        self.work_elements
    }

    /// Spans within `frame` that contain at least one library call — the
    /// LC-layers delimiting fusable regions (§3.2), ascending.
    pub fn lc_layers(&self, comp: &Computation, frame: u32) -> Vec<u32> {
        let mut out = Vec::new();
        for (&(f, s), ids) in &self.layers {
            if f == frame && ids.iter().any(|&id| comp.get(id).opcode.is_library_call()) {
                out.push(s);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::{GraphBuilder, Shape};

    /// Reproduce the Figure 3 layering property: ops on the same layer
    /// have no data dependences, root has span 0.
    #[test]
    fn figure3_like_spans() {
        let mut b = GraphBuilder::new("fig3");
        let scores = b.param("scores", Shape::f32(&[8, 64, 64]));
        let v = b.param("v", Shape::f32(&[8, 64, 32]));
        let m = b.reduce(scores, &[2], ReduceKind::Max);
        let mb = b.broadcast(m, &[8, 64, 64], &[0, 1]);
        let sh = b.sub(scores, mb);
        let e = b.exp(sh);
        let s = b.reduce(e, &[2], ReduceKind::Sum);
        let sb = b.broadcast(s, &[8, 64, 64], &[0, 1]);
        let p = b.div(e, sb);
        let out = b.batch_dot(p, v);
        let comp = b.finish(out);
        let sa = SpanAnalysis::run(&comp);

        assert_eq!(sa.span_of(out), 0);
        assert_eq!(sa.span_of(p), 1);
        assert_eq!(sa.span_of(sb), 2);
        assert_eq!(sa.span_of(s), 3);
        // exp feeds both div (span 1) and sum-reduce (span 3): span = 4
        assert_eq!(sa.span_of(e), 4);
        assert_eq!(sa.span_of(sh), 5);
        assert_eq!(sa.span_of(mb), 6);
        assert_eq!(sa.span_of(m), 7);
        assert_eq!(sa.span_of(scores), 8);
        // v is consumed only by the root dot
        assert_eq!(sa.span_of(v), 1);
        assert_eq!(sa.critical_path(0), 8);
    }

    #[test]
    fn same_layer_has_no_dependences() {
        let mut b = GraphBuilder::new("layers");
        let x = b.param("x", Shape::f32(&[16]));
        let y = b.param("y", Shape::f32(&[16]));
        let e1 = b.exp(x);
        let e2 = b.tanh(y);
        let sum = b.add(e1, e2);
        let comp = b.finish(sum);
        let sa = SpanAnalysis::run(&comp);
        assert_eq!(sa.span_of(e1), sa.span_of(e2));
        for (frame, span) in sa.layers.keys() {
            let ids = sa.layer(*frame, *span);
            for &a in ids {
                for &bb in ids {
                    if a != bb {
                        assert!(!comp.get(a).operands.contains(&bb));
                    }
                }
            }
        }
    }

    #[test]
    fn frames_analysed_independently() {
        let mut b = GraphBuilder::new("frames");
        let x = b.param("x", Shape::f32(&[16]));
        let e = b.exp(x);
        b.set_frame(1);
        let t = b.tanh(e); // crosses into frame 1
        let u = b.sigmoid(t);
        b.set_frame(0);
        // bring `u` back via a same-shape op in frame 0
        let u0 = b.copy(u);
        let out = b.add(e, u0);
        let comp = b.finish(out);
        let sa = SpanAnalysis::run(&comp);
        // frame 1's sink is `u` (its only user is in frame 0) → span 0
        assert_eq!(sa.span_of(u), 0);
        assert_eq!(sa.span_of(t), 1);
        assert_eq!(sa.frames(), vec![0, 1]);
        assert!(sa.critical_path(1) >= 1);
    }

    #[test]
    fn lc_layers_found() {
        let mut b = GraphBuilder::new("lc");
        let x = b.param("x", Shape::f32(&[4, 4]));
        let w = b.param("w", Shape::f32(&[4, 4]));
        let d = b.dot(x, w); // library call
        let e = b.exp(d);
        let comp = b.finish(e);
        let sa = SpanAnalysis::run(&comp);
        let lc = sa.lc_layers(&comp, 0);
        assert_eq!(lc, vec![sa.span_of(d)]);
    }

    #[test]
    fn work_counts_non_free_ops() {
        let mut b = GraphBuilder::new("w");
        let x = b.param("x", Shape::f32(&[10]));
        let e = b.exp(x);
        let t = b.tanh(e);
        let comp = b.finish(t);
        let sa = SpanAnalysis::run(&comp);
        assert_eq!(sa.work_elements(), 20); // exp + tanh, not the parameter
    }
}
