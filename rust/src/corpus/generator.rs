//! Corpus generation + footprint statistics (Figure 1).

use crate::analysis::footprint::instr_footprint_elements;
use crate::hlo::instruction::ReduceKind;
use crate::hlo::{Computation, GraphBuilder, Opcode, Shape};
use crate::testutil::Rng;

/// The six most frequent computing ops of Figure 1. `Reduce` collects
/// mean/sum/min/max like the paper's orange line.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    Mul,
    Add,
    Sub,
    Reduce,
    MatMul,
    Conv2D,
}

impl OpClass {
    pub const ALL: [OpClass; 6] =
        [OpClass::Mul, OpClass::Add, OpClass::Sub, OpClass::Reduce, OpClass::MatMul, OpClass::Conv2D];

    pub fn label(self) -> &'static str {
        match self {
            OpClass::Mul => "mul",
            OpClass::Add => "add",
            OpClass::Sub => "sub",
            OpClass::Reduce => "reduce",
            OpClass::MatMul => "matmul",
            OpClass::Conv2D => "conv2d",
        }
    }

    fn classify(op: Opcode, kind: Option<ReduceKind>) -> Option<OpClass> {
        match op {
            Opcode::Multiply => Some(OpClass::Mul),
            Opcode::Add => Some(OpClass::Add),
            Opcode::Subtract => Some(OpClass::Sub),
            Opcode::Reduce => kind.map(|_| OpClass::Reduce),
            Opcode::Dot | Opcode::BatchDot => Some(OpClass::MatMul),
            Opcode::Convolution => Some(OpClass::Conv2D),
            _ => None,
        }
    }
}

/// Corpus generation parameters.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub seed: u64,
    /// Number of synthetic models. (The paper's population is 53,470
    /// models; percentile curves stabilize far earlier.)
    pub models: usize,
    /// Ops per model, min/max.
    pub ops_per_model: (usize, usize),
    /// Cap on the heavy-tailed layer-width distribution (widths are
    /// `2^(3..=max_width_log2)`). The default reproduces Figure 1; the
    /// stitched-execution differential harness caps it low so every
    /// graph executes in test time.
    pub max_width_log2: u32,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig { seed: 1701, models: 800, ops_per_model: (24, 96), max_width_log2: 13 }
    }
}

/// Footprint samples (in number of floats, like Figure 1's x-axis) per
/// op class.
#[derive(Debug, Default, Clone)]
pub struct CorpusStats {
    pub samples: std::collections::HashMap<OpClass, Vec<i64>>,
}

impl CorpusStats {
    pub fn total_instances(&self) -> usize {
        self.samples.values().map(Vec::len).sum()
    }

    pub fn record(&mut self, comp: &Computation) {
        for instr in comp.instructions() {
            if let Some(class) = OpClass::classify(instr.opcode, instr.attrs.reduce_kind) {
                self.samples
                    .entry(class)
                    .or_default()
                    .push(instr_footprint_elements(comp, instr.id));
            }
        }
    }

    /// Finalize: sort all series ascending for percentile queries.
    pub fn finalize(&mut self) {
        for v in self.samples.values_mut() {
            v.sort_unstable();
        }
    }
}

/// Generate the corpus and collect footprint statistics.
pub fn generate(cfg: &CorpusConfig) -> CorpusStats {
    let mut stats = CorpusStats::default();
    for comp in generate_models(cfg) {
        stats.record(&comp);
    }
    stats.finalize();
    stats
}

/// Generate the corpus graphs themselves (same stream as [`generate`]):
/// the workload set of the stitched-execution differential harness.
pub fn generate_models(cfg: &CorpusConfig) -> Vec<Computation> {
    let mut rng = Rng::new(cfg.seed);
    (0..cfg.models).map(|i| gen_model(&mut rng, i, cfg)).collect()
}

/// The corpus's large-intermediate tail: models whose interior reduce
/// produces a per-block chunk provably over the default
/// [`crate::gpusim::DeviceConfig`]'s shared-memory budget, so
/// shared-memory stitching alone cannot fuse across it and the
/// global-memory tier (spill + grid fence) is the only way to merge.
///
/// Each model is the chain
///
/// ```text
/// x[b, r, w] → exp → reduce(dim 1, Sum) → [b, w] → tanh → reduce(dim 1, Sum) → [b]
/// ```
///
/// Every legal schedule of the `[b]` root splits dim 0 into at most `b`
/// blocks, so the interior `[b, w]` reduce deposits at least `w` f32s
/// (`4w` bytes) per block — and every shape below keeps `4w` over the
/// 20 KB default budget. Deterministic (no RNG draws): the shapes *are*
/// the test vector.
pub fn overflow_shapes() -> &'static [(i64, i64, i64)] {
    &[(64, 2, 5376), (32, 2, 6144), (112, 2, 5376)]
}

/// Build the [`overflow_shapes`] models (see there for the shape
/// argument): the workload that forces the global-memory stitching tier.
pub fn generate_overflow_models() -> Vec<Computation> {
    overflow_shapes()
        .iter()
        .enumerate()
        .map(|(i, &(b_dim, r_dim, w_dim))| {
            let mut b = GraphBuilder::new(format!("overflow_{i}"));
            let x = b.param("x", Shape::f32(&[b_dim, r_dim, w_dim]));
            let e = b.exp(x);
            let r1 = b.reduce(e, &[1], ReduceKind::Sum); // [b, w] interior
            let t = b.tanh(r1);
            let r2 = b.reduce(t, &[1], ReduceKind::Sum); // [b] root
            b.finish(r2)
        })
        .collect()
}

/// Accumulated-percentile curve of a sorted series at the given
/// cut-points of log2(footprint): returns, per cut, the fraction of
/// instances with footprint ≤ 2^cut — Figure 1's y-axis.
pub fn percentiles(sorted: &[i64], log2_cuts: &[u32]) -> Vec<f64> {
    log2_cuts
        .iter()
        .map(|&c| {
            let bound = 1i64 << c;
            let pos = sorted.partition_point(|&x| x <= bound);
            pos as f64 / sorted.len().max(1) as f64
        })
        .collect()
}

/// One synthetic model: a stack of layers whose widths follow a
/// heavy-tailed distribution — mostly small (embedding/update tails),
/// occasionally large (wide dense layers).
fn gen_model(rng: &mut Rng, idx: usize, cfg: &CorpusConfig) -> Computation {
    let mut b = GraphBuilder::new(format!("corpus_{idx}"));
    // Heavy-tailed width: 2^(3..14) weighted toward the low end
    // (quadratic bias), capped by the config.
    let cap = cfg.max_width_log2.max(3);
    fn width(rng: &mut Rng, cap: u32) -> i64 {
        let exp = 3 + (rng.f64() * rng.f64() * 11.0) as u32;
        1i64 << exp.min(cap)
    }
    let batch = [1i64, 8, 32, 128][rng.below(4)];

    let d0 = width(rng, cap);
    let x0 = b.param("x", Shape::f32(&[batch, d0]));
    let mut cur = x0;
    let layers = rng.range(2, 6);
    for _ in 0..layers {
        let cur_dims = b.peek().get(cur).shape.dims.clone();
        let d_in = cur_dims[1];
        match rng.below(8) {
            // dense layer (matmul + bias/activation elementwise tail)
            0 | 1 => {
                let d_out = width(rng, cap);
                let w = b.param("w", Shape::f32(&[d_in, d_out]));
                let y = b.dot(cur, w);
                let bias = b.param("bias", Shape::f32(&[d_out]));
                let bb = b.broadcast(bias, &[batch, d_out], &[1]);
                let z = b.add(y, bb);
                cur = b.tanh(z);
            }
            // conv block when the width factors nicely
            2 => {
                let hw = 16i64;
                if d_in % (hw * hw) == 0 && d_in / (hw * hw) > 0 {
                    let c = d_in / (hw * hw);
                    let img = b.reshape(cur, &[batch, hw, hw, c]);
                    let k = b.param("k", Shape::f32(&[3, 3, c, c]));
                    let cv = b.conv2d(img, k);
                    cur = b.reshape(cv, &[batch, d_in]);
                } else {
                    let o = b.param("o", Shape::f32(&[batch, d_in]));
                    cur = b.mul(cur, o);
                }
            }
            // normalization-ish reduce + broadcast + sub/mul
            3 | 4 => {
                let kind = *rng.pick(&[
                    ReduceKind::Mean,
                    ReduceKind::Sum,
                    ReduceKind::Min,
                    ReduceKind::Max,
                ]);
                let r = b.reduce(cur, &[1], kind);
                let rb = b.broadcast(r, &[batch, d_in], &[0]);
                cur = b.sub(cur, rb);
            }
            // elementwise update pairs (the fine-granularity population)
            _ => {
                let o = b.param("o", Shape::f32(&[batch, d_in]));
                let m = b.mul(cur, o);
                let a = b.add(m, o);
                cur = b.sub(a, cur);
            }
        }
    }
    // Gated-update tail (no rng draws, so the Figure 1 stream above is
    // untouched): power/compare/select — the opcodes the op-by-op
    // interpreter must also cover for the stitched differential harness.
    // sigmoid keeps the power base strictly positive.
    let tail_dims = b.peek().get(cur).shape.dims.clone();
    let gate = b.param("gate", Shape::f32(&tail_dims));
    let sg = b.sigmoid(cur);
    let pw = b.pow(sg, gate);
    let cmp = b.compare(pw, gate);
    cur = b.select(cmp, pw, cur);

    let dims = b.peek().get(cur).shape.dims.clone();
    let all: Vec<usize> = (0..dims.len()).collect();
    let out = b.reduce(cur, &all, ReduceKind::Mean);
    b.finish(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CorpusStats {
        generate(&CorpusConfig { seed: 7, models: 120, ops_per_model: (8, 32), ..Default::default() })
    }

    #[test]
    fn corpus_covers_all_classes() {
        let stats = small();
        for class in OpClass::ALL {
            assert!(
                stats.samples.get(&class).map(|v| !v.is_empty()).unwrap_or(false),
                "class {class:?} missing"
            );
        }
        assert!(stats.total_instances() > 500);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = small();
        let b = small();
        for class in OpClass::ALL {
            assert_eq!(a.samples[&class], b.samples[&class]);
        }
    }

    #[test]
    fn percentile_curve_monotone() {
        let stats = small();
        let cuts: Vec<u32> = (4..26).collect();
        for class in OpClass::ALL {
            let p = percentiles(&stats.samples[&class], &cuts);
            for w in p.windows(2) {
                assert!(w[0] <= w[1] + 1e-12, "non-monotone percentile curve");
            }
            assert!(p.last().copied().unwrap_or(0.0) > 0.99);
        }
    }

    #[test]
    fn figure1_shape_matmul_bigger_than_elementwise() {
        // The paper's observation: MatMul/Conv2D footprints are generally
        // larger than elementwise ones — compare medians.
        let stats = small();
        let median = |v: &Vec<i64>| v[v.len() / 2];
        let mm = median(&stats.samples[&OpClass::MatMul]);
        let add = median(&stats.samples[&OpClass::Add]);
        assert!(mm > add, "matmul median {mm} should exceed add median {add}");
    }

    #[test]
    fn overflow_models_actually_overflow_shared_memory() {
        // The whole point of the large-intermediate tail: on the default
        // device, fusing the full chain overflows the shared-memory
        // budget under *every* tuned schedule — the strict planner
        // rejects the group, and the spill planner moves the interior
        // reduce to the global tier.
        use crate::codegen::{plan_shared_memory, plan_shared_memory_spill};
        use crate::gpusim::DeviceConfig;
        use crate::hlo::InstrId;
        use crate::schedule::{tune, PerfLibrary, TuningConfig};
        use std::collections::HashSet;

        let models = generate_overflow_models();
        assert_eq!(models.len(), overflow_shapes().len());
        let dev = DeviceConfig::pascal();
        let mut lib = PerfLibrary::new(dev.clone());
        for comp in &models {
            let members: HashSet<InstrId> = comp
                .instructions()
                .filter(|i| i.opcode != Opcode::Parameter)
                .map(|i| i.id)
                .collect();
            let roots = [comp.root()];
            let tuned = tune(comp, &members, &roots, &mut lib, &TuningConfig::default())
                .expect("overflow chains must still be schedulable");
            assert!(
                plan_shared_memory(comp, &members, &roots, &tuned, &dev).is_err(),
                "{}: interior reduce chunk must exceed the shm budget",
                comp.name
            );
            let shm = plan_shared_memory_spill(comp, &members, &roots, &tuned, &dev);
            assert!(!shm.spilled.is_empty(), "{}: spill planner must fire", comp.name);
            assert!(shm.total_bytes <= dev.shared_mem_kernel_limit);
        }
    }

    #[test]
    fn most_instances_are_small() {
        // "most op instances have small memory footprints" — over half
        // of elementwise instances below 2^20 floats.
        let stats = small();
        let p = percentiles(&stats.samples[&OpClass::Add], &[20]);
        assert!(p[0] > 0.5, "fraction below 2^20 = {}", p[0]);
    }
}
