//! Corpus generation + footprint statistics (Figure 1).

use crate::analysis::footprint::instr_footprint_elements;
use crate::hlo::instruction::ReduceKind;
use crate::hlo::{Computation, GraphBuilder, Opcode, Shape};
use crate::testutil::Rng;

/// The six most frequent computing ops of Figure 1. `Reduce` collects
/// mean/sum/min/max like the paper's orange line.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    Mul,
    Add,
    Sub,
    Reduce,
    MatMul,
    Conv2D,
}

impl OpClass {
    pub const ALL: [OpClass; 6] =
        [OpClass::Mul, OpClass::Add, OpClass::Sub, OpClass::Reduce, OpClass::MatMul, OpClass::Conv2D];

    pub fn label(self) -> &'static str {
        match self {
            OpClass::Mul => "mul",
            OpClass::Add => "add",
            OpClass::Sub => "sub",
            OpClass::Reduce => "reduce",
            OpClass::MatMul => "matmul",
            OpClass::Conv2D => "conv2d",
        }
    }

    fn classify(op: Opcode, kind: Option<ReduceKind>) -> Option<OpClass> {
        match op {
            Opcode::Multiply => Some(OpClass::Mul),
            Opcode::Add => Some(OpClass::Add),
            Opcode::Subtract => Some(OpClass::Sub),
            Opcode::Reduce => kind.map(|_| OpClass::Reduce),
            Opcode::Dot | Opcode::BatchDot => Some(OpClass::MatMul),
            Opcode::Convolution => Some(OpClass::Conv2D),
            _ => None,
        }
    }
}

/// Corpus generation parameters.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub seed: u64,
    /// Number of synthetic models. (The paper's population is 53,470
    /// models; percentile curves stabilize far earlier.)
    pub models: usize,
    /// Ops per model, min/max.
    pub ops_per_model: (usize, usize),
    /// Cap on the heavy-tailed layer-width distribution (widths are
    /// `2^(3..=max_width_log2)`). The default reproduces Figure 1; the
    /// stitched-execution differential harness caps it low so every
    /// graph executes in test time.
    pub max_width_log2: u32,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig { seed: 1701, models: 800, ops_per_model: (24, 96), max_width_log2: 13 }
    }
}

/// Footprint samples (in number of floats, like Figure 1's x-axis) per
/// op class.
#[derive(Debug, Default, Clone)]
pub struct CorpusStats {
    pub samples: std::collections::HashMap<OpClass, Vec<i64>>,
}

impl CorpusStats {
    pub fn total_instances(&self) -> usize {
        self.samples.values().map(Vec::len).sum()
    }

    pub fn record(&mut self, comp: &Computation) {
        for instr in comp.instructions() {
            if let Some(class) = OpClass::classify(instr.opcode, instr.attrs.reduce_kind) {
                self.samples
                    .entry(class)
                    .or_default()
                    .push(instr_footprint_elements(comp, instr.id));
            }
        }
    }

    /// Finalize: sort all series ascending for percentile queries.
    pub fn finalize(&mut self) {
        for v in self.samples.values_mut() {
            v.sort_unstable();
        }
    }
}

/// Generate the corpus and collect footprint statistics.
pub fn generate(cfg: &CorpusConfig) -> CorpusStats {
    let mut stats = CorpusStats::default();
    for comp in generate_models(cfg) {
        stats.record(&comp);
    }
    stats.finalize();
    stats
}

/// Generate the corpus graphs themselves (same stream as [`generate`]):
/// the workload set of the stitched-execution differential harness.
pub fn generate_models(cfg: &CorpusConfig) -> Vec<Computation> {
    let mut rng = Rng::new(cfg.seed);
    (0..cfg.models).map(|i| gen_model(&mut rng, i, cfg)).collect()
}

/// Accumulated-percentile curve of a sorted series at the given
/// cut-points of log2(footprint): returns, per cut, the fraction of
/// instances with footprint ≤ 2^cut — Figure 1's y-axis.
pub fn percentiles(sorted: &[i64], log2_cuts: &[u32]) -> Vec<f64> {
    log2_cuts
        .iter()
        .map(|&c| {
            let bound = 1i64 << c;
            let pos = sorted.partition_point(|&x| x <= bound);
            pos as f64 / sorted.len().max(1) as f64
        })
        .collect()
}

/// One synthetic model: a stack of layers whose widths follow a
/// heavy-tailed distribution — mostly small (embedding/update tails),
/// occasionally large (wide dense layers).
fn gen_model(rng: &mut Rng, idx: usize, cfg: &CorpusConfig) -> Computation {
    let mut b = GraphBuilder::new(format!("corpus_{idx}"));
    // Heavy-tailed width: 2^(3..14) weighted toward the low end
    // (quadratic bias), capped by the config.
    let cap = cfg.max_width_log2.max(3);
    fn width(rng: &mut Rng, cap: u32) -> i64 {
        let exp = 3 + (rng.f64() * rng.f64() * 11.0) as u32;
        1i64 << exp.min(cap)
    }
    let batch = [1i64, 8, 32, 128][rng.below(4)];

    let d0 = width(rng, cap);
    let x0 = b.param("x", Shape::f32(&[batch, d0]));
    let mut cur = x0;
    let layers = rng.range(2, 6);
    for _ in 0..layers {
        let cur_dims = b.peek().get(cur).shape.dims.clone();
        let d_in = cur_dims[1];
        match rng.below(8) {
            // dense layer (matmul + bias/activation elementwise tail)
            0 | 1 => {
                let d_out = width(rng, cap);
                let w = b.param("w", Shape::f32(&[d_in, d_out]));
                let y = b.dot(cur, w);
                let bias = b.param("bias", Shape::f32(&[d_out]));
                let bb = b.broadcast(bias, &[batch, d_out], &[1]);
                let z = b.add(y, bb);
                cur = b.tanh(z);
            }
            // conv block when the width factors nicely
            2 => {
                let hw = 16i64;
                if d_in % (hw * hw) == 0 && d_in / (hw * hw) > 0 {
                    let c = d_in / (hw * hw);
                    let img = b.reshape(cur, &[batch, hw, hw, c]);
                    let k = b.param("k", Shape::f32(&[3, 3, c, c]));
                    let cv = b.conv2d(img, k);
                    cur = b.reshape(cv, &[batch, d_in]);
                } else {
                    let o = b.param("o", Shape::f32(&[batch, d_in]));
                    cur = b.mul(cur, o);
                }
            }
            // normalization-ish reduce + broadcast + sub/mul
            3 | 4 => {
                let kind = *rng.pick(&[
                    ReduceKind::Mean,
                    ReduceKind::Sum,
                    ReduceKind::Min,
                    ReduceKind::Max,
                ]);
                let r = b.reduce(cur, &[1], kind);
                let rb = b.broadcast(r, &[batch, d_in], &[0]);
                cur = b.sub(cur, rb);
            }
            // elementwise update pairs (the fine-granularity population)
            _ => {
                let o = b.param("o", Shape::f32(&[batch, d_in]));
                let m = b.mul(cur, o);
                let a = b.add(m, o);
                cur = b.sub(a, cur);
            }
        }
    }
    // Gated-update tail (no rng draws, so the Figure 1 stream above is
    // untouched): power/compare/select — the opcodes the op-by-op
    // interpreter must also cover for the stitched differential harness.
    // sigmoid keeps the power base strictly positive.
    let tail_dims = b.peek().get(cur).shape.dims.clone();
    let gate = b.param("gate", Shape::f32(&tail_dims));
    let sg = b.sigmoid(cur);
    let pw = b.pow(sg, gate);
    let cmp = b.compare(pw, gate);
    cur = b.select(cmp, pw, cur);

    let dims = b.peek().get(cur).shape.dims.clone();
    let all: Vec<usize> = (0..dims.len()).collect();
    let out = b.reduce(cur, &all, ReduceKind::Mean);
    b.finish(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CorpusStats {
        generate(&CorpusConfig { seed: 7, models: 120, ops_per_model: (8, 32), ..Default::default() })
    }

    #[test]
    fn corpus_covers_all_classes() {
        let stats = small();
        for class in OpClass::ALL {
            assert!(
                stats.samples.get(&class).map(|v| !v.is_empty()).unwrap_or(false),
                "class {class:?} missing"
            );
        }
        assert!(stats.total_instances() > 500);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = small();
        let b = small();
        for class in OpClass::ALL {
            assert_eq!(a.samples[&class], b.samples[&class]);
        }
    }

    #[test]
    fn percentile_curve_monotone() {
        let stats = small();
        let cuts: Vec<u32> = (4..26).collect();
        for class in OpClass::ALL {
            let p = percentiles(&stats.samples[&class], &cuts);
            for w in p.windows(2) {
                assert!(w[0] <= w[1] + 1e-12, "non-monotone percentile curve");
            }
            assert!(p.last().copied().unwrap_or(0.0) > 0.99);
        }
    }

    #[test]
    fn figure1_shape_matmul_bigger_than_elementwise() {
        // The paper's observation: MatMul/Conv2D footprints are generally
        // larger than elementwise ones — compare medians.
        let stats = small();
        let median = |v: &Vec<i64>| v[v.len() / 2];
        let mm = median(&stats.samples[&OpClass::MatMul]);
        let add = median(&stats.samples[&OpClass::Add]);
        assert!(mm > add, "matmul median {mm} should exceed add median {add}");
    }

    #[test]
    fn most_instances_are_small() {
        // "most op instances have small memory footprints" — over half
        // of elementwise instances below 2^20 floats.
        let stats = small();
        let p = percentiles(&stats.samples[&OpClass::Add], &[20]);
        assert!(p[0] > 0.5, "fraction below 2^20 = {}", p[0]);
    }
}
