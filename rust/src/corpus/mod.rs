//! Synthetic model corpus — regenerates Figure 1.
//!
//! The paper's Figure 1 plots the accumulated percentile distribution of
//! memory IO footprints for the six most frequent computing ops over
//! 53,470 production models on Alibaba PAI. We have no access to that
//! corpus, so this module generates a seeded synthetic population with
//! the qualitative properties the paper reports (see DESIGN.md
//! substitutions): most elementwise/reduce instances have small
//! footprints (launch-bound territory), MatMul/Conv2D instances run
//! larger, and all distributions are heavy-tailed (spanning many decades
//! at log2 scale).

pub mod generator;

pub use generator::{
    generate_overflow_models, overflow_shapes, percentiles, CorpusConfig, CorpusStats, OpClass,
};
