//! # FusionStitching
//!
//! A reproduction of *"FusionStitching: Deep Fusion and Code Generation for
//! Tensorflow Computations on GPUs"* (Long, Yang, Zhu, Lin — Alibaba, 2018)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate is organised around the paper's pipeline
//! (`HloModule` → op fusion → schedule planning → code generation):
//!
//! - [`hlo`] — the HLO-like intermediate representation every pass
//!   operates on (substrate; mirrors the XLA `HloModule` subset the paper
//!   needs: elementwise, shape-modulation, reduce, batch-dot, library
//!   calls, while-frames), plus canonicalization + structural
//!   fingerprinting ([`hlo::fingerprint`]) — the identity the
//!   compilation cache keys on.
//! - [`analysis`] — Work/Span (critical path) analysis, while-loop frame
//!   contexts, dominance trees and memory-footprint accounting (§3.1,
//!   §5.1.3 of the paper).
//! - [`fusion`] — the XLA-like baseline fusion pass and the paper's deep
//!   fusion: intra-layer `ElementwiseFusion` plus layered subgraph fusion
//!   (Algorithm 1) gated by `SchdConsistent` (§3.2).
//! - [`schedule`] — schedule specification (`split_dim`, `sword`,
//!   `sched_type`), Table 1 constraint propagation, tuning and the
//!   persistent performance library (§4).
//! - [`codegen`] — shared-memory planning (size analysis, shrinking,
//!   dominance-based space sharing) and the stitched emitter producing
//!   kernel plans (Algorithm 2, §5).
//! - [`gpusim`] — an analytical Pascal-class GPU cost model standing in
//!   for the paper's physical GPU + nvprof (see DESIGN.md substitutions).
//! - [`exec`] — the stitched VM: compiled modules lowered to register
//!   bytecode with an explicit grid model and executed as one launch
//!   per fused group, with a launch ledger measuring the paper's
//!   kernel-launch reduction on real runs.
//! - [`models`] — the six benchmark graphs of Table 2.
//! - [`corpus`] — synthetic model corpus regenerating Figure 1.
//! - [`runtime`] — the execution runtime for AOT-lowered JAX/Pallas
//!   artifacts (HLO-text interpreter standing in for the PJRT CPU
//!   client; the numeric hot path).
//! - [`coordinator`] — the end-to-end pipeline driver (a pass manager
//!   with per-pass instrumentation), the fingerprint-keyed compilation
//!   cache for compile-once serving, and the NMT online serving loop
//!   (shape-keyed dynamic batching over the runtime).
//! - [`obs`] — the observability layer: a bounded flight recorder
//!   tracing the request life cycle (queue → batch → compile → launch →
//!   reply), a per-fused-group kernel profiler joined against the
//!   modeled costs, and Chrome-trace / Prometheus exporters.
//!
//! Architecture, the paper-section ↔ module map and every cost-model
//! substitution are documented in `DESIGN.md` at the repository root.

pub mod analysis;
pub mod codegen;
pub mod coordinator;
pub mod corpus;
pub mod exec;
pub mod fusion;
pub mod gpusim;
pub mod hlo;
pub mod models;
pub mod obs;
pub mod runtime;
pub mod schedule;
pub mod testutil;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
