//! Learned performance predictor — the paper's stated future work
//! (§4.4): *"it should be possible to build a learning model to predict
//! a performance metric from features in the key, and return the
//! predicted value to the tuning process immediately, thus shortening
//! the critical path by offloading the kernel generation, compilation
//! and execution asynchronously."*
//!
//! We implement exactly that: a ridge-regularized linear model over
//! log-domain kernel features (bytes moved, grid/block geometry,
//! coalescing, instruction weight), trained on the performance library's
//! measured entries, predicting log kernel time. Training is a closed
//! form normal-equation solve (the feature space is tiny), so the model
//! can be refit cheaply whenever the library grows.

use crate::gpusim::cost::KernelDesc;

/// Feature vector of one kernel measurement.
const NFEAT: usize = 7;

fn features(desc: &KernelDesc) -> [f64; NFEAT] {
    let bytes = (desc.bytes_read + desc.bytes_written) as f64;
    [
        1.0,                                    // bias
        bytes.max(1.0).ln(),                    // memory traffic
        desc.effective_flops().max(1.0).ln(),   // weighted compute
        (desc.blocks as f64).max(1.0).ln(),     // grid size
        (desc.threads as f64).max(1.0).ln(),    // block size
        desc.coalescing.clamp(0.05, 1.0).ln(),  // access efficiency
        desc.op_weight.max(1.0).ln(),           // transcendental weight
    ]
}

/// The trained model: weights of the log-linear predictor.
#[derive(Debug, Clone)]
pub struct PerfPredictor {
    w: [f64; NFEAT],
    /// Residual statistics on the training set.
    pub train_rmse_log: f64,
    pub train_r2: f64,
    pub n_samples: usize,
}

impl PerfPredictor {
    /// Fit on (descriptor, measured execution time µs) pairs with ridge
    /// regularization `lambda`. Returns `None` with fewer samples than
    /// features.
    pub fn fit(samples: &[(KernelDesc, f64)], lambda: f64) -> Option<PerfPredictor> {
        let n = samples.len();
        if n < NFEAT {
            return None;
        }
        // Normal equations: (XᵀX + λI) w = Xᵀy in log-time domain.
        let mut xtx = [[0.0f64; NFEAT]; NFEAT];
        let mut xty = [0.0f64; NFEAT];
        for (desc, t) in samples {
            let x = features(desc);
            let y = t.max(1e-3).ln();
            for i in 0..NFEAT {
                for j in 0..NFEAT {
                    xtx[i][j] += x[i] * x[j];
                }
                xty[i] += x[i] * y;
            }
        }
        for (i, row) in xtx.iter_mut().enumerate() {
            row[i] += lambda;
        }
        let w = solve(xtx, xty)?;

        // Training diagnostics.
        let mean_y: f64 =
            samples.iter().map(|(_, t)| t.max(1e-3).ln()).sum::<f64>() / n as f64;
        let mut sse = 0.0;
        let mut sst = 0.0;
        for (desc, t) in samples {
            let y = t.max(1e-3).ln();
            let x = features(desc);
            let pred: f64 = x.iter().zip(&w).map(|(a, b)| a * b).sum();
            sse += (y - pred) * (y - pred);
            sst += (y - mean_y) * (y - mean_y);
        }
        Some(PerfPredictor {
            w,
            train_rmse_log: (sse / n as f64).sqrt(),
            train_r2: if sst > 0.0 { 1.0 - sse / sst } else { 1.0 },
            n_samples: n,
        })
    }

    /// Predicted kernel execution time in µs.
    pub fn predict(&self, desc: &KernelDesc) -> f64 {
        let x = features(desc);
        let log_t: f64 = x.iter().zip(&self.w).map(|(a, b)| a * b).sum();
        log_t.exp()
    }
}

/// Gaussian elimination with partial pivoting for the NFEAT×NFEAT system.
fn solve(mut a: [[f64; NFEAT]; NFEAT], mut b: [f64; NFEAT]) -> Option<[f64; NFEAT]> {
    for col in 0..NFEAT {
        // pivot
        let mut piv = col;
        for r in col + 1..NFEAT {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        if a[piv][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        // eliminate
        for r in col + 1..NFEAT {
            let f = a[r][col] / a[col][col];
            for c in col..NFEAT {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    // back substitution
    let mut x = [0.0f64; NFEAT];
    for col in (0..NFEAT).rev() {
        let mut s = b[col];
        for c in col + 1..NFEAT {
            s -= a[col][c] * x[c];
        }
        x[col] = s / a[col][col];
    }
    Some(x)
}

/// Collect a training set by sweeping the analytical model across a
/// spectrum of kernel geometries (the stand-in for the paper's nvprof
/// measurements; with a real GPU these pairs come from the library's
/// measured entries).
pub fn training_sweep(dev: &crate::gpusim::DeviceConfig, seed: u64) -> Vec<(KernelDesc, f64)> {
    let mut rng = crate::testutil::Rng::new(seed);
    let mut out = Vec::new();
    for _ in 0..512 {
        let bytes = 1u64 << rng.range(10, 27);
        let blocks = 1u64 << rng.range(0, 14);
        let threads = [64u32, 128, 256, 512, 1024][rng.below(5)];
        let desc = KernelDesc {
            bytes_read: bytes,
            bytes_written: bytes / (1 + rng.below(4) as u64),
            flops: bytes / 4 * (1 + rng.below(8) as u64),
            blocks,
            threads,
            smem_bytes: 0,
            coalescing: [1.0, 0.95, 0.9, 0.55, 0.45][rng.below(5)],
            op_weight: [1.0, 1.0, 8.0][rng.below(3)],
        };
        let t = crate::gpusim::cost::kernel_exec_time_us(&desc, dev);
        out.push((desc, t));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::cost::kernel_exec_time_us;
    use crate::gpusim::DeviceConfig;

    fn fitted() -> (PerfPredictor, Vec<(KernelDesc, f64)>) {
        let dev = DeviceConfig::pascal();
        let train = training_sweep(&dev, 42);
        let model = PerfPredictor::fit(&train, 1e-6).expect("fit");
        (model, training_sweep(&dev, 77)) // held-out set
    }

    #[test]
    fn fits_with_high_r2() {
        let (model, _) = fitted();
        assert!(model.train_r2 > 0.85, "R² = {}", model.train_r2);
        assert_eq!(model.n_samples, 512);
    }

    #[test]
    fn generalizes_to_held_out_kernels() {
        let (model, held_out) = fitted();
        // median relative error on unseen geometries under 60% — good
        // enough for *ranking* schedules, which is all tuning needs.
        let mut rel: Vec<f64> = held_out
            .iter()
            .map(|(d, t)| (model.predict(d) - t).abs() / t.max(1e-6))
            .collect();
        rel.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = rel[rel.len() / 2];
        assert!(median < 0.6, "median relative error {median}");
    }

    #[test]
    fn preserves_schedule_ordering() {
        // The tuner only needs the predictor to rank schedules: verify
        // it agrees with the simulator on clear-cut comparisons.
        let (model, _) = fitted();
        let dev = DeviceConfig::pascal();
        let base = KernelDesc {
            bytes_read: 1 << 22,
            bytes_written: 1 << 22,
            flops: 1 << 20,
            blocks: 2048,
            threads: 256,
            smem_bytes: 0,
            coalescing: 1.0,
            op_weight: 1.0,
        };
        let mut single_block = base.clone();
        single_block.blocks = 1;
        let mut uncoalesced = base.clone();
        uncoalesced.coalescing = 0.45;
        for (a, b) in [(&base, &single_block), (&base, &uncoalesced)] {
            let sim = kernel_exec_time_us(a, &dev) < kernel_exec_time_us(b, &dev);
            let pred = model.predict(a) < model.predict(b);
            assert_eq!(sim, pred, "ordering disagreement");
        }
    }

    #[test]
    fn too_few_samples_rejected() {
        assert!(PerfPredictor::fit(&[], 1e-6).is_none());
    }

    #[test]
    fn solver_handles_identity() {
        let mut a = [[0.0; NFEAT]; NFEAT];
        for (i, row) in a.iter_mut().enumerate() {
            row[i] = 2.0;
        }
        let b = [4.0; NFEAT];
        let x = solve(a, b).unwrap();
        for v in x {
            assert!((v - 2.0).abs() < 1e-12);
        }
    }
}
