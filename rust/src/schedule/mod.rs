//! Schedule planning — §4 of the paper.
//!
//! - [`spec`] — the compact schedule space: `(split_dim, sword,
//!   sched_type)` triples over an instruction's output shape (§4.1).
//! - [`propagate`] — Table 1's constraint-propagation rules, resolving
//!   whether a root schedule is satisfiable by every instruction of a
//!   fused computation (§4.2).
//! - [`perf_library`] — the persistent key-value store of per-schedule
//!   kernel times, filled on miss from the GPU cost model (§4.4).
//! - [`tuning`] — candidate enumeration, the two-stage multi-root search
//!   and best-so-far pruning (§4.3).
//! - [`predictor`] — the paper's §4.4 future work: a learned model
//!   predicting kernel time from key features, replacing synchronous
//!   measurement on library misses.
//! - [`oracle`] — the [`CostOracle`] seam between every cost consumer
//!   and the numbers it consumes: the analytic model ([`ModeledCost`])
//!   or serving-path wall-clock overlays ([`MeasuredCost`]).

pub mod oracle;
pub mod perf_library;
pub mod predictor;
pub mod propagate;
pub mod spec;
pub mod tuning;

pub use oracle::{CostOracle, CostSource, MeasuredCost, ModeledCost};
pub use perf_library::PerfLibrary;
pub use predictor::PerfPredictor;
pub use propagate::{propagate, OpSchedule, PropagationResult};
pub use spec::{SchedType, Schedule};
pub use tuning::{tune, tune_with_oracle, TunedPlan, TuningConfig};
