//! The performance library — §4.4.
//!
//! A key-value store mapping `(opcode, shape, split_dim, sword,
//! sched_type, thread-block size [, reduce/trans warps])` to kernel
//! execution time. The paper keeps it in permanent storage, loads it at
//! system initialization, and on a miss constructs a CUDA C kernel,
//! times it with nvprof and inserts the result. We do the same, except
//! misses are filled from the analytical GPU model ([`crate::gpusim`])
//! instead of a physical GPU — see DESIGN.md substitutions.

use super::propagate::OpSchedule;
use super::spec::{SchedType, Schedule};
use super::tuning::TunedPlan;
use crate::gpusim::cost::{kernel_exec_time_us, KernelDesc};
use crate::gpusim::device::DeviceConfig;
use crate::hlo::{Computation, InstrId, Opcode};
use std::collections::HashMap;
use std::path::Path;

/// Persistent on-disk format: per-schedule kernel times, whole tuned
/// group plans keyed by fingerprint-derived keys, memoized fusion-
/// exploration group costs, and measured per-group wall-clock entries
/// written back from the serving path.
#[derive(Debug, Default)]
struct Store {
    entries: HashMap<String, f64>,
    tuned: HashMap<String, TunedPlan>,
    explored: HashMap<String, f64>,
    measured: HashMap<String, MeasuredEntry>,
}

/// Wall-clock samples retained per measured group: the k *smallest*.
/// Timing noise on a shared machine is one-sided (preemption only ever
/// inflates a sample), so min-k retention is both outlier-robust and —
/// unlike reservoir or strided subsampling — order-independent:
/// `min_k(min_k(A) ∪ B) == min_k(A ∪ B)`, which is what makes merges of
/// concurrent worker write-backs deterministic.
pub const MEASURED_MAX_SAMPLES: usize = 64;

/// Launches a group must accumulate before its measured estimate is
/// allowed to override the analytic model (a couple of cold outliers
/// must not re-steer fusion).
pub const MEASURED_MIN_SAMPLES: u64 = 8;

/// One group's measured wall-clock record: the write-back side of the
/// feedback loop ([`crate::schedule::oracle::MeasuredCost`] snapshots
/// these into per-fingerprint overrides).
#[derive(Debug, Clone, PartialEq)]
pub struct MeasuredEntry {
    /// The id-invariant group fingerprint
    /// ([`crate::fusion::group_fingerprint`]) this entry describes.
    pub fp: u64,
    /// Total launches absorbed for this group — the write-back
    /// high-water mark, and the sample-count gate's denominator.
    pub count: u64,
    /// Retained samples, ascending (the `MEASURED_MAX_SAMPLES`
    /// smallest seen).
    pub samples_us: Vec<f64>,
}

impl MeasuredEntry {
    /// Min-k merge of new samples into the retained set.
    fn absorb(&mut self, samples_us: &[f64]) {
        self.samples_us.extend(samples_us.iter().copied().filter(|v| v.is_finite()));
        self.samples_us.sort_by(|a, b| a.partial_cmp(b).expect("finite samples"));
        self.samples_us.truncate(MEASURED_MAX_SAMPLES);
    }

    /// Outlier-trimmed running estimate: the mean of the retained
    /// samples after dropping `len/8` from each end, available only
    /// once [`MEASURED_MIN_SAMPLES`] launches accumulated.
    pub fn estimate_us(&self) -> Option<f64> {
        if self.count < MEASURED_MIN_SAMPLES || self.samples_us.is_empty() {
            return None;
        }
        let trim = self.samples_us.len() / 8;
        let kept = &self.samples_us[trim..self.samples_us.len() - trim];
        Some(kept.iter().sum::<f64>() / kept.len() as f64)
    }
}

/// FNV-1a offset basis — the seed every cache/memo key in the pipeline
/// hashes from.
pub const FNV_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// One FNV-1a folding step over a byte string, continuing from `h`.
/// Centralized (with [`fnv1a`]) so the fold can never diverge between
/// key producers: config digests, device signatures, group fingerprints.
pub fn fnv1a_fold(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// FNV-1a over a byte string, from the standard seed.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_fold(FNV_SEED, bytes)
}

/// Signature of the device a library entry was produced under. Folded
/// into every persisted key: a library saved under one [`DeviceConfig`]
/// must never silently serve schedules or costs after a device change —
/// mismatched entries simply read as misses.
pub fn device_signature(dev: &DeviceConfig) -> u64 {
    fnv1a(format!("{dev:?}").as_bytes())
}

/// The performance library. Cheap to clone-by-reference; interior state
/// is the memo table plus hit/miss counters.
#[derive(Debug)]
pub struct PerfLibrary {
    store: Store,
    dev: DeviceConfig,
    dev_sig: u64,
    hits: u64,
    misses: u64,
    tuned_hits: u64,
    explore_hits: u64,
}

impl PerfLibrary {
    pub fn new(dev: DeviceConfig) -> Self {
        let dev_sig = device_signature(&dev);
        PerfLibrary {
            store: Store::default(),
            dev,
            dev_sig,
            hits: 0,
            misses: 0,
            tuned_hits: 0,
            explore_hits: 0,
        }
    }

    /// Load from permanent storage (system initialization, §4.4).
    /// Missing file → empty library (warmup phase). Format: one
    /// `key\tmicroseconds` entry per line, plus `T\t…` lines carrying
    /// persisted tuned plans (see [`PerfLibrary::tuned_insert`]) and
    /// `E\t…` lines carrying memoized exploration costs. Every key
    /// embeds the [`device_signature`] it was produced under, so a file
    /// written for a different device loads cleanly but answers every
    /// lookup with a miss.
    pub fn load(path: &Path, dev: DeviceConfig) -> Self {
        let mut store = Store::default();
        if let Ok(text) = std::fs::read_to_string(path) {
            for line in text.lines() {
                if let Some(rest) = line.strip_prefix("T\t") {
                    if let Some((key, plan)) = parse_tuned_line(rest) {
                        store.tuned.insert(key, plan);
                    }
                } else if let Some(rest) = line.strip_prefix("E\t") {
                    if let Some((key, us)) = rest.rsplit_once('\t') {
                        if let Ok(t) = us.parse::<f64>() {
                            store.explored.insert(key.to_string(), t);
                        }
                    }
                } else if let Some(rest) = line.strip_prefix("M\t") {
                    if let Some((key, entry)) = parse_measured_line(rest) {
                        store.measured.insert(key, entry);
                    }
                } else if let Some((k, v)) = line.rsplit_once('\t') {
                    if let Ok(t) = v.parse::<f64>() {
                        store.entries.insert(k.to_string(), t);
                    }
                }
            }
        }
        let dev_sig = device_signature(&dev);
        PerfLibrary {
            store,
            dev,
            dev_sig,
            hits: 0,
            misses: 0,
            tuned_hits: 0,
            explore_hits: 0,
        }
    }

    /// Prefix `key` with the signature of the device this library is
    /// bound to — the namespace all three stores live under.
    fn sigged(&self, key: &str) -> String {
        format!("d{:016x}|{key}", self.dev_sig)
    }

    /// Persist for repeated usage across compilations.
    pub fn save(&self, path: &Path) -> crate::Result<()> {
        let mut keys: Vec<&String> = self.store.entries.keys().collect();
        keys.sort(); // deterministic files diff cleanly
        let mut out = String::new();
        for k in keys {
            out.push_str(k);
            out.push('\t');
            out.push_str(&self.store.entries[k].to_string());
            out.push('\n');
        }
        let mut tuned_keys: Vec<&String> = self.store.tuned.keys().collect();
        tuned_keys.sort();
        for k in tuned_keys {
            out.push_str(&format_tuned_line(k, &self.store.tuned[k]));
            out.push('\n');
        }
        let mut explore_keys: Vec<&String> = self.store.explored.keys().collect();
        explore_keys.sort();
        for k in explore_keys {
            out.push_str(&format!("E\t{k}\t{}\n", self.store.explored[k]));
        }
        let mut measured_keys: Vec<&String> = self.store.measured.keys().collect();
        measured_keys.sort();
        for k in measured_keys {
            out.push_str(&format_measured_line(k, &self.store.measured[k]));
            out.push('\n');
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    // ---- tuned-plan persistence (compile-once serving) ----

    /// Look up a persisted tuned plan under an opaque key (the driver
    /// derives keys from the module [`crate::hlo::Fingerprint`], fusion
    /// mode, config digest and group identity). Returns a clone — plans
    /// are small — and counts the hit. Callers that validate a plan
    /// before trusting it should use [`PerfLibrary::tuned_peek`] +
    /// [`PerfLibrary::tuned_mark_reused`] instead, so rejected plans do
    /// not inflate the hit counter.
    pub fn tuned_lookup(&mut self, key: &str) -> Option<TunedPlan> {
        let plan = self.store.tuned.get(&self.sigged(key)).cloned();
        if plan.is_some() {
            self.tuned_hits += 1;
        }
        plan
    }

    /// Borrow a persisted tuned plan without touching the hit counter.
    pub fn tuned_peek(&self, key: &str) -> Option<&TunedPlan> {
        self.store.tuned.get(&self.sigged(key))
    }

    /// Record that a peeked plan passed validation and was reused.
    pub fn tuned_mark_reused(&mut self) {
        self.tuned_hits += 1;
    }

    /// Record a tuned group plan for reuse across compilations and —
    /// after [`PerfLibrary::save`] / [`PerfLibrary::load`] — across
    /// processes.
    pub fn tuned_insert(&mut self, key: String, plan: TunedPlan) {
        let k = self.sigged(&key);
        self.store.tuned.insert(k, plan);
    }

    // ---- fusion-exploration memo (cost-guided fusion) ----

    /// Memoized modeled cost (us) of a fused group, keyed by the group's
    /// structural fingerprint. Lets serving recompiles reuse exploration
    /// verdicts instead of re-tuning every merge/split candidate.
    pub fn explore_lookup(&mut self, key: &str) -> Option<f64> {
        let v = self.store.explored.get(&self.sigged(key)).copied();
        if v.is_some() {
            self.explore_hits += 1;
        }
        v
    }

    /// Record a group's modeled cost for future explorations.
    pub fn explore_insert(&mut self, key: &str, modeled_us: f64) {
        let k = self.sigged(key);
        self.store.explored.insert(k, modeled_us);
    }

    /// Number of memoized exploration entries.
    pub fn explore_len(&self) -> usize {
        self.store.explored.len()
    }

    /// How many exploration lookups were answered from the memo.
    pub fn explore_hits(&self) -> u64 {
        self.explore_hits
    }

    // ---- measured write-back store (feedback-directed autotuning) ----

    /// Inner key of a measured entry — the same `xm{fp:016x}` namespace
    /// convention the explore memo uses (`xg…`), wrapped in the device
    /// signature by [`PerfLibrary::sigged`] so a device change reads as
    /// a miss.
    fn measured_key(group_fp: u64) -> String {
        format!("xm{group_fp:016x}")
    }

    /// This library's device-signed measured-key prefix.
    fn measured_prefix(&self) -> String {
        format!("d{:016x}|xm", self.dev_sig)
    }

    /// Record measured wall-clock samples for one group: min-k merge
    /// into the retained set, `launches` added to the sample-count gate.
    pub fn measured_record(&mut self, group_fp: u64, samples_us: &[f64], launches: u64) {
        let key = self.sigged(&Self::measured_key(group_fp));
        let entry = self
            .store
            .measured
            .entry(key)
            .or_insert(MeasuredEntry { fp: group_fp, count: 0, samples_us: Vec::new() });
        entry.absorb(samples_us);
        entry.count += launches;
    }

    /// Absorb a serving-path [`crate::obs::KernelProfile`] snapshot:
    /// every group whose launch count grew past this library's
    /// high-water mark contributes its reservoir samples. Idempotent
    /// per snapshot — re-absorbing the same profile is a no-op, so the
    /// background autotuner can poll freely. Returns the number of
    /// newly absorbed launches.
    pub fn absorb_profile(&mut self, profile: &crate::obs::KernelProfile) -> u64 {
        let mut absorbed = 0;
        for (fp, g) in profile.groups() {
            if g.launches == 0 {
                continue;
            }
            let key = self.sigged(&Self::measured_key(fp));
            let entry = self
                .store
                .measured
                .entry(key)
                .or_insert(MeasuredEntry { fp, count: 0, samples_us: Vec::new() });
            if g.launches <= entry.count {
                continue;
            }
            absorbed += g.launches - entry.count;
            entry.absorb(g.measured_us.samples());
            entry.count = g.launches;
        }
        absorbed
    }

    /// The trimmed measured estimate for one group under this device
    /// (None below the [`MEASURED_MIN_SAMPLES`] gate or on a device
    /// mismatch).
    pub fn measured_estimate(&self, group_fp: u64) -> Option<f64> {
        self.store
            .measured
            .get(&self.sigged(&Self::measured_key(group_fp)))
            .and_then(MeasuredEntry::estimate_us)
    }

    /// Borrow one group's full measured record (tests, reports).
    pub fn measured_entry(&self, group_fp: u64) -> Option<&MeasuredEntry> {
        self.store.measured.get(&self.sigged(&Self::measured_key(group_fp)))
    }

    /// Measured entries stored under this library's device signature.
    pub fn measured_len(&self) -> usize {
        let prefix = self.measured_prefix();
        self.store.measured.keys().filter(|k| k.starts_with(&prefix)).count()
    }

    /// The measured-sample epoch: total launches absorbed under this
    /// device signature. Monotone; stamps the measured oracle's memo
    /// tag so stale verdicts refresh as new samples land.
    pub fn measured_epoch(&self) -> u64 {
        let prefix = self.measured_prefix();
        self.store
            .measured
            .iter()
            .filter(|(k, _)| k.starts_with(&prefix))
            .map(|(_, e)| e.count)
            .sum()
    }

    /// Every group fingerprint with a gate-passing estimate under this
    /// device — the snapshot [`crate::schedule::MeasuredCost`] overlays.
    pub fn measured_overrides(&self) -> HashMap<u64, f64> {
        let prefix = self.measured_prefix();
        self.store
            .measured
            .iter()
            .filter(|(k, _)| k.starts_with(&prefix))
            .filter_map(|(_, e)| e.estimate_us().map(|t| (e.fp, t)))
            .collect()
    }

    /// Number of persisted tuned plans.
    pub fn tuned_len(&self) -> usize {
        self.store.tuned.len()
    }

    /// How many tuned-plan lookups were answered from the store.
    pub fn tuned_hits(&self) -> u64 {
        self.tuned_hits
    }

    pub fn device(&self) -> &DeviceConfig {
        &self.dev
    }

    pub fn len(&self) -> usize {
        self.store.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.entries.is_empty()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Kernel execution time (us, no launch overhead) for instruction
    /// `id` of `comp` run standalone under `sched` with `threads` threads
    /// per block. Fills the library on miss.
    pub fn lookup(
        &mut self,
        comp: &Computation,
        id: InstrId,
        sched: Schedule,
        threads: u32,
    ) -> f64 {
        let key = self.key(comp, id, sched, threads);
        if let Some(&v) = self.store.entries.get(&key) {
            self.hits += 1;
            return v;
        }
        self.misses += 1;
        // Miss: "constructs a CUDA C kernel from the key, compiles and
        // executes it" — here: build the kernel descriptor and ask the
        // analytical model.
        let desc = kernel_desc(comp, id, sched, threads, &self.dev);
        let t = kernel_exec_time_us(&desc, &self.dev);
        self.store.entries.insert(key, t);
        t
    }

    /// Cache key: the paper's common features (opcode, shape, split_dim,
    /// sword, sched_type, thread block size) plus the op-specific
    /// `reduce_warps`/`trans_warps` feature, which is derived from the
    /// block size here.
    fn key(&self, comp: &Computation, id: InstrId, sched: Schedule, threads: u32) -> String {
        let i = comp.get(id);
        let mut key = format!(
            "d{:016x}|{}|{}|{}|{}|{}|{}",
            self.dev_sig, i.opcode, i.shape, sched.split_dim, sched.sword, sched.sched_type, threads
        );
        // operand shapes disambiguate e.g. reduce input sizes
        for s in comp.operand_shapes(id) {
            key.push_str(&format!("|{s}"));
        }
        if i.opcode.is_reduce() || i.opcode == Opcode::Transpose {
            key.push_str(&format!("|warps={}", threads / self.dev.warp_size));
        }
        key
    }
}

// ---------------------------------------------------------------------
// Tuned-plan text (de)serialization
// ---------------------------------------------------------------------
//
// One line per plan:
//   T\t<key>\t<blocks>\t<threads>\t<est_us>\t<roots>\t<assignment>
// where <roots> and <assignment> are `;`-joined items. A root item is
// `id=split:sword:R|C`; an assignment item is the same or `id=I` for
// inlined ops. `-` stands for an empty list.

fn sched_to_text(s: &Schedule) -> String {
    let ty = match s.sched_type {
        SchedType::Row => "R",
        SchedType::Column => "C",
    };
    format!("{}:{}:{}", s.split_dim, s.sword, ty)
}

fn sched_from_text(t: &str) -> Option<Schedule> {
    let mut it = t.split(':');
    let split_dim = it.next()?.parse().ok()?;
    let sword = it.next()?.parse().ok()?;
    let sched_type = match it.next()? {
        "R" => SchedType::Row,
        "C" => SchedType::Column,
        _ => return None,
    };
    Some(Schedule { split_dim, sword, sched_type })
}

fn format_tuned_line(key: &str, plan: &TunedPlan) -> String {
    let roots = if plan.root_schedules.is_empty() {
        "-".to_string()
    } else {
        plan.root_schedules
            .iter()
            .map(|(id, s)| format!("{}={}", id.0, sched_to_text(s)))
            .collect::<Vec<_>>()
            .join(";")
    };
    let assignment = if plan.assignment.is_empty() {
        "-".to_string()
    } else {
        plan.assignment
            .iter()
            .map(|(id, st)| match st {
                OpSchedule::Scheduled(s) => format!("{}={}", id.0, sched_to_text(s)),
                OpSchedule::Inlined => format!("{}=I", id.0),
            })
            .collect::<Vec<_>>()
            .join(";")
    };
    format!(
        "T\t{key}\t{}\t{}\t{}\t{roots}\t{assignment}",
        plan.blocks, plan.threads, plan.est_exec_us
    )
}

fn parse_tuned_line(rest: &str) -> Option<(String, TunedPlan)> {
    let mut f = rest.split('\t');
    let key = f.next()?.to_string();
    let blocks = f.next()?.parse().ok()?;
    let threads = f.next()?.parse().ok()?;
    let est_exec_us = f.next()?.parse().ok()?;
    let roots_text = f.next()?;
    let assign_text = f.next()?;

    let mut root_schedules = Vec::new();
    if roots_text != "-" {
        for item in roots_text.split(';') {
            let (id, s) = item.split_once('=')?;
            root_schedules.push((InstrId(id.parse().ok()?), sched_from_text(s)?));
        }
    }
    let mut assignment = std::collections::BTreeMap::new();
    if assign_text != "-" {
        for item in assign_text.split(';') {
            let (id, s) = item.split_once('=')?;
            let st = if s == "I" {
                OpSchedule::Inlined
            } else {
                OpSchedule::Scheduled(sched_from_text(s)?)
            };
            assignment.insert(InstrId(id.parse().ok()?), st);
        }
    }
    Some((key, TunedPlan { root_schedules, assignment, blocks, threads, est_exec_us }))
}

// ---------------------------------------------------------------------
// Measured-entry text (de)serialization
// ---------------------------------------------------------------------
//
// One line per group:
//   M\t<key>\t<fp:016x>\t<count>\t<samples>
// where <samples> is the retained min-k sample set, comma-joined in
// ascending order (`-` when empty).

fn format_measured_line(key: &str, e: &MeasuredEntry) -> String {
    let samples = if e.samples_us.is_empty() {
        "-".to_string()
    } else {
        e.samples_us.iter().map(f64::to_string).collect::<Vec<_>>().join(",")
    };
    format!("M\t{key}\t{:016x}\t{}\t{samples}", e.fp, e.count)
}

fn parse_measured_line(rest: &str) -> Option<(String, MeasuredEntry)> {
    let mut f = rest.split('\t');
    let key = f.next()?.to_string();
    let fp = u64::from_str_radix(f.next()?, 16).ok()?;
    let count = f.next()?.parse().ok()?;
    let samples_text = f.next()?;
    let mut samples_us = Vec::new();
    if samples_text != "-" {
        for s in samples_text.split(',') {
            samples_us.push(s.parse().ok()?);
        }
    }
    Some((key, MeasuredEntry { fp, count, samples_us }))
}

/// Build the resource descriptor of a standalone kernel computing `id`
/// under `sched`. Encodes the schedule-sensitivity the tuner needs:
/// coalescing differs between Row/Column reductions and transposes, and
/// expensive elementwise ops carry a higher instruction weight.
pub fn kernel_desc(
    comp: &Computation,
    id: InstrId,
    sched: Schedule,
    threads: u32,
    _dev: &DeviceConfig,
) -> KernelDesc {
    let i = comp.get(id);
    let out_bytes = i.shape.byte_size() as u64;
    let in_bytes: u64 = comp.operand_shapes(id).iter().map(|s| s.byte_size() as u64).sum();
    let out_elems = i.shape.num_elements() as u64;
    let in_elems: u64 =
        comp.operand_shapes(id).iter().map(|s| s.num_elements() as u64).sum();
    let blocks = sched.blocks(&i.shape);

    let (flops, coalescing, op_weight) = match i.opcode {
        op if op.is_expensive_elementwise() => (out_elems, 1.0, 8.0),
        op if op.is_elementwise() => (out_elems, 1.0, 1.0),
        Opcode::Reduce | Opcode::ReduceWindow => {
            let c = match sched.sched_type {
                // Row: the reduced (minor-side) window is contiguous per
                // thread → coalesced streaming.
                SchedType::Row => 0.95,
                // Column: strided access across the reduced window — the
                // "column reductions" XLA's rules trip over (§1).
                SchedType::Column => 0.55,
            };
            (in_elems, c, 1.0)
        }
        Opcode::Transpose => (0, 0.55, 1.0),
        Opcode::Broadcast | Opcode::Reshape | Opcode::Bitcast | Opcode::Copy => (0, 1.0, 1.0),
        Opcode::Concatenate | Opcode::Slice | Opcode::Pad => (0, 0.9, 1.0),
        Opcode::Gather | Opcode::DynamicSlice | Opcode::DynamicUpdateSlice => (0, 0.5, 1.0),
        Opcode::BatchDot => {
            let r = i.shape.rank();
            let k = comp.operand_shapes(id)[0].dims[r - 1] as u64;
            (2 * out_elems * k, 0.9, 1.0)
        }
        _ => (out_elems, 0.9, 1.0),
    };

    KernelDesc {
        bytes_read: in_bytes,
        bytes_written: out_bytes,
        flops,
        blocks,
        threads,
        smem_bytes: 0,
        coalescing,
        op_weight,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::{GraphBuilder, Shape};

    fn reduce_graph() -> (Computation, InstrId) {
        let mut b = GraphBuilder::new("pl");
        let x = b.param("x", Shape::f32(&[64, 256]));
        let r = b.reduce(x, &[1], ReduceKind::Sum);
        let c = b.finish(r);
        (c, r)
    }

    #[test]
    fn miss_then_hit() {
        let (c, r) = reduce_graph();
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let s = Schedule::new(0, 8, SchedType::Row);
        let t1 = lib.lookup(&c, r, s, 256);
        assert_eq!(lib.len(), 1);
        assert_eq!(lib.hit_rate(), 0.0);
        let t2 = lib.lookup(&c, r, s, 256);
        assert_eq!(t1, t2);
        assert!(lib.hit_rate() > 0.4);
    }

    #[test]
    fn row_reduce_beats_column_reduce() {
        // The schedule-sensitivity signal the tuner relies on.
        let (c, r) = reduce_graph();
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let row = lib.lookup(&c, r, Schedule::new(0, 64, SchedType::Row), 256);
        let col = lib.lookup(&c, r, Schedule::new(0, 64, SchedType::Column), 256);
        assert!(row < col, "row {row} should beat column {col}");
    }

    #[test]
    fn more_blocks_help_large_ops() {
        let mut b = GraphBuilder::new("big");
        let x = b.param("x", Shape::f32(&[4096, 1024]));
        let e = b.exp(x);
        let c = b.finish(e);
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let few = lib.lookup(&c, e, Schedule::new(0, 1, SchedType::Row), 256);
        let many = lib.lookup(&c, e, Schedule::new(0, 4096, SchedType::Row), 256);
        assert!(many < few);
    }

    #[test]
    fn persistence_roundtrip() {
        let (c, r) = reduce_graph();
        let dir = crate::testutil::TempDir::new("perf");
        let path = dir.path().join("perf.tsv");
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let t = lib.lookup(&c, r, Schedule::new(0, 4, SchedType::Row), 128);
        lib.save(&path).unwrap();
        let mut lib2 = PerfLibrary::load(&path, DeviceConfig::pascal());
        assert_eq!(lib2.len(), 1);
        let t2 = lib2.lookup(&c, r, Schedule::new(0, 4, SchedType::Row), 128);
        assert_eq!(t, t2);
        assert_eq!(lib2.hit_rate(), 1.0);
    }

    #[test]
    fn tuned_plan_roundtrips_through_disk() {
        use crate::schedule::propagate::OpSchedule;
        let dir = crate::testutil::TempDir::new("tuned");
        let path = dir.path().join("perf.tsv");
        let plan = TunedPlan {
            root_schedules: vec![(InstrId(3), Schedule::new(1, 8, SchedType::Column))],
            assignment: [
                (InstrId(2), OpSchedule::Inlined),
                (InstrId(3), OpSchedule::Scheduled(Schedule::new(1, 8, SchedType::Column))),
            ]
            .into_iter()
            .collect(),
            blocks: 64,
            threads: 256,
            est_exec_us: 12.5,
        };
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        lib.tuned_insert("fp123|FusionStitching|g0".to_string(), plan.clone());
        // also one ordinary perf entry, to prove the formats coexist
        let (c, r) = reduce_graph();
        lib.lookup(&c, r, Schedule::new(0, 4, SchedType::Row), 128);
        lib.save(&path).unwrap();

        let mut lib2 = PerfLibrary::load(&path, DeviceConfig::pascal());
        assert_eq!(lib2.len(), 1);
        assert_eq!(lib2.tuned_len(), 1);
        let got = lib2.tuned_lookup("fp123|FusionStitching|g0").unwrap();
        assert_eq!(got.blocks, plan.blocks);
        assert_eq!(got.threads, plan.threads);
        assert_eq!(got.est_exec_us, plan.est_exec_us);
        assert_eq!(got.root_schedules, plan.root_schedules);
        assert_eq!(got.assignment, plan.assignment);
        assert_eq!(lib2.tuned_hits(), 1);
        assert!(lib2.tuned_lookup("missing").is_none());
    }

    #[test]
    fn device_change_invalidates_persisted_entries() {
        // A library saved under one DeviceConfig must not serve stale
        // schedules/costs after a device change — every store keys on
        // the device signature, so mismatches read as misses.
        let (c, r) = reduce_graph();
        let dir = crate::testutil::TempDir::new("devsig");
        let path = dir.path().join("perf.tsv");
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        lib.lookup(&c, r, Schedule::new(0, 4, SchedType::Row), 128);
        lib.tuned_insert(
            "fp|g0".to_string(),
            TunedPlan {
                root_schedules: vec![(InstrId(1), Schedule::fallback())],
                assignment: std::collections::BTreeMap::new(),
                blocks: 1,
                threads: 128,
                est_exec_us: 3.0,
            },
        );
        lib.explore_insert("xg1", 7.5);
        lib.save(&path).unwrap();

        // Same name, different constants: still a different device.
        let mut other = DeviceConfig::pascal();
        other.launch_overhead_us = 9.0;
        let mut lib2 = PerfLibrary::load(&path, other);
        assert!(lib2.tuned_lookup("fp|g0").is_none(), "tuned plan must miss");
        assert!(lib2.explore_lookup("xg1").is_none(), "explore memo must miss");
        lib2.lookup(&c, r, Schedule::new(0, 4, SchedType::Row), 128);
        assert_eq!(lib2.hit_rate(), 0.0, "schedule entry must re-derive, not hit");

        // The original device keeps hitting its own entries.
        let mut lib3 = PerfLibrary::load(&path, DeviceConfig::pascal());
        assert!(lib3.tuned_lookup("fp|g0").is_some());
        assert_eq!(lib3.explore_lookup("xg1"), Some(7.5));
        lib3.lookup(&c, r, Schedule::new(0, 4, SchedType::Row), 128);
        assert_eq!(lib3.hit_rate(), 1.0);
    }

    #[test]
    fn explore_memo_roundtrips_through_disk() {
        let dir = crate::testutil::TempDir::new("explore");
        let path = dir.path().join("perf.tsv");
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        assert_eq!(lib.explore_len(), 0);
        assert!(lib.explore_lookup("xg42").is_none());
        assert_eq!(lib.explore_hits(), 0);
        lib.explore_insert("xg42", 12.25);
        assert_eq!(lib.explore_lookup("xg42"), Some(12.25));
        assert_eq!(lib.explore_hits(), 1);
        lib.save(&path).unwrap();

        let mut lib2 = PerfLibrary::load(&path, DeviceConfig::pascal());
        assert_eq!(lib2.explore_len(), 1);
        assert_eq!(lib2.explore_lookup("xg42"), Some(12.25));
    }

    #[test]
    fn measured_roundtrip_keeps_samples() {
        let dir = crate::testutil::TempDir::new("measured");
        let path = dir.path().join("perf.tsv");
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let samples: Vec<f64> = (0..12).map(|i| 10.0 + i as f64).collect();
        lib.measured_record(0xfeed, &samples, 12);
        lib.measured_record(0xbeef, &[5.0, 6.0], 2); // below the gate
        let est = lib.measured_estimate(0xfeed).expect("12 launches pass the gate");
        assert!(lib.measured_estimate(0xbeef).is_none(), "2 launches stay gated");
        assert_eq!(lib.measured_len(), 2);
        assert_eq!(lib.measured_epoch(), 14);
        lib.save(&path).unwrap();

        let lib2 = PerfLibrary::load(&path, DeviceConfig::pascal());
        assert_eq!(lib2.measured_len(), 2);
        assert_eq!(lib2.measured_epoch(), 14);
        assert_eq!(lib2.measured_estimate(0xfeed), Some(est));
        let e = lib2.measured_entry(0xfeed).unwrap();
        assert_eq!(e.count, 12);
        assert_eq!(e.samples_us, samples, "round-trip keeps every retained sample");
        let overrides = lib2.measured_overrides();
        assert_eq!(overrides.len(), 1);
        assert_eq!(overrides[&0xfeed], est);
    }

    #[test]
    fn measured_device_mismatch_reads_as_miss() {
        let dir = crate::testutil::TempDir::new("measured-dev");
        let path = dir.path().join("perf.tsv");
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        lib.measured_record(0xfeed, &[1.0; 16], 16);
        assert!(lib.measured_estimate(0xfeed).is_some());
        lib.save(&path).unwrap();

        let mut other = DeviceConfig::pascal();
        other.launch_overhead_us = 9.0;
        let lib2 = PerfLibrary::load(&path, other);
        assert!(lib2.measured_estimate(0xfeed).is_none(), "other device must miss");
        assert_eq!(lib2.measured_len(), 0);
        assert_eq!(lib2.measured_epoch(), 0);
        assert!(lib2.measured_overrides().is_empty());

        // the original device still reads its own entries
        let lib3 = PerfLibrary::load(&path, DeviceConfig::pascal());
        assert!(lib3.measured_estimate(0xfeed).is_some());
    }

    #[test]
    fn measured_merge_is_deterministic() {
        // Concurrent workers write back the same sample multiset in
        // arbitrary interleavings; min-k retention must make the final
        // entry independent of arrival order and partitioning.
        let all: Vec<f64> = (0..200).map(|i| 100.0 + ((i * 37) % 100) as f64).collect();
        let mut forward = PerfLibrary::new(DeviceConfig::pascal());
        for chunk in all.chunks(7) {
            forward.measured_record(0xabc, chunk, chunk.len() as u64);
        }
        let mut backward = PerfLibrary::new(DeviceConfig::pascal());
        let mut rev = all.clone();
        rev.reverse();
        for chunk in rev.chunks(31) {
            backward.measured_record(0xabc, chunk, chunk.len() as u64);
        }
        let (a, b) =
            (forward.measured_entry(0xabc).unwrap(), backward.measured_entry(0xabc).unwrap());
        assert_eq!(a, b, "write-back merge must not depend on arrival order");
        assert_eq!(a.samples_us.len(), MEASURED_MAX_SAMPLES);
        assert_eq!(forward.measured_estimate(0xabc), backward.measured_estimate(0xabc));
    }

    #[test]
    fn measured_estimate_trims_outliers() {
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        // 15 clean samples at ~10µs plus one preempted outlier
        let mut samples = vec![10.0; 15];
        samples.push(10_000.0);
        lib.measured_record(1, &samples, 16);
        let est = lib.measured_estimate(1).unwrap();
        assert!((est - 10.0).abs() < 1e-9, "trimmed mean must drop the outlier, got {est}");
    }

    #[test]
    fn absorb_profile_is_idempotent_per_snapshot() {
        use crate::exec::StitchTier;
        let mut profile = crate::obs::KernelProfile::default();
        for i in 0..10 {
            profile.record_launch(0x77, StitchTier::Plain, 2.0, 4.0 + i as f64, 0, 0);
        }
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        assert_eq!(lib.absorb_profile(&profile), 10);
        assert_eq!(lib.measured_epoch(), 10);
        // the same snapshot again: nothing new to absorb
        assert_eq!(lib.absorb_profile(&profile), 0);
        assert_eq!(lib.measured_epoch(), 10);
        // four more launches: only the delta counts
        for _ in 0..4 {
            profile.record_launch(0x77, StitchTier::Plain, 2.0, 4.5, 0, 0);
        }
        assert_eq!(lib.absorb_profile(&profile), 4);
        assert_eq!(lib.measured_epoch(), 14);
        assert!(lib.measured_estimate(0x77).is_some());
    }

    #[test]
    fn key_distinguishes_thread_block_size() {
        let (c, r) = reduce_graph();
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let s = Schedule::new(0, 8, SchedType::Row);
        lib.lookup(&c, r, s, 128);
        lib.lookup(&c, r, s, 512);
        assert_eq!(lib.len(), 2);
    }
}
