//! Schedule tuning — §4.3.
//!
//! Iterates the candidate schedules of the fusion root(s), tests each for
//! satisfiability via [`super::propagate`], scores satisfiable ones by
//! summing per-op kernel times from the performance library, and returns
//! the best implementation plan.
//!
//! Implements both of the paper's optimizations:
//! 1. computationally trivial shape-modulation ops are bypassed (inlined
//!    via thread composition) rather than letting their strict shape
//!    modulation reject good schedules — handled inside propagation and
//!    by skipping `Inlined` members during scoring;
//! 2. best-so-far pruning: scoring aborts as soon as the accumulated time
//!    exceeds the current best.
//!
//! Multi-root computations use the two-stage search: stage one intersects
//! the valid `blocks` sets of all roots; stage two only scores schedule
//! combinations whose grid lies in the intersection.

use super::oracle::{CostOracle, ModeledCost};
use super::perf_library::PerfLibrary;
use super::propagate::{propagate, OpSchedule, PropagationResult};
use super::spec::Schedule;
use crate::hlo::{Computation, InstrId};
use std::collections::{BTreeMap, BTreeSet, HashSet};

/// Tuning knobs.
#[derive(Debug, Clone)]
pub struct TuningConfig {
    /// Thread-block sizes to consider — multiples of the warp size in
    /// `[1, 1024]` (§4.4).
    pub thread_candidates: Vec<u32>,
    /// Cap on root schedules examined per root (the schedule space is
    /// small in practice; this is a safety bound for huge dims).
    pub max_schedules_per_root: usize,
}

impl Default for TuningConfig {
    fn default() -> Self {
        TuningConfig { thread_candidates: vec![256, 512], max_schedules_per_root: 24 }
    }
}

/// The tuned implementation plan handed to code generation: launch
/// parameters plus the per-op schedule assignment.
#[derive(Debug, Clone)]
pub struct TunedPlan {
    /// Chosen schedule per fusion root.
    pub root_schedules: Vec<(InstrId, Schedule)>,
    /// Per-member emitter assignment.
    pub assignment: BTreeMap<InstrId, OpSchedule>,
    /// Grid size (launch dimension).
    pub blocks: u64,
    /// Thread-block size (launch dimension).
    pub threads: u32,
    /// Estimated kernel execution time (sum of member op times — the
    /// paper's accumulated-performance metric, §4.4 last paragraph).
    pub est_exec_us: f64,
}

/// Tune the fused computation `members` with the given `roots`. Returns
/// `None` when no root schedule satisfies the constraints — the signal
/// `SchdConsistent` uses to reject a fusion candidate.
pub fn tune(
    comp: &Computation,
    members: &HashSet<InstrId>,
    roots: &[InstrId],
    lib: &mut PerfLibrary,
    cfg: &TuningConfig,
) -> Option<TunedPlan> {
    tune_with_oracle(comp, members, roots, lib, cfg, &ModeledCost)
}

/// [`tune`] against an explicit [`CostOracle`]: the per-op scoring
/// lookups route through `oracle.schedule_cost_us`, so a measured
/// backend can overlay what it has data for while everything else
/// stays the analytic path. `tune` itself is this with [`ModeledCost`]
/// — bit-identical to the pre-oracle behavior.
pub fn tune_with_oracle(
    comp: &Computation,
    members: &HashSet<InstrId>,
    roots: &[InstrId],
    lib: &mut PerfLibrary,
    cfg: &TuningConfig,
    oracle: &dyn CostOracle,
) -> Option<TunedPlan> {
    if roots.is_empty() {
        return None;
    }
    if roots.len() == 1 {
        tune_single_root(comp, members, roots[0], lib, cfg, oracle)
    } else {
        tune_multi_root(comp, members, roots, lib, cfg, oracle)
    }
}

fn candidate_schedules(comp: &Computation, root: InstrId, cap: usize) -> Vec<Schedule> {
    let mut v = Schedule::enumerate(&comp.get(root).shape);
    v.truncate(cap);
    v
}

fn tune_single_root(
    comp: &Computation,
    members: &HashSet<InstrId>,
    root: InstrId,
    lib: &mut PerfLibrary,
    cfg: &TuningConfig,
    oracle: &dyn CostOracle,
) -> Option<TunedPlan> {
    let mut best: Option<TunedPlan> = None;
    for sched in candidate_schedules(comp, root, cfg.max_schedules_per_root) {
        let Ok(prop) = propagate(comp, members, &[(root, sched)]) else {
            continue;
        };
        score_and_keep(comp, &[(root, sched)], &prop, lib, cfg, oracle, &mut best);
    }
    best
}

fn tune_multi_root(
    comp: &Computation,
    members: &HashSet<InstrId>,
    roots: &[InstrId],
    lib: &mut PerfLibrary,
    cfg: &TuningConfig,
    oracle: &dyn CostOracle,
) -> Option<TunedPlan> {
    // No roots → nothing to pair schedules over (also keeps the max()
    // below total, should a future caller bypass `tune`'s own guard).
    if roots.is_empty() {
        return None;
    }
    // Stage 1: valid blocks set per root, then intersect (§4.3).
    let mut per_root: Vec<Vec<(u64, Schedule)>> = Vec::with_capacity(roots.len());
    let mut common: Option<BTreeSet<u64>> = None;
    for &root in roots {
        let shape = &comp.get(root).shape;
        let cands: Vec<(u64, Schedule)> = candidate_schedules(comp, root, cfg.max_schedules_per_root)
            .into_iter()
            .map(|s| (s.blocks(shape), s))
            .collect();
        let blocks: BTreeSet<u64> = cands.iter().map(|(b, _)| *b).collect();
        common = Some(match common {
            None => blocks,
            Some(c) => c.intersection(&blocks).copied().collect(),
        });
        per_root.push(cands);
    }
    let common = common?;

    // Stage 2: iterate grids in the agreed blocks set; for each grid take
    // each root's candidate schedules at that grid. To keep the
    // combination count bounded we pair schedules positionally per grid
    // (first-valid per root first), scoring with best-so-far pruning.
    let mut best: Option<TunedPlan> = None;
    for &b in common.iter().rev() {
        // prefer larger grids first: tends to reach good plans (and thus
        // effective pruning) sooner
        let lists: Vec<Vec<Schedule>> = per_root
            .iter()
            .map(|cands| cands.iter().filter(|(bb, _)| *bb == b).map(|(_, s)| *s).collect())
            .collect();
        if lists.iter().any(|l: &Vec<Schedule>| l.is_empty()) {
            continue;
        }
        let max_len = lists.iter().map(Vec::len).max().unwrap_or(0);
        // Positional pairing clamps short lists to their last schedule,
        // which re-creates the same combo once per excess index when
        // roots have unequal candidate counts — dedup before the
        // expensive propagate + scoring.
        let mut seen: HashSet<Vec<(InstrId, Schedule)>> = HashSet::new();
        for k in 0..max_len {
            let combo: Vec<(InstrId, Schedule)> = roots
                .iter()
                .zip(&lists)
                .map(|(&r, l)| (r, l[k.min(l.len() - 1)]))
                .collect();
            if !seen.insert(combo.clone()) {
                continue;
            }
            let Ok(prop) = propagate(comp, members, &combo) else {
                continue;
            };
            score_and_keep(comp, &combo, &prop, lib, cfg, oracle, &mut best);
        }
    }
    best
}

/// Score one satisfiable plan across thread-candidate sizes, with the
/// paper's best-so-far pruning, updating `best` in place. Per-op times
/// come from the oracle's schedule seam (the modeled default is the
/// perf-library lookup).
fn score_and_keep(
    comp: &Computation,
    root_schedules: &[(InstrId, Schedule)],
    prop: &PropagationResult,
    lib: &mut PerfLibrary,
    cfg: &TuningConfig,
    oracle: &dyn CostOracle,
    best: &mut Option<TunedPlan>,
) {
    for &threads in &cfg.thread_candidates {
        let budget = best.as_ref().map(|b| b.est_exec_us).unwrap_or(f64::INFINITY);
        let mut total = 0.0;
        let mut pruned = false;
        for (&id, st) in &prop.assignment {
            if let OpSchedule::Scheduled(s) = st {
                // Trivial modulation ops are ignored during evaluation
                // (§4.3 optimization 1) even when scheduled.
                if comp.get(id).opcode.is_trivially_inlinable() {
                    continue;
                }
                total += oracle.schedule_cost_us(lib, comp, id, *s, threads);
                if total >= budget {
                    pruned = true; // §4.3 optimization 2
                    break;
                }
            }
        }
        if !pruned && total < budget {
            *best = Some(TunedPlan {
                root_schedules: root_schedules.to_vec(),
                assignment: prop.assignment.clone(),
                blocks: prop.blocks,
                threads,
                est_exec_us: total,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::DeviceConfig;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::{GraphBuilder, Shape};

    fn members_of(comp: &Computation) -> HashSet<InstrId> {
        comp.ids().filter(|&i| !comp.get(i).opcode.is_free()).collect()
    }

    #[test]
    fn fallback_always_tunable() {
        // Any fused computation admits the (0, 1, Row) schedule (§4.3),
        // so tuning a well-formed group must succeed.
        let mut b = GraphBuilder::new("fb");
        let x = b.param("x", Shape::f32(&[32, 16]));
        let e = b.exp(x);
        let r = b.reduce(e, &[0, 1], ReduceKind::Sum); // full reduce: 1 block only
        let comp = b.finish(r);
        let plan =
            tune(&comp, &members_of(&comp), &[r], &mut PerfLibrary::new(DeviceConfig::pascal()), &TuningConfig::default())
                .expect("fallback must exist");
        assert_eq!(plan.blocks, 1);
    }

    #[test]
    fn tuner_prefers_parallel_grids() {
        let mut b = GraphBuilder::new("par");
        let x = b.param("x", Shape::f32(&[512, 1024]));
        let e = b.exp(x);
        let t = b.tanh(e);
        let comp = b.finish(t);
        let plan = tune(
            &comp,
            &members_of(&comp),
            &[t],
            &mut PerfLibrary::new(DeviceConfig::pascal()),
            &TuningConfig::default(),
        )
        .unwrap();
        assert!(plan.blocks > 16, "expected a parallel grid, got {}", plan.blocks);
    }

    #[test]
    fn tuner_picks_row_for_minor_reduce() {
        let mut b = GraphBuilder::new("rr");
        let x = b.param("x", Shape::f32(&[256, 2048]));
        let e = b.mul(x, x);
        let r = b.reduce(e, &[1], ReduceKind::Sum);
        let comp = b.finish(r);
        let plan = tune(
            &comp,
            &members_of(&comp),
            &[r],
            &mut PerfLibrary::new(DeviceConfig::pascal()),
            &TuningConfig::default(),
        )
        .unwrap();
        let (_, s) = plan.root_schedules[0];
        assert_eq!(s.sched_type, super::super::spec::SchedType::Row);
    }

    #[test]
    fn multi_root_agrees_on_grid() {
        // Two independent elementwise chains fused by ElementwiseFusion:
        // same output shapes → blocks sets intersect richly.
        let mut b = GraphBuilder::new("mr");
        let x = b.param("x", Shape::f32(&[128, 64]));
        let y = b.param("y", Shape::f32(&[128, 64]));
        let e = b.exp(x);
        let t = b.tanh(y);
        let comp = b.finish(t);
        let members: HashSet<InstrId> = [e, t].into_iter().collect();
        let plan = tune(
            &comp,
            &members,
            &[e, t],
            &mut PerfLibrary::new(DeviceConfig::pascal()),
            &TuningConfig::default(),
        )
        .unwrap();
        assert_eq!(plan.root_schedules.len(), 2);
        let s0 = plan.root_schedules[0].1;
        let s1 = plan.root_schedules[1].1;
        assert_eq!(
            s0.blocks(&comp.get(e).shape),
            s1.blocks(&comp.get(t).shape),
            "grids must agree"
        );
        assert!(plan.blocks >= 1);
    }

    #[test]
    fn multi_root_mismatched_shapes_still_intersect_at_common_grids() {
        let mut b = GraphBuilder::new("mm");
        let x = b.param("x", Shape::f32(&[96, 8]));
        let y = b.param("y", Shape::f32(&[64, 32]));
        let e = b.exp(x);
        let t = b.tanh(y);
        let comp = b.finish(t);
        let members: HashSet<InstrId> = [e, t].into_iter().collect();
        let plan = tune(
            &comp,
            &members,
            &[e, t],
            &mut PerfLibrary::new(DeviceConfig::pascal()),
            &TuningConfig::default(),
        );
        // 96 and 64 share divisors {1,2,4,8,16,32,96*...}: grids like 32
        // exist, so tuning succeeds.
        assert!(plan.is_some());
    }

    #[test]
    fn unsatisfiable_group_returns_none() {
        // A slice consuming an in-group producer can't block-compose.
        let mut b = GraphBuilder::new("bad");
        let x = b.param("x", Shape::f32(&[16, 16]));
        let e = b.exp(x);
        let s = b.slice(e, &[0, 0], &[8, 16]);
        let t = b.tanh(s);
        let comp = b.finish(t);
        let members: HashSet<InstrId> = [e, s, t].into_iter().collect();
        let plan = tune(
            &comp,
            &members,
            &[t],
            &mut PerfLibrary::new(DeviceConfig::pascal()),
            &TuningConfig::default(),
        );
        // e is only reachable through the slice, which unconstrains it →
        // e (non-trivial) is inlined; plan may exist. What must hold: if
        // a plan exists, the slice is never Scheduled with its own loop
        // over an in-group producer.
        if let Some(p) = plan {
            // slice itself may be scheduled (it reads DRAM-visible data
            // only if e were external — e is in-group, so e must be
            // Inlined in the plan)
            assert_eq!(p.assignment.get(&e), Some(&OpSchedule::Inlined));
        }
    }

    #[test]
    fn empty_root_set_is_rejected_not_a_panic() {
        let mut b = GraphBuilder::new("empty");
        let x = b.param("x", Shape::f32(&[64]));
        let e = b.exp(x);
        let comp = b.finish(e);
        let members: HashSet<InstrId> = [e].into_iter().collect();
        let plan = tune(
            &comp,
            &members,
            &[],
            &mut PerfLibrary::new(DeviceConfig::pascal()),
            &TuningConfig::default(),
        );
        assert!(plan.is_none());
    }

    #[test]
    fn unequal_candidate_counts_tune_deterministically() {
        // Roots with different shapes have different-length candidate
        // lists at a shared grid; the clamped pairing must dedup the
        // repeated combos and still land on one best plan, stably.
        let mut b = GraphBuilder::new("uneq");
        let x = b.param("x", Shape::f32(&[96, 8]));
        let y = b.param("y", Shape::f32(&[64, 32]));
        let e = b.exp(x);
        let t = b.tanh(y);
        let comp = b.finish(t);
        let members: HashSet<InstrId> = [e, t].into_iter().collect();
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let a = tune(&comp, &members, &[e, t], &mut lib, &TuningConfig::default())
            .expect("shared grids exist");
        let b2 = tune(&comp, &members, &[e, t], &mut lib, &TuningConfig::default()).unwrap();
        assert_eq!(a.blocks, b2.blocks);
        assert_eq!(a.threads, b2.threads);
        assert_eq!(a.root_schedules, b2.root_schedules);
        assert!(a.est_exec_us > 0.0);
    }

    #[test]
    fn est_time_is_positive_and_bounded() {
        let mut b = GraphBuilder::new("est");
        let x = b.param("x", Shape::f32(&[64, 64]));
        let e = b.exp(x);
        let comp = b.finish(e);
        let plan = tune(
            &comp,
            &members_of(&comp),
            &[e],
            &mut PerfLibrary::new(DeviceConfig::pascal()),
            &TuningConfig::default(),
        )
        .unwrap();
        assert!(plan.est_exec_us > 0.0 && plan.est_exec_us < 1e6);
    }
}
