//! Schedule constraint propagation — §4.2 / Table 1.
//!
//! Given schedules for the roots of a fused computation, decide whether
//! they are satisfiable by every member instruction, and if so derive the
//! per-instruction schedule assignment. Propagation walks backwards
//! (root → operands), transforming `(split_dim, sword)` through shape
//! modulation and rejecting combinations Table 1 forbids (e.g. splitting
//! inside a reduce's reduction dims).
//!
//! Every instruction in one kernel must agree on the grid — the `blocks`
//! count — because block composition (§5) stitches their per-block data
//! chunks through shared memory, which is private to a block.

use super::spec::{SchedType, Schedule};
use crate::hlo::{Computation, InstrId, Opcode, Shape};
use std::collections::{BTreeMap, HashSet};

/// What codegen will do with one member of the fused computation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OpSchedule {
    /// Op gets its own parallel loop emitter under this schedule
    /// (block composition).
    Scheduled(Schedule),
    /// Op is folded into its consumer's loop (thread composition), like
    /// XLA's elemental IR emitter — used for trivially-inlinable shape
    /// modulation (§4.3 optimization 1).
    Inlined,
}

/// Successful propagation: a consistent assignment for all members.
#[derive(Debug, Clone)]
pub struct PropagationResult {
    pub assignment: BTreeMap<InstrId, OpSchedule>,
    /// Common grid size shared by every scheduled member.
    pub blocks: u64,
}

/// Why propagation failed. Feeds the fusion pass's `SchdConsistent`
/// decision and (via tuning) the shared-memory feedback loop.
#[derive(Debug, Clone, PartialEq)]
pub enum Unsatisfiable {
    /// Table 1 rejects the schedule at this instruction.
    RuleViolation(InstrId, &'static str),
    /// Two users demand different schedules of the same producer.
    Conflict(InstrId),
    /// Root schedule invalid for the root shape.
    BadRootSchedule(InstrId),
}

impl std::fmt::Display for Unsatisfiable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Unsatisfiable::RuleViolation(id, why) => write!(f, "rule violation at {id}: {why}"),
            Unsatisfiable::Conflict(id) => write!(f, "conflicting schedules demanded of {id}"),
            Unsatisfiable::BadRootSchedule(id) => write!(f, "invalid root schedule at {id}"),
        }
    }
}

/// Propagate root schedules through the fused computation `members`.
///
/// `roots` pairs each fusion root with its candidate schedule. All
/// non-root members must be reachable from some root through operand
/// edges within `members`.
pub fn propagate(
    comp: &Computation,
    members: &HashSet<InstrId>,
    roots: &[(InstrId, Schedule)],
) -> Result<PropagationResult, Unsatisfiable> {
    let mut assignment: BTreeMap<InstrId, OpSchedule> = BTreeMap::new();
    let mut blocks: Option<u64> = None;

    // Seed roots, checking validity and grid agreement (§4.3's multi-root
    // blocks intersection reduces to this check here).
    for &(root, sched) in roots {
        let shape = &comp.get(root).shape;
        if !sched.is_valid_for(shape) {
            return Err(Unsatisfiable::BadRootSchedule(root));
        }
        let b = sched.blocks(shape);
        match blocks {
            None => blocks = Some(b),
            Some(prev) if prev != b => {
                return Err(Unsatisfiable::RuleViolation(root, "roots disagree on grid size"))
            }
            _ => {}
        }
        merge(&mut assignment, root, OpSchedule::Scheduled(sched))?;
    }
    let blocks = blocks.unwrap_or(1);

    // Members are ids into one arena; descending id order is reverse
    // topological, so each instruction is processed after all its users.
    let mut order: Vec<InstrId> = members.iter().copied().collect();
    order.sort_unstable_by(|a, b| b.cmp(a));

    for id in order {
        let state = match assignment.get(&id) {
            Some(&OpSchedule::Scheduled(s)) => s,
            Some(&OpSchedule::Inlined) => {
                // Inlined ops impose no constraint of their own; their
                // operands were already handled when the op was inlined.
                continue;
            }
            None => {
                // Never demanded by any user: only acceptable for ops we
                // can always inline (e.g. a dead-end trivial op) — reject
                // otherwise so fusion keeps groups connected.
                if comp.get(id).opcode.is_trivially_inlinable() {
                    assignment.insert(id, OpSchedule::Inlined);
                    continue;
                }
                return Err(Unsatisfiable::RuleViolation(id, "member unreachable from roots"));
            }
        };
        for (operand, req) in propagate_one(comp, members, id, state)? {
            debug_assert!(members.contains(&operand));
            match req {
                Some(s) => merge(&mut assignment, operand, OpSchedule::Scheduled(s))?,
                None => {
                    // Constraint-free operand (e.g. a broadcast's small
                    // input): recomputed per block via thread
                    // composition. Reductions and contractions cannot be
                    // thread-composed (no single-lane form) — reject.
                    let oc = comp.get(operand).opcode;
                    if oc.is_reduce() || oc == Opcode::BatchDot {
                        return Err(Unsatisfiable::RuleViolation(
                            operand,
                            "reduce/batch-dot cannot be thread-composed",
                        ));
                    }
                    assignment.entry(operand).or_insert(OpSchedule::Inlined);
                }
            }
        }
    }

    Ok(PropagationResult { assignment, blocks })
}

fn merge(
    assignment: &mut BTreeMap<InstrId, OpSchedule>,
    id: InstrId,
    new: OpSchedule,
) -> Result<(), Unsatisfiable> {
    match assignment.get(&id) {
        None => {
            assignment.insert(id, new);
            Ok(())
        }
        Some(old) if *old == new => Ok(()),
        // An op already marked Inlined can be upgraded to Scheduled by a
        // stronger demand; two *different* schedules conflict.
        Some(OpSchedule::Inlined) => {
            assignment.insert(id, new);
            Ok(())
        }
        Some(OpSchedule::Scheduled(_)) if new == OpSchedule::Inlined => Ok(()),
        _ => Err(Unsatisfiable::Conflict(id)),
    }
}

/// Requirements `id`'s schedule imposes on each **in-group** operand:
/// `Some(s)` = the operand must run under schedule `s`; `None` =
/// unconstrained (recomputed per block via thread composition).
///
/// Operands outside `members` are kernel inputs read from DRAM — blocks
/// can read arbitrary regions of them, so Table 1's structural rules
/// only apply along in-group edges (where a producer must deposit
/// exactly the consumer's per-block chunk into shared memory).
fn propagate_one(
    comp: &Computation,
    members: &HashSet<InstrId>,
    id: InstrId,
    sched: Schedule,
) -> Result<Vec<(InstrId, Option<Schedule>)>, Unsatisfiable> {
    let instr = comp.get(id);
    let out_shape = &instr.shape;
    let ops = &instr.operands;
    use Opcode::*;

    let internal = |o: &InstrId| members.contains(o);
    let same_for_internal = |s: Schedule| -> Vec<(InstrId, Option<Schedule>)> {
        ops.iter().filter(|o| internal(o)).map(|&o| (o, Some(s))).collect()
    };

    if instr.opcode.is_library_call() {
        return Err(Unsatisfiable::RuleViolation(id, "library calls are never fused"));
    }

    // §4.3: "There is always a valid Row schedule for any fused
    // computation, with split_dim = 0 and sword = 1. In this case, we
    // only use one thread block for all instructions." A single block
    // sees every operand chunk whole, so all directional rules pass.
    if instr.opcode.is_fusable() && sched.blocks(out_shape) == 1 {
        return Ok(ops
            .iter()
            .filter(|o| internal(o))
            .map(|&o| (o, Some(Schedule::fallback())))
            .collect());
    }

    match instr.opcode {
        // Table 1: Elementwise — pass Row, Column unchanged.
        op if op.is_elementwise() => Ok(same_for_internal(sched)),

        Parameter | Constant | Iota => Ok(vec![]),

        // Table 1: Transpose — the split must stay outside the
        // transposed window for the producer's chunk to align:
        // `split_dim <= min_trans_dim` passes Row, `split_dim >=
        // max_trans_dim` passes Column. Outside the window the
        // permutation is the identity, so (split_dim, sword) carry over.
        Transpose => {
            if !internal(&ops[0]) {
                return Ok(vec![]);
            }
            match (instr.min_trans_dim(), instr.max_trans_dim()) {
                (None, _) | (_, None) => Ok(same_for_internal(sched)), // identity perm
                (Some(lo), Some(hi)) => match sched.sched_type {
                    SchedType::Row if sched.split_dim < lo => Ok(same_for_internal(sched)),
                    SchedType::Column if sched.split_dim > hi => Ok(same_for_internal(sched)),
                    _ => Err(Unsatisfiable::RuleViolation(
                        id,
                        "transpose: split inside transposed window",
                    )),
                },
            }
        }

        // Table 1: Reduce — all reduction dims must live inside one
        // thread block; the output split maps to the matching input dim
        // and must fall strictly left (Row) or right (Column) of the
        // reduced window.
        Reduce => {
            let in_shape = &comp.get(ops[0]).shape;
            let dims = instr.attrs.reduce_dims.as_ref().expect("verified");
            if !internal(&ops[0]) {
                return Ok(vec![]);
            }
            if dims.len() == in_shape.rank() {
                // Full reduction: only a single-block grid can see all
                // the data of an in-group producer.
                if sched.blocks(out_shape) != 1 {
                    return Err(Unsatisfiable::RuleViolation(id, "full reduce needs 1 block"));
                }
                return Ok(vec![(ops[0], Some(Schedule::fallback()))]);
            }
            let kept: Vec<usize> =
                (0..in_shape.rank()).filter(|d| !dims.contains(d)).collect();
            let isd = kept[sched.split_dim]; // input dim the output split maps to
            let lo = instr.min_reduce_dim();
            let hi = instr.max_reduce_dim();
            let ok = match sched.sched_type {
                SchedType::Row => isd < lo,
                SchedType::Column => isd > hi,
            };
            if !ok {
                return Err(Unsatisfiable::RuleViolation(
                    id,
                    "reduce: split does not clear the reduced window",
                ));
            }
            Ok(vec![(ops[0], Some(Schedule::new(isd, sched.sword, sched.sched_type)))])
        }

        // Table 1: BatchDot — with in-group producers, only Row
        // schedules over batch dims pass (`split_dim < num_dims - 2`);
        // operands share the batch dims.
        BatchDot => {
            if ops.iter().all(|o| !internal(o)) {
                return Ok(vec![]);
            }
            if sched.sched_type != SchedType::Row || sched.split_dim + 2 >= out_shape.rank() {
                return Err(Unsatisfiable::RuleViolation(
                    id,
                    "batch-dot: schedule must split a batch dim with Row",
                ));
            }
            Ok(same_for_internal(sched))
        }

        // Table 1: Reshape — transform (split_dim, sword) through the
        // element-count-preserving relayout, pass Row/Column.
        Reshape | Bitcast => {
            if !internal(&ops[0]) {
                return Ok(vec![]);
            }
            let in_shape = &comp.get(ops[0]).shape;
            match transform_through_reshape(out_shape, in_shape, sched) {
                Some(s) => Ok(vec![(ops[0], Some(s))]),
                None => Err(Unsatisfiable::RuleViolation(
                    id,
                    "reshape: no input split matches the grid",
                )),
            }
        }

        // Table 1: Broadcast — transform through the dim mapping; dims
        // created by the broadcast leave the operand unconstrained (each
        // block recomputes/rereads the small operand whole).
        Broadcast => {
            if !internal(&ops[0]) {
                return Ok(vec![]);
            }
            let bdims = instr.attrs.broadcast_dims.as_ref().expect("verified");
            let in_shape = &comp.get(ops[0]).shape;
            match bdims.iter().position(|&d| d == sched.split_dim) {
                // The mapped split only describes the same grid when no
                // broadcast-created dim contributes to the block count
                // (prefix for Row / suffix for Column); otherwise each
                // block sees a *slice* of the operand repeated — fall
                // back to per-block recomputation.
                Some(i) => {
                    let s = Schedule::new(i, sched.sword, sched.sched_type);
                    if s.is_valid_for(in_shape) && s.blocks(in_shape) == sched.blocks(out_shape)
                    {
                        Ok(vec![(ops[0], Some(s))])
                    } else {
                        Ok(vec![(ops[0], None)])
                    }
                }
                None => Ok(vec![(ops[0], None)]),
            }
        }

        // Concatenate: blocks agree iff the split stays on the
        // non-joined side (prefix products match for Row, suffix for
        // Column).
        Concatenate => {
            if ops.iter().all(|o| !internal(o)) {
                return Ok(vec![]);
            }
            let cdim = instr.attrs.concat_dim.expect("verified");
            let ok = match sched.sched_type {
                SchedType::Row => sched.split_dim < cdim,
                SchedType::Column => sched.split_dim > cdim,
            };
            if !ok {
                return Err(Unsatisfiable::RuleViolation(
                    id,
                    "concat: split crosses the joined dim",
                ));
            }
            Ok(same_for_internal(sched))
        }

        // Data-movement ops whose output chunks draw from input regions
        // no block-aligned producer schedule can match: in-group
        // producers fall back to per-block recomputation (thread
        // composition) — rejected upstream if they cannot be.
        Slice | Pad | Gather | DynamicSlice | DynamicUpdateSlice => {
            Ok(ops.iter().filter(|o| internal(o)).map(|&o| (o, None)).collect())
        }

        op if op.is_library_call() => {
            Err(Unsatisfiable::RuleViolation(id, "library calls are never fused"))
        }

        _ => Err(Unsatisfiable::RuleViolation(id, "op has no propagation rule")),
    }
}

/// Reshape transform: a `Row` schedule partitions the (row-major) linear
/// element space into `blocks` equal contiguous chunks, so any input
/// `(split_dim', sword')` producing the same block count describes the
/// same partition; `Column` is the mirror image on the reversed dims.
fn transform_through_reshape(out: &Shape, input: &Shape, sched: Schedule) -> Option<Schedule> {
    let target_blocks = sched.blocks(out);
    if target_blocks == 1 {
        return Some(Schedule::new(0, 1, sched.sched_type));
    }
    let rank = input.rank();
    let dims: Vec<i64> = match sched.sched_type {
        SchedType::Row => input.dims.clone(),
        SchedType::Column => input.dims.iter().rev().copied().collect(),
    };
    // Find (sd, sword): prod(dims[..sd]) * sword == target, sword | dims[sd].
    let mut prefix: i64 = 1;
    for sd in 0..rank {
        let t = target_blocks as i64;
        if t % prefix == 0 {
            let sword = t / prefix;
            if sword >= 1 && sword <= dims[sd] && dims[sd] % sword == 0 {
                let real_sd = match sched.sched_type {
                    SchedType::Row => sd,
                    SchedType::Column => rank - 1 - sd,
                };
                return Some(Schedule::new(real_sd, sword, sched.sched_type));
            }
        }
        prefix *= dims[sd];
        if prefix > target_blocks as i64 {
            break;
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::{GraphBuilder, Shape};

    fn all(comp: &Computation) -> HashSet<InstrId> {
        comp.ids().filter(|&i| !comp.get(i).opcode.is_free() || comp.get(i).opcode == Opcode::Bitcast).collect()
    }

    /// The motivating pattern: softmax + batch-dot, Row over the batch
    /// dim — the schedule used by our L1 Pallas kernel.
    #[test]
    fn figure3_row_schedule_satisfiable() {
        let mut b = GraphBuilder::new("fig3");
        let scores = b.param("scores", Shape::f32(&[8, 64, 64]));
        let v = b.param("v", Shape::f32(&[8, 64, 32]));
        let m = b.reduce(scores, &[2], ReduceKind::Max);
        let mb = b.broadcast(m, &[8, 64, 64], &[0, 1]);
        let sh = b.sub(scores, mb);
        let e = b.exp(sh);
        let s = b.reduce(e, &[2], ReduceKind::Sum);
        let sb = b.broadcast(s, &[8, 64, 64], &[0, 1]);
        let p = b.div(e, sb);
        let out = b.batch_dot(p, v);
        let comp = b.finish(out);

        let members = all(&comp);
        let sched = Schedule::new(0, 8, SchedType::Row); // one block per batch
        let res = propagate(&comp, &members, &[(out, sched)]).unwrap();
        assert_eq!(res.blocks, 8);
        // Every non-parameter member scheduled with 8 blocks.
        for (&id, st) in &res.assignment {
            if let OpSchedule::Scheduled(s) = st {
                assert_eq!(s.blocks(&comp.get(id).shape), 8, "at {id}");
            }
        }
        // The reduce over dim 2 propagates a Row split on dim 0.
        match res.assignment[&sh] {
            OpSchedule::Scheduled(s) => {
                assert_eq!(s.split_dim, 0);
                assert_eq!(s.sched_type, SchedType::Row);
            }
            _ => panic!("sub should be scheduled"),
        }
    }

    #[test]
    fn reduce_rejects_split_inside_window() {
        let mut b = GraphBuilder::new("r");
        let x = b.param("x", Shape::f32(&[4, 8, 16]));
        let e = b.exp(x);
        let r = b.reduce(e, &[0], ReduceKind::Sum); // reduce major dim
        let comp = b.finish(r);
        let members: HashSet<InstrId> = [e, r].into_iter().collect();
        // Row over the output's dim 0 maps to input dim 1 > min_reduce_dim=0.
        let bad = Schedule::new(0, 4, SchedType::Row);
        assert!(matches!(
            propagate(&comp, &members, &[(r, bad)]),
            Err(Unsatisfiable::RuleViolation(_, _))
        ));
        // Column over output dim 1 maps to input dim 2 > max_reduce_dim: ok.
        let good = Schedule::new(1, 4, SchedType::Column);
        let res = propagate(&comp, &members, &[(r, good)]).unwrap();
        assert_eq!(res.blocks, Schedule::new(1, 4, SchedType::Column).blocks(&Shape::f32(&[8, 16])));
    }

    #[test]
    fn full_reduce_needs_one_block() {
        let mut b = GraphBuilder::new("fr");
        let x = b.param("x", Shape::f32(&[32, 32]));
        let e = b.exp(x);
        let r = b.reduce(e, &[0, 1], ReduceKind::Sum);
        let comp = b.finish(r);
        let members: HashSet<InstrId> = [e, r].into_iter().collect();
        let res = propagate(&comp, &members, &[(r, Schedule::fallback())]).unwrap();
        assert_eq!(res.blocks, 1);
        assert_eq!(res.assignment[&e], OpSchedule::Scheduled(Schedule::fallback()));
    }

    #[test]
    fn transpose_row_passes_left_of_window() {
        let mut b = GraphBuilder::new("t");
        let x = b.param("x", Shape::f32(&[8, 4, 16]));
        let e = b.exp(x);
        let t = b.transpose(e, &[0, 2, 1]); // dims 1,2 move
        let comp = b.finish(t);
        let members: HashSet<InstrId> = [e, t].into_iter().collect();
        // split_dim 0 < min_trans_dim 1: Row passes
        let ok = Schedule::new(0, 8, SchedType::Row);
        assert!(propagate(&comp, &members, &[(t, ok)]).is_ok());
        // split_dim 1 inside the window: rejected
        let bad = Schedule::new(1, 2, SchedType::Row);
        assert!(propagate(&comp, &members, &[(t, bad)]).is_err());
    }

    #[test]
    fn reshape_transforms_split() {
        let mut b = GraphBuilder::new("rs");
        let x = b.param("x", Shape::f32(&[8, 64]));
        let e = b.exp(x);
        let r = b.reshape(e, &[8, 8, 8]);
        let t = b.tanh(r);
        let comp = b.finish(t);
        let members: HashSet<InstrId> = [e, r, t].into_iter().collect();
        // 8 blocks over the reshaped output → input split (0, 8) or (1, 1)...
        let sched = Schedule::new(0, 8, SchedType::Row);
        let res = propagate(&comp, &members, &[(t, sched)]).unwrap();
        match res.assignment[&e] {
            OpSchedule::Scheduled(s) => {
                assert_eq!(s.blocks(&Shape::f32(&[8, 64])), 8);
                assert_eq!(s.sched_type, SchedType::Row);
            }
            _ => panic!("exp should be scheduled"),
        }
    }

    #[test]
    fn reshape_rejects_unalignable_grid() {
        let mut b = GraphBuilder::new("rs2");
        let x = b.param("x", Shape::f32(&[7, 11]));
        let e = b.exp(x);
        let r = b.reshape(e, &[11, 7]);
        let comp = b.finish(r);
        let members: HashSet<InstrId> = [e, r].into_iter().collect();
        // 11 blocks on the [11,7] output cannot split [7,11] rows evenly
        // at any dim: 11 ∤ 7 and prefix 7 ∤ 11.
        let sched = Schedule::new(0, 11, SchedType::Row);
        assert!(propagate(&comp, &members, &[(r, sched)]).is_err());
    }

    #[test]
    fn broadcast_unconstrains_new_dims() {
        let mut b = GraphBuilder::new("bc");
        let x = b.param("x", Shape::f32(&[64]));
        let e = b.exp(x);
        let bc = b.broadcast(e, &[8, 64], &[1]); // dim 0 is new
        let t = b.tanh(bc);
        let comp = b.finish(t);
        let members: HashSet<InstrId> = [e, bc, t].into_iter().collect();
        let sched = Schedule::new(0, 8, SchedType::Row); // split the new dim
        let res = propagate(&comp, &members, &[(t, sched)]).unwrap();
        // exp feeds only the broadcast on an unsplit dim → inlined.
        assert_eq!(res.assignment[&e], OpSchedule::Inlined);
    }

    #[test]
    fn concat_split_must_avoid_joined_dim() {
        let mut b = GraphBuilder::new("cc");
        let x = b.param("x", Shape::f32(&[8, 16]));
        let y = b.param("y", Shape::f32(&[8, 16]));
        let ex = b.exp(x);
        let ey = b.exp(y);
        let c = b.concat(&[ex, ey], 1);
        let comp = b.finish(c);
        let members: HashSet<InstrId> = [ex, ey, c].into_iter().collect();
        assert!(propagate(&comp, &members, &[(c, Schedule::new(0, 8, SchedType::Row))]).is_ok());
        assert!(propagate(&comp, &members, &[(c, Schedule::new(1, 4, SchedType::Row))]).is_err());
    }

    #[test]
    fn conflict_detected() {
        // One producer consumed under two different demanded schedules.
        let mut b = GraphBuilder::new("conflict");
        let x = b.param("x", Shape::f32(&[4, 4, 16]));
        let e = b.exp(x);
        let r1 = b.reduce(e, &[2], ReduceKind::Sum); // [4,4]
        let t = b.transpose(e, &[1, 0, 2]);
        let r2 = b.reduce(t, &[2], ReduceKind::Sum); // [4,4]
        let s = b.add(r1, r2);
        let comp = b.finish(s);
        let members: HashSet<InstrId> = [e, r1, t, r2, s].into_iter().collect();
        // Splitting dim 0 of the sum: r1 demands e split at 0; r2 demands
        // t split at 0 → e split at 1 (perm). Conflict at e.
        let sched = Schedule::new(0, 4, SchedType::Row);
        let err = propagate(&comp, &members, &[(s, sched)]);
        assert!(matches!(err, Err(Unsatisfiable::Conflict(_)) | Err(Unsatisfiable::RuleViolation(_, _))));
    }

    #[test]
    fn multi_root_grid_agreement() {
        let mut b = GraphBuilder::new("mr");
        let x = b.param("x", Shape::f32(&[16, 8]));
        let e = b.exp(x);
        let t = b.tanh(x);
        let comp = b.finish(t);
        let members: HashSet<InstrId> = [e, t].into_iter().collect();
        let ok = propagate(
            &comp,
            &members,
            &[(e, Schedule::new(0, 4, SchedType::Row)), (t, Schedule::new(0, 4, SchedType::Row))],
        );
        assert!(ok.is_ok());
        let bad = propagate(
            &comp,
            &members,
            &[(e, Schedule::new(0, 4, SchedType::Row)), (t, Schedule::new(0, 2, SchedType::Row))],
        );
        assert!(bad.is_err());
    }
}
