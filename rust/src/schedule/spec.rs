//! Schedule specification — §4.1.
//!
//! A schedule on an instruction's output shape (its *work space*) is the
//! triple `(split_dim, sword, sched_type)`:
//!
//! - `split_dim` — the dimension where the work space is split;
//! - `sword` — how that dimension is partitioned (must divide its size);
//! - `sched_type` — `Row` or `Column`.
//!
//! The schedule determines `blocks`, the number of thread blocks (CTAs):
//! a `Row` schedule uses the dims on the left (more significant side) of
//! `split_dim` times `sword` as the grid; a `Column` schedule mirrors
//! this on the right (Fig. 5).

use crate::hlo::Shape;
use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchedType {
    Row,
    Column,
}

impl fmt::Display for SchedType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Schedule {
    pub split_dim: usize,
    pub sword: i64,
    pub sched_type: SchedType,
}

impl Schedule {
    pub fn new(split_dim: usize, sword: i64, sched_type: SchedType) -> Self {
        Schedule { split_dim, sword, sched_type }
    }

    /// The always-valid fallback (§4.3): `split_dim = 0`, `sword = 1`,
    /// Row — one thread block does everything.
    pub fn fallback() -> Self {
        Schedule::new(0, 1, SchedType::Row)
    }

    /// Is this schedule legal on `shape`?
    pub fn is_valid_for(&self, shape: &Shape) -> bool {
        if shape.rank() == 0 {
            return self.split_dim == 0 && self.sword == 1;
        }
        self.split_dim < shape.rank()
            && self.sword >= 1
            && shape.dims[self.split_dim] % self.sword == 0
    }

    /// Number of thread blocks (grid size) this schedule launches.
    ///
    /// `Row`: `prod(dims[0..split_dim]) * sword` — the Fig. 5 C-code.
    /// `Column`: `sword * prod(dims[split_dim+1..])`.
    pub fn blocks(&self, shape: &Shape) -> u64 {
        if shape.rank() == 0 {
            return 1;
        }
        debug_assert!(self.is_valid_for(shape), "{self:?} invalid for {shape}");
        let p: i64 = match self.sched_type {
            SchedType::Row => shape.dims[..self.split_dim].iter().product(),
            SchedType::Column => shape.dims[self.split_dim + 1..].iter().product(),
        };
        (p * self.sword).max(1) as u64
    }

    /// Elements each block processes.
    pub fn chunk_elements(&self, shape: &Shape) -> i64 {
        let b = self.blocks(shape) as i64;
        (shape.num_elements() / b).max(1)
    }

    /// Enumerate the full legal schedule space on `shape` (§4.1: the
    /// Cartesian product of legal `split_dim`, `sword`, `sched_type`
    /// values). Small by construction — this is what keeps compilation
    /// fast.
    pub fn enumerate(shape: &Shape) -> Vec<Schedule> {
        if shape.rank() == 0 {
            return vec![Schedule::fallback()];
        }
        let mut out = Vec::new();
        for sd in 0..shape.rank() {
            for sword in divisors(shape.dims[sd]) {
                for ty in [SchedType::Row, SchedType::Column] {
                    out.push(Schedule::new(sd, sword, ty));
                }
            }
        }
        out
    }
}

impl fmt::Display for Schedule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "({}, {}, {})", self.split_dim, self.sword, self.sched_type)
    }
}

/// All positive divisors of `n`, ascending. `divisors(0) = [1]` (degenerate
/// dims appear in rank-reducing corner cases).
pub fn divisors(n: i64) -> Vec<i64> {
    if n <= 0 {
        return vec![1];
    }
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            small.push(d);
            if d != n / d {
                large.push(n / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(7), vec![1, 7]);
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(0), vec![1]);
    }

    #[test]
    fn blocks_row_and_column() {
        let shape = Shape::f32(&[4, 6, 8]);
        // Row, split at dim 1 with sword 3: blocks = 4 * 3 = 12
        assert_eq!(Schedule::new(1, 3, SchedType::Row).blocks(&shape), 12);
        // Column, split at dim 1 with sword 3: blocks = 3 * 8 = 24
        assert_eq!(Schedule::new(1, 3, SchedType::Column).blocks(&shape), 24);
        // fallback = single block
        assert_eq!(Schedule::fallback().blocks(&shape), 1);
    }

    #[test]
    fn chunk_times_blocks_covers_workspace() {
        let shape = Shape::f32(&[4, 6, 8]);
        for s in Schedule::enumerate(&shape) {
            assert_eq!(
                s.chunk_elements(&shape) * s.blocks(&shape) as i64,
                shape.num_elements(),
                "schedule {s}"
            );
        }
    }

    #[test]
    fn enumerate_counts() {
        // dims [4,6]: (divisors(4)=3 + divisors(6)=4) * 2 types = 14
        let shape = Shape::f32(&[4, 6]);
        assert_eq!(Schedule::enumerate(&shape).len(), 14);
        for s in Schedule::enumerate(&shape) {
            assert!(s.is_valid_for(&shape));
        }
    }

    #[test]
    fn scalar_has_one_schedule() {
        let shape = Shape::f32(&[]);
        let e = Schedule::enumerate(&shape);
        assert_eq!(e.len(), 1);
        assert_eq!(e[0].blocks(&shape), 1);
    }

    #[test]
    fn validity_requires_divisibility() {
        let shape = Shape::f32(&[6]);
        assert!(Schedule::new(0, 3, SchedType::Row).is_valid_for(&shape));
        assert!(!Schedule::new(0, 4, SchedType::Row).is_valid_for(&shape));
        assert!(!Schedule::new(1, 1, SchedType::Row).is_valid_for(&shape));
    }
}
