//! The cost oracle — one seam between every fusion/tuning decision and
//! the latency numbers those decisions stand on.
//!
//! The paper's passes (deep fusion §3.2, schedule tuning §4.3, the
//! explore pass of PR 4) all consume *modeled* cost from
//! [`crate::gpusim::cost`], and the XLA fusion study (arXiv 2301.13062)
//! attributes most production mis-fusions to exactly that model error.
//! [`CostOracle`] turns the five scattered call-sites into one trait:
//!
//! - [`ModeledCost`] reproduces today's analytic path bit-for-bit — it
//!   is the identity overlay, so every pre-existing consumer produces
//!   byte-identical plans under it (the differential test in
//!   `tests/autotune.rs` pins this).
//! - [`MeasuredCost`] overlays per-group wall-clock estimates written
//!   back from the serving path ([`PerfLibrary`]'s measured store,
//!   keyed by the device-signed group fingerprint). Groups without
//!   enough samples fall through to the model, so the measured oracle
//!   degrades gracefully to the modeled one on cold fingerprints.
//!
//! Later tuning work (SIMD tiers, mixed precision, shape buckets) plugs
//! in as further `CostOracle` impls without touching the passes again.

use super::perf_library::PerfLibrary;
use super::spec::Schedule;
use crate::gpusim::cost::{kernel_time_us, KernelDesc};
use crate::gpusim::DeviceConfig;
use crate::hlo::{Computation, InstrId};
use std::collections::HashMap;

/// Where a pipeline run's cost numbers come from. Part of
/// [`crate::coordinator::PipelineConfig`]; folded into the compile-cache
/// config digest so modeled and measured compiles never share a cache
/// entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum CostSource {
    /// The analytic GPU model only — today's behavior, the default.
    #[default]
    Modeled,
    /// Measured per-group wall-clock overlays from the perf library's
    /// write-back store, falling back to the model where samples are
    /// missing or too few.
    Measured,
}

/// The one cost seam every fusion/tuning consumer queries.
///
/// Default methods forward to the analytic paths, so an oracle only
/// overrides the granularity it actually has data for: the measured
/// oracle overlays *group* costs (fingerprint-keyed wall clock) while
/// per-schedule lookups stay modeled — measured samples are per fused
/// group, not per (op, schedule) pair.
pub trait CostOracle {
    /// Cache/memo tag identifying this oracle's data generation: memo
    /// entries written under one tag are invisible under another, so a
    /// measured write-back (which bumps the epoch) can never be
    /// shadowed by a stale modeled verdict.
    fn source_tag(&self) -> String;

    /// The cost of one fused group: `modeled_us` is what the analytic
    /// path computed for it; an overlay may replace it.
    fn group_cost_us(&self, group_fp: u64, modeled_us: f64) -> f64;

    /// Per-(op, schedule) kernel time for the tuner's inner loop.
    fn schedule_cost_us(
        &self,
        lib: &mut PerfLibrary,
        comp: &Computation,
        id: InstrId,
        sched: Schedule,
        threads: u32,
    ) -> f64 {
        lib.lookup(comp, id, sched, threads)
    }

    /// Raw kernel-descriptor time (the fused-kernel estimate of
    /// `SchdConsistent` and the explore pass).
    fn kernel_time_us(&self, desc: &KernelDesc, dev: &DeviceConfig) -> f64 {
        kernel_time_us(desc, dev)
    }
}

/// The analytic model, unchanged: every method is the default identity
/// path. This is what all pre-existing entry points use.
#[derive(Debug, Clone, Copy, Default)]
pub struct ModeledCost;

impl CostOracle for ModeledCost {
    fn source_tag(&self) -> String {
        "m".to_string()
    }

    fn group_cost_us(&self, _group_fp: u64, modeled_us: f64) -> f64 {
        modeled_us
    }
}

/// Measured overlay: an owned snapshot of the perf library's per-group
/// wall-clock estimates (outlier-trimmed means over at least
/// [`super::perf_library::MEASURED_MIN_SAMPLES`] samples). Owning the
/// snapshot keeps the oracle usable alongside the `&mut PerfLibrary`
/// the passes already thread through.
#[derive(Debug, Clone, Default)]
pub struct MeasuredCost {
    overrides: HashMap<u64, f64>,
    epoch: u64,
}

impl MeasuredCost {
    /// Snapshot every group fingerprint with enough samples under the
    /// library's device signature. The epoch (total measured sample
    /// count) stamps the source tag so memo entries refresh as new
    /// samples land.
    pub fn from_library(lib: &PerfLibrary) -> Self {
        MeasuredCost { overrides: lib.measured_overrides(), epoch: lib.measured_epoch() }
    }

    /// Number of group fingerprints this oracle overlays.
    pub fn override_count(&self) -> usize {
        self.overrides.len()
    }

    /// The measured estimate for one group, if this snapshot holds one.
    pub fn override_for(&self, group_fp: u64) -> Option<f64> {
        self.overrides.get(&group_fp).copied()
    }
}

impl CostOracle for MeasuredCost {
    fn source_tag(&self) -> String {
        format!("w{:x}", self.epoch)
    }

    fn group_cost_us(&self, group_fp: u64, modeled_us: f64) -> f64 {
        self.overrides.get(&group_fp).copied().unwrap_or(modeled_us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modeled_oracle_is_the_identity() {
        let m = ModeledCost;
        assert_eq!(m.source_tag(), "m");
        assert_eq!(m.group_cost_us(0xdead, 42.5), 42.5);
        let desc = KernelDesc {
            bytes_read: 1 << 20,
            bytes_written: 1 << 20,
            flops: 1 << 20,
            blocks: 64,
            threads: 256,
            smem_bytes: 0,
            coalescing: 1.0,
            op_weight: 1.0,
        };
        let dev = DeviceConfig::pascal();
        assert_eq!(m.kernel_time_us(&desc, &dev), kernel_time_us(&desc, &dev));
    }

    #[test]
    fn measured_oracle_overlays_and_falls_back() {
        let mut o = MeasuredCost::default();
        o.overrides.insert(7, 123.0);
        o.epoch = 16;
        assert_eq!(o.group_cost_us(7, 5.0), 123.0);
        assert_eq!(o.group_cost_us(8, 5.0), 5.0, "unknown fingerprints fall back to the model");
        assert_eq!(o.source_tag(), "w10");
        assert_eq!(o.override_for(7), Some(123.0));
        assert_eq!(o.override_count(), 1);
    }
}
