//! Code generation — §5 of the paper.
//!
//! - [`shm_planner`] — shared-memory planning: size-requirements
//!   analysis, best-effort size shrinking (trade space for recompute)
//!   and dominance-tree space sharing (§5.1).
//! - [`emitter`] — `IrEmitterStitched` (Algorithm 2): block composition
//!   of per-op parallel loop emitters, falling back to the elemental
//!   (thread-composition) emitter where possible.
//! - [`kernel_plan`] — the emitted kernel artifact: launch dimensions,
//!   shared-memory layout, per-op emitters and pseudo-IR, plus the
//!   conversion into a simulator kernel descriptor.

pub mod emitter;
pub mod kernel_plan;
pub mod shm_planner;

pub use emitter::emit_group;
pub use kernel_plan::KernelPlan;
pub use shm_planner::{plan_shared_memory, plan_shared_memory_spill, ShmError, ShmPlan};
