//! `IrEmitterStitched` — Algorithm 2, §5.2.
//!
//! Walks the fused computation in emission (topological) order and, per
//! instruction, decides the emitter:
//!
//! ```text
//! if !root && !shared.count(hlo) && !dot && !reduce:
//!     return ElementalIrEmitter(hlo)        # thread composition
//! StitchedEmitter(hlo, schedule)            # own parallel loop
//! if shared.count(hlo):  EmitWriteSharedArray
//! if root:               EmitWriteOutputArray
//! else:                  EmitGenerator(generators, hlo)
//! ```
//!
//! We emit pseudo-IR (inspectable text) rather than LLVM IR; the numeric
//! hot path of the reproduction is executed by the PJRT runtime instead
//! (see DESIGN.md). The *decisions* — who gets a loop, who is inlined,
//! who touches shared memory, barrier placement — are the contribution
//! and are fully implemented.

use super::kernel_plan::{EmittedOp, EmitterKind, KernelPlan};
use super::shm_planner::plan_shared_memory_spill;
use crate::gpusim::DeviceConfig;
use crate::hlo::{Computation, InstrId, Opcode};
use crate::schedule::{OpSchedule, TunedPlan};
use std::collections::HashSet;

/// Emit the kernel plan for one fused group.
pub fn emit_group(
    comp: &Computation,
    members: &HashSet<InstrId>,
    roots: &[InstrId],
    tuned: &TunedPlan,
    dev: &DeviceConfig,
    name: &str,
) -> crate::Result<KernelPlan> {
    // The spill-capable planner never rejects a group: mandatory
    // buffers that overflow the budget land in `shm.spilled` and are
    // stitched through global memory (third tier) instead.
    let shm = plan_shared_memory_spill(comp, members, roots, tuned, dev);
    let spilled: HashSet<InstrId> = shm.spilled.iter().copied().collect();
    let root_set: HashSet<InstrId> = roots.iter().copied().collect();

    // Emission order: ascending id = topological.
    let mut order: Vec<InstrId> = members.iter().copied().collect();
    order.sort_unstable();

    // `generators` — ops whose values are produced on demand inside a
    // consumer's loop (thread composition), like XLA's generators_ map.
    let mut generators: HashSet<InstrId> = HashSet::new();
    let mut ops: Vec<EmittedOp> = Vec::new();

    for id in order {
        let instr = comp.get(id);
        let is_root = root_set.contains(&id);
        let in_shared = shm.slots.contains_key(&id);
        let is_dot = instr.opcode == Opcode::BatchDot;
        let is_reduce = instr.opcode.is_reduce();
        let assigned = tuned.assignment.get(&id).copied();

        // Algorithm 2's dispatch: plain interior ops without a shared
        // buffer fall back to the elemental emitter.
        if !is_root && !in_shared && !is_dot && !is_reduce {
            generators.insert(id);
            ops.push(EmittedOp {
                id,
                emitter: EmitterKind::Elemental,
                writes_shared: false,
                writes_output: false,
                writes_spill: false,
                ir: vec![format!(
                    "  ; %{} {} -> generator (thread composition)",
                    id.0, instr.opcode
                )],
            });
            continue;
        }

        // StitchedEmitter: needs the tuned schedule.
        let sched = match assigned {
            Some(OpSchedule::Scheduled(s)) => s,
            // A shared/root op that tuning marked inlined (possible for
            // trivially-inlinable roots): emit elementally.
            _ => {
                generators.insert(id);
                ops.push(EmittedOp {
                    id,
                    emitter: EmitterKind::Elemental,
                    writes_shared: false,
                    writes_output: is_root,
                    writes_spill: false,
                    ir: vec![format!("  ; %{} {} -> elemental (inlined)", id.0, instr.opcode)],
                });
                continue;
            }
        };

        let mut ir = Vec::new();
        ir.push(format!(
            "  ; %{} {} stitched loop: split_dim={} sword={} {} chunk={}",
            id.0,
            instr.opcode,
            sched.split_dim,
            sched.sword,
            sched.sched_type,
            sched.chunk_elements(&instr.shape),
        ));
        // Operand access: shared array, spill region, generator call,
        // or global load.
        for &op in &instr.operands {
            if let Some(slot) = shm.slots.get(&op) {
                ir.push(format!("  %v{} = load shared [off={} {}B]", op.0, slot.offset, slot.bytes));
            } else if spilled.contains(&op) {
                ir.push(format!("  %v{} = load global %{} ; spill region (post-fence)", op.0, op.0));
            } else if generators.contains(&op) {
                ir.push(format!("  %v{} = call generator_{}()", op.0, op.0));
            } else {
                ir.push(format!("  %v{} = load global %{}", op.0, op.0));
            }
        }
        ir.push(emit_body(comp, id));

        let mut writes_shared = false;
        if let Some(slot) = shm.slots.get(&id) {
            writes_shared = true;
            let tag = match slot.reused_from {
                Some(prev) => format!("SHARE(from=%{})", prev.0),
                None => "ALLOC".to_string(),
            };
            ir.push(format!(
                "  store shared [off={} {}B] {} ; EmitWriteSharedArray",
                slot.offset, slot.bytes, tag
            ));
            // Block composition: consumers with different loop emitters
            // must see completed shared writes.
            ir.push("  barrier ; __syncthreads".to_string());
        }
        let in_spill = spilled.contains(&id);
        if is_root {
            ir.push(format!("  store global %{} ; EmitWriteOutputArray", id.0));
        } else if in_spill {
            // Third tier: the whole value goes to a grid-visible
            // arena region; every block must observe the completed
            // write before any consumer phase starts.
            ir.push(format!("  store global %{} ; EmitWriteSpillArray", id.0));
            ir.push("  grid_fence ; grid.sync".to_string());
        } else if !writes_shared {
            generators.insert(id);
            ir.push(format!("  ; register generator_{} (EmitGenerator)", id.0));
        }

        ops.push(EmittedOp {
            id,
            emitter: EmitterKind::Stitched(sched),
            writes_shared,
            writes_output: is_root,
            writes_spill: in_spill,
            ir,
        });
    }

    Ok(KernelPlan {
        name: name.to_string(),
        blocks: tuned.blocks,
        threads: tuned.threads,
        shm,
        ops,
        est_exec_us: tuned.est_exec_us,
    })
}

fn emit_body(comp: &Computation, id: InstrId) -> String {
    let instr = comp.get(id);
    match instr.opcode {
        Opcode::Reduce => {
            let dims = instr.attrs.reduce_dims.as_ref().unwrap();
            let kind = instr.attrs.reduce_kind.unwrap();
            format!(
                "  %v{} = warp_reduce.{kind:?} dims={dims:?} ; cooperative tree reduce",
                id.0
            )
        }
        Opcode::BatchDot => format!("  %v{} = block_tile_matmul ; smem-tiled MMA", id.0),
        Opcode::Transpose => {
            format!("  %v{} = smem_tiled_transpose perm={:?}", id.0, instr.attrs.transpose_perm.as_ref().unwrap())
        }
        op => format!("  %v{} = {} elementwise-lane", id.0, op),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::{GraphBuilder, Shape};
    use crate::schedule::{tune, PerfLibrary, TuningConfig};

    fn emit_fig3() -> (Computation, Vec<InstrId>, KernelPlan) {
        let mut b = GraphBuilder::new("fig3");
        let scores = b.param("scores", Shape::f32(&[8, 64, 64]));
        let v = b.param("v", Shape::f32(&[8, 64, 32]));
        let m = b.reduce(scores, &[2], ReduceKind::Max);
        let mb = b.broadcast(m, &[8, 64, 64], &[0, 1]);
        let sh = b.sub(scores, mb);
        let e = b.exp(sh);
        let s = b.reduce(e, &[2], ReduceKind::Sum);
        let sb = b.broadcast(s, &[8, 64, 64], &[0, 1]);
        let p = b.div(e, sb);
        let out = b.batch_dot(p, v);
        let comp = b.finish(out);
        let ids = vec![m, mb, sh, e, s, sb, p, out];
        let members: HashSet<InstrId> = ids.iter().copied().collect();
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let tuned = tune(&comp, &members, &[out], &mut lib, &TuningConfig::default()).unwrap();
        let plan =
            emit_group(&comp, &members, &[out], &tuned, &DeviceConfig::pascal(), "fig3").unwrap();
        (comp, ids, plan)
    }

    #[test]
    fn figure3_emission_structure() {
        let (_, ids, plan) = emit_fig3();
        let (m, e, s, p, out) = (ids[0], ids[3], ids[4], ids[6], ids[7]);
        let find = |id: InstrId| plan.ops.iter().find(|o| o.id == id).unwrap();

        // Interior reduces + shared expensive ops get stitched loops and
        // write shared memory.
        for id in [m, e, s, p] {
            let op = find(id);
            assert!(matches!(op.emitter, EmitterKind::Stitched(_)), "{id} should stitch");
            assert!(op.writes_shared, "{id} should write shared memory");
        }
        // The root batch-dot writes global output.
        let root = find(out);
        assert!(root.writes_output);
        assert!(!root.writes_shared);
        // Broadcasts/sub are thread-composed.
        let bcast = find(ids[1]);
        assert_eq!(bcast.emitter, EmitterKind::Elemental);
    }

    #[test]
    fn barriers_follow_shared_writes() {
        let (_, _, plan) = emit_fig3();
        let text = plan.ir_text();
        let writes = text.matches("EmitWriteSharedArray").count();
        let barriers = text.matches("__syncthreads").count();
        assert_eq!(writes, barriers);
        assert!(writes >= 4);
        assert!(text.contains("SHARE(from="), "space sharing should appear in the IR");
    }

    #[test]
    fn every_shared_read_is_fenced_by_a_barrier() {
        // Block composition's ordering contract: a shared region may
        // only be read after its write has been fenced by
        // __syncthreads. Scan the emitted IR in order: stores mark the
        // offset pending, a barrier publishes all pending offsets, and
        // every load must hit a published offset.
        let (_, _, plan) = emit_fig3();
        let off_of = |line: &str| -> usize {
            let start = line.find("[off=").expect("offset tag") + 5;
            line[start..]
                .split_whitespace()
                .next()
                .unwrap()
                .parse()
                .expect("offset value")
        };
        let mut pending: Vec<usize> = Vec::new();
        let mut published: Vec<usize> = Vec::new();
        let mut loads = 0usize;
        for line in plan.ir_text().lines() {
            if line.contains("store shared") {
                pending.push(off_of(line));
            } else if line.contains("__syncthreads") {
                published.append(&mut pending);
            } else if line.contains("load shared") {
                loads += 1;
                let off = off_of(line);
                assert!(
                    published.contains(&off),
                    "shared load at offset {off} before its write was fenced:\n{}",
                    plan.ir_text()
                );
            }
        }
        assert!(loads >= 3, "fig3 must read shared memory repeatedly ({loads})");
    }

    #[test]
    fn pure_elementwise_group_uses_single_loop() {
        let mut b = GraphBuilder::new("ew");
        let x = b.param("x", Shape::f32(&[1024]));
        let a = b.add(x, x);
        let t = b.tanh(a);
        let comp = b.finish(t);
        let members: HashSet<InstrId> = [a, t].into_iter().collect();
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let tuned = tune(&comp, &members, &[t], &mut lib, &TuningConfig::default()).unwrap();
        let plan = emit_group(&comp, &members, &[t], &tuned, &DeviceConfig::pascal(), "ew").unwrap();
        // add is a generator; only tanh has a stitched loop.
        let stitched = plan
            .ops
            .iter()
            .filter(|o| matches!(o.emitter, EmitterKind::Stitched(_)))
            .count();
        assert_eq!(stitched, 1);
        assert_eq!(plan.shm.total_bytes, 0);
    }

    #[test]
    fn overflowing_group_emits_spill_store_and_grid_fence() {
        // The consistency checker's overflow shape: a scalar root pins
        // the grid to one block, so the interior reduce's 32 KB chunk
        // exceeds pascal's 20 KB budget and must spill to the global
        // tier instead of failing emission.
        let mut b = GraphBuilder::new("ovf");
        let x = b.param("x", Shape::f32(&[64, 8192]));
        let e = b.exp(x);
        let r = b.reduce(e, &[0], ReduceKind::Sum);
        let t = b.tanh(r);
        let rr = b.reduce(t, &[0], ReduceKind::Sum);
        let comp = b.finish(rr);
        let members: HashSet<InstrId> = [e, r, t, rr].into_iter().collect();
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let tuned = tune(&comp, &members, &[rr], &mut lib, &TuningConfig::default()).unwrap();
        let plan =
            emit_group(&comp, &members, &[rr], &tuned, &DeviceConfig::pascal(), "ovf").unwrap();
        assert!(plan.shm.spilled.contains(&r), "interior reduce must spill");
        let op = plan.ops.iter().find(|o| o.id == r).unwrap();
        assert!(op.writes_spill && !op.writes_shared && !op.writes_output);
        let text = plan.ir_text();
        assert!(text.contains("EmitWriteSpillArray"));
        assert!(text.contains("grid.sync"));
    }

    #[test]
    fn ir_mentions_launch_dims() {
        let (_, _, plan) = emit_fig3();
        let text = plan.ir_text();
        assert!(text.contains(&format!("<<<{}, {}>>>", plan.blocks, plan.threads)));
    }
}
